//! Scenario driver for the paper's §6.5 scalability study: how much of
//! the fine-grain DVFS opportunity survives as V/f domains grow from one
//! CU to half the chip — the question an SoC architect asks when deciding
//! how many IVR rails to budget.
//!
//! Usage: cargo run --release --example domain_granularity

use pcstall::config::SimConfig;
use pcstall::dvfs::manager::{DvfsManager, Policy, RunMode};
use pcstall::dvfs::objective::Objective;
use pcstall::models::EstModel;
use pcstall::power::params::F_STATIC_IDX;
use pcstall::stats::emit::print_table;
use pcstall::workloads;

fn main() {
    let n_cu = 8;
    let grans = [1usize, 2, 4];
    let workload_set = ["comd", "hacc", "xsbench", "dgemm", "BwdBN"];

    let mut rows = Vec::new();
    for &g in &grans {
        let mut imp_pc = Vec::new();
        let mut imp_crisp = Vec::new();
        let mut imp_or = Vec::new();
        for wl_name in workload_set {
            let run = |policy: Policy| {
                let mut cfg = SimConfig::default();
                cfg.gpu.n_cu = n_cu;
                cfg.gpu.n_wf = 16;
                cfg.dvfs.cus_per_domain = g;
                let wl = workloads::build(wl_name, 0.08);
                let mut mgr = DvfsManager::new(cfg, &wl, policy, Objective::Ed2p);
                mgr.run(RunMode::Completion { max_epochs: 100_000 }, wl_name)
            };
            let base = run(Policy::Static(F_STATIC_IDX)).ed2p();
            imp_crisp.push((1.0 - run(Policy::Reactive(EstModel::Crisp)).ed2p() / base) * 100.0);
            imp_pc.push((1.0 - run(Policy::PcStall).ed2p() / base) * 100.0);
            imp_or.push((1.0 - run(Policy::Oracle).ed2p() / base) * 100.0);
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        rows.push(vec![
            format!("{g} CU/domain ({} domains)", n_cu / g),
            format!("{:+.1}%", mean(&imp_crisp)),
            format!("{:+.1}%", mean(&imp_pc)),
            format!("{:+.1}%", mean(&imp_or)),
        ]);
    }
    print_table(
        "ED²P improvement vs static 1.7 GHz by V/f-domain granularity (§6.5)",
        &["granularity", "CRISP", "PCSTALL", "ORACLE"],
        &rows,
    );
    println!("\npaper: opportunity shrinks with coarser domains; PCSTALL keeps");
    println!("most of ORACLE's win even at large granularity (18% vs 24% @32CU).");
}
