//! End-to-end validation driver (the repo's headline experiment).
//!
//! Runs the full system — wavefront GPU simulator, per-CU V/f domains,
//! the PJRT-compiled `dvfs_step` artifact on the epoch hot path, the
//! PCSTALL predictor — over the paper's workload suite, and reports the
//! paper's headline metric: ED²P normalized to static 1.7 GHz, for
//! PCSTALL vs CRISP (state-of-art reactive) vs ORACLE.
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.
//!
//! Usage: cargo run --release --example full_gpu_ed2p [-- --full]

use pcstall::config::SimConfig;
use pcstall::dvfs::manager::{DvfsManager, Policy, RunMode};
use pcstall::dvfs::objective::Objective;
use pcstall::models::EstModel;
use pcstall::power::params::F_STATIC_IDX;
use pcstall::runtime;
use pcstall::stats::emit::print_table;
use pcstall::util::geomean;
use pcstall::workloads;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let mut cfg = SimConfig::default();
    if !full {
        cfg.gpu.n_cu = 8;
        cfg.gpu.n_wf = 16;
        cfg.gpu.l2_bytes = 1024 * 1024;
    }
    let waves = if full { 1.0 } else { 0.1 };
    let policies = [
        Policy::Static(F_STATIC_IDX),
        Policy::Reactive(EstModel::Crisp),
        Policy::PcStall,
        Policy::Oracle,
    ];

    // PCSTALL runs on the PJRT artifact when available — the proof that
    // all three layers compose: JAX/Pallas-authored math, AOT-lowered to
    // HLO, executed from the Rust hot path at every epoch boundary.
    println!(
        "== full_gpu_ed2p: {} CUs x {} WFs, {} workloads ==",
        cfg.gpu.n_cu,
        cfg.gpu.n_wf,
        workloads::names().len()
    );

    let mut rows = Vec::new();
    let mut norm_crisp = Vec::new();
    let mut norm_pc = Vec::new();
    let mut norm_or = Vec::new();
    let t0 = std::time::Instant::now();

    for wl_name in workloads::names() {
        let wl = workloads::build(wl_name, waves);
        let mut results = Vec::new();
        for &p in &policies {
            let mut mgr = if p == Policy::PcStall {
                DvfsManager::with_backend(
                    cfg.clone(),
                    &wl,
                    p,
                    Objective::Ed2p,
                    runtime::best_backend(None),
                )
            } else {
                DvfsManager::new(cfg.clone(), &wl, p, Objective::Ed2p)
            };
            let r = mgr.run(RunMode::Completion { max_epochs: 100_000 }, wl_name);
            assert!(r.completed, "{wl_name}/{} did not complete", p.name());
            results.push(r);
        }
        let base = results[0].ed2p();
        let n = |i: usize| results[i].ed2p() / base;
        norm_crisp.push(n(1));
        norm_pc.push(n(2));
        norm_or.push(n(3));
        rows.push(vec![
            wl_name.to_string(),
            format!("{:.3}", n(1)),
            format!("{:.3}", n(2)),
            format!("{:.3}", n(3)),
            format!("{:.3}", results[2].mean_accuracy),
        ]);
    }

    print_table(
        "ED²P normalized to STATIC-1.7 (lower is better)",
        &["workload", "CRISP", "PCSTALL", "ORACLE", "PCSTALL acc"],
        &rows,
    );
    println!("\ngeomean normalized ED²P:");
    println!("  CRISP   {:.3}   (paper ~0.77)", geomean(&norm_crisp));
    println!("  PCSTALL {:.3}   (paper ~0.52)", geomean(&norm_pc));
    println!("  ORACLE  {:.3}   (paper ~0.46)", geomean(&norm_or));
    let pc_capture = (1.0 - geomean(&norm_pc)) / (1.0 - geomean(&norm_or)).max(1e-9) * 100.0;
    println!("\nPCSTALL captures {pc_capture:.0}% of the ORACLE opportunity (paper: ~89%)");
    println!("total wall time: {:.1?}", t0.elapsed());
}
