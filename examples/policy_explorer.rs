//! Scenario driver: sweep every policy × objective on one workload and
//! print the power/performance trade-off surface — the tool a power
//! architect would use to pick an operating policy for a product.
//!
//! Usage: cargo run --release --example policy_explorer [-- <workload>]

use pcstall::config::SimConfig;
use pcstall::dvfs::manager::{DvfsManager, Policy, RunMode};
use pcstall::dvfs::objective::Objective;
use pcstall::power::params::{FREQS_GHZ, N_FREQ};
use pcstall::stats::emit::print_table;
use pcstall::workloads;

fn main() {
    let wl_name = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with("--"))
        .unwrap_or_else(|| "BwdBN".to_string());
    let mut cfg = SimConfig::default();
    cfg.gpu.n_cu = 8;
    cfg.gpu.n_wf = 16;

    let objectives = [
        Objective::Edp,
        Objective::Ed2p,
        Objective::EnergyBound { max_slowdown: 0.05 },
        Objective::EnergyBound { max_slowdown: 0.10 },
    ];
    let mut policies = vec![
        Policy::Static(0),
        Policy::Static(4),
        Policy::Static(N_FREQ - 1),
    ];
    policies.extend(Policy::all_dvfs());

    let mut rows = Vec::new();
    for p in policies {
        for (oi, &obj) in objectives.iter().enumerate() {
            // statics ignore the objective; run them once
            if matches!(p, Policy::Static(_)) && oi > 0 {
                continue;
            }
            let wl = workloads::build(&wl_name, 0.1);
            let mut mgr = DvfsManager::new(cfg.clone(), &wl, p, obj);
            let r = mgr.run(RunMode::Completion { max_epochs: 100_000 }, &wl_name);
            let share = r.freq_time_share();
            let dominant = share
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(k, s)| format!("{:.1}GHz {:.0}%", FREQS_GHZ[k], s * 100.0))
                .unwrap();
            rows.push(vec![
                r.policy.clone(),
                r.objective.clone(),
                format!("{:.2}", r.total_time_ns / 1e6),
                format!("{:.4}", r.total_energy_j),
                format!("{:.3e}", r.edp()),
                format!("{:.3e}", r.ed2p()),
                format!("{:.3}", r.mean_accuracy),
                dominant,
            ]);
        }
    }
    print_table(
        &format!("policy × objective surface — workload {wl_name}"),
        &[
            "policy", "objective", "time_ms", "energy_J", "EDP", "ED2P", "accuracy", "dominant f",
        ],
        &rows,
    );
}
