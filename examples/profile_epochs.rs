//! Profiling helper: run compute-heavy epochs in a tight loop.
use pcstall::config::SimConfig;
use pcstall::sim::gpu::Gpu;
use pcstall::workloads;
fn main() {
    let mut cfg = SimConfig::default();
    cfg.gpu.n_cu = 8; cfg.gpu.n_wf = 16;
    let spec = workloads::build("hacc", 1.0);
    let mut g = Gpu::new(cfg);
    g.load_workload(spec.launches(), spec.rounds);
    let t0 = std::time::Instant::now();
    let mut n = 0u64;
    while t0.elapsed().as_secs_f64() < 4.0 {
        g.run_epoch();
        n += 1;
        if g.workload_done() {
            let spec = workloads::build("hacc", 1.0);
            g.load_workload(spec.launches(), spec.rounds);
        }
    }
    let cycles: u64 = g.cus.iter().map(|c| c.counters.cycles).sum();
    println!("epochs {n}, last epoch cycles {cycles}, {:.1} epochs/s", n as f64 / 4.0);
}
