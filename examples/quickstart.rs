//! Quickstart: one workload, PCSTALL vs static 1.7 GHz, ED²P report.
use pcstall::config::SimConfig;
use pcstall::dvfs::manager::{DvfsManager, Policy, RunMode};
use pcstall::dvfs::objective::Objective;
use pcstall::workloads;

fn main() {
    let mut cfg = SimConfig::small();
    cfg.gpu.n_cu = 8;
    cfg.gpu.n_wf = 16;
    let wl = workloads::build("comd", 0.2);

    let t0 = std::time::Instant::now();
    let mut m = DvfsManager::new(cfg.clone(), &wl, Policy::Static(4), Objective::Ed2p);
    let st = m.run(RunMode::Completion { max_epochs: 5000 }, "comd");
    println!("static: {} epochs, {:.2?}, E={:.4} J, done={}", st.records.len(), t0.elapsed(), st.total_energy_j, st.completed);

    let t0 = std::time::Instant::now();
    let mut m = DvfsManager::new(cfg.clone(), &wl, Policy::PcStall, Objective::Ed2p);
    let pc = m.run(RunMode::Completion { max_epochs: 5000 }, "comd");
    println!("pcstall: {} epochs, {:.2?}, E={:.4} J done={}", pc.records.len(), t0.elapsed(), pc.total_energy_j, pc.completed);

    let t0 = std::time::Instant::now();
    let mut m = DvfsManager::new(cfg, &wl, Policy::Oracle, Objective::Ed2p);
    let or = m.run(RunMode::Completion { max_epochs: 5000 }, "comd");
    println!("oracle: {} epochs, {:.2?}, E={:.4} J done={}", or.records.len(), t0.elapsed(), or.total_energy_j, or.completed);

    println!("ED2P: static {:.4e}  pcstall {:.4e} ({:+.1}%)  oracle {:.4e} ({:+.1}%)",
        st.ed2p(), pc.ed2p(), (pc.ed2p()/st.ed2p()-1.0)*100.0, or.ed2p(), (or.ed2p()/st.ed2p()-1.0)*100.0);
    println!("accuracy: pcstall {:.3} oracle {:.3}", pc.mean_accuracy, or.mean_accuracy);
}
