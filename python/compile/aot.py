"""AOT lowering: jax ``dvfs_step`` -> HLO text for the Rust PJRT runtime.

HLO *text* (NOT ``lowered.compile().serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids
which the ``xla`` crate's bundled xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``).  The text parser reassigns ids and
round-trips cleanly — see /opt/xla-example/README.md.

Usage (from ``make artifacts``):
    cd python && python -m compile.aot --out ../artifacts/dvfs_step.hlo.txt
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import params as P
from .model import dvfs_step, example_args


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_path: str, n_cu: int = P.N_CU, n_wf: int = P.N_WF) -> dict:
    lowered = jax.jit(dvfs_step).lower(*example_args(n_cu=n_cu, n_wf=n_wf))
    text = to_hlo_text(lowered)
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        f.write(text)

    # Metadata sidecar: the Rust runtime validates shapes + constants hash
    # so a stale artifact fails loudly instead of silently mispredicting.
    meta = {
        "artifact": os.path.basename(out_path),
        "n_cu": n_cu,
        "n_wf": n_wf,
        "n_dom": n_cu,
        "n_freq": P.N_FREQ,
        "freqs_ghz": P.FREQS_GHZ,
        "constants": {
            "v0": P.V0_VOLTS,
            "kv": P.KV_VOLTS_PER_GHZ,
            "vnom": P.V_NOM,
            "c1": P.C1_W,
            "c2": P.C2_W,
            "l0": P.L0_W,
            "lv": P.LV_PER_VOLT,
            "eta0": P.ETA0,
            "eta_slope": P.ETA_SLOPE,
            "eps": P.EPS,
        },
        "hlo_sha256": hashlib.sha256(text.encode()).hexdigest(),
        "inputs": [
            {"name": "instr", "shape": [n_cu, n_wf]},
            {"name": "t_core_ns", "shape": [n_cu, n_wf]},
            {"name": "age_factor", "shape": [n_cu, n_wf]},
            {"name": "freq_ghz", "shape": [n_cu]},
            {"name": "pred_sens", "shape": [n_cu]},
            {"name": "pred_i0", "shape": [n_cu]},
            {"name": "mask", "shape": [n_cu]},
            {"name": "n_exp", "shape": [1]},
            {"name": "epoch_ns", "shape": [1]},
        ],
        "outputs": [
            {"name": "sens_wf", "shape": [n_cu, n_wf]},
            {"name": "sens_cu", "shape": [n_cu]},
            {"name": "i0_cu", "shape": [n_cu]},
            {"name": "pred_instr", "shape": [n_cu, P.N_FREQ]},
            {"name": "power_w", "shape": [n_cu, P.N_FREQ]},
            {"name": "ednp", "shape": [n_cu, P.N_FREQ]},
            {"name": "best_idx", "shape": [n_cu]},
        ],
    }
    meta_path = os.path.splitext(out_path)[0]
    if meta_path.endswith(".hlo"):
        meta_path = meta_path[: -len(".hlo")]
    meta_path += ".meta.json"
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2)
    return meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/dvfs_step.hlo.txt")
    ap.add_argument("--n-cu", type=int, default=P.N_CU)
    ap.add_argument("--n-wf", type=int, default=P.N_WF)
    args = ap.parse_args()
    meta = build(args.out, n_cu=args.n_cu, n_wf=args.n_wf)
    print(
        f"wrote {args.out} (n_cu={meta['n_cu']}, n_wf={meta['n_wf']}, "
        f"sha256={meta['hlo_sha256'][:12]}...)"
    )


if __name__ == "__main__":
    main()
