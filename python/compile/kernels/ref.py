"""Pure-jnp reference oracle for the two Pallas kernels.

These functions define the *semantics* of the DVFS step; the Pallas
kernels in ``sensitivity.py`` / ``selector.py`` must match them under
``jnp.allclose`` (pytest + hypothesis enforce this).  The Rust native
implementation (``rust/src/dvfs/native.rs``) mirrors the same math and a
parity integration test compares it against the AOT artifact.
"""

import jax.numpy as jnp

from .. import params as P


def wf_sensitivity_ref(instr, t_core_ns, age_factor, freq_ghz, epoch_ns):
    """Wavefront-level STALL-model sensitivity estimate (paper §4.4).

    ``Sens_WF = IPC_WF x T_core,WF`` normalized by the wavefront's
    scheduling-age contention factor.

    Args:
      instr:       [n_cu, n_wf] f32 — instructions committed this epoch.
      t_core_ns:   [n_cu, n_wf] f32 — non-stalled (core) time in ns.
      age_factor:  [n_cu, n_wf] f32 — contention normalization in (0, 1].
      freq_ghz:    [n_cu]       f32 — CU operating frequency this epoch.
      epoch_ns:    scalar f32   — epoch duration (IPC denominator).

    Returns:
      sens_wf: [n_cu, n_wf] — per-wavefront dI/df (instr per GHz).
      sens_cu: [n_cu]       — CU-level sensitivity (sum over wavefronts).
      i0_cu:   [n_cu]       — CU-level intercept of I_f = I0 + S*f, >= 0.
    """
    instr = jnp.asarray(instr, jnp.float32)
    t_core_ns = jnp.asarray(t_core_ns, jnp.float32)
    age_factor = jnp.asarray(age_factor, jnp.float32)
    freq_ghz = jnp.asarray(freq_ghz, jnp.float32)

    f_col = freq_ghz[:, None]
    cycles_epoch = jnp.float32(epoch_ns) * f_col  # epoch cycles at f
    ipc = instr / jnp.maximum(cycles_epoch, P.EPS)
    sens_wf = ipc * t_core_ns * age_factor
    sens_cu = jnp.sum(sens_wf, axis=1)
    i0_cu = jnp.maximum(jnp.sum(instr, axis=1) - sens_cu * freq_ghz, 0.0)
    return sens_wf, sens_cu, i0_cu


def freq_grid_ref(sens_dom, i0_dom, mask, n_exp, epoch_ns):
    """Objective-grid evaluation over all (domain x V/f-state) pairs.

    For each domain d and frequency state k:
      I[d,k]    = max(I0[d] + S[d] * f_k, eps)        predicted instructions
      rate[d,k] = I / epoch_ns                        Ginstr/s
      P[d,k]    = (C1 V^2 rate + C2 V^2 f + L0 e^{LV (V - Vnom)}) / eta(f)
      ednp[d,k] = P / rate^n_exp   (n_exp = 2 -> EDP, 3 -> ED^2P)

    Masked-out domains get ednp = +inf on all but state 0 so argmin is
    deterministic.

    Args:
      sens_dom: [n_dom] f32 — predicted sensitivity per domain.
      i0_dom:   [n_dom] f32 — predicted intercept per domain.
      mask:     [n_dom] f32 — 1.0 for active domains, 0.0 for padding.
      n_exp:    scalar f32 — delay exponent + 1 (ED^{n}P => n + 1).
      epoch_ns: scalar f32 — epoch duration in nanoseconds.

    Returns:
      pred_instr: [n_dom, N_FREQ]
      power_w:    [n_dom, N_FREQ]
      ednp:       [n_dom, N_FREQ]
      best_idx:   [n_dom] f32 — argmin_k ednp (index as float).
    """
    sens_dom = jnp.asarray(sens_dom, jnp.float32)
    i0_dom = jnp.asarray(i0_dom, jnp.float32)
    mask = jnp.asarray(mask, jnp.float32)
    n_exp = jnp.float32(n_exp)
    epoch_ns = jnp.float32(epoch_ns)

    freqs = jnp.asarray(P.FREQS_GHZ, jnp.float32)[None, :]  # [1, NF]
    volts = P.V0_VOLTS + P.KV_VOLTS_PER_GHZ * (freqs - P.F_MIN_GHZ)
    eta = P.ETA0 + P.ETA_SLOPE * (freqs - P.F_MIN_GHZ) / (
        P.F_MAX_GHZ - P.F_MIN_GHZ
    )

    pred_instr = jnp.maximum(i0_dom[:, None] + sens_dom[:, None] * freqs, P.EPS)
    rate = pred_instr / epoch_ns  # Ginstr/s
    v2 = volts * volts
    p_dyn = P.C1_W * v2 * rate + P.C2_W * v2 * freqs
    p_leak = P.L0_W * jnp.exp(P.LV_PER_VOLT * (volts - P.V_NOM))
    power_w = (p_dyn + p_leak) / eta

    ednp = power_w / jnp.power(jnp.maximum(rate, P.EPS), n_exp)
    inactive = mask[:, None] < 0.5
    col = jnp.arange(ednp.shape[1], dtype=jnp.float32)[None, :]
    ednp = jnp.where(inactive & (col > 0.0), jnp.float32(jnp.inf), ednp)
    best_idx = jnp.argmin(ednp, axis=1).astype(jnp.float32)
    return pred_instr, power_w, ednp, best_idx


def dvfs_step_ref(
    instr, t_core_ns, age_factor, freq_ghz, pred_sens, pred_i0, mask, n_exp, epoch_ns
):
    """Full per-epoch DVFS step = estimation (update path) + selection
    (lookup path).  Matches ``model.dvfs_step``."""
    sens_wf, sens_cu, i0_cu = wf_sensitivity_ref(
        instr, t_core_ns, age_factor, freq_ghz, epoch_ns
    )
    pred_instr, power_w, ednp, best_idx = freq_grid_ref(
        pred_sens, pred_i0, mask, n_exp, epoch_ns
    )
    return sens_wf, sens_cu, i0_cu, pred_instr, power_w, ednp, best_idx
