"""L1 Pallas kernel: DVFS objective-grid evaluation + argmin selection.

For every V/f domain the kernel evaluates the predicted instruction
count, power, and ED^nP objective at all ``N_FREQ`` V/f states, then
reduces to the argmin state — the tensorized analogue of the per-domain
hardware comparator tree the paper's DVFS manager would use.

The frequency axis (10 states) lives on lanes (padded to 128 on real
TPUs); voltage/eta curves are computed in-register from an iota instead
of a lookup table so the kernel has no gather.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import params as P


def _grid_kernel(
    sens_ref, i0_ref, mask_ref, nexp_ref, epoch_ref,
    instr_ref, power_ref, ednp_ref, best_ref,
):
    sens = sens_ref[...]  # [rows]
    i0 = i0_ref[...]
    mask = mask_ref[...]
    n_exp = nexp_ref[0]
    epoch_ns = epoch_ref[0]

    rows = sens.shape[0]
    nf = P.N_FREQ
    k = jax.lax.broadcasted_iota(jnp.float32, (rows, nf), 1)
    freqs = P.F_MIN_GHZ + 0.1 * k
    volts = P.V0_VOLTS + P.KV_VOLTS_PER_GHZ * (freqs - P.F_MIN_GHZ)
    eta = P.ETA0 + P.ETA_SLOPE * (freqs - P.F_MIN_GHZ) / (
        P.F_MAX_GHZ - P.F_MIN_GHZ
    )

    pred_instr = jnp.maximum(i0[:, None] + sens[:, None] * freqs, P.EPS)
    rate = pred_instr / epoch_ns
    v2 = volts * volts
    p_dyn = P.C1_W * v2 * rate + P.C2_W * v2 * freqs
    p_leak = P.L0_W * jnp.exp(P.LV_PER_VOLT * (volts - P.V_NOM))
    power = (p_dyn + p_leak) / eta

    ednp = power / jnp.power(jnp.maximum(rate, P.EPS), n_exp)
    inactive = mask[:, None] < 0.5
    ednp = jnp.where(inactive & (k > 0.0), jnp.float32(jnp.inf), ednp)

    instr_ref[...] = pred_instr
    power_ref[...] = power
    ednp_ref[...] = ednp
    best_ref[...] = jnp.argmin(ednp, axis=1).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def freq_grid(sens_dom, i0_dom, mask, n_exp, epoch_ns, *, interpret=True):
    """Pallas-call wrapper.

    Args:
      sens_dom, i0_dom, mask: ``[n_dom]`` f32.
      n_exp, epoch_ns: ``[1]`` f32 (scalar prefetch-style operands).

    Returns ``(pred_instr, power_w, ednp)`` each ``[n_dom, N_FREQ]`` and
    ``best_idx`` ``[n_dom]``.
    """
    n_dom = sens_dom.shape[0]
    # §Perf L2: single whole-array block (see sensitivity.py).
    rows = n_dom
    grid = (n_dom // rows,)

    vec_spec = pl.BlockSpec((rows,), lambda i: (i,))
    scalar_spec = pl.BlockSpec((1,), lambda i: (0,))
    mat_spec = pl.BlockSpec((rows, P.N_FREQ), lambda i: (i, 0))

    return pl.pallas_call(
        _grid_kernel,
        grid=grid,
        in_specs=[vec_spec, vec_spec, vec_spec, scalar_spec, scalar_spec],
        out_specs=[mat_spec, mat_spec, mat_spec, vec_spec],
        out_shape=[
            jax.ShapeDtypeStruct((n_dom, P.N_FREQ), jnp.float32),
            jax.ShapeDtypeStruct((n_dom, P.N_FREQ), jnp.float32),
            jax.ShapeDtypeStruct((n_dom, P.N_FREQ), jnp.float32),
            jax.ShapeDtypeStruct((n_dom,), jnp.float32),
        ],
        interpret=interpret,
    )(
        sens_dom.astype(jnp.float32),
        i0_dom.astype(jnp.float32),
        mask.astype(jnp.float32),
        jnp.asarray(n_exp, jnp.float32).reshape(1),
        jnp.asarray(epoch_ns, jnp.float32).reshape(1),
    )
