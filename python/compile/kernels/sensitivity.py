"""L1 Pallas kernel: batched wavefront-level sensitivity estimation.

This is the paper's per-wavefront STALL estimator (§4.4) recast as one
tensor kernel over the whole ``[n_cu, n_wf]`` wavefront grid instead of
64 per-CU hardware state machines — see DESIGN.md §Hardware-Adaptation.

TPU mapping notes (the kernel is lowered with ``interpret=True`` for the
CPU PJRT runtime; the BlockSpec structure is what we would ship to a real
TPU):

* The whole problem (64 x 40 x 4 B per operand ≈ 10 KiB x 4 operands) is
  VMEM-resident; we still tile over CU rows so the same kernel scales to
  larger GPUs without spilling.
* The wavefront axis is the lane axis; 40 lanes pad to 128 on real
  hardware.  All ops are elementwise + a lane-axis reduction, so the
  roofline is VPU/memory — the MXU is intentionally unused.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import params as P


def _sens_kernel(instr_ref, tcore_ref, age_ref, freq_ref, epoch_ref, sens_ref, senscu_ref, i0_ref):
    """One CU-row tile: sens_wf = IPC * T_core * age, plus row reductions.

    IPC is the wavefront's *epoch-wide* commit rate in instructions per
    cycle (instr / (epoch * f)); multiplied by the core (non-stalled)
    time it yields dI/df, and the relative-age factor redistributes the
    estimate across contending wavefronts (paper §4.4).
    """
    instr = instr_ref[...]
    t_core = tcore_ref[...]
    age = age_ref[...]
    freq = freq_ref[...]  # [rows]
    epoch_ns = epoch_ref[0]

    f_col = freq[:, None]
    cycles_epoch = epoch_ns * f_col
    ipc = instr / jnp.maximum(cycles_epoch, P.EPS)
    sens_wf = ipc * t_core * age

    sens_cu = jnp.sum(sens_wf, axis=1)
    i0_cu = jnp.maximum(jnp.sum(instr, axis=1) - sens_cu * freq, 0.0)

    sens_ref[...] = sens_wf
    senscu_ref[...] = sens_cu
    i0_ref[...] = i0_cu


@functools.partial(jax.jit, static_argnames=("interpret",))
def wf_sensitivity(instr, t_core_ns, age_factor, freq_ghz, epoch_ns, *, interpret=True):
    """Pallas-call wrapper; shapes ``[n_cu, n_wf]`` + ``[n_cu]`` + ``[1]``.

    Returns ``(sens_wf [n_cu, n_wf], sens_cu [n_cu], i0_cu [n_cu])``.
    """
    n_cu, n_wf = instr.shape
    # §Perf L2: a gridded pallas_call lowers (in interpret mode) to an HLO
    # while-loop — 8 sequential trips blocked XLA fusion and tripled the
    # artifact's execute time.  The whole [64, 40] problem is ~10 KiB (VMEM-
    # trivial), so use one whole-array block; the row-tiling BlockSpec
    # structure below still scales the kernel to larger GPUs.
    rows = n_cu
    grid = (n_cu // rows,)

    mat_spec = pl.BlockSpec((rows, n_wf), lambda i: (i, 0))
    vec_spec = pl.BlockSpec((rows,), lambda i: (i,))
    scalar_spec = pl.BlockSpec((1,), lambda i: (0,))

    return pl.pallas_call(
        _sens_kernel,
        grid=grid,
        in_specs=[mat_spec, mat_spec, mat_spec, vec_spec, scalar_spec],
        out_specs=[mat_spec, vec_spec, vec_spec],
        out_shape=[
            jax.ShapeDtypeStruct((n_cu, n_wf), jnp.float32),
            jax.ShapeDtypeStruct((n_cu,), jnp.float32),
            jax.ShapeDtypeStruct((n_cu,), jnp.float32),
        ],
        interpret=interpret,
    )(
        instr.astype(jnp.float32),
        t_core_ns.astype(jnp.float32),
        age_factor.astype(jnp.float32),
        freq_ghz.astype(jnp.float32),
        jnp.asarray(epoch_ns, jnp.float32).reshape(1),
    )
