"""L2: the per-epoch DVFS-step compute graph.

Composes the two Pallas kernels (wavefront sensitivity estimation +
frequency objective grid) into the single function that is AOT-lowered
to ``artifacts/dvfs_step.hlo.txt`` and executed from the Rust
coordinator's epoch loop.  Python never runs at simulation time.
"""

import jax
import jax.numpy as jnp

from . import params as P
from .kernels.selector import freq_grid
from .kernels.sensitivity import wf_sensitivity


def dvfs_step(
    instr, t_core_ns, age_factor, freq_ghz, pred_sens, pred_i0, mask, n_exp, epoch_ns
):
    """One DVFS epoch boundary.

    Update path: estimate per-wavefront and per-CU sensitivity of the
    *elapsed* epoch (feeds the PC table / reactive state in Rust).

    Lookup path: given the predicted sensitivity/intercept of the *next*
    epoch per domain, evaluate the objective grid and pick the best V/f
    state per domain.

    All array shapes are static; the artifact is built at the 64-CU GPU
    default and Rust masks/pads for smaller configurations.

    Returns a 7-tuple:
      sens_wf [n_cu, n_wf], sens_cu [n_cu], i0_cu [n_cu],
      pred_instr [n_dom, NF], power_w [n_dom, NF], ednp [n_dom, NF],
      best_idx [n_dom].
    """
    sens_wf, sens_cu, i0_cu = wf_sensitivity(
        instr, t_core_ns, age_factor, freq_ghz, epoch_ns
    )
    pred_instr, power_w, ednp, best_idx = freq_grid(
        pred_sens, pred_i0, mask, n_exp, epoch_ns
    )
    return sens_wf, sens_cu, i0_cu, pred_instr, power_w, ednp, best_idx


def example_args(n_cu=P.N_CU, n_wf=P.N_WF, n_dom=P.N_CU):
    """ShapeDtypeStructs used for AOT lowering (order matches dvfs_step)."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((n_cu, n_wf), f32),  # instr
        jax.ShapeDtypeStruct((n_cu, n_wf), f32),  # t_core_ns
        jax.ShapeDtypeStruct((n_cu, n_wf), f32),  # age_factor
        jax.ShapeDtypeStruct((n_cu,), f32),       # freq_ghz
        jax.ShapeDtypeStruct((n_dom,), f32),      # pred_sens
        jax.ShapeDtypeStruct((n_dom,), f32),      # pred_i0
        jax.ShapeDtypeStruct((n_dom,), f32),      # mask
        jax.ShapeDtypeStruct((1,), f32),          # n_exp
        jax.ShapeDtypeStruct((1,), f32),          # epoch_ns
    )
