"""Shared physical/model constants for the PCSTALL DVFS step.

These constants are the single Python-side source of truth; the Rust
coordinator mirrors them in ``rust/src/power/params.rs``.  A parity
integration test (``rust/tests/pjrt_parity.rs``) executes the AOT artifact
and the native Rust implementation on the same inputs and asserts
agreement to 1e-4, so any drift between the two copies is caught in CI.

Units used throughout the stack:

* frequency      — GHz
* time           — nanoseconds (epoch durations, core/stall time)
* sensitivity    — instructions per GHz over one epoch (dI/df)
* power          — watts (per CU / per V/f domain)
* rate           — Giga-instructions per second (= instructions / ns)
"""

# --- V/f operating points (paper §5: 1.3–2.2 GHz in 100 MHz steps) -------
N_FREQ = 10
FREQS_GHZ = [1.3 + 0.1 * i for i in range(N_FREQ)]
F_MIN_GHZ = FREQS_GHZ[0]
F_MAX_GHZ = FREQS_GHZ[-1]
F_STATIC_GHZ = 1.7  # the paper's normalization point (Figs. 15, 17)

# --- voltage curve (linear over the IVR range, paper §5.4) ---------------
# V(f) = V0 + KV * (f - F_MIN);  1.3 GHz -> 0.75 V, 2.2 GHz -> 1.05 V
V0_VOLTS = 0.75
KV_VOLTS_PER_GHZ = (1.05 - 0.75) / (F_MAX_GHZ - F_MIN_GHZ)
V_NOM = 0.90  # leakage reference voltage

# --- per-CU power model: P = C1*V^2*rate + C2*V^2*f + leak(V), / eta ------
# C1: instruction-driven switching (W per V^2 per Ginstr/s)
# C2: clock-tree + idle pipeline switching (W per V^2 per GHz)
# L0/LV: leakage magnitude and exponential voltage slope (paper notes the
#        leakage variation over the small IVR range is mild).
C1_W = 0.9
C2_W = 0.6
L0_W = 0.35
LV_PER_VOLT = 2.0

# --- IVR conversion efficiency per state (paper's DLDO, §5 power model) --
# eta(f) = ETA0 + ETA_SLOPE * (f - F_MIN) / (F_MAX - F_MIN)
ETA0 = 0.88
ETA_SLOPE = 0.05

# --- default artifact shapes (64-CU Vega-class GPU, 40 WF slots / CU) ----
N_CU = 64
N_WF = 40

# numerical floor used by both kernels when dividing by core cycles/rate
EPS = 1e-6


def voltage(f_ghz):
    """V(f) on the IVR line."""
    return V0_VOLTS + KV_VOLTS_PER_GHZ * (f_ghz - F_MIN_GHZ)


def ivr_eta(f_ghz):
    """IVR conversion efficiency at the state supplying frequency f."""
    return ETA0 + ETA_SLOPE * (f_ghz - F_MIN_GHZ) / (F_MAX_GHZ - F_MIN_GHZ)
