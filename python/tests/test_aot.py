"""AOT artifact build checks: HLO text is parseable-shaped, metadata is
consistent, and the lowering contains no TPU-only custom calls."""

import json
import os
import tempfile

from compile import params as P
from compile.aot import build


def test_build_writes_hlo_and_meta():
    with tempfile.TemporaryDirectory() as d:
        out = os.path.join(d, "dvfs_step.hlo.txt")
        meta = build(out, n_cu=8, n_wf=8)
        text = open(out).read()
        assert text.startswith("HloModule")
        # all 9 params and the 7-tuple root must be present
        assert "f32[8,8]" in text
        assert meta["n_cu"] == 8 and meta["n_wf"] == 8
        sidecar = json.load(open(os.path.join(d, "dvfs_step.meta.json")))
        assert sidecar["hlo_sha256"] == meta["hlo_sha256"]
        assert len(sidecar["inputs"]) == 9
        assert len(sidecar["outputs"]) == 7


def test_no_mosaic_custom_call():
    with tempfile.TemporaryDirectory() as d:
        out = os.path.join(d, "a.hlo.txt")
        build(out, n_cu=8, n_wf=8)
        text = open(out).read().lower()
        assert "mosaic" not in text
        assert "custom-call" not in text


def test_constants_match_params():
    with tempfile.TemporaryDirectory() as d:
        out = os.path.join(d, "a.hlo.txt")
        meta = build(out, n_cu=8, n_wf=8)
        c = meta["constants"]
        assert c["c1"] == P.C1_W and c["c2"] == P.C2_W
        assert c["v0"] == P.V0_VOLTS
        assert meta["freqs_ghz"][0] == P.F_MIN_GHZ
        assert len(meta["freqs_ghz"]) == P.N_FREQ


def test_build_is_deterministic():
    with tempfile.TemporaryDirectory() as d:
        m1 = build(os.path.join(d, "a.hlo.txt"), n_cu=8, n_wf=8)
        m2 = build(os.path.join(d, "b.hlo.txt"), n_cu=8, n_wf=8)
        assert m1["hlo_sha256"] == m2["hlo_sha256"]
