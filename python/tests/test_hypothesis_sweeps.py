"""Hypothesis property sweeps: Pallas kernels vs the jnp reference across
randomized shapes and value regimes (including degenerate epochs)."""

import numpy as np
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from compile import params as P
from compile.kernels.ref import freq_grid_ref, wf_sensitivity_ref
from compile.kernels.sensitivity import wf_sensitivity
from compile.kernels.selector import freq_grid

_shapes = st.tuples(st.integers(1, 32), st.integers(1, 48))


def _finite_f32(lo, hi):
    # snap bounds to exactly-representable f32 values (hypothesis requires it)
    lo = float(np.nextafter(np.float32(lo), np.float32(np.inf)))
    hi = float(np.nextafter(np.float32(hi), np.float32(-np.inf)))
    return st.floats(
        min_value=lo, max_value=hi, allow_nan=False, allow_infinity=False, width=32
    )


@settings(max_examples=60, deadline=None)
@given(
    shape=_shapes,
    data=st.data(),
)
def test_wf_sensitivity_matches_ref(shape, data):
    n_cu, n_wf = shape
    instr = data.draw(
        hnp.arrays(np.float32, (n_cu, n_wf), elements=_finite_f32(0.0, 1e5))
    )
    t_core = data.draw(
        hnp.arrays(np.float32, (n_cu, n_wf), elements=_finite_f32(0.0, 1e5))
    )
    age = data.draw(
        hnp.arrays(np.float32, (n_cu, n_wf), elements=_finite_f32(0.0, 1.0))
    )
    freq = data.draw(
        hnp.arrays(np.float32, (n_cu,), elements=_finite_f32(P.F_MIN_GHZ, P.F_MAX_GHZ))
    )
    epoch_ns = np.float32(1000.0)
    got = wf_sensitivity(instr, t_core, age, freq, epoch_ns)
    want = wf_sensitivity_ref(instr, t_core, age, freq, epoch_ns)
    for g, w in zip(got, want):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=3e-5, atol=1e-4
        )


@settings(max_examples=60, deadline=None)
@given(
    n_dom=st.integers(1, 64),
    n_exp=st.sampled_from([1.0, 2.0, 3.0]),
    epoch_ns=st.sampled_from([1_000.0, 10_000.0, 50_000.0, 100_000.0]),
    data=st.data(),
)
def test_freq_grid_matches_ref(n_dom, n_exp, epoch_ns, data):
    sens = data.draw(
        hnp.arrays(np.float32, (n_dom,), elements=_finite_f32(0.0, 50.0 * epoch_ns))
    )
    i0 = data.draw(
        hnp.arrays(np.float32, (n_dom,), elements=_finite_f32(0.0, 4.0 * epoch_ns))
    )
    mask_bits = data.draw(st.lists(st.booleans(), min_size=n_dom, max_size=n_dom))
    mask = np.asarray(mask_bits, np.float32)
    got = freq_grid(sens, i0, mask, n_exp, epoch_ns)
    want = freq_grid_ref(sens, i0, mask, n_exp, epoch_ns)
    for g, w in zip(got, want):
        g, w = np.asarray(g), np.asarray(w)
        finite = np.isfinite(w)
        assert (np.isfinite(g) == finite).all()
        np.testing.assert_allclose(g[finite], w[finite], rtol=3e-5, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(
    n_dom=st.integers(1, 16),
    data=st.data(),
)
def test_best_idx_is_true_argmin(n_dom, data):
    """best_idx must be consistent with the emitted ednp grid."""
    sens = data.draw(
        hnp.arrays(np.float32, (n_dom,), elements=_finite_f32(0.0, 4e4))
    )
    i0 = data.draw(hnp.arrays(np.float32, (n_dom,), elements=_finite_f32(0.0, 4e3)))
    mask = np.ones((n_dom,), np.float32)
    _, _, ednp, best = freq_grid(sens, i0, mask, 3.0, 1000.0)
    ednp, best = np.asarray(ednp), np.asarray(best).astype(int)
    np.testing.assert_array_equal(best, np.argmin(ednp, axis=1))


@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_sensitivity_scale_invariance(data):
    """Scaling instr and t_core by the same time factor scales sens by the
    same factor (the estimator is epoch-length covariant)."""
    rngseed = data.draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(rngseed)
    k = data.draw(st.sampled_from([2.0, 5.0, 10.0]))
    instr = rng.uniform(1.0, 1e3, (4, 8)).astype(np.float32)
    t_core = rng.uniform(1.0, 1e3, (4, 8)).astype(np.float32)
    age = np.ones((4, 8), np.float32)
    freq = np.full((4,), 1.8, np.float32)
    s1, _, _ = wf_sensitivity(instr, t_core, age, freq, 1000.0)
    s2, _, _ = wf_sensitivity(instr * k, t_core * k, age, freq, np.float32(1000.0 * k))
    np.testing.assert_allclose(np.asarray(s2), k * np.asarray(s1), rtol=1e-3)
