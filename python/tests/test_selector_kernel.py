"""Pallas freq_grid kernel vs reference + semantic checks on the DVFS
objective (the physics the whole evaluation rests on)."""

import numpy as np
import pytest

from compile import params as P
from compile.kernels.ref import freq_grid_ref
from compile.kernels.selector import freq_grid


def rand_inputs(rng, n_dom, epoch_ns=1000.0):
    sens = rng.uniform(0.0, 40.0 * epoch_ns, (n_dom,)).astype(np.float32)
    i0 = rng.uniform(0.0, 2.0 * epoch_ns, (n_dom,)).astype(np.float32)
    mask = np.ones((n_dom,), np.float32)
    return sens, i0, mask


def run_both(sens, i0, mask, n_exp=3.0, epoch_ns=1000.0):
    got = freq_grid(sens, i0, mask, n_exp, epoch_ns)
    want = freq_grid_ref(sens, i0, mask, n_exp, epoch_ns)
    return got, want


@pytest.mark.parametrize("n_dom", [1, 2, 8, 64])
@pytest.mark.parametrize("n_exp", [1.0, 2.0, 3.0])
def test_matches_ref(n_dom, n_exp):
    rng = np.random.default_rng(int(n_dom * 10 + n_exp))
    got, want = run_both(*rand_inputs(rng, n_dom), n_exp=n_exp)
    for g, w in zip(got, want):
        g, w = np.asarray(g), np.asarray(w)
        finite = np.isfinite(w)
        np.testing.assert_allclose(g[finite], w[finite], rtol=2e-5, atol=1e-6)
        assert (np.isfinite(g) == finite).all()


def test_compute_bound_domain_prefers_high_freq_ed2p():
    """Pure compute phase under ED^2P should select the top V/f state
    (paper Fig. 16: dgemm/hacc live at high frequencies)."""
    epoch = 1000.0
    sens = np.array([40.0 * epoch], np.float32)  # 40 fully-busy wavefronts
    i0 = np.array([0.0], np.float32)
    *_, best = freq_grid(sens, i0, np.ones(1, np.float32), 3.0, epoch)
    assert int(np.asarray(best)[0]) == P.N_FREQ - 1


def test_memory_bound_domain_prefers_low_freq():
    """Zero sensitivity: instructions don't scale with f, so the lowest
    V/f state minimizes every ED^nP (paper Fig. 16: hpgmg/xsbench)."""
    epoch = 1000.0
    sens = np.array([0.0], np.float32)
    i0 = np.array([800.0], np.float32)
    for n_exp in (1.0, 2.0, 3.0):
        *_, best = freq_grid(sens, i0, np.ones(1, np.float32), n_exp, epoch)
        assert int(np.asarray(best)[0]) == 0


def test_intermediate_sensitivity_midrange():
    """Sweeping sensitivity from 0 to max moves the chosen state
    monotonically upward through the range."""
    epoch = 1000.0
    chosen = []
    for s in np.linspace(0.0, 40.0 * epoch, 24, dtype=np.float32):
        *_, best = freq_grid(
            np.array([s], np.float32),
            np.array([200.0], np.float32),
            np.ones(1, np.float32),
            3.0,
            epoch,
        )
        chosen.append(int(np.asarray(best)[0]))
    assert chosen == sorted(chosen)
    assert chosen[0] == 0 and chosen[-1] == P.N_FREQ - 1


def test_power_increases_with_frequency():
    rng = np.random.default_rng(3)
    sens, i0, mask = rand_inputs(rng, 8)
    _, power, _, _ = freq_grid(sens, i0, mask, 3.0, 1000.0)
    power = np.asarray(power)
    assert (np.diff(power, axis=1) > 0.0).all()


def test_pred_instr_linear_in_frequency():
    sens = np.array([1000.0], np.float32)
    i0 = np.array([500.0], np.float32)
    instr, *_ = freq_grid(sens, i0, np.ones(1, np.float32), 3.0, 1000.0)
    instr = np.asarray(instr)[0]
    for k, f in enumerate(P.FREQS_GHZ):
        np.testing.assert_allclose(instr[k], 500.0 + 1000.0 * f, rtol=1e-5)


def test_masked_domain_argmin_is_state_zero():
    sens = np.array([40000.0, 40000.0], np.float32)
    i0 = np.zeros(2, np.float32)
    mask = np.array([1.0, 0.0], np.float32)
    _, _, ednp, best = freq_grid(sens, i0, mask, 3.0, 1000.0)
    assert int(np.asarray(best)[1]) == 0
    assert np.isinf(np.asarray(ednp)[1, 1:]).all()


def test_edp_vs_ed2p_ordering():
    """ED^2P weights delay more -> chosen frequency under ED^2P is >= the
    EDP choice for the same phase (paper §6.3: EDP gains are milder)."""
    rng = np.random.default_rng(5)
    for _ in range(32):
        sens, i0, mask = rand_inputs(rng, 4)
        *_, b_edp = freq_grid(sens, i0, mask, 2.0, 1000.0)
        *_, b_ed2p = freq_grid(sens, i0, mask, 3.0, 1000.0)
        assert (np.asarray(b_ed2p) >= np.asarray(b_edp)).all()
