"""Pallas wf_sensitivity kernel vs pure-jnp reference — the core L1
correctness signal, plus semantic unit checks on the estimator itself."""

import numpy as np
import pytest

from compile import params as P
from compile.kernels.ref import wf_sensitivity_ref
from compile.kernels.sensitivity import wf_sensitivity


def rand_inputs(rng, n_cu, n_wf, epoch_ns=1000.0):
    instr = rng.uniform(0.0, 2.5 * epoch_ns, (n_cu, n_wf)).astype(np.float32)
    t_core = rng.uniform(0.0, epoch_ns, (n_cu, n_wf)).astype(np.float32)
    age = rng.uniform(0.05, 1.0, (n_cu, n_wf)).astype(np.float32)
    freq = rng.uniform(P.F_MIN_GHZ, P.F_MAX_GHZ, (n_cu,)).astype(np.float32)
    return instr, t_core, age, freq, np.float32(epoch_ns)


def assert_matches_ref(instr, t_core, age, freq, epoch_ns):
    got = wf_sensitivity(instr, t_core, age, freq, epoch_ns)
    want = wf_sensitivity_ref(instr, t_core, age, freq, epoch_ns)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=2e-5, atol=1e-5)


@pytest.mark.parametrize("n_cu", [1, 3, 8, 16, 64])
@pytest.mark.parametrize("n_wf", [1, 7, 40])
def test_matches_ref_shapes(n_cu, n_wf):
    rng = np.random.default_rng(n_cu * 100 + n_wf)
    assert_matches_ref(*rand_inputs(rng, n_cu, n_wf))


def test_zero_core_time_gives_zero_sensitivity():
    """A fully memory-stalled wavefront (t_core = 0) has sensitivity 0."""
    instr = np.full((4, 8), 100.0, np.float32)
    t_core = np.zeros((4, 8), np.float32)
    age = np.ones((4, 8), np.float32)
    freq = np.full((4,), 1.7, np.float32)
    sens_wf, sens_cu, i0_cu = wf_sensitivity(instr, t_core, age, freq, 1000.0)
    np.testing.assert_allclose(np.asarray(sens_wf), 0.0, atol=1e-3)
    # everything becomes intercept: these instructions arrive regardless of f
    np.testing.assert_allclose(np.asarray(i0_cu), 800.0, rtol=1e-4)


def test_fully_compute_bound_wavefront():
    """t_core == epoch and IPC == 1: sens == epoch_ns, I0 == 0."""
    epoch = 1000.0
    f = 2.0
    instr = np.full((8, 8), epoch * f, np.float32)  # 1 instr / cycle
    t_core = np.full((8, 8), epoch, np.float32)
    age = np.ones((8, 8), np.float32)
    freq = np.full((8,), f, np.float32)
    sens_wf, sens_cu, i0_cu = wf_sensitivity(instr, t_core, age, freq, epoch)
    np.testing.assert_allclose(np.asarray(sens_wf), epoch, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(sens_cu), 8 * epoch, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(i0_cu), 0.0, atol=1e-1)


def test_age_factor_scales_linearly():
    rng = np.random.default_rng(7)
    instr, t_core, _, freq, epoch = rand_inputs(rng, 8, 8)
    ones = np.ones((8, 8), np.float32)
    halves = np.full((8, 8), 0.5, np.float32)
    s1, _, _ = wf_sensitivity(instr, t_core, ones, freq, epoch)
    s2, _, _ = wf_sensitivity(instr, t_core, halves, freq, epoch)
    np.testing.assert_allclose(np.asarray(s2), 0.5 * np.asarray(s1), rtol=1e-5)


def test_sensitivity_is_commutative_across_wavefronts():
    """Paper §4.2: domain sensitivity is the *sum* of WF sensitivities —
    permuting wavefront slots must not change the CU aggregate."""
    rng = np.random.default_rng(11)
    instr, t_core, age, freq, epoch = rand_inputs(rng, 8, 16)
    perm = rng.permutation(16)
    _, sens_cu_a, i0_a = wf_sensitivity(instr, t_core, age, freq, epoch)
    _, sens_cu_b, i0_b = wf_sensitivity(
        instr[:, perm], t_core[:, perm], age[:, perm], freq, epoch
    )
    np.testing.assert_allclose(np.asarray(sens_cu_a), np.asarray(sens_cu_b), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(i0_a), np.asarray(i0_b), rtol=1e-4, atol=1e-3)


def test_intercept_nonnegative():
    rng = np.random.default_rng(13)
    for _ in range(16):
        instr, t_core, age, freq, epoch = rand_inputs(rng, 8, 8)
        _, _, i0 = wf_sensitivity(instr, t_core, age, freq, epoch)
        assert (np.asarray(i0) >= 0.0).all()


def test_noninterpret_lowering_has_no_custom_call():
    """interpret=True must lower to plain HLO the CPU PJRT client can run."""
    import jax
    import jax.numpy as jnp

    spec = [
        jnp.zeros((8, 8), jnp.float32),
        jnp.zeros((8, 8), jnp.float32),
        jnp.ones((8, 8), jnp.float32),
        jnp.full((8,), 1.7, jnp.float32),
        jnp.full((1,), 1000.0, jnp.float32),
    ]
    text = jax.jit(lambda a, b, c, d, e: wf_sensitivity(a, b, c, d, e)).lower(*spec).as_text()
    assert "mosaic" not in text.lower()
