//! Bench form of Fig. 1a: end-to-end ED²P runs across epoch durations.
//! Reports wall time per configuration and the resulting improvement so
//! perf regressions in the full pipeline are visible.

use pcstall::dvfs::manager::{DvfsManager, Policy, RunMode};
use pcstall::dvfs::objective::Objective;
use pcstall::power::params::F_STATIC_IDX;
use pcstall::stats::bench::fmt_ns;
use pcstall::workloads;

fn run(epoch_ns: f64, policy: Policy) -> (f64, std::time::Duration) {
    let mut cfg = pcstall::config::SimConfig::default();
    cfg.gpu.n_cu = 8;
    cfg.gpu.n_wf = 16;
    cfg.dvfs.epoch_ns = epoch_ns;
    let wl = workloads::build("comd", 0.1);
    let mut mgr = DvfsManager::new(cfg, &wl, policy, Objective::Ed2p);
    let t0 = std::time::Instant::now();
    let r = mgr.run(RunMode::Completion { max_epochs: 400_000 }, "comd");
    (r.ed2p(), t0.elapsed())
}

fn main() {
    println!("== fig1a bench: epoch-duration sweep (comd, 8CU) ==");
    for &epoch_ns in &[1_000.0, 10_000.0, 50_000.0, 100_000.0] {
        let (base, t_base) = run(epoch_ns, Policy::Static(F_STATIC_IDX));
        let (pc, t_pc) = run(epoch_ns, Policy::PcStall);
        println!(
            "epoch {:>6}ns  static {}  pcstall {}  ED2P improvement {:+.1}%",
            epoch_ns,
            fmt_ns(t_base.as_nanos() as f64),
            fmt_ns(t_pc.as_nanos() as f64),
            (1.0 - pc / base) * 100.0,
        );
    }
}
