//! Bench form of Fig. 14: per-design accuracy measurement runs, timed.

use pcstall::dvfs::manager::{DvfsManager, Policy, RunMode};
use pcstall::dvfs::objective::Objective;
use pcstall::stats::bench::fmt_ns;
use pcstall::workloads;

fn main() {
    println!("== fig14 bench: accuracy runs per design (comd, 8CU, 60 epochs) ==");
    for d in Policy::all_dvfs() {
        let mut cfg = pcstall::config::SimConfig::default();
        cfg.gpu.n_cu = 8;
        cfg.gpu.n_wf = 16;
        let wl = workloads::build("comd", 0.2);
        let mut mgr = DvfsManager::new(cfg, &wl, d, Objective::Ed2p);
        let t0 = std::time::Instant::now();
        let r = mgr.run(RunMode::Epochs(60), "comd");
        println!(
            "{:<8} accuracy {:.3}   wall {}",
            r.policy,
            r.mean_accuracy,
            fmt_ns(t0.elapsed().as_nanos() as f64)
        );
    }
}
