//! Bench form of Fig. 15: full completion runs for the headline ED²P
//! table on a 3-workload subset, timed per design.

use pcstall::dvfs::manager::{DvfsManager, Policy, RunMode};
use pcstall::dvfs::objective::Objective;
use pcstall::models::EstModel;
use pcstall::power::params::F_STATIC_IDX;
use pcstall::stats::bench::fmt_ns;
use pcstall::util::geomean;
use pcstall::workloads;

fn main() {
    println!("== fig15 bench: ED²P completion runs (8CU) ==");
    let designs = [
        Policy::Static(F_STATIC_IDX),
        Policy::Reactive(EstModel::Crisp),
        Policy::PcStall,
        Policy::Oracle,
    ];
    let wls = ["comd", "hacc", "xsbench"];
    let mut base = vec![0.0; wls.len()];
    for d in designs {
        let mut norms = Vec::new();
        let t0 = std::time::Instant::now();
        for (i, wl_name) in wls.iter().enumerate() {
            let mut cfg = pcstall::config::SimConfig::default();
            cfg.gpu.n_cu = 8;
            cfg.gpu.n_wf = 16;
            let wl = workloads::build(wl_name, 0.1);
            let mut mgr = DvfsManager::new(cfg, &wl, d, Objective::Ed2p);
            let r = mgr.run(RunMode::Completion { max_epochs: 100_000 }, wl_name);
            if matches!(d, Policy::Static(_)) {
                base[i] = r.ed2p();
            }
            norms.push(r.ed2p() / if base[i] > 0.0 { base[i] } else { r.ed2p() });
        }
        println!(
            "{:<12} geomean norm ED²P {:.3}   wall {}",
            d.name(),
            geomean(&norms),
            fmt_ns(t0.elapsed().as_nanos() as f64)
        );
    }
}
