//! Bench form of Fig. 17: EDP-objective completion runs across epoch
//! durations (PCSTALL vs static), timed.

use pcstall::dvfs::manager::{DvfsManager, Policy, RunMode};
use pcstall::dvfs::objective::Objective;
use pcstall::power::params::F_STATIC_IDX;
use pcstall::stats::bench::fmt_ns;
use pcstall::workloads;

fn main() {
    println!("== fig17 bench: EDP epoch sweep (BwdBN, 8CU) ==");
    for &epoch_ns in &[1_000.0, 10_000.0, 100_000.0] {
        let run = |p: Policy| {
            let mut cfg = pcstall::config::SimConfig::default();
            cfg.gpu.n_cu = 8;
            cfg.gpu.n_wf = 16;
            cfg.dvfs.epoch_ns = epoch_ns;
            let wl = workloads::build("BwdBN", 0.1);
            let mut mgr = DvfsManager::new(cfg, &wl, p, Objective::Edp);
            let t0 = std::time::Instant::now();
            let r = mgr.run(RunMode::Completion { max_epochs: 400_000 }, "BwdBN");
            (r.edp(), t0.elapsed())
        };
        let (base, tb) = run(Policy::Static(F_STATIC_IDX));
        let (pc, tp) = run(Policy::PcStall);
        println!(
            "epoch {:>6}ns  EDP improvement {:+.1}%  (static {} / pcstall {})",
            epoch_ns,
            (1.0 - pc / base) * 100.0,
            fmt_ns(tb.as_nanos() as f64),
            fmt_ns(tp.as_nanos() as f64),
        );
    }
}
