//! Bench form of Fig. 18b: V/f-domain granularity sweep, timed.

use pcstall::dvfs::manager::{DvfsManager, Policy, RunMode};
use pcstall::dvfs::objective::Objective;
use pcstall::power::params::F_STATIC_IDX;
use pcstall::stats::bench::fmt_ns;
use pcstall::workloads;

fn main() {
    println!("== fig18b bench: domain granularity sweep (comd, 8CU) ==");
    for &g in &[1usize, 2, 4] {
        let run = |p: Policy| {
            let mut cfg = pcstall::config::SimConfig::default();
            cfg.gpu.n_cu = 8;
            cfg.gpu.n_wf = 16;
            cfg.dvfs.cus_per_domain = g;
            let wl = workloads::build("comd", 0.1);
            let mut mgr = DvfsManager::new(cfg, &wl, p, Objective::Ed2p);
            let t0 = std::time::Instant::now();
            let r = mgr.run(RunMode::Completion { max_epochs: 100_000 }, "comd");
            (r.ed2p(), t0.elapsed())
        };
        let (base, _) = run(Policy::Static(F_STATIC_IDX));
        let (pc, t) = run(Policy::PcStall);
        println!(
            "{g} CU/domain: ED²P improvement {:+.1}%  wall {}",
            (1.0 - pc / base) * 100.0,
            fmt_ns(t.as_nanos() as f64)
        );
    }
}
