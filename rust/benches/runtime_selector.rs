//! PJRT-vs-native backend comparison on the epoch hot path — quantifies
//! the cost of executing the AOT artifact at every epoch boundary.

use pcstall::dvfs::native::{DvfsStepBackend, NativeBackend, StepInputs};
use pcstall::runtime::{find_artifact, PjrtBackend};
use pcstall::stats::bench::bench;
use pcstall::util::SplitMix64;

fn inputs(n_cu: usize, n_wf: usize) -> StepInputs {
    let mut rng = SplitMix64::new(7);
    let mut inp = StepInputs::zeros(n_cu, n_wf);
    for v in inp.instr.iter_mut() {
        *v = (rng.next_f64() * 2000.0) as f32;
    }
    for v in inp.t_core_ns.iter_mut() {
        *v = (rng.next_f64() * 1000.0) as f32;
    }
    for d in 0..n_cu {
        inp.pred_sens[d] = (rng.next_f64() * 30_000.0) as f32;
        inp.pred_i0[d] = (rng.next_f64() * 1_000.0) as f32;
    }
    inp
}

fn main() {
    println!("== runtime selector: native vs PJRT ==");
    let inp = inputs(64, 40);

    let mut native = NativeBackend::default();
    bench("native backend 64x40", || {
        let _ = native.step(&inp).unwrap();
    });

    match find_artifact(None).map(|p| PjrtBackend::load(&p)) {
        Some(Ok(mut pjrt)) => {
            bench("pjrt backend 64x40 (AOT artifact)", || {
                let _ = pjrt.step(&inp).unwrap();
            });
            let small = inputs(8, 16);
            bench("pjrt backend 8x16 (padded to 64x40)", || {
                let _ = pjrt.step(&small).unwrap();
            });
        }
        Some(Err(e)) => println!("pjrt load failed: {e:#}"),
        None => println!("no artifact found — run `make artifacts` for the PJRT numbers"),
    }
}
