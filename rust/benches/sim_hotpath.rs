//! Hot-path microbenchmarks: simulator epoch stepping, oracle sampling,
//! snapshotting, and the native dvfs_step.  These are the L3 profiling
//! targets of the §Perf pass (EXPERIMENTS.md).  Besides the stdout
//! report, writes `BENCH_sim_hotpath.json` (schema-versioned trajectory
//! artifact; CI archives it per commit).

use pcstall::config::SimConfig;
use pcstall::dvfs::native::{dvfs_step_native, StepInputs};
use pcstall::power::PowerParams;
use pcstall::predictors::OracleSampler;
use pcstall::sim::gpu::Gpu;
use pcstall::stats::bench::{bench, bench_cfg, write_bench_json, BenchResult};
use pcstall::util::SplitMix64;
use pcstall::workloads;
use std::time::Duration;

fn gpu(n_cu: usize, n_wf: usize, wl: &str) -> Gpu {
    gpu_threaded(n_cu, n_wf, wl, 1)
}

fn gpu_threaded(n_cu: usize, n_wf: usize, wl: &str, sim_threads: usize) -> Gpu {
    let mut cfg = SimConfig::default();
    cfg.gpu.n_cu = n_cu;
    cfg.gpu.n_wf = n_wf;
    cfg.gpu.sim_threads = sim_threads;
    let spec = workloads::build(wl, 1.0);
    let mut g = Gpu::new(cfg);
    g.load_workload(spec.launches(), spec.rounds);
    g.run_epoch(); // warm
    g
}

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();

    println!("== sim hot path ==");
    for (wl, tag) in [("hacc", "compute"), ("xsbench", "membound"), ("comd", "mixed")] {
        let mut g = gpu(8, 16, wl);
        let r = bench(&format!("epoch 8CUx16WF {tag}"), || {
            g.run_epoch();
        });
        let cycles: u64 = g.cus.iter().map(|c| c.counters.cycles).sum();
        let rate = cycles as f64 / r.median_ns();
        println!("    -> {:.1} M CU-cycles/s", rate * 1e3);
        results.push(r);
    }

    {
        let mut g = gpu(64, 40, "comd");
        let r = bench_cfg(
            "epoch 64CUx40WF comd (paper scale)",
            Duration::from_millis(400),
            5,
            50,
            &mut || {
                g.run_epoch();
            },
        );
        let cycles: u64 = g.cus.iter().map(|c| c.counters.cycles).sum();
        println!(
            "    -> {:.1} M CU-cycles/s",
            cycles as f64 / r.median_ns() * 1e3
        );
        results.push(r);
    }

    // Intra-sim parallelism scaling at paper scale: same work, stepped
    // by 1/2/4/nproc CU threads.  Results are byte-identical across the
    // axis (tests/sim_parallel.rs asserts it); only wall-clock moves.
    {
        let nproc = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let mut axis = vec![1usize, 2, 4];
        if !axis.contains(&nproc) {
            axis.push(nproc);
        }
        let mut serial_ns = 0.0;
        for st in axis {
            let mut g = gpu_threaded(64, 40, "comd", st);
            let r = bench_cfg(
                &format!("epoch 64CUx40WF comd threads={st}"),
                Duration::from_millis(400),
                5,
                50,
                &mut || {
                    g.run_epoch();
                },
            );
            if st == 1 {
                serial_ns = r.median_ns();
            } else if serial_ns > 0.0 {
                println!("    -> {:.2}x vs serial", serial_ns / r.median_ns());
            }
            results.push(r);
        }
    }

    {
        let g = gpu(8, 16, "comd");
        let sampler = OracleSampler::default();
        results.push(bench_cfg(
            "oracle sample (10 pre-executions, 8CU)",
            Duration::from_millis(400),
            5,
            50,
            &mut || {
                let _ = sampler.sample(&g);
            },
        ));
    }

    {
        let g = gpu(8, 16, "comd");
        results.push(bench("gpu snapshot clone (8CU)", || {
            let _ = g.snapshot();
        }));
        let g64 = gpu(64, 40, "comd");
        results.push(bench("gpu snapshot clone (64CU)", || {
            let _ = g64.snapshot();
        }));
    }

    {
        let mut rng = SplitMix64::new(1);
        let mut inp = StepInputs::zeros(64, 40);
        for v in inp.instr.iter_mut() {
            *v = (rng.next_f64() * 2000.0) as f32;
        }
        for v in inp.t_core_ns.iter_mut() {
            *v = (rng.next_f64() * 1000.0) as f32;
        }
        let p = PowerParams::default();
        results.push(bench("native dvfs_step 64x40", || {
            let _ = dvfs_step_native(&inp, &p);
        }));
    }

    // Trajectory artifact: run metadata comes from the environment so
    // the emitter itself stays timestamp-free and deterministic.
    let commit = std::env::var("GITHUB_SHA").unwrap_or_else(|_| "unknown".into());
    let out = std::path::Path::new("BENCH_sim_hotpath.json");
    match write_bench_json(out, "sim_hotpath", &[("commit", &commit)], &results) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("warning: could not write {}: {e}", out.display()),
    }
}
