//! A tiny TOML-subset parser (offline environment — no external crates).
//!
//! Supported: `[section]` headers, `key = value` pairs with integer,
//! float, boolean, double-quoted string and single-line array values,
//! `#` comments, blank lines.  Nested tables beyond one level, nested
//! arrays and dates are not needed by [`crate::SimConfig`] or the sweep
//! plan grammar ([`crate::harness::sweep`]) and are rejected loudly.

/// A parsed value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    /// Single-line `[a, b, c]` array of scalars (sweep-plan axes).
    Arr(Vec<Value>),
}

impl Value {
    /// Best-effort parse used by CLI overrides (no quoting required).
    pub fn parse(s: &str) -> Value {
        if let Ok(i) = s.parse::<i64>() {
            Value::Int(i)
        } else if let Ok(f) = s.parse::<f64>() {
            Value::Float(f)
        } else if let Ok(b) = s.parse::<bool>() {
            Value::Bool(b)
        } else {
            Value::Str(s.trim_matches('"').to_string())
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Float(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(items) => Some(items.as_slice()),
            _ => None,
        }
    }
}

/// Parse TOML-subset text into a flat `section.key -> value` list
/// (top-level keys have no section prefix).
pub fn parse(text: &str) -> anyhow::Result<Vec<(String, Value)>> {
    let mut out = Vec::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| anyhow::anyhow!("line {}: unterminated section", lineno + 1))?
                .trim();
            if name.is_empty() || name.contains('[') {
                anyhow::bail!("line {}: bad section name: {name}", lineno + 1);
            }
            section = name.to_string();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim();
        // TOML-style quoted keys: `"dvfs.transition_ns" = [..]` names the
        // same key as the bare spelling (needed because dots in bare keys
        // are literal here, and sweep-plan `[axis]` tables quote them)
        let key = match key.strip_prefix('"') {
            Some(rest) => rest
                .strip_suffix('"')
                .ok_or_else(|| anyhow::anyhow!("line {}: unterminated quoted key", lineno + 1))?,
            None => key,
        };
        if key.is_empty() {
            anyhow::bail!("line {}: empty key", lineno + 1);
        }
        let value = parse_value(value.trim())
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        let full = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        out.push((full, value));
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(q) = s.strip_prefix('"') {
        let inner = q.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        for part in split_array_items(body)? {
            let part = part.trim();
            if part.is_empty() {
                return Err("empty array element".into());
            }
            if part.starts_with('[') {
                return Err("nested arrays are not supported by minitoml".into());
            }
            items.push(parse_value(part)?);
        }
        return Ok(Value::Arr(items));
    }
    let cleaned = s.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value: {s}"))
}

/// Split an array body on top-level commas (commas inside quoted strings
/// do not separate).  A TOML-style trailing comma is allowed.
fn split_array_items(body: &str) -> Result<Vec<&str>, String> {
    let mut items = Vec::new();
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in body.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                items.push(&body[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if in_str {
        return Err("unterminated string in array".into());
    }
    // text after the last separator; an empty tail is a trailing comma
    if !body[start..].trim().is_empty() {
        items.push(&body[start..]);
    }
    Ok(items)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let kv = parse(
            r#"
# top comment
seed = 42
[gpu]
n_cu = 64          # trailing comment
mem_freq_ghz = 1.6
name = "vega"
big = 1_000_000
[dvfs]
enabled = true
"#,
        )
        .unwrap();
        assert!(kv.contains(&("seed".into(), Value::Int(42))));
        assert!(kv.contains(&("gpu.n_cu".into(), Value::Int(64))));
        assert!(kv.contains(&("gpu.mem_freq_ghz".into(), Value::Float(1.6))));
        assert!(kv.contains(&("gpu.name".into(), Value::Str("vega".into()))));
        assert!(kv.contains(&("gpu.big".into(), Value::Int(1_000_000))));
        assert!(kv.contains(&("dvfs.enabled".into(), Value::Bool(true))));
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(parse("[unterminated\n").is_err());
        assert!(parse("novalue\n").is_err());
        assert!(parse("k = \"unterminated\n").is_err());
        assert!(parse("= 3\n").is_err());
        assert!(parse("k = [1, [2]]\n").is_err());
        assert!(parse("k = [1,, 2]\n").is_err());
        assert!(parse("k = [1, 2\n").is_err());
    }

    #[test]
    fn parses_arrays() {
        let kv = parse("xs = [1, 2.5, \"a,b\", true]\nempty = []\ntrail = [7,]\n").unwrap();
        assert_eq!(
            kv[0].1,
            Value::Arr(vec![
                Value::Int(1),
                Value::Float(2.5),
                Value::Str("a,b".into()),
                Value::Bool(true),
            ])
        );
        assert_eq!(kv[1].1, Value::Arr(vec![]));
        assert_eq!(kv[2].1, Value::Arr(vec![Value::Int(7)]));
        assert_eq!(kv[2].1.as_arr().map(|a| a.len()), Some(1));
    }

    #[test]
    fn seed_arrays_parse_as_int_arrays() {
        // the sweep-plan seed axis rides on plain integer arrays,
        // including underscore separators and trailing commas
        let kv = parse("seed = [2, 3, 5, 1_000,]\n").unwrap();
        assert_eq!(kv[0].0, "seed");
        assert_eq!(
            kv[0].1,
            Value::Arr(vec![
                Value::Int(2),
                Value::Int(3),
                Value::Int(5),
                Value::Int(1_000),
            ])
        );
        let ints: Option<Vec<i64>> = kv[0].1.as_arr().unwrap().iter().map(|v| v.as_int()).collect();
        assert_eq!(ints, Some(vec![2, 3, 5, 1_000]));
    }

    #[test]
    fn seed_key_is_section_qualified_under_set() {
        // a plan's top-level `seed = [..]` axis and a `[set]` master-seed
        // override are different keys: position relative to the section
        // header decides which one the parser yields
        let kv = parse("seed = [1, 2]\n[set]\nseed = 9\ngpu.n_wf = 4\n").unwrap();
        assert_eq!(kv[0].0, "seed");
        assert!(matches!(kv[0].1, Value::Arr(_)));
        assert_eq!(kv[1], ("set.seed".into(), Value::Int(9)));
        assert_eq!(kv[2], ("set.gpu.n_wf".into(), Value::Int(4)));
        // and the same spelling *below* the header is a [set] key, which
        // the plan grammar rejects as an array (sweep::from_toml)
        let kv = parse("[set]\nseed = [1, 2]\n").unwrap();
        assert_eq!(kv[0].0, "set.seed");
        assert!(matches!(kv[0].1, Value::Arr(_)));
    }

    #[test]
    fn quoted_keys_name_the_same_key_as_bare_ones() {
        // the `[axis]` plan table quotes dotted config keys, TOML-style
        let kv = parse("[axis]\n\"dvfs.transition_ns\" = [5, 20]\n").unwrap();
        assert_eq!(kv[0].0, "axis.dvfs.transition_ns");
        assert_eq!(kv[0].1, Value::Arr(vec![Value::Int(5), Value::Int(20)]));
        let bare = parse("[axis]\ndvfs.transition_ns = [5, 20]\n").unwrap();
        assert_eq!(kv, bare, "quoted and bare spellings must agree");
        // quoting works at top level too
        let kv = parse("\"seed\" = 7\n").unwrap();
        assert_eq!(kv[0], ("seed".into(), Value::Int(7)));
    }

    #[test]
    fn malformed_quoted_keys_are_rejected() {
        assert!(parse("\"unterminated = 1\n").is_err());
        assert!(parse("\"\" = 1\n").is_err(), "empty quoted key");
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let kv = parse("k = \"a#b\"\n").unwrap();
        assert_eq!(kv[0].1, Value::Str("a#b".into()));
    }

    #[test]
    fn value_coercions() {
        assert_eq!(Value::Int(3).as_float(), Some(3.0));
        assert_eq!(Value::Float(3.0).as_int(), Some(3));
        assert_eq!(Value::Float(3.5).as_int(), None);
        assert_eq!(Value::Str("x".into()).as_float(), None);
        assert_eq!(Value::Bool(true).as_bool(), Some(true));
        assert_eq!(Value::Str("s".into()).as_str(), Some("s"));
    }

    #[test]
    fn cli_value_parse() {
        assert_eq!(Value::parse("42"), Value::Int(42));
        assert_eq!(Value::parse("4.5"), Value::Float(4.5));
        assert_eq!(Value::parse("true"), Value::Bool(true));
        assert_eq!(Value::parse("abc"), Value::Str("abc".into()));
    }
}
