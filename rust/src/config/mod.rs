//! Layered configuration system: compiled defaults → TOML file →
//! CLI `--set section.key=value` overrides.
//!
//! The build environment is fully offline (no serde/toml crates), so this
//! module ships a small self-contained TOML-subset parser
//! ([`minitoml`]) covering what configs need: `[section]` headers,
//! integer/float/bool/string values, comments, and blank lines.
//!
//! Every `[set]`-addressable key is declared exactly once, in the
//! [`config_fields!`] macro seam below: the typed key *registry*
//! ([`registry`]), the setter ([`SimConfig::set_key`]), the getter
//! ([`SimConfig::get_key`]) and the serializer ([`SimConfig::to_toml`])
//! all expand from it, so the key set cannot drift between them.

pub mod minitoml;
pub mod registry;

use crate::power::PowerParams;

/// GPU shape + timing parameters (the paper's 64-CU Vega-class part).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuConfig {
    /// Number of compute units.
    pub n_cu: usize,
    /// Wavefront slots per CU (paper: ~40 waves).
    pub n_wf: usize,
    /// Instructions issued per CU per cycle (4 SIMDs on GCN3).
    pub issue_width: usize,
    /// Wavefronts per workgroup (barrier scope).
    pub wf_per_wg: usize,
    /// Fixed memory/L2 domain frequency (paper: 1.6 GHz).
    pub mem_freq_ghz: f64,
    /// L1 vector cache: total bytes / line bytes / associativity.
    pub l1_bytes: usize,
    pub l1_line: usize,
    pub l1_ways: usize,
    /// L1 hit latency in CU cycles (GPU L1s are slow).
    pub l1_hit_cycles: u32,
    /// Shared L2: total bytes / banks / associativity.
    pub l2_bytes: usize,
    pub l2_banks: usize,
    pub l2_ways: usize,
    /// L2 hit latency in ns (fixed 1.6 GHz domain).
    pub l2_hit_ns: f64,
    /// L2 bank service time per access in ns (queueing granularity).
    pub l2_service_ns: f64,
    /// DRAM latency in ns and bandwidth in bytes/ns (GB/s).
    pub dram_ns: f64,
    pub dram_bw_bytes_per_ns: f64,
    /// Coupling quantum for cross-CU contention statistics (ns).
    pub quantum_ns: f64,
    /// CU-stepping threads per simulation (0 = all available cores).
    /// Execution-only: results are byte-identical for every value, so
    /// the key is excluded from run identity ([`SimConfig::identity_toml`]).
    pub sim_threads: usize,
}

impl Default for GpuConfig {
    fn default() -> Self {
        Self {
            n_cu: 64,
            n_wf: 40,
            issue_width: 4,
            wf_per_wg: 4,
            mem_freq_ghz: 1.6,
            l1_bytes: 16 * 1024,
            l1_line: 64,
            l1_ways: 4,
            l1_hit_cycles: 24,
            l2_bytes: 4 * 1024 * 1024,
            l2_banks: 16,
            l2_ways: 16,
            l2_hit_ns: 90.0,
            l2_service_ns: 2.0,
            dram_ns: 250.0,
            dram_bw_bytes_per_ns: 448.0,
            quantum_ns: 200.0,
            sim_threads: 1,
        }
    }
}

/// DVFS mechanism parameters (paper §5).
#[derive(Debug, Clone, PartialEq)]
pub struct DvfsConfig {
    /// Epoch duration in ns (1 µs default — the paper's headline regime).
    pub epoch_ns: f64,
    /// CUs per V/f domain (1 = paper default; §6.5 sweeps 2..32).
    pub cus_per_domain: usize,
    /// Explicit V/f transition latency in ns; negative derives the paper's
    /// scaling (4 ns @1 µs … 400 ns @100 µs) from the epoch length.
    pub transition_ns: f64,
    /// PC-table entries per instance (paper: 128).
    pub pc_table_entries: usize,
    /// PC index offset bits over *byte* PCs (paper: 4 ⇒ ~4 instructions).
    pub pc_offset_bits: u32,
    /// EWMA weight for PC-table updates (1.0 = overwrite, paper default).
    pub pc_update_alpha: f64,
    /// Share one PC table across this many CUs (paper: per-CU or shared).
    pub pc_table_share: usize,
}

impl Default for DvfsConfig {
    fn default() -> Self {
        Self {
            epoch_ns: 1_000.0,
            cus_per_domain: 1,
            transition_ns: -1.0,
            pc_table_entries: 128,
            pc_offset_bits: 4,
            pc_update_alpha: 1.0,
            pc_table_share: 1,
        }
    }
}

impl DvfsConfig {
    /// Paper §5: transition latency grows with epoch length (slower IVR
    /// technology suffices for coarser epochs): 4 ns at 1 µs, 40 ns at
    /// 10 µs, 200 ns at 50 µs, 400 ns at 100 µs — i.e. ~0.4% of epoch.
    pub fn transition_latency_ns(&self) -> f64 {
        if self.transition_ns >= 0.0 {
            self.transition_ns
        } else {
            0.004 * self.epoch_ns
        }
    }
}

/// Serve-mode parameters: the arrival process and deadline policy for
/// continuous-traffic runs ([`crate::harness::serve`]).  Every field is
/// a registry key (`serve.*`) so offered-load and deadline axes are
/// sweepable `[axis]` grid dimensions like any other config knob.
///
/// The arrival process is a seeded two-state modulated Poisson stream:
/// exponential inter-arrival gaps at `arrival_rate` launches/µs, with a
/// burst state that multiplies the rate by `burst_factor` and persists
/// for an exponential dwell of mean `burst_dwell_us`.
/// `burst_factor = 1.0` degenerates exactly to a pure Poisson process
/// (the state modulation becomes a no-op on the gap distribution).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// Number of kernel launches in the arrival stream.
    pub launches: usize,
    /// Mean arrival rate in launches per µs.
    pub arrival_rate: f64,
    /// Per-launch completion deadline in µs (queueing + service).
    pub deadline_us: f64,
    /// Burst-state rate multiplier (1.0 = pure Poisson).
    pub burst_factor: f64,
    /// Mean dwell time of each burst/calm state in µs.
    pub burst_dwell_us: f64,
    /// Deadline-risk threshold: when the most urgent outstanding
    /// launch's remaining-deadline fraction drops below this, the
    /// deadline objective falls back to max-performance.
    pub risk_frac: f64,
    /// Slowdown bound (vs max-perf prediction) the deadline objective
    /// tolerates while minimizing energy outside the risk region.
    pub slack_slowdown: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            launches: 24,
            arrival_rate: 0.02,
            deadline_us: 400.0,
            burst_factor: 1.0,
            burst_dwell_us: 50.0,
            risk_frac: 0.25,
            slack_slowdown: 0.5,
        }
    }
}

/// Top-level simulation configuration.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SimConfig {
    pub gpu: GpuConfig,
    pub dvfs: DvfsConfig,
    pub power: PowerParams,
    pub serve: ServeConfig,
    /// Master seed for workload generation.
    pub seed: u64,
}

/// The single declaration of every addressable config key:
/// `(key path, kind, field lvalue, one-line doc)`.  The registry
/// ([`registry::key_schema`]), [`SimConfig::set_key`],
/// [`SimConfig::get_key`] and [`SimConfig::to_toml`] all expand from
/// this list — add a field here and every surface (TOML files, `--set`,
/// plan `[set]` / `[axis]` tables, `pcstall config keys`) picks it up.
macro_rules! config_fields {
    ($self:ident, $apply:ident) => {
        $apply!("gpu.n_cu", usize, $self.gpu.n_cu, "Number of compute units");
        $apply!("gpu.n_wf", usize, $self.gpu.n_wf, "Wavefront slots per CU");
        $apply!("gpu.issue_width", usize, $self.gpu.issue_width, "Instructions issued per CU per cycle");
        $apply!("gpu.wf_per_wg", usize, $self.gpu.wf_per_wg, "Wavefronts per workgroup (barrier scope)");
        $apply!("gpu.mem_freq_ghz", f64, $self.gpu.mem_freq_ghz, "Fixed memory/L2 domain frequency (GHz)");
        $apply!("gpu.l1_bytes", usize, $self.gpu.l1_bytes, "L1 vector cache size (bytes)");
        $apply!("gpu.l1_line", usize, $self.gpu.l1_line, "L1 line size (bytes)");
        $apply!("gpu.l1_ways", usize, $self.gpu.l1_ways, "L1 associativity");
        $apply!("gpu.l1_hit_cycles", u32, $self.gpu.l1_hit_cycles, "L1 hit latency (CU cycles)");
        $apply!("gpu.l2_bytes", usize, $self.gpu.l2_bytes, "Shared L2 size (bytes)");
        $apply!("gpu.l2_banks", usize, $self.gpu.l2_banks, "L2 bank count");
        $apply!("gpu.l2_ways", usize, $self.gpu.l2_ways, "L2 associativity");
        $apply!("gpu.l2_hit_ns", f64, $self.gpu.l2_hit_ns, "L2 hit latency (ns)");
        $apply!("gpu.l2_service_ns", f64, $self.gpu.l2_service_ns, "L2 bank service time per access (ns)");
        $apply!("gpu.dram_ns", f64, $self.gpu.dram_ns, "DRAM latency (ns)");
        $apply!("gpu.dram_bw_bytes_per_ns", f64, $self.gpu.dram_bw_bytes_per_ns, "DRAM bandwidth (bytes/ns)");
        $apply!("gpu.quantum_ns", f64, $self.gpu.quantum_ns, "Cross-CU contention coupling quantum (ns)");
        // NOTE: gpu.sim_threads must stay the *last* gpu key: it is an
        // execution-only knob that identity_toml() skips, and keeping it
        // at the section tail means the identity text is byte-identical
        // to a serialization that never knew the key (stable RunKeys).
        $apply!("gpu.sim_threads", usize, $self.gpu.sim_threads, "CU-stepping threads per simulation (0 = all cores; result-invariant)");
        $apply!("dvfs.epoch_ns", f64, $self.dvfs.epoch_ns, "DVFS epoch duration (ns)");
        $apply!("dvfs.cus_per_domain", usize, $self.dvfs.cus_per_domain, "CUs per V/f domain");
        $apply!("dvfs.transition_ns", f64, $self.dvfs.transition_ns, "V/f transition latency (ns; negative derives ~0.4% of epoch)");
        $apply!("dvfs.pc_table_entries", usize, $self.dvfs.pc_table_entries, "PC-table entries per instance");
        $apply!("dvfs.pc_offset_bits", u32, $self.dvfs.pc_offset_bits, "PC index offset bits over byte PCs");
        $apply!("dvfs.pc_update_alpha", f64, $self.dvfs.pc_update_alpha, "EWMA weight for PC-table updates (1.0 = overwrite)");
        $apply!("dvfs.pc_table_share", usize, $self.dvfs.pc_table_share, "CUs sharing one PC table");
        $apply!("power.f_min_ghz", f64, $self.power.f_min_ghz, "Lowest ladder frequency (GHz)");
        $apply!("power.f_max_ghz", f64, $self.power.f_max_ghz, "Highest ladder frequency (GHz)");
        $apply!("power.v0", f64, $self.power.v0, "Voltage at f_min (V)");
        $apply!("power.kv", f64, $self.power.kv, "Voltage slope (V per GHz)");
        $apply!("power.v_nom", f64, $self.power.v_nom, "Leakage reference voltage (V)");
        $apply!("power.c1", f64, $self.power.c1, "Instruction-driven switching coefficient");
        $apply!("power.c2", f64, $self.power.c2, "Clock-tree switching coefficient");
        $apply!("power.l0", f64, $self.power.l0, "Leakage magnitude at v_nom (W)");
        $apply!("power.lv", f64, $self.power.lv, "Leakage exponential slope (1/V)");
        $apply!("power.eta0", f64, $self.power.eta0, "IVR efficiency at the lowest state");
        $apply!("power.eta_slope", f64, $self.power.eta_slope, "IVR efficiency rise across the ladder");
        $apply!("power.rail_cj", f64, $self.power.rail_cj, "Rail charge constant for transition energy (J per V)");
        $apply!("serve.launches", usize, $self.serve.launches, "Serve mode: kernel launches in the arrival stream");
        $apply!("serve.arrival_rate", f64, $self.serve.arrival_rate, "Serve mode: mean arrival rate (launches per microsecond)");
        $apply!("serve.deadline_us", f64, $self.serve.deadline_us, "Serve mode: per-launch completion deadline (microseconds)");
        $apply!("serve.burst_factor", f64, $self.serve.burst_factor, "Serve mode: burst-state rate multiplier (1.0 = pure Poisson)");
        $apply!("serve.burst_dwell_us", f64, $self.serve.burst_dwell_us, "Serve mode: mean burst/calm state dwell (microseconds)");
        $apply!("serve.risk_frac", f64, $self.serve.risk_frac, "Serve mode: remaining-deadline fraction triggering max-perf fallback");
        $apply!("serve.slack_slowdown", f64, $self.serve.slack_slowdown, "Serve mode: slowdown bound the deadline objective tolerates off-risk");
        $apply!("seed", u64, $self.seed, "Master seed for workload generation");
    };
}

/// Make the declaration seam importable by [`registry`] (macros are
/// textually scoped; the re-export gives it a path).
pub(crate) use config_fields;

impl SimConfig {
    /// Parse from TOML-subset text, starting from defaults.
    pub fn from_toml(text: &str) -> anyhow::Result<Self> {
        let mut cfg = Self::default();
        for (key, value) in minitoml::parse(text)? {
            cfg.set_key(&key, &value)
                .map_err(|e| anyhow::anyhow!("config key {key}: {e}"))?;
        }
        Ok(cfg)
    }

    /// Load from a TOML file.
    pub fn from_path(path: &std::path::Path) -> anyhow::Result<Self> {
        Self::from_toml(&std::fs::read_to_string(path)?)
    }

    /// Apply a `section.key=value` override (CLI `--set`).
    pub fn apply_override(&mut self, spec: &str) -> anyhow::Result<()> {
        let (key, value) = spec
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("override must be key=value: {spec}"))?;
        self.set_key(key.trim(), &minitoml::Value::parse(value.trim()))
            .map_err(|e| anyhow::anyhow!("override {spec}: {e}"))
    }

    /// Apply one parsed `section.key` value (TOML loading, CLI overrides,
    /// and sweep-plan `[set]` tables / `[axis]` dimensions).  The key is
    /// resolved and type-checked against the registry first, so every
    /// caller reports the same error for the same mistake.
    pub(crate) fn set_key(&mut self, key: &str, value: &minitoml::Value) -> Result<(), String> {
        let desc = registry::key_schema()
            .lookup(key)
            .ok_or_else(|| format!("unknown config key: {key} (see `pcstall config keys`)"))?;
        desc.canonicalize(value)?;
        macro_rules! apply {
            ($name:literal, usize, $field:expr, $doc:literal) => {
                if key == $name {
                    $field = value.as_int().expect("canonicalize admitted an integer") as usize;
                    return Ok(());
                }
            };
            ($name:literal, u32, $field:expr, $doc:literal) => {
                if key == $name {
                    $field = value.as_int().expect("canonicalize admitted an integer") as u32;
                    return Ok(());
                }
            };
            ($name:literal, u64, $field:expr, $doc:literal) => {
                if key == $name {
                    $field = value.as_int().expect("canonicalize admitted an integer") as u64;
                    return Ok(());
                }
            };
            ($name:literal, f64, $field:expr, $doc:literal) => {
                if key == $name {
                    $field = value.as_float().expect("canonicalize admitted a number");
                    return Ok(());
                }
            };
        }
        config_fields!(self, apply);
        unreachable!("registry and set_key expand from the same config_fields! seam")
    }

    /// Read one `section.key` back as a typed value — the inverse of
    /// [`Self::set_key`] (registry queries, round-trip tests).
    pub fn get_key(&self, key: &str) -> Option<minitoml::Value> {
        macro_rules! apply {
            ($name:literal, usize, $field:expr, $doc:literal) => {
                if key == $name {
                    return Some(minitoml::Value::Int($field as i64));
                }
            };
            ($name:literal, u32, $field:expr, $doc:literal) => {
                if key == $name {
                    return Some(minitoml::Value::Int($field as i64));
                }
            };
            ($name:literal, u64, $field:expr, $doc:literal) => {
                if key == $name {
                    return Some(minitoml::Value::Int($field as i64));
                }
            };
            ($name:literal, f64, $field:expr, $doc:literal) => {
                if key == $name {
                    return Some(minitoml::Value::Float($field));
                }
            };
        }
        config_fields!(self, apply);
        None
    }

    /// Serialize to TOML (used by `pcstall config dump`).
    pub fn to_toml(&self) -> String {
        self.render_toml(false)
    }

    /// The result-identity serialization: like [`Self::to_toml`] but
    /// with execution-only keys (`gpu.sim_threads`) skipped.  RunKey
    /// fingerprints hash this text, so knobs that cannot change results
    /// cannot perturb cache identity.  Because the skipped key sits at
    /// its section's tail, this text is byte-identical to a
    /// serialization that never knew the key.  (Tail placement alone is
    /// only enough to preserve old cache entries when a knob is added
    /// *without* changing results; the quantum-barrier refactor that
    /// introduced `sim_threads` also changed observable semantics, so
    /// [`crate::exec::key::SCHEMA_VERSION`] was bumped to orphan
    /// pre-refactor entries.)
    ///
    /// The `[serve]` section *is* part of identity: serve keys select
    /// the arrival stream and deadline policy of `RunMode::Serve` cells,
    /// so they must fingerprint.  Adding the section changed this text
    /// for every config — one of the two reasons `SCHEMA_VERSION` moved
    /// to 3 (see the versioning policy on the constant).
    pub fn identity_toml(&self) -> String {
        self.render_toml(true)
    }

    fn render_toml(&self, skip_exec_keys: bool) -> String {
        let mut out = String::new();
        #[allow(unused_assignments)]
        let mut section = "";
        macro_rules! apply {
            ($name:literal, $_ty:ident, $field:expr, $doc:literal) => {{
                let (sec, leaf) = match $name.split_once('.') {
                    Some((s, l)) => (s, l),
                    None => ("", $name),
                };
                if sec != section {
                    if !out.is_empty() {
                        out.push('\n');
                    }
                    out.push_str(&format!("[{sec}]\n"));
                    section = sec;
                }
                out.push_str(&format!("{leaf} = {}\n", $field));
            }};
        }
        // top-level keys must come first in TOML
        out.push_str(&format!("seed = {}\n", self.seed));
        let this = self;
        macro_rules! apply_filtered {
            ("seed", $t:ident, $f:expr, $d:literal) => {};
            ("gpu.sim_threads", $t:ident, $f:expr, $d:literal) => {
                if !skip_exec_keys {
                    apply!("gpu.sim_threads", $t, $f, $d)
                }
            };
            ($name:literal, $t:ident, $f:expr, $d:literal) => {
                apply!($name, $t, $f, $d)
            };
        }
        config_fields!(this, apply_filtered);
        out
    }

    /// A scaled-down preset for fast CI runs and unit tests.
    pub fn small() -> Self {
        let mut c = Self::default();
        c.gpu.n_cu = 4;
        c.gpu.n_wf = 8;
        c.gpu.l2_bytes = 512 * 1024;
        c
    }

    /// The paper's full 64-CU configuration.
    pub fn paper() -> Self {
        Self::default()
    }

    /// Number of V/f domains implied by the GPU shape.
    pub fn n_domains(&self) -> usize {
        self.gpu.n_cu.div_ceil(self.dvfs.cus_per_domain)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_shape() {
        let c = SimConfig::default();
        assert_eq!(c.gpu.n_cu, 64);
        assert_eq!(c.gpu.n_wf, 40);
        assert_eq!(c.dvfs.pc_table_entries, 128);
        assert_eq!(c.dvfs.pc_offset_bits, 4);
        assert_eq!(c.n_domains(), 64);
    }

    #[test]
    fn toml_roundtrip() {
        let mut c = SimConfig::default();
        c.seed = 99;
        c.gpu.n_cu = 16;
        c.dvfs.epoch_ns = 50_000.0;
        let t = c.to_toml();
        let c2 = SimConfig::from_toml(&t).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn parse_partial_config_keeps_defaults() {
        let c = SimConfig::from_toml("[gpu]\nn_cu = 8\n").unwrap();
        assert_eq!(c.gpu.n_cu, 8);
        assert_eq!(c.gpu.n_wf, 40); // default preserved
    }

    #[test]
    fn transition_latency_scales_with_epoch() {
        let mut d = DvfsConfig::default();
        d.epoch_ns = 1_000.0;
        assert!((d.transition_latency_ns() - 4.0).abs() < 1e-9);
        d.epoch_ns = 100_000.0;
        assert!((d.transition_latency_ns() - 400.0).abs() < 1e-9);
        d.transition_ns = 7.0;
        assert_eq!(d.transition_latency_ns(), 7.0);
    }

    #[test]
    fn apply_override_patches_nested_keys() {
        let mut c = SimConfig::default();
        c.apply_override("gpu.n_cu=8").unwrap();
        assert_eq!(c.gpu.n_cu, 8);
        c.apply_override("dvfs.epoch_ns=50000").unwrap();
        assert!((c.dvfs.epoch_ns - 50_000.0).abs() < 1e-9);
        c.apply_override("power.c1=1.5").unwrap();
        assert!((c.power.c1 - 1.5).abs() < 1e-12);
        c.apply_override("seed=123").unwrap();
        assert_eq!(c.seed, 123);
    }

    #[test]
    fn apply_override_rejects_unknown_keys() {
        let mut c = SimConfig::default();
        assert!(c.apply_override("gpu.bogus=1").is_err());
        assert!(c.apply_override("no_equals").is_err());
        assert!(c.apply_override("gpu.n_cu=notanumber").is_err());
    }

    #[test]
    fn set_key_rejects_negative_unsigned_values() {
        // pre-registry this silently wrapped through an `as usize` cast
        let mut c = SimConfig::default();
        assert!(c.apply_override("gpu.n_cu=-1").is_err());
        assert!(c.apply_override("seed=-3").is_err());
        assert_eq!(c.gpu.n_cu, 64, "failed override must not mutate");
    }

    #[test]
    fn get_key_reads_what_set_key_wrote() {
        let mut c = SimConfig::default();
        c.set_key("dvfs.transition_ns", &minitoml::Value::Int(20))
            .unwrap();
        assert_eq!(
            c.get_key("dvfs.transition_ns"),
            Some(minitoml::Value::Float(20.0))
        );
        c.set_key("gpu.n_wf", &minitoml::Value::Int(16)).unwrap();
        assert_eq!(c.get_key("gpu.n_wf"), Some(minitoml::Value::Int(16)));
        assert_eq!(c.get_key("gpu.bogus"), None);
    }

    #[test]
    fn unknown_key_error_points_at_the_registry() {
        let mut c = SimConfig::default();
        let err = c.apply_override("gpu.bogus=1").unwrap_err().to_string();
        assert!(err.contains("config keys"), "no discovery hint: {err}");
    }

    #[test]
    fn identity_toml_skips_sim_threads_only() {
        let mut a = SimConfig::default();
        let mut b = SimConfig::default();
        a.gpu.sim_threads = 1;
        b.gpu.sim_threads = 8;
        // full serialization sees the knob...
        assert!(a.to_toml().contains("sim_threads = 1"));
        assert!(b.to_toml().contains("sim_threads = 8"));
        assert_ne!(a.to_toml(), b.to_toml());
        // ...identity does not, so both configs share one identity
        assert!(!a.identity_toml().contains("sim_threads"));
        assert_eq!(a.identity_toml(), b.identity_toml());
        // and everything else still flows into identity
        b.gpu.n_cu = 8;
        assert_ne!(a.identity_toml(), b.identity_toml());
    }

    #[test]
    fn identity_toml_matches_pre_sim_threads_serialization() {
        // the identity text must be exactly the full text minus the one
        // sim_threads line (tail of [gpu]) — the invariant that keeps
        // execution-only knobs from ever perturbing run identity
        // (cross-version invalidation is SCHEMA_VERSION's job)
        let c = SimConfig::default();
        let full: Vec<&str> = c.to_toml().lines().collect();
        let ident: Vec<&str> = c.identity_toml().lines().collect();
        let mut removed: Vec<&str> = Vec::new();
        for l in &full {
            if !ident.contains(l) {
                removed.push(*l);
            }
        }
        assert_eq!(removed, vec!["sim_threads = 1"]);
        assert_eq!(ident.len() + 1, full.len());
    }

    #[test]
    fn sim_threads_round_trips_like_any_key() {
        let mut c = SimConfig::default();
        c.apply_override("gpu.sim_threads=4").unwrap();
        assert_eq!(c.gpu.sim_threads, 4);
        assert_eq!(
            c.get_key("gpu.sim_threads"),
            Some(minitoml::Value::Int(4))
        );
        let c2 = SimConfig::from_toml(&c.to_toml()).unwrap();
        assert_eq!(c, c2);
        // 0 = auto is an admissible value
        c.apply_override("gpu.sim_threads=0").unwrap();
        assert_eq!(c.gpu.sim_threads, 0);
    }

    #[test]
    fn serve_keys_round_trip_and_enter_identity() {
        let mut c = SimConfig::default();
        c.apply_override("serve.arrival_rate=0.05").unwrap();
        c.apply_override("serve.deadline_us=250").unwrap();
        c.apply_override("serve.launches=12").unwrap();
        assert!((c.serve.arrival_rate - 0.05).abs() < 1e-12);
        assert!((c.serve.deadline_us - 250.0).abs() < 1e-9);
        assert_eq!(c.serve.launches, 12);
        let c2 = SimConfig::from_toml(&c.to_toml()).unwrap();
        assert_eq!(c, c2);
        // serve keys select the arrival stream, so they must fingerprint
        let base = SimConfig::default();
        assert_ne!(base.identity_toml(), c.identity_toml());
        assert!(c.identity_toml().contains("[serve]"));
    }

    #[test]
    fn burst_factor_one_is_the_default_pure_poisson() {
        let c = SimConfig::default();
        assert_eq!(c.serve.burst_factor, 1.0);
        assert!(c.serve.arrival_rate > 0.0);
        assert!(c.serve.deadline_us > 0.0);
    }

    #[test]
    fn domains_round_up() {
        let mut c = SimConfig::default();
        c.gpu.n_cu = 10;
        c.dvfs.cus_per_domain = 4;
        assert_eq!(c.n_domains(), 3);
    }
}
