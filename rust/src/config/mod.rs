//! Layered configuration system: compiled defaults → TOML file →
//! CLI `--set section.key=value` overrides.
//!
//! The build environment is fully offline (no serde/toml crates), so this
//! module ships a small self-contained TOML-subset parser
//! ([`minitoml`]) covering what configs need: `[section]` headers,
//! integer/float/bool/string values, comments, and blank lines.

pub mod minitoml;

use crate::power::PowerParams;

/// GPU shape + timing parameters (the paper's 64-CU Vega-class part).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuConfig {
    /// Number of compute units.
    pub n_cu: usize,
    /// Wavefront slots per CU (paper: ~40 waves).
    pub n_wf: usize,
    /// Instructions issued per CU per cycle (4 SIMDs on GCN3).
    pub issue_width: usize,
    /// Wavefronts per workgroup (barrier scope).
    pub wf_per_wg: usize,
    /// Fixed memory/L2 domain frequency (paper: 1.6 GHz).
    pub mem_freq_ghz: f64,
    /// L1 vector cache: total bytes / line bytes / associativity.
    pub l1_bytes: usize,
    pub l1_line: usize,
    pub l1_ways: usize,
    /// L1 hit latency in CU cycles (GPU L1s are slow).
    pub l1_hit_cycles: u32,
    /// Shared L2: total bytes / banks / associativity.
    pub l2_bytes: usize,
    pub l2_banks: usize,
    pub l2_ways: usize,
    /// L2 hit latency in ns (fixed 1.6 GHz domain).
    pub l2_hit_ns: f64,
    /// L2 bank service time per access in ns (queueing granularity).
    pub l2_service_ns: f64,
    /// DRAM latency in ns and bandwidth in bytes/ns (GB/s).
    pub dram_ns: f64,
    pub dram_bw_bytes_per_ns: f64,
    /// Coupling quantum for cross-CU contention statistics (ns).
    pub quantum_ns: f64,
}

impl Default for GpuConfig {
    fn default() -> Self {
        Self {
            n_cu: 64,
            n_wf: 40,
            issue_width: 4,
            wf_per_wg: 4,
            mem_freq_ghz: 1.6,
            l1_bytes: 16 * 1024,
            l1_line: 64,
            l1_ways: 4,
            l1_hit_cycles: 24,
            l2_bytes: 4 * 1024 * 1024,
            l2_banks: 16,
            l2_ways: 16,
            l2_hit_ns: 90.0,
            l2_service_ns: 2.0,
            dram_ns: 250.0,
            dram_bw_bytes_per_ns: 448.0,
            quantum_ns: 200.0,
        }
    }
}

/// DVFS mechanism parameters (paper §5).
#[derive(Debug, Clone, PartialEq)]
pub struct DvfsConfig {
    /// Epoch duration in ns (1 µs default — the paper's headline regime).
    pub epoch_ns: f64,
    /// CUs per V/f domain (1 = paper default; §6.5 sweeps 2..32).
    pub cus_per_domain: usize,
    /// Explicit V/f transition latency in ns; negative derives the paper's
    /// scaling (4 ns @1 µs … 400 ns @100 µs) from the epoch length.
    pub transition_ns: f64,
    /// PC-table entries per instance (paper: 128).
    pub pc_table_entries: usize,
    /// PC index offset bits over *byte* PCs (paper: 4 ⇒ ~4 instructions).
    pub pc_offset_bits: u32,
    /// EWMA weight for PC-table updates (1.0 = overwrite, paper default).
    pub pc_update_alpha: f64,
    /// Share one PC table across this many CUs (paper: per-CU or shared).
    pub pc_table_share: usize,
}

impl Default for DvfsConfig {
    fn default() -> Self {
        Self {
            epoch_ns: 1_000.0,
            cus_per_domain: 1,
            transition_ns: -1.0,
            pc_table_entries: 128,
            pc_offset_bits: 4,
            pc_update_alpha: 1.0,
            pc_table_share: 1,
        }
    }
}

impl DvfsConfig {
    /// Paper §5: transition latency grows with epoch length (slower IVR
    /// technology suffices for coarser epochs): 4 ns at 1 µs, 40 ns at
    /// 10 µs, 200 ns at 50 µs, 400 ns at 100 µs — i.e. ~0.4% of epoch.
    pub fn transition_latency_ns(&self) -> f64 {
        if self.transition_ns >= 0.0 {
            self.transition_ns
        } else {
            0.004 * self.epoch_ns
        }
    }
}

/// Top-level simulation configuration.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SimConfig {
    pub gpu: GpuConfig,
    pub dvfs: DvfsConfig,
    pub power: PowerParams,
    /// Master seed for workload generation.
    pub seed: u64,
}

macro_rules! config_fields {
    ($self:ident, $apply:ident) => {
        // (key path, getter expression, setter closure)
        $apply!("gpu.n_cu", usize, $self.gpu.n_cu);
        $apply!("gpu.n_wf", usize, $self.gpu.n_wf);
        $apply!("gpu.issue_width", usize, $self.gpu.issue_width);
        $apply!("gpu.wf_per_wg", usize, $self.gpu.wf_per_wg);
        $apply!("gpu.mem_freq_ghz", f64, $self.gpu.mem_freq_ghz);
        $apply!("gpu.l1_bytes", usize, $self.gpu.l1_bytes);
        $apply!("gpu.l1_line", usize, $self.gpu.l1_line);
        $apply!("gpu.l1_ways", usize, $self.gpu.l1_ways);
        $apply!("gpu.l1_hit_cycles", u32, $self.gpu.l1_hit_cycles);
        $apply!("gpu.l2_bytes", usize, $self.gpu.l2_bytes);
        $apply!("gpu.l2_banks", usize, $self.gpu.l2_banks);
        $apply!("gpu.l2_ways", usize, $self.gpu.l2_ways);
        $apply!("gpu.l2_hit_ns", f64, $self.gpu.l2_hit_ns);
        $apply!("gpu.l2_service_ns", f64, $self.gpu.l2_service_ns);
        $apply!("gpu.dram_ns", f64, $self.gpu.dram_ns);
        $apply!("gpu.dram_bw_bytes_per_ns", f64, $self.gpu.dram_bw_bytes_per_ns);
        $apply!("gpu.quantum_ns", f64, $self.gpu.quantum_ns);
        $apply!("dvfs.epoch_ns", f64, $self.dvfs.epoch_ns);
        $apply!("dvfs.cus_per_domain", usize, $self.dvfs.cus_per_domain);
        $apply!("dvfs.transition_ns", f64, $self.dvfs.transition_ns);
        $apply!("dvfs.pc_table_entries", usize, $self.dvfs.pc_table_entries);
        $apply!("dvfs.pc_offset_bits", u32, $self.dvfs.pc_offset_bits);
        $apply!("dvfs.pc_update_alpha", f64, $self.dvfs.pc_update_alpha);
        $apply!("dvfs.pc_table_share", usize, $self.dvfs.pc_table_share);
        $apply!("power.f_min_ghz", f64, $self.power.f_min_ghz);
        $apply!("power.f_max_ghz", f64, $self.power.f_max_ghz);
        $apply!("power.v0", f64, $self.power.v0);
        $apply!("power.kv", f64, $self.power.kv);
        $apply!("power.v_nom", f64, $self.power.v_nom);
        $apply!("power.c1", f64, $self.power.c1);
        $apply!("power.c2", f64, $self.power.c2);
        $apply!("power.l0", f64, $self.power.l0);
        $apply!("power.lv", f64, $self.power.lv);
        $apply!("power.eta0", f64, $self.power.eta0);
        $apply!("power.eta_slope", f64, $self.power.eta_slope);
        $apply!("power.rail_cj", f64, $self.power.rail_cj);
        $apply!("seed", u64, $self.seed);
    };
}

impl SimConfig {
    /// Parse from TOML-subset text, starting from defaults.
    pub fn from_toml(text: &str) -> anyhow::Result<Self> {
        let mut cfg = Self::default();
        for (key, value) in minitoml::parse(text)? {
            cfg.set_key(&key, &value)
                .map_err(|e| anyhow::anyhow!("config key {key}: {e}"))?;
        }
        Ok(cfg)
    }

    /// Load from a TOML file.
    pub fn from_path(path: &std::path::Path) -> anyhow::Result<Self> {
        Self::from_toml(&std::fs::read_to_string(path)?)
    }

    /// Apply a `section.key=value` override (CLI `--set`).
    pub fn apply_override(&mut self, spec: &str) -> anyhow::Result<()> {
        let (key, value) = spec
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("override must be key=value: {spec}"))?;
        self.set_key(key.trim(), &minitoml::Value::parse(value.trim()))
            .map_err(|e| anyhow::anyhow!("override {spec}: {e}"))
    }

    /// Apply one parsed `section.key` value (TOML loading, CLI overrides,
    /// and sweep-plan `[set]` tables).
    pub(crate) fn set_key(&mut self, key: &str, value: &minitoml::Value) -> Result<(), String> {
        macro_rules! apply {
            ($name:literal, usize, $field:expr) => {
                if key == $name {
                    $field = value.as_int().ok_or("expected integer")? as usize;
                    return Ok(());
                }
            };
            ($name:literal, u32, $field:expr) => {
                if key == $name {
                    $field = value.as_int().ok_or("expected integer")? as u32;
                    return Ok(());
                }
            };
            ($name:literal, u64, $field:expr) => {
                if key == $name {
                    $field = value.as_int().ok_or("expected integer")? as u64;
                    return Ok(());
                }
            };
            ($name:literal, f64, $field:expr) => {
                if key == $name {
                    $field = value.as_float().ok_or("expected number")?;
                    return Ok(());
                }
            };
        }
        config_fields!(self, apply);
        Err(format!("unknown config key: {key}"))
    }

    /// Serialize to TOML (used by `pcstall config dump`).
    pub fn to_toml(&self) -> String {
        let mut out = String::new();
        #[allow(unused_assignments)]
        let mut section = "";
        macro_rules! apply {
            ($name:literal, $_ty:ident, $field:expr) => {{
                let (sec, leaf) = match $name.split_once('.') {
                    Some((s, l)) => (s, l),
                    None => ("", $name),
                };
                if sec != section {
                    if !out.is_empty() {
                        out.push('\n');
                    }
                    out.push_str(&format!("[{sec}]\n"));
                    section = sec;
                }
                out.push_str(&format!("{leaf} = {}\n", $field));
            }};
        }
        // top-level keys must come first in TOML
        out.push_str(&format!("seed = {}\n", self.seed));
        let this = self;
        macro_rules! apply_skip_seed {
            ("seed", $t:ident, $f:expr) => {};
            ($name:literal, $t:ident, $f:expr) => {
                apply!($name, $t, $f)
            };
        }
        config_fields!(this, apply_skip_seed);
        out
    }

    /// A scaled-down preset for fast CI runs and unit tests.
    pub fn small() -> Self {
        let mut c = Self::default();
        c.gpu.n_cu = 4;
        c.gpu.n_wf = 8;
        c.gpu.l2_bytes = 512 * 1024;
        c
    }

    /// The paper's full 64-CU configuration.
    pub fn paper() -> Self {
        Self::default()
    }

    /// Number of V/f domains implied by the GPU shape.
    pub fn n_domains(&self) -> usize {
        self.gpu.n_cu.div_ceil(self.dvfs.cus_per_domain)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_shape() {
        let c = SimConfig::default();
        assert_eq!(c.gpu.n_cu, 64);
        assert_eq!(c.gpu.n_wf, 40);
        assert_eq!(c.dvfs.pc_table_entries, 128);
        assert_eq!(c.dvfs.pc_offset_bits, 4);
        assert_eq!(c.n_domains(), 64);
    }

    #[test]
    fn toml_roundtrip() {
        let mut c = SimConfig::default();
        c.seed = 99;
        c.gpu.n_cu = 16;
        c.dvfs.epoch_ns = 50_000.0;
        let t = c.to_toml();
        let c2 = SimConfig::from_toml(&t).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn parse_partial_config_keeps_defaults() {
        let c = SimConfig::from_toml("[gpu]\nn_cu = 8\n").unwrap();
        assert_eq!(c.gpu.n_cu, 8);
        assert_eq!(c.gpu.n_wf, 40); // default preserved
    }

    #[test]
    fn transition_latency_scales_with_epoch() {
        let mut d = DvfsConfig::default();
        d.epoch_ns = 1_000.0;
        assert!((d.transition_latency_ns() - 4.0).abs() < 1e-9);
        d.epoch_ns = 100_000.0;
        assert!((d.transition_latency_ns() - 400.0).abs() < 1e-9);
        d.transition_ns = 7.0;
        assert_eq!(d.transition_latency_ns(), 7.0);
    }

    #[test]
    fn apply_override_patches_nested_keys() {
        let mut c = SimConfig::default();
        c.apply_override("gpu.n_cu=8").unwrap();
        assert_eq!(c.gpu.n_cu, 8);
        c.apply_override("dvfs.epoch_ns=50000").unwrap();
        assert!((c.dvfs.epoch_ns - 50_000.0).abs() < 1e-9);
        c.apply_override("power.c1=1.5").unwrap();
        assert!((c.power.c1 - 1.5).abs() < 1e-12);
        c.apply_override("seed=123").unwrap();
        assert_eq!(c.seed, 123);
    }

    #[test]
    fn apply_override_rejects_unknown_keys() {
        let mut c = SimConfig::default();
        assert!(c.apply_override("gpu.bogus=1").is_err());
        assert!(c.apply_override("no_equals").is_err());
        assert!(c.apply_override("gpu.n_cu=notanumber").is_err());
    }

    #[test]
    fn domains_round_up() {
        let mut c = SimConfig::default();
        c.gpu.n_cu = 10;
        c.dvfs.cus_per_domain = 4;
        assert_eq!(c.n_domains(), 3);
    }
}
