//! Typed, queryable registry of every `[set]`-addressable config key.
//!
//! The `config_fields!` seam in [`crate::config`] used to be write-only:
//! keys existed only as macro arms inside `set_key`, so nothing could
//! *enumerate* them, describe their types, or canonicalize a value
//! without mutating a config.  This module expands the same seam into a
//! [`KeySchema`] — one [`KeyDesc`] per key, carrying its dotted path,
//! [`KeyKind`], compiled default, and one-line doc — which makes keys
//!
//! * **enumerable** (`pcstall config keys`, plan validation),
//! * **type-checkable without side effects** ([`KeyDesc::canonicalize`]
//!   rejects a wrong-kind value with the same error every caller sees),
//! * **value-roundtrip-stable**: `canonicalize` renders the canonical
//!   text form of a value, and canonicalizing a re-parse of that text
//!   yields the same bytes — so sweep-axis CSV cells and cache/shard
//!   fingerprints survive re-encoding (`5` vs `5.0` for an f64 key are
//!   one identity).
//!
//! The sweep-plan `[axis]` grammar ([`crate::harness::sweep`]) is the
//! main consumer: any key listed here can be swept as a grid dimension.

use std::sync::OnceLock;

use super::minitoml::Value;
use super::{config_fields, SimConfig};

/// The scalar type of a config key, as declared in `config_fields!`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KeyKind {
    F64,
    USize,
    U32,
    U64,
}

impl KeyKind {
    /// Display name (`pcstall config keys`).
    pub fn name(self) -> &'static str {
        match self {
            KeyKind::F64 => "f64",
            KeyKind::USize => "usize",
            KeyKind::U32 => "u32",
            KeyKind::U64 => "u64",
        }
    }
}

/// Canonical text form of an f64 (Rust's shortest round-trip `{:?}`),
/// shared with [`crate::exec::key::RunKey::canonical`]'s float style.
pub fn canonical_f64(x: f64) -> String {
    format!("{x:?}")
}

/// One addressable config key: path, kind, compiled default, doc line.
#[derive(Debug, Clone)]
pub struct KeyDesc {
    /// Dotted key path (`dvfs.transition_ns`).
    pub path: &'static str,
    pub kind: KeyKind,
    /// Canonical rendering of the compiled-in default value.
    pub default: String,
    /// One-line description.
    pub doc: &'static str,
}

impl KeyDesc {
    /// Type-check `v` against this key's kind and render its canonical
    /// text form.  Canonicalizing a re-parse of the result is stable:
    /// `canonicalize(parse(canonicalize(v))) == canonicalize(v)`.
    /// Errors do not name the key — callers add their own context.
    pub fn canonicalize(&self, v: &Value) -> Result<String, String> {
        match self.kind {
            KeyKind::F64 => v
                .as_float()
                .map(canonical_f64)
                .ok_or_else(|| format!("expected a number, got {v:?}")),
            KeyKind::USize | KeyKind::U64 => v
                .as_int()
                .filter(|i| *i >= 0)
                .map(|i| i.to_string())
                .ok_or_else(|| format!("expected a non-negative integer, got {v:?}")),
            KeyKind::U32 => v
                .as_int()
                .filter(|i| *i >= 0 && *i <= u32::MAX as i64)
                .map(|i| i.to_string())
                .ok_or_else(|| format!("expected a non-negative 32-bit integer, got {v:?}")),
        }
    }
}

/// The full key registry, in `config_fields!` declaration order.
#[derive(Debug)]
pub struct KeySchema {
    keys: Vec<KeyDesc>,
}

impl KeySchema {
    /// Every addressable key, in declaration order.
    pub fn keys(&self) -> &[KeyDesc] {
        &self.keys
    }

    /// Look a key up by its dotted path.
    pub fn lookup(&self, path: &str) -> Option<&KeyDesc> {
        self.keys.iter().find(|d| d.path == path)
    }
}

/// The process-wide schema (defaults are rendered from
/// [`SimConfig::default`] exactly once).
pub fn key_schema() -> &'static KeySchema {
    static SCHEMA: OnceLock<KeySchema> = OnceLock::new();
    SCHEMA.get_or_init(|| {
        let dflt = SimConfig::default();
        let mut keys: Vec<KeyDesc> = Vec::new();
        macro_rules! apply {
            ($name:literal, usize, $field:expr, $doc:literal) => {
                keys.push(KeyDesc {
                    path: $name,
                    kind: KeyKind::USize,
                    default: $field.to_string(),
                    doc: $doc,
                });
            };
            ($name:literal, u32, $field:expr, $doc:literal) => {
                keys.push(KeyDesc {
                    path: $name,
                    kind: KeyKind::U32,
                    default: $field.to_string(),
                    doc: $doc,
                });
            };
            ($name:literal, u64, $field:expr, $doc:literal) => {
                keys.push(KeyDesc {
                    path: $name,
                    kind: KeyKind::U64,
                    default: $field.to_string(),
                    doc: $doc,
                });
            };
            ($name:literal, f64, $field:expr, $doc:literal) => {
                keys.push(KeyDesc {
                    path: $name,
                    kind: KeyKind::F64,
                    default: canonical_f64($field),
                    doc: $doc,
                });
            };
        }
        config_fields!(dflt, apply);
        KeySchema { keys }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schema_enumerates_distinct_documented_keys() {
        let schema = key_schema();
        assert!(schema.keys().len() >= 30, "registry lost keys");
        let mut paths: Vec<&str> = schema.keys().iter().map(|d| d.path).collect();
        let n = paths.len();
        paths.sort_unstable();
        paths.dedup();
        assert_eq!(paths.len(), n, "duplicate key paths");
        for d in schema.keys() {
            assert!(!d.doc.is_empty(), "{} has no doc line", d.path);
            assert!(!d.default.is_empty(), "{} has no default", d.path);
        }
    }

    #[test]
    fn lookup_finds_known_keys_only() {
        let schema = key_schema();
        let t = schema.lookup("dvfs.transition_ns").expect("registered");
        assert_eq!(t.kind, KeyKind::F64);
        assert_eq!(t.default, "-1.0");
        assert_eq!(schema.lookup("gpu.n_cu").map(|d| d.kind), Some(KeyKind::USize));
        assert_eq!(schema.lookup("seed").map(|d| d.kind), Some(KeyKind::U64));
        assert!(schema.lookup("gpu.bogus").is_none());
        assert!(schema.lookup("").is_none());
    }

    #[test]
    fn canonicalize_unifies_int_and_float_spellings() {
        let t = key_schema().lookup("dvfs.transition_ns").unwrap();
        // 5, 5.0 and a re-parse of the canonical text are one identity
        assert_eq!(t.canonicalize(&Value::Int(5)).unwrap(), "5.0");
        assert_eq!(t.canonicalize(&Value::Float(5.0)).unwrap(), "5.0");
        let canon = t.canonicalize(&Value::Int(5)).unwrap();
        assert_eq!(t.canonicalize(&Value::parse(&canon)).unwrap(), canon);
    }

    #[test]
    fn canonicalize_rejects_wrong_kinds() {
        let schema = key_schema();
        let n_cu = schema.lookup("gpu.n_cu").unwrap();
        assert!(n_cu.canonicalize(&Value::Float(1.5)).is_err(), "fractional int");
        assert!(n_cu.canonicalize(&Value::Int(-1)).is_err(), "negative int");
        assert!(n_cu.canonicalize(&Value::Str("x".into())).is_err());
        assert!(n_cu.canonicalize(&Value::Bool(true)).is_err());
        assert!(n_cu.canonicalize(&Value::Arr(vec![])).is_err());
        let f = schema.lookup("power.c1").unwrap();
        assert!(f.canonicalize(&Value::Str("1.0".into())).is_err());
        let hit = schema.lookup("gpu.l1_hit_cycles").unwrap();
        assert!(hit.canonicalize(&Value::Int(i64::MAX)).is_err(), "u32 overflow");
    }

    #[test]
    fn defaults_are_roundtrip_stable_and_match_the_config() {
        let dflt = SimConfig::default();
        for d in key_schema().keys() {
            // the rendered default re-parses and re-canonicalizes to
            // itself (the fingerprint-stability contract), ...
            let v = Value::parse(&d.default);
            assert_eq!(
                d.canonicalize(&v).unwrap(),
                d.default,
                "{} default not canonical",
                d.path
            );
            // ... and agrees with what the compiled config reports
            let got = dflt.get_key(d.path).expect("every registry key is readable");
            assert_eq!(d.canonicalize(&got).unwrap(), d.default, "{} drifted", d.path);
        }
    }

    #[test]
    fn every_key_sets_and_reads_back() {
        let mut cfg = SimConfig::default();
        for d in key_schema().keys() {
            let v = Value::parse(&d.default);
            cfg.set_key(d.path, &v).unwrap_or_else(|e| panic!("{}: {e}", d.path));
            let back = cfg.get_key(d.path).unwrap();
            assert_eq!(
                d.canonicalize(&back).unwrap(),
                d.default,
                "{} set/get roundtrip drifted",
                d.path
            );
        }
    }
}
