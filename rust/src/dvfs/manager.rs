//! The per-epoch DVFS manager — the coordination loop that ties the
//! simulator, estimation models, predictors, objective and the (PJRT or
//! native) compute backend together.
//!
//! Epoch boundary protocol (fixed-time epochs, paper §3.1):
//!
//! 1. **Predict** each domain's `(S, I0)` for the upcoming epoch
//!    (policy-specific: last-value, PC-table lookup, or oracle sample).
//! 2. **Evaluate + select**: run the `dvfs_step` compute graph (the AOT
//!    artifact on the hot path, or its native mirror) to obtain the
//!    objective grid and per-domain best state; program the IVRs (paying
//!    the transition blackout for state changes).
//! 3. **Run** the epoch on the simulator.
//! 4. **Estimate** the elapsed epoch (models §2.3 / wavefront estimator
//!    §4.4 — the latter comes back from the same backend call) and
//!    **update** the predictor.
//!
//! Note on update ordering: the PC-table *lookup* for epoch `t` uses the
//! table as of the update for epoch `t−2`'s estimates (updates ride the
//! same backend call as the next lookup).  The paper makes the same
//! trade: "the update mechanism happens in a non-critical path and has no
//! latency impact on future predictions" (§4.4).

use crate::config::SimConfig;
use crate::dvfs::native::{DvfsStepBackend, NativeBackend, StepInputs, StepOutputs};
use crate::dvfs::objective::Objective;
use crate::dvfs::sensitivity::{ladder_regret, prediction_accuracy, SensEstimate};
use crate::models::{estimate_cu, EstModel};
use crate::obs::{DecisionSample, EpochSample, NoopSink, ObsSink, RunCounters, RunEndSample};
use crate::power::params::{freq_index, FREQS_GHZ, N_FREQ};
use crate::predictors::{OracleSampler, PcTables, ReactiveState};
use crate::sim::gpu::{EpochObservation, Gpu, KernelLaunch};
use crate::stats::{EpochRecord, RunResult, ServeStats};
use crate::util::{hash2, SplitMix64};
use crate::workloads::WorkloadSpec;

/// Domain-separation tag for the serve-mode arrival RNG ("serve" in
/// ASCII): the arrival stream is a pure function of `(seed, tag)` and is
/// therefore identical across policies, objectives and sim widths.
const SERVE_TAG: u64 = 0x73_6572_7665;

/// The DVFS designs of paper Table III (plus static baselines).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Policy {
    /// Fixed ladder state for the whole run.
    Static(usize),
    /// CU-level estimation model used reactively (STALL/LEAD/CRIT/CRISP).
    Reactive(EstModel),
    /// Accurate (oracle-sampled) estimates used reactively — ACCREAC.
    AccReac,
    /// Wavefront STALL estimator + PC table — PCSTALL.
    PcStall,
    /// Accurate per-wavefront estimates + PC table — ACCPC.
    AccPc,
    /// Accurate estimates of the *next* epoch — ORACLE.
    Oracle,
}

impl Policy {
    pub fn name(&self) -> String {
        match self {
            Policy::Static(idx) => format!("STATIC-{:.1}", FREQS_GHZ[*idx]),
            Policy::Reactive(m) => m.name().to_string(),
            Policy::AccReac => "ACCREAC".into(),
            Policy::PcStall => "PCSTALL".into(),
            Policy::AccPc => "ACCPC".into(),
            Policy::Oracle => "ORACLE".into(),
        }
    }

    /// All DVFS designs evaluated in the paper's Fig. 14/15 (no statics).
    pub fn all_dvfs() -> Vec<Policy> {
        vec![
            Policy::Reactive(EstModel::Stall),
            Policy::Reactive(EstModel::Lead),
            Policy::Reactive(EstModel::Crit),
            Policy::Reactive(EstModel::Crisp),
            Policy::AccReac,
            Policy::PcStall,
            Policy::AccPc,
            Policy::Oracle,
        ]
    }

    /// Parse a CLI policy name.
    pub fn parse(s: &str) -> anyhow::Result<Policy> {
        let lower = s.to_ascii_lowercase();
        Ok(match lower.as_str() {
            "stall" => Policy::Reactive(EstModel::Stall),
            "lead" => Policy::Reactive(EstModel::Lead),
            "crit" => Policy::Reactive(EstModel::Crit),
            "crisp" => Policy::Reactive(EstModel::Crisp),
            "accreac" => Policy::AccReac,
            "pcstall" => Policy::PcStall,
            "accpc" => Policy::AccPc,
            "oracle" => Policy::Oracle,
            _ => {
                if let Some(f) = lower.strip_prefix("static:") {
                    let ghz: f64 = f.parse()?;
                    Policy::Static(freq_index(ghz))
                } else {
                    anyhow::bail!(
                        "unknown policy '{s}' (stall|lead|crit|crisp|accreac|pcstall|accpc|oracle|static:<ghz>)"
                    );
                }
            }
        })
    }

    fn uses_oracle(&self) -> bool {
        matches!(self, Policy::AccReac | Policy::AccPc | Policy::Oracle)
    }

    /// Whether this design owns a PC table (Table I accounting).
    pub fn uses_pc_table(&self) -> bool {
        matches!(self, Policy::PcStall | Policy::AccPc)
    }
}

/// Run termination mode.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RunMode {
    /// Run exactly this many epochs (characterization experiments).
    Epochs(u64),
    /// Run until the workload completes (fixed-work ED^nP experiments),
    /// bounded by a safety cap.
    Completion { max_epochs: u64 },
    /// Continuous-traffic serving: a seeded arrival process re-launches
    /// the loaded workload `cfg.serve.launches` times, queueing launches
    /// while the GPU is busy, until all launches drain or the cap hits.
    Serve { max_epochs: u64 },
}

/// The manager.
pub struct DvfsManager {
    pub cfg: SimConfig,
    pub gpu: Gpu,
    pub policy: Policy,
    pub objective: Objective,
    backend: Box<dyn DvfsStepBackend>,
    sampler: OracleSampler,
    reactive: ReactiveState,
    pc: PcTables,
    /// Prediction made for the *current* epoch (per domain), for accuracy
    /// scoring after the epoch runs.
    pending_pred_instr: Option<Vec<f64>>,
    /// Last observation (estimation inputs for the next boundary).
    last_ob: Option<EpochObservation>,
    /// Oracle sample of the elapsed epoch (ACCREAC/ACCPC update payload).
    last_sample: Option<crate::predictors::OracleSample>,
    epoch_idx: u64,
    /// Observability sink, consulted at epoch boundaries only.  The
    /// default [`NoopSink`] reports `enabled() == false`, so the loop
    /// pays one virtual call per epoch and builds no samples.
    obs_sink: Box<dyn ObsSink>,
    /// Serve-mode inter-arrival override (µs): when set, gaps are read
    /// from this list (cycled) instead of drawn from the seeded arrival
    /// process — the trace-derived arrival path of `pcstall serve`.
    arrival_gaps_us: Option<Vec<f64>>,
}

impl DvfsManager {
    /// Build a manager with the native backend.
    pub fn new(cfg: SimConfig, workload: &WorkloadSpec, policy: Policy, objective: Objective) -> Self {
        Self::from_launches(cfg, workload.launches(), workload.rounds, policy, objective)
    }

    /// Build a manager with an explicit backend (PJRT on the hot path).
    pub fn with_backend(
        cfg: SimConfig,
        workload: &WorkloadSpec,
        policy: Policy,
        objective: Objective,
        backend: Box<dyn DvfsStepBackend>,
    ) -> Self {
        Self::from_launches_with_backend(
            cfg,
            workload.launches(),
            workload.rounds,
            policy,
            objective,
            backend,
        )
    }

    /// Build a manager from a pre-lowered launch list (trace replay and
    /// any other non-catalog workload source) with the native backend.
    pub fn from_launches(
        cfg: SimConfig,
        launches: Vec<KernelLaunch>,
        rounds: u32,
        policy: Policy,
        objective: Objective,
    ) -> Self {
        let backend = Box::new(NativeBackend { params: cfg.power });
        Self::from_launches_with_backend(cfg, launches, rounds, policy, objective, backend)
    }

    /// [`DvfsManager::from_launches`] with an explicit backend.
    pub fn from_launches_with_backend(
        cfg: SimConfig,
        launches: Vec<KernelLaunch>,
        rounds: u32,
        policy: Policy,
        objective: Objective,
        backend: Box<dyn DvfsStepBackend>,
    ) -> Self {
        let mut gpu = Gpu::new(cfg.clone());
        gpu.load_workload(launches, rounds);
        // Static policies start at their pinned state; DVFS policies start
        // at the paper's 1.7 GHz reference.
        if let Policy::Static(idx) = policy {
            gpu.set_all_frequencies(FREQS_GHZ[idx]);
        }
        let n_cu = cfg.gpu.n_cu;
        let n_wf = cfg.gpu.n_wf;
        DvfsManager {
            reactive: ReactiveState::new(n_cu),
            pc: PcTables::new(&cfg.dvfs, n_cu, n_wf),
            sampler: OracleSampler::default(),
            pending_pred_instr: None,
            last_ob: None,
            last_sample: None,
            epoch_idx: 0,
            obs_sink: Box::new(NoopSink),
            arrival_gaps_us: None,
            gpu,
            cfg,
            policy,
            objective,
            backend,
        }
    }

    /// Execute a full run.
    pub fn run(&mut self, mode: RunMode, workload_name: &str) -> RunResult {
        let max = match mode {
            RunMode::Epochs(n) => n,
            RunMode::Completion { max_epochs } => max_epochs,
            RunMode::Serve { max_epochs } => return self.run_serve(max_epochs, workload_name),
        };
        let mut records = Vec::new();
        let mut total_energy = 0f64;
        let mut total_instr = 0f64;
        let mut acc_sum = 0f64;
        let mut acc_n = 0u64;

        // Predictor warm-up: the first epochs have no history (reactive)
        // and an empty PC table; their trivially-wrong predictions are
        // excluded from the accuracy aggregate (they still count for
        // energy/delay — the mechanism pays for its cold start).
        const ACC_WARMUP: u64 = 2;

        for i in 0..max {
            if matches!(mode, RunMode::Completion { .. }) && self.gpu.workload_done() {
                break;
            }
            let rec = self.step_epoch();
            total_energy += rec.energy_j;
            total_instr += rec.instr;
            if rec.accuracy.is_finite() && i >= ACC_WARMUP {
                acc_sum += rec.accuracy;
                acc_n += 1;
            }
            records.push(rec);
        }

        // Fixed-work runs use the exact time of the last commit as delay
        // (the final epoch is usually only partially occupied); fixed-time
        // runs use the epoch-quantized duration.
        let completed = self.gpu.workload_done();
        let total_time_ns = if completed && matches!(mode, RunMode::Completion { .. }) {
            self.gpu.last_commit_ns()
        } else {
            records.len() as f64 * self.cfg.dvfs.epoch_ns
        };

        // Obs channel 1: run-cumulative counters (memory + PC table)
        // only make sense as whole-run totals.
        self.emit_run_end_obs();
        RunResult {
            workload: workload_name.to_string(),
            policy: self.policy.name(),
            objective: self.objective.name(),
            total_energy_j: total_energy,
            total_time_ns,
            total_instr,
            mean_accuracy: if acc_n > 0 {
                acc_sum / acc_n as f64
            } else {
                f64::NAN
            },
            pc_hit_rate: self.pc.hit_rate(),
            completed,
            serve: None,
            records,
        }
    }

    /// Whole-run counter flush (obs channel 1) shared by the batch and
    /// serve run loops.
    fn emit_run_end_obs(&mut self) {
        if self.obs_sink.enabled() {
            let (pc_hits, pc_misses, pc_evictions) = self.pc.counts();
            let end = RunEndSample {
                mem: self.gpu.mem_counters(),
                pc_hits,
                pc_misses,
                pc_evictions,
                n_domains: self.gpu.n_domains(),
            };
            self.obs_sink.on_run_end(&end);
        }
    }

    /// Install a trace-derived inter-arrival gap list (µs) for serve
    /// mode, replacing the seeded synthetic arrival process.  The list
    /// is cycled if shorter than `serve.launches`.
    pub fn set_arrival_gaps(&mut self, gaps_us: Option<Vec<f64>>) {
        self.arrival_gaps_us = gaps_us;
    }

    /// Absolute arrival times (µs) of every launch in the stream: either
    /// the cycled trace-derived gap list, or a seeded two-state modulated
    /// Poisson process (MMPP-2) that degenerates to pure Poisson at
    /// `serve.burst_factor == 1.0`.
    fn arrival_times_us(&self) -> Vec<f64> {
        let s = &self.cfg.serve;
        assert!(s.launches > 0, "serve.launches must be positive");
        let mut out = Vec::with_capacity(s.launches);
        let mut t = 0f64;
        if let Some(gaps) = &self.arrival_gaps_us {
            assert!(!gaps.is_empty(), "arrival-gap trace must be non-empty");
            for i in 0..s.launches {
                t += gaps[i % gaps.len()].max(0.0);
                out.push(t);
            }
            return out;
        }
        assert!(s.arrival_rate > 0.0, "serve.arrival_rate must be positive");
        assert!(s.burst_factor >= 1.0, "serve.burst_factor must be >= 1");
        let mut rng = SplitMix64::new(hash2(self.cfg.seed, SERVE_TAG));
        if s.burst_factor == 1.0 {
            // Pure Poisson: exactly one draw per arrival.
            for _ in 0..s.launches {
                t += exp_gap(&mut rng, s.arrival_rate);
                out.push(t);
            }
            return out;
        }
        // MMPP-2: exponential dwell times (mean `burst_dwell_us`)
        // alternate a calm state (base rate) with a burst state (rate ×
        // burst_factor).  A gap that overruns the current dwell advances
        // to the state flip and redraws — unbiased by memorylessness.
        assert!(s.burst_dwell_us > 0.0, "serve.burst_dwell_us must be positive");
        let mut in_burst = false;
        let mut dwell_left = exp_gap(&mut rng, 1.0 / s.burst_dwell_us);
        for _ in 0..s.launches {
            loop {
                let rate = if in_burst {
                    s.arrival_rate * s.burst_factor
                } else {
                    s.arrival_rate
                };
                let gap = exp_gap(&mut rng, rate);
                if gap <= dwell_left {
                    dwell_left -= gap;
                    t += gap;
                    out.push(t);
                    break;
                }
                t += dwell_left;
                in_burst = !in_burst;
                dwell_left = exp_gap(&mut rng, 1.0 / s.burst_dwell_us);
            }
        }
        out
    }

    /// The serve loop: a seeded arrival process re-launches the loaded
    /// workload (the "template") `serve.launches` times; launches queue
    /// FIFO while the GPU is busy, and the DVFS boundary protocol keeps
    /// running across launch and idle gaps alike (predictor state is
    /// never reset — serving is one long run).
    ///
    /// Under [`Objective::Deadline`] the per-epoch objective is phase-
    /// switched: an `EnergyBound` with `serve.slack_slowdown` while every
    /// outstanding launch has comfortable slack, tightened to a zero
    /// bound (max-perf) once the most urgent remaining-deadline fraction
    /// drops below `serve.risk_frac`.
    fn run_serve(&mut self, max_epochs: u64, workload_name: &str) -> RunResult {
        let scfg = self.cfg.serve.clone();
        assert!(scfg.deadline_us > 0.0, "serve.deadline_us must be positive");

        // Capture the launch template, then restart from an idle GPU so
        // the constructor-loaded copy doesn't run before the first
        // arrival (launch 0 pays its queueing delay like every other).
        let template: Vec<KernelLaunch> = self.gpu.loaded_kernels().to_vec();
        let rounds = self.gpu.loaded_rounds().max(1);
        self.gpu = Gpu::new(self.cfg.clone());
        if let Policy::Static(idx) = self.policy {
            self.gpu.set_all_frequencies(FREQS_GHZ[idx]);
        }

        let arrivals_us = self.arrival_times_us();
        let n = arrivals_us.len();
        let epoch_us = self.cfg.dvfs.epoch_ns / 1000.0;
        let deadline_objective = self.objective == Objective::Deadline;

        let mut next_arrival = 0usize;
        let mut queue: std::collections::VecDeque<usize> = std::collections::VecDeque::new();
        let mut in_service: Option<usize> = None;
        // NaN = not completed before the epoch cap (counted as a miss,
        // excluded from the latency percentiles).
        let mut latency_us = vec![f64::NAN; n];
        let mut queue_depth_sum = 0f64;

        let mut records = Vec::new();
        let mut total_energy = 0f64;
        let mut total_instr = 0f64;
        let mut acc_sum = 0f64;
        let mut acc_n = 0u64;
        const ACC_WARMUP: u64 = 2;

        while (records.len() as u64) < max_epochs {
            let t_us = records.len() as f64 * epoch_us;

            // Launch-queue service point (epoch boundary): enqueue every
            // arrival due by now, then dispatch the head if idle.
            while next_arrival < n && arrivals_us[next_arrival] <= t_us {
                queue.push_back(next_arrival);
                next_arrival += 1;
            }
            if in_service.is_none() {
                if let Some(j) = queue.pop_front() {
                    self.gpu.dispatch_workload(template.clone(), rounds);
                    in_service = Some(j);
                }
            }
            if in_service.is_none() && queue.is_empty() && next_arrival >= n {
                break; // stream drained
            }
            queue_depth_sum += queue.len() as f64 + in_service.is_some() as u64 as f64;

            if deadline_objective {
                let mut min_frac = f64::INFINITY;
                for &j in in_service.iter().chain(queue.iter()) {
                    let remain = arrivals_us[j] + scfg.deadline_us - t_us;
                    min_frac = min_frac.min(remain / scfg.deadline_us);
                }
                let bound = if min_frac < scfg.risk_frac {
                    0.0 // at risk: max-perf
                } else {
                    scfg.slack_slowdown
                };
                self.objective = Objective::EnergyBound { max_slowdown: bound };
            }
            let rec = self.step_epoch();
            if deadline_objective {
                self.objective = Objective::Deadline;
            }

            total_energy += rec.energy_j;
            total_instr += rec.instr;
            if rec.accuracy.is_finite() && rec.epoch >= ACC_WARMUP {
                acc_sum += rec.accuracy;
                acc_n += 1;
            }
            records.push(rec);

            if in_service.is_some() && self.gpu.workload_done() {
                let j = in_service.take().unwrap();
                // Exact completion time: the last commit freezes when the
                // launch drains, even though the epoch runs to its end.
                let done_us = self.gpu.last_commit_ns() / 1000.0;
                latency_us[j] = (done_us - arrivals_us[j]).max(0.0);
            }
        }
        let all_done = in_service.is_none() && queue.is_empty() && next_arrival >= n;

        let mut lats: Vec<f64> = latency_us.iter().copied().filter(|l| l.is_finite()).collect();
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let completed_launches = lats.len() as u64;
        let misses = latency_us
            .iter()
            .filter(|l| !(l.is_finite() && **l <= scfg.deadline_us))
            .count();
        let sim_ms = records.len() as f64 * self.cfg.dvfs.epoch_ns * 1e-6;
        let serve = ServeStats {
            launches: n as u64,
            completed_launches,
            p50_us: percentile_nearest_rank(&lats, 0.50),
            p99_us: percentile_nearest_rank(&lats, 0.99),
            mean_latency_us: if lats.is_empty() {
                f64::NAN
            } else {
                lats.iter().sum::<f64>() / lats.len() as f64
            },
            deadline_miss_rate: misses as f64 / n as f64,
            throughput_per_ms: if sim_ms > 0.0 {
                completed_launches as f64 / sim_ms
            } else {
                0.0
            },
            mean_queue_depth: if records.is_empty() {
                0.0
            } else {
                queue_depth_sum / records.len() as f64
            },
        };

        self.emit_run_end_obs();
        RunResult {
            workload: workload_name.to_string(),
            policy: self.policy.name(),
            objective: self.objective.name(),
            total_energy_j: total_energy,
            total_time_ns: records.len() as f64 * self.cfg.dvfs.epoch_ns,
            total_instr,
            mean_accuracy: if acc_n > 0 {
                acc_sum / acc_n as f64
            } else {
                f64::NAN
            },
            pc_hit_rate: self.pc.hit_rate(),
            completed: all_done,
            serve: Some(serve),
            records,
        }
    }

    /// One epoch of the boundary protocol.  Public so experiments can
    /// interleave their own measurements.
    pub fn step_epoch(&mut self) -> EpochRecord {
        let n_dom = self.gpu.n_domains();

        // ---- (oracle family) pre-execute the upcoming epoch -------------
        let sample = if self.policy.uses_oracle() {
            Some(self.sampler.sample(&self.gpu))
        } else {
            None
        };

        // ---- 1. predict (S, I0) per domain ------------------------------
        let pred: Vec<SensEstimate> = match self.policy {
            Policy::Static(_) => vec![SensEstimate::default(); n_dom],
            Policy::Oracle => sample.as_ref().unwrap().dom.clone(),
            Policy::Reactive(_) | Policy::AccReac => (0..n_dom)
                .map(|d| self.reactive.predict_domain(self.gpu.domain_cus(d)))
                .collect(),
            Policy::PcStall | Policy::AccPc => self.predict_pc_table(),
        };

        // Physical clamp: no prediction may exceed the machine's peak
        // commit rate (issue_width instructions per cycle).  Guards the
        // selector against stale/aliased PC-table entries, which otherwise
        // destroy accuracy at coarse epochs where PCs rarely recur.
        let epoch_ns = self.cfg.dvfs.epoch_ns;
        let width = self.cfg.gpu.issue_width as f64 * self.cfg.dvfs.cus_per_domain as f64;
        let max_sens = width * epoch_ns; // dI/df of a fully compute-bound domain
        let max_i0 = width * epoch_ns * crate::power::params::FREQS_GHZ[N_FREQ - 1];
        let pred: Vec<SensEstimate> = pred
            .into_iter()
            .map(|e| SensEstimate::new(e.sens.clamp(0.0, max_sens), e.i0.clamp(0.0, max_i0)))
            .collect();

        // ---- 2. evaluate grid + select ----------------------------------
        let inputs = self.build_step_inputs(&pred);
        let out = self
            .backend
            .step(&inputs)
            .expect("dvfs step backend failed");
        let mut freq_idx = vec![0u8; n_dom];
        let mut pred_instr_at_choice = vec![0f64; n_dom];
        for d in 0..n_dom {
            let row_i = grid_row(&out.pred_instr, d);
            let row_p = grid_row(&out.power_w, d);
            let row_e = grid_row(&out.ednp, d);
            let k = match self.policy {
                Policy::Static(idx) => idx,
                _ => self.objective.select(&row_i, &row_p, &row_e),
            };
            freq_idx[d] = k as u8;
            pred_instr_at_choice[d] = row_i[k];
        }

        // energy cost of the transitions we are about to make
        let obs_on = self.obs_sink.enabled();
        let mut switched_domains: Vec<usize> = Vec::new();
        let mut transition_energy = 0f64;
        for d in 0..n_dom {
            let from = self.gpu.domain_frequency(d);
            let to = FREQS_GHZ[freq_idx[d] as usize];
            if (from - to).abs() > 1e-9 {
                transition_energy += self.cfg.power.transition_energy_j(from, to)
                    * self.gpu.domain_cus(d).len() as f64;
                if obs_on {
                    switched_domains.push(d);
                }
            }
            self.gpu.set_domain_frequency(d, to);
        }

        // ---- 3. run the epoch --------------------------------------------
        let ob = self.gpu.run_epoch();

        // ---- obs channel 1: epoch-boundary counter sample ----------------
        if obs_on {
            let mut s = EpochSample {
                switched_domains,
                ..EpochSample::default()
            };
            for c in &ob.cu {
                s.instr += c.instr;
                s.cycles += c.cycles;
                s.issued_cycles += c.issued_cycles;
                s.stall_waitcnt_ps += c.stall_all_ps;
                s.stall_mem_outstanding_ps += c.mem_outstanding_ps;
                s.stall_issue_empty_ps += c.issue_empty_ps;
            }
            self.obs_sink.on_epoch(&s);
        }

        // ---- accuracy scoring (prediction made for THIS epoch) ----------
        let actual_dom = self.gpu.domain_epoch_instr();
        let accuracy = if matches!(self.policy, Policy::Static(_)) {
            f64::NAN
        } else {
            let mut s = 0f64;
            let mut n = 0u64;
            for d in 0..n_dom {
                // only score domains that did meaningful work
                if actual_dom[d] > 1.0 || pred_instr_at_choice[d] > 1.0 {
                    s += prediction_accuracy(pred_instr_at_choice[d], actual_dom[d]);
                    n += 1;
                }
            }
            if n > 0 {
                s / n as f64
            } else {
                f64::NAN
            }
        };

        // ---- energy accounting -------------------------------------------
        let mut energy = transition_energy;
        for cu in &self.gpu.cus {
            energy += self
                .cfg
                .power
                .epoch_power(cu.counters.freq_ghz, cu.counters.instr as f64, self.cfg.dvfs.epoch_ns)
                .energy_j;
        }

        // ---- obs channel 3: per-domain decision audit --------------------
        // Emitted before `sample` moves into the predictor update: the
        // regret column re-scores the oracle's measured ladder.
        if obs_on {
            let epoch_ns_ps = epoch_ns * 1000.0;
            for d in 0..n_dom {
                let chosen = freq_idx[d] as usize;
                let (regret, best) = match (&self.policy, &sample) {
                    // the oracle minimized over its own ladder — 0 by
                    // definition (its linreg smoothing may pick a state
                    // off the raw-sample argmin, which is not regret)
                    (Policy::Oracle, _) | (_, None) => (0.0, chosen),
                    (_, Some(s)) => ladder_regret(
                        &s.dom_instr_at[d],
                        chosen,
                        &self.objective,
                        epoch_ns,
                        &self.cfg.power,
                    ),
                };
                let (pc, has_pc) = if self.policy.uses_pc_table() {
                    self.modal_domain_pc(d)
                } else {
                    (0, false)
                };
                let cus = self.gpu.domain_cus(d);
                let n_cus = cus.len().max(1);
                let stall_ps: u64 = cus
                    .map(|c| {
                        let k = &ob.cu[c];
                        k.stall_all_ps + k.mem_outstanding_ps + k.issue_empty_ps
                    })
                    .sum();
                let ds = DecisionSample {
                    epoch: self.epoch_idx,
                    domain: d,
                    pc,
                    has_pc,
                    pred_instr: pred_instr_at_choice[d],
                    chosen: chosen as u8,
                    oracle_best: best as u8,
                    actual_instr: actual_dom[d],
                    accuracy,
                    stall_frac: stall_ps as f64 / (n_cus as f64 * epoch_ns_ps),
                    energy_j: energy,
                    regret,
                };
                self.obs_sink.on_decision(&ds);
            }
        }

        // ---- 4. estimate elapsed epoch + update predictors ---------------
        let prev_ob = self.last_ob.take();
        self.update_predictors(&ob, prev_ob.as_ref(), &out, sample);

        let dom_sens: Vec<f32> = pred.iter().map(|e| e.sens as f32).collect();
        let instr: f64 = actual_dom.iter().sum();
        self.epoch_idx += 1;
        self.pending_pred_instr = Some(pred_instr_at_choice);
        self.last_ob = Some(ob);

        EpochRecord {
            epoch: self.epoch_idx - 1,
            t_ns: crate::sim::ps_to_ns(self.gpu.now_ps),
            freq_idx,
            instr,
            energy_j: energy,
            accuracy,
            dom_sens,
        }
    }

    /// PC-table lookup path: per-WF prediction keyed by the *current*
    /// (next-epoch-start) PC of every resident wavefront.
    fn predict_pc_table(&mut self) -> Vec<SensEstimate> {
        let n_dom = self.gpu.n_domains();
        let Some(ob) = &self.last_ob else {
            return vec![SensEstimate::default(); n_dom];
        };
        let mut per_cu = vec![SensEstimate::default(); self.gpu.cfg.gpu.n_cu];
        for c in 0..ob.wf_next_pc.len() {
            let mut sum = SensEstimate::default();
            for w in 0..ob.wf_next_pc[c].len() {
                if !ob.wf_next_active[c][w] {
                    continue;
                }
                let e = self
                    .pc
                    .lookup_wf(c, w, ob.wf_next_kernel[c][w], ob.wf_next_pc[c][w]);
                sum.sens += e.sens;
                sum.i0 += e.i0;
            }
            sum.i0 = sum.i0.max(0.0);
            per_cu[c] = sum;
        }
        (0..n_dom)
            .map(|d| SensEstimate::sum(self.gpu.domain_cus(d).map(|c| per_cu[c])))
            .collect()
    }

    /// Modal epoch-start PC among the domain's active wavefronts, masked
    /// to the PC table's aliasing bucket (two PCs in one bucket are the
    /// same entry to the predictor); ties break toward the lowest PC.
    /// `(_, false)` before the first epoch or with no active wavefront.
    fn modal_domain_pc(&self, d: usize) -> (u32, bool) {
        let Some(ob) = &self.last_ob else {
            return (0, false);
        };
        let mut counts: std::collections::BTreeMap<u32, u32> = std::collections::BTreeMap::new();
        for c in self.gpu.domain_cus(d) {
            for w in 0..ob.wf_next_pc[c].len() {
                if ob.wf_next_active[c][w] {
                    *counts
                        .entry(self.pc.bucket_base_pc(ob.wf_next_pc[c][w]))
                        .or_insert(0) += 1;
                }
            }
        }
        match counts
            .into_iter()
            .max_by_key(|&(pc, n)| (n, std::cmp::Reverse(pc)))
        {
            Some((pc, _)) => (pc, true),
            None => (0, false),
        }
    }

    /// Estimation of the elapsed epoch → predictor state updates.
    /// `ob` is the just-finished epoch (reactive models estimate it
    /// directly from counters); `prev_ob` is the epoch whose wavefront
    /// stats the backend call consumed — kernel-1 outputs (`out.sens_wf`)
    /// are keyed by *its* start PCs.
    fn update_predictors(
        &mut self,
        ob: &EpochObservation,
        prev_ob: Option<&EpochObservation>,
        out: &StepOutputs,
        sample: Option<crate::predictors::OracleSample>,
    ) {
        match self.policy {
            Policy::Static(_) => {}
            Policy::Reactive(model) => {
                for (c, counters) in ob.cu.iter().enumerate() {
                    self.reactive.update(c, estimate_cu(model, counters));
                }
            }
            Policy::AccReac => {
                // the sample taken at this boundary pre-executed THIS
                // epoch; as a reactive estimate it predicts the next one.
                if let Some(s) = &sample {
                    for d in 0..s.dom.len() {
                        let cus = self.gpu.domain_cus(d);
                        let k = cus.len().max(1);
                        for c in cus {
                            // spread the domain estimate over member CUs
                            self.reactive.update(
                                c,
                                SensEstimate::new(s.dom[d].sens / k as f64, s.dom[d].i0 / k as f64),
                            );
                        }
                    }
                }
            }
            Policy::PcStall => {
                // Wavefront estimates came back from the backend call
                // (kernel-1 output), computed over `prev_ob`'s stats and
                // therefore keyed by *its* epoch-start PCs.  The update
                // rides one boundary behind execution — the paper's
                // "non-critical-path" update (§4.4).
                let Some(pob) = prev_ob else { return };
                let n_wf = self.cfg.gpu.n_wf;
                for c in 0..pob.wf_instr.len() {
                    for w in 0..n_wf {
                        if !pob.wf_active[c][w] {
                            continue;
                        }
                        let sens = out.sens_wf[c * n_wf + w] as f64;
                        let i0 = pob.wf_instr[c][w] as f64 - sens * pob.cu[c].freq_ghz;
                        let est = SensEstimate::new(sens, i0);
                        self.pc
                            .update_wf(c, pob.wf_start_kernel[c][w], pob.wf_start_pc[c][w], est);
                        self.pc.remember_last(c, w, est);
                    }
                }
            }
            Policy::AccPc => {
                if let Some(s) = &sample {
                    for c in 0..s.wf.len() {
                        for w in 0..s.wf[c].len() {
                            if !s.wf_active[c][w] {
                                continue;
                            }
                            let est = s.wf[c][w];
                            self.pc.update_wf(
                                c,
                                s.wf_start_kernel[c][w],
                                s.wf_start_pc[c][w],
                                est,
                            );
                            self.pc.remember_last(c, w, est);
                        }
                    }
                }
            }
            Policy::Oracle => {}
        }
        self.last_sample = sample;
    }

    /// Flatten an observation + predictions into backend inputs.
    fn build_step_inputs(&self, pred: &[SensEstimate]) -> StepInputs {
        let n_cu = self.cfg.gpu.n_cu;
        let n_wf = self.cfg.gpu.n_wf;
        let n_dom = pred.len();
        let mut inp = StepInputs::zeros(n_cu, n_wf);
        inp.n_exp = self.objective.n_exp() as f32;
        inp.epoch_ns = self.cfg.dvfs.epoch_ns as f32;
        if let Some(ob) = &self.last_ob {
            for c in 0..n_cu {
                inp.freq_ghz[c] = ob.cu[c].freq_ghz as f32;
                for w in 0..n_wf {
                    let i = c * n_wf + w;
                    inp.instr[i] = ob.wf_instr[c][w];
                    inp.t_core_ns[i] = ob.wf_core_ns[c][w];
                    inp.age_factor[i] = ob.wf_age_factor[c][w];
                }
            }
        }
        // predictions live in the first n_dom lanes; the rest are masked
        for d in 0..n_cu {
            if d < n_dom {
                inp.pred_sens[d] = pred[d].sens as f32;
                inp.pred_i0[d] = pred[d].i0 as f32;
                inp.mask[d] = 1.0;
            } else {
                inp.mask[d] = 0.0;
            }
        }
        inp
    }

    /// PC-table hit rate (sizing experiments).
    pub fn pc_hit_rate(&self) -> f64 {
        self.pc.hit_rate()
    }

    /// Install an observability sink (default: the no-op sink).
    pub fn set_obs_sink(&mut self, sink: Box<dyn ObsSink>) {
        self.obs_sink = sink;
    }

    /// Counter totals accumulated by the installed sink, if any.
    pub fn obs_counters(&self) -> Option<&RunCounters> {
        self.obs_sink.counters()
    }

    /// Decision trace (obs channel 3) accumulated by the installed
    /// sink, if any — emission order: epoch-major, domain-minor.
    pub fn obs_decisions(&self) -> Option<&[DecisionSample]> {
        self.obs_sink.decisions()
    }
}

/// One exponential inter-event gap at `rate` events per µs (inverse-CDF
/// sampling; `u ∈ [0,1)` keeps the argument of `ln` in `(0,1]`).
fn exp_gap(rng: &mut SplitMix64, rate: f64) -> f64 {
    -(1.0 - rng.next_f64()).ln() / rate
}

/// Nearest-rank percentile of an ascending-sorted slice (NaN if empty).
/// Monotone in `p` by construction, so p99 ≥ p50 always holds.
fn percentile_nearest_rank(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let k = ((p * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[k - 1]
}

/// Extract one domain's N_FREQ-row from a flattened grid.
fn grid_row(grid: &[f32], d: usize) -> [f64; N_FREQ] {
    let mut row = [0f64; N_FREQ];
    for k in 0..N_FREQ {
        row[k] = grid[d * N_FREQ + k] as f64;
    }
    row
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    fn small_cfg() -> SimConfig {
        let mut c = SimConfig::small();
        c.gpu.n_cu = 4;
        c.gpu.n_wf = 8;
        c
    }

    fn run_policy(policy: Policy, epochs: u64) -> RunResult {
        let wl = workloads::build("comd", 0.25);
        let mut m = DvfsManager::new(small_cfg(), &wl, policy, Objective::Ed2p);
        m.run(RunMode::Epochs(epochs), "comd")
    }

    #[test]
    fn static_policy_never_switches() {
        let r = run_policy(Policy::Static(4), 10);
        for rec in &r.records {
            assert!(rec.freq_idx.iter().all(|&k| k == 4));
        }
        assert!(r.mean_accuracy.is_nan());
    }

    #[test]
    fn policies_produce_energy_and_instructions() {
        for p in [
            Policy::Reactive(EstModel::Crisp),
            Policy::PcStall,
            Policy::Oracle,
        ] {
            let r = run_policy(p, 6);
            assert_eq!(r.records.len(), 6);
            assert!(r.total_energy_j > 0.0, "{}", p.name());
            assert!(r.total_instr > 0.0, "{}", p.name());
        }
    }

    #[test]
    fn oracle_accuracy_beats_reactive() {
        let r_oracle = run_policy(Policy::Oracle, 12);
        let r_stall = run_policy(Policy::Reactive(EstModel::Stall), 12);
        assert!(
            r_oracle.mean_accuracy > r_stall.mean_accuracy,
            "oracle {} vs stall {}",
            r_oracle.mean_accuracy,
            r_stall.mean_accuracy
        );
        assert!(r_oracle.mean_accuracy > 0.8, "{}", r_oracle.mean_accuracy);
    }

    #[test]
    fn dvfs_policy_adapts_to_workload_character() {
        // compute-heavy hacc must live at higher states than memory-bound
        // xsbench under the same oracle/ED²P policy.
        let share_of = |wl_name: &str| {
            let wl = workloads::build(wl_name, 0.25);
            let mut m = DvfsManager::new(small_cfg(), &wl, Policy::Oracle, Objective::Ed2p);
            let r = m.run(RunMode::Epochs(12), wl_name);
            let share = r.freq_time_share();
            // mean selected state index
            share
                .iter()
                .enumerate()
                .map(|(k, s)| k as f64 * s)
                .sum::<f64>()
        };
        let hacc = share_of("hacc");
        let xsbench = share_of("xsbench");
        assert!(
            hacc > xsbench + 0.5,
            "oracle did not separate workloads: hacc mean state {hacc}, xsbench {xsbench}"
        );
    }

    #[test]
    fn completion_mode_stops_at_workload_end() {
        let wl = workloads::build("comd", 0.02);
        let mut m = DvfsManager::new(small_cfg(), &wl, Policy::Static(4), Objective::Ed2p);
        let r = m.run(
            RunMode::Completion { max_epochs: 5_000 },
            "comd",
        );
        assert!(r.completed, "workload did not complete in 5000 epochs");
        assert!(r.records.len() < 5_000);
    }

    #[test]
    fn pcstall_populates_table() {
        let wl = workloads::build("comd", 0.25);
        let mut m = DvfsManager::new(small_cfg(), &wl, Policy::PcStall, Objective::Ed2p);
        m.run(RunMode::Epochs(20), "comd");
        assert!(m.pc_hit_rate() > 0.3, "hit rate {}", m.pc_hit_rate());
    }

    #[test]
    fn counter_sink_observes_without_perturbing() {
        let wl = workloads::build("comd", 0.25);
        let run = |with_sink: bool| {
            let mut m = DvfsManager::new(small_cfg(), &wl, Policy::PcStall, Objective::Ed2p);
            if with_sink {
                m.set_obs_sink(Box::new(crate::obs::CounterSink::new()));
            }
            let r = m.run(RunMode::Epochs(8), "comd");
            let c = m.obs_counters().cloned();
            (r, c)
        };
        let (r_off, c_off) = run(false);
        let (r_on, c_on) = run(true);
        assert!(c_off.is_none(), "noop sink must expose no counters");
        let c = c_on.expect("counter sink must expose totals");
        // bit-identical results: the sink only reads, never steers
        assert_eq!(r_off.total_energy_j.to_bits(), r_on.total_energy_j.to_bits());
        assert_eq!(r_off.total_instr.to_bits(), r_on.total_instr.to_bits());
        assert_eq!(r_off.total_time_ns.to_bits(), r_on.total_time_ns.to_bits());
        for (a, b) in r_off.records.iter().zip(&r_on.records) {
            assert_eq!(a.freq_idx, b.freq_idx);
        }
        // and the totals are live: epochs, work, stalls, memory, PC table
        assert_eq!(c.epochs, 8);
        assert!((c.instr as f64 - r_on.total_instr).abs() < 1e-6);
        assert!(c.stall_total_ps() > 0, "no stall breakdown recorded");
        assert!(c.l2_accesses > 0);
        assert!(c.l2_queue_depth_hist.iter().sum::<u64>() > 0);
        assert!(c.pc_hits + c.pc_misses > 0, "no PC-table traffic");
        assert_eq!(c.transitions_per_domain.len(), r_on.records[0].freq_idx.len());
    }

    #[test]
    fn decision_trace_shape_and_regret_invariants() {
        let wl = workloads::build("comd", 0.25);
        let run = |p: Policy| {
            let mut m = DvfsManager::new(small_cfg(), &wl, p, Objective::Ed2p);
            m.set_obs_sink(Box::new(crate::obs::CounterSink::new()));
            m.run(RunMode::Epochs(8), "comd");
            let n_dom = m.gpu.n_domains();
            (m.obs_decisions().unwrap().to_vec(), n_dom)
        };
        // ACCPC: oracle-laddered and PC-keyed — regret defined, PCs present
        let (dec, n_dom) = run(Policy::AccPc);
        assert_eq!(dec.len(), 8 * n_dom, "one row per domain per epoch");
        assert_eq!((dec[0].epoch, dec[0].domain), (0, 0));
        assert_eq!(dec[n_dom].epoch, 1, "epoch-major emission order");
        assert!(dec.iter().all(|s| s.regret >= 0.0), "regret is non-negative");
        assert!(dec.iter().any(|s| s.has_pc), "ACCPC rows carry modal PCs");
        for s in &dec {
            if s.chosen == s.oracle_best {
                assert_eq!(s.regret, 0.0, "choosing the ladder best costs nothing");
            }
        }
        // ORACLE: regret identically zero on every epoch (by definition)
        let (dec_o, _) = run(Policy::Oracle);
        assert!(dec_o
            .iter()
            .all(|s| s.regret == 0.0 && s.chosen == s.oracle_best));
        // no oracle ladder at all: regret 0, best echoes chosen, no PC
        let (dec_c, _) = run(Policy::Reactive(EstModel::Crisp));
        assert!(dec_c
            .iter()
            .all(|s| s.regret == 0.0 && s.chosen == s.oracle_best && !s.has_pc));
    }

    #[test]
    fn decision_accuracy_column_reproduces_mean_accuracy() {
        let wl = workloads::build("comd", 0.25);
        let mut m = DvfsManager::new(small_cfg(), &wl, Policy::PcStall, Objective::Ed2p);
        m.set_obs_sink(Box::new(crate::obs::CounterSink::new()));
        let r = m.run(RunMode::Epochs(10), "comd");
        let dec = m.obs_decisions().unwrap();
        // accuracy is epoch-level, repeated on every domain row: take the
        // domain-0 rows and apply the same warm-up exclusion as run()
        let (mut acc_sum, mut n) = (0f64, 0u64);
        for s in dec.iter().filter(|s| s.domain == 0) {
            if s.accuracy.is_finite() && s.epoch >= 2 {
                acc_sum += s.accuracy;
                n += 1;
            }
        }
        assert!(n > 0);
        assert!(
            (acc_sum / n as f64 - r.mean_accuracy).abs() < 1e-12,
            "trace mean {} vs RunResult {}",
            acc_sum / n as f64,
            r.mean_accuracy
        );
    }

    fn serve_cfg(launches: usize, rate: f64) -> SimConfig {
        let mut c = small_cfg();
        c.serve.launches = launches;
        c.serve.arrival_rate = rate;
        c
    }

    #[test]
    fn serve_mode_drains_the_stream_and_reports_latencies() {
        let wl = workloads::build("comd", 0.02);
        let mut m = DvfsManager::new(
            serve_cfg(3, 0.1),
            &wl,
            Policy::Static(4),
            Objective::Deadline,
        );
        let r = m.run(RunMode::Serve { max_epochs: 50_000 }, "comd");
        let s = r.serve.as_ref().expect("serve run must carry ServeStats");
        assert!(r.completed, "stream did not drain: {s:?}");
        assert_eq!(s.launches, 3);
        assert_eq!(s.completed_launches, 3);
        assert!(s.p50_us > 0.0);
        assert!(s.p99_us >= s.p50_us, "p99 {} < p50 {}", s.p99_us, s.p50_us);
        assert!(s.mean_latency_us > 0.0);
        assert!(s.throughput_per_ms > 0.0);
        assert!(s.mean_queue_depth > 0.0);
        assert!((0.0..=1.0).contains(&s.deadline_miss_rate));
        assert!(r.total_energy_j > 0.0, "idle + service epochs burn energy");
    }

    #[test]
    fn serve_runs_are_deterministic_and_seeded() {
        let wl = workloads::build("comd", 0.02);
        let run = |seed: u64| {
            let mut c = serve_cfg(3, 0.1);
            c.seed = seed;
            let mut m = DvfsManager::new(c, &wl, Policy::PcStall, Objective::Deadline);
            m.run(RunMode::Serve { max_epochs: 50_000 }, "comd")
        };
        let a = run(7);
        let b = run(7);
        assert_eq!(a.serve, b.serve, "same seed must reproduce bit-exactly");
        assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
        assert_eq!(a.records.len(), b.records.len());
        let c = run(8);
        assert_ne!(
            a.serve.as_ref().unwrap().p50_us.to_bits(),
            c.serve.as_ref().unwrap().p50_us.to_bits(),
            "the seed must move the arrival stream"
        );
    }

    #[test]
    fn arrival_streams_burst_cycle_and_stay_seeded() {
        let wl = workloads::build("comd", 0.02);
        let mk = |burst: f64| {
            let mut c = serve_cfg(8, 0.05);
            c.serve.burst_factor = burst;
            DvfsManager::new(c, &wl, Policy::Static(4), Objective::Ed2p)
        };
        let poisson = mk(1.0).arrival_times_us();
        let bursty = mk(3.0).arrival_times_us();
        assert_eq!(poisson.len(), 8);
        assert!(poisson.windows(2).all(|w| w[1] >= w[0]), "times ascend");
        assert_ne!(poisson, bursty, "burst modulation must reshape the stream");
        assert_eq!(bursty, mk(3.0).arrival_times_us(), "bursty stream is seeded");
        // trace-derived gaps replace the synthetic process, cycled to
        // cover all launches
        let mut m = mk(1.0);
        m.set_arrival_gaps(Some(vec![10.0, 20.0]));
        assert_eq!(
            m.arrival_times_us(),
            vec![10.0, 30.0, 40.0, 60.0, 70.0, 90.0, 100.0, 120.0]
        );
    }

    #[test]
    fn policy_parse_roundtrip() {
        for (s, p) in [
            ("crisp", Policy::Reactive(EstModel::Crisp)),
            ("pcstall", Policy::PcStall),
            ("ORACLE", Policy::Oracle),
        ] {
            assert_eq!(Policy::parse(s).unwrap(), p);
        }
        assert_eq!(Policy::parse("static:1.7").unwrap(), Policy::Static(4));
        assert!(Policy::parse("bogus").is_err());
    }

    #[test]
    fn ed2p_of_oracle_not_worse_than_static_much() {
        // Sanity: on a mixed workload the oracle should not lose ED²P
        // badly to the static reference (it should usually win).
        let wl = workloads::build("comd", 0.05);
        let run = |p: Policy| {
            let mut m = DvfsManager::new(small_cfg(), &wl, p, Objective::Ed2p);
            m.run(RunMode::Completion { max_epochs: 3_000 }, "comd")
        };
        let st = run(Policy::Static(4));
        let or = run(Policy::Oracle);
        assert!(st.completed && or.completed);
        assert!(
            or.ed2p() < st.ed2p() * 1.3,
            "oracle ED²P {} vs static {}",
            or.ed2p(),
            st.ed2p()
        );
    }
}
