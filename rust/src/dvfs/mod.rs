//! DVFS core: the sensitivity metric, objective functions, the native
//! mirror of the AOT compute graph, and the per-epoch manager.

pub mod manager;
pub mod native;
pub mod objective;
pub mod sensitivity;

pub use manager::{DvfsManager, Policy};
pub use crate::stats::RunResult;
pub use objective::Objective;
pub use sensitivity::SensEstimate;
