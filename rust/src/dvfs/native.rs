//! Native (pure-Rust) mirror of the AOT `dvfs_step` compute graph.
//!
//! Implements exactly the math of the two Pallas kernels
//! (`python/compile/kernels/{sensitivity,selector}.py`).  Used for
//! differential testing against the PJRT artifact
//! (`rust/tests/pjrt_parity.rs`) and as the fallback backend when no
//! artifact is present.  Arithmetic is done in f32 where the kernels use
//! f32 so parity holds to ~1e-5.

use crate::power::params::{N_FREQ, PowerParams};

/// Inputs of one DVFS step (shapes follow the artifact metadata).
#[derive(Debug, Clone, Default)]
pub struct StepInputs {
    /// `[n_cu * n_wf]`, row-major.
    pub instr: Vec<f32>,
    pub t_core_ns: Vec<f32>,
    pub age_factor: Vec<f32>,
    /// `[n_cu]`.
    pub freq_ghz: Vec<f32>,
    /// `[n_dom]` (padded to n_cu for the artifact).
    pub pred_sens: Vec<f32>,
    pub pred_i0: Vec<f32>,
    pub mask: Vec<f32>,
    /// ED^nP exponent (2 = EDP, 3 = ED²P).
    pub n_exp: f32,
    pub epoch_ns: f32,
    pub n_cu: usize,
    pub n_wf: usize,
}

/// Outputs of one DVFS step.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StepOutputs {
    /// `[n_cu * n_wf]` row-major.
    pub sens_wf: Vec<f32>,
    /// `[n_cu]`.
    pub sens_cu: Vec<f32>,
    pub i0_cu: Vec<f32>,
    /// `[n_dom * N_FREQ]` row-major.
    pub pred_instr: Vec<f32>,
    pub power_w: Vec<f32>,
    pub ednp: Vec<f32>,
    /// `[n_dom]`.
    pub best_idx: Vec<f32>,
}

const EPS: f32 = 1e-6;

/// Backend abstraction: native math or the PJRT-compiled artifact.
pub trait DvfsStepBackend {
    fn step(&mut self, inp: &StepInputs) -> anyhow::Result<StepOutputs>;
    fn name(&self) -> &'static str;
}

/// The pure-Rust backend.
#[derive(Debug, Clone, Default)]
pub struct NativeBackend {
    pub params: PowerParams,
}

impl DvfsStepBackend for NativeBackend {
    fn step(&mut self, inp: &StepInputs) -> anyhow::Result<StepOutputs> {
        Ok(dvfs_step_native(inp, &self.params))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Kernel 1 mirror: wavefront sensitivity estimation.
pub fn wf_sensitivity_native(inp: &StepInputs) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (n_cu, n_wf) = (inp.n_cu, inp.n_wf);
    let mut sens_wf = vec![0f32; n_cu * n_wf];
    let mut sens_cu = vec![0f32; n_cu];
    let mut i0_cu = vec![0f32; n_cu];
    for c in 0..n_cu {
        let f = inp.freq_ghz[c];
        let cycles_epoch = inp.epoch_ns * f;
        let mut sum_sens = 0f32;
        let mut sum_instr = 0f32;
        for w in 0..n_wf {
            let idx = c * n_wf + w;
            let ipc = inp.instr[idx] / cycles_epoch.max(EPS);
            let s = ipc * inp.t_core_ns[idx] * inp.age_factor[idx];
            sens_wf[idx] = s;
            sum_sens += s;
            sum_instr += inp.instr[idx];
        }
        sens_cu[c] = sum_sens;
        i0_cu[c] = (sum_instr - sum_sens * f).max(0.0);
    }
    (sens_wf, sens_cu, i0_cu)
}

/// Kernel 2 mirror for a single domain row.
pub fn eval_grid_row(
    sens: f64,
    i0: f64,
    n_exp: f64,
    epoch_ns: f64,
    p: &PowerParams,
) -> ([f64; N_FREQ], [f64; N_FREQ], [f64; N_FREQ]) {
    let mut instr = [0f64; N_FREQ];
    let mut power = [0f64; N_FREQ];
    let mut ednp = [0f64; N_FREQ];
    for k in 0..N_FREQ {
        let f = p.f_min_ghz + 0.1 * k as f64;
        let v = p.v0 + p.kv * (f - p.f_min_ghz);
        let eta = p.eta0 + p.eta_slope * (f - p.f_min_ghz) / (p.f_max_ghz - p.f_min_ghz);
        let i = (i0 + sens * f).max(EPS as f64);
        let rate = i / epoch_ns;
        let v2 = v * v;
        let pw = (p.c1 * v2 * rate + p.c2 * v2 * f
            + p.l0 * (p.lv * (v - p.v_nom)).exp())
            / eta;
        instr[k] = i;
        power[k] = pw;
        ednp[k] = pw / rate.max(EPS as f64).powf(n_exp);
    }
    (instr, power, ednp)
}

/// Kernel 2 mirror over a *measured* ladder: the same power / ED^nP
/// math as [`eval_grid_row`], but evaluated at instruction counts that
/// were actually observed per state (the oracle's pre-executed ladder)
/// instead of the linear-model extrapolation `i0 + sens·f`.  Used by
/// the decision-trace regret column: it scores what each ladder state
/// *did* cost, so chosen-vs-best differences are exact counterfactuals.
pub fn eval_ladder_row(
    instr_at: &[f64; N_FREQ],
    n_exp: f64,
    epoch_ns: f64,
    p: &PowerParams,
) -> ([f64; N_FREQ], [f64; N_FREQ], [f64; N_FREQ]) {
    let mut instr = [0f64; N_FREQ];
    let mut power = [0f64; N_FREQ];
    let mut ednp = [0f64; N_FREQ];
    for k in 0..N_FREQ {
        let f = p.f_min_ghz + 0.1 * k as f64;
        let v = p.v0 + p.kv * (f - p.f_min_ghz);
        let eta = p.eta0 + p.eta_slope * (f - p.f_min_ghz) / (p.f_max_ghz - p.f_min_ghz);
        let i = instr_at[k].max(EPS as f64);
        let rate = i / epoch_ns;
        let v2 = v * v;
        let pw = (p.c1 * v2 * rate + p.c2 * v2 * f
            + p.l0 * (p.lv * (v - p.v_nom)).exp())
            / eta;
        instr[k] = i;
        power[k] = pw;
        ednp[k] = pw / rate.max(EPS as f64).powf(n_exp);
    }
    (instr, power, ednp)
}

/// Kernel 2 mirror: full grid in f32 (exact artifact semantics incl.
/// the masked-domain +inf rule).
pub fn freq_grid_native(
    pred_sens: &[f32],
    pred_i0: &[f32],
    mask: &[f32],
    n_exp: f32,
    epoch_ns: f32,
    p: &PowerParams,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let n_dom = pred_sens.len();
    let mut instr = vec![0f32; n_dom * N_FREQ];
    let mut power = vec![0f32; n_dom * N_FREQ];
    let mut ednp = vec![0f32; n_dom * N_FREQ];
    let mut best = vec![0f32; n_dom];
    for d in 0..n_dom {
        let mut best_k = 0usize;
        let mut best_v = f32::INFINITY;
        for k in 0..N_FREQ {
            let f = (p.f_min_ghz + 0.1 * k as f64) as f32;
            let v = (p.v0 as f32) + (p.kv as f32) * (f - p.f_min_ghz as f32);
            let eta = (p.eta0 as f32)
                + (p.eta_slope as f32) * (f - p.f_min_ghz as f32)
                    / (p.f_max_ghz - p.f_min_ghz) as f32;
            let i = (pred_i0[d] + pred_sens[d] * f).max(EPS);
            let rate = i / epoch_ns;
            let v2 = v * v;
            let pw = ((p.c1 as f32) * v2 * rate
                + (p.c2 as f32) * v2 * f
                + (p.l0 as f32) * ((p.lv as f32) * (v - p.v_nom as f32)).exp())
                / eta;
            let idx = d * N_FREQ + k;
            instr[idx] = i;
            power[idx] = pw;
            let mut e = pw / rate.max(EPS).powf(n_exp);
            if mask[d] < 0.5 && k > 0 {
                e = f32::INFINITY;
            }
            ednp[idx] = e;
            if e < best_v {
                best_v = e;
                best_k = k;
            }
        }
        best[d] = best_k as f32;
    }
    (instr, power, ednp, best)
}

/// The full step (mirror of `python/compile/model.py::dvfs_step`).
pub fn dvfs_step_native(inp: &StepInputs, p: &PowerParams) -> StepOutputs {
    let (sens_wf, sens_cu, i0_cu) = wf_sensitivity_native(inp);
    let (pred_instr, power_w, ednp, best_idx) = freq_grid_native(
        &inp.pred_sens,
        &inp.pred_i0,
        &inp.mask,
        inp.n_exp,
        inp.epoch_ns,
        p,
    );
    StepOutputs {
        sens_wf,
        sens_cu,
        i0_cu,
        pred_instr,
        power_w,
        ednp,
        best_idx,
    }
}

impl StepInputs {
    /// Build an input bundle with sane shapes (helper for tests/benches).
    pub fn zeros(n_cu: usize, n_wf: usize) -> Self {
        StepInputs {
            instr: vec![0.0; n_cu * n_wf],
            t_core_ns: vec![0.0; n_cu * n_wf],
            age_factor: vec![1.0; n_cu * n_wf],
            freq_ghz: vec![1.7; n_cu],
            pred_sens: vec![0.0; n_cu],
            pred_i0: vec![0.0; n_cu],
            mask: vec![1.0; n_cu],
            n_exp: 3.0,
            epoch_ns: 1000.0,
            n_cu,
            n_wf,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> PowerParams {
        PowerParams::default()
    }

    #[test]
    fn wf_sensitivity_matches_model_module() {
        // native.rs and models::estimate_wf must agree (two mirrors of the
        // same kernel).
        let mut inp = StepInputs::zeros(2, 3);
        inp.instr = vec![100.0, 0.0, 550.0, 80.0, 1200.0, 10.0];
        inp.t_core_ns = vec![400.0, 0.0, 900.0, 100.0, 1000.0, 5.0];
        inp.age_factor = vec![1.0, 1.0, 0.5, 0.8, 0.3, 1.0];
        inp.freq_ghz = vec![1.5, 2.1];
        let (sens_wf, sens_cu, i0_cu) = wf_sensitivity_native(&inp);
        for c in 0..2 {
            let mut sum_s = 0.0;
            let mut sum_i = 0.0;
            for w in 0..3 {
                let idx = c * 3 + w;
                let e = crate::models::estimate_wf(
                    inp.instr[idx] as f64,
                    inp.t_core_ns[idx] as f64,
                    inp.age_factor[idx] as f64,
                    inp.freq_ghz[c] as f64,
                    inp.epoch_ns as f64,
                );
                assert!(
                    (sens_wf[idx] as f64 - e.sens).abs() < 1e-3 * e.sens.abs().max(1.0),
                    "mismatch at {idx}"
                );
                sum_s += e.sens;
                sum_i += inp.instr[idx] as f64;
            }
            assert!((sens_cu[c] as f64 - sum_s).abs() < 1e-2);
            assert!((i0_cu[c] as f64 - (sum_i - sum_s * inp.freq_ghz[c] as f64).max(0.0)).abs() < 0.5);
        }
    }

    #[test]
    fn grid_f32_f64_mirrors_agree() {
        let p = params();
        let (i64g, p64g, e64g) = eval_grid_row(12_345.0, 678.0, 3.0, 1000.0, &p);
        let (i32g, p32g, e32g, _) = freq_grid_native(
            &[12_345.0],
            &[678.0],
            &[1.0],
            3.0,
            1000.0,
            &p,
        );
        for k in 0..N_FREQ {
            assert!((i64g[k] - i32g[k] as f64).abs() / i64g[k] < 1e-4);
            assert!((p64g[k] - p32g[k] as f64).abs() / p64g[k] < 1e-4);
            assert!((e64g[k] - e32g[k] as f64).abs() / e64g[k] < 1e-3);
        }
    }

    #[test]
    fn ladder_row_agrees_with_grid_row_on_linear_samples() {
        // When the measured ladder happens to be exactly the linear model,
        // both evaluators must produce identical rows.
        let p = params();
        let (sens, i0) = (12_345.0, 678.0);
        let mut measured = [0f64; N_FREQ];
        for (k, m) in measured.iter_mut().enumerate() {
            *m = i0 + sens * (p.f_min_ghz + 0.1 * k as f64);
        }
        let (ig, pg, eg) = eval_grid_row(sens, i0, 3.0, 1000.0, &p);
        let (il, pl, el) = eval_ladder_row(&measured, 3.0, 1000.0, &p);
        for k in 0..N_FREQ {
            assert!((ig[k] - il[k]).abs() < 1e-9);
            assert!((pg[k] - pl[k]).abs() < 1e-12 * pg[k].abs().max(1.0));
            assert!((eg[k] - el[k]).abs() < 1e-12 * eg[k].abs().max(1.0));
        }
    }

    #[test]
    fn best_idx_is_argmin_of_ednp() {
        let (_, _, ednp, best) = freq_grid_native(
            &[0.0, 40_000.0, 5_000.0],
            &[800.0, 0.0, 400.0],
            &[1.0, 1.0, 1.0],
            3.0,
            1000.0,
            &params(),
        );
        for d in 0..3 {
            let row = &ednp[d * N_FREQ..(d + 1) * N_FREQ];
            let argmin = row
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            assert_eq!(best[d] as usize, argmin);
        }
    }

    #[test]
    fn masked_domain_selects_state_zero() {
        let (_, _, ednp, best) = freq_grid_native(
            &[40_000.0],
            &[0.0],
            &[0.0],
            3.0,
            1000.0,
            &params(),
        );
        assert_eq!(best[0], 0.0);
        assert!(ednp[1..N_FREQ].iter().all(|e| e.is_infinite()));
    }

    #[test]
    fn full_step_composes_both_kernels() {
        let mut inp = StepInputs::zeros(4, 8);
        for i in 0..inp.instr.len() {
            inp.instr[i] = (i as f32 * 37.0) % 900.0;
            inp.t_core_ns[i] = (i as f32 * 53.0) % 1000.0;
        }
        inp.pred_sens = vec![100.0, 30_000.0, 0.0, 5_000.0];
        inp.pred_i0 = vec![50.0, 0.0, 700.0, 200.0];
        let out = dvfs_step_native(&inp, &params());
        assert_eq!(out.sens_wf.len(), 32);
        assert_eq!(out.pred_instr.len(), 4 * N_FREQ);
        assert_eq!(out.best_idx.len(), 4);
        // memory-bound domain 2 picks state 0; compute-bound domain 1 top
        assert_eq!(out.best_idx[2], 0.0);
        assert_eq!(out.best_idx[1] as usize, N_FREQ - 1);
    }
}
