//! DVFS objective functions (paper §5.2).
//!
//! Prediction is objective-agnostic: every mechanism produces `(S, I0)`;
//! the objective then picks a ladder state from the evaluated
//! (instructions, power, ED^nP) grid.  For a fixed amount of work at
//! rate `r` and power `P`: `E ∝ P/r`, `D ∝ 1/r`, so `ED^nP ∝ P / r^{n+1}`.

use crate::power::params::{FREQS_GHZ, N_FREQ};

/// Selection objective.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Objective {
    /// Minimize energy-delay product.
    Edp,
    /// Minimize energy-delay² product (the paper's headline).
    Ed2p,
    /// Minimize energy subject to ≤ `max_slowdown` (e.g. 0.05) predicted
    /// performance degradation vs the top state (paper §6.4).
    EnergyBound { max_slowdown: f64 },
}

impl Objective {
    pub fn name(&self) -> String {
        match self {
            Objective::Edp => "EDP".into(),
            Objective::Ed2p => "ED2P".into(),
            Objective::EnergyBound { max_slowdown } => {
                format!("E@{:.0}%", max_slowdown * 100.0)
            }
        }
    }

    /// Exponent on rate for the ED^nP grid (n_exp in the AOT artifact):
    /// EDP → 2, ED²P → 3.  EnergyBound selects natively from the grids.
    pub fn n_exp(&self) -> f64 {
        match self {
            Objective::Edp => 2.0,
            Objective::Ed2p => 3.0,
            Objective::EnergyBound { .. } => 1.0, // P/r = energy per work
        }
    }

    /// Pick a ladder index from one domain's evaluated grid row.
    ///
    /// * `pred_instr` — predicted instructions at each state,
    /// * `power_w`    — predicted power at each state,
    /// * `ednp`       — `P / r^{n_exp}` at each state.
    pub fn select(&self, pred_instr: &[f64; N_FREQ], _power_w: &[f64; N_FREQ], ednp: &[f64; N_FREQ]) -> usize {
        match self {
            Objective::Edp | Objective::Ed2p => argmin(ednp),
            Objective::EnergyBound { max_slowdown } => {
                let perf_floor = pred_instr[N_FREQ - 1] * (1.0 - max_slowdown);
                // lowest-energy state meeting the performance floor; the
                // ednp row already holds P/r = energy-per-instruction.
                let mut best = N_FREQ - 1;
                let mut best_v = f64::INFINITY;
                for k in 0..N_FREQ {
                    if pred_instr[k] + 1e-9 >= perf_floor && ednp[k] < best_v {
                        best_v = ednp[k];
                        best = k;
                    }
                }
                best
            }
        }
    }

    /// Selected frequency in GHz.
    pub fn select_freq(&self, pred_instr: &[f64; N_FREQ], power_w: &[f64; N_FREQ], ednp: &[f64; N_FREQ]) -> f64 {
        FREQS_GHZ[self.select(pred_instr, power_w, ednp)]
    }
}

fn argmin(xs: &[f64; N_FREQ]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v < xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dvfs::native::eval_grid_row;

    fn grids(sens: f64, i0: f64, obj: Objective) -> ([f64; N_FREQ], [f64; N_FREQ], [f64; N_FREQ]) {
        let p = crate::power::PowerParams::default();
        eval_grid_row(sens, i0, obj.n_exp(), 1000.0, &p)
    }

    #[test]
    fn ed2p_compute_bound_picks_top_state() {
        let obj = Objective::Ed2p;
        let (i, p, e) = grids(40_000.0, 0.0, obj);
        assert_eq!(obj.select(&i, &p, &e), N_FREQ - 1);
    }

    #[test]
    fn memory_bound_picks_bottom_state_for_all_objectives() {
        for obj in [
            Objective::Edp,
            Objective::Ed2p,
            Objective::EnergyBound { max_slowdown: 0.05 },
        ] {
            let (i, p, e) = grids(0.0, 800.0, obj);
            assert_eq!(obj.select(&i, &p, &e), 0, "{}", obj.name());
        }
    }

    #[test]
    fn ed2p_choice_at_least_edp_choice() {
        for s in [0.0, 500.0, 2_000.0, 8_000.0, 20_000.0, 40_000.0] {
            let edp = Objective::Edp;
            let ed2p = Objective::Ed2p;
            let (i1, p1, e1) = grids(s, 300.0, edp);
            let (i2, p2, e2) = grids(s, 300.0, ed2p);
            assert!(
                ed2p.select(&i2, &p2, &e2) >= edp.select(&i1, &p1, &e1),
                "sens {s}"
            );
        }
    }

    #[test]
    fn energy_bound_respects_performance_floor() {
        let obj = Objective::EnergyBound { max_slowdown: 0.05 };
        // strongly compute-bound: rate ∝ f, so only the top states meet a
        // 5% floor (2.2 * 0.95 = 2.09 ⇒ state 2.1 or 2.2)
        let (i, p, e) = grids(40_000.0, 0.0, obj);
        let k = obj.select(&i, &p, &e);
        assert!(i[k] >= i[N_FREQ - 1] * 0.95 - 1e-6);
        assert!(k >= N_FREQ - 2, "state {k} violates the 5% bound");
    }

    #[test]
    fn energy_bound_relaxed_lowers_frequency() {
        let tight = Objective::EnergyBound { max_slowdown: 0.05 };
        let loose = Objective::EnergyBound { max_slowdown: 0.10 };
        let (i, p, e) = grids(40_000.0, 0.0, tight);
        let (i2, p2, e2) = grids(40_000.0, 0.0, loose);
        assert!(loose.select(&i2, &p2, &e2) <= tight.select(&i, &p, &e));
    }

    #[test]
    fn names_are_stable() {
        assert_eq!(Objective::Edp.name(), "EDP");
        assert_eq!(Objective::Ed2p.name(), "ED2P");
        assert_eq!(
            Objective::EnergyBound { max_slowdown: 0.1 }.name(),
            "E@10%"
        );
    }
}
