//! DVFS objective functions (paper §5.2).
//!
//! Prediction is objective-agnostic: every mechanism produces `(S, I0)`;
//! the objective then picks a ladder state from the evaluated
//! (instructions, power, ED^nP) grid.  For a fixed amount of work at
//! rate `r` and power `P`: `E ∝ P/r`, `D ∝ 1/r`, so `ED^nP ∝ P / r^{n+1}`.

use crate::power::params::{FREQS_GHZ, N_FREQ};

/// Selection objective.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Objective {
    /// Minimize energy-delay product.
    Edp,
    /// Minimize energy-delay² product (the paper's headline).
    Ed2p,
    /// Minimize energy subject to ≤ `max_slowdown` (e.g. 0.05) predicted
    /// performance degradation vs the top state (paper §6.4).
    EnergyBound { max_slowdown: f64 },
    /// Serve mode: minimize energy subject to per-launch completion
    /// deadlines.  The slack/risk phase logic lives in the manager's
    /// serve loop (which swaps in an [`Objective::EnergyBound`] whose
    /// bound tracks queue urgency — `serve.slack_slowdown` when slack,
    /// `0` when a deadline is at risk); standalone `select` (regret
    /// attribution, non-serve runs) behaves as the risk phase:
    /// an `EnergyBound` with a zero bound — the deadline-safe default.
    Deadline,
}

impl Objective {
    pub fn name(&self) -> String {
        match self {
            Objective::Edp => "EDP".into(),
            Objective::Ed2p => "ED2P".into(),
            Objective::EnergyBound { max_slowdown } => {
                format!("E@{:.0}%", max_slowdown * 100.0)
            }
            Objective::Deadline => "DEADLINE".into(),
        }
    }

    /// Parse the CLI/plan objective grammar: `edp`, `ed2p`, or
    /// `energy@<pct>` (e.g. `energy@5` = minimize energy within a 5%
    /// predicted slowdown).
    pub fn parse(s: &str) -> anyhow::Result<Objective> {
        let lower = s.to_ascii_lowercase();
        Ok(match lower.as_str() {
            "edp" => Objective::Edp,
            "ed2p" => Objective::Ed2p,
            "deadline" => Objective::Deadline,
            _ => {
                if let Some(pct) = lower.strip_prefix("energy@") {
                    let p: f64 = pct.trim_end_matches('%').parse().map_err(|_| {
                        anyhow::anyhow!("bad percentage in objective '{s}' (try energy@5)")
                    })?;
                    // A degenerate bound (negative, NaN, >=100%) would
                    // silently select via the unreachable-floor fallback
                    // or disable the bound entirely — reject it here.
                    anyhow::ensure!(
                        p.is_finite() && (0.0..100.0).contains(&p),
                        "objective '{s}': slowdown bound must be in [0, 100)%"
                    );
                    Objective::EnergyBound {
                        max_slowdown: p / 100.0,
                    }
                } else {
                    anyhow::bail!("unknown objective '{s}' (edp|ed2p|energy@<pct>|deadline)");
                }
            }
        })
    }

    /// Exponent on rate for the ED^nP grid (n_exp in the AOT artifact):
    /// EDP → 2, ED²P → 3.  EnergyBound selects natively from the grids.
    pub fn n_exp(&self) -> f64 {
        match self {
            Objective::Edp => 2.0,
            Objective::Ed2p => 3.0,
            // P/r = energy per work for both bounded forms
            Objective::EnergyBound { .. } | Objective::Deadline => 1.0,
        }
    }

    /// Pick a ladder index from one domain's evaluated grid row.
    ///
    /// * `pred_instr` — predicted instructions at each state,
    /// * `power_w`    — predicted power at each state,
    /// * `ednp`       — `P / r^{n_exp}` at each state.
    pub fn select(&self, pred_instr: &[f64; N_FREQ], _power_w: &[f64; N_FREQ], ednp: &[f64; N_FREQ]) -> usize {
        match self {
            Objective::Edp | Objective::Ed2p => argmin(ednp),
            // Standalone Deadline selection is the risk phase: a zero
            // slowdown bound (the serve loop swaps in slack-aware bounds
            // per epoch before selection ever reaches this point).
            Objective::Deadline => {
                Objective::EnergyBound { max_slowdown: 0.0 }.select(pred_instr, _power_w, ednp)
            }
            Objective::EnergyBound { max_slowdown } => {
                let perf_floor = pred_instr[N_FREQ - 1] * (1.0 - max_slowdown);
                // Lowest-energy state meeting the performance floor; the
                // ednp row already holds P/r = energy-per-instruction.
                let mut best: Option<usize> = None;
                for k in 0..N_FREQ {
                    let feasible = pred_instr[k] + 1e-9 >= perf_floor;
                    // NaN energies are never selectable (matching the
                    // historical `< INFINITY` seed): a feasible state
                    // with undefined energy must not shadow — or be
                    // chosen over — one with a real energy value.
                    if feasible
                        && !ednp[k].is_nan()
                        && best.is_none_or(|b| ednp[k] < ednp[b])
                    {
                        best = Some(k);
                    }
                }
                // No state meets the floor (possible when the prediction
                // is non-monotonic in f, or the grid row is degenerate —
                // e.g. all-NaN energies make every comparison false): the
                // bound takes priority over energy, so fall back to the
                // highest-predicted-performance state, ties broken toward
                // the higher frequency.  With a monotonic prediction this
                // is the top state — the same index the previous implicit
                // fallback produced.
                best.unwrap_or_else(|| {
                    let mut k_max = N_FREQ - 1;
                    for k in 0..N_FREQ {
                        if pred_instr[k] >= pred_instr[k_max] {
                            k_max = k;
                        }
                    }
                    k_max
                })
            }
        }
    }

    /// Selected frequency in GHz.
    pub fn select_freq(&self, pred_instr: &[f64; N_FREQ], power_w: &[f64; N_FREQ], ednp: &[f64; N_FREQ]) -> f64 {
        FREQS_GHZ[self.select(pred_instr, power_w, ednp)]
    }
}

fn argmin(xs: &[f64; N_FREQ]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v < xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dvfs::native::eval_grid_row;

    fn grids(sens: f64, i0: f64, obj: Objective) -> ([f64; N_FREQ], [f64; N_FREQ], [f64; N_FREQ]) {
        let p = crate::power::PowerParams::default();
        eval_grid_row(sens, i0, obj.n_exp(), 1000.0, &p)
    }

    #[test]
    fn ed2p_compute_bound_picks_top_state() {
        let obj = Objective::Ed2p;
        let (i, p, e) = grids(40_000.0, 0.0, obj);
        assert_eq!(obj.select(&i, &p, &e), N_FREQ - 1);
    }

    #[test]
    fn memory_bound_picks_bottom_state_for_all_objectives() {
        for obj in [
            Objective::Edp,
            Objective::Ed2p,
            Objective::EnergyBound { max_slowdown: 0.05 },
        ] {
            let (i, p, e) = grids(0.0, 800.0, obj);
            assert_eq!(obj.select(&i, &p, &e), 0, "{}", obj.name());
        }
    }

    #[test]
    fn ed2p_choice_at_least_edp_choice() {
        for s in [0.0, 500.0, 2_000.0, 8_000.0, 20_000.0, 40_000.0] {
            let edp = Objective::Edp;
            let ed2p = Objective::Ed2p;
            let (i1, p1, e1) = grids(s, 300.0, edp);
            let (i2, p2, e2) = grids(s, 300.0, ed2p);
            assert!(
                ed2p.select(&i2, &p2, &e2) >= edp.select(&i1, &p1, &e1),
                "sens {s}"
            );
        }
    }

    #[test]
    fn energy_bound_respects_performance_floor() {
        let obj = Objective::EnergyBound { max_slowdown: 0.05 };
        // strongly compute-bound: rate ∝ f, so only the top states meet a
        // 5% floor (2.2 * 0.95 = 2.09 ⇒ state 2.1 or 2.2)
        let (i, p, e) = grids(40_000.0, 0.0, obj);
        let k = obj.select(&i, &p, &e);
        assert!(i[k] >= i[N_FREQ - 1] * 0.95 - 1e-6);
        assert!(k >= N_FREQ - 2, "state {k} violates the 5% bound");
    }

    #[test]
    fn energy_bound_fallback_is_explicit_when_floor_unreachable() {
        let obj = Objective::EnergyBound { max_slowdown: 0.05 };
        // Non-monotonic prediction: the top state is NOT the fastest, and
        // no state reaches floor = pred[top] * 0.95 ... construct so that
        // nothing is feasible: floor derives from pred[N-1], which any
        // state (including N-1 itself) always meets when finite — so the
        // only unreachable-floor case is a degenerate row.  All-NaN
        // predictions: every feasibility and argmax comparison is false.
        let nan_row = [f64::NAN; N_FREQ];
        let p = [1.0; N_FREQ];
        assert_eq!(
            obj.select(&nan_row, &p, &nan_row),
            N_FREQ - 1,
            "degenerate rows must fall back to the top state, deterministically"
        );
        // Non-monotonic but finite: state 3 predicts the most work, so if
        // energies are NaN (no feasible argmin by energy is still fine —
        // feasibility holds for k=3) the bound picks by energy among the
        // feasible set.
        let mut pred = [0.0; N_FREQ];
        pred[3] = 100.0;
        pred[N_FREQ - 1] = 50.0;
        let mut ednp = [f64::NAN; N_FREQ];
        ednp[3] = 2.0;
        assert_eq!(obj.select(&pred, &p, &ednp), 3);
        // A feasible state with NaN energy must not shadow a later
        // feasible state with real energy (it is never selectable).
        let mut pred = [0.0; N_FREQ];
        pred[2] = 100.0;
        pred[7] = 90.0;
        pred[N_FREQ - 1] = 50.0;
        let mut ednp = [f64::NAN; N_FREQ];
        ednp[7] = 2.0;
        assert_eq!(obj.select(&pred, &p, &ednp), 7);
    }

    #[test]
    fn energy_bound_relaxed_lowers_frequency() {
        let tight = Objective::EnergyBound { max_slowdown: 0.05 };
        let loose = Objective::EnergyBound { max_slowdown: 0.10 };
        let (i, p, e) = grids(40_000.0, 0.0, tight);
        let (i2, p2, e2) = grids(40_000.0, 0.0, loose);
        assert!(loose.select(&i2, &p2, &e2) <= tight.select(&i, &p, &e));
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(Objective::parse("edp").unwrap(), Objective::Edp);
        assert_eq!(Objective::parse("ED2P").unwrap(), Objective::Ed2p);
        assert_eq!(
            Objective::parse("energy@5").unwrap(),
            Objective::EnergyBound { max_slowdown: 0.05 }
        );
        assert_eq!(
            Objective::parse("energy@10%").unwrap(),
            Objective::EnergyBound { max_slowdown: 0.10 }
        );
        assert!(Objective::parse("bogus").is_err());
        assert!(Objective::parse("energy@x").is_err());
        // degenerate bounds are rejected, not silently defanged
        for bad in ["energy@-5", "energy@100", "energy@150", "energy@nan", "energy@inf"] {
            assert!(Objective::parse(bad).is_err(), "accepted '{bad}'");
        }
        assert!(Objective::parse("energy@0").is_ok());
    }

    #[test]
    fn names_are_stable() {
        assert_eq!(Objective::Edp.name(), "EDP");
        assert_eq!(Objective::Ed2p.name(), "ED2P");
        assert_eq!(
            Objective::EnergyBound { max_slowdown: 0.1 }.name(),
            "E@10%"
        );
        assert_eq!(Objective::Deadline.name(), "DEADLINE");
    }

    #[test]
    fn deadline_parses_and_selects_like_a_zero_bound() {
        assert_eq!(Objective::parse("deadline").unwrap(), Objective::Deadline);
        assert_eq!(Objective::parse("DEADLINE").unwrap(), Objective::Deadline);
        assert_eq!(Objective::Deadline.n_exp(), 1.0);
        let zero = Objective::EnergyBound { max_slowdown: 0.0 };
        for s in [0.0, 500.0, 8_000.0, 40_000.0] {
            let (i, p, e) = grids(s, 300.0, Objective::Deadline);
            assert_eq!(
                Objective::Deadline.select(&i, &p, &e),
                zero.select(&i, &p, &e),
                "sens {s}"
            );
        }
        // memory-bound: rate is flat in f, so even a zero slowdown bound
        // admits every state and the lowest-energy one wins
        let (i, p, e) = grids(0.0, 800.0, Objective::Deadline);
        assert_eq!(Objective::Deadline.select(&i, &p, &e), 0);
    }
}
