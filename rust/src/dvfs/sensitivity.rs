//! The frequency-sensitivity metric (paper §3.2).
//!
//! A fixed-time epoch's phase is characterised by the linear model
//! `I_f = I0 + S·f`: `S` (instructions per GHz) is the *sensitivity* —
//! high for compute phases, ~0 for memory-bound phases — and `I0` the
//! frequency-independent intercept.

use crate::dvfs::native::eval_ladder_row;
use crate::dvfs::objective::Objective;
use crate::power::params::{FREQS_GHZ, N_FREQ, PowerParams};
use crate::util::linreg;

/// A phase estimate for one scope (wavefront / CU / domain).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct SensEstimate {
    /// dI/df in instructions per GHz over the epoch.
    pub sens: f64,
    /// Intercept instructions (work that arrives regardless of f).
    pub i0: f64,
}

impl SensEstimate {
    pub fn new(sens: f64, i0: f64) -> Self {
        SensEstimate { sens, i0 }
    }

    /// Predicted instructions at frequency `f_ghz`.
    #[inline]
    pub fn instr_at(&self, f_ghz: f64) -> f64 {
        (self.i0 + self.sens * f_ghz).max(0.0)
    }

    /// Sensitivities are commutative across scopes (paper §4.2).
    pub fn sum(estimates: impl IntoIterator<Item = SensEstimate>) -> SensEstimate {
        let mut total = SensEstimate::default();
        for e in estimates {
            total.sens += e.sens;
            total.i0 += e.i0;
        }
        total
    }

    /// Fit from (frequency, instructions) samples — the oracle's
    /// regression over pre-executed epochs (paper §5.1).
    pub fn fit(freqs_ghz: &[f64], instr: &[f64]) -> (SensEstimate, f64) {
        let (i0, s, r2) = linreg(freqs_ghz, instr);
        (
            SensEstimate {
                sens: s,
                i0: i0.max(0.0),
            },
            r2,
        )
    }
}

/// Relative sensitivity change between consecutive epochs — the paper's
/// variability metric (Figs. 7, 10, 11).  Symmetric, in [0, 2].
pub fn relative_change(prev: f64, cur: f64) -> f64 {
    let denom = 0.5 * (prev.abs() + cur.abs());
    if denom < 1e-9 {
        0.0
    } else {
        (cur - prev).abs() / denom
    }
}

/// Prediction accuracy of an instruction-count forecast (paper §6.1):
/// `1 − |pred − actual| / max(pred, actual)`, clamped to [0, 1].
pub fn prediction_accuracy(predicted: f64, actual: f64) -> f64 {
    let m = predicted.max(actual);
    if m < 1.0 {
        return 1.0; // both ~zero: trivially right
    }
    (1.0 - (predicted - actual).abs() / m).clamp(0.0, 1.0)
}

/// Instructions sampled at every ladder frequency (oracle ground truth).
pub type FreqSamples = [f64; N_FREQ];

/// Regress a [`FreqSamples`] row against the ladder.
pub fn fit_ladder(samples: &FreqSamples) -> (SensEstimate, f64) {
    SensEstimate::fit(&FREQS_GHZ, samples)
}

/// Counterfactual regret of choosing ladder state `chosen` when the
/// oracle measured `measured` instructions per state (decision-trace
/// channel, paper §6.1 attribution).  Scores every state with the
/// selector's own power/ED^nP math
/// ([`eval_ladder_row`]) and the run's [`Objective`], then returns
/// `(value[chosen] − value[best], best)`.  Clamped at 0: for
/// `EnergyBound` the objective value (energy-per-instruction) of the
/// constrained best can legitimately exceed an infeasible state's, and
/// regret is defined against the *feasible* best.
pub fn ladder_regret(
    measured: &FreqSamples,
    chosen: usize,
    objective: &Objective,
    epoch_ns: f64,
    p: &PowerParams,
) -> (f64, usize) {
    let (instr, power, ednp) = eval_ladder_row(measured, objective.n_exp(), epoch_ns, p);
    let best = objective.select(&instr, &power, &ednp);
    ((ednp[chosen] - ednp[best]).max(0.0), best)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn instr_at_is_linear_and_clamped() {
        let e = SensEstimate::new(100.0, 500.0);
        assert_eq!(e.instr_at(2.0), 700.0);
        let neg = SensEstimate::new(-400.0, 100.0);
        assert_eq!(neg.instr_at(2.0), 0.0);
    }

    #[test]
    fn sum_is_componentwise() {
        let t = SensEstimate::sum([SensEstimate::new(1.0, 2.0), SensEstimate::new(3.0, 4.0)]);
        assert_eq!((t.sens, t.i0), (4.0, 6.0));
    }

    #[test]
    fn fit_recovers_linear_phase() {
        let samples: Vec<f64> = FREQS_GHZ.iter().map(|f| 200.0 + 150.0 * f).collect();
        let (e, r2) = SensEstimate::fit(&FREQS_GHZ, &samples);
        assert!((e.sens - 150.0).abs() < 1e-9);
        assert!((e.i0 - 200.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn fit_clamps_negative_intercept() {
        // steep line through the origin region: intercept may fit negative
        let samples: Vec<f64> = FREQS_GHZ.iter().map(|f| (1000.0 * (f - 1.4)).max(0.0)).collect();
        let (e, _) = SensEstimate::fit(&FREQS_GHZ, &samples);
        assert!(e.i0 >= 0.0);
    }

    #[test]
    fn relative_change_bounds() {
        assert_eq!(relative_change(0.0, 0.0), 0.0);
        assert!((relative_change(100.0, 100.0)).abs() < 1e-12);
        assert!((relative_change(100.0, 0.0) - 2.0).abs() < 1e-12);
        assert!((relative_change(100.0, 150.0) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn ladder_regret_is_zero_at_best_and_positive_off_best() {
        let p = PowerParams::default();
        // compute-bound ladder: instructions scale with frequency
        let mut measured = [0f64; N_FREQ];
        for (k, m) in measured.iter_mut().enumerate() {
            *m = 30_000.0 * (p.f_min_ghz + 0.1 * k as f64);
        }
        let obj = Objective::Ed2p;
        let (_, best) = ladder_regret(&measured, 0, &obj, 1000.0, &p);
        let (r_best, b2) = ladder_regret(&measured, best, &obj, 1000.0, &p);
        assert_eq!(best, b2);
        assert_eq!(r_best, 0.0, "regret at the best state is exactly 0");
        for k in 0..N_FREQ {
            let (r, _) = ladder_regret(&measured, k, &obj, 1000.0, &p);
            assert!(r >= 0.0, "regret must be non-negative at state {k}");
            if k != best {
                assert!(r > 0.0, "off-best state {k} must carry regret");
            }
        }
    }

    #[test]
    fn ladder_regret_energy_bound_is_clamped_non_negative() {
        let p = PowerParams::default();
        // memory-bound ladder: frequency buys nothing, so EnergyBound's
        // feasible set spans all states and low f wins on energy.
        let measured = [5_000.0; N_FREQ];
        let obj = Objective::EnergyBound { max_slowdown: 0.1 };
        for k in 0..N_FREQ {
            let (r, best) = ladder_regret(&measured, k, &obj, 1000.0, &p);
            assert!(r >= 0.0);
            assert_eq!(best, 0, "flat ladder: lowest state is energy-best");
        }
    }

    #[test]
    fn accuracy_metric_properties() {
        assert_eq!(prediction_accuracy(100.0, 100.0), 1.0);
        assert_eq!(prediction_accuracy(0.0, 0.0), 1.0);
        assert!((prediction_accuracy(50.0, 100.0) - 0.5).abs() < 1e-12);
        assert!((prediction_accuracy(100.0, 50.0) - 0.5).abs() < 1e-12);
        assert_eq!(prediction_accuracy(0.0, 1000.0), 0.0);
    }
}
