//! Content-addressed on-disk store of serialized [`RunResult`]s.
//!
//! Layout: `<dir>/<hash>.json`, one file per unique [`RunKey`] content
//! address (`results/cache/` under the experiment output directory by
//! default).  Every entry embeds the canonical key text; a lookup whose
//! stored key disagrees with the requested one (hash collision, schema
//! drift, truncated write) is **invalidated**: the file is deleted, the
//! event counted, and the run recomputed.
//!
//! Writes go through a temp file + rename so a concurrently-running
//! second `pcstall` process never observes a half-written entry.

use std::path::PathBuf;
use std::sync::Mutex;

use crate::exec::key::{RunKey, SCHEMA_VERSION};
use crate::stats::emit::Json;
use crate::stats::RunResult;

/// Serialized-entry size cap: larger results are recomputed rather than
/// cached (parsing them back would cost more than the simulation).
pub const MAX_ENTRY_BYTES: usize = 64 * 1024 * 1024;

/// Hit/miss/invalidation accounting.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub stores: u64,
    pub invalidations: u64,
}

impl CacheStats {
    /// Fraction of lookups served from disk (0 when nothing was looked
    /// up, e.g. a disabled cache).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// The store.  A `ResultCache` with no directory (`disabled`) satisfies
/// the same API but never touches disk — `--no-cache`.
#[derive(Debug)]
pub struct ResultCache {
    dir: Option<PathBuf>,
    stats: Mutex<CacheStats>,
}

impl ResultCache {
    /// A cache rooted at `dir` (created lazily on first store).
    pub fn at(dir: PathBuf) -> Self {
        ResultCache {
            dir: Some(dir),
            stats: Mutex::new(CacheStats::default()),
        }
    }

    /// A cache that never hits and never stores.
    pub fn disabled() -> Self {
        ResultCache {
            dir: None,
            stats: Mutex::new(CacheStats::default()),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.dir.is_some()
    }

    pub fn stats(&self) -> CacheStats {
        *self.stats.lock().unwrap()
    }

    fn path_of(&self, key: &RunKey) -> Option<PathBuf> {
        self.dir
            .as_ref()
            .map(|d| d.join(format!("{}.json", key.hash_hex())))
    }

    /// Fetch the result stored for `key`, if any.
    pub fn lookup(&self, key: &RunKey) -> Option<RunResult> {
        let path = self.path_of(key)?;
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(_) => {
                self.stats.lock().unwrap().misses += 1;
                return None;
            }
        };
        match decode_entry(&text, key) {
            Ok(result) => {
                self.stats.lock().unwrap().hits += 1;
                Some(result)
            }
            Err(why) => {
                eprintln!(
                    "[exec] invalidating stale cache entry {}: {why}",
                    path.display()
                );
                let _ = std::fs::remove_file(&path);
                let mut st = self.stats.lock().unwrap();
                st.invalidations += 1;
                st.misses += 1;
                None
            }
        }
    }

    /// Persist `result` under `key`'s content address.
    ///
    /// Entries above [`MAX_ENTRY_BYTES`] are skipped (with a warning):
    /// a `Scale::Full` completion run can carry hundreds of thousands of
    /// per-epoch records, and a cache hit that has to parse a
    /// multi-hundred-MB document is slower than recomputing the cell.
    pub fn store(&self, key: &RunKey, result: &RunResult) {
        let Some(path) = self.path_of(key) else {
            return;
        };
        let entry = Json::obj(vec![
            ("schema", Json::Num(SCHEMA_VERSION as f64)),
            ("key", Json::Str(key.canonical())),
            ("result", result.to_json()),
        ]);
        let text = entry.render();
        if text.len() > MAX_ENTRY_BYTES {
            eprintln!(
                "[exec] not caching {} ({} MB > {} MB cap): rerun will recompute this cell",
                key.canonical(),
                text.len() >> 20,
                MAX_ENTRY_BYTES >> 20,
            );
            return;
        }
        if let Some(dir) = path.parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        let tmp = path.with_extension(format!("tmp{}", std::process::id()));
        match std::fs::write(&tmp, &text).and_then(|_| std::fs::rename(&tmp, &path)) {
            Ok(()) => self.stats.lock().unwrap().stores += 1,
            Err(e) => {
                eprintln!("[exec] failed to write cache entry {}: {e}", path.display());
                let _ = std::fs::remove_file(&tmp);
            }
        }
    }
}

/// Metadata of one on-disk entry (`pcstall cache stats|clear`).
#[derive(Debug, Clone)]
pub struct EntryMeta {
    pub path: PathBuf,
    pub bytes: u64,
    /// Seconds since last modification (0 when the mtime is unreadable).
    pub age_secs: u64,
    /// Whether the file parses as a cache entry document.
    pub valid: bool,
}

/// Aggregate on-disk accounting.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DiskStats {
    pub entries: u64,
    pub valid: u64,
    pub corrupt: u64,
    pub bytes: u64,
    pub oldest_secs: u64,
    pub newest_secs: u64,
}

impl ResultCache {
    /// List on-disk entries, oldest first.  A missing or unreadable
    /// directory yields an empty list (nothing cached yet).
    pub fn scan(&self) -> Vec<EntryMeta> {
        let Some(dir) = &self.dir else {
            return Vec::new();
        };
        let Ok(rd) = std::fs::read_dir(dir) else {
            return Vec::new();
        };
        let now = std::time::SystemTime::now();
        let mut out = Vec::new();
        for e in rd.flatten() {
            let path = e.path();
            if path.extension().and_then(|s| s.to_str()) != Some("json") {
                continue; // skip in-flight .tmp<pid> writes
            }
            let Ok(md) = e.metadata() else { continue };
            if !md.is_file() {
                continue;
            }
            let age_secs = md
                .modified()
                .ok()
                .and_then(|m| now.duration_since(m).ok())
                .map(|d| d.as_secs())
                .unwrap_or(0);
            let valid = std::fs::read_to_string(&path)
                .ok()
                .and_then(|t| Json::parse(&t).ok())
                .map(|j| j.get("key").is_some() && j.get("result").is_some())
                .unwrap_or(false);
            out.push(EntryMeta {
                path,
                bytes: md.len(),
                age_secs,
                valid,
            });
        }
        out.sort_by(|a, b| b.age_secs.cmp(&a.age_secs));
        out
    }

    /// Aggregate entry-count / byte / age accounting for `cache stats`.
    pub fn disk_stats(&self) -> DiskStats {
        let entries = self.scan();
        let mut s = DiskStats {
            newest_secs: u64::MAX,
            ..DiskStats::default()
        };
        for e in &entries {
            s.entries += 1;
            s.bytes += e.bytes;
            if e.valid {
                s.valid += 1;
            } else {
                s.corrupt += 1;
            }
            s.oldest_secs = s.oldest_secs.max(e.age_secs);
            s.newest_secs = s.newest_secs.min(e.age_secs);
        }
        if s.entries == 0 {
            s.newest_secs = 0;
        }
        s
    }

    /// Garbage-collect: remove entries at least `max_age_secs` old, then
    /// — oldest first — until the directory is within `max_bytes`.
    /// Corrupt entries are always removed (a lookup would invalidate
    /// them anyway).  Returns `(entries_removed, bytes_freed)`.
    pub fn gc(&self, max_age_secs: Option<u64>, max_bytes: Option<u64>) -> (u64, u64) {
        let entries = self.scan(); // oldest first
        let mut total: u64 = entries.iter().map(|e| e.bytes).sum();
        let mut removed = 0u64;
        let mut freed = 0u64;
        for e in &entries {
            let too_old = max_age_secs.is_some_and(|a| e.age_secs >= a);
            let over_budget = max_bytes.is_some_and(|b| total > b);
            if (too_old || over_budget || !e.valid) && std::fs::remove_file(&e.path).is_ok() {
                removed += 1;
                freed += e.bytes;
                total -= e.bytes;
            }
        }
        (removed, freed)
    }
}

fn decode_entry(text: &str, key: &RunKey) -> Result<RunResult, String> {
    let j = Json::parse(text)?;
    let stored = j
        .get("key")
        .and_then(|k| k.as_str())
        .ok_or_else(|| "entry has no canonical key".to_string())?;
    if stored != key.canonical() {
        return Err(format!(
            "canonical key mismatch (stored '{stored}', requested '{}')",
            key.canonical()
        ));
    }
    let result = j
        .get("result")
        .ok_or_else(|| "entry has no result".to_string())?;
    RunResult::from_json(result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::dvfs::manager::{Policy, RunMode};
    use crate::dvfs::objective::Objective;
    use crate::stats::EpochRecord;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("pcstall_cache_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn a_key(workload: &str) -> RunKey {
        RunKey::new(
            &SimConfig::small(),
            "quick",
            "native",
            workload,
            Policy::PcStall,
            Objective::Ed2p,
            RunMode::Epochs(4),
            0.05,
        )
    }

    fn a_result(workload: &str) -> RunResult {
        RunResult {
            workload: workload.into(),
            policy: "PCSTALL".into(),
            objective: "ED2P".into(),
            records: vec![EpochRecord {
                epoch: 0,
                t_ns: 1000.0,
                freq_idx: vec![4, 9],
                instr: 123.0,
                energy_j: 1e-6,
                accuracy: 0.5,
                dom_sens: vec![1.5, 2.5],
            }],
            total_energy_j: 1e-6,
            total_time_ns: 1000.0,
            total_instr: 123.0,
            mean_accuracy: 0.5,
            pc_hit_rate: 0.9,
            completed: false,
            serve: None,
        }
    }

    #[test]
    fn store_then_lookup_roundtrips() {
        let dir = tmp_dir("roundtrip");
        let cache = ResultCache::at(dir.clone());
        let key = a_key("comd");
        assert!(cache.lookup(&key).is_none());
        cache.store(&key, &a_result("comd"));
        let got = cache.lookup(&key).expect("entry should hit");
        assert_eq!(got.workload, "comd");
        assert_eq!(got.records.len(), 1);
        assert_eq!(got.records[0].freq_idx, vec![4, 9]);
        let st = cache.stats();
        assert_eq!((st.hits, st.misses, st.stores, st.invalidations), (1, 1, 1, 0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupted_entries_are_invalidated() {
        let dir = tmp_dir("corrupt");
        let cache = ResultCache::at(dir.clone());
        let key = a_key("hacc");
        cache.store(&key, &a_result("hacc"));
        let path = dir.join(format!("{}.json", key.hash_hex()));
        std::fs::write(&path, "{not json").unwrap();
        assert!(cache.lookup(&key).is_none());
        assert!(!path.exists(), "stale entry should be deleted");
        assert_eq!(cache.stats().invalidations, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn key_mismatch_is_invalidated() {
        // Simulate a hash collision / schema drift: an entry whose file
        // name matches but whose canonical key does not.
        let dir = tmp_dir("mismatch");
        let cache = ResultCache::at(dir.clone());
        let key = a_key("comd");
        let other = a_key("dgemm");
        cache.store(&other, &a_result("dgemm"));
        let from = dir.join(format!("{}.json", other.hash_hex()));
        let to = dir.join(format!("{}.json", key.hash_hex()));
        std::fs::rename(&from, &to).unwrap();
        assert!(cache.lookup(&key).is_none());
        assert_eq!(cache.stats().invalidations, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn disk_stats_count_entries_and_corruption() {
        let dir = tmp_dir("diskstats");
        let cache = ResultCache::at(dir.clone());
        assert_eq!(cache.disk_stats(), DiskStats::default()); // no dir yet
        cache.store(&a_key("comd"), &a_result("comd"));
        cache.store(&a_key("hacc"), &a_result("hacc"));
        std::fs::write(dir.join("deadbeef.json"), "{not json").unwrap();
        std::fs::write(dir.join("ignored.tmp123"), "partial").unwrap();
        let s = cache.disk_stats();
        assert_eq!(s.entries, 3, "{s:?}");
        assert_eq!(s.valid, 2);
        assert_eq!(s.corrupt, 1);
        assert!(s.bytes > 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_by_age_zero_clears_everything() {
        let dir = tmp_dir("gcage");
        let cache = ResultCache::at(dir.clone());
        cache.store(&a_key("comd"), &a_result("comd"));
        cache.store(&a_key("hacc"), &a_result("hacc"));
        let (removed, freed) = cache.gc(Some(0), None);
        assert_eq!(removed, 2);
        assert!(freed > 0);
        assert_eq!(cache.disk_stats().entries, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_by_bytes_removes_down_to_budget() {
        let dir = tmp_dir("gcbytes");
        let cache = ResultCache::at(dir.clone());
        for wl in ["comd", "hacc", "dgemm", "xsbench"] {
            cache.store(&a_key(wl), &a_result(wl));
        }
        let s = cache.disk_stats();
        assert_eq!(s.entries, 4);
        // budget for roughly half the data: some must go, some must stay
        let (removed, _) = cache.gc(None, Some(s.bytes / 2));
        assert!(removed >= 1 && removed < 4, "removed {removed}");
        assert!(cache.disk_stats().bytes <= s.bytes / 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_always_sweeps_corrupt_entries() {
        let dir = tmp_dir("gccorrupt");
        let cache = ResultCache::at(dir.clone());
        cache.store(&a_key("comd"), &a_result("comd"));
        std::fs::write(dir.join("deadbeef.json"), "{not json").unwrap();
        // generous bounds: only the corrupt entry qualifies
        let (removed, _) = cache.gc(Some(u64::MAX), Some(u64::MAX));
        assert_eq!(removed, 1);
        let s = cache.disk_stats();
        assert_eq!((s.entries, s.corrupt), (1, 0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn disabled_cache_never_touches_disk() {
        let cache = ResultCache::disabled();
        let key = a_key("comd");
        cache.store(&key, &a_result("comd"));
        assert!(cache.lookup(&key).is_none());
        assert_eq!(cache.stats(), CacheStats::default());
        assert!(!cache.is_enabled());
    }
}
