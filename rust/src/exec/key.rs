//! Canonical, hash-stable fingerprints of run requests.
//!
//! Two run requests that would execute the same simulation — same
//! workload, policy, objective, termination mode, waves multiplier,
//! backend, and full simulator configuration (which covers scale, seed
//! and epoch length) — produce the same [`RunKey`], and therefore the
//! same content address in the result cache.  Identical cells are thus
//! identified *across* figures: the static-1.7 GHz baseline computed by
//! fig14 is the same cache entry fig15–17 read.
//!
//! The canonical string embeds [`SCHEMA_VERSION`] as a salt: bumping it
//! orphans (rather than corrupts) every previously cached result.

use crate::config::SimConfig;
use crate::dvfs::manager::{Policy, RunMode};
use crate::dvfs::objective::Objective;

/// Bump whenever the `RunResult` serialization or the simulator's
/// observable semantics change: old cache entries become unreachable.
///
/// ## Versioning policy
///
/// The constant salts every [`RunKey::canonical`] string, so a bump
/// **orphans** the whole result cache: old entries stay on disk (until
/// `pcstall cache clear` collects them) but no new run can address
/// them, and nothing is corrupted or silently mixed.  Bump it when:
///
/// * the simulator's *observable semantics* change — the same request
///   now produces a different `RunResult` (timing model, arbitration,
///   energy math), so cached results are stale even though their keys
///   still parse;
/// * the `RunResult` *serialization* gains/changes fields that readers
///   of old entries would mis-, partially-, or default-decode in a way
///   that changes downstream CSVs;
/// * the config identity text ([`SimConfig::identity_toml`]) changes
///   shape for *existing* configs (a new section/field that renders for
///   every config) — every `cfg_fp` moves anyway, and the bump makes
///   the orphaning explicit and debuggable instead of incidental.
///
/// Do **not** bump for execution-only knobs (`gpu.sim_threads`-style
/// keys excluded from `identity_toml`) or output-formatting changes
/// that leave cached payloads exact.
///
/// v2: the MemPort/quantum-barrier refactor. Deferred memory responses
/// now resolve no earlier than the quantum barrier (previously they
/// could wake wavefronts mid-quantum at issue time), which shifts cycle
/// counts, stall intervals, and downstream request streams — v1 entries
/// hold old-semantics results and must not mix with new ones.
///
/// v3: serve mode. The `[serve]` config section joined
/// `identity_toml` (moving every config fingerprint), and `RunResult`
/// grew an optional `serve` stats object in its cache serialization.
pub const SCHEMA_VERSION: u32 = 3;

/// A fully-resolved run request fingerprint.
#[derive(Debug, Clone, PartialEq)]
pub struct RunKey {
    pub workload: String,
    /// Canonical policy id (not the display name): `static:<idx>`,
    /// `reactive:<model>`, `pcstall`, ...
    pub policy: String,
    pub objective: String,
    /// `epochs:<n>`, `completion:<cap>`, or `serve:<cap>`.
    pub mode: String,
    /// `native` or `pjrt`.
    pub backend: String,
    /// Scale preset name (`quick`/`default`/`full`) — redundant with the
    /// config fingerprint but kept for readable cache entries.
    pub scale: String,
    pub epoch_ns: f64,
    /// Effective workload-length multiplier passed to the generator.
    pub waves: f64,
    pub seed: u64,
    /// FNV-1a fingerprint of the `SimConfig` *identity* serialization —
    /// covers every ablation override (table sizes, domain granularity,
    /// power constants, ...) but skips execution-only knobs like
    /// `gpu.sim_threads`, which cannot change results.
    pub cfg_fp: u64,
}

/// Canonical policy encoding (distinct from `Policy::name`, which is a
/// display string).
pub fn policy_id(p: Policy) -> String {
    match p {
        Policy::Static(idx) => format!("static:{idx}"),
        Policy::Reactive(m) => format!("reactive:{}", m.name()),
        Policy::AccReac => "accreac".into(),
        Policy::PcStall => "pcstall".into(),
        Policy::AccPc => "accpc".into(),
        Policy::Oracle => "oracle".into(),
    }
}

/// Canonical objective encoding.
pub fn objective_id(o: Objective) -> String {
    match o {
        Objective::Edp => "edp".into(),
        Objective::Ed2p => "ed2p".into(),
        Objective::EnergyBound { max_slowdown } => format!("energy@{max_slowdown:?}"),
        Objective::Deadline => "deadline".into(),
    }
}

/// Canonical termination-mode encoding.
pub fn mode_id(m: RunMode) -> String {
    match m {
        RunMode::Epochs(n) => format!("epochs:{n}"),
        RunMode::Completion { max_epochs } => format!("completion:{max_epochs}"),
        RunMode::Serve { max_epochs } => format!("serve:{max_epochs}"),
    }
}

const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
const FNV_OFFSET_A: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_OFFSET_B: u64 = 0x6c62_272e_07bb_0142;

/// FNV-1a over `bytes` from an explicit offset basis (two bases give two
/// independent 64-bit streams for a 128-bit content address).
pub fn fnv1a(bytes: &[u8], offset: u64) -> u64 {
    let mut h = offset;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// 128-bit FNV-1a of `bytes` as 32 hex characters — the shared content-
/// addressing primitive (cache file stems, trace content hashes).
pub fn fnv1a128_hex(bytes: &[u8]) -> String {
    format!(
        "{:016x}{:016x}",
        fnv1a(bytes, FNV_OFFSET_A),
        fnv1a(bytes, FNV_OFFSET_B)
    )
}

impl RunKey {
    /// Build the key for one cell.  `cfg` must be the exact config the
    /// run will use (epoch length and overrides already applied).
    ///
    /// `workload` must be the *canonical workload id*, not a user-facing
    /// spec: catalog workloads use their catalog name, trace-driven
    /// workloads use `trace:<content-hash>` (see
    /// [`crate::workloads::WorkloadSource`]).  Fingerprinting the trace
    /// *content* (never its path) means an edited trace file can never be
    /// answered from a stale cache entry.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        cfg: &SimConfig,
        scale: &str,
        backend: &str,
        workload: &str,
        policy: Policy,
        objective: Objective,
        mode: RunMode,
        waves: f64,
    ) -> RunKey {
        RunKey {
            workload: workload.to_string(),
            policy: policy_id(policy),
            objective: objective_id(objective),
            mode: mode_id(mode),
            backend: backend.to_string(),
            scale: scale.to_string(),
            epoch_ns: cfg.dvfs.epoch_ns,
            waves,
            seed: cfg.seed,
            cfg_fp: fnv1a(cfg.identity_toml().as_bytes(), FNV_OFFSET_A),
        }
    }

    /// The canonical text form: stable across processes and platforms
    /// (floats use Rust's shortest round-trip `{:?}` formatting).
    pub fn canonical(&self) -> String {
        format!(
            "v{}|wl={}|policy={}|obj={}|mode={}|backend={}|scale={}|epoch_ns={:?}|waves={:?}|seed={}|cfg={:016x}",
            SCHEMA_VERSION,
            self.workload,
            self.policy,
            self.objective,
            self.mode,
            self.backend,
            self.scale,
            self.epoch_ns,
            self.waves,
            self.seed,
            self.cfg_fp,
        )
    }

    /// 128-bit content address as 32 hex chars (the cache file stem).
    pub fn hash_hex(&self) -> String {
        fnv1a128_hex(self.canonical().as_bytes())
    }

    /// Deterministic shard assignment: which of `n_shards` partitions
    /// owns this key.  Uses the second FNV stream over the canonical
    /// text, so the partition is stable across processes/machines (the
    /// property `pcstall sweep --shard i/N` relies on: every shard
    /// derives the same global partition independently) and independent
    /// of the cache file stem's primary stream.
    pub fn shard_of(&self, n_shards: usize) -> usize {
        debug_assert!(n_shards > 0);
        (fnv1a(self.canonical().as_bytes(), FNV_OFFSET_B) % n_shards.max(1) as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::EstModel;

    fn key(policy: Policy, epoch_ns: f64) -> RunKey {
        let mut cfg = SimConfig::small();
        cfg.dvfs.epoch_ns = epoch_ns;
        RunKey::new(
            &cfg,
            "quick",
            "native",
            "comd",
            policy,
            Objective::Ed2p,
            RunMode::Epochs(40),
            0.05,
        )
    }

    #[test]
    fn identical_requests_share_a_key() {
        let a = key(Policy::PcStall, 1000.0);
        let b = key(Policy::PcStall, 1000.0);
        assert_eq!(a, b);
        assert_eq!(a.canonical(), b.canonical());
        assert_eq!(a.hash_hex(), b.hash_hex());
    }

    #[test]
    fn any_field_change_changes_the_address() {
        let base = key(Policy::PcStall, 1000.0);
        let variants = [
            key(Policy::Oracle, 1000.0),
            key(Policy::Static(4), 1000.0),
            key(Policy::Reactive(EstModel::Crisp), 1000.0),
            key(Policy::PcStall, 50_000.0),
        ];
        for v in &variants {
            assert_ne!(base.hash_hex(), v.hash_hex(), "{}", v.canonical());
        }
    }

    #[test]
    fn config_overrides_change_the_fingerprint() {
        let a = key(Policy::PcStall, 1000.0);
        let mut cfg = SimConfig::small();
        cfg.dvfs.epoch_ns = 1000.0;
        cfg.dvfs.pc_table_entries = 8; // ablation override
        let b = RunKey::new(
            &cfg,
            "quick",
            "native",
            "comd",
            Policy::PcStall,
            Objective::Ed2p,
            RunMode::Epochs(40),
            0.05,
        );
        assert_ne!(a.cfg_fp, b.cfg_fp);
        assert_ne!(a.hash_hex(), b.hash_hex());
    }

    #[test]
    fn canonical_embeds_schema_salt() {
        assert!(key(Policy::PcStall, 1000.0)
            .canonical()
            .starts_with(&format!("v{SCHEMA_VERSION}|")));
    }

    #[test]
    fn policy_ids_are_distinct() {
        let mut ids: Vec<String> = Policy::all_dvfs().into_iter().map(policy_id).collect();
        ids.push(policy_id(Policy::Static(0)));
        ids.push(policy_id(Policy::Static(4)));
        let n = ids.len();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), n);
    }

    #[test]
    fn objective_ids_distinguish_bounds() {
        assert_ne!(
            objective_id(Objective::EnergyBound { max_slowdown: 0.05 }),
            objective_id(Objective::EnergyBound { max_slowdown: 0.10 })
        );
    }

    #[test]
    fn trace_workload_ids_address_by_content() {
        // Two traces at the same path but with different content get
        // distinct ids (the id embeds the content hash, never the path),
        // and therefore distinct cache addresses.
        let cfg = SimConfig::small();
        let key_of = |wl_id: &str| {
            RunKey::new(
                &cfg,
                "quick",
                "native",
                wl_id,
                Policy::PcStall,
                Objective::Ed2p,
                RunMode::Epochs(4),
                1.0,
            )
        };
        let a = key_of(&format!("trace:{}", fnv1a128_hex(b"stream-a")));
        let b = key_of(&format!("trace:{}", fnv1a128_hex(b"stream-b")));
        let c = key_of("comd");
        assert_ne!(a.hash_hex(), b.hash_hex());
        assert_ne!(a.hash_hex(), c.hash_hex());
    }

    #[test]
    fn shard_assignment_is_a_partition() {
        // every key belongs to exactly one shard, stably
        let keys: Vec<RunKey> = ["comd", "hacc", "dgemm", "xsbench", "BwdBN"]
            .iter()
            .flat_map(|wl| {
                [1_000.0, 10_000.0, 50_000.0].map(|e| {
                    let mut cfg = SimConfig::small();
                    cfg.dvfs.epoch_ns = e;
                    RunKey::new(
                        &cfg,
                        "quick",
                        "native",
                        wl,
                        Policy::PcStall,
                        Objective::Ed2p,
                        RunMode::Epochs(40),
                        0.05,
                    )
                })
            })
            .collect();
        for n in [1usize, 2, 3, 7] {
            for k in &keys {
                let s = k.shard_of(n);
                assert!(s < n);
                assert_eq!(s, k.shard_of(n), "assignment must be stable");
            }
        }
        // with one shard everything lands in shard 0
        assert!(keys.iter().all(|k| k.shard_of(1) == 0));
    }

    #[test]
    fn synth_seed_population_keys_are_distinct() {
        // The sweep-plan seed axis resolves each seed to its synthesized
        // trace's `trace:<content-hash>` id; distinct seeds must give
        // distinct cache addresses and a stable shard assignment, or a
        // seed-population sweep could alias cells across seeds/shards.
        let cfg = SimConfig::small();
        let keys: Vec<RunKey> = [2u64, 3, 5, 7, 11, 13]
            .iter()
            .map(|s| {
                let t = crate::trace::synth::synthesize(*s);
                RunKey::new(
                    &cfg,
                    "quick",
                    "native",
                    &format!("trace:{}", t.content_hash()),
                    Policy::PcStall,
                    Objective::Ed2p,
                    RunMode::Epochs(24),
                    1.0,
                )
            })
            .collect();
        let mut hexes: Vec<String> = keys.iter().map(|k| k.hash_hex()).collect();
        let n = hexes.len();
        hexes.sort();
        hexes.dedup();
        assert_eq!(hexes.len(), n, "seed-population keys must not collide");
        for shards in [2usize, 3] {
            for k in &keys {
                assert!(k.shard_of(shards) < shards);
                assert_eq!(k.shard_of(shards), k.shard_of(shards), "must be stable");
            }
        }
    }

    #[test]
    fn exec_kernel_and_size_parameters_key_distinctly() {
        // `exec:` workloads resolve to `trace:<content-hash>` ids, so a
        // kernel-name or size-parameter change must move the cache
        // address, while re-resolving the same spec must not.
        use crate::workloads::WorkloadSource;
        let cfg = SimConfig::small();
        let key_of = |spec: &str| {
            let id = WorkloadSource::parse(spec).unwrap().resolve().unwrap().id;
            assert!(id.starts_with("trace:"), "{spec} -> {id}");
            RunKey::new(
                &cfg,
                "quick",
                "native",
                &id,
                Policy::PcStall,
                Objective::Ed2p,
                RunMode::Epochs(8),
                1.0,
            )
        };
        let a = key_of("exec:vectoradd:4096");
        let a2 = key_of("exec:vectoradd:4096");
        assert_eq!(a, a2, "re-lowering the same spec must reproduce the key");
        let bigger = key_of("exec:vectoradd:8192");
        let other = key_of("exec:matmul:64");
        assert_ne!(a.hash_hex(), bigger.hash_hex(), "size change must move the key");
        assert_ne!(a.hash_hex(), other.hash_hex(), "kernel change must move the key");
    }

    #[test]
    fn config_axis_overrides_fingerprint_canonically() {
        // A sweep-plan `[axis]` dimension reaches the key through the
        // cell config: distinct axis values must give distinct cache
        // addresses, while different *spellings* of one value (`5` vs
        // `5.0` for an f64 key) must collapse to one — cache and shard
        // identity survive re-encoding the plan.
        use crate::config::minitoml::Value;
        let key_with = |v: &Value| {
            let mut cfg = SimConfig::small();
            cfg.set_key("dvfs.transition_ns", v).unwrap();
            RunKey::new(
                &cfg,
                "quick",
                "native",
                "comd",
                Policy::PcStall,
                Objective::Ed2p,
                RunMode::Epochs(24),
                0.05,
            )
        };
        let int5 = key_with(&Value::Int(5));
        let float5 = key_with(&Value::Float(5.0));
        assert_eq!(int5, float5, "value spelling must not change the key");
        assert_eq!(int5.hash_hex(), float5.hash_hex());
        let lat20 = key_with(&Value::Int(20));
        assert_ne!(int5.cfg_fp, lat20.cfg_fp);
        assert_ne!(int5.hash_hex(), lat20.hash_hex());
        // and the paper's regimes are pairwise distinct
        let mut hexes: Vec<String> = [5, 20, 100, 1000]
            .iter()
            .map(|ns| key_with(&Value::Int(*ns)).hash_hex())
            .collect();
        let n = hexes.len();
        hexes.sort();
        hexes.dedup();
        assert_eq!(hexes.len(), n);
    }

    #[test]
    fn sim_threads_is_absent_from_identity() {
        // the thread count is result-invariant, so two requests that
        // differ only in gpu.sim_threads must share one cache address
        let key_with = |threads: usize| {
            let mut cfg = SimConfig::small();
            cfg.gpu.sim_threads = threads;
            RunKey::new(
                &cfg,
                "quick",
                "native",
                "comd",
                Policy::PcStall,
                Objective::Ed2p,
                RunMode::Epochs(24),
                0.05,
            )
        };
        let serial = key_with(1);
        let wide = key_with(8);
        let auto = key_with(0);
        assert_eq!(serial, wide);
        assert_eq!(serial.cfg_fp, wide.cfg_fp);
        assert_eq!(serial.hash_hex(), auto.hash_hex());
        for n in [2usize, 3, 7] {
            assert_eq!(serial.shard_of(n), wide.shard_of(n));
        }
    }

    #[test]
    fn serve_cells_fingerprint_mode_objective_and_serve_keys() {
        let key_of = |cfg: &SimConfig, obj: Objective, mode: RunMode| {
            RunKey::new(cfg, "quick", "native", "comd", Policy::PcStall, obj, mode, 0.05)
        };
        let cfg = SimConfig::small();
        let batch = key_of(&cfg, Objective::Ed2p, RunMode::Epochs(24));
        let serve = key_of(&cfg, Objective::Deadline, RunMode::Serve { max_epochs: 24 });
        assert_ne!(batch.hash_hex(), serve.hash_hex());
        assert_eq!(serve.mode, "serve:24");
        assert_eq!(serve.objective, "deadline");
        // offered load is a config identity: sweeping serve.arrival_rate
        // must give distinct cache addresses per grid value
        let mut loaded = SimConfig::small();
        loaded.serve.arrival_rate = 0.05;
        let hot = key_of(&loaded, Objective::Deadline, RunMode::Serve { max_epochs: 24 });
        assert_ne!(serve.cfg_fp, hot.cfg_fp);
        assert_ne!(serve.hash_hex(), hot.hash_hex());
    }

    #[test]
    fn fnv_is_stable() {
        // Golden value: pins the hash function across refactors so old
        // cache entries stay addressable.
        assert_eq!(fnv1a(b"pcstall", FNV_OFFSET_A), 0xb798_d403_4dde_f226);
    }
}
