//! Sweep-execution engine: job-based parallel execution of simulation
//! grids with a content-addressed result cache.
//!
//! The paper's evaluation is a grid of *independent* (workload × policy
//! × objective × epoch-length) simulations.  This subsystem turns that
//! grid from a serial inline loop into submitted **jobs**:
//!
//! * [`key`] — canonical, hash-stable fingerprint of a run request, so
//!   identical cells are identified across figures and invocations;
//! * [`cache`] — content-addressed on-disk store of serialized
//!   `RunResult`s (`results/cache/<hash>.json`) with hit/miss/
//!   invalidation accounting;
//! * [`pool`] — std-only worker pool (threads + channels) that executes
//!   jobs out of order but returns results in deterministic submission
//!   order, so emitted CSVs are byte-identical to serial runs.
//!
//! [`Engine`] ties the three together: a batch of `(RunKey, job)` pairs
//! is deduplicated (shared baselines submitted by several series run
//! once), probed against the cache, and only the misses are executed.

pub mod cache;
pub mod key;
pub mod pool;
pub mod shard;

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::obs::ObsRecorder;
use crate::stats::RunResult;
use cache::{CacheStats, ResultCache};
use key::RunKey;

pub use shard::ShardSpec;

/// The sweep engine: one per harness invocation, shared by every
/// experiment so cross-figure cache reuse and accounting aggregate.
#[derive(Debug)]
pub struct Engine {
    cache: ResultCache,
    /// Simulations actually executed (batch slots minus dedup + hits).
    executed: AtomicU64,
    /// Batch slots answered by another slot of the same batch.
    deduped: AtomicU64,
    /// Span recorder (`--obs`): cache read/write + pool spans.
    obs: Option<Arc<ObsRecorder>>,
    /// `--progress`: periodic stderr lines while a batch executes.
    progress: bool,
}

impl Engine {
    pub fn new(cache: ResultCache) -> Engine {
        Engine {
            cache,
            executed: AtomicU64::new(0),
            deduped: AtomicU64::new(0),
            obs: None,
            progress: false,
        }
    }

    /// Attach a span recorder (before the engine is shared/Arc-wrapped).
    pub fn set_obs(&mut self, obs: Option<Arc<ObsRecorder>>) {
        self.obs = obs;
    }

    /// Enable periodic stderr progress lines during batch execution.
    /// Stdout and every emitted artifact stay byte-identical.
    pub fn set_progress(&mut self, on: bool) {
        self.progress = on;
    }

    /// Engine with the on-disk cache rooted at `dir`.
    pub fn with_cache_dir(dir: PathBuf) -> Engine {
        Engine::new(ResultCache::at(dir))
    }

    /// Engine that recomputes everything (`--no-cache`).  In-batch
    /// deduplication still applies — it changes nothing observable.
    pub fn no_cache() -> Engine {
        Engine::new(ResultCache::disabled())
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    pub fn cache_enabled(&self) -> bool {
        self.cache.is_enabled()
    }

    /// Simulations executed (not served by cache or dedup) so far.
    pub fn executed(&self) -> u64 {
        self.executed.load(Ordering::Relaxed)
    }

    /// Batch slots deduplicated against an identical slot so far.
    pub fn deduped(&self) -> u64 {
        self.deduped.load(Ordering::Relaxed)
    }

    /// Execute a batch of keyed jobs on up to `workers` threads and
    /// return the results in submission order.
    ///
    /// Slots with identical keys run once; keys present in the cache do
    /// not run at all.  Fresh results are persisted before returning.
    pub fn run_batch<F>(&self, workers: usize, batch: Vec<(RunKey, F)>) -> Vec<RunResult>
    where
        F: FnOnce() -> RunResult + Send,
    {
        let n = batch.len();

        // 1. Deduplicate within the batch: slot -> unique index.
        let mut slot_of: Vec<usize> = Vec::with_capacity(n);
        let mut uniques: Vec<(RunKey, Option<F>)> = Vec::new();
        let mut index: HashMap<String, usize> = HashMap::new();
        for (key, job) in batch {
            let canon = key.canonical();
            match index.get(&canon) {
                Some(&u) => {
                    slot_of.push(u);
                    self.deduped.fetch_add(1, Ordering::Relaxed);
                }
                None => {
                    let u = uniques.len();
                    index.insert(canon, u);
                    uniques.push((key, Some(job)));
                    slot_of.push(u);
                }
            }
        }

        // 2. Probe the cache once per unique key.
        enum Src {
            Ready(RunResult),
            Ran(usize), // index into the executed-results vector
        }
        let mut srcs: Vec<Src> = Vec::with_capacity(uniques.len());
        let mut run_uniques: Vec<usize> = Vec::new();
        let mut run_jobs: Vec<F> = Vec::new();
        let mut cache_hits = 0u64;
        let t_read = Instant::now();
        for (u, (key, job)) in uniques.iter_mut().enumerate() {
            match self.cache.lookup(key) {
                Some(r) => {
                    cache_hits += 1;
                    srcs.push(Src::Ready(r));
                }
                None => {
                    srcs.push(Src::Ran(run_jobs.len()));
                    run_uniques.push(u);
                    run_jobs.push(job.take().expect("job consumed twice"));
                }
            }
        }
        if let Some(o) = &self.obs {
            o.add_span("exec", "cache.read", t_read, Instant::now(), 0);
            // Obs × cache interaction: cached cells never execute, so they
            // leave no counter/decision records.  Account for them in the
            // sidecar header and warn — silently-partial sidecars are the
            // trap `--no-cache` exists to avoid.
            o.note_batch(run_jobs.len() as u64, cache_hits);
            if cache_hits > 0 {
                eprintln!(
                    "[obs] warning: {cache_hits} cell(s) served from the result cache carry no \
                     obs records — pair --obs with --no-cache for complete sidecars"
                );
            }
        }

        // 3. Execute the misses (out of order, collected in order),
        // optionally narrating progress to stderr (`--progress`).  The
        // wrapper only counts completions — results and their order are
        // untouched, so stdout/CSV bytes cannot change.
        let total_to_run = run_jobs.len();
        let served = n - total_to_run; // cache hits + in-batch dedups
        if self.progress && total_to_run == 0 && n > 0 {
            eprintln!("[progress] 0 to run — all {n} cell(s) served by cache/dedup");
        }
        let done = AtomicU64::new(0);
        let last_line = Mutex::new(Instant::now());
        let t_run = Instant::now();
        let progress = self.progress;
        let wrapped: Vec<_> = run_jobs
            .into_iter()
            .map(|f| {
                let done = &done;
                let last_line = &last_line;
                move || {
                    let r = f();
                    if progress {
                        let d = done.fetch_add(1, Ordering::Relaxed) + 1;
                        let mut last = last_line.lock().unwrap();
                        if d == total_to_run as u64 || last.elapsed().as_secs() >= 1 {
                            *last = Instant::now();
                            let elapsed = t_run.elapsed().as_secs_f64();
                            let eta = elapsed / d as f64 * (total_to_run as f64 - d as f64);
                            eprintln!(
                                "[progress] {d}/{total_to_run} cells, {served} served by cache/dedup, ETA {eta:.0}s"
                            );
                        }
                    }
                    r
                }
            })
            .collect();
        let ran = pool::run_ordered_obs(wrapped, workers, self.obs.as_deref());
        self.executed.fetch_add(ran.len() as u64, Ordering::Relaxed);
        let t_write = Instant::now();
        for (k, result) in ran.iter().enumerate() {
            let (key, _) = &uniques[run_uniques[k]];
            self.cache.store(key, result);
        }
        if let Some(o) = &self.obs {
            o.add_span("exec", "cache.write", t_write, Instant::now(), 0);
        }

        // 4. Resolve every slot in submission order, moving each unique
        // result into its last-use slot (clones only for true duplicates
        // — results can be large at full scale).
        let mut ran: Vec<Option<RunResult>> = ran.into_iter().map(Some).collect();
        let mut by_unique: Vec<Option<RunResult>> = srcs
            .into_iter()
            .map(|s| match s {
                Src::Ready(r) => Some(r),
                Src::Ran(k) => ran[k].take(),
            })
            .collect();
        let mut uses_left = vec![0usize; by_unique.len()];
        for &u in &slot_of {
            uses_left[u] += 1;
        }
        slot_of
            .into_iter()
            .map(|u| {
                uses_left[u] -= 1;
                if uses_left[u] == 0 {
                    by_unique[u].take().expect("unique result consumed twice")
                } else {
                    by_unique[u].as_ref().expect("unique result missing").clone()
                }
            })
            .collect()
    }

    /// One-line accounting summary (printed by the CLI after a run).
    pub fn summary(&self, workers: usize) -> String {
        let c = self.cache_stats();
        format!(
            "[exec] jobs={} simulations={} deduped={} | cache{}: {} hits / {} misses / {} stored / {} invalidated ({:.1}% hit)",
            workers,
            self.executed(),
            self.deduped(),
            if self.cache.is_enabled() { "" } else { " (disabled)" },
            c.hits,
            c.misses,
            c.stores,
            c.invalidations,
            c.hit_rate() * 100.0,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::dvfs::manager::{Policy, RunMode};
    use crate::dvfs::objective::Objective;
    use std::sync::atomic::AtomicU64 as Counter;

    fn a_key(workload: &str, epochs: u64) -> RunKey {
        RunKey::new(
            &SimConfig::small(),
            "quick",
            "native",
            workload,
            Policy::Static(4),
            Objective::Ed2p,
            RunMode::Epochs(epochs),
            0.05,
        )
    }

    fn a_result(tag: f64) -> RunResult {
        RunResult {
            workload: "t".into(),
            policy: "p".into(),
            objective: "o".into(),
            records: Vec::new(),
            total_energy_j: tag,
            total_time_ns: 1.0,
            total_instr: 1.0,
            mean_accuracy: f64::NAN,
            pc_hit_rate: 0.0,
            completed: true,
            serve: None,
        }
    }

    #[test]
    fn duplicate_keys_run_once() {
        let engine = Engine::no_cache();
        let runs = Counter::new(0);
        let batch: Vec<_> = (0..6)
            .map(|i| {
                let runs = &runs;
                // three slots share the "comd" key, three the "hacc" key
                let wl = if i % 2 == 0 { "comd" } else { "hacc" };
                (a_key(wl, 4), move || {
                    runs.fetch_add(1, Ordering::Relaxed);
                    a_result(i as f64)
                })
            })
            .collect();
        let out = engine.run_batch(2, batch);
        assert_eq!(out.len(), 6);
        assert_eq!(runs.load(Ordering::Relaxed), 2);
        assert_eq!(engine.executed(), 2);
        assert_eq!(engine.deduped(), 4);
        // every slot with the same key sees the first occurrence's result
        assert_eq!(out[0].total_energy_j, out[2].total_energy_j);
        assert_eq!(out[1].total_energy_j, out[3].total_energy_j);
    }

    #[test]
    fn warm_cache_executes_nothing() {
        let dir = std::env::temp_dir().join(format!("pcstall_engine_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);

        let cold = Engine::with_cache_dir(dir.clone());
        let batch: Vec<_> = (0..3)
            .map(|i| (a_key("comd", i), move || a_result(i as f64)))
            .collect();
        let first = cold.run_batch(2, batch);
        assert_eq!(cold.executed(), 3);

        let warm = Engine::with_cache_dir(dir.clone());
        let batch: Vec<_> = (0..3)
            .map(|i| (a_key("comd", i), move || a_result(-1.0)))
            .collect();
        let second = warm.run_batch(2, batch);
        assert_eq!(warm.executed(), 0, "warm cache must not execute");
        let st = warm.cache_stats();
        assert_eq!(st.misses, 0);
        assert_eq!(st.hits, 3);
        for (a, b) in first.iter().zip(&second) {
            assert_eq!(a.total_energy_j, b.total_energy_j);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn obs_and_progress_do_not_change_results() {
        let mk_batch = || -> Vec<_> {
            (0..4)
                .map(|i| (a_key("comd", i), move || a_result(i as f64)))
                .collect()
        };
        let rec = Arc::new(ObsRecorder::new(PathBuf::from("/nonexistent-unused")));
        let mut observed = Engine::no_cache();
        observed.set_obs(Some(rec.clone()));
        observed.set_progress(true);
        let out = observed.run_batch(2, mk_batch());
        let plain = Engine::no_cache().run_batch(2, mk_batch());
        assert_eq!(out.len(), plain.len());
        for (a, b) in out.iter().zip(&plain) {
            assert_eq!(a.total_energy_j, b.total_energy_j);
        }
        // 4 jobs x (queue + run) + cache.read + cache.write
        assert_eq!(rec.span_count(), 10);
    }

    #[test]
    fn summary_mentions_cache_state() {
        let engine = Engine::no_cache();
        assert!(engine.summary(4).contains("cache (disabled)"));
        assert!(engine.summary(4).contains("jobs=4"));
    }
}
