//! Std-only worker pool for sweep execution.
//!
//! Jobs are claimed out of order by a fixed set of worker threads
//! (threads + channels, no external crates), but results are returned in
//! deterministic **submission order** — so anything emitted from the
//! collected results (CSV tables, terminal output) is byte-identical to
//! a serial run regardless of `--jobs`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Mutex;
use std::time::Instant;

use crate::obs::ObsRecorder;

/// Default worker count: the machine's available parallelism.
pub fn default_jobs() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Split the machine between batch workers and per-simulation CU
/// threads so `jobs x sim_threads` never oversubscribes `nproc`.
/// Returns `(jobs, sim_threads)`.
///
/// `requested` is the user's `--sim-threads`: `Some(0)` means "as wide
/// as the machine" (each sim gets every core; jobs shrink to fit),
/// `Some(s)` pins the per-sim width, and `None` lets the policy decide:
/// a batch big enough to fill the worker pool runs serial sims (between-
/// cell parallelism already saturates the machine), while a smaller
/// batch hands the idle cores to each simulation.
///
/// `plan_width` is the widest per-cell width any cell in the batch will
/// actually run with beyond the budgeted one — cells whose sweep plan
/// pinned `gpu.sim_threads` via `[set]` keep their own value instead of
/// the budgeted width (0 = no such cells).  The worker pool shrinks to
/// fit the widest cell, so a plan-pinned batch can never multiply out
/// to `jobs x plan_width > nproc`.
pub fn thread_budget(
    n_cells: usize,
    jobs: usize,
    requested: Option<usize>,
    plan_width: usize,
    nproc: usize,
) -> (usize, usize) {
    let n = n_cells.max(1);
    let nproc = nproc.max(1);
    let st = match requested {
        Some(0) => nproc,
        Some(s) => s.max(1),
        None if n >= jobs.max(1) => 1,
        None => (nproc / n).max(1),
    };
    let widest = st.max(plan_width);
    let j = jobs.clamp(1, n).min((nproc / widest.min(nproc)).max(1));
    (j, st)
}

/// Run every job, using up to `workers` threads, and return the results
/// in submission order.  `workers <= 1` degenerates to a plain serial
/// loop on the calling thread.
pub fn run_ordered<T, F>(jobs: Vec<F>, workers: usize) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    run_ordered_obs(jobs, workers, None)
}

/// [`run_ordered`] with optional span recording (obs channel 2): each
/// job contributes a `pool.queue` span (batch start → claim) and a
/// `pool.run` span (claim → done), tagged with the worker lane as the
/// trace `tid`.  Results are unaffected — spans only observe.
pub fn run_ordered_obs<T, F>(jobs: Vec<F>, workers: usize, obs: Option<&ObsRecorder>) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    let n = jobs.len();
    let workers = workers.clamp(1, n.max(1));
    let t_batch = Instant::now();
    if workers <= 1 || n <= 1 {
        return jobs
            .into_iter()
            .map(|f| {
                let claimed = Instant::now();
                let out = f();
                if let Some(o) = obs {
                    o.add_span("exec", "pool.queue", t_batch, claimed, 0);
                    o.add_span("exec", "pool.run", claimed, Instant::now(), 0);
                }
                out
            })
            .collect();
    }

    // Each slot holds one pending job; workers claim the next index from
    // a shared counter, run it, and send (index, result) back.
    let slots: Vec<Mutex<Option<F>>> = jobs.into_iter().map(|f| Mutex::new(Some(f))).collect();
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, T)>();

    std::thread::scope(|s| {
        for w in 0..workers {
            let tx = tx.clone();
            let slots = &slots;
            let next = &next;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= slots.len() {
                    break;
                }
                let job = slots[i].lock().unwrap().take().expect("job claimed twice");
                let claimed = Instant::now();
                let out = job();
                if let Some(o) = obs {
                    o.add_span("exec", "pool.queue", t_batch, claimed, w as u64);
                    o.add_span("exec", "pool.run", claimed, Instant::now(), w as u64);
                }
                if tx.send((i, out)).is_err() {
                    break;
                }
            });
        }
        drop(tx);

        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, v) in rx {
            out[i] = Some(v);
        }
        out.into_iter()
            .map(|o| o.expect("worker exited before emitting a result"))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::time::Duration;

    #[test]
    fn preserves_submission_order() {
        // Earlier jobs sleep longer, so completion order is reversed —
        // the returned vector must still be in submission order.
        let jobs: Vec<_> = (0..8u64)
            .map(|i| {
                move || {
                    std::thread::sleep(Duration::from_millis(8 - i));
                    i * 10
                }
            })
            .collect();
        let out = run_ordered(jobs, 4);
        assert_eq!(out, (0..8u64).map(|i| i * 10).collect::<Vec<_>>());
    }

    #[test]
    fn serial_and_parallel_agree() {
        let mk = || (0..32u64).map(|i| move || i * i).collect::<Vec<_>>();
        assert_eq!(run_ordered(mk(), 1), run_ordered(mk(), 7));
    }

    #[test]
    fn runs_every_job_exactly_once() {
        let count = AtomicU64::new(0);
        let jobs: Vec<_> = (0..100)
            .map(|_| {
                let count = &count;
                move || count.fetch_add(1, Ordering::Relaxed)
            })
            .collect();
        let out = run_ordered(jobs, 16);
        assert_eq!(out.len(), 100);
        assert_eq!(count.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn more_workers_than_jobs() {
        let jobs: Vec<fn() -> i32> = vec![|| 1, || 2];
        let out = run_ordered(jobs, 64);
        assert_eq!(out, vec![1, 2]);
    }

    #[test]
    fn empty_batch() {
        let out: Vec<u32> = run_ordered(Vec::<fn() -> u32>::new(), 4);
        assert!(out.is_empty());
    }

    #[test]
    fn default_jobs_is_positive() {
        assert!(default_jobs() >= 1);
    }

    #[test]
    fn span_recording_does_not_change_results() {
        let rec = ObsRecorder::new(std::path::PathBuf::from("/nonexistent-unused"));
        let mk = || (0..16u64).map(|i| move || i * 3).collect::<Vec<_>>();
        let plain = run_ordered(mk(), 4);
        let observed = run_ordered_obs(mk(), 4, Some(&rec));
        assert_eq!(plain, observed);
        // 16 jobs -> 16 queue spans + 16 run spans in the timeline
        assert_eq!(rec.span_count(), 32);
        // serial path records spans too
        let rec2 = ObsRecorder::new(std::path::PathBuf::from("/nonexistent-unused"));
        run_ordered_obs(vec![|| 1], 1, Some(&rec2));
        assert_eq!(rec2.span_count(), 2);
    }

    #[test]
    fn thread_budget_never_oversubscribes() {
        for n_cells in [1usize, 2, 5, 16, 100] {
            for jobs in [1usize, 4, 16, 64] {
                for req in [None, Some(0), Some(1), Some(4), Some(32)] {
                    for plan in [0usize, 4, 32] {
                        for nproc in [1usize, 4, 16] {
                            let (j, st) = thread_budget(n_cells, jobs, req, plan, nproc);
                            assert!(j >= 1 && st >= 1);
                            assert!(j <= n_cells.max(1));
                            // explicit widths may exceed nproc on their
                            // own (the user asked), but the pool never
                            // multiplies the machine out: jobs shrink to
                            // cover the widest cell the batch can run.
                            let widest = st.max(plan);
                            assert!(
                                j * widest.min(nproc) <= nproc,
                                "oversubscribed: {n_cells} cells, {jobs} jobs, {req:?}, plan {plan}, {nproc} cores -> ({j}, {st})"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn thread_budget_auto_policy() {
        // big batch, default request: fill the pool with serial sims
        assert_eq!(thread_budget(100, 16, None, 0, 16), (16, 1));
        // small batch: idle cores flow into each simulation
        assert_eq!(thread_budget(4, 16, None, 0, 16), (4, 4));
        // single Full-scale run: one job, machine-wide sim
        assert_eq!(thread_budget(1, 16, None, 0, 16), (1, 16));
        // explicit width caps the worker pool
        assert_eq!(thread_budget(100, 16, Some(4), 0, 16), (4, 4));
        // --sim-threads 0: as wide as the machine, one job at a time
        assert_eq!(thread_budget(100, 16, Some(0), 0, 16), (1, 16));
        // explicit serial: unchanged pool behavior
        assert_eq!(thread_budget(100, 16, Some(1), 0, 16), (16, 1));
    }

    #[test]
    fn thread_budget_respects_plan_pinned_width() {
        // cells pinned at width 4 by a plan's `[set] gpu.sim_threads`
        // shrink the pool even though the budgeted width stays serial
        assert_eq!(thread_budget(100, 16, None, 4, 16), (4, 1));
        // pinned wider than the machine: one cell at a time
        assert_eq!(thread_budget(100, 16, None, 64, 16), (1, 1));
        // plan width never *widens* the pool past the budgeted width
        assert_eq!(thread_budget(4, 16, None, 2, 16), (4, 4));
    }

    #[test]
    fn jobs_may_borrow_environment() {
        // `run_ordered` must work with non-'static borrows (the harness
        // captures `&ExpOptions` in trace jobs).
        let data = vec![1u64, 2, 3, 4];
        let jobs: Vec<_> = data.iter().map(|x| move || x + 1).collect();
        assert_eq!(run_ordered(jobs, 2), vec![2, 3, 4, 5]);
    }
}
