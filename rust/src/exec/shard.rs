//! Shard specifications: deterministic partitioning of a keyed job grid
//! across independent `pcstall` invocations (possibly on different
//! machines).
//!
//! A [`ShardSpec`] `i/N` owns every [`RunKey`] whose fingerprint maps to
//! partition `i` of `N` (see [`RunKey::shard_of`]).  Because the
//! partition is a pure function of the key's canonical text, every
//! shard derives the same global assignment without coordination:
//! shards are **disjoint** (no row computed twice), **complete** (their
//! union is the full grid), and **cache-compatible** (a shard's cells
//! carry exactly the keys an unsharded run would, so shard results and
//! unsharded results share one content-addressed cache).

use crate::exec::key::RunKey;

/// One shard of an `N`-way partition (`--shard i/N`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    /// Zero-based shard index, `< count`.
    pub index: usize,
    /// Total number of shards, `>= 1`.
    pub count: usize,
}

impl ShardSpec {
    /// The trivial 1-way partition that owns everything.
    pub fn whole() -> ShardSpec {
        ShardSpec { index: 0, count: 1 }
    }

    /// Parse the CLI form `i/N` (e.g. `0/4`), zero-based.
    pub fn parse(s: &str) -> anyhow::Result<ShardSpec> {
        let (i, n) = s
            .split_once('/')
            .ok_or_else(|| anyhow::anyhow!("shard spec must be i/N (e.g. 0/4), got '{s}'"))?;
        let index: usize = i
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("bad shard index '{i}' in '{s}'"))?;
        let count: usize = n
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("bad shard count '{n}' in '{s}'"))?;
        anyhow::ensure!(count >= 1, "shard count must be >= 1 (got {count})");
        anyhow::ensure!(
            index < count,
            "shard index {index} out of range for {count} shard(s) (indices are zero-based)"
        );
        Ok(ShardSpec { index, count })
    }

    /// Does this shard own `key`?
    pub fn owns(&self, key: &RunKey) -> bool {
        key.shard_of(self.count) == self.index
    }
}

impl std::fmt::Display for ShardSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}", self.index, self.count)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::dvfs::manager::{Policy, RunMode};
    use crate::dvfs::objective::Objective;

    fn a_key(workload: &str, epoch_ns: f64) -> RunKey {
        let mut cfg = SimConfig::small();
        cfg.dvfs.epoch_ns = epoch_ns;
        RunKey::new(
            &cfg,
            "quick",
            "native",
            workload,
            Policy::PcStall,
            Objective::Ed2p,
            RunMode::Epochs(40),
            0.05,
        )
    }

    #[test]
    fn parse_accepts_valid_and_rejects_invalid() {
        assert_eq!(ShardSpec::parse("0/1").unwrap(), ShardSpec::whole());
        assert_eq!(
            ShardSpec::parse("2/3").unwrap(),
            ShardSpec { index: 2, count: 3 }
        );
        for bad in ["", "3", "3/3", "4/3", "-1/3", "a/3", "1/b", "1/0"] {
            assert!(ShardSpec::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn shards_partition_exactly() {
        let keys: Vec<RunKey> = ["comd", "hacc", "dgemm", "xsbench"]
            .iter()
            .flat_map(|wl| [1_000.0, 10_000.0, 50_000.0, 100_000.0].map(|e| a_key(wl, e)))
            .collect();
        for count in [1usize, 2, 3, 5] {
            for key in &keys {
                let owners: Vec<usize> = (0..count)
                    .filter(|&index| ShardSpec { index, count }.owns(key))
                    .collect();
                assert_eq!(owners.len(), 1, "key owned by {owners:?} of {count}");
            }
        }
    }

    #[test]
    fn seed_population_keys_partition_exactly() {
        // Per-seed workload ids (what a `seed = [..]` sweep plan shards
        // on) are owned by exactly one shard each — the property that
        // keeps population shards disjoint and their union complete.
        let keys: Vec<RunKey> = (0..16u64)
            .map(|s| {
                let t = crate::trace::synth::synthesize(s);
                a_key(&format!("trace:{}", t.content_hash()), 1000.0)
            })
            .collect();
        for count in [1usize, 2, 4] {
            for key in &keys {
                let owners: Vec<usize> = (0..count)
                    .filter(|&index| ShardSpec { index, count }.owns(key))
                    .collect();
                assert_eq!(owners.len(), 1, "key owned by {owners:?} of {count}");
            }
        }
    }

    #[test]
    fn config_axis_keys_partition_exactly() {
        // Keys that differ only in a config-axis value (the sweep-plan
        // `[axis]` dimension, e.g. `dvfs.transition_ns`) are owned by
        // exactly one shard each, and the assignment is identical no
        // matter how the plan spelled the value (int vs float).
        use crate::config::minitoml::Value;
        let key_with = |v: &Value| {
            let mut cfg = SimConfig::small();
            cfg.set_key("dvfs.transition_ns", v).unwrap();
            RunKey::new(
                &cfg,
                "quick",
                "native",
                "comd",
                Policy::PcStall,
                Objective::Ed2p,
                RunMode::Epochs(24),
                0.05,
            )
        };
        let keys: Vec<RunKey> = [5i64, 20, 100, 1000]
            .iter()
            .map(|ns| key_with(&Value::Int(*ns)))
            .collect();
        for count in [1usize, 2, 3] {
            for key in &keys {
                let owners: Vec<usize> = (0..count)
                    .filter(|&index| ShardSpec { index, count }.owns(key))
                    .collect();
                assert_eq!(owners.len(), 1, "key owned by {owners:?} of {count}");
            }
        }
        for (ns, key) in [5i64, 20, 100, 1000].iter().zip(&keys) {
            let respelled = key_with(&Value::Float(*ns as f64));
            for count in [2usize, 3, 5] {
                assert_eq!(
                    key.shard_of(count),
                    respelled.shard_of(count),
                    "spelling changed the shard at {ns} ns / {count} shards"
                );
            }
        }
    }

    #[test]
    fn whole_owns_everything() {
        assert!(ShardSpec::whole().owns(&a_key("comd", 1000.0)));
    }

    #[test]
    fn display_roundtrips() {
        let s = ShardSpec { index: 1, count: 4 };
        assert_eq!(ShardSpec::parse(&s.to_string()).unwrap(), s);
    }
}
