//! Characterization experiments (paper §3 and §4): Figs. 5–11 plus the
//! oracle-methodology validation of §5.1.
//!
//! All of these measure *ground-truth* fine-grain sensitivity via the
//! fork-pre-execute sampler while the GPU executes at the static 1.7 GHz
//! reference — the same instrumentation methodology the paper uses.

use std::collections::HashMap;

use crate::dvfs::sensitivity::relative_change;
use crate::exec::pool;
use crate::power::params::{FREQS_GHZ, N_FREQ};
use crate::predictors::OracleSampler;
use crate::sim::gpu::Gpu;
use crate::stats::emit::CsvTable;
use crate::util::geomean;
use crate::workloads::WorkloadSource;

use super::ExpOptions;

/// Collect one ground-truth profile per workload in parallel (`--jobs`),
/// preserving workload order.  Profiles are not cached (they are not
/// `RunResult`s), but they parallelize perfectly — each is an
/// independent simulation.
fn ground_truths_for(
    opts: &ExpOptions,
    wls: &[&'static str],
    epochs: u64,
    epoch_ns: f64,
) -> anyhow::Result<Vec<GroundTruth>> {
    let jobs: Vec<_> = wls
        .iter()
        .map(|&wl| move || ground_truth(opts, wl, epochs, epoch_ns))
        .collect();
    pool::run_ordered(jobs, opts.jobs.max(1)).into_iter().collect()
}

/// Ground-truth fine-grain profile of one workload at fixed frequency
/// (the figures' measurement substrate; distinct from
/// [`crate::trace::Trace`], the instruction-trace workload format).
pub struct GroundTruth {
    /// `[epoch][domain]` oracle-regressed sensitivity.
    pub dom_sens: Vec<Vec<f64>>,
    /// `[epoch][domain][state]` measured instructions at each ladder state.
    pub dom_instr_at: Vec<Vec<[f64; N_FREQ]>>,
    /// `[epoch][domain]` regression R².
    pub dom_r2: Vec<Vec<f64>>,
    /// `[epoch][cu][slot]` per-wavefront sensitivity (oracle regression).
    pub wf_sens: Vec<Vec<Vec<f64>>>,
    /// `[epoch][cu][slot]` per-wavefront sensitivity from the wavefront
    /// STALL estimator over the *executed* epoch — deterministic, free of
    /// sampling noise; used for the per-WF stability figures (8/10/11).
    pub wf_est_sens: Vec<Vec<Vec<f64>>>,
    /// `[epoch][cu][slot]` epoch-start PC / kernel / active.
    pub wf_pc: Vec<Vec<Vec<u32>>>,
    pub wf_kernel: Vec<Vec<Vec<u32>>>,
    pub wf_active: Vec<Vec<Vec<bool>>>,
}

/// Collect `epochs` ground-truth epochs of `workload` (any
/// [`WorkloadSource`] spec: catalog name, `trace:<path>`, `synth:<seed>`).
pub fn ground_truth(
    opts: &ExpOptions,
    workload: &str,
    epochs: u64,
    epoch_ns: f64,
) -> anyhow::Result<GroundTruth> {
    let mut cfg = opts.base_cfg();
    cfg.dvfs.epoch_ns = epoch_ns;
    // full-length kernels: profiles should not be dominated by kernel
    // boundaries
    let (launches, rounds) = WorkloadSource::parse(workload)?.resolve()?.lower(1.0);
    let mut gpu = Gpu::new(cfg);
    gpu.load_workload(launches, rounds);
    let sampler = OracleSampler::default();

    let mut t = GroundTruth {
        dom_sens: Vec::new(),
        dom_instr_at: Vec::new(),
        dom_r2: Vec::new(),
        wf_sens: Vec::new(),
        wf_est_sens: Vec::new(),
        wf_pc: Vec::new(),
        wf_kernel: Vec::new(),
        wf_active: Vec::new(),
    };
    for _ in 0..epochs {
        if gpu.workload_done() {
            break;
        }
        let s = sampler.sample(&gpu);
        t.dom_sens.push(s.dom.iter().map(|e| e.sens).collect());
        t.dom_instr_at.push(s.dom_instr_at.clone());
        t.dom_r2.push(s.dom_r2.clone());
        t.wf_sens.push(
            s.wf.iter()
                .map(|cu| cu.iter().map(|e| e.sens).collect())
                .collect(),
        );
        t.wf_pc.push(s.wf_start_pc.clone());
        t.wf_kernel.push(s.wf_start_kernel.clone());
        t.wf_active.push(s.wf_active.clone());
        let ob = gpu.run_epoch();
        let (per_wf, _) = crate::models::estimate_wf_all(&ob, &gpu.cfg);
        t.wf_est_sens.push(
            per_wf
                .iter()
                .map(|cu| cu.iter().map(|e| e.sens).collect())
                .collect(),
        );
    }
    Ok(t)
}

impl GroundTruth {
    /// Mean relative change in domain sensitivity across consecutive
    /// epochs (the paper's Fig. 7 metric).
    pub fn mean_consecutive_change(&self) -> f64 {
        let mut sum = 0f64;
        let mut n = 0u64;
        for w in self.dom_sens.windows(2) {
            for (a, b) in w[0].iter().zip(&w[1]) {
                if a.abs() + b.abs() > 1.0 {
                    sum += relative_change(*a, *b);
                    n += 1;
                }
            }
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    /// Mean relative change between *same-starting-PC* iterations at a
    /// given sharing scope (Fig. 10 / Fig. 11b).  `bucket_of(pc)` maps a
    /// PC to its table bucket; `scope_of(cu, slot)` maps to the sharing
    /// scope key (WF / CU / whole-GPU).
    pub fn same_pc_change(
        &self,
        bucket_of: impl Fn(u32) -> u64,
        scope_of: impl Fn(usize, usize) -> u64,
    ) -> f64 {
        let mut last: HashMap<(u64, u32, u64), f64> = HashMap::new();
        let mut sum = 0f64;
        let mut n = 0u64;
        for e in 0..self.wf_est_sens.len() {
            for c in 0..self.wf_est_sens[e].len() {
                for w in 0..self.wf_est_sens[e][c].len() {
                    if !self.wf_active[e][c][w] {
                        continue;
                    }
                    let s = self.wf_est_sens[e][c][w];
                    let key = (
                        scope_of(c, w),
                        self.wf_kernel[e][c][w],
                        bucket_of(self.wf_pc[e][c][w]),
                    );
                    if let Some(prev) = last.insert(key, s) {
                        if prev.abs() + s.abs() > 1.0 {
                            sum += relative_change(prev, s);
                            n += 1;
                        }
                    }
                }
            }
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }
}

/// Fig. 5 — instructions vs frequency linearity for sampled epochs.
pub fn fig5(opts: &ExpOptions) -> anyhow::Result<()> {
    let t = ground_truth(opts, "comd", opts.trace_epochs().min(24), 1000.0)?;
    let mut table = CsvTable::new(&["epoch", "freq_ghz", "instructions"]);
    let mut r2s = Vec::new();
    let step = (t.dom_instr_at.len() / 8).max(1);
    for (e, per_dom) in t.dom_instr_at.iter().enumerate().step_by(step) {
        // domain 0's samples, one row per ladder state
        for k in 0..N_FREQ {
            table.push(vec![
                e.to_string(),
                format!("{:.1}", FREQS_GHZ[k]),
                format!("{:.0}", per_dom[0][k]),
            ]);
        }
    }
    for per_dom in &t.dom_r2 {
        r2s.extend(per_dom.iter().copied().filter(|r| r.is_finite()));
    }
    let mean_r2 = r2s.iter().sum::<f64>() / r2s.len().max(1) as f64;
    opts.emit("fig5", "Fig 5: instructions vs frequency (comd, sampled epochs)", &table);
    println!("mean R² of linear fit: {mean_r2:.3}  (paper: 0.82)");
    Ok(())
}

/// Fig. 6 — sensitivity-over-time profiles for four contrast workloads.
pub fn fig6(opts: &ExpOptions) -> anyhow::Result<()> {
    let wls = ["dgemm", "hacc", "BwdBN", "xsbench"];
    let traces = ground_truths_for(opts, &wls, opts.trace_epochs(), 1000.0)?;
    let mut table = CsvTable::new(&["workload", "epoch", "gpu_sens"]);
    for (&wl, t) in wls.iter().zip(&traces) {
        for (e, doms) in t.dom_sens.iter().enumerate() {
            table.push(vec![
                wl.into(),
                e.to_string(),
                format!("{:.1}", doms.iter().sum::<f64>()),
            ]);
        }
    }
    opts.emit("fig6", "Fig 6: sensitivity profiles over time (1 µs epochs)", &table);
    Ok(())
}

/// Fig. 7 — variability of sensitivity across consecutive epochs.
pub fn fig7(opts: &ExpOptions) -> anyhow::Result<()> {
    // (a) per workload at 1 µs
    let wls = opts.workloads();
    let traces = ground_truths_for(opts, &wls, opts.trace_epochs(), 1000.0)?;
    let mut ta = CsvTable::new(&["workload", "mean_rel_change_1us"]);
    let mut per_wl = Vec::new();
    for (&wl, t) in wls.iter().zip(&traces) {
        let ch = t.mean_consecutive_change();
        per_wl.push(ch);
        ta.push(vec![wl.into(), format!("{:.3}", ch)]);
    }
    let mean_1us = per_wl.iter().sum::<f64>() / per_wl.len().max(1) as f64;
    opts.emit("fig7a", "Fig 7a: consecutive-epoch sensitivity change @1µs", &ta);
    println!("average @1µs: {:.1}% (paper: 37%)", mean_1us * 100.0);

    // (b) average across workloads at coarser epochs
    let mut tb = CsvTable::new(&["epoch_us", "mean_rel_change"]);
    for &epoch_ns in &super::sweep::EPOCH_LENS_NS {
        let budget_ns = opts.trace_epochs() as f64 * 1_000.0;
        let epochs = ((budget_ns / epoch_ns) as u64).clamp(8, opts.trace_epochs());
        let vals: Vec<f64> = ground_truths_for(opts, &opts.sweep_workloads(), epochs, epoch_ns)?
            .iter()
            .map(|t| t.mean_consecutive_change())
            .collect();
        let mean = vals.iter().sum::<f64>() / vals.len().max(1) as f64;
        tb.push(vec![
            format!("{}", epoch_ns / 1000.0),
            format!("{:.3}", mean),
        ]);
    }
    opts.emit("fig7b", "Fig 7b: variability vs epoch duration", &tb);
    println!("(paper: 12% @100µs rising to 37% @1µs)");
    Ok(())
}

/// Fig. 8 — per-wavefront contribution profile (BwdBN, one CU).
pub fn fig8(opts: &ExpOptions) -> anyhow::Result<()> {
    let t = ground_truth(opts, "BwdBN", opts.trace_epochs().min(60), 1000.0)?;
    let mut table = CsvTable::new(&["epoch", "slot", "wf_sens"]);
    for (e, cus) in t.wf_est_sens.iter().enumerate() {
        for (w, s) in cus[0].iter().enumerate() {
            table.push(vec![e.to_string(), w.to_string(), format!("{:.2}", s)]);
        }
    }
    opts.emit("fig8", "Fig 8: per-wavefront sensitivity contributions (BwdBN, CU0)", &table);
    Ok(())
}

/// Fig. 10 — same-starting-PC iteration stability at WF/CU/GPU scopes.
pub fn fig10(opts: &ExpOptions) -> anyhow::Result<()> {
    let n_wf = opts.base_cfg().gpu.n_wf as u64;
    let wls = opts.workloads();
    let traces = ground_truths_for(opts, &wls, opts.trace_epochs(), 1000.0)?;
    let mut table = CsvTable::new(&["workload", "scope", "mean_rel_change"]);
    let mut agg: HashMap<&str, Vec<f64>> = HashMap::new();
    for (&wl, t) in wls.iter().zip(&traces) {
        for (scope, f) in [
            ("WF", Box::new(move |c: usize, w: usize| c as u64 * n_wf + w as u64)
                as Box<dyn Fn(usize, usize) -> u64>),
            ("CU", Box::new(|c: usize, _w: usize| c as u64)),
            ("GPU", Box::new(|_c: usize, _w: usize| 0)),
        ] {
            let ch = t.same_pc_change(|pc| pc as u64, f.as_ref());
            table.push(vec![wl.into(), scope.into(), format!("{:.3}", ch)]);
            agg.entry(scope).or_default().push(ch);
        }
    }
    opts.emit("fig10", "Fig 10: same-PC iteration sensitivity change", &table);
    for scope in ["WF", "CU", "GPU"] {
        let v = &agg[scope];
        println!(
            "average {scope}: {:.1}%",
            v.iter().sum::<f64>() / v.len().max(1) as f64 * 100.0
        );
    }
    println!("(paper: ~10% — much lower than the 37% consecutive-epoch change)");
    Ok(())
}

/// Fig. 11a — per-slot sensitivity change for quickS (contention).
pub fn fig11a(opts: &ExpOptions) -> anyhow::Result<()> {
    let t = ground_truth(opts, "quickS", opts.trace_epochs(), 1000.0)?;
    let n_wf = opts.base_cfg().gpu.n_wf;
    let mut table = CsvTable::new(&["slot", "mean_rel_change"]);
    for w in 0..n_wf {
        let mut sum = 0f64;
        let mut n = 0u64;
        for e in 1..t.wf_est_sens.len() {
            for c in 0..t.wf_est_sens[e].len() {
                let (a, b) = (t.wf_est_sens[e - 1][c][w], t.wf_est_sens[e][c][w]);
                if t.wf_active[e][c][w] && t.wf_active[e - 1][c][w] && a.abs() + b.abs() > 1.0 {
                    sum += relative_change(a, b);
                    n += 1;
                }
            }
        }
        let ch = if n == 0 { 0.0 } else { sum / n as f64 };
        table.push(vec![w.to_string(), format!("{:.3}", ch)]);
    }
    opts.emit(
        "fig11a",
        "Fig 11a: per-slot sensitivity change, quickS (oldest slot most stable)",
        &table,
    );
    Ok(())
}

/// Fig. 11b — PC-table index offset sweep (CU-level sharing).
pub fn fig11b(opts: &ExpOptions) -> anyhow::Result<()> {
    let mut table = CsvTable::new(&["offset_bits", "mean_rel_change"]);
    // reuse one profile set across offsets (collected in parallel)
    let traces = ground_truths_for(opts, &opts.sweep_workloads(), opts.trace_epochs(), 1000.0)?;
    for offset in 0..=8u32 {
        let mut vals = Vec::new();
        for t in &traces {
            vals.push(t.same_pc_change(
                |pc| ((pc as u64) << 2) >> offset,
                |c, _w| c as u64,
            ));
        }
        let mean = vals.iter().sum::<f64>() / vals.len().max(1) as f64;
        table.push(vec![offset.to_string(), format!("{:.3}", mean)]);
    }
    opts.emit(
        "fig11b",
        "Fig 11b: index-offset sweep (change rises past ~4 bits)",
        &table,
    );
    Ok(())
}

/// §5.1 — validate the 10-process shuffled sampling methodology.
pub fn oracle_validation(opts: &ExpOptions) -> anyhow::Result<()> {
    let mut table = CsvTable::new(&["workload", "validation_accuracy"]);
    let wls = opts.sweep_workloads();
    let jobs: Vec<_> = wls
        .iter()
        .map(|&wl| {
            move || -> anyhow::Result<f64> {
                let sampler = OracleSampler::default();
                let mut cfg = opts.base_cfg();
                cfg.dvfs.epoch_ns = 1000.0;
                let resolved = WorkloadSource::parse(wl)?.resolve()?;
                // traces always run at recorded length (the catalog
                // multiplier is tuned to catalog base sizes)
                let waves = if resolved.trace().is_some() {
                    1.0
                } else {
                    opts.waves_scale().max(0.2)
                };
                let (launches, rounds) = resolved.lower(waves);
                let mut gpu = Gpu::new(cfg);
                gpu.load_workload(launches, rounds);
                // settle, then validate a handful of epochs
                for _ in 0..4 {
                    gpu.run_epoch();
                }
                let mut wl_accs = Vec::new();
                for i in 0..5 {
                    let freqs: Vec<f64> = (0..gpu.n_domains())
                        .map(|d| FREQS_GHZ[(d + i) % N_FREQ])
                        .collect();
                    wl_accs.push(sampler.validate(&gpu, &freqs));
                    gpu.run_epoch();
                }
                Ok(wl_accs.iter().sum::<f64>() / wl_accs.len() as f64)
            }
        })
        .collect();
    let per_wl = pool::run_ordered(jobs, opts.jobs.max(1))
        .into_iter()
        .collect::<anyhow::Result<Vec<f64>>>()?;
    let mut accs = Vec::new();
    for (&wl, &acc) in wls.iter().zip(&per_wl) {
        accs.push(acc);
        table.push(vec![wl.into(), format!("{:.4}", acc)]);
    }
    opts.emit("oracle_validation", "§5.1: fork-pre-execute validation", &table);
    println!(
        "mean validation accuracy: {:.1}% (paper: 97.6% with 10 processes)",
        geomean(&accs) * 100.0
    );
    Ok(())
}
