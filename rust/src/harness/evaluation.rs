//! Evaluation experiments (paper §6): Figs. 1, 14–18 and Table I.

use crate::dvfs::manager::{DvfsManager, Policy, RunMode};
use crate::dvfs::objective::Objective;
use crate::models::EstModel;
use crate::power::params::{FREQS_GHZ, F_STATIC_IDX, N_FREQ};
use crate::stats::emit::CsvTable;
use crate::stats::RunResult;
use crate::util::geomean;
use crate::workloads;

use super::ExpOptions;

/// Completion-run safety cap.
const MAX_EPOCHS: u64 = 200_000;

/// Run one (workload, policy, objective) configuration.
pub fn run_design(
    opts: &ExpOptions,
    workload: &str,
    policy: Policy,
    objective: Objective,
    epoch_ns: f64,
    mode: RunMode,
) -> RunResult {
    run_design_scaled(opts, workload, policy, objective, epoch_ns, mode, 1.0)
}

/// `run_design` with an extra workload-length multiplier (epoch-duration
/// sweeps need enough work to fill many coarse epochs).
#[allow(clippy::too_many_arguments)]
pub fn run_design_scaled(
    opts: &ExpOptions,
    workload: &str,
    policy: Policy,
    objective: Objective,
    epoch_ns: f64,
    mode: RunMode,
    extra_waves: f64,
) -> RunResult {
    let mut cfg = opts.base_cfg();
    cfg.dvfs.epoch_ns = epoch_ns;
    let wl = workloads::build(workload, opts.waves_scale() * extra_waves);
    let mut mgr = if opts.use_pjrt {
        DvfsManager::with_backend(cfg, &wl, policy, objective, crate::runtime::best_backend(None))
    } else {
        DvfsManager::new(cfg, &wl, policy, objective)
    };
    mgr.run(mode, workload)
}

fn completion(epoch_ns: f64) -> RunMode {
    // cap scales with epoch length so the cap is a time budget
    RunMode::Completion {
        max_epochs: (MAX_EPOCHS as f64 / (epoch_ns / 1000.0)).max(64.0) as u64,
    }
}

/// ED^nP improvement (%) of `r` over the static-1.7 reference.
fn improvement(r: &RunResult, base: &RunResult, n: u32) -> f64 {
    (1.0 - r.ednp(n) / base.ednp(n)) * 100.0
}

/// Fig. 1a — ED²P opportunity vs DVFS epoch duration.
pub fn fig1a(opts: &ExpOptions) -> anyhow::Result<()> {
    let designs = [
        Policy::Reactive(EstModel::Crisp),
        Policy::PcStall,
        Policy::Oracle,
    ];
    let mut table = CsvTable::new(&["epoch_us", "design", "ed2p_improvement_pct"]);
    for &epoch_ns in &[1_000.0, 10_000.0, 50_000.0, 100_000.0] {
        for &d in &designs {
            let mut imps = Vec::new();
            for wl in opts.sweep_workloads() {
                let base = run_design(
                    opts,
                    wl,
                    Policy::Static(F_STATIC_IDX),
                    Objective::Ed2p,
                    epoch_ns,
                    completion(epoch_ns),
                );
                let r = run_design(opts, wl, d, Objective::Ed2p, epoch_ns, completion(epoch_ns));
                imps.push(improvement(&r, &base, 2));
            }
            let mean = imps.iter().sum::<f64>() / imps.len().max(1) as f64;
            table.push(vec![
                format!("{}", epoch_ns / 1000.0),
                d.name(),
                format!("{:.1}", mean),
            ]);
        }
    }
    opts.emit(
        "fig1a",
        "Fig 1a: ED²P improvement vs epoch duration (finer epochs win)",
        &table,
    );
    Ok(())
}

/// Fig. 1b — prediction accuracy vs epoch duration.
pub fn fig1b(opts: &ExpOptions) -> anyhow::Result<()> {
    let designs = [
        Policy::Reactive(EstModel::Crisp),
        Policy::AccReac,
        Policy::PcStall,
    ];
    let mut table = CsvTable::new(&["epoch_us", "design", "accuracy"]);
    for &epoch_ns in &[1_000.0, 10_000.0, 50_000.0, 100_000.0] {
        let budget = (opts.trace_epochs() as f64 * 1_000.0 / epoch_ns) as u64;
        let epochs = budget.clamp(10, opts.trace_epochs());
        // enough work that the run never drains inside the window
        let extra = 2.0 * (epochs as f64 * epoch_ns) / (350.0 * 1_000.0);
        for &d in &designs {
            let mut accs = Vec::new();
            for wl in opts.sweep_workloads() {
                let r = run_design_scaled(
                    opts,
                    wl,
                    d,
                    Objective::Ed2p,
                    epoch_ns,
                    RunMode::Epochs(epochs),
                    extra.max(1.0),
                );
                if r.mean_accuracy.is_finite() {
                    accs.push(r.mean_accuracy);
                }
            }
            let mean = accs.iter().sum::<f64>() / accs.len().max(1) as f64;
            table.push(vec![
                format!("{}", epoch_ns / 1000.0),
                d.name(),
                format!("{:.3}", mean),
            ]);
        }
    }
    opts.emit(
        "fig1b",
        "Fig 1b: prediction accuracy vs epoch duration",
        &table,
    );
    Ok(())
}

/// Table I — hardware storage overhead per predictor instance.
pub fn table1(opts: &ExpOptions) -> anyhow::Result<()> {
    let cfg = opts.base_cfg();
    let rows = crate::predictors::storage::table1(&cfg.dvfs, 40);
    let mut table = CsvTable::new(&["design", "item", "bytes", "total_bytes"]);
    for r in &rows {
        for (item, bytes) in &r.items {
            table.push(vec![
                r.design.into(),
                item.clone(),
                bytes.to_string(),
                r.total_bytes().to_string(),
            ]);
        }
    }
    opts.emit("table1", "Table I: storage overhead per instance (bytes)", &table);
    Ok(())
}

/// Fig. 14 — prediction accuracy of every design at 1 µs.
pub fn fig14(opts: &ExpOptions) -> anyhow::Result<()> {
    let mut table = CsvTable::new(&["workload", "design", "accuracy"]);
    let mut per_design: Vec<(String, Vec<f64>)> = Vec::new();
    for d in Policy::all_dvfs() {
        let mut accs = Vec::new();
        for wl in opts.workloads() {
            let r = run_design(
                opts,
                wl,
                d,
                Objective::Ed2p,
                1000.0,
                RunMode::Epochs(opts.trace_epochs()),
            );
            table.push(vec![wl.into(), d.name(), format!("{:.3}", r.mean_accuracy)]);
            if r.mean_accuracy.is_finite() {
                accs.push(r.mean_accuracy);
            }
        }
        per_design.push((d.name(), accs));
    }
    opts.emit("fig14", "Fig 14: prediction accuracy by design @1µs", &table);
    println!("\naverages:");
    for (name, accs) in &per_design {
        println!(
            "  {:<8} {:.3}",
            name,
            accs.iter().sum::<f64>() / accs.len().max(1) as f64
        );
    }
    println!("(paper: STALL/LEAD < CRIT/CRISP ~0.60 < ACCREAC 0.63 < PCSTALL 0.81 < ACCPC 0.90)");
    Ok(())
}

/// Every design of Fig. 15/17 including the static baselines.
fn fig15_designs() -> Vec<Policy> {
    let mut v = vec![
        Policy::Static(0),
        Policy::Static(N_FREQ - 1),
    ];
    v.extend(Policy::all_dvfs());
    v
}

/// Fig. 15 — ED²P normalized to static 1.7 GHz at 1 µs epochs.
pub fn fig15(opts: &ExpOptions) -> anyhow::Result<()> {
    let mut table = CsvTable::new(&["workload", "design", "norm_ed2p"]);
    let mut per_design: Vec<(String, Vec<f64>)> = Vec::new();
    for d in fig15_designs() {
        let mut norms = Vec::new();
        for wl in opts.workloads() {
            let base = run_design(
                opts,
                wl,
                Policy::Static(F_STATIC_IDX),
                Objective::Ed2p,
                1000.0,
                completion(1000.0),
            );
            let r = run_design(opts, wl, d, Objective::Ed2p, 1000.0, completion(1000.0));
            let norm = r.ed2p() / base.ed2p();
            norms.push(norm);
            table.push(vec![wl.into(), d.name(), format!("{:.3}", norm)]);
        }
        per_design.push((d.name(), norms));
    }
    opts.emit("fig15", "Fig 15: ED²P normalized to static 1.7 GHz @1µs", &table);
    println!("\ngeomean normalized ED²P (lower is better):");
    for (name, norms) in &per_design {
        println!("  {:<12} {:.3}", name, geomean(norms));
    }
    println!("(paper: ORACLE 0.46, ACCPC 0.49, PCSTALL 0.52, CRISP 0.77)");
    Ok(())
}

/// Fig. 16 — frequency-state time share under PCSTALL / ED²P.
pub fn fig16(opts: &ExpOptions) -> anyhow::Result<()> {
    let mut header: Vec<String> = vec!["workload".into()];
    header.extend(FREQS_GHZ.iter().map(|f| format!("{f:.1}GHz")));
    let mut table = CsvTable {
        header,
        rows: Vec::new(),
    };
    for wl in opts.workloads() {
        let r = run_design(
            opts,
            wl,
            Policy::PcStall,
            Objective::Ed2p,
            1000.0,
            completion(1000.0),
        );
        let share = r.freq_time_share();
        let mut row = vec![wl.to_string()];
        row.extend(share.iter().map(|s| format!("{:.3}", s)));
        table.rows.push(row);
    }
    opts.emit(
        "fig16",
        "Fig 16: time share per V/f state (PCSTALL, ED²P, 1µs)",
        &table,
    );
    println!("(paper: dgemm/hacc live high, hpgmg/xsbench live low, BwdPool locks one state)");
    Ok(())
}

/// Fig. 17 — geomean EDP vs epoch duration.
pub fn fig17(opts: &ExpOptions) -> anyhow::Result<()> {
    let designs = [
        Policy::Reactive(EstModel::Crisp),
        Policy::PcStall,
        Policy::Oracle,
    ];
    let mut table = CsvTable::new(&["epoch_us", "design", "geomean_norm_edp"]);
    for &epoch_ns in &[1_000.0, 10_000.0, 50_000.0, 100_000.0] {
        for &d in &designs {
            let mut norms = Vec::new();
            for wl in opts.sweep_workloads() {
                let base = run_design(
                    opts,
                    wl,
                    Policy::Static(F_STATIC_IDX),
                    Objective::Edp,
                    epoch_ns,
                    completion(epoch_ns),
                );
                let r = run_design(opts, wl, d, Objective::Edp, epoch_ns, completion(epoch_ns));
                norms.push(r.edp() / base.edp());
            }
            table.push(vec![
                format!("{}", epoch_ns / 1000.0),
                d.name(),
                format!("{:.3}", geomean(&norms)),
            ]);
        }
    }
    opts.emit("fig17", "Fig 17: geomean EDP normalized to static 1.7 GHz", &table);
    println!("(paper: same trend as ED²P but with smaller predictive/reactive gaps)");
    Ok(())
}

/// Fig. 18a — energy savings under performance-degradation bounds.
pub fn fig18a(opts: &ExpOptions) -> anyhow::Result<()> {
    let mut table = CsvTable::new(&[
        "bound_pct",
        "design",
        "energy_savings_pct",
        "perf_degradation_pct",
    ]);
    for &bound in &[0.05, 0.10] {
        for d in [Policy::Reactive(EstModel::Crisp), Policy::PcStall] {
            let mut savings = Vec::new();
            let mut degr = Vec::new();
            for wl in opts.workloads() {
                // reference: max performance = static top state
                let top = run_design(
                    opts,
                    wl,
                    Policy::Static(N_FREQ - 1),
                    Objective::Ed2p,
                    1000.0,
                    completion(1000.0),
                );
                let r = run_design(
                    opts,
                    wl,
                    d,
                    Objective::EnergyBound { max_slowdown: bound },
                    1000.0,
                    completion(1000.0),
                );
                savings.push((1.0 - r.total_energy_j / top.total_energy_j) * 100.0);
                degr.push((r.total_time_ns / top.total_time_ns - 1.0) * 100.0);
            }
            table.push(vec![
                format!("{:.0}", bound * 100.0),
                d.name(),
                format!("{:.1}", savings.iter().sum::<f64>() / savings.len() as f64),
                format!("{:.1}", degr.iter().sum::<f64>() / degr.len() as f64),
            ]);
        }
    }
    opts.emit(
        "fig18a",
        "Fig 18a: energy savings under performance bounds",
        &table,
    );
    println!("(paper: PCSTALL 9.6%@5% / 19.9%@10% vs CRISP 2.1% / 4.7%)");
    Ok(())
}

/// Ablation (§4.4 sizing): PC-table entries vs hit rate and accuracy —
/// the paper's "128 entries reach a 95%+ hit ratio" argument.
pub fn ablation_table_size(opts: &ExpOptions) -> anyhow::Result<()> {
    let mut table = CsvTable::new(&["entries", "hit_rate", "accuracy"]);
    for &entries in &[8usize, 16, 32, 64, 128, 256, 512] {
        let mut hits = Vec::new();
        let mut accs = Vec::new();
        for wl in opts.sweep_workloads() {
            let mut cfg = opts.base_cfg();
            cfg.dvfs.pc_table_entries = entries;
            let spec = workloads::build(wl, opts.waves_scale().max(0.2));
            let mut mgr = DvfsManager::new(cfg, &spec, Policy::PcStall, Objective::Ed2p);
            let r = mgr.run(RunMode::Epochs(opts.trace_epochs()), wl);
            hits.push(mgr.pc_hit_rate());
            if r.mean_accuracy.is_finite() {
                accs.push(r.mean_accuracy);
            }
        }
        table.push(vec![
            entries.to_string(),
            format!("{:.3}", hits.iter().sum::<f64>() / hits.len().max(1) as f64),
            format!("{:.3}", accs.iter().sum::<f64>() / accs.len().max(1) as f64),
        ]);
    }
    opts.emit(
        "ablation_table_size",
        "Ablation: PC-table entries vs hit rate / accuracy (paper: 128 ⇒ 95%+)",
        &table,
    );
    Ok(())
}

/// Ablation: PC-table EWMA update weight (1.0 = paper's overwrite).
pub fn ablation_alpha(opts: &ExpOptions) -> anyhow::Result<()> {
    let mut table = CsvTable::new(&["alpha", "accuracy"]);
    for &alpha in &[0.25f64, 0.5, 0.75, 1.0] {
        let mut accs = Vec::new();
        for wl in opts.sweep_workloads() {
            let mut cfg = opts.base_cfg();
            cfg.dvfs.pc_update_alpha = alpha;
            let spec = workloads::build(wl, opts.waves_scale().max(0.2));
            let mut mgr = DvfsManager::new(cfg, &spec, Policy::PcStall, Objective::Ed2p);
            let r = mgr.run(RunMode::Epochs(opts.trace_epochs()), wl);
            if r.mean_accuracy.is_finite() {
                accs.push(r.mean_accuracy);
            }
        }
        table.push(vec![
            format!("{alpha}"),
            format!("{:.3}", accs.iter().sum::<f64>() / accs.len().max(1) as f64),
        ]);
    }
    opts.emit(
        "ablation_alpha",
        "Ablation: PC-table EWMA weight (1.0 = paper's last-value overwrite)",
        &table,
    );
    Ok(())
}

/// Ablation: PC-table sharing across CUs (paper §4.4 placement
/// flexibility — Fig. 10 implies sharing costs little accuracy).
pub fn ablation_table_share(opts: &ExpOptions) -> anyhow::Result<()> {
    let n_cu = opts.base_cfg().gpu.n_cu;
    let mut table = CsvTable::new(&["cus_per_table", "accuracy"]);
    let mut share = 1usize;
    while share <= n_cu {
        let mut accs = Vec::new();
        for wl in opts.sweep_workloads() {
            let mut cfg = opts.base_cfg();
            cfg.dvfs.pc_table_share = share;
            let spec = workloads::build(wl, opts.waves_scale().max(0.2));
            let mut mgr = DvfsManager::new(cfg, &spec, Policy::PcStall, Objective::Ed2p);
            let r = mgr.run(RunMode::Epochs(opts.trace_epochs()), wl);
            if r.mean_accuracy.is_finite() {
                accs.push(r.mean_accuracy);
            }
        }
        table.push(vec![
            share.to_string(),
            format!("{:.3}", accs.iter().sum::<f64>() / accs.len().max(1) as f64),
        ]);
        share *= 4;
    }
    opts.emit(
        "ablation_table_share",
        "Ablation: CUs sharing one PC table (paper: sharing is nearly free)",
        &table,
    );
    Ok(())
}

/// Fig. 18b — ED²P vs V/f-domain granularity.
pub fn fig18b(opts: &ExpOptions) -> anyhow::Result<()> {
    let n_cu = opts.base_cfg().gpu.n_cu;
    let mut grans = vec![1usize];
    while *grans.last().unwrap() * 2 <= n_cu / 2 {
        let g = grans.last().unwrap() * 2;
        grans.push(g);
    }
    let designs = [
        Policy::Reactive(EstModel::Crisp),
        Policy::PcStall,
        Policy::Oracle,
    ];
    let mut table = CsvTable::new(&["cus_per_domain", "design", "ed2p_improvement_pct"]);
    for &g in &grans {
        for &d in &designs {
            let mut imps = Vec::new();
            for wl in opts.sweep_workloads() {
                let mut sub = opts.clone();
                sub.scale = opts.scale;
                let run_g = |policy: Policy| {
                    let mut cfg = opts.base_cfg();
                    cfg.dvfs.cus_per_domain = g;
                    cfg.dvfs.epoch_ns = 1000.0;
                    let wlspec = workloads::build(wl, opts.waves_scale());
                    let mut mgr = DvfsManager::new(cfg, &wlspec, policy, Objective::Ed2p);
                    mgr.run(completion(1000.0), wl)
                };
                let base = run_g(Policy::Static(F_STATIC_IDX));
                let r = run_g(d);
                imps.push(improvement(&r, &base, 2));
            }
            table.push(vec![
                g.to_string(),
                d.name(),
                format!("{:.1}", imps.iter().sum::<f64>() / imps.len().max(1) as f64),
            ]);
        }
    }
    opts.emit(
        "fig18b",
        "Fig 18b: ED²P improvement vs V/f-domain granularity",
        &table,
    );
    println!("(paper: opportunity shrinks with domain size; PCSTALL keeps most of ORACLE's win)");
    Ok(())
}
