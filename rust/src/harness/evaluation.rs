//! Evaluation experiments (paper §6): Figs. 1, 14–18 and Table I.
//!
//! Every figure is a grid of independent simulations.  Instead of
//! running each cell inline, the figures build a flat job list (one
//! [`Cell`] per grid point, in deterministic iteration order), submit it
//! to the sweep engine ([`crate::exec::Engine`]), and consume the
//! results in the same order.  The engine deduplicates repeated cells
//! (e.g. the static-1.7 GHz baseline requested once per design series),
//! serves previously-computed cells from the content-addressed result
//! cache, and fans the rest out across `--jobs` workers — while keeping
//! the emitted CSVs byte-identical to a serial run.

use std::sync::Arc;

use crate::config::SimConfig;
use crate::dvfs::manager::{DvfsManager, Policy, RunMode};
use crate::dvfs::objective::Objective;
use crate::exec::key::RunKey;
use crate::models::EstModel;
use crate::power::params::{FREQS_GHZ, F_STATIC_IDX, N_FREQ};
use crate::stats::emit::CsvTable;
use crate::stats::RunResult;
use crate::util::geomean;
use crate::workloads::{ResolvedWorkload, WorkloadSource};

use super::sweep::{doubling_axis, EPOCH_LENS_NS};
use super::ExpOptions;

/// Completion-run safety cap.
const MAX_EPOCHS: u64 = 200_000;

/// One grid cell of a sweep: a fully-resolved run request.
#[derive(Debug, Clone)]
pub struct Cell {
    pub workload: String,
    pub policy: Policy,
    pub objective: Objective,
    pub mode: RunMode,
    /// Final workload-length multiplier passed to the generator.
    pub waves: f64,
    /// Exact simulator config for the run (epoch length and any
    /// ablation overrides already applied).
    pub cfg: SimConfig,
}

impl Cell {
    /// Standard cell: scale-derived config with `epoch_ns` applied and
    /// the scale's waves multiplier times `extra_waves`.
    pub fn at(
        opts: &ExpOptions,
        workload: &str,
        policy: Policy,
        objective: Objective,
        epoch_ns: f64,
        mode: RunMode,
        extra_waves: f64,
    ) -> Cell {
        let mut cfg = opts.base_cfg();
        cfg.dvfs.epoch_ns = epoch_ns;
        Cell {
            workload: workload.to_string(),
            policy,
            objective,
            mode,
            waves: opts.waves_scale() * extra_waves,
            cfg,
        }
    }

    /// Cell with an explicitly prepared config (ablation overrides,
    /// domain-granularity sweeps).
    pub fn with_cfg(
        cfg: SimConfig,
        workload: &str,
        policy: Policy,
        objective: Objective,
        mode: RunMode,
        waves: f64,
    ) -> Cell {
        Cell {
            workload: workload.to_string(),
            policy,
            objective,
            mode,
            waves,
            cfg,
        }
    }

    /// Content-address fingerprint of this cell.  `workload_id` is the
    /// *resolved* canonical id (catalog name or `trace:<content-hash>`),
    /// not the user-facing spec — see [`WorkloadSource::resolve`].
    /// Callers outside the submit path should go through [`cell_key`],
    /// which also applies the trace waves normalization.
    fn key_for(&self, opts: &ExpOptions, workload_id: &str) -> RunKey {
        RunKey::new(
            &self.cfg,
            opts.scale.name(),
            opts.backend_name(),
            workload_id,
            self.policy,
            self.objective,
            self.mode,
            self.waves,
        )
    }

    /// Execute the simulation this cell describes.  When an obs
    /// recorder is passed, a [`CounterSink`](crate::obs::CounterSink)
    /// observes the run (bit-identical results either way) and the
    /// counters land in the recorder under the cell's canonical key.
    fn execute(
        self,
        use_pjrt: bool,
        resolved: &ResolvedWorkload,
        obs: Option<(&crate::obs::ObsRecorder, &str, &str)>,
    ) -> RunResult {
        let t_sim = std::time::Instant::now();
        let (launches, rounds) = resolved.lower(self.waves);
        let mut mgr = if use_pjrt {
            DvfsManager::from_launches_with_backend(
                self.cfg,
                launches,
                rounds,
                self.policy,
                self.objective,
                crate::runtime::best_backend(None),
            )
        } else {
            DvfsManager::from_launches(self.cfg, launches, rounds, self.policy, self.objective)
        };
        if obs.is_some() {
            mgr.set_obs_sink(Box::new(crate::obs::CounterSink::new()));
        }
        let r = mgr.run(self.mode, &self.workload);
        if let Some((rec, canonical, hash)) = obs {
            if let Some(c) = mgr.obs_counters() {
                let decisions = mgr.obs_decisions().map(<[_]>::to_vec).unwrap_or_default();
                rec.record_cell(canonical, hash, &r, c.clone(), mgr.cfg.dvfs.epoch_ns, decisions);
            }
            rec.add_span("harness", "cell.simulate", t_sim, std::time::Instant::now(), 0);
        }
        r
    }
}

/// Submit a batch of cells to the engine and collect the results in
/// submission order.  Workload specs are resolved up front and
/// memoized per spec (a trace file is read, parsed, and content-hashed
/// once per batch, and all its cells share one in-memory copy); an
/// unreadable or invalid spec fails the whole batch with a clear error
/// before anything runs.
///
/// Trace-driven cells ignore the scale's waves multiplier: a trace
/// records its absolute launch geometry, and the catalog multipliers
/// are tuned to catalog base sizes.  Normalizing `waves` to 1.0 before
/// the key is computed keeps the cell's [`RunKey`] identical across
/// scale presets (and identical to a direct `trace replay`).
pub fn run_cells(opts: &ExpOptions, cells: Vec<Cell>) -> anyhow::Result<Vec<RunResult>> {
    use std::collections::HashMap;

    let mut resolved_by_spec: HashMap<String, Arc<ResolvedWorkload>> = HashMap::new();
    let mut batch = Vec::with_capacity(cells.len());
    for cell in cells {
        let resolved = match resolved_by_spec.get(&cell.workload) {
            Some(r) => r.clone(),
            None => {
                let t_resolve = std::time::Instant::now();
                let r = Arc::new(WorkloadSource::parse(&cell.workload)?.resolve()?);
                if let Some(o) = &opts.obs {
                    o.add_span(
                        "harness",
                        "cell.resolve",
                        t_resolve,
                        std::time::Instant::now(),
                        0,
                    );
                }
                resolved_by_spec.insert(cell.workload.clone(), r.clone());
                r
            }
        };
        batch.push((cell, resolved));
    }
    Ok(run_cells_resolved(opts, batch))
}

/// [`run_cells`] for pre-resolved cells (the sweep-plan path, which
/// resolves every spec once at compile time so the shard partition and
/// the execution see the same workload content).
pub(crate) fn run_cells_resolved(
    opts: &ExpOptions,
    cells: Vec<(Cell, Arc<ResolvedWorkload>)>,
) -> Vec<RunResult> {
    let use_pjrt = opts.use_pjrt;
    // Split the machine between batch workers and per-simulation CU
    // threads (never oversubscribing): big batches keep the worker pool
    // wide with serial sims; small batches hand idle cores to each sim.
    // sim_threads is execution-only, so budgeting it here cannot perturb
    // any cell's RunKey.
    let nproc = crate::exec::pool::default_jobs();
    // Cells whose plan set `gpu.sim_threads` via `[set]` keep their own
    // width below; the pool must budget for the widest of them or
    // jobs x plan width could oversubscribe the machine.  An explicit
    // --sim-threads overwrites every cell, making plan widths moot.
    let plan_width = if opts.sim_threads.is_some() {
        0
    } else {
        cells
            .iter()
            .map(|(c, _)| match c.cfg.gpu.sim_threads {
                0 => nproc, // 0 = as wide as the machine
                w => w,
            })
            .filter(|&w| w != 1)
            .max()
            .unwrap_or(0)
    };
    let (jobs, sim_threads) = crate::exec::pool::thread_budget(
        cells.len(),
        opts.jobs.max(1),
        opts.sim_threads,
        plan_width,
        nproc,
    );
    let batch: Vec<_> = cells
        .into_iter()
        .map(|(mut cell, resolved)| {
            // An explicit --sim-threads pins every cell; the automatic
            // budget only fills cells still at the serial default, so a
            // plan's own `[set] gpu.sim_threads` survives.
            if opts.sim_threads.is_some() || cell.cfg.gpu.sim_threads == 1 {
                cell.cfg.gpu.sim_threads = sim_threads;
            }
            let key = cell_key(opts, &mut cell, &resolved);
            let obs = opts.obs.clone();
            let canonical = key.canonical();
            let hash = key.hash_hex();
            (key, move || {
                let obs_ref = obs
                    .as_deref()
                    .map(|rec| (rec, canonical.as_str(), hash.as_str()));
                cell.execute(use_pjrt, &resolved, obs_ref)
            })
        })
        .collect();
    opts.engine.run_batch(jobs, batch)
}

/// The fingerprint a cell will execute under, after normalization: the
/// single source of truth shared by the submit path above and the
/// sweep-plan shard partition ([`crate::harness::sweep`]).  Mutates the
/// cell exactly the way submission would (trace-driven cells pin
/// `waves` to 1.0 — see [`run_cells`]), so a key computed here is
/// byte-identical to the one the engine sees.
pub(crate) fn cell_key(opts: &ExpOptions, cell: &mut Cell, resolved: &ResolvedWorkload) -> RunKey {
    if resolved.trace().is_some() {
        cell.waves = 1.0;
    }
    cell.key_for(opts, &resolved.id)
}

/// Run one (workload, policy, objective) configuration through the
/// engine (cache-aware single-cell batch).
pub fn run_design(
    opts: &ExpOptions,
    workload: &str,
    policy: Policy,
    objective: Objective,
    epoch_ns: f64,
    mode: RunMode,
) -> anyhow::Result<RunResult> {
    run_design_scaled(opts, workload, policy, objective, epoch_ns, mode, 1.0)
}

/// `run_design` with an extra workload-length multiplier (epoch-duration
/// sweeps need enough work to fill many coarse epochs).
#[allow(clippy::too_many_arguments)]
pub fn run_design_scaled(
    opts: &ExpOptions,
    workload: &str,
    policy: Policy,
    objective: Objective,
    epoch_ns: f64,
    mode: RunMode,
    extra_waves: f64,
) -> anyhow::Result<RunResult> {
    let cell = Cell::at(opts, workload, policy, objective, epoch_ns, mode, extra_waves);
    Ok(run_cells(opts, vec![cell])?
        .pop()
        .expect("single-cell batch returns one result"))
}

/// Completion mode with the standard epoch-scaled safety cap (shared by
/// the fixed-work figures and the sweep plans).
pub(crate) fn completion(epoch_ns: f64) -> RunMode {
    // cap scales with epoch length so the cap is a time budget
    RunMode::Completion {
        max_epochs: (MAX_EPOCHS as f64 / (epoch_ns / 1000.0)).max(64.0) as u64,
    }
}

/// ED^nP improvement (%) of `r` over the static-1.7 reference.
fn improvement(r: &RunResult, base: &RunResult, n: u32) -> f64 {
    (1.0 - r.ednp(n) / base.ednp(n)) * 100.0
}

/// `[axis][design] -> per-workload (baseline, design) result pairs`.
type PairedGrid = Vec<Vec<Vec<(RunResult, RunResult)>>>;

/// Shared grid helper for the paired (baseline, design) axis figures
/// (Figs. 1a, 17, 18b): build the interleaved baseline/design cell
/// batch for `axis × designs × sweep_workloads`, run it through the
/// engine, and hand back the result pairs grouped `[axis][design] ->
/// Vec<(baseline, design)>` in workload order.  `cell_of` maps one
/// `(axis value, workload, policy)` coordinate to its cell — epoch
/// sweeps set `epoch_ns`, granularity sweeps set `cus_per_domain`.
fn paired_axis_grid<A: Copy>(
    opts: &ExpOptions,
    axis: &[A],
    designs: &[Policy],
    baseline: Policy,
    cell_of: impl Fn(A, &str, Policy) -> Cell,
) -> anyhow::Result<PairedGrid> {
    let wls = opts.sweep_workloads();
    let mut cells = Vec::with_capacity(axis.len() * designs.len() * wls.len() * 2);
    for &a in axis {
        for &d in designs {
            for &wl in &wls {
                cells.push(cell_of(a, wl, baseline));
                cells.push(cell_of(a, wl, d));
            }
        }
    }
    let mut results = run_cells(opts, cells)?.into_iter();
    let mut grid = Vec::with_capacity(axis.len());
    for _ in axis {
        let mut per_design = Vec::with_capacity(designs.len());
        for _ in designs {
            let pairs: Vec<(RunResult, RunResult)> = wls
                .iter()
                .map(|_| {
                    let base = results.next().expect("batch size mismatch");
                    let r = results.next().expect("batch size mismatch");
                    (base, r)
                })
                .collect();
            per_design.push(pairs);
        }
        grid.push(per_design);
    }
    Ok(grid)
}

/// Fig. 1a — ED²P opportunity vs DVFS epoch duration.
pub fn fig1a(opts: &ExpOptions) -> anyhow::Result<()> {
    let designs = [
        Policy::Reactive(EstModel::Crisp),
        Policy::PcStall,
        Policy::Oracle,
    ];
    let grid = paired_axis_grid(
        opts,
        &EPOCH_LENS_NS,
        &designs,
        Policy::Static(F_STATIC_IDX),
        |epoch_ns, wl, p| {
            Cell::at(opts, wl, p, Objective::Ed2p, epoch_ns, completion(epoch_ns), 1.0)
        },
    )?;

    let mut table = CsvTable::new(&["epoch_us", "design", "ed2p_improvement_pct"]);
    for (&epoch_ns, per_design) in EPOCH_LENS_NS.iter().zip(&grid) {
        for (&d, pairs) in designs.iter().zip(per_design) {
            let imps: Vec<f64> = pairs.iter().map(|(base, r)| improvement(r, base, 2)).collect();
            let mean = imps.iter().sum::<f64>() / imps.len().max(1) as f64;
            table.push(vec![
                format!("{}", epoch_ns / 1000.0),
                d.name(),
                format!("{:.1}", mean),
            ]);
        }
    }
    opts.emit(
        "fig1a",
        "Fig 1a: ED²P improvement vs epoch duration (finer epochs win)",
        &table,
    );
    Ok(())
}

/// Fig. 1b — prediction accuracy vs epoch duration.
pub fn fig1b(opts: &ExpOptions) -> anyhow::Result<()> {
    let designs = [
        Policy::Reactive(EstModel::Crisp),
        Policy::AccReac,
        Policy::PcStall,
    ];
    let epoch_lens = EPOCH_LENS_NS;

    let plan = |epoch_ns: f64| {
        let budget = (opts.trace_epochs() as f64 * 1_000.0 / epoch_ns) as u64;
        let epochs = budget.clamp(10, opts.trace_epochs());
        // enough work that the run never drains inside the window
        let extra = 2.0 * (epochs as f64 * epoch_ns) / (350.0 * 1_000.0);
        (epochs, extra.max(1.0))
    };

    let mut cells = Vec::new();
    for &epoch_ns in &epoch_lens {
        let (epochs, extra) = plan(epoch_ns);
        for &d in &designs {
            for wl in opts.sweep_workloads() {
                cells.push(Cell::at(
                    opts,
                    wl,
                    d,
                    Objective::Ed2p,
                    epoch_ns,
                    RunMode::Epochs(epochs),
                    extra,
                ));
            }
        }
    }
    let mut results = run_cells(opts, cells)?.into_iter();

    let mut table = CsvTable::new(&["epoch_us", "design", "accuracy"]);
    for &epoch_ns in &epoch_lens {
        for &d in &designs {
            let mut accs = Vec::new();
            for _wl in opts.sweep_workloads() {
                let r = results.next().unwrap();
                if r.mean_accuracy.is_finite() {
                    accs.push(r.mean_accuracy);
                }
            }
            let mean = accs.iter().sum::<f64>() / accs.len().max(1) as f64;
            table.push(vec![
                format!("{}", epoch_ns / 1000.0),
                d.name(),
                format!("{:.3}", mean),
            ]);
        }
    }
    opts.emit(
        "fig1b",
        "Fig 1b: prediction accuracy vs epoch duration",
        &table,
    );
    Ok(())
}

/// Table I — hardware storage overhead per predictor instance.
pub fn table1(opts: &ExpOptions) -> anyhow::Result<()> {
    let cfg = opts.base_cfg();
    let rows = crate::predictors::storage::table1(&cfg.dvfs, 40);
    let mut table = CsvTable::new(&["design", "item", "bytes", "total_bytes"]);
    for r in &rows {
        for (item, bytes) in &r.items {
            table.push(vec![
                r.design.into(),
                item.clone(),
                bytes.to_string(),
                r.total_bytes().to_string(),
            ]);
        }
    }
    opts.emit("table1", "Table I: storage overhead per instance (bytes)", &table);
    Ok(())
}

/// Fig. 14 — prediction accuracy of every design at 1 µs.
pub fn fig14(opts: &ExpOptions) -> anyhow::Result<()> {
    let designs = Policy::all_dvfs();

    let mut cells = Vec::new();
    for &d in &designs {
        for wl in opts.workloads() {
            cells.push(Cell::at(
                opts,
                wl,
                d,
                Objective::Ed2p,
                1000.0,
                RunMode::Epochs(opts.trace_epochs()),
                1.0,
            ));
        }
    }
    let mut results = run_cells(opts, cells)?.into_iter();

    let mut table = CsvTable::new(&["workload", "design", "accuracy"]);
    let mut per_design: Vec<(String, Vec<f64>)> = Vec::new();
    for &d in &designs {
        let mut accs = Vec::new();
        for wl in opts.workloads() {
            let r = results.next().unwrap();
            table.push(vec![wl.into(), d.name(), format!("{:.3}", r.mean_accuracy)]);
            if r.mean_accuracy.is_finite() {
                accs.push(r.mean_accuracy);
            }
        }
        per_design.push((d.name(), accs));
    }
    opts.emit("fig14", "Fig 14: prediction accuracy by design @1µs", &table);
    println!("\naverages:");
    for (name, accs) in &per_design {
        println!(
            "  {:<8} {:.3}",
            name,
            accs.iter().sum::<f64>() / accs.len().max(1) as f64
        );
    }
    println!("(paper: STALL/LEAD < CRIT/CRISP ~0.60 < ACCREAC 0.63 < PCSTALL 0.81 < ACCPC 0.90)");
    Ok(())
}

/// Every design of Fig. 15/17 including the static baselines.
fn fig15_designs() -> Vec<Policy> {
    let mut v = vec![
        Policy::Static(0),
        Policy::Static(N_FREQ - 1),
    ];
    v.extend(Policy::all_dvfs());
    v
}

/// Fig. 15 — ED²P normalized to static 1.7 GHz at 1 µs epochs.
pub fn fig15(opts: &ExpOptions) -> anyhow::Result<()> {
    let designs = fig15_designs();

    let mut cells = Vec::new();
    for &d in &designs {
        for wl in opts.workloads() {
            cells.push(Cell::at(
                opts,
                wl,
                Policy::Static(F_STATIC_IDX),
                Objective::Ed2p,
                1000.0,
                completion(1000.0),
                1.0,
            ));
            cells.push(Cell::at(
                opts,
                wl,
                d,
                Objective::Ed2p,
                1000.0,
                completion(1000.0),
                1.0,
            ));
        }
    }
    let mut results = run_cells(opts, cells)?.into_iter();

    let mut table = CsvTable::new(&["workload", "design", "norm_ed2p"]);
    let mut per_design: Vec<(String, Vec<f64>)> = Vec::new();
    for &d in &designs {
        let mut norms = Vec::new();
        for wl in opts.workloads() {
            let base = results.next().unwrap();
            let r = results.next().unwrap();
            let norm = r.ed2p() / base.ed2p();
            norms.push(norm);
            table.push(vec![wl.into(), d.name(), format!("{:.3}", norm)]);
        }
        per_design.push((d.name(), norms));
    }
    opts.emit("fig15", "Fig 15: ED²P normalized to static 1.7 GHz @1µs", &table);
    println!("\ngeomean normalized ED²P (lower is better):");
    for (name, norms) in &per_design {
        println!("  {:<12} {:.3}", name, geomean(norms));
    }
    println!("(paper: ORACLE 0.46, ACCPC 0.49, PCSTALL 0.52, CRISP 0.77)");
    Ok(())
}

/// Fig. 16 — frequency-state time share under PCSTALL / ED²P.
pub fn fig16(opts: &ExpOptions) -> anyhow::Result<()> {
    let cells: Vec<Cell> = opts
        .workloads()
        .iter()
        .map(|&wl| {
            Cell::at(
                opts,
                wl,
                Policy::PcStall,
                Objective::Ed2p,
                1000.0,
                completion(1000.0),
                1.0,
            )
        })
        .collect();
    let results = run_cells(opts, cells)?;

    let mut header: Vec<String> = vec!["workload".into()];
    header.extend(FREQS_GHZ.iter().map(|f| format!("{f:.1}GHz")));
    let mut table = CsvTable {
        header,
        rows: Vec::new(),
    };
    for (wl, r) in opts.workloads().iter().zip(&results) {
        let share = r.freq_time_share();
        let mut row = vec![wl.to_string()];
        row.extend(share.iter().map(|s| format!("{:.3}", s)));
        table.rows.push(row);
    }
    opts.emit(
        "fig16",
        "Fig 16: time share per V/f state (PCSTALL, ED²P, 1µs)",
        &table,
    );
    println!("(paper: dgemm/hacc live high, hpgmg/xsbench live low, BwdPool locks one state)");
    Ok(())
}

/// Fig. 17 — geomean EDP vs epoch duration.
pub fn fig17(opts: &ExpOptions) -> anyhow::Result<()> {
    let designs = [
        Policy::Reactive(EstModel::Crisp),
        Policy::PcStall,
        Policy::Oracle,
    ];
    let grid = paired_axis_grid(
        opts,
        &EPOCH_LENS_NS,
        &designs,
        Policy::Static(F_STATIC_IDX),
        |epoch_ns, wl, p| {
            Cell::at(opts, wl, p, Objective::Edp, epoch_ns, completion(epoch_ns), 1.0)
        },
    )?;

    let mut table = CsvTable::new(&["epoch_us", "design", "geomean_norm_edp"]);
    for (&epoch_ns, per_design) in EPOCH_LENS_NS.iter().zip(&grid) {
        for (&d, pairs) in designs.iter().zip(per_design) {
            let norms: Vec<f64> = pairs.iter().map(|(base, r)| r.edp() / base.edp()).collect();
            table.push(vec![
                format!("{}", epoch_ns / 1000.0),
                d.name(),
                format!("{:.3}", geomean(&norms)),
            ]);
        }
    }
    opts.emit("fig17", "Fig 17: geomean EDP normalized to static 1.7 GHz", &table);
    println!("(paper: same trend as ED²P but with smaller predictive/reactive gaps)");
    Ok(())
}

/// Fig. 18a — energy savings under performance-degradation bounds.
pub fn fig18a(opts: &ExpOptions) -> anyhow::Result<()> {
    let bounds = [0.05, 0.10];
    let designs = [Policy::Reactive(EstModel::Crisp), Policy::PcStall];

    let mut cells = Vec::new();
    for &bound in &bounds {
        for &d in &designs {
            for wl in opts.workloads() {
                // reference: max performance = static top state
                cells.push(Cell::at(
                    opts,
                    wl,
                    Policy::Static(N_FREQ - 1),
                    Objective::Ed2p,
                    1000.0,
                    completion(1000.0),
                    1.0,
                ));
                cells.push(Cell::at(
                    opts,
                    wl,
                    d,
                    Objective::EnergyBound { max_slowdown: bound },
                    1000.0,
                    completion(1000.0),
                    1.0,
                ));
            }
        }
    }
    let mut results = run_cells(opts, cells)?.into_iter();

    let mut table = CsvTable::new(&[
        "bound_pct",
        "design",
        "energy_savings_pct",
        "perf_degradation_pct",
    ]);
    for &bound in &bounds {
        for &d in &designs {
            let mut savings = Vec::new();
            let mut degr = Vec::new();
            for _wl in opts.workloads() {
                let top = results.next().unwrap();
                let r = results.next().unwrap();
                savings.push((1.0 - r.total_energy_j / top.total_energy_j) * 100.0);
                degr.push((r.total_time_ns / top.total_time_ns - 1.0) * 100.0);
            }
            table.push(vec![
                format!("{:.0}", bound * 100.0),
                d.name(),
                format!("{:.1}", savings.iter().sum::<f64>() / savings.len() as f64),
                format!("{:.1}", degr.iter().sum::<f64>() / degr.len() as f64),
            ]);
        }
    }
    opts.emit(
        "fig18a",
        "Fig 18a: energy savings under performance bounds",
        &table,
    );
    println!("(paper: PCSTALL 9.6%@5% / 19.9%@10% vs CRISP 2.1% / 4.7%)");
    Ok(())
}

/// Shared axis helper for the PCSTALL config ablations: run PCSTALL /
/// ED²P at `trace_epochs` over the sweep workloads for every value of a
/// config axis (`cfg_at(i)` prepares the i-th config) and return the
/// per-value `(mean PC-table hit rate, mean accuracy)`.
fn pcstall_cfg_axis(
    opts: &ExpOptions,
    n_values: usize,
    cfg_at: impl Fn(usize) -> SimConfig,
) -> anyhow::Result<Vec<(f64, f64)>> {
    let wls = opts.sweep_workloads();
    let mut cells = Vec::with_capacity(n_values * wls.len());
    for i in 0..n_values {
        for wl in &wls {
            cells.push(Cell::with_cfg(
                cfg_at(i),
                wl,
                Policy::PcStall,
                Objective::Ed2p,
                RunMode::Epochs(opts.trace_epochs()),
                opts.waves_scale().max(0.2),
            ));
        }
    }
    let mut results = run_cells(opts, cells)?.into_iter();
    let mut out = Vec::with_capacity(n_values);
    for _ in 0..n_values {
        let mut hits = Vec::new();
        let mut accs = Vec::new();
        for _ in &wls {
            let r = results.next().expect("batch size mismatch");
            hits.push(r.pc_hit_rate);
            if r.mean_accuracy.is_finite() {
                accs.push(r.mean_accuracy);
            }
        }
        out.push((
            hits.iter().sum::<f64>() / hits.len().max(1) as f64,
            accs.iter().sum::<f64>() / accs.len().max(1) as f64,
        ));
    }
    Ok(out)
}

/// Ablation (§4.4 sizing): PC-table entries vs hit rate and accuracy —
/// the paper's "128 entries reach a 95%+ hit ratio" argument.
pub fn ablation_table_size(opts: &ExpOptions) -> anyhow::Result<()> {
    let sizes = [8usize, 16, 32, 64, 128, 256, 512];
    let stats = pcstall_cfg_axis(opts, sizes.len(), |i| {
        let mut cfg = opts.base_cfg();
        cfg.dvfs.pc_table_entries = sizes[i];
        cfg
    })?;

    let mut table = CsvTable::new(&["entries", "hit_rate", "accuracy"]);
    for (&entries, &(hit, acc)) in sizes.iter().zip(&stats) {
        table.push(vec![
            entries.to_string(),
            format!("{:.3}", hit),
            format!("{:.3}", acc),
        ]);
    }
    opts.emit(
        "ablation_table_size",
        "Ablation: PC-table entries vs hit rate / accuracy (paper: 128 ⇒ 95%+)",
        &table,
    );
    Ok(())
}

/// Ablation: PC-table EWMA update weight (1.0 = paper's overwrite).
pub fn ablation_alpha(opts: &ExpOptions) -> anyhow::Result<()> {
    let alphas = [0.25f64, 0.5, 0.75, 1.0];
    let stats = pcstall_cfg_axis(opts, alphas.len(), |i| {
        let mut cfg = opts.base_cfg();
        cfg.dvfs.pc_update_alpha = alphas[i];
        cfg
    })?;

    let mut table = CsvTable::new(&["alpha", "accuracy"]);
    for (&alpha, &(_, acc)) in alphas.iter().zip(&stats) {
        table.push(vec![format!("{alpha}"), format!("{:.3}", acc)]);
    }
    opts.emit(
        "ablation_alpha",
        "Ablation: PC-table EWMA weight (1.0 = paper's last-value overwrite)",
        &table,
    );
    Ok(())
}

/// Ablation: PC-table sharing across CUs (paper §4.4 placement
/// flexibility — Fig. 10 implies sharing costs little accuracy).
pub fn ablation_table_share(opts: &ExpOptions) -> anyhow::Result<()> {
    let n_cu = opts.base_cfg().gpu.n_cu;
    let mut shares = Vec::new();
    let mut share = 1usize;
    while share <= n_cu {
        shares.push(share);
        share *= 4;
    }
    let stats = pcstall_cfg_axis(opts, shares.len(), |i| {
        let mut cfg = opts.base_cfg();
        cfg.dvfs.pc_table_share = shares[i];
        cfg
    })?;

    let mut table = CsvTable::new(&["cus_per_table", "accuracy"]);
    for (&share, &(_, acc)) in shares.iter().zip(&stats) {
        table.push(vec![share.to_string(), format!("{:.3}", acc)]);
    }
    opts.emit(
        "ablation_table_share",
        "Ablation: CUs sharing one PC table (paper: sharing is nearly free)",
        &table,
    );
    Ok(())
}

/// Fig. 18b — ED²P vs V/f-domain granularity.
pub fn fig18b(opts: &ExpOptions) -> anyhow::Result<()> {
    let grans = doubling_axis(opts.base_cfg().gpu.n_cu / 2);
    let designs = [
        Policy::Reactive(EstModel::Crisp),
        Policy::PcStall,
        Policy::Oracle,
    ];
    let grid = paired_axis_grid(
        opts,
        &grans,
        &designs,
        Policy::Static(F_STATIC_IDX),
        |g, wl, policy| {
            let mut cfg = opts.base_cfg();
            cfg.dvfs.cus_per_domain = g;
            cfg.dvfs.epoch_ns = 1000.0;
            Cell::with_cfg(
                cfg,
                wl,
                policy,
                Objective::Ed2p,
                completion(1000.0),
                opts.waves_scale(),
            )
        },
    )?;

    let mut table = CsvTable::new(&["cus_per_domain", "design", "ed2p_improvement_pct"]);
    for (&g, per_design) in grans.iter().zip(&grid) {
        for (&d, pairs) in designs.iter().zip(per_design) {
            let imps: Vec<f64> = pairs.iter().map(|(base, r)| improvement(r, base, 2)).collect();
            table.push(vec![
                g.to_string(),
                d.name(),
                format!("{:.1}", imps.iter().sum::<f64>() / imps.len().max(1) as f64),
            ]);
        }
    }
    opts.emit(
        "fig18b",
        "Fig 18b: ED²P improvement vs V/f-domain granularity",
        &table,
    );
    println!("(paper: opportunity shrinks with domain size; PCSTALL keeps most of ORACLE's win)");
    Ok(())
}
