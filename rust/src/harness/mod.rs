//! Experiment harness: one entry per paper figure/table (DESIGN.md §3).
//!
//! Every experiment regenerates the *shape* of its paper artifact —
//! workloads, parameter sweeps, baselines and the same rows/series —
//! printed as a terminal table and written to `results/<id>.csv`.
//! Absolute numbers differ (our substrate is a simulator, not the
//! authors' testbed); orderings and approximate factors are the
//! reproduction target.

pub mod characterization;
pub mod evaluation;
pub mod serve;
pub mod sweep;

use std::path::PathBuf;
use std::sync::Arc;

use crate::config::SimConfig;
use crate::exec::Engine;
use crate::stats::emit::CsvTable;

/// Experiment scale preset.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// CI-speed: 4 CUs, 6 workloads, short runs.
    Quick,
    /// Development default: 8 CUs, all 16 workloads.
    Default,
    /// Paper shape: 64 CUs, 40 WFs (slow!).
    Full,
}

impl Scale {
    /// Stable name used in run-key fingerprints.
    pub fn name(self) -> &'static str {
        match self {
            Scale::Quick => "quick",
            Scale::Default => "default",
            Scale::Full => "full",
        }
    }
}

/// Options shared by all experiments.
#[derive(Debug, Clone)]
pub struct ExpOptions {
    pub scale: Scale,
    pub out_dir: PathBuf,
    /// Use the PJRT artifact backend in manager runs when available.
    pub use_pjrt: bool,
    pub seed: u64,
    /// Worker threads for sweep execution (`--jobs`; 1 = serial).
    pub jobs: usize,
    /// Sweep engine shared by every experiment of this invocation
    /// (result cache + execution accounting).
    pub engine: Arc<Engine>,
    /// Explicit workload set (`--workload`, repeatable): replaces the
    /// scale's catalog subset in every experiment.  Accepts any
    /// [`crate::workloads::WorkloadSource`] spec — catalog names,
    /// `trace:<path>`, `synth:<seed>`.  (`&'static` because the CLI
    /// leaks its handful of argv strings once per process.)
    pub workloads_override: Vec<&'static str>,
    /// Observability recorder (`--obs <dir>`): deterministic per-cell
    /// counters + wall-clock spans.  `None` = off (zero overhead).
    pub obs: Option<Arc<crate::obs::ObsRecorder>>,
    /// `--progress`: periodic stderr progress from batch execution.
    pub progress: bool,
    /// `--sim-threads`: CU-stepping threads per simulation.  `None`
    /// lets [`crate::exec::pool::thread_budget`] decide from the batch
    /// size; `Some(0)` = as wide as the machine; `Some(n)` pins the
    /// width.  Result-invariant — never part of run identity.
    pub sim_threads: Option<usize>,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            scale: Scale::Default,
            out_dir: PathBuf::from("results"),
            use_pjrt: false,
            seed: 0,
            jobs: 1,
            engine: Arc::new(Engine::no_cache()),
            workloads_override: Vec::new(),
            obs: None,
            progress: false,
            sim_threads: None,
        }
    }
}

impl ExpOptions {
    /// Base simulator config for this scale.
    pub fn base_cfg(&self) -> SimConfig {
        let mut c = SimConfig::default();
        match self.scale {
            Scale::Quick => {
                c.gpu.n_cu = 4;
                c.gpu.n_wf = 8;
                c.gpu.l2_bytes = 512 * 1024;
            }
            Scale::Default => {
                c.gpu.n_cu = 8;
                c.gpu.n_wf = 16;
                c.gpu.l2_bytes = 1024 * 1024;
            }
            Scale::Full => {}
        }
        c.seed = self.seed;
        c
    }

    /// Workload subset for heavyweight sweeps (or the `--workload`
    /// override, verbatim, when one was given).
    pub fn workloads(&self) -> Vec<&'static str> {
        if !self.workloads_override.is_empty() {
            return self.workloads_override.clone();
        }
        match self.scale {
            Scale::Quick => vec!["comd", "hpgmg", "xsbench", "hacc", "dgemm", "BwdBN"],
            _ => crate::workloads::names(),
        }
    }

    /// Smaller subset for epoch-length sweeps (each point is a full run).
    pub fn sweep_workloads(&self) -> Vec<&'static str> {
        if !self.workloads_override.is_empty() {
            return self.workloads_override.clone();
        }
        match self.scale {
            Scale::Quick => vec!["comd", "xsbench", "hacc", "dgemm"],
            _ => vec![
                "comd", "hpgmg", "xsbench", "hacc", "quickS", "dgemm", "BwdBN", "FwdSoft",
            ],
        }
    }

    /// Completion-run waves multiplier (controls run length).
    pub fn waves_scale(&self) -> f64 {
        match self.scale {
            Scale::Quick => 0.05,
            Scale::Default => 0.1,
            Scale::Full => 1.0,
        }
    }

    /// Backend name used in run-key fingerprints.  This must reflect the
    /// backend that will actually execute, not the one requested:
    /// `best_backend` silently falls back to native when the build lacks
    /// the `pjrt` feature or no artifact is present, and caching those
    /// results under a `pjrt` key would poison later real-PJRT runs.
    pub fn backend_name(&self) -> &'static str {
        if self.use_pjrt
            && cfg!(feature = "pjrt")
            && crate::runtime::find_artifact(None).is_some()
        {
            "pjrt"
        } else {
            "native"
        }
    }

    /// Characterization trace length in epochs.
    pub fn trace_epochs(&self) -> u64 {
        match self.scale {
            Scale::Quick => 40,
            Scale::Default => 120,
            Scale::Full => 400,
        }
    }

    /// Save a table under `results/` and print it.
    pub fn emit(&self, id: &str, title: &str, table: &CsvTable) {
        let t_emit = std::time::Instant::now();
        let path = self.out_dir.join(format!("{id}.csv"));
        if let Err(e) = table.write(&path) {
            eprintln!("[harness] failed to write {}: {e}", path.display());
        } else {
            println!("[harness] wrote {}", path.display());
        }
        crate::stats::emit::print_table(
            title,
            &table.header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
            &table.rows,
        );
        if let Some(o) = &self.obs {
            o.add_span("harness", "cell.emit", t_emit, std::time::Instant::now(), 0);
        }
    }
}

/// Registry of every experiment id.
pub fn all_experiments() -> Vec<&'static str> {
    vec![
        "fig1a", "fig1b", "fig5", "fig6", "fig7", "fig8", "fig10", "fig11a", "fig11b",
        "table1", "oracle-validation", "fig14", "fig15", "fig16", "fig17", "fig18a", "fig18b",
        "ablation-table-size", "ablation-alpha", "ablation-table-share",
    ]
}

/// Run one experiment by id.
pub fn run_experiment(id: &str, opts: &ExpOptions) -> anyhow::Result<()> {
    match id {
        "fig1a" => evaluation::fig1a(opts),
        "fig1b" => evaluation::fig1b(opts),
        "fig5" => characterization::fig5(opts),
        "fig6" => characterization::fig6(opts),
        "fig7" => characterization::fig7(opts),
        "fig8" => characterization::fig8(opts),
        "fig10" => characterization::fig10(opts),
        "fig11a" => characterization::fig11a(opts),
        "fig11b" => characterization::fig11b(opts),
        "table1" => evaluation::table1(opts),
        "oracle-validation" => characterization::oracle_validation(opts),
        "fig14" => evaluation::fig14(opts),
        "fig15" => evaluation::fig15(opts),
        "fig16" => evaluation::fig16(opts),
        "fig17" => evaluation::fig17(opts),
        "fig18a" => evaluation::fig18a(opts),
        "fig18b" => evaluation::fig18b(opts),
        "ablation-table-size" => evaluation::ablation_table_size(opts),
        "ablation-alpha" => evaluation::ablation_alpha(opts),
        "ablation-table-share" => evaluation::ablation_table_share(opts),
        "all" => {
            for e in all_experiments() {
                println!("\n########## experiment {e} ##########");
                run_experiment(e, opts)?;
            }
            Ok(())
        }
        _ => anyhow::bail!("unknown experiment '{id}' (see `pcstall list`)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_paper_artifacts() {
        let ids = all_experiments();
        // every evaluation figure + table of the paper
        for want in [
            "fig1a", "fig1b", "fig5", "fig6", "fig7", "fig8", "fig10", "fig11a", "fig11b",
            "table1", "fig14", "fig15", "fig16", "fig17", "fig18a", "fig18b",
        ] {
            assert!(ids.contains(&want), "missing {want}");
        }
    }

    #[test]
    fn scales_shrink_config() {
        let q = ExpOptions {
            scale: Scale::Quick,
            ..Default::default()
        };
        let f = ExpOptions {
            scale: Scale::Full,
            ..Default::default()
        };
        assert!(q.base_cfg().gpu.n_cu < f.base_cfg().gpu.n_cu);
        assert_eq!(f.base_cfg().gpu.n_cu, 64);
        assert!(q.workloads().len() < f.workloads().len());
    }

    #[test]
    fn unknown_experiment_errors() {
        assert!(run_experiment("nope", &ExpOptions::default()).is_err());
    }

    #[test]
    fn workload_override_replaces_both_subsets() {
        let o = ExpOptions {
            workloads_override: vec!["dgemm", "trace:/tmp/x.trace"],
            ..Default::default()
        };
        assert_eq!(o.workloads(), vec!["dgemm", "trace:/tmp/x.trace"]);
        assert_eq!(o.sweep_workloads(), o.workloads());
    }
}
