//! Serve-mode harness: continuous-traffic DVFS under deadlines.
//!
//! The paper evaluates DVFS policies on *fixed-work* runs (a workload
//! executes once; ED²P over that span).  Datacenter GPUs instead see a
//! continuous launch stream, and the figure of merit becomes "energy
//! saved at a fixed tail-latency target".  `pcstall serve` drives one
//! long-horizon simulation per policy: a seeded arrival process
//! ([`crate::dvfs::manager::DvfsManager::run`] with
//! [`RunMode::Serve`]) offers `serve.launches` copies of the workload,
//! the policy runs throughout (idle epochs included), and the run
//! reports per-launch p50/p99 latency, deadline-miss rate, throughput
//! and energy in one CSV row per policy.
//!
//! Two execution paths:
//!
//! * **Synthetic arrivals** (Poisson / bursty MMPP-2, selected by
//!   `serve.burst_factor`): the arrival stream is derived from
//!   `cfg.seed` + the `serve.*` config keys, all of which are part of
//!   run identity — so serve cells ride the ordinary [`Cell`] batch
//!   machinery (dedup, `--jobs` fan-out, the content-addressed result
//!   cache, `--obs`) unchanged.
//! * **Trace-derived arrivals** (`--arrival-trace <file>`: one
//!   inter-arrival gap in µs per line): the gap list lives outside the
//!   config, hence outside the [`RunKey`](crate::exec::key::RunKey)
//!   fingerprint — these runs bypass the cache and execute directly,
//!   like `pcstall simulate`.
//!
//! Load and deadline axes sweep through the ordinary plan grammar
//! (`[axis] serve.arrival_rate = [..]` etc.; see the `serve_load`
//! preset), not through this single-point driver.

use std::path::PathBuf;

use crate::config::registry::canonical_f64;
use crate::config::SimConfig;
use crate::dvfs::manager::{DvfsManager, Policy, RunMode};
use crate::dvfs::objective::Objective;
use crate::stats::emit::CsvTable;
use crate::stats::RunResult;
use crate::workloads::WorkloadSource;

use super::evaluation::{completion, run_cells, Cell};
use super::ExpOptions;

/// One `pcstall serve` invocation: a workload under an arrival process,
/// compared across policies at a single operating point.
#[derive(Debug, Clone)]
pub struct ServeSpec {
    /// Workload spec (catalog name, `trace:<path>`, `synth:<seed>`).
    pub workload: String,
    /// Policies compared side by side (one CSV row each).
    pub policies: Vec<Policy>,
    /// DVFS objective for every policy (default: `deadline`).
    pub objective: Objective,
    /// `--arrival-trace`: explicit inter-arrival gaps (µs), cycled if
    /// shorter than the launch count.  `None` = synthetic arrivals.
    pub arrival_gaps_us: Option<Vec<f64>>,
}

/// The serve run mode at this epoch length: same epoch-scaled safety
/// cap as a completion run (the serve loop stops early once the stream
/// drains, exactly like completion mode stops at `workload_done`).
pub fn serve_mode(epoch_ns: f64) -> RunMode {
    match completion(epoch_ns) {
        RunMode::Completion { max_epochs } => RunMode::Serve { max_epochs },
        _ => unreachable!("completion() always yields RunMode::Completion"),
    }
}

/// Column schema of `serve.csv` — one row per policy.  Order and
/// formatting are stable (CI `cmp`-gates rerun determinism on the
/// bytes).
pub const SERVE_HEADER: [&str; 16] = [
    "workload",
    "policy",
    "objective",
    "arrival_per_us",
    "deadline_us",
    "burst_factor",
    "launches",
    "completed",
    "p50_us",
    "p99_us",
    "mean_us",
    "miss_rate",
    "throughput_per_ms",
    "queue_depth",
    "energy_j",
    "time_ms",
];

fn serve_row(cfg: &SimConfig, spec: &ServeSpec, policy: Policy, r: &RunResult) -> Vec<String> {
    let mut row = vec![
        spec.workload.clone(),
        policy.name(),
        spec.objective.name(),
        canonical_f64(cfg.serve.arrival_rate),
        canonical_f64(cfg.serve.deadline_us),
        canonical_f64(cfg.serve.burst_factor),
    ];
    match &r.serve {
        Some(s) => row.extend([
            s.launches.to_string(),
            s.completed_launches.to_string(),
            format!("{:.3}", s.p50_us),
            format!("{:.3}", s.p99_us),
            format!("{:.3}", s.mean_latency_us),
            format!("{:.4}", s.deadline_miss_rate),
            format!("{:.4}", s.throughput_per_ms),
            format!("{:.3}", s.mean_queue_depth),
        ]),
        None => row.extend(std::iter::repeat("-".to_string()).take(8)),
    }
    row.extend([
        format!("{:.4e}", r.total_energy_j),
        format!("{:.4}", r.total_time_ns / 1e6),
    ]);
    row
}

/// Run one serve operating point and emit `<out>/serve.csv` (one row
/// per policy, [`SERVE_HEADER`] schema).  `cfg` is the fully-overridden
/// simulator config (`--set serve.arrival_rate=0.05` etc. already
/// applied); returns the written CSV path.
pub fn run_serve(opts: &ExpOptions, cfg: SimConfig, spec: &ServeSpec) -> anyhow::Result<PathBuf> {
    anyhow::ensure!(!spec.policies.is_empty(), "serve needs at least one --policy");
    let mode = serve_mode(cfg.dvfs.epoch_ns);
    let source = WorkloadSource::parse(&spec.workload)?;
    // trace sources carry their recorded geometry (run_cells normalizes
    // their waves the same way)
    let waves = match &source {
        WorkloadSource::Catalog(_) => opts.waves_scale(),
        _ => 1.0,
    };

    let results: Vec<RunResult> = match &spec.arrival_gaps_us {
        // Synthetic arrivals: identity-complete, so ride the engine
        // (cache + dedup + --jobs + --obs).
        None => {
            let cells: Vec<Cell> = spec
                .policies
                .iter()
                .map(|&p| {
                    Cell::with_cfg(cfg.clone(), &spec.workload, p, spec.objective, mode, waves)
                })
                .collect();
            run_cells(opts, cells)?
        }
        // Trace-derived arrivals: the gap list is not part of the
        // RunKey, so never cache these — execute directly.
        Some(gaps) => {
            anyhow::ensure!(
                !gaps.is_empty(),
                "--arrival-trace: no inter-arrival gaps (need one µs value per line)"
            );
            let resolved = source.resolve()?;
            let (launches, rounds) = resolved.lower(waves);
            spec.policies
                .iter()
                .map(|&p| {
                    let mut mgr = DvfsManager::from_launches(
                        cfg.clone(),
                        launches.clone(),
                        rounds,
                        p,
                        spec.objective,
                    );
                    mgr.set_arrival_gaps(Some(gaps.clone()));
                    mgr.run(mode, &resolved.display)
                })
                .collect()
        }
    };

    let mut table = CsvTable::new(&SERVE_HEADER);
    for (&policy, r) in spec.policies.iter().zip(&results) {
        table.push(serve_row(&cfg, spec, policy, r));
    }
    let title = format!(
        "serve {}: {} launches at {}/µs, deadline {} µs",
        spec.workload,
        cfg.serve.launches,
        canonical_f64(cfg.serve.arrival_rate),
        canonical_f64(cfg.serve.deadline_us),
    );
    opts.emit("serve", &title, &table);
    Ok(opts.out_dir.join("serve.csv"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::Scale;

    fn tmp_out(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("pcstall_serve_{tag}_{}", std::process::id()));
        let _ = std::fs::create_dir_all(&dir);
        dir
    }

    fn quick_opts(out: PathBuf) -> ExpOptions {
        ExpOptions {
            scale: Scale::Quick,
            out_dir: out,
            ..Default::default()
        }
    }

    fn small_spec() -> (SimConfig, ServeSpec) {
        let mut cfg = ExpOptions {
            scale: Scale::Quick,
            ..Default::default()
        }
        .base_cfg();
        cfg.serve.launches = 2;
        cfg.serve.arrival_rate = 0.05;
        let spec = ServeSpec {
            workload: "comd".into(),
            policies: vec![Policy::Reactive(crate::models::EstModel::Crisp), Policy::PcStall],
            objective: Objective::Deadline,
            arrival_gaps_us: None,
        };
        (cfg, spec)
    }

    #[test]
    fn serve_mode_carries_the_completion_cap() {
        let RunMode::Serve { max_epochs } = serve_mode(1000.0) else {
            panic!("serve_mode must be Serve")
        };
        let RunMode::Completion { max_epochs: cap } = completion(1000.0) else {
            unreachable!()
        };
        assert_eq!(max_epochs, cap, "same epoch-scaled safety cap as completion runs");
    }

    #[test]
    fn serve_csv_has_one_row_per_policy_and_stable_bytes() {
        let (cfg, spec) = small_spec();
        let out_a = tmp_out("a");
        let out_b = tmp_out("b");
        let path_a = run_serve(&quick_opts(out_a.clone()), cfg.clone(), &spec).unwrap();
        let path_b = run_serve(&quick_opts(out_b.clone()), cfg, &spec).unwrap();
        let a = std::fs::read(&path_a).unwrap();
        let b = std::fs::read(&path_b).unwrap();
        assert_eq!(a, b, "serve.csv must be byte-identical across reruns");
        let text = String::from_utf8(a).unwrap();
        let mut lines = text.lines();
        assert_eq!(lines.next().unwrap(), SERVE_HEADER.join(","));
        let rows: Vec<&str> = lines.collect();
        assert_eq!(rows.len(), 2, "one row per policy");
        assert!(rows[0].starts_with("comd,CRISP,DEADLINE,"));
        assert!(rows[1].starts_with("comd,PCSTALL,DEADLINE,"));
        let _ = std::fs::remove_dir_all(&out_a);
        let _ = std::fs::remove_dir_all(&out_b);
    }

    #[test]
    fn trace_derived_arrivals_run_uncached_and_complete() {
        let (cfg, mut spec) = small_spec();
        spec.policies = vec![Policy::PcStall];
        spec.arrival_gaps_us = Some(vec![5.0, 15.0]);
        let out = tmp_out("gaps");
        let path = run_serve(&quick_opts(out.clone()), cfg, &spec).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let row = text.lines().nth(1).expect("one data row");
        let cols: Vec<&str> = row.split(',').collect();
        assert_eq!(cols.len(), SERVE_HEADER.len());
        // launches offered == completed for a tiny stream
        assert_eq!(cols[6], "2");
        assert_eq!(cols[7], "2");
        let _ = std::fs::remove_dir_all(&out);
    }

    #[test]
    fn empty_policies_or_gaps_error() {
        let (cfg, mut spec) = small_spec();
        spec.policies.clear();
        let out = tmp_out("err");
        assert!(run_serve(&quick_opts(out.clone()), cfg.clone(), &spec).is_err());
        let (_, mut spec) = small_spec();
        spec.arrival_gaps_us = Some(Vec::new());
        assert!(run_serve(&quick_opts(out.clone()), cfg, &spec).is_err());
        let _ = std::fs::remove_dir_all(&out);
    }
}
