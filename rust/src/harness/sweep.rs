//! Declarative sweep plans: N-dimensional experiment grids compiled onto
//! the sweep-execution engine, with deterministic cross-machine sharding.
//!
//! The paper's headline results are *sweeps* — ED²P improvement vs DVFS
//! epoch length (Fig. 1a/14) and vs V/f-domain granularity (Fig. 18b).
//! Instead of hard-coding one figure per grid, a [`SweepPlan`] declares
//! the axes —
//!
//! * **epoch length** (`epoch_ns`),
//! * **V/f-domain granularity** (`cus_per_domain`),
//! * **workload source** (any [`WorkloadSource`] spec: catalog name,
//!   `trace:<path>`, `synth:<seed>`, `exec:<kernel>:<size>`),
//! * **synth-seed population** (`seed`: expands the bare `synth`
//!   workload template into one `synth:<seed>` source per seed),
//! * **objective** (`edp` / `ed2p` / `energy@<pct>` / `deadline`),
//! * **predictor design** (any [`Policy`]),
//! * **any config key** (`[axis]`: every key in the typed registry,
//!   [`crate::config::registry`], can be swept as a grid dimension —
//!   e.g. `dvfs.transition_ns` for transition-latency sensitivity),
//!
//! — and compiles their cross product into the existing [`Cell`] /
//! [`RunKey`] batch machinery: one baseline + one design cell per grid
//! point, deduplicated and served by the content-addressed result cache
//! exactly like the hard-coded figures.
//!
//! ## Sharding
//!
//! `pcstall sweep <plan> --shard i/N` partitions the grid by each
//! point's *baseline* [`RunKey`] fingerprint ([`RunKey::shard_of`]):
//! every shard derives the same global assignment independently, so
//! shards are disjoint, cache-compatible with unsharded runs, and
//! mergeable — and because all rows sharing a baseline colocate, no
//! baseline simulation is ever duplicated across machines.  A
//! shard writes `sweep_<name>.part<i>of<N>.csv` — the final rows plus a
//! leading global `row` index — and [`merge_dir`] recombines a complete
//! part set into `sweep_<name>.csv`, byte-identical to an unsharded run.
//!
//! ## Plan grammar (TOML subset, see [`crate::config::minitoml`])
//!
//! ```toml
//! name = "my_sweep"                      # default: file stem
//! epoch_ns = [1000, 10000, 50000]        # default: EPOCH_LENS_NS
//! cus_per_domain = [1, 2, 4]             # default: doubling_axis(n_cu)
//! workloads = ["comd", "synth:7"]        # default: the scale's sweep set
//! workloads_add = ["synth:7"]            # or: scale set + extras
//! seed = [2, 3, 5]                       # synth-seed population axis
//! designs = ["crisp", "pcstall"]         # default: crisp, pcstall, oracle
//! objectives = ["ed2p", "energy@5"]      # default: ed2p
//! baseline = "static:1.7"                # improvement reference
//! epochs = 40                            # fixed-epoch mode; default: completion
//! mode = "serve"                         # continuous-arrival serve cells
//! [set]                                  # config overrides for every cell
//! gpu.n_wf = 16                          # (grid axes override [set] keys)
//! [axis]                                 # config-key grid dimensions
//! "dvfs.transition_ns" = [5, 20, 100, 1000]   # quoted or bare keys
//! ```
//!
//! ## Config axes (`[axis]`)
//!
//! Each `[axis]` entry turns one registry key into a grid dimension:
//! the key is validated against [`crate::config::registry::key_schema`]
//! at parse time (unknown key, wrong-kind value, empty or duplicate
//! value lists are errors, as is a key that also appears under `[set]`),
//! values are *canonicalized* (`5` and `5.0` for an f64 key are one
//! identity), and the CSV grows one column per axis, named by the key,
//! between the coordinate and metric columns ([`sweep_header`]).  Cache
//! and shard identity need no special casing: the axis value is applied
//! to the cell's config before its [`RunKey`] is computed, so the config
//! fingerprint covers it — canonically, because equal post-apply configs
//! serialize identically regardless of how the plan spelled the value.
//!
//! ## Seed populations
//!
//! `seed = [..]` turns the grid into a *population* sweep: each grid
//! point carries a seed coordinate, the workload axis must consist of
//! bare `synth` templates (each point resolves `synth:<seed>`), and the
//! CSV grows a `seed` column (`-` for plans without the axis).  Because
//! every seed synthesizes a distinct trace, each seed's cells get their
//! own content-hashed workload id — per-seed [`RunKey`] fingerprints —
//! so seed-axis shards stay disjoint and cache-compatible exactly like
//! every other axis.  `pcstall sweep plot` ([`crate::stats::plot`])
//! aggregates the merged CSV over the population (mean ± min/max band).
//!
//! ## Serve plans (`mode = "serve"`)
//!
//! A serve plan runs every cell through the continuous-arrival loop
//! ([`crate::harness::serve`]): the workload becomes a launch *stream*
//! under the seeded arrival process configured by the `serve.*` config
//! keys, which — being ordinary registry keys — sweep as `[axis]`
//! dimensions (`serve.arrival_rate` for offered load,
//! `serve.deadline_us` for the latency target, `serve.burst_factor`
//! for burstiness).  The CSV appends the [`SERVE_COLS`] latency tail
//! (`p50_us`, `p99_us`, `miss_rate`) after the base metrics; batch-plan
//! CSVs are byte-unchanged.  See the `serve_load` preset.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::config::minitoml::{self, Value};
use crate::dvfs::manager::{Policy, RunMode};
use crate::dvfs::objective::Objective;
use crate::exec::key::RunKey;
use crate::exec::ShardSpec;
use crate::power::params::F_STATIC_IDX;
use crate::stats::emit::{print_table, CsvTable, Json};
use crate::stats::RunResult;
use crate::workloads::{ResolvedWorkload, WorkloadSource};

use super::evaluation::{cell_key, completion, run_cells_resolved, Cell};
use super::ExpOptions;

/// The paper's canonical epoch-duration axis (Figs. 1a/1b/17): 1 µs to
/// 100 µs.  Single source of truth — the figure grids and the sweep
/// presets consume this constant, so their axes cannot drift apart.
pub const EPOCH_LENS_NS: [f64; 4] = [1_000.0, 10_000.0, 50_000.0, 100_000.0];

/// Power-of-two axis `1, 2, 4, … <= max` (domain-granularity sweeps).
pub fn doubling_axis(max: usize) -> Vec<usize> {
    let mut axis = vec![1usize];
    while axis.last().unwrap() * 2 <= max {
        let next = axis.last().unwrap() * 2;
        axis.push(next);
    }
    axis
}

/// One config-key grid dimension (`[axis]` plan table): a registry key
/// plus the value list it sweeps over.
#[derive(Debug, Clone, PartialEq)]
pub struct ConfigAxis {
    /// Registry key path (e.g. `dvfs.transition_ns`).
    pub key: String,
    /// Parsed values, in plan order (applied via
    /// [`crate::config::SimConfig::set_key`]).
    pub values: Vec<Value>,
    /// Canonical rendering of each value, aligned with `values` —
    /// the CSV cell text ([`crate::config::registry::KeyDesc::canonicalize`]).
    pub canon: Vec<String>,
}

impl ConfigAxis {
    /// Validate a raw `(key, values)` pair against the config-key
    /// registry: the key must exist (and not shadow a dedicated plan
    /// axis), every value must parse under the key's kind, and the
    /// canonicalized values must be non-empty and distinct.
    pub fn new(key: &str, values: &[Value]) -> anyhow::Result<ConfigAxis> {
        let desc = crate::config::registry::key_schema().lookup(key).ok_or_else(|| {
            anyhow::anyhow!(
                "[axis] {key}: not a config key (run `pcstall config keys` for the \
                 sweepable set)"
            )
        })?;
        match key {
            "dvfs.epoch_ns" => anyhow::bail!(
                "[axis] dvfs.epoch_ns: the epoch length has a dedicated plan axis — \
                 use `epoch_ns = [..]` at the top level"
            ),
            "dvfs.cus_per_domain" => anyhow::bail!(
                "[axis] dvfs.cus_per_domain: the domain granularity has a dedicated \
                 plan axis — use `cus_per_domain = [..]` at the top level"
            ),
            "seed" => anyhow::bail!(
                "[axis] seed: use the plan-level `seed = [..]` synth-population axis, \
                 or `[set] seed = <n>` for a scalar master-seed override"
            ),
            "gpu.sim_threads" => anyhow::bail!(
                "[axis] gpu.sim_threads: the CU-stepping thread count is execution-only \
                 and excluded from run identity, so every grid value would alias one \
                 cached result — use `--sim-threads <n>` on the sweep invocation instead"
            ),
            _ => {}
        }
        anyhow::ensure!(!values.is_empty(), "[axis] {key}: value list must not be empty");
        let mut canon: Vec<String> = Vec::with_capacity(values.len());
        for v in values {
            let c = desc
                .canonicalize(v)
                .map_err(|e| anyhow::anyhow!("[axis] {key}: {e}"))?;
            anyhow::ensure!(
                !canon.contains(&c),
                "[axis] {key}: duplicate value {c} (each axis value may appear once)"
            );
            canon.push(c);
        }
        Ok(ConfigAxis {
            key: key.to_string(),
            values: values.to_vec(),
            canon,
        })
    }
}

/// The workload-source axis of a plan.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadAxis {
    /// The scale's sweep workload set ([`ExpOptions::sweep_workloads`]).
    Scale,
    /// The scale set plus extra specs (synth/trace sources riding along
    /// with the catalog subset of whatever `--quick`/`--full` selects).
    ScalePlus(Vec<String>),
    /// An explicit spec list, independent of scale.
    Explicit(Vec<String>),
}

/// A declarative sweep grid.  Empty axis vectors mean "use the default
/// axis for the active scale" (resolved in [`SweepPlan::compile`]).
#[derive(Debug, Clone)]
pub struct SweepPlan {
    pub name: String,
    /// Epoch-length axis in ns; empty → [`EPOCH_LENS_NS`].
    pub epoch_ns: Vec<f64>,
    /// Domain-granularity axis; empty → `doubling_axis(n_cu)`.
    pub cus_per_domain: Vec<usize>,
    pub workloads: WorkloadAxis,
    /// Synth-seed population axis; empty → no seed dimension.  When
    /// non-empty, every workload spec must be the bare `synth` template
    /// (each grid point resolves `synth:<seed>`); duplicates are
    /// rejected at parse time.
    pub seeds: Vec<u64>,
    pub designs: Vec<Policy>,
    pub objectives: Vec<Objective>,
    /// Reference policy for the improvement columns.
    pub baseline: Policy,
    /// `Some(n)`: run exactly `n` epochs; `None`: run to completion
    /// (with the standard epoch-scaled safety cap).
    pub epochs: Option<u64>,
    /// `mode = "serve"`: every cell runs the continuous-arrival serve
    /// loop ([`RunMode::Serve`]) instead of a single fixed-work pass,
    /// and the CSV grows the [`SERVE_COLS`] latency tail.  The `serve.*`
    /// config keys (offered load, deadline, burstiness) then make
    /// natural `[set]`/`[axis]` entries.
    pub serve: bool,
    /// `[set]` config overrides applied to every cell before the grid
    /// axes (axes win on conflict).
    pub overrides: Vec<(String, Value)>,
    /// `[axis]` config-key grid dimensions, in plan order (the first
    /// axis is the outermost loop of the compiled grid).
    pub config_axes: Vec<ConfigAxis>,
}

impl Default for SweepPlan {
    fn default() -> Self {
        SweepPlan {
            name: "sweep".into(),
            epoch_ns: Vec::new(),
            cus_per_domain: Vec::new(),
            workloads: WorkloadAxis::Scale,
            seeds: Vec::new(),
            designs: vec![
                Policy::Reactive(crate::models::EstModel::Crisp),
                Policy::PcStall,
                Policy::Oracle,
            ],
            objectives: vec![Objective::Ed2p],
            baseline: Policy::Static(F_STATIC_IDX),
            epochs: None,
            serve: false,
            overrides: Vec::new(),
            config_axes: Vec::new(),
        }
    }
}

/// Names of the built-in plans (`pcstall sweep <preset>`).
pub fn preset_names() -> Vec<&'static str> {
    vec![
        "epoch_x_granularity",
        "epoch_sweep",
        "granularity_sweep",
        "seed_population",
        "transition_latency",
        "serve_load",
    ]
}

impl SweepPlan {
    /// A built-in plan by name.
    pub fn preset(name: &str) -> Option<SweepPlan> {
        match name {
            // The fig1a × fig18b cross figure, over both catalog and
            // synthesized workload sources: every epoch length at every
            // domain granularity.  The two synth seeds are arbitrary but
            // fixed — they are part of the figure's identity.
            "epoch_x_granularity" => Some(SweepPlan {
                name: name.into(),
                workloads: WorkloadAxis::ScalePlus(vec!["synth:11".into(), "synth:23".into()]),
                ..SweepPlan::default()
            }),
            // fig1a's grid as an open plan (granularity pinned at 1).
            "epoch_sweep" => Some(SweepPlan {
                name: name.into(),
                cus_per_domain: vec![1],
                ..SweepPlan::default()
            }),
            // fig18b's axis family as an open plan (epoch pinned at
            // 1 µs).  Note the default axis runs to a whole-GPU single
            // domain (n_cu), one point past fig18b's n_cu/2 cap; the
            // shared points reuse fig18b's cache entries.
            "granularity_sweep" => Some(SweepPlan {
                name: name.into(),
                epoch_ns: vec![1_000.0],
                ..SweepPlan::default()
            }),
            // The ROADMAP's PCSTALL-accuracy-over-seeds figure: the
            // paper's headline accuracy claim is a population statistic,
            // so sweep a population of synthesized workloads (six fixed
            // seeds — part of the figure's identity, like the cross
            // preset's) along the epoch axis and aggregate with
            // `pcstall sweep plot` (mean ± min/max band over seeds).
            // Fixed-epoch mode keeps every (epoch, seed) point the same
            // statistical length, so the bands compare like for like.
            "seed_population" => Some(SweepPlan {
                name: name.into(),
                cus_per_domain: vec![1],
                workloads: WorkloadAxis::Explicit(vec!["synth".into()]),
                seeds: vec![2, 3, 5, 7, 11, 13],
                designs: vec![
                    Policy::Reactive(crate::models::EstModel::Crisp),
                    Policy::PcStall,
                ],
                epochs: Some(24),
                ..SweepPlan::default()
            }),
            // The ROADMAP's named next figure: DVFS transition-latency
            // sensitivity.  The paper's headline contrast (32% power
            // efficiency at 1 µs vs 19% ED²P at 50 µs) assumes the V/f
            // transition cost scales with the epoch regime (4 ns at
            // 1 µs … 400 ns at 100 µs); this plan sweeps the latency
            // *explicitly* — ns through µs — against the full epoch
            // axis via a `dvfs.transition_ns` config axis, crisp vs
            // pcstall vs oracle, over one catalog and one synth source.
            // Fixed-epoch mode keeps every point the same statistical
            // length across the latency regimes.
            "transition_latency" => Some(SweepPlan {
                name: name.into(),
                cus_per_domain: vec![1],
                workloads: WorkloadAxis::Explicit(vec!["comd".into(), "synth:11".into()]),
                config_axes: vec![ConfigAxis::new(
                    "dvfs.transition_ns",
                    &[Value::Int(5), Value::Int(20), Value::Int(100), Value::Int(1000)],
                )
                .expect("preset axis is registry-valid")],
                epochs: Some(24),
                ..SweepPlan::default()
            }),
            // The serve-mode headline: energy saved at a fixed p99
            // target across offered-load levels.  One workload under a
            // seeded Poisson arrival stream, the offered load swept as a
            // `serve.arrival_rate` config axis (launches per µs, from
            // light load to past saturation at quick scale), crisp vs
            // pcstall vs oracle under the deadline objective.  Plot with
            // `pcstall sweep plot --metric p99_us` (or miss_rate /
            // energy_j) from the merged CSV.
            "serve_load" => Some(SweepPlan {
                name: name.into(),
                epoch_ns: vec![1_000.0],
                cus_per_domain: vec![1],
                workloads: WorkloadAxis::Explicit(vec!["comd".into()]),
                objectives: vec![Objective::Deadline],
                serve: true,
                config_axes: vec![ConfigAxis::new(
                    "serve.arrival_rate",
                    &[
                        Value::Float(0.005),
                        Value::Float(0.01),
                        Value::Float(0.02),
                        Value::Float(0.04),
                    ],
                )
                .expect("preset axis is registry-valid")],
                ..SweepPlan::default()
            }),
            _ => None,
        }
    }

    /// Load a plan: preset name, or path to a plan TOML file.
    pub fn load(spec: &str) -> anyhow::Result<SweepPlan> {
        if let Some(p) = SweepPlan::preset(spec) {
            return Ok(p);
        }
        let path = Path::new(spec);
        let text = std::fs::read_to_string(path).map_err(|e| {
            anyhow::anyhow!(
                "'{spec}' is not a preset ({}) and not a readable plan file: {e}",
                preset_names().join(", ")
            )
        })?;
        let stem = path
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("sweep")
            .to_string();
        let mut plan = SweepPlan::from_toml(&text)
            .map_err(|e| anyhow::anyhow!("plan {}: {e}", path.display()))?;
        if plan.name == "sweep" {
            plan.name = sanitize_name(&stem);
        }
        Ok(plan)
    }

    /// Parse the plan grammar (see the module docs).
    pub fn from_toml(text: &str) -> anyhow::Result<SweepPlan> {
        let mut plan = SweepPlan::default();
        let mut explicit: Option<Vec<String>> = None;
        let mut add: Option<Vec<String>> = None;
        for (key, value) in minitoml::parse(text)? {
            match key.as_str() {
                "name" => {
                    let s = value
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("name must be a string"))?;
                    anyhow::ensure!(!s.is_empty(), "name must not be empty");
                    plan.name = sanitize_name(s);
                }
                "epoch_ns" => {
                    plan.epoch_ns = float_axis(&value, "epoch_ns")?;
                    anyhow::ensure!(
                        plan.epoch_ns.iter().all(|e| *e > 0.0),
                        "epoch_ns values must be positive"
                    );
                }
                "cus_per_domain" => {
                    plan.cus_per_domain = float_axis(&value, "cus_per_domain")?
                        .into_iter()
                        .map(|g| {
                            anyhow::ensure!(
                                g >= 1.0 && g.fract() == 0.0,
                                "cus_per_domain values must be positive integers"
                            );
                            Ok(g as usize)
                        })
                        .collect::<anyhow::Result<_>>()?;
                }
                "workloads" => explicit = Some(string_axis(&value, "workloads")?),
                "workloads_add" => add = Some(string_axis(&value, "workloads_add")?),
                "seed" => {
                    let items = value.as_arr().ok_or_else(|| {
                        anyhow::anyhow!(
                            "seed must be an array of integer seeds (e.g. seed = [2, 3, 5]); \
                             for the simulator master seed use [set] seed = <n>"
                        )
                    })?;
                    anyhow::ensure!(!items.is_empty(), "seed must not be empty");
                    let mut seeds: Vec<u64> = Vec::with_capacity(items.len());
                    for v in items {
                        let s = v.as_int().filter(|s| *s >= 0).ok_or_else(|| {
                            anyhow::anyhow!("seed: expected a non-negative integer, got {v:?}")
                        })?;
                        anyhow::ensure!(
                            !seeds.contains(&(s as u64)),
                            "seed: duplicate seed {s} (each synth seed may appear once)"
                        );
                        seeds.push(s as u64);
                    }
                    plan.seeds = seeds;
                }
                "designs" => {
                    plan.designs = string_axis(&value, "designs")?
                        .iter()
                        .map(|s| Policy::parse(s))
                        .collect::<anyhow::Result<_>>()?;
                }
                "objectives" => {
                    plan.objectives = string_axis(&value, "objectives")?
                        .iter()
                        .map(|s| Objective::parse(s))
                        .collect::<anyhow::Result<_>>()?;
                }
                "baseline" => {
                    plan.baseline = Policy::parse(
                        value
                            .as_str()
                            .ok_or_else(|| anyhow::anyhow!("baseline must be a policy string"))?,
                    )?;
                }
                "epochs" => {
                    let n = value
                        .as_int()
                        .ok_or_else(|| anyhow::anyhow!("epochs must be an integer"))?;
                    anyhow::ensure!(n > 0, "epochs must be positive");
                    plan.epochs = Some(n as u64);
                }
                "mode" => {
                    let s = value.as_str().ok_or_else(|| {
                        anyhow::anyhow!("mode must be a string (\"batch\" or \"serve\")")
                    })?;
                    plan.serve = match s {
                        "batch" => false,
                        "serve" => true,
                        other => anyhow::bail!(
                            "mode must be \"batch\" or \"serve\", got \"{other}\""
                        ),
                    };
                }
                _ => {
                    if let Some(cfg_key) = key.strip_prefix("axis.") {
                        let items = value.as_arr().ok_or_else(|| {
                            anyhow::anyhow!(
                                "[axis] {cfg_key}: must be an array of values \
                                 (e.g. {cfg_key} = [5, 20, 100])"
                            )
                        })?;
                        anyhow::ensure!(
                            !plan.config_axes.iter().any(|a| a.key == cfg_key),
                            "[axis] {cfg_key}: declared twice (each config key may be \
                             one grid dimension)"
                        );
                        plan.config_axes.push(ConfigAxis::new(cfg_key, items)?);
                    } else if let Some(cfg_key) = key.strip_prefix("set.") {
                        anyhow::ensure!(
                            cfg_key != "seed" || value.as_arr().is_none(),
                            "seed = [..] is a plan-level axis and must appear above [set] \
                             (inside [set], 'seed' is the scalar simulator master-seed \
                             override)"
                        );
                        plan.overrides.push((cfg_key.to_string(), value));
                    } else {
                        anyhow::bail!(
                            "unknown plan key '{key}' (axes: epoch_ns, cus_per_domain, \
                             workloads, workloads_add, seed, designs, objectives; scalars: \
                             name, baseline, epochs, mode; config overrides go under [set], \
                             config-key grid dimensions under [axis])"
                        );
                    }
                }
            }
        }
        match (explicit, add) {
            (Some(_), Some(_)) => {
                anyhow::bail!("'workloads' and 'workloads_add' are mutually exclusive")
            }
            (Some(w), None) => {
                anyhow::ensure!(!w.is_empty(), "workloads must not be empty");
                plan.workloads = WorkloadAxis::Explicit(w);
            }
            (None, Some(w)) => plan.workloads = WorkloadAxis::ScalePlus(w),
            (None, None) => {}
        }
        anyhow::ensure!(!plan.designs.is_empty(), "designs must not be empty");
        anyhow::ensure!(!plan.objectives.is_empty(), "objectives must not be empty");
        // a key that is both a scalar override and a grid dimension used
        // to be silently last-writer-wins at the override seam — make the
        // ambiguity a parse error naming both sites
        for axis in &plan.config_axes {
            anyhow::ensure!(
                !plan.overrides.iter().any(|(k, _)| *k == axis.key),
                "config key '{0}' appears under both [set] ('[set] {0} = <value>', a \
                 scalar override) and [axis] ('[axis] {0} = [..]', a grid dimension) — \
                 drop one of the two",
                axis.key
            );
        }
        Ok(plan)
    }

    /// Human-readable axis summary, derived from the plan itself (the
    /// `pcstall sweep list` renderer — presets can never drift from
    /// their descriptions because there is no hand-written description).
    pub fn describe(&self) -> Vec<String> {
        let mut out = Vec::new();
        out.push(match &self.epoch_ns[..] {
            [] => format!("epoch_ns: paper axis {EPOCH_LENS_NS:?}"),
            v => format!("epoch_ns: {v:?}"),
        });
        out.push(match &self.cus_per_domain[..] {
            [] => "cus_per_domain: 1, 2, 4, ... up to the GPU's n_cu".to_string(),
            v => format!("cus_per_domain: {v:?}"),
        });
        out.push(match &self.workloads {
            WorkloadAxis::Scale => "workloads: the scale's sweep set".to_string(),
            WorkloadAxis::ScalePlus(extra) => {
                format!("workloads: the scale's sweep set + {extra:?}")
            }
            WorkloadAxis::Explicit(w) => format!("workloads: {w:?}"),
        });
        if !self.seeds.is_empty() {
            out.push(format!("seed population: {:?}", self.seeds));
        }
        for axis in &self.config_axes {
            out.push(format!("axis {}: [{}]", axis.key, axis.canon.join(", ")));
        }
        let names = |ps: &[Policy]| ps.iter().map(|p| p.name()).collect::<Vec<_>>().join(", ");
        out.push(format!("designs: {}", names(&self.designs)));
        out.push(format!(
            "objectives: {}",
            self.objectives.iter().map(|o| o.name()).collect::<Vec<_>>().join(", ")
        ));
        out.push(format!("baseline: {}", self.baseline.name()));
        if self.serve {
            out.push("mode: serve (continuous arrivals, p50/p99/miss columns)".to_string());
        }
        out.push(match (self.serve, self.epochs) {
            (_, Some(n)) => format!("epochs: {n} (fixed)"),
            (false, None) => "epochs: run to completion".to_string(),
            (true, None) => "epochs: run until the arrival stream drains".to_string(),
        });
        out
    }

    /// The workload spec list this plan runs under `opts` (the CLI
    /// `--workload` override, when present, replaces the axis entirely —
    /// same contract as the hard-coded figures).
    fn workload_specs(&self, opts: &ExpOptions) -> Vec<String> {
        if !opts.workloads_override.is_empty() {
            return opts
                .workloads_override
                .iter()
                .map(|s| s.to_string())
                .collect();
        }
        match &self.workloads {
            WorkloadAxis::Scale => opts
                .sweep_workloads()
                .iter()
                .map(|s| s.to_string())
                .collect(),
            WorkloadAxis::ScalePlus(extra) => {
                let mut v: Vec<String> = opts
                    .sweep_workloads()
                    .iter()
                    .map(|s| s.to_string())
                    .collect();
                v.extend(extra.iter().cloned());
                v
            }
            WorkloadAxis::Explicit(w) => w.clone(),
        }
    }

    /// [`Self::workload_specs`] under the seed axis: with `seed = [..]`
    /// every spec must be the bare `synth` template (the axis supplies
    /// the seed), and a plan that left the workload axis defaulted gets
    /// `["synth"]` instead of the scale's catalog set.
    fn seeded_workload_specs(&self, opts: &ExpOptions) -> anyhow::Result<Vec<String>> {
        if self.seeds.is_empty() {
            return Ok(self.workload_specs(opts));
        }
        if opts.workloads_override.is_empty() && self.workloads == WorkloadAxis::Scale {
            return Ok(vec!["synth".into()]);
        }
        for wl in &self.workload_specs(opts) {
            anyhow::ensure!(
                !wl.starts_with("synth:"),
                "plan seed axis: workload '{wl}' pins its own seed — use the bare 'synth' \
                 template (the seed = [..] axis supplies the seed)"
            );
            anyhow::ensure!(
                wl == "synth",
                "plan seed axis: workload '{wl}' is not a synth source — seed = [..] \
                 expands only bare 'synth' templates (catalog, trace:, and exec: \
                 sources carry no seed)"
            );
        }
        // every entry validated to be the one template — collapse repeats
        // so `workloads = ["synth", "synth"]` cannot duplicate the grid
        Ok(vec!["synth".into()])
    }

    /// Compile the plan into a flat, deterministically-ordered grid.
    /// Workload specs are resolved (and trace files read + content-
    /// hashed) exactly once here and carried on the grid points, so the
    /// shard partition and the eventual execution are defined over the
    /// same workload content — a trace file changing on disk between
    /// compile and run cannot desynchronize them.
    pub fn compile(&self, opts: &ExpOptions) -> anyhow::Result<SweepGrid> {
        let epoch_axis: Vec<f64> = if self.epoch_ns.is_empty() {
            EPOCH_LENS_NS.to_vec()
        } else {
            self.epoch_ns.clone()
        };
        // Base config with the plan's `[set]` overrides applied — also
        // the config the *default* granularity axis must be derived
        // from (a plan overriding gpu.n_cu gets the axis of the GPU it
        // actually simulates).
        let mut proto_cfg = opts.base_cfg();
        for (key, value) in &self.overrides {
            proto_cfg
                .set_key(key, value)
                .map_err(|e| anyhow::anyhow!("plan [set] override {key}: {e}"))?;
        }
        let gran_axis: Vec<usize> = if self.cus_per_domain.is_empty() {
            doubling_axis(proto_cfg.gpu.n_cu)
        } else {
            self.cus_per_domain.clone()
        };
        // the default granularity axis is derived from one GPU shape; a
        // config axis varying that shape would desynchronize the two
        if self.cus_per_domain.is_empty() {
            anyhow::ensure!(
                self.config_axes.iter().all(|a| a.key != "gpu.n_cu"),
                "plan [axis] gpu.n_cu: give an explicit cus_per_domain axis (the default \
                 granularity axis would be derived from a single GPU shape)"
            );
        }
        let workloads = self.seeded_workload_specs(opts)?;
        anyhow::ensure!(!workloads.is_empty(), "plan has no workloads to run");
        // No seed axis: one degenerate coordinate so the nest below
        // stays a plain cross product.
        let seed_axis: Vec<Option<u64>> = if self.seeds.is_empty() {
            vec![None]
        } else {
            self.seeds.iter().map(|s| Some(*s)).collect()
        };
        // Config-axis value combinations, first axis outermost.  With no
        // `[axis]` table this is one empty combination and the grid (and
        // its CSV) is byte-identical to the closed-axis-set era.
        let combos = index_cross(
            &self.config_axes.iter().map(|a| a.values.len()).collect::<Vec<_>>(),
        );

        let mut resolved_memo: HashMap<String, Arc<ResolvedWorkload>> = HashMap::new();
        let mut points = Vec::new();
        for combo in &combos {
            let mut combo_cfg = proto_cfg.clone();
            let mut config_vals: Vec<String> = Vec::with_capacity(combo.len());
            for (axis, &vi) in self.config_axes.iter().zip(combo) {
                combo_cfg
                    .set_key(&axis.key, &axis.values[vi])
                    .map_err(|e| anyhow::anyhow!("plan [axis] {}: {e}", axis.key))?;
                config_vals.push(axis.canon[vi].clone());
            }
            for &epoch_ns in &epoch_axis {
                for &gran in &gran_axis {
                    for &objective in &self.objectives {
                        for &design in &self.designs {
                            for wl in &workloads {
                                for &seed in &seed_axis {
                                    // a seed coordinate instantiates the bare
                                    // `synth` template into a concrete source
                                    let spec = match seed {
                                        Some(s) => format!("synth:{s}"),
                                        None => wl.clone(),
                                    };
                                    let resolved = match resolved_memo.get(&spec) {
                                        Some(r) => r.clone(),
                                        None => {
                                            let r = Arc::new(
                                                WorkloadSource::parse(&spec)?.resolve()?,
                                            );
                                            resolved_memo.insert(spec.clone(), r.clone());
                                            r
                                        }
                                    };
                                    let mut cfg = combo_cfg.clone();
                                    cfg.dvfs.epoch_ns = epoch_ns;
                                    cfg.dvfs.cus_per_domain = gran;
                                    let mode = match (self.serve, self.epochs) {
                                        (false, Some(n)) => RunMode::Epochs(n),
                                        (false, None) => completion(epoch_ns),
                                        // serve plans always run the arrival
                                        // loop; `epochs` becomes the safety cap
                                        (true, Some(n)) => RunMode::Serve { max_epochs: n },
                                        (true, None) => super::serve::serve_mode(epoch_ns),
                                    };
                                    let waves = opts.waves_scale();
                                    let mut baseline_cell = Cell::with_cfg(
                                        cfg.clone(),
                                        &spec,
                                        self.baseline,
                                        objective,
                                        mode,
                                        waves,
                                    );
                                    let design_cell =
                                        Cell::with_cfg(cfg, &spec, design, objective, mode, waves);
                                    let shard_key =
                                        cell_key(opts, &mut baseline_cell, &resolved);
                                    points.push(SweepPoint {
                                        row: points.len(),
                                        epoch_ns,
                                        cus_per_domain: gran,
                                        workload: spec,
                                        seed,
                                        config: config_vals.clone(),
                                        design,
                                        objective,
                                        shard_key,
                                        baseline_cell,
                                        design_cell,
                                        resolved,
                                    });
                                }
                            }
                        }
                    }
                }
            }
        }
        Ok(SweepGrid {
            name: self.name.clone(),
            config_keys: self.config_axes.iter().map(|a| a.key.clone()).collect(),
            serve: self.serve,
            points,
        })
    }
}

/// Cross product of index ranges `0..lens[i]`, first range outermost;
/// `[[]]` (one empty combination) for no ranges.
fn index_cross(lens: &[usize]) -> Vec<Vec<usize>> {
    let mut out = vec![Vec::new()];
    for &len in lens {
        let mut next = Vec::with_capacity(out.len() * len);
        for prefix in &out {
            for i in 0..len {
                let mut combo = prefix.clone();
                combo.push(i);
                next.push(combo);
            }
        }
        out = next;
    }
    out
}

/// One fully-resolved grid point: a (baseline, design) cell pair plus
/// the row coordinates it renders to.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// Global row index in the full (unsharded) grid.
    pub row: usize,
    pub epoch_ns: f64,
    pub cus_per_domain: usize,
    /// Concrete workload spec (`synth:<seed>` for seed-axis points).
    pub workload: String,
    /// The seed coordinate, for plans with a `seed = [..]` axis.
    pub seed: Option<u64>,
    /// Canonical config-axis values, aligned with the grid's
    /// [`SweepGrid::config_keys`] (empty without an `[axis]` table).
    pub config: Vec<String>,
    pub design: Policy,
    pub objective: Objective,
    /// The *baseline* cell's fingerprint — the shard-partition domain.
    /// Partitioning on the shared baseline colocates every row of one
    /// (epoch, granularity, workload, objective) point on one shard, so
    /// a baseline simulation is never duplicated across machines.
    pub shard_key: RunKey,
    baseline_cell: Cell,
    design_cell: Cell,
    /// The workload resolved at compile time (trace content already
    /// read + hashed), shared by both cells at execution.
    resolved: Arc<ResolvedWorkload>,
}

/// A compiled plan: the flat grid in row order.
#[derive(Debug)]
pub struct SweepGrid {
    pub name: String,
    /// Config-axis key paths, in plan order — one CSV column each.
    pub config_keys: Vec<String>,
    /// `mode = "serve"` plans append the [`SERVE_COLS`] metric tail.
    pub serve: bool,
    pub points: Vec<SweepPoint>,
}

/// Base column schema of every sweep CSV (part files prepend a `row`
/// column; config-axis plans splice their key columns in — see
/// [`sweep_header`]).  `seed` is the population coordinate of a
/// `seed = [..]` plan, `-` for plans without the axis.
pub const SWEEP_HEADER: [&str; 11] = [
    "epoch_us",
    "cus_per_domain",
    "workload",
    "seed",
    "design",
    "objective",
    "improvement_pct",
    "norm",
    "energy_j",
    "time_ms",
    "accuracy",
];

/// Where config-axis columns are spliced into [`SWEEP_HEADER`]: after
/// the coordinate columns (`..objective`), before the metric columns
/// (`improvement_pct..`).
const CONFIG_COL_AT: usize = 6;

/// Extra metric columns of a `mode = "serve"` plan, appended *after*
/// the base metrics so batch-plan CSVs keep their exact historical
/// bytes.  `pcstall sweep plot --metric p99_us` selects them by name
/// like any other column.
pub const SERVE_COLS: [&str; 3] = ["p50_us", "p99_us", "miss_rate"];

/// The dynamic CSV schema for a grid with `config_keys` config axes —
/// one column per key, named by the key path.  With no config axes this
/// is exactly [`SWEEP_HEADER`] (plus the [`SERVE_COLS`] tail for serve
/// plans), so legacy plans emit byte-identical CSVs.
pub fn sweep_header(config_keys: &[String], serve: bool) -> Vec<String> {
    let mut header: Vec<String> =
        SWEEP_HEADER[..CONFIG_COL_AT].iter().map(|s| s.to_string()).collect();
    header.extend(config_keys.iter().cloned());
    header.extend(SWEEP_HEADER[CONFIG_COL_AT..].iter().map(|s| s.to_string()));
    if serve {
        header.extend(SERVE_COLS.iter().map(|s| s.to_string()));
    }
    header
}

/// The objective's scalar figure of merit (lower is better): ED^nP for
/// EDP/ED²P points, plain energy for energy-bound and deadline points
/// (the deadline objective's latency side is reported directly by the
/// serve columns, not folded into the merit scalar).
fn merit(objective: Objective, r: &RunResult) -> f64 {
    match objective {
        Objective::Edp => r.edp(),
        Objective::Ed2p => r.ed2p(),
        Objective::EnergyBound { .. } => r.total_energy_j,
        Objective::Deadline => r.total_energy_j,
    }
}

fn render_row(p: &SweepPoint, base: &RunResult, r: &RunResult, serve: bool) -> Vec<String> {
    let norm = merit(p.objective, r) / merit(p.objective, base);
    let mut row = vec![
        format!("{}", p.epoch_ns / 1000.0),
        p.cus_per_domain.to_string(),
        p.workload.clone(),
        match p.seed {
            Some(s) => s.to_string(),
            None => "-".into(),
        },
        p.design.name(),
        p.objective.name(),
    ];
    row.extend(p.config.iter().cloned());
    row.extend([
        format!("{:.2}", (1.0 - norm) * 100.0),
        format!("{:.4}", norm),
        format!("{:.4e}", r.total_energy_j),
        format!("{:.4}", r.total_time_ns / 1e6),
        format!("{:.3}", r.mean_accuracy),
    ]);
    if serve {
        match &r.serve {
            Some(s) => row.extend([
                format!("{:.3}", s.p50_us),
                format!("{:.3}", s.p99_us),
                format!("{:.4}", s.deadline_miss_rate),
            ]),
            None => row.extend(std::iter::repeat("-".to_string()).take(SERVE_COLS.len())),
        }
    }
    row
}

impl SweepGrid {
    /// This grid's CSV schema (see [`sweep_header`]).
    pub fn header(&self) -> Vec<String> {
        sweep_header(&self.config_keys, self.serve)
    }

    /// The subset of the grid a shard owns, in row order.
    pub fn shard_points(&self, shard: ShardSpec) -> Vec<&SweepPoint> {
        self.points
            .iter()
            .filter(|p| shard.owns(&p.shard_key))
            .collect()
    }

    /// Execute `points` (a subset of this grid) through the engine and
    /// render one `(global_row, cells)` per point.  Uses the workloads
    /// resolved at compile time — no spec is re-read here.
    pub fn execute(
        &self,
        opts: &ExpOptions,
        points: &[&SweepPoint],
    ) -> anyhow::Result<Vec<(usize, Vec<String>)>> {
        let mut cells = Vec::with_capacity(points.len() * 2);
        for p in points {
            cells.push((p.baseline_cell.clone(), p.resolved.clone()));
            cells.push((p.design_cell.clone(), p.resolved.clone()));
        }
        let results = run_cells_resolved(opts, cells);
        let mut out = Vec::with_capacity(points.len());
        for (p, pair) in points.iter().zip(results.chunks(2)) {
            out.push((p.row, render_row(p, &pair[0], &pair[1], self.serve)));
        }
        Ok(out)
    }
}

/// Run a plan (one shard of it, or all of it for `ShardSpec::whole()`)
/// and write the CSV.  Returns the written path.
///
/// Unsharded runs write the final `sweep_<name>.csv`.  Sharded runs
/// write `sweep_<name>.part<i>of<N>.csv` carrying a leading global
/// `row` column; [`merge_dir`] turns a complete part set into the final
/// CSV, byte-identical to the unsharded run.
pub fn run_sweep(
    opts: &ExpOptions,
    plan: &SweepPlan,
    shard: ShardSpec,
) -> anyhow::Result<PathBuf> {
    let grid = plan.compile(opts)?;
    let points = grid.shard_points(shard);
    println!(
        "[sweep {}] {} grid point(s){}",
        grid.name,
        grid.points.len(),
        if shard.count > 1 {
            format!(", shard {shard} owns {}", points.len())
        } else {
            String::new()
        }
    );
    let rows = grid.execute(opts, &points)?;

    let (id, table) = if shard.count > 1 {
        let mut header: Vec<String> = vec!["row".to_string()];
        header.extend(grid.header());
        let mut table = CsvTable::with_header(header);
        for (row, cells) in rows {
            let mut line = vec![row.to_string()];
            line.extend(cells);
            table.push(line);
        }
        (
            format!("sweep_{}.part{}of{}", grid.name, shard.index, shard.count),
            table,
        )
    } else {
        let mut table = CsvTable::with_header(grid.header());
        for (_, cells) in rows {
            table.push(cells);
        }
        (format!("sweep_{}", grid.name), table)
    };
    let title = format!(
        "sweep {}: {} (shard {shard})",
        grid.name,
        if shard.count > 1 { "partial grid" } else { "full grid" },
    );
    opts.emit(&id, &title, &table);
    if shard.count > 1 {
        // Part meta sidecar: per-shard execution accounting consumed by
        // the `sweep merge` summary table.  It rides *next to* the part
        // CSV (never inside it), so the merged CSV stays byte-identical
        // to an unsharded run; merges of part sets without sidecars
        // (older runs) still work, with `-` in the accounting columns.
        let c = opts.engine.cache_stats();
        let meta = Json::obj(vec![
            ("schema", Json::Num(1.0)),
            ("sweep", Json::Str(grid.name.clone())),
            ("part", Json::Num(shard.index as f64)),
            ("of", Json::Num(shard.count as f64)),
            ("rows", Json::Num(table.rows.len() as f64)),
            ("cache_hits", Json::Num(c.hits as f64)),
            ("cache_misses", Json::Num(c.misses as f64)),
            ("executed", Json::Num(opts.engine.executed() as f64)),
            ("deduped", Json::Num(opts.engine.deduped() as f64)),
        ]);
        let meta_path = opts.out_dir.join(format!("{id}.meta.json"));
        meta.write(&meta_path)
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", meta_path.display()))?;
        println!("[sweep {}] wrote {}", grid.name, meta_path.display());
    }
    Ok(opts.out_dir.join(format!("{id}.csv")))
}

/// A part's cache-hit share, rendered from its `.meta.json` sidecar;
/// `-` when the sidecar is absent or unreadable.
fn part_cache_share(part: &Path) -> String {
    let meta_path = part.with_extension("meta.json");
    let Ok(text) = std::fs::read_to_string(&meta_path) else {
        return "-".into();
    };
    let Ok(j) = Json::parse(&text) else {
        return "-".into();
    };
    let num = |k: &str| j.get(k).and_then(|v| v.as_f64());
    match (num("cache_hits"), num("cache_misses")) {
        (Some(h), Some(m)) if h + m > 0.0 => format!("{:.0}%", h / (h + m) * 100.0),
        (Some(_), Some(_)) => "0%".into(),
        _ => "-".into(),
    }
}

fn sanitize_name(s: &str) -> String {
    crate::stats::emit::sanitize_ident(s)
}

/// A non-empty numeric axis from a plan value.
fn float_axis(value: &Value, key: &str) -> anyhow::Result<Vec<f64>> {
    let items = value
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("{key} must be an array (e.g. {key} = [1, 2])"))?;
    anyhow::ensure!(!items.is_empty(), "{key} must not be empty");
    items
        .iter()
        .map(|v| {
            v.as_float()
                .ok_or_else(|| anyhow::anyhow!("{key}: expected a number, got {v:?}"))
        })
        .collect()
}

/// A non-empty string axis from a plan value.
fn string_axis(value: &Value, key: &str) -> anyhow::Result<Vec<String>> {
    let items = value
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("{key} must be an array of strings"))?;
    anyhow::ensure!(!items.is_empty(), "{key} must not be empty");
    items
        .iter()
        .map(|v| {
            v.as_str()
                .map(|s| s.to_string())
                .ok_or_else(|| anyhow::anyhow!("{key}: expected a string, got {v:?}"))
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Shard merging
// ---------------------------------------------------------------------------

/// Parse `<base>.part<i>of<N>.csv` into `(base, i, N)`.
fn parse_part_name(name: &str) -> Option<(String, usize, usize)> {
    let stem = name.strip_suffix(".csv")?;
    let (base, part) = stem.rsplit_once(".part")?;
    let (i, n) = part.split_once("of")?;
    let i: usize = i.parse().ok()?;
    let n: usize = n.parse().ok()?;
    if base.is_empty() || n == 0 || i >= n {
        return None;
    }
    Some((base.to_string(), i, n))
}

/// Merge every complete shard set found in `dir`: for each
/// `<base>.part<i>of<N>.csv` family with all `N` parts present, validate
/// disjointness + completeness of the global row indices and write
/// `<base>.csv`.  Returns the written paths.
pub fn merge_dir(dir: &Path) -> anyhow::Result<Vec<PathBuf>> {
    // One plan's part-file family: shard count + (index -> path).
    type PartGroup = (usize, HashMap<usize, PathBuf>);
    let rd = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", dir.display()))?;
    let mut groups: HashMap<String, PartGroup> = HashMap::new();
    for entry in rd.flatten() {
        let path = entry.path();
        let Some(name) = path.file_name().and_then(|s| s.to_str()) else {
            continue;
        };
        let Some((base, i, n)) = parse_part_name(name) else {
            continue;
        };
        let group = groups.entry(base.clone()).or_insert_with(|| (n, HashMap::new()));
        anyhow::ensure!(
            group.0 == n,
            "conflicting shard counts for '{base}': found both /{} and /{n} part files \
             (remove the stale set before merging)",
            group.0
        );
        anyhow::ensure!(
            group.1.insert(i, path).is_none(),
            "duplicate part {i}/{n} for '{base}'"
        );
    }
    anyhow::ensure!(
        !groups.is_empty(),
        "no shard part files (*.part<i>of<N>.csv) in {}",
        dir.display()
    );

    let mut bases: Vec<String> = groups.keys().cloned().collect();
    bases.sort();
    let mut written = Vec::new();
    for base in bases {
        let (count, parts) = &groups[&base];
        let missing: Vec<String> = (0..*count)
            .filter(|i| !parts.contains_key(i))
            .map(|i| format!("{i}/{count}"))
            .collect();
        anyhow::ensure!(
            missing.is_empty(),
            "'{base}' is missing shard part(s): {}",
            missing.join(", ")
        );
        let mut header: Option<Vec<String>> = None;
        let mut rows: Vec<(usize, Vec<String>)> = Vec::new();
        let mut summary: Vec<Vec<String>> = Vec::new();
        for i in 0..*count {
            let table = CsvTable::read(&parts[&i]).map_err(anyhow::Error::msg)?;
            anyhow::ensure!(
                table.header.first().map(|s| s.as_str()) == Some("row"),
                "{}: not a sweep part file (no leading 'row' column)",
                parts[&i].display()
            );
            match &header {
                None => header = Some(table.header[1..].to_vec()),
                Some(h) => anyhow::ensure!(
                    *h == table.header[1..],
                    "{}: header disagrees with the other parts",
                    parts[&i].display()
                ),
            }
            let n_rows = table.rows.len();
            let (mut lo, mut hi) = (usize::MAX, 0usize);
            for row in table.rows {
                let idx: usize = row[0]
                    .parse()
                    .map_err(|_| anyhow::anyhow!("{}: bad row index '{}'", parts[&i].display(), row[0]))?;
                lo = lo.min(idx);
                hi = hi.max(idx);
                rows.push((idx, row[1..].to_vec()));
            }
            summary.push(vec![
                parts[&i]
                    .file_name()
                    .and_then(|s| s.to_str())
                    .unwrap_or("?")
                    .to_string(),
                format!("{i}/{count}"),
                n_rows.to_string(),
                if n_rows == 0 {
                    "-".into()
                } else {
                    format!("{lo}..{hi}")
                },
                part_cache_share(&parts[&i]),
            ]);
        }
        print_table(
            &format!("sweep merge {base}: {count} part(s)"),
            &["part", "shard", "rows", "row_range", "cache_hit_share"],
            &summary,
        );
        rows.sort_by_key(|(idx, _)| *idx);
        for (pos, (idx, _)) in rows.iter().enumerate() {
            anyhow::ensure!(
                pos == *idx,
                "'{base}': expected global row {pos}, found {idx} — a row is {} \
                 (parts must come from one plan at one shard count)",
                if *idx < pos { "duplicated across shards" } else { "missing" }
            );
        }
        let table = CsvTable {
            header: header.expect("complete part set implies at least one part"),
            rows: rows.into_iter().map(|(_, cells)| cells).collect(),
        };
        let out = dir.join(format!("{base}.csv"));
        table
            .write(&out)
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", out.display()))?;
        println!(
            "[sweep merge] {} <- {count} part(s), {} row(s)",
            out.display(),
            table.rows.len()
        );
        written.push(out);
    }
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::Scale;

    #[test]
    fn doubling_axis_shapes() {
        assert_eq!(doubling_axis(1), vec![1]);
        assert_eq!(doubling_axis(4), vec![1, 2, 4]);
        assert_eq!(doubling_axis(6), vec![1, 2, 4]);
        assert_eq!(doubling_axis(64), vec![1, 2, 4, 8, 16, 32, 64]);
    }

    #[test]
    fn plan_toml_roundtrip_of_every_key() {
        let plan = SweepPlan::from_toml(
            r#"
name = "my plan"
epoch_ns = [1000, 50_000.0]
cus_per_domain = [1, 4]
workloads = ["comd", "synth:7"]
designs = ["pcstall", "oracle"]
objectives = ["ed2p", "energy@5"]
baseline = "static:1.3"
epochs = 24
[set]
gpu.n_wf = 16
[axis]
"dvfs.transition_ns" = [5, 20.0]
dvfs.pc_update_alpha = [0.5, 1.0]
"#,
        )
        .unwrap();
        assert_eq!(plan.name, "my_plan");
        assert_eq!(plan.epoch_ns, vec![1000.0, 50_000.0]);
        assert_eq!(plan.cus_per_domain, vec![1, 4]);
        assert_eq!(
            plan.workloads,
            WorkloadAxis::Explicit(vec!["comd".into(), "synth:7".into()])
        );
        assert_eq!(plan.designs, vec![Policy::PcStall, Policy::Oracle]);
        assert_eq!(
            plan.objectives,
            vec![Objective::Ed2p, Objective::EnergyBound { max_slowdown: 0.05 }]
        );
        assert_eq!(plan.baseline, Policy::Static(0));
        assert_eq!(plan.epochs, Some(24));
        assert_eq!(plan.overrides.len(), 1);
        assert_eq!(plan.overrides[0].0, "gpu.n_wf");
        // [axis] dimensions, in plan order, values canonicalized (the
        // int spelling 5 and the float spelling 20.0 both land as f64)
        assert_eq!(plan.config_axes.len(), 2);
        assert_eq!(plan.config_axes[0].key, "dvfs.transition_ns");
        assert_eq!(plan.config_axes[0].canon, vec!["5.0", "20.0"]);
        assert_eq!(plan.config_axes[1].key, "dvfs.pc_update_alpha");
        assert_eq!(plan.config_axes[1].canon, vec!["0.5", "1.0"]);
    }

    #[test]
    fn plan_toml_rejects_bad_input() {
        for (bad, why) in [
            ("bogus_key = 1\n", "unknown key"),
            ("epoch_ns = [0]\n", "non-positive epoch"),
            ("epoch_ns = 1000\n", "scalar where axis expected"),
            ("cus_per_domain = [1.5]\n", "fractional granularity"),
            ("designs = [\"nope\"]\n", "unknown policy"),
            ("objectives = [\"nope\"]\n", "unknown objective"),
            ("designs = []\n", "empty designs"),
            ("epochs = 0\n", "zero epochs"),
            ("mode = \"nope\"\n", "unknown mode"),
            ("mode = 1\n", "non-string mode"),
            (
                "workloads = [\"comd\"]\nworkloads_add = [\"synth:1\"]\n",
                "exclusive workload keys",
            ),
            ("seed = []\n", "empty seed population"),
            ("seed = [1, 1]\n", "duplicate seeds"),
            ("seed = [1.5]\n", "fractional seed"),
            ("seed = [-3]\n", "negative seed"),
            ("seed = 7\n", "scalar where seed array expected"),
            ("[set]\nseed = [1, 2]\n", "seed axis below [set]"),
            ("[axis]\ngpu.bogus = [1, 2]\n", "unknown config key"),
            ("[axis]\ndvfs.transition_ns = [\"a\"]\n", "type mismatch"),
            ("[axis]\ngpu.n_wf = [1.5]\n", "fractional int axis value"),
            ("[axis]\ndvfs.transition_ns = []\n", "empty axis"),
            ("[axis]\ndvfs.transition_ns = 5\n", "scalar where axis expected"),
            ("[axis]\ndvfs.transition_ns = [5, 5.0]\n", "duplicate axis value"),
            (
                "[axis]\ndvfs.transition_ns = [5]\ndvfs.transition_ns = [9]\n",
                "axis declared twice",
            ),
            ("[axis]\ndvfs.epoch_ns = [1000]\n", "dedicated epoch axis"),
            ("[axis]\ndvfs.cus_per_domain = [1, 2]\n", "dedicated granularity axis"),
            ("[axis]\nseed = [1, 2]\n", "plan-level seed axis"),
            ("[axis]\n\"gpu.sim_threads\" = [1, 4]\n", "identity-excluded exec key"),
            (
                "[set]\ndvfs.transition_ns = 9\n[axis]\ndvfs.transition_ns = [5]\n",
                "[set]/[axis] conflict",
            ),
        ] {
            assert!(SweepPlan::from_toml(bad).is_err(), "accepted ({why}): {bad}");
        }
    }

    #[test]
    fn set_axis_conflict_error_names_both_sites() {
        let err = SweepPlan::from_toml(
            "[axis]\ndvfs.transition_ns = [5, 20]\n[set]\ndvfs.transition_ns = 9\n",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("[set]") && err.contains("[axis]"), "{err}");
        assert!(err.contains("dvfs.transition_ns"), "{err}");
    }

    #[test]
    fn seed_axis_expands_a_synth_population() {
        let opts = ExpOptions {
            scale: Scale::Quick,
            ..Default::default()
        };
        let plan = SweepPlan::from_toml(
            "epoch_ns = [1000, 10000]\ncus_per_domain = [1]\nworkloads = [\"synth\"]\n\
             seed = [1, 2, 3]\ndesigns = [\"pcstall\"]\nepochs = 4\n",
        )
        .unwrap();
        assert_eq!(plan.seeds, vec![1, 2, 3]);
        let grid = plan.compile(&opts).unwrap();
        assert_eq!(grid.points.len(), 6, "2 epochs x 3 seeds");
        for (i, p) in grid.points.iter().enumerate() {
            assert_eq!(p.row, i);
            let s = p.seed.expect("seed-axis points carry a seed coordinate");
            assert_eq!(p.workload, format!("synth:{s}"));
        }
        // per-seed RunKey fingerprints: with one design, every
        // (epoch, seed) baseline is distinct, so shards stay disjoint
        let mut keys: Vec<String> =
            grid.points.iter().map(|p| p.shard_key.hash_hex()).collect();
        let n = keys.len();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), n, "per-seed baseline fingerprints must be distinct");
    }

    #[test]
    fn seed_axis_defaults_workloads_to_the_synth_template() {
        let opts = ExpOptions {
            scale: Scale::Quick,
            ..Default::default()
        };
        let plan = SweepPlan::from_toml(
            "epoch_ns = [1000]\ncus_per_domain = [1]\nseed = [4, 9]\n\
             designs = [\"pcstall\"]\nepochs = 4\n",
        )
        .unwrap();
        let grid = plan.compile(&opts).unwrap();
        assert_eq!(grid.points.len(), 2);
        assert!(grid.points.iter().all(|p| p.workload.starts_with("synth:")));
    }

    #[test]
    fn seed_axis_rejects_non_synth_and_pinned_workloads() {
        let opts = ExpOptions {
            scale: Scale::Quick,
            ..Default::default()
        };
        for (toml, why) in [
            ("workloads = [\"comd\"]\nseed = [1, 2]\n", "catalog workload"),
            ("workloads = [\"synth:7\"]\nseed = [1, 2]\n", "pinned synth seed"),
            (
                "workloads_add = [\"synth\"]\nseed = [1, 2]\n",
                "scale catalog set riding along",
            ),
        ] {
            let plan = SweepPlan::from_toml(toml).unwrap();
            assert!(plan.compile(&opts).is_err(), "compiled ({why}): {toml}");
        }
        // the CLI --workload override is validated the same way
        let plan = SweepPlan::from_toml("seed = [1, 2]\n").unwrap();
        let opts = ExpOptions {
            scale: Scale::Quick,
            workloads_override: vec!["comd"],
            ..Default::default()
        };
        assert!(plan.compile(&opts).is_err());
    }

    #[test]
    fn exec_workload_axis_compiles_to_content_hashed_points() {
        let opts = ExpOptions {
            scale: Scale::Quick,
            ..Default::default()
        };
        let plan = SweepPlan::from_toml(
            "epoch_ns = [1000, 10000]\ncus_per_domain = [1]\n\
             workloads = [\"exec:vectoradd:4096\", \"exec:stencil2d:128\"]\n\
             designs = [\"pcstall\"]\nepochs = 4\n",
        )
        .unwrap();
        let grid = plan.compile(&opts).unwrap();
        assert_eq!(grid.points.len(), 4);
        assert!(grid.points.iter().all(|p| p.workload.starts_with("exec:")));
        // a typoed size fails at compile, not at run
        let bad = SweepPlan::from_toml(
            "epoch_ns = [1000]\nworkloads = [\"exec:vectoradd:4097\"]\n",
        )
        .unwrap();
        assert!(bad.compile(&opts).is_err());
    }

    #[test]
    fn seed_axis_rejects_exec_workloads() {
        let opts = ExpOptions {
            scale: Scale::Quick,
            ..Default::default()
        };
        let plan = SweepPlan::from_toml(
            "workloads = [\"exec:matmul:128\"]\nseed = [1, 2]\n",
        )
        .unwrap();
        let err = plan.compile(&opts).unwrap_err().to_string();
        assert!(err.contains("exec:"), "error should mention exec sources: {err}");
    }

    #[test]
    fn seed_axis_composes_with_set_overrides() {
        let opts = ExpOptions {
            scale: Scale::Quick,
            ..Default::default()
        };
        let plan = SweepPlan::from_toml(
            "epoch_ns = [1000]\ncus_per_domain = [1]\nseed = [1, 2]\n\
             designs = [\"pcstall\"]\nepochs = 4\n[set]\ngpu.n_wf = 4\nseed = 9\n",
        )
        .unwrap();
        let grid = plan.compile(&opts).unwrap();
        assert_eq!(grid.points.len(), 2);
        for p in &grid.points {
            assert_eq!(p.baseline_cell.cfg.gpu.n_wf, 4);
            assert_eq!(
                p.baseline_cell.cfg.seed, 9,
                "[set] seed stays the scalar master-seed override"
            );
        }
    }

    #[test]
    fn preset_seed_population_covers_a_population() {
        let opts = ExpOptions {
            scale: Scale::Quick,
            ..Default::default()
        };
        let plan = SweepPlan::preset("seed_population").unwrap();
        assert!(plan.seeds.len() >= 5, "acceptance: >= 5 synth seeds");
        assert!(plan.designs.contains(&Policy::PcStall));
        let grid = plan.compile(&opts).unwrap();
        let seeds: std::collections::BTreeSet<u64> =
            grid.points.iter().filter_map(|p| p.seed).collect();
        assert!(seeds.len() >= 5, "{seeds:?}");
        assert!(grid.points.iter().all(|p| p.workload.starts_with("synth:")));
        // along the paper's full epoch axis
        let epochs: std::collections::BTreeSet<u64> =
            grid.points.iter().map(|p| p.epoch_ns as u64).collect();
        assert!(epochs.len() >= 4, "{epochs:?}");
        // the seed column is part of the schema the plot emitter groups on
        assert!(SWEEP_HEADER.contains(&"seed"));
    }

    #[test]
    fn preset_epoch_x_granularity_covers_the_cross_figure() {
        // Acceptance shape at --quick: >= 4 epoch lengths, >= 3 domain
        // granularities, >= 2 workload sources (catalog + synth).
        let opts = ExpOptions {
            scale: Scale::Quick,
            ..Default::default()
        };
        let plan = SweepPlan::preset("epoch_x_granularity").unwrap();
        let grid = plan.compile(&opts).unwrap();
        let epochs: std::collections::BTreeSet<u64> =
            grid.points.iter().map(|p| p.epoch_ns as u64).collect();
        let grans: std::collections::BTreeSet<usize> =
            grid.points.iter().map(|p| p.cus_per_domain).collect();
        assert!(epochs.len() >= 4, "epochs: {epochs:?}");
        assert!(grans.len() >= 3, "grans: {grans:?}");
        let has_catalog = grid.points.iter().any(|p| !p.workload.contains(':'));
        let has_synth = grid.points.iter().any(|p| p.workload.starts_with("synth:"));
        assert!(has_catalog && has_synth, "need catalog + synth sources");
        // rows are dense and in order
        for (i, p) in grid.points.iter().enumerate() {
            assert_eq!(p.row, i);
        }
    }

    #[test]
    fn unknown_preset_or_file_errors() {
        assert!(SweepPlan::load("no_such_preset_or_file").is_err());
        assert!(SweepPlan::preset("nope").is_none());
        for p in preset_names() {
            assert!(SweepPlan::preset(p).is_some(), "{p}");
        }
    }

    #[test]
    fn shards_partition_grid_rows_exactly() {
        let opts = ExpOptions {
            scale: Scale::Quick,
            ..Default::default()
        };
        let plan = SweepPlan::from_toml(
            "epoch_ns = [1000, 10000]\ncus_per_domain = [1, 2]\nworkloads = [\"comd\", \"synth:3\"]\ndesigns = [\"pcstall\"]\nepochs = 4\n",
        )
        .unwrap();
        let grid = plan.compile(&opts).unwrap();
        assert_eq!(grid.points.len(), 8);
        for count in [1usize, 2, 3] {
            let mut seen = vec![0usize; grid.points.len()];
            for index in 0..count {
                for p in grid.shard_points(ShardSpec { index, count }) {
                    seen[p.row] += 1;
                }
            }
            assert!(
                seen.iter().all(|&c| c == 1),
                "rows not partitioned exactly once across {count} shard(s): {seen:?}"
            );
        }
    }

    #[test]
    fn part_name_parsing() {
        assert_eq!(
            parse_part_name("sweep_x.part0of3.csv"),
            Some(("sweep_x".into(), 0, 3))
        );
        assert_eq!(
            parse_part_name("sweep_a.b.part11of12.csv"),
            Some(("sweep_a.b".into(), 11, 12))
        );
        for bad in [
            "sweep_x.csv",
            "sweep_x.part3of3.csv",
            "sweep_x.partof3.csv",
            "sweep_x.part1of0.csv",
            ".part0of1.csv",
            "sweep_x.part0of1.txt",
        ] {
            assert_eq!(parse_part_name(bad), None, "{bad}");
        }
    }

    #[test]
    fn part_cache_share_reads_the_meta_sidecar() {
        let dir = std::env::temp_dir()
            .join(format!("pcstall_part_share_{}", std::process::id()));
        let _ = std::fs::create_dir_all(&dir);
        let part = dir.join("sweep_x.part0of2.csv");
        // absent or malformed sidecars degrade to "-" (older part sets
        // must keep merging)
        assert_eq!(part_cache_share(&part), "-");
        let meta = dir.join("sweep_x.part0of2.meta.json");
        std::fs::write(&meta, "not json").unwrap();
        assert_eq!(part_cache_share(&part), "-");
        std::fs::write(&meta, "{\"cache_hits\": 3, \"cache_misses\": 1}").unwrap();
        assert_eq!(part_cache_share(&part), "75%");
        std::fs::write(&meta, "{\"cache_hits\": 0, \"cache_misses\": 0}").unwrap();
        assert_eq!(part_cache_share(&part), "0%");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn config_axis_expands_the_grid_and_patches_cell_configs() {
        let opts = ExpOptions {
            scale: Scale::Quick,
            ..Default::default()
        };
        let plan = SweepPlan::from_toml(
            "epoch_ns = [1000, 10000]\ncus_per_domain = [1]\nworkloads = [\"comd\"]\n\
             designs = [\"pcstall\"]\nepochs = 4\n[axis]\ndvfs.transition_ns = [5, 1000]\n",
        )
        .unwrap();
        let grid = plan.compile(&opts).unwrap();
        assert_eq!(grid.config_keys, vec!["dvfs.transition_ns"]);
        assert_eq!(grid.points.len(), 4, "2 transitions x 2 epochs");
        // first axis is outermost; the coordinate reaches the cell config
        let coords: Vec<(String, f64)> = grid
            .points
            .iter()
            .map(|p| (p.config[0].clone(), p.baseline_cell.cfg.dvfs.transition_ns))
            .collect();
        assert_eq!(
            coords,
            vec![
                ("5.0".into(), 5.0),
                ("5.0".into(), 5.0),
                ("1000.0".into(), 1000.0),
                ("1000.0".into(), 1000.0),
            ]
        );
        // one CSV column per axis, spliced before the metric columns
        let header = grid.header();
        assert_eq!(header[6], "dvfs.transition_ns");
        assert_eq!(header[7], "improvement_pct");
        // distinct axis values give distinct shard fingerprints
        let mut keys: Vec<String> = grid.points.iter().map(|p| p.shard_key.hash_hex()).collect();
        let n = keys.len();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), n, "config-axis fingerprints must be distinct");
    }

    #[test]
    fn config_axis_value_spelling_does_not_change_cache_identity() {
        // `5` and `5.0` for an f64 key are one canonical value: the
        // compiled grids carry identical RunKey fingerprints, so cache
        // entries and shard assignments survive re-encoding the plan.
        let opts = ExpOptions {
            scale: Scale::Quick,
            ..Default::default()
        };
        let base = "epoch_ns = [1000]\ncus_per_domain = [1]\nworkloads = [\"comd\"]\n\
                    designs = [\"pcstall\"]\nepochs = 4\n[axis]\n";
        let a = SweepPlan::from_toml(&format!("{base}dvfs.transition_ns = [5, 20]\n"))
            .unwrap()
            .compile(&opts)
            .unwrap();
        let b = SweepPlan::from_toml(&format!("{base}dvfs.transition_ns = [5.0, 20.0]\n"))
            .unwrap()
            .compile(&opts)
            .unwrap();
        assert_eq!(a.points.len(), b.points.len());
        for (pa, pb) in a.points.iter().zip(&b.points) {
            assert_eq!(pa.shard_key.hash_hex(), pb.shard_key.hash_hex());
            assert_eq!(pa.config, pb.config);
        }
    }

    #[test]
    fn default_granularity_axis_rejects_an_n_cu_config_axis() {
        let opts = ExpOptions {
            scale: Scale::Quick,
            ..Default::default()
        };
        let plan = SweepPlan::from_toml(
            "epoch_ns = [1000]\nworkloads = [\"comd\"]\ndesigns = [\"pcstall\"]\n\
             epochs = 4\n[axis]\ngpu.n_cu = [2, 4]\n",
        )
        .unwrap();
        assert!(plan.compile(&opts).is_err(), "defaulted cus_per_domain is ambiguous");
        // with an explicit granularity axis the same sweep compiles
        let plan = SweepPlan::from_toml(
            "epoch_ns = [1000]\ncus_per_domain = [1]\nworkloads = [\"comd\"]\n\
             designs = [\"pcstall\"]\nepochs = 4\n[axis]\ngpu.n_cu = [2, 4]\n",
        )
        .unwrap();
        let grid = plan.compile(&opts).unwrap();
        assert_eq!(grid.points.len(), 2);
        let n_cus: Vec<usize> =
            grid.points.iter().map(|p| p.baseline_cell.cfg.gpu.n_cu).collect();
        assert_eq!(n_cus, vec![2, 4]);
    }

    #[test]
    fn legacy_plans_keep_the_golden_schema_and_row_order() {
        // Back-compat golden: a pre-redesign plan (no [axis] table) must
        // compile to exactly the closed-axis-set schema and grid order,
        // so its CSVs stay byte-identical across the API redesign.
        let opts = ExpOptions {
            scale: Scale::Quick,
            ..Default::default()
        };
        let plan = SweepPlan::from_toml(
            "epoch_ns = [1000, 10000]\ncus_per_domain = [1, 2]\n\
             workloads = [\"comd\", \"synth:5\"]\ndesigns = [\"pcstall\"]\nepochs = 12\n",
        )
        .unwrap();
        let grid = plan.compile(&opts).unwrap();
        assert!(grid.config_keys.is_empty());
        assert_eq!(
            grid.header(),
            SWEEP_HEADER.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
            "legacy schema drifted"
        );
        let coords: Vec<String> = grid
            .points
            .iter()
            .map(|p| format!("{}|{}|{}", p.epoch_ns, p.cus_per_domain, p.workload))
            .collect();
        assert_eq!(
            coords,
            vec![
                "1000|1|comd",
                "1000|1|synth:5",
                "1000|2|comd",
                "1000|2|synth:5",
                "10000|1|comd",
                "10000|1|synth:5",
                "10000|2|comd",
                "10000|2|synth:5",
            ],
            "legacy grid order drifted"
        );
        assert!(grid.points.iter().all(|p| p.config.is_empty()));
        // every preset still compiles with an unchanged base schema,
        // except the ones that declare a config axis (and the serve
        // preset's latency tail)
        for name in preset_names() {
            let preset = SweepPlan::preset(name).unwrap();
            let grid = preset.compile(&opts).unwrap();
            match name {
                "transition_latency" => {
                    assert_eq!(grid.config_keys, vec!["dvfs.transition_ns"]);
                }
                "serve_load" => {
                    assert_eq!(grid.config_keys, vec!["serve.arrival_rate"]);
                    assert!(grid.serve);
                }
                _ => {
                    assert!(grid.config_keys.is_empty(), "{name} grew a config axis");
                    assert_eq!(grid.header().len(), SWEEP_HEADER.len(), "{name}");
                    assert!(!grid.serve, "{name} became a serve plan");
                }
            }
        }
    }

    #[test]
    fn preset_transition_latency_covers_the_regimes() {
        let opts = ExpOptions {
            scale: Scale::Quick,
            ..Default::default()
        };
        let plan = SweepPlan::preset("transition_latency").unwrap();
        assert_eq!(plan.epochs, Some(24), "fixed-epoch mode for like-for-like rows");
        let grid = plan.compile(&opts).unwrap();
        // >= 4 latency regimes (ns -> us) x the full paper epoch axis
        let lats: std::collections::BTreeSet<String> =
            grid.points.iter().map(|p| p.config[0].clone()).collect();
        assert!(lats.len() >= 4, "{lats:?}");
        let epochs: std::collections::BTreeSet<u64> =
            grid.points.iter().map(|p| p.epoch_ns as u64).collect();
        assert!(epochs.len() >= 4, "{epochs:?}");
        // crisp vs pcstall vs oracle, over catalog + synth sources
        let designs: std::collections::BTreeSet<String> =
            grid.points.iter().map(|p| p.design.name()).collect();
        assert!(designs.len() >= 3, "{designs:?}");
        assert!(grid.points.iter().any(|p| !p.workload.contains(':')));
        assert!(grid.points.iter().any(|p| p.workload.starts_with("synth:")));
        // the latency coordinate reaches the simulated config
        for p in &grid.points {
            let applied = p.baseline_cell.cfg.dvfs.transition_ns;
            assert_eq!(crate::config::registry::canonical_f64(applied), p.config[0]);
        }
    }

    #[test]
    fn serve_plans_append_the_latency_tail_and_run_the_arrival_loop() {
        let opts = ExpOptions {
            scale: Scale::Quick,
            ..Default::default()
        };
        let plan = SweepPlan::from_toml(
            "epoch_ns = [1000]\ncus_per_domain = [1]\nworkloads = [\"comd\"]\n\
             designs = [\"pcstall\"]\nobjectives = [\"deadline\"]\nmode = \"serve\"\n\
             [axis]\nserve.arrival_rate = [0.01, 0.04]\n",
        )
        .unwrap();
        assert!(plan.serve);
        let grid = plan.compile(&opts).unwrap();
        assert!(grid.serve);
        let header = grid.header();
        let tail: Vec<&str> =
            header[header.len() - SERVE_COLS.len()..].iter().map(|s| s.as_str()).collect();
        assert_eq!(tail, SERVE_COLS);
        assert_eq!(grid.points.len(), 2, "2 offered-load levels");
        for p in &grid.points {
            assert!(
                matches!(p.baseline_cell.mode, RunMode::Serve { .. }),
                "serve plans must compile serve cells, got {:?}",
                p.baseline_cell.mode
            );
            assert_eq!(p.objective, Objective::Deadline);
        }
        // the axis coordinate reaches the simulated config
        let rates: Vec<f64> =
            grid.points.iter().map(|p| p.baseline_cell.cfg.serve.arrival_rate).collect();
        assert_eq!(rates, vec![0.01, 0.04]);
        // batch plans never carry the tail
        let batch = SweepPlan::from_toml(
            "epoch_ns = [1000]\ncus_per_domain = [1]\nworkloads = [\"comd\"]\n\
             designs = [\"pcstall\"]\nepochs = 4\nmode = \"batch\"\n",
        )
        .unwrap();
        let grid = batch.compile(&opts).unwrap();
        assert!(!grid.serve);
        assert_eq!(grid.header().len(), SWEEP_HEADER.len());
        // an explicit `epochs` cap on a serve plan stays a serve run
        let capped = SweepPlan::from_toml(
            "epoch_ns = [1000]\ncus_per_domain = [1]\nworkloads = [\"comd\"]\n\
             designs = [\"pcstall\"]\nmode = \"serve\"\nepochs = 64\n",
        )
        .unwrap()
        .compile(&opts)
        .unwrap();
        assert!(matches!(
            capped.points[0].baseline_cell.mode,
            RunMode::Serve { max_epochs: 64 }
        ));
    }

    #[test]
    fn preset_serve_load_covers_the_offered_load_axis() {
        let opts = ExpOptions {
            scale: Scale::Quick,
            ..Default::default()
        };
        let plan = SweepPlan::preset("serve_load").unwrap();
        assert!(plan.serve);
        assert_eq!(plan.objectives, vec![Objective::Deadline]);
        let grid = plan.compile(&opts).unwrap();
        assert_eq!(grid.config_keys, vec!["serve.arrival_rate"]);
        let rates: std::collections::BTreeSet<String> =
            grid.points.iter().map(|p| p.config[0].clone()).collect();
        assert!(rates.len() >= 4, "offered-load levels: {rates:?}");
        let designs: std::collections::BTreeSet<String> =
            grid.points.iter().map(|p| p.design.name()).collect();
        assert!(designs.len() >= 3, "crisp vs pcstall vs oracle: {designs:?}");
        let desc = plan.describe().join("\n");
        assert!(desc.contains("mode: serve"), "{desc}");
    }

    #[test]
    fn deadline_merit_is_energy() {
        let r = RunResult {
            workload: "w".into(),
            policy: "p".into(),
            objective: "deadline".into(),
            records: Vec::new(),
            total_energy_j: 2.5,
            total_time_ns: 1e6,
            total_instr: 1.0,
            mean_accuracy: f64::NAN,
            pc_hit_rate: 0.0,
            completed: true,
            serve: None,
        };
        assert_eq!(merit(Objective::Deadline, &r), 2.5);
    }

    #[test]
    fn describe_is_derived_from_the_plan() {
        let plan = SweepPlan::preset("transition_latency").unwrap();
        let desc = plan.describe().join("\n");
        assert!(desc.contains("axis dvfs.transition_ns: [5.0, 20.0, 100.0, 1000.0]"), "{desc}");
        assert!(desc.contains("epochs: 24 (fixed)"), "{desc}");
        for p in preset_names() {
            assert!(!SweepPlan::preset(p).unwrap().describe().is_empty());
        }
    }

    #[test]
    fn workload_override_replaces_the_axis() {
        let opts = ExpOptions {
            scale: Scale::Quick,
            workloads_override: vec!["dgemm"],
            ..Default::default()
        };
        let plan = SweepPlan::preset("epoch_x_granularity").unwrap();
        let grid = plan.compile(&opts).unwrap();
        assert!(grid.points.iter().all(|p| p.workload == "dgemm"));
    }
}
