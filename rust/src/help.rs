//! The `pcstall` CLI help text, as a library constant.
//!
//! Living in the library (not `main.rs`) so tests can cross-check it:
//! `tests/cli_docs.rs` gates `docs/cli.md` against this text — every
//! verb and `--flag` mentioned here must be documented there, so the
//! CLI reference cannot silently drift from the binary.

/// `pcstall help` output.  One source of truth for the CLI surface.
pub const HELP: &str = r#"pcstall — PC-based fine-grain DVFS for GPUs (paper reproduction)

USAGE:
  pcstall simulate --workload <spec> --policy <p> [options]
  pcstall serve [--workload <spec>] [--policy <p> ...] [options]
  pcstall run <id|all> [--quick|--full] [--out dir] [--pjrt]
                       [--jobs N] [--no-cache] [--seed s]
                       [--workload <spec> ...]
  pcstall experiment ...   (alias of `run`)
  pcstall sweep <plan.toml|preset> [run options] [--shard i/N]
  pcstall sweep merge <dir>
  pcstall sweep plot <merged.csv> [--metric col] [--band minmax|iqr] [--out dir]
  pcstall sweep list
  pcstall trace record <spec> [--out file] [--waves-scale x] [--binary]
  pcstall trace replay <file> [simulate options]
  pcstall trace gen [--seed s] [--out file] [--binary]
  pcstall trace info <file>
  pcstall trace ingest <accel-sim-file> [--out file] [--binary]
  pcstall trace diff <a> <b>
  pcstall workloads list
  pcstall cache stats [--dir results/cache]
  pcstall cache clear [--dir results/cache] [--max-age days] [--max-bytes MB]
  pcstall obs report [<dir>]
  pcstall obs diff <dirA> <dirB>
  pcstall obs plot [<dir>] [--out dir]
  pcstall list
  pcstall config dump [--set k=v ...]
  pcstall config keys
  pcstall table1

WORKLOAD SPECS (accepted wherever a workload name is):
  <name>                catalog workload from `pcstall list`
  trace:<path>          instruction-trace file (text or binary encoding)
  synth:<seed>          seeded synthesized trace workload
  exec:<kernel>[:<size>]  executable library kernel (matmul, transpose,
                        vectoradd, reduce, stencil2d, spmv-ella), run
                        under instrumentation and lowered to a trace;
                        `pcstall workloads list` shows size ranges

RUN OPTIONS:
  --quick | --full      scale preset (default: 8 CUs, all workloads)
  --out <dir>           output directory               (default results/)
  --jobs <n>            sweep worker threads   (default: all CPU cores)
  --sim-threads <n>     CU-stepping threads inside each simulation
                        (0 = as wide as the machine; default: auto —
                        batches big enough to fill --jobs run serial
                        sims, smaller batches hand idle cores to each
                        sim).  Results are byte-identical for every
                        value; jobs x sim-threads never oversubscribes
  --no-cache            recompute everything; do not read or write the
                        content-addressed result cache (<out>/cache/)
  --pjrt                use the PJRT artifact backend when available
  --seed <s>            master workload seed
  --workload <spec>     replace the experiment's workload set (repeatable)
  --obs <dir>           record observability artifacts into <dir>:
                        byte-deterministic per-cell counters
                        (counters.json / counters.csv — stall breakdown,
                        queue-depth histograms, PC-table and DVFS traffic),
                        per-epoch decision traces (decisions.csv /
                        decisions.ndjson — predicted vs actual
                        instructions, chosen ladder state, counterfactual
                        regret) and a Chrome-trace span timeline
                        (timeline.ndjson).  Cells served by the result
                        cache carry no obs records (a stderr warning names
                        the count) — pair with --no-cache for complete
                        sidecars
  --progress            periodic stderr progress (cells done/total, cells
                        served by cache, ETA); stdout and every emitted
                        artifact stay byte-identical

SIMULATE / REPLAY OPTIONS:
  --workload <spec>     workload spec (required for simulate)
  --policy <p>          stall|lead|crit|crisp|accreac|pcstall|accpc|oracle|static:<ghz>
  --objective <o>       edp|ed2p|energy@<pct>|deadline  (default ed2p)
  --epochs <n>          run exactly n epochs      (default: run to completion)
  --epoch-ns <x>        epoch duration override
  --waves-scale <x>     workload length multiplier
                        (default 0.1 for catalog, 1.0 for traces)
  --config <file>       TOML config
  --set k=v             config override (repeatable)
  --backend native|pjrt compute backend            (default native)
  --json <file>         dump the run result as JSON
  --sim-threads <n>     CU-stepping threads (0 = all cores; default 1);
                        results are byte-identical for every value

SERVE OPTIONS (continuous-traffic DVFS under deadlines):
  serve drives one long-horizon simulation per policy: a seeded arrival
  process offers serve.launches copies of the workload, the DVFS policy
  runs throughout (idle epochs included), launches queue FIFO while the
  GPU is busy, and <out>/serve.csv reports one row per policy with
  p50_us/p99_us latency, miss_rate against serve.deadline_us,
  throughput, queue depth, and energy.  The arrival stream is derived
  from --seed + the serve.* config keys (set them with --set; see
  `pcstall config keys`): serve.arrival_rate (launches per µs),
  serve.deadline_us, serve.burst_factor (1.0 = pure Poisson, >1 = bursty
  two-state MMPP), serve.burst_dwell_us, serve.launches,
  serve.risk_frac, serve.slack_slowdown.  Synthetic-arrival runs ride
  the result cache and --jobs like any experiment; sweep load levels
  with `pcstall sweep serve_load` or an `[axis] serve.arrival_rate`
  plan.  Accepts all RUN OPTIONS plus:
  --workload <spec>     the served workload       (default comd; one spec)
  --policy <p>          policy to compare (repeatable; default crisp and
                        pcstall — each adds one serve.csv row)
  --objective <o>       objective for every policy (default deadline:
                        energy-min while deadlines are safe, max-perf
                        fallback when a launch's remaining slack drops
                        below serve.risk_frac)
  --epoch-ns <x>        epoch duration override
  --arrival-trace <f>   replace the synthetic arrival process with
                        inter-arrival gaps read from <f> (one µs value
                        per line, cycled); these runs bypass the result
                        cache (the gap list is outside the run identity)

SWEEP COMMANDS:
  <plan.toml|preset>    run a declarative sweep plan (grid over epoch
                        length x cus_per_domain x workload source x
                        synth-seed population x objective x design x any
                        [axis] config key); presets: epoch_x_granularity,
                        epoch_sweep, granularity_sweep, seed_population,
                        transition_latency, serve_load.  A `mode =
                        "serve"` plan runs every cell through the
                        continuous-arrival serve loop and appends
                        p50_us/p99_us/miss_rate columns.  Accepts all
                        RUN OPTIONS plus:
    --shard i/N         run only partition i of N (deterministic split by
                        RunKey fingerprint; shards are disjoint and
                        cache-compatible).  Writes
                        <out>/sweep_<name>.part<i>of<N>.csv
  merge <dir>           combine a complete part set into
                        <out>/sweep_<name>.csv (byte-identical to an
                        unsharded run)
  plot <merged.csv>     emit a self-contained gnuplot script + matplotlib
                        fallback from a merged sweep CSV: x = the most-
                        varying grid axis (config axes win ties), one
                        panel per (objective, other axes), one series per
                        design, mean inside a band over the seed/workload
                        population.  --metric picks the column (default
                        accuracy; serve plans add p50_us/p99_us/
                        miss_rate); --band picks the envelope (minmax |
                        iqr, default minmax); --out redirects the scripts
  list                  show presets (axes derived from the plans
                        themselves) and the plan TOML grammar

OBS COMMANDS:
  report [<dir>]        summarize a --obs directory (default results/obs):
                        counter totals across cells, the top wall-clock
                        spans from the timeline, and — when decision
                        traces are present — a prediction-accuracy
                        histogram, the worst-regret epochs, and a per-PC
                        mispredict leaderboard.  Load timeline.ndjson in
                        Perfetto / chrome://tracing for the full picture.
  diff <dirA> <dirB>    align two decision traces by (cell, epoch, domain)
                        and report where the policies diverge, with regret
                        attribution per side (greppable
                        `divergent pairs    : N` line); same-policy cells
                        pair with themselves, leftover policies pair in
                        sorted order (e.g. CRISP-only run vs PCSTALL-only
                        run over the same workloads)
  plot [<dir>]          emit a gnuplot script + matplotlib fallback
                        rendering accuracy and mean chosen frequency vs
                        epoch, one panel per cell, from <dir>/decisions.csv
                        (--out redirects the scripts)

CONFIG COMMANDS:
  dump                  print the effective TOML config (with --set)
  keys                  print the typed config-key registry: every key
                        usable in --set, plan [set] tables, and plan
                        [axis] grid dimensions (key, type, default, doc)

TRACE COMMANDS:
  record <spec>         capture a workload's executed stream to a file
                        (default traces/<name>.trace; --binary for the
                        length-prefixed binary encoding; --waves-scale
                        is baked into the written geometry)
  replay <file>         simulate a trace file (same options as simulate)
  gen                   synthesize a randomized trace (--seed, default 1)
  info <file>           print header, per-kernel stats, content hash
  ingest <file>         lower an accel-sim-style kernel trace
  diff <a> <b>          compare two trace files structurally: per-kernel
                        opcode mix, stride histogram, and length deltas,
                        ending in a greppable `divergent: N` line
                        (0 = structurally identical)

WORKLOADS COMMANDS:
  list                  one table of catalog workloads, exec kernels
                        (with size ranges and defaults), and the accepted
                        workload spec grammars
"#;
