//! # PCSTALL — fine-grain GPU DVFS via PC-based sensitivity prediction
//!
//! A from-scratch reproduction of *"Predict; Don't React for Enabling
//! Efficient Fine-Grain DVFS in GPUs"* (Bharadwaj et al., AMD, 2022).
//!
//! The crate is organised bottom-up:
//!
//! * [`sim`] — the substrate: a deterministic, snapshot-able,
//!   wavefront-level GPU timing simulator (the paper's gem5 GCN3 stand-in)
//!   with per-CU V/f domains, async vector memory + `s_waitcnt` semantics,
//!   an L1/L2/DRAM hierarchy and quantum-coupled cross-CU contention.
//! * [`workloads`] — seeded synthetic generators reproducing the phase
//!   character of the paper's Table II applications (ECP proxies +
//!   DeepBench/DNNMark kernels), plus [`workloads::exec`]: a library of
//!   executable Rust kernels run over instrumented device arrays and
//!   lowered to content-hashed traces (`exec:<kernel>:<size>` specs).
//! * [`power`] — the CV²Af + leakage + IVR-efficiency power model shared
//!   (constant-for-constant) with the Python/Pallas artifact.
//! * [`models`] — frequency-sensitivity estimation models: STALL, LEAD,
//!   CRIT, CRISP (CU-level baselines) and the paper's wavefront-level
//!   STALL estimator.
//! * [`predictors`] — reactive (last-value), PC-indexed table (PCSTALL),
//!   and the fork-pre-execute oracle.
//! * [`dvfs`] — sensitivity metric, objective functions, the per-epoch
//!   DVFS manager, and the native mirror of the AOT compute graph.
//! * [`runtime`] — PJRT bridge: loads `artifacts/dvfs_step.hlo.txt` and
//!   executes it on the epoch hot path (Python never runs at sim time).
//! * [`exec`] — the sweep-execution engine: job keys, the
//!   content-addressed result cache, and the ordered worker pool that
//!   make experiment grids parallel and incremental.
//! * [`obs`] — observability: deterministic stall/queue/PC-table
//!   counters collected through an epoch-boundary `ObsSink`, plus a
//!   wall-clock span timeline (`--obs <dir>`, `pcstall obs report`).
//! * [`trace`] — wavefront instruction traces as first-class workloads:
//!   a versioned text/binary format, simulator + recorded-kernel
//!   capture, accel-sim-style ingest, a seeded trace synthesizer, and a
//!   structural trace differ (`pcstall trace diff`).
//! * [`harness`] — one experiment per paper figure/table (see DESIGN.md),
//!   plus declarative sweep plans ([`harness::sweep`]): N-dimensional
//!   epoch × granularity × workload-source × objective × design grids,
//!   shardable across machines by run-key fingerprint — and the
//!   continuous-traffic serve harness ([`harness::serve`]): seeded
//!   arrival streams, deadline objectives, p50/p99 latency reporting.
//!
//! The repo-level ARCHITECTURE.md walks the same modules top-down
//! (data flow, determinism contract, cache versioning); docs/cli.md is
//! the full CLI reference, drift-gated against [`help::HELP`].

// Style allowances for the simulator's index-heavy kernels (CI runs
// clippy with `-D warnings`).
#![allow(clippy::needless_range_loop)]

pub mod config;
pub mod dvfs;
pub mod exec;
pub mod harness;
pub mod help;
pub mod models;
pub mod obs;
pub mod power;
pub mod predictors;
pub mod runtime;
pub mod sim;
pub mod stats;
pub mod trace;
pub mod util;
pub mod workloads;

pub use config::SimConfig;
pub use dvfs::manager::DvfsManager;
pub use sim::gpu::Gpu;
