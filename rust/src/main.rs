//! `pcstall` — leader entrypoint + CLI.
//!
//! Subcommands (hand-rolled parser; offline environment has no clap):
//!
//! ```text
//! pcstall simulate  --workload comd --policy pcstall [--objective ed2p]
//!                   [--epochs N | --completion] [--epoch-ns X]
//!                   [--config file.toml] [--set k=v ...]
//!                   [--backend native|pjrt] [--json out.json]
//! pcstall run <id|all> [--quick|--full] [--out results/] [--pjrt]
//!                      [--jobs N] [--no-cache]
//! pcstall experiment ...   (alias of `run`)
//! pcstall list
//! pcstall config dump [--set k=v ...]
//! pcstall table1
//! ```

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;

use pcstall::config::SimConfig;
use pcstall::dvfs::manager::{DvfsManager, Policy, RunMode};
use pcstall::dvfs::objective::Objective;
use pcstall::exec::{pool, Engine};
use pcstall::harness::{all_experiments, run_experiment, ExpOptions, Scale};
use pcstall::stats::emit::Json;
use pcstall::workloads;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "simulate" => simulate(&args[1..]),
        "run" | "experiment" => experiment(&args[1..]),
        "list" => list(),
        "config" => config_cmd(&args[1..]),
        "table1" => run_experiment("table1", &ExpOptions::default()),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}' (try `pcstall help`)"),
    }
}

const HELP: &str = r#"pcstall — PC-based fine-grain DVFS for GPUs (paper reproduction)

USAGE:
  pcstall simulate --workload <name> --policy <p> [options]
  pcstall run <id|all> [--quick|--full] [--out dir] [--pjrt]
                       [--jobs N] [--no-cache] [--seed s]
  pcstall experiment ...   (alias of `run`)
  pcstall list
  pcstall config dump [--set k=v ...]
  pcstall table1

RUN OPTIONS:
  --quick | --full      scale preset (default: 8 CUs, all workloads)
  --out <dir>           output directory               (default results/)
  --jobs <n>            sweep worker threads   (default: all CPU cores)
  --no-cache            recompute everything; do not read or write the
                        content-addressed result cache (<out>/cache/)
  --pjrt                use the PJRT artifact backend when available
  --seed <s>            master workload seed

SIMULATE OPTIONS:
  --workload <name>     one of `pcstall list` (required)
  --policy <p>          stall|lead|crit|crisp|accreac|pcstall|accpc|oracle|static:<ghz>
  --objective <o>       edp|ed2p|energy@<pct>     (default ed2p)
  --epochs <n>          run exactly n epochs      (default: run to completion)
  --epoch-ns <x>        epoch duration override
  --waves-scale <x>     workload length multiplier (default 0.1)
  --config <file>       TOML config
  --set k=v             config override (repeatable)
  --backend native|pjrt compute backend            (default native)
  --json <file>         dump the run result as JSON
"#;

/// Pull `--key value` / `--flag` options out of an arg list.
struct Opts {
    args: Vec<String>,
}

impl Opts {
    fn new(args: &[String]) -> Self {
        Opts {
            args: args.to_vec(),
        }
    }

    fn take(&mut self, key: &str) -> Option<String> {
        let pos = self.args.iter().position(|a| a == key)?;
        if pos + 1 >= self.args.len() {
            return None;
        }
        let v = self.args.remove(pos + 1);
        self.args.remove(pos);
        Some(v)
    }

    fn take_all(&mut self, key: &str) -> Vec<String> {
        let mut out = Vec::new();
        while let Some(v) = self.take(key) {
            out.push(v);
        }
        out
    }

    fn take_flag(&mut self, key: &str) -> bool {
        if let Some(pos) = self.args.iter().position(|a| a == key) {
            self.args.remove(pos);
            true
        } else {
            false
        }
    }

    fn finish(self) -> Result<Vec<String>> {
        for a in &self.args {
            if a.starts_with("--") {
                anyhow::bail!("unknown option: {a}");
            }
        }
        Ok(self.args)
    }
}

fn parse_objective(s: &str) -> Result<Objective> {
    let lower = s.to_ascii_lowercase();
    Ok(match lower.as_str() {
        "edp" => Objective::Edp,
        "ed2p" => Objective::Ed2p,
        _ => {
            if let Some(pct) = lower.strip_prefix("energy@") {
                let p: f64 = pct.trim_end_matches('%').parse()?;
                Objective::EnergyBound {
                    max_slowdown: p / 100.0,
                }
            } else {
                anyhow::bail!("unknown objective '{s}' (edp|ed2p|energy@<pct>)");
            }
        }
    })
}

fn simulate(args: &[String]) -> Result<()> {
    let mut o = Opts::new(args);
    let workload = o
        .take("--workload")
        .ok_or_else(|| anyhow::anyhow!("--workload is required"))?;
    let policy = Policy::parse(&o.take("--policy").unwrap_or_else(|| "pcstall".into()))?;
    let objective = parse_objective(&o.take("--objective").unwrap_or_else(|| "ed2p".into()))?;
    let epochs = o.take("--epochs").map(|s| s.parse::<u64>()).transpose()?;
    let epoch_ns = o.take("--epoch-ns").map(|s| s.parse::<f64>()).transpose()?;
    let waves: f64 = o.take("--waves-scale").unwrap_or_else(|| "0.1".into()).parse()?;
    let cfg_path = o.take("--config");
    let sets = o.take_all("--set");
    let backend = o.take("--backend").unwrap_or_else(|| "native".into());
    let json_out = o.take("--json").map(PathBuf::from);
    o.finish()?;

    let mut cfg = match cfg_path {
        Some(p) => SimConfig::from_path(std::path::Path::new(&p))?,
        None => {
            let mut c = SimConfig::default();
            c.gpu.n_cu = 8;
            c.gpu.n_wf = 16;
            c
        }
    };
    for s in sets {
        cfg.apply_override(&s)?;
    }
    if let Some(e) = epoch_ns {
        cfg.dvfs.epoch_ns = e;
    }

    anyhow::ensure!(
        workloads::names().contains(&workload.as_str()),
        "unknown workload '{workload}' (see `pcstall list`)"
    );
    let wl = workloads::build(&workload, waves);

    let mut mgr = match backend.as_str() {
        "native" => DvfsManager::new(cfg, &wl, policy, objective),
        "pjrt" => DvfsManager::with_backend(
            cfg,
            &wl,
            policy,
            objective,
            pcstall::runtime::best_backend(None),
        ),
        other => anyhow::bail!("unknown backend '{other}'"),
    };
    let mode = match epochs {
        Some(n) => RunMode::Epochs(n),
        None => RunMode::Completion {
            max_epochs: 200_000,
        },
    };
    let t0 = std::time::Instant::now();
    let r = mgr.run(mode, &workload);
    let dt = t0.elapsed();

    println!("workload   : {}", r.workload);
    println!("policy     : {}", r.policy);
    println!("objective  : {}", r.objective);
    println!("epochs     : {} ({}completed)", r.records.len(), if r.completed { "" } else { "NOT " });
    println!("sim time   : {:.3} ms simulated in {:.2?}", r.total_time_ns / 1e6, dt);
    println!("instructions: {:.3e}", r.total_instr);
    println!("energy     : {:.6} J", r.total_energy_j);
    println!("EDP        : {:.4e} J*s", r.edp());
    println!("ED2P       : {:.4e} J*s^2", r.ed2p());
    println!("accuracy   : {:.3}", r.mean_accuracy);
    let share = r.freq_time_share();
    println!(
        "freq share : {}",
        share
            .iter()
            .enumerate()
            .filter(|(_, s)| **s > 0.005)
            .map(|(k, s)| format!("{:.1}GHz:{:.0}%", 1.3 + 0.1 * k as f64, s * 100.0))
            .collect::<Vec<_>>()
            .join(" ")
    );

    if let Some(path) = json_out {
        let j = Json::obj(vec![
            ("workload", Json::Str(r.workload.clone())),
            ("policy", Json::Str(r.policy.clone())),
            ("objective", Json::Str(r.objective.clone())),
            ("epochs", Json::Num(r.records.len() as f64)),
            ("completed", Json::Bool(r.completed)),
            ("total_instr", Json::Num(r.total_instr)),
            ("energy_j", Json::Num(r.total_energy_j)),
            ("time_ns", Json::Num(r.total_time_ns)),
            ("edp", Json::Num(r.edp())),
            ("ed2p", Json::Num(r.ed2p())),
            ("accuracy", Json::Num(r.mean_accuracy)),
            ("freq_share", Json::nums(&share)),
        ]);
        j.write(&path)?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

fn experiment(args: &[String]) -> Result<()> {
    let mut o = Opts::new(args);
    let mut opts = ExpOptions::default();
    if o.take_flag("--quick") {
        opts.scale = Scale::Quick;
    }
    if o.take_flag("--full") {
        opts.scale = Scale::Full;
    }
    if let Some(dir) = o.take("--out") {
        opts.out_dir = PathBuf::from(dir);
    }
    opts.use_pjrt = o.take_flag("--pjrt");
    if let Some(seed) = o.take("--seed") {
        opts.seed = seed.parse()?;
    }
    opts.jobs = match o.take("--jobs") {
        Some(n) => n.parse::<usize>()?.max(1),
        None => pool::default_jobs(),
    };
    let no_cache = o.take_flag("--no-cache");
    opts.engine = Arc::new(if no_cache {
        Engine::no_cache()
    } else {
        Engine::with_cache_dir(opts.out_dir.join("cache"))
    });
    let rest = o.finish()?;
    let id = rest.first().map(|s| s.as_str()).unwrap_or("all");
    let t0 = std::time::Instant::now();
    run_experiment(id, &opts)?;
    println!("\n{}", opts.engine.summary(opts.jobs));
    println!("[experiment {id} done in {:.1?}]", t0.elapsed());
    Ok(())
}

fn list() -> Result<()> {
    println!("workloads (paper Table II):");
    for w in workloads::names() {
        let spec = workloads::build(w, 1.0);
        println!("  {:<10} {} kernel(s)", w, spec.kernels.len());
    }
    println!("\npolicies (paper Table III):");
    for p in ["stall", "lead", "crit", "crisp", "accreac", "pcstall", "accpc", "oracle", "static:<ghz>"] {
        println!("  {p}");
    }
    println!("\nexperiments:");
    for e in all_experiments() {
        println!("  {e}");
    }
    Ok(())
}

fn config_cmd(args: &[String]) -> Result<()> {
    let mut o = Opts::new(args);
    let sets = o.take_all("--set");
    let rest = o.finish()?;
    anyhow::ensure!(
        rest.first().map(|s| s.as_str()) == Some("dump"),
        "usage: pcstall config dump [--set k=v ...]"
    );
    let mut cfg = SimConfig::default();
    for s in sets {
        cfg.apply_override(&s)?;
    }
    print!("{}", cfg.to_toml());
    Ok(())
}
