//! `pcstall` — leader entrypoint + CLI.
//!
//! Subcommands (hand-rolled parser; offline environment has no clap):
//!
//! ```text
//! pcstall simulate  --workload <spec> --policy pcstall [--objective ed2p]
//!                   [--epochs N | --completion] [--epoch-ns X]
//!                   [--config file.toml] [--set k=v ...]
//!                   [--backend native|pjrt] [--json out.json]
//! pcstall serve     [--workload <spec>] [--policy p ...] [--objective o]
//!                   [--set serve.arrival_rate=0.02 ...] [--arrival-trace f]
//! pcstall run <id|all> [--quick|--full] [--out results/] [--pjrt]
//!                      [--jobs N] [--no-cache] [--workload <spec> ...]
//! pcstall experiment ...   (alias of `run`)
//! pcstall sweep <plan.toml|preset> [run options] [--shard i/N]
//! pcstall sweep merge <dir>
//! pcstall sweep plot <merged.csv> [--metric col] [--band minmax|iqr] [--out dir]
//! pcstall sweep list
//! pcstall trace record|replay|gen|info|ingest ...
//! pcstall cache stats|clear [--dir d] [--max-age days] [--max-bytes MB]
//! pcstall obs report [<dir>]
//! pcstall obs diff <dirA> <dirB>
//! pcstall obs plot [<dir>] [--out dir]
//! pcstall list
//! pcstall config dump [--set k=v ...]
//! pcstall config keys
//! pcstall table1
//! ```
//!
//! A workload `<spec>` is a catalog name (`comd`), a trace file
//! (`trace:path/to/file.trace`), or a synthesized trace (`synth:<seed>`).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::Result;

use pcstall::config::SimConfig;
use pcstall::dvfs::manager::{DvfsManager, Policy, RunMode};
use pcstall::dvfs::objective::Objective;
use pcstall::exec::cache::ResultCache;
use pcstall::exec::{pool, Engine, ShardSpec};
use pcstall::harness::sweep::{self, SweepPlan};
use pcstall::harness::{all_experiments, run_experiment, ExpOptions, Scale};
use pcstall::stats::emit::Json;
use pcstall::stats::plot;
use pcstall::trace::{capture_named, parse_accelsim, synthesize, Trace};
use pcstall::workloads::{self, WorkloadSource};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "simulate" => simulate(&args[1..]),
        "serve" => serve(&args[1..]),
        "run" | "experiment" => experiment(&args[1..]),
        "sweep" => sweep_cmd(&args[1..]),
        "trace" => trace_cmd(&args[1..]),
        "cache" => cache_cmd(&args[1..]),
        "obs" => obs_cmd(&args[1..]),
        "workloads" => workloads_cmd(&args[1..]),
        "list" => list(),
        "config" => config_cmd(&args[1..]),
        "table1" => run_experiment("table1", &ExpOptions::default()),
        "help" | "--help" | "-h" => {
            print!("{}", pcstall::help::HELP);
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}' (try `pcstall help`)"),
    }
}

/// Pull `--key value` / `--flag` options out of an arg list.
struct Opts {
    args: Vec<String>,
}

impl Opts {
    fn new(args: &[String]) -> Self {
        Opts {
            args: args.to_vec(),
        }
    }

    fn take(&mut self, key: &str) -> Option<String> {
        let pos = self.args.iter().position(|a| a == key)?;
        if pos + 1 >= self.args.len() {
            return None;
        }
        let v = self.args.remove(pos + 1);
        self.args.remove(pos);
        Some(v)
    }

    fn take_all(&mut self, key: &str) -> Vec<String> {
        let mut out = Vec::new();
        while let Some(v) = self.take(key) {
            out.push(v);
        }
        out
    }

    fn take_flag(&mut self, key: &str) -> bool {
        if let Some(pos) = self.args.iter().position(|a| a == key) {
            self.args.remove(pos);
            true
        } else {
            false
        }
    }

    fn finish(self) -> Result<Vec<String>> {
        for a in &self.args {
            if a.starts_with("--") {
                anyhow::bail!("unknown option: {a}");
            }
        }
        Ok(self.args)
    }
}

fn simulate(args: &[String]) -> Result<()> {
    let mut o = Opts::new(args);
    let workload = o
        .take("--workload")
        .ok_or_else(|| anyhow::anyhow!("--workload is required"))?;
    run_one(&workload, o)
}

/// `pcstall serve`: continuous-traffic DVFS under deadlines (see
/// `pcstall help`, SERVE OPTIONS).  One serve simulation per `--policy`
/// at a single operating point; load/deadline *axes* go through
/// `pcstall sweep` (`serve_load` preset or `[axis] serve.*` plans).
fn serve(args: &[String]) -> Result<()> {
    use pcstall::harness::serve::{run_serve, ServeSpec};

    let mut o = Opts::new(args);
    let workload = o.take("--workload").unwrap_or_else(|| "comd".into());
    let policies = {
        let named = o.take_all("--policy");
        if named.is_empty() {
            vec![
                Policy::Reactive(pcstall::models::EstModel::Crisp),
                Policy::PcStall,
            ]
        } else {
            named
                .iter()
                .map(|s| Policy::parse(s))
                .collect::<Result<Vec<_>>>()?
        }
    };
    let objective =
        Objective::parse(&o.take("--objective").unwrap_or_else(|| "deadline".into()))?;
    let epoch_ns = o.take("--epoch-ns").map(|s| s.parse::<f64>()).transpose()?;
    let sets = o.take_all("--set");
    let arrival_gaps_us = match o.take("--arrival-trace") {
        None => None,
        Some(path) => {
            let text = std::fs::read_to_string(&path)
                .map_err(|e| anyhow::anyhow!("reading --arrival-trace {path}: {e}"))?;
            let gaps: Vec<f64> = text
                .lines()
                .map(str::trim)
                .filter(|l| !l.is_empty() && !l.starts_with('#'))
                .map(|l| {
                    l.parse::<f64>().map_err(|_| {
                        anyhow::anyhow!(
                            "--arrival-trace {path}: bad inter-arrival gap '{l}' \
                             (expected one µs value per line)"
                        )
                    })
                })
                .collect::<Result<_>>()?;
            Some(gaps)
        }
    };
    let opts = exp_options_from(&mut o)?;
    let rest = o.finish()?;
    anyhow::ensure!(
        rest.is_empty(),
        "unexpected argument(s): {} (serve takes options only)",
        rest.join(" ")
    );

    let mut cfg = opts.base_cfg();
    for s in sets {
        cfg.apply_override(&s)?;
    }
    if let Some(e) = epoch_ns {
        cfg.dvfs.epoch_ns = e;
    }
    if let Some(st) = opts.sim_threads {
        cfg.gpu.sim_threads = st;
    }

    let spec = ServeSpec {
        workload,
        policies,
        objective,
        arrival_gaps_us,
    };
    let t0 = std::time::Instant::now();
    let path = run_serve(&opts, cfg, &spec)?;
    flush_obs(&opts)?;
    println!("\n{}", opts.engine.summary(opts.jobs));
    println!("[serve done in {:.1?}] wrote {}", t0.elapsed(), path.display());
    Ok(())
}

/// Shared engine of `simulate` and `trace replay`: run one workload spec
/// (catalog / trace file / synth seed) and print the result.
fn run_one(spec: &str, mut o: Opts) -> Result<()> {
    let policy = Policy::parse(&o.take("--policy").unwrap_or_else(|| "pcstall".into()))?;
    let objective = Objective::parse(&o.take("--objective").unwrap_or_else(|| "ed2p".into()))?;
    let epochs = o.take("--epochs").map(|s| s.parse::<u64>()).transpose()?;
    let epoch_ns = o.take("--epoch-ns").map(|s| s.parse::<f64>()).transpose()?;
    let waves_flag = o.take("--waves-scale").map(|s| s.parse::<f64>()).transpose()?;
    let cfg_path = o.take("--config");
    let sets = o.take_all("--set");
    let backend = o.take("--backend").unwrap_or_else(|| "native".into());
    let json_out = o.take("--json").map(PathBuf::from);
    let sim_threads = o
        .take("--sim-threads")
        .map(|s| s.parse::<usize>())
        .transpose()?;
    o.finish()?;

    let mut cfg = match cfg_path {
        Some(p) => SimConfig::from_path(Path::new(&p))?,
        None => {
            let mut c = SimConfig::default();
            c.gpu.n_cu = 8;
            c.gpu.n_wf = 16;
            c
        }
    };
    for s in sets {
        cfg.apply_override(&s)?;
    }
    if let Some(e) = epoch_ns {
        cfg.dvfs.epoch_ns = e;
    }
    if let Some(st) = sim_threads {
        cfg.gpu.sim_threads = st;
    }

    let source = WorkloadSource::parse(spec)?;
    // traces carry their recorded length; catalog runs default short
    let waves = waves_flag.unwrap_or(match &source {
        WorkloadSource::Catalog(_) => 0.1,
        _ => 1.0,
    });
    let resolved = source.resolve()?;
    let (launches, rounds) = resolved.lower(waves);

    let mut mgr = match backend.as_str() {
        "native" => DvfsManager::from_launches(cfg, launches, rounds, policy, objective),
        "pjrt" => DvfsManager::from_launches_with_backend(
            cfg,
            launches,
            rounds,
            policy,
            objective,
            pcstall::runtime::best_backend(None),
        ),
        other => anyhow::bail!("unknown backend '{other}'"),
    };
    let mode = match epochs {
        Some(n) => RunMode::Epochs(n),
        None => RunMode::Completion {
            max_epochs: 200_000,
        },
    };
    let t0 = std::time::Instant::now();
    let r = mgr.run(mode, &resolved.display);
    let dt = t0.elapsed();

    println!("workload   : {}", r.workload);
    println!("policy     : {}", r.policy);
    println!("objective  : {}", r.objective);
    println!("epochs     : {} ({}completed)", r.records.len(), if r.completed { "" } else { "NOT " });
    println!("sim time   : {:.3} ms simulated in {:.2?}", r.total_time_ns / 1e6, dt);
    println!("instructions: {:.3e}", r.total_instr);
    println!("energy     : {:.6} J", r.total_energy_j);
    println!("EDP        : {:.4e} J*s", r.edp());
    println!("ED2P       : {:.4e} J*s^2", r.ed2p());
    println!("accuracy   : {:.3}", r.mean_accuracy);
    let share = r.freq_time_share();
    println!(
        "freq share : {}",
        share
            .iter()
            .enumerate()
            .filter(|(_, s)| **s > 0.005)
            .map(|(k, s)| format!("{:.1}GHz:{:.0}%", 1.3 + 0.1 * k as f64, s * 100.0))
            .collect::<Vec<_>>()
            .join(" ")
    );

    if let Some(path) = json_out {
        let j = Json::obj(vec![
            ("workload", Json::Str(r.workload.clone())),
            ("policy", Json::Str(r.policy.clone())),
            ("objective", Json::Str(r.objective.clone())),
            ("epochs", Json::Num(r.records.len() as f64)),
            ("completed", Json::Bool(r.completed)),
            ("total_instr", Json::Num(r.total_instr)),
            ("energy_j", Json::Num(r.total_energy_j)),
            ("time_ns", Json::Num(r.total_time_ns)),
            ("edp", Json::Num(r.edp())),
            ("ed2p", Json::Num(r.ed2p())),
            ("accuracy", Json::Num(r.mean_accuracy)),
            ("freq_share", Json::nums(&share)),
        ]);
        j.write(&path)?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

/// Build the shared experiment/sweep options from an arg list (scale,
/// output dir, jobs, cache, seed, workload overrides).
fn exp_options_from(o: &mut Opts) -> Result<ExpOptions> {
    let mut opts = ExpOptions::default();
    if o.take_flag("--quick") {
        opts.scale = Scale::Quick;
    }
    if o.take_flag("--full") {
        opts.scale = Scale::Full;
    }
    if let Some(dir) = o.take("--out") {
        opts.out_dir = PathBuf::from(dir);
    }
    opts.use_pjrt = o.take_flag("--pjrt");
    if let Some(seed) = o.take("--seed") {
        opts.seed = seed.parse()?;
    }
    opts.jobs = match o.take("--jobs") {
        Some(n) => n.parse::<usize>()?.max(1),
        None => pool::default_jobs(),
    };
    opts.sim_threads = o
        .take("--sim-threads")
        .map(|s| s.parse::<usize>())
        .transpose()?;
    // validate specs now for early errors; leak the handful of argv
    // strings (once per process) to satisfy the harness's &'static set
    for spec in o.take_all("--workload") {
        WorkloadSource::parse(&spec)?;
        opts.workloads_override
            .push(&*Box::leak(spec.into_boxed_str()));
    }
    let no_cache = o.take_flag("--no-cache");
    opts.progress = o.take_flag("--progress");
    if let Some(dir) = o.take("--obs") {
        opts.obs = Some(Arc::new(pcstall::obs::ObsRecorder::new(PathBuf::from(dir))));
    }
    let mut engine = if no_cache {
        Engine::no_cache()
    } else {
        Engine::with_cache_dir(opts.out_dir.join("cache"))
    };
    engine.set_progress(opts.progress);
    engine.set_obs(opts.obs.clone());
    opts.engine = Arc::new(engine);
    Ok(opts)
}

/// Flush a `--obs` recorder's artifacts to its directory (no-op when
/// obs is off).  Counter sidecars only cover *executed* cells, so
/// byte-determinism gates should pair `--obs` with `--no-cache`.
fn flush_obs(opts: &ExpOptions) -> Result<()> {
    if let Some(rec) = &opts.obs {
        let paths = rec.write().map_err(|e| anyhow::anyhow!(e))?;
        for p in paths {
            println!("[obs] wrote {}", p.display());
        }
    }
    Ok(())
}

fn experiment(args: &[String]) -> Result<()> {
    let mut o = Opts::new(args);
    let opts = exp_options_from(&mut o)?;
    let rest = o.finish()?;
    let id = rest.first().map(|s| s.as_str()).unwrap_or("all");
    let t0 = std::time::Instant::now();
    run_experiment(id, &opts)?;
    flush_obs(&opts)?;
    println!("\n{}", opts.engine.summary(opts.jobs));
    println!("[experiment {id} done in {:.1?}]", t0.elapsed());
    Ok(())
}

// ---------------------------------------------------------------------------
// `pcstall sweep ...`
// ---------------------------------------------------------------------------

fn sweep_cmd(args: &[String]) -> Result<()> {
    match args.first().map(|s| s.as_str()) {
        None | Some("list") => {
            println!("sweep presets (axes rendered from the plans themselves):");
            for p in sweep::preset_names() {
                let plan = SweepPlan::preset(p).expect("preset_names lists only presets");
                println!("  {p}");
                for line in plan.describe() {
                    println!("      {line}");
                }
            }
            println!(
                "\nplan file grammar (TOML subset; every key optional):\n\
                 \n\
                 name = \"my_sweep\"\n\
                 epoch_ns = [1000, 10000, 50000, 100000]  # epoch-length axis (ns)\n\
                 cus_per_domain = [1, 2, 4]               # V/f-domain granularity axis\n\
                 workloads = [\"comd\", \"trace:t.trace\", \"synth:7\", \"exec:matmul:512\"]  # workload-source axis\n\
                 workloads_add = [\"synth:7\"]              # or: scale's sweep set + extras\n\
                 seed = [2, 3, 5]                         # synth-seed population axis\n\
                 designs = [\"crisp\", \"pcstall\", \"oracle\"]  # predictor-design axis\n\
                 objectives = [\"ed2p\"]                    # edp | ed2p | energy@<pct> | deadline\n\
                 baseline = \"static:1.7\"                  # improvement reference\n\
                 epochs = 40                              # fixed epochs (default: completion)\n\
                 mode = \"serve\"                           # continuous-arrival serve cells\n\
                 [set]                                    # config overrides for every cell\n\
                 gpu.n_wf = 16\n\
                 [axis]                                   # config-key grid dimensions\n\
                 \"dvfs.transition_ns\" = [5, 20, 100, 1000]\n\
                 \n\
                 any `pcstall config keys` entry can be an [axis] dimension (one CSV\n\
                 column per key); a key under both [set] and [axis] is a parse error.\n\
                 with a seed axis, workloads defaults to the bare \"synth\" template\n\
                 (each grid point runs synth:<seed>); the CSV carries a seed column.\n\
                 mode = \"serve\" runs every cell through the continuous-arrival serve\n\
                 loop (sweep serve.* keys as [axis] dimensions — e.g. the serve_load\n\
                 preset's serve.arrival_rate axis) and appends p50_us/p99_us/miss_rate\n\
                 columns to the CSV\n\
                 \n\
                 run:   pcstall sweep <plan> [--quick|--full] [--jobs N] [--shard i/N]\n\
                 merge: pcstall sweep merge <dir>\n\
                 plot:  pcstall sweep plot <merged.csv> [--metric col] [--band minmax|iqr]\n\
                        [--out dir]"
            );
            Ok(())
        }
        Some("plot") => {
            let mut o = Opts::new(&args[1..]);
            let metric = o
                .take("--metric")
                .unwrap_or_else(|| plot::DEFAULT_METRIC.into());
            let band = plot::Band::parse(&o.take("--band").unwrap_or_else(|| "minmax".into()))?;
            let out_dir = o.take("--out").map(PathBuf::from);
            let rest = o.finish()?;
            anyhow::ensure!(
                rest.len() == 1,
                "usage: pcstall sweep plot <merged.csv> [--metric col] [--band minmax|iqr] \
                 [--out dir]"
            );
            let (gp, py) =
                plot::emit_plot_scripts(Path::new(&rest[0]), &metric, band, out_dir.as_deref())?;
            println!("wrote {}", gp.display());
            println!("wrote {}", py.display());
            // the scripts write their PNG into the invoker's cwd, so
            // render from the scripts' own directory
            let dir = gp.parent().unwrap_or_else(|| Path::new("."));
            let file =
                |p: &Path| p.file_name().unwrap_or_default().to_string_lossy().into_owned();
            println!(
                "render: (cd {} && gnuplot {})   # or: python3 {}",
                dir.display(),
                file(&gp),
                file(&py)
            );
            Ok(())
        }
        Some("merge") => {
            let mut o = Opts::new(&args[1..]);
            let rest = o.finish()?;
            anyhow::ensure!(
                rest.len() <= 1,
                "sweep merge takes one directory, got: {}",
                rest.join(" ")
            );
            let dir = rest
                .first()
                .map(|s| s.as_str())
                .unwrap_or("results");
            let written = sweep::merge_dir(Path::new(dir))?;
            println!(
                "merged {} sweep(s) in {dir}",
                written.len()
            );
            Ok(())
        }
        Some(plan_ref) => {
            let mut o = Opts::new(&args[1..]);
            let shard = match o.take("--shard") {
                Some(s) => ShardSpec::parse(&s)?,
                None => ShardSpec::whole(),
            };
            let opts = exp_options_from(&mut o)?;
            let rest = o.finish()?;
            anyhow::ensure!(
                rest.is_empty(),
                "unexpected argument(s) after the plan: {}",
                rest.join(" ")
            );
            let plan = SweepPlan::load(plan_ref)?;
            let t0 = std::time::Instant::now();
            let path = sweep::run_sweep(&opts, &plan, shard)?;
            flush_obs(&opts)?;
            println!("\n{}", opts.engine.summary(opts.jobs));
            if shard.count > 1 {
                println!(
                    "[sweep {} shard {shard} done in {:.1?}] merge with: pcstall sweep merge {}",
                    plan.name,
                    t0.elapsed(),
                    opts.out_dir.display()
                );
            } else {
                println!(
                    "[sweep {} done in {:.1?}] wrote {}",
                    plan.name,
                    t0.elapsed(),
                    path.display()
                );
            }
            Ok(())
        }
    }
}

// ---------------------------------------------------------------------------
// `pcstall trace ...`
// ---------------------------------------------------------------------------

fn trace_cmd(args: &[String]) -> Result<()> {
    let verb = args.first().map(|s| s.as_str()).unwrap_or("");
    match verb {
        "record" => trace_record(&args[1..]),
        "replay" => {
            let file = args
                .get(1)
                .ok_or_else(|| anyhow::anyhow!("usage: pcstall trace replay <file> [options]"))?;
            run_one(&format!("trace:{file}"), Opts::new(&args[2..]))
        }
        "gen" => trace_gen(&args[1..]),
        "info" => {
            let file = args
                .get(1)
                .ok_or_else(|| anyhow::anyhow!("usage: pcstall trace info <file>"))?;
            trace_info(Path::new(file))
        }
        "ingest" => trace_ingest(&args[1..]),
        "diff" => {
            let (a, b) = match (args.get(1), args.get(2)) {
                (Some(a), Some(b)) => (a, b),
                _ => anyhow::bail!("usage: pcstall trace diff <a> <b>"),
            };
            let ta = Trace::load(Path::new(a))?;
            let tb = Trace::load(Path::new(b))?;
            print!("{}", pcstall::trace::diff(&ta, &tb).render(a, b));
            Ok(())
        }
        _ => anyhow::bail!("usage: pcstall trace record|replay|gen|info|ingest|diff ..."),
    }
}

/// Default on-disk location for a captured/generated trace.
fn default_trace_path(name: &str) -> PathBuf {
    PathBuf::from("traces").join(format!("{name}.trace"))
}

fn save_and_report(trace: &Trace, out: Option<String>, binary: bool) -> Result<()> {
    let path = out
        .map(PathBuf::from)
        .unwrap_or_else(|| default_trace_path(&trace.name));
    trace.save(&path, binary)?;
    let records: usize = trace.kernels.iter().map(|k| k.records.len()).sum();
    println!(
        "wrote {} ({} encoding, {} kernel(s), {} records, rounds {})",
        path.display(),
        if binary { "binary" } else { "text" },
        trace.kernels.len(),
        records,
        trace.rounds,
    );
    println!("content hash: {}", trace.content_hash());
    println!("replay with : pcstall trace replay {}", path.display());
    Ok(())
}

fn trace_record(args: &[String]) -> Result<()> {
    let spec = args
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: pcstall trace record <spec> [options]"))?;
    let mut o = Opts::new(&args[1..]);
    let out = o.take("--out");
    let binary = o.take_flag("--binary");
    let waves_flag = o.take("--waves-scale").map(|s| s.parse::<f64>()).transpose()?;
    o.finish()?;

    let trace = match WorkloadSource::parse(spec)? {
        // same default length as `pcstall simulate`, so record → replay
        // reproduces the default direct run
        WorkloadSource::Catalog(name) => capture_named(&name, waves_flag.unwrap_or(0.1))?,
        // for already-recorded geometry, --waves-scale is baked into the
        // written file (e.g. down-scale a big trace for CI)
        WorkloadSource::Synth(seed) => scale_trace(synthesize(seed), waves_flag),
        // re-encode an existing file (text <-> binary conversion)
        WorkloadSource::TraceFile(path) => scale_trace(Trace::load(&path)?, waves_flag),
        // lower the kernel, then bake any waves multiplier in
        WorkloadSource::Exec { kernel, size } => {
            scale_trace(workloads::exec::lower(&kernel, size)?, waves_flag)
        }
    };
    save_and_report(&trace, out, binary)
}

/// Bake a waves multiplier into a trace's recorded launch geometry.
fn scale_trace(mut t: Trace, waves: Option<f64>) -> Trace {
    if let Some(w) = waves {
        for k in &mut t.kernels {
            k.waves_per_cu = ((k.waves_per_cu as f64 * w).round() as u64).max(1);
        }
    }
    t
}

fn trace_gen(args: &[String]) -> Result<()> {
    let mut o = Opts::new(args);
    let seed: u64 = o.take("--seed").unwrap_or_else(|| "1".into()).parse()?;
    let out = o.take("--out");
    let binary = o.take_flag("--binary");
    o.finish()?;
    save_and_report(&synthesize(seed), out, binary)
}

fn trace_info(path: &Path) -> Result<()> {
    let trace = Trace::load(path)?;
    println!("trace      : {}", path.display());
    println!("name       : {}", trace.name);
    println!("source     : {}", trace.source);
    println!("rounds     : {}", trace.rounds);
    println!("content    : {}", trace.content_hash());
    println!("kernels    : {}", trace.kernels.len());
    for k in &trace.kernels {
        let s = k.stats();
        println!(
            "  [{}] {:<20} waves/cu {:<5} static {:<6} dyn/wave {:<9} \
             valu {} salu {} ld {} st {} wait {} bar {} loop {}",
            k.kernel_id,
            k.name,
            k.waves_per_cu,
            s.static_records,
            s.dyn_per_wave,
            s.valu,
            s.salu,
            s.loads,
            s.stores,
            s.waitcnts,
            s.barriers,
            s.loops,
        );
    }
    println!("dyn instr/CU (all rounds): {:.3e}", trace.dyn_instrs_per_cu() as f64);
    Ok(())
}

fn trace_ingest(args: &[String]) -> Result<()> {
    let file = args
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: pcstall trace ingest <accel-sim-file> [options]"))?;
    let mut o = Opts::new(&args[1..]);
    let out = o.take("--out");
    let binary = o.take_flag("--binary");
    o.finish()?;
    let text = std::fs::read_to_string(file)
        .map_err(|e| anyhow::anyhow!("reading {file}: {e}"))?;
    let ingested = parse_accelsim(&text, file)
        .map_err(|e| anyhow::anyhow!("ingesting {file}: {e}"))?;
    for w in &ingested.warnings {
        eprintln!("warning: {w}");
    }
    save_and_report(&ingested.trace, out, binary)
}

// ---------------------------------------------------------------------------
// `pcstall cache ...`
// ---------------------------------------------------------------------------

fn cache_cmd(args: &[String]) -> Result<()> {
    let verb = args.first().map(|s| s.as_str()).unwrap_or("");
    let mut o = Opts::new(args.get(1..).unwrap_or(&[]));
    let dir = PathBuf::from(o.take("--dir").unwrap_or_else(|| "results/cache".into()));
    match verb {
        "stats" => {
            o.finish()?;
            let cache = ResultCache::at(dir.clone());
            let s = cache.disk_stats();
            println!("cache dir  : {}", dir.display());
            println!("entries    : {} ({} valid, {} corrupt)", s.entries, s.valid, s.corrupt);
            println!("bytes      : {:.2} MB", s.bytes as f64 / (1 << 20) as f64);
            if s.entries > 0 {
                println!(
                    "entry age  : {:.1} h oldest, {:.1} h newest",
                    s.oldest_secs as f64 / 3600.0,
                    s.newest_secs as f64 / 3600.0
                );
            }
            println!("(hit/miss accounting is per-invocation: see the [exec] summary line)");
            Ok(())
        }
        "clear" => {
            let max_age_days = o.take("--max-age").map(|s| s.parse::<f64>()).transpose()?;
            let max_mb = o.take("--max-bytes").map(|s| s.parse::<f64>()).transpose()?;
            o.finish()?;
            let cache = ResultCache::at(dir.clone());
            let (age, bytes) = match (max_age_days, max_mb) {
                // no bound given: clear everything
                (None, None) => (Some(0), None),
                (a, b) => (
                    a.map(|d| (d * 86_400.0).max(0.0) as u64),
                    b.map(|m| (m * (1 << 20) as f64).max(0.0) as u64),
                ),
            };
            let (removed, freed) = cache.gc(age, bytes);
            println!(
                "removed {removed} entr{} ({:.2} MB) from {}",
                if removed == 1 { "y" } else { "ies" },
                freed as f64 / (1 << 20) as f64,
                dir.display()
            );
            Ok(())
        }
        _ => anyhow::bail!("usage: pcstall cache stats|clear [--dir d] [--max-age days] [--max-bytes MB]"),
    }
}

// ---------------------------------------------------------------------------
// `pcstall obs ...`
// ---------------------------------------------------------------------------

fn obs_cmd(args: &[String]) -> Result<()> {
    match args.first().map(|s| s.as_str()) {
        Some("report") => {
            let o = Opts::new(&args[1..]);
            let rest = o.finish()?;
            anyhow::ensure!(rest.len() <= 1, "usage: pcstall obs report [<dir>]");
            let dir = rest.first().map(|s| s.as_str()).unwrap_or("results/obs");
            pcstall::obs::report(Path::new(dir)).map_err(|e| anyhow::anyhow!(e))
        }
        Some("diff") => {
            let o = Opts::new(&args[1..]);
            let rest = o.finish()?;
            anyhow::ensure!(rest.len() == 2, "usage: pcstall obs diff <dirA> <dirB>");
            let (a, b) = (Path::new(&rest[0]), Path::new(&rest[1]));
            let summary = pcstall::obs::diff_decisions(a, b).map_err(|e| anyhow::anyhow!(e))?;
            pcstall::obs::print_diff(a, b, &summary);
            Ok(())
        }
        Some("plot") => {
            let mut o = Opts::new(&args[1..]);
            let out_dir = o.take("--out").map(PathBuf::from);
            let rest = o.finish()?;
            anyhow::ensure!(rest.len() <= 1, "usage: pcstall obs plot [<dir>] [--out dir]");
            let dir = rest.first().map(|s| s.as_str()).unwrap_or("results/obs");
            let (gp, py) = plot::emit_decision_timeline(Path::new(dir), out_dir.as_deref())?;
            println!("wrote {}", gp.display());
            println!("wrote {}", py.display());
            let script_dir = gp.parent().unwrap_or_else(|| Path::new("."));
            let file =
                |p: &Path| p.file_name().unwrap_or_default().to_string_lossy().into_owned();
            println!(
                "render: (cd {} && gnuplot {})   # or: python3 {}",
                script_dir.display(),
                file(&gp),
                file(&py)
            );
            Ok(())
        }
        _ => anyhow::bail!("usage: pcstall obs report|diff|plot ..."),
    }
}

fn list() -> Result<()> {
    println!("workloads (paper Table II):");
    for w in workloads::names() {
        let spec = workloads::build(w, 1.0);
        println!("  {:<10} {} kernel(s)", w, spec.kernels.len());
    }
    println!("\npolicies (paper Table III):");
    for p in ["stall", "lead", "crit", "crisp", "accreac", "pcstall", "accpc", "oracle", "static:<ghz>"] {
        println!("  {p}");
    }
    println!("\nexperiments:");
    for e in all_experiments() {
        println!("  {e}");
    }
    println!(
        "\nworkload specs: any name above, trace:<path>, synth:<seed>, \
         exec:<kernel>[:<size>] (see `pcstall workloads list`)"
    );
    Ok(())
}

fn workloads_cmd(args: &[String]) -> Result<()> {
    let verb = args.first().map(|s| s.as_str()).unwrap_or("list");
    match verb {
        "list" => {
            let o = Opts::new(&args[1..]);
            let rest = o.finish()?;
            anyhow::ensure!(rest.is_empty(), "usage: pcstall workloads list");
            println!("catalog workloads (paper Table II generators):");
            for w in workloads::names() {
                let spec = workloads::build(w, 1.0);
                println!("  {:<10} {} kernel(s)", w, spec.kernels.len());
            }
            println!("\nexec kernels (executable Rust kernels, lowered to traces on demand):");
            println!(
                "  {:<10} {:<22} {:>9} {:>9} {:>9}  {}",
                "name", "size parameter", "min", "max", "default", "about"
            );
            for k in workloads::exec::kernels() {
                println!(
                    "  {:<10} {:<22} {:>9} {:>9} {:>9}  {}",
                    k.name, k.size_doc, k.min_size, k.max_size, k.default_size, k.about
                );
            }
            println!("  (sizes are powers of two; `exec:<kernel>` uses the default)");
            println!("\nworkload spec grammar (accepted wherever a workload is named):");
            println!("  <name>                  catalog workload above");
            println!("  trace:<path>            trace file, text or binary (`pcstall trace`)");
            println!("  synth:<seed>            seeded synthesized trace");
            println!("  exec:<kernel>[:<size>]  executable kernel at <size>");
            Ok(())
        }
        _ => anyhow::bail!("usage: pcstall workloads list"),
    }
}

fn config_cmd(args: &[String]) -> Result<()> {
    let verb = args.first().map(|s| s.as_str()).unwrap_or("");
    match verb {
        "dump" => {
            let mut o = Opts::new(&args[1..]);
            let sets = o.take_all("--set");
            let rest = o.finish()?;
            anyhow::ensure!(rest.is_empty(), "usage: pcstall config dump [--set k=v ...]");
            let mut cfg = SimConfig::default();
            for s in sets {
                cfg.apply_override(&s)?;
            }
            print!("{}", cfg.to_toml());
            Ok(())
        }
        "keys" => {
            let o = Opts::new(&args[1..]);
            let rest = o.finish()?;
            anyhow::ensure!(rest.is_empty(), "usage: pcstall config keys");
            let schema = pcstall::config::registry::key_schema();
            println!(
                "{} config keys (usable in --set k=v, plan [set] tables, and plan \
                 [axis] grid dimensions):\n",
                schema.keys().len()
            );
            let width = schema
                .keys()
                .iter()
                .map(|d| d.path.len())
                .max()
                .unwrap_or(0);
            for d in schema.keys() {
                println!(
                    "  {:<width$}  {:<5}  {:<22}  {}",
                    d.path,
                    d.kind.name(),
                    d.default,
                    d.doc,
                    width = width
                );
            }
            Ok(())
        }
        _ => anyhow::bail!("usage: pcstall config dump [--set k=v ...] | pcstall config keys"),
    }
}
