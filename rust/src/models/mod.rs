//! Frequency-sensitivity estimation models (paper §2.3, Table III).
//!
//! Every CU-level model reduces the elapsed epoch to an *(asynchronous
//! time, core time)* split at the operating frequency f₁, then converts
//! to the linear `(S, I0)` phase estimate by evaluating the classic DVFS
//! time-scaling identity
//!
//! `T(f₂) = T_async + (f₁ / f₂) · T_core`
//!
//! at the ladder endpoints.  The wavefront-level model (PCSTALL's
//! estimator) works per wavefront instead and is the native mirror of the
//! Pallas `wf_sensitivity` kernel.

use crate::config::SimConfig;
use crate::dvfs::sensitivity::SensEstimate;
use crate::power::params::{FREQS_GHZ, N_FREQ};
use crate::sim::cu::EpochCounters;
use crate::sim::gpu::EpochObservation;
use crate::sim::ps_to_ns;

/// CU-level estimation models from the literature (paper Table III).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EstModel {
    /// Stall model [Keramidas'10]: async time = cycles with no issue while
    /// memory-blocked.  Ignores memory-level parallelism.
    Stall,
    /// Leading Load [Keramidas'10, Eyerman'10, Rountree'11]: async time =
    /// accumulated latency of loads issued with no other load in flight.
    Lead,
    /// Critical Path [Miftakhutdinov'12]: async time = intervals where the
    /// oldest (criticality proxy) wavefront is memory-blocked.
    Crit,
    /// CRISP [Nath & Tullsen '15]: Critical-path extended with GPU store
    /// stalls and compute/memory overlap credit.
    Crisp,
}

impl EstModel {
    pub fn name(&self) -> &'static str {
        match self {
            EstModel::Stall => "STALL",
            EstModel::Lead => "LEAD",
            EstModel::Crit => "CRIT",
            EstModel::Crisp => "CRISP",
        }
    }

    pub fn all() -> [EstModel; 4] {
        [EstModel::Stall, EstModel::Lead, EstModel::Crit, EstModel::Crisp]
    }

    /// Asynchronous (frequency-independent) time for the epoch, ns.
    fn t_async_ns(&self, c: &EpochCounters) -> f64 {
        let epoch = ps_to_ns(c.epoch_ps);
        let t = match self {
            EstModel::Stall => ps_to_ns(c.stall_all_ps),
            EstModel::Lead => ps_to_ns(c.lead_load_ps),
            EstModel::Crit => ps_to_ns(c.crit_ps),
            EstModel::Crisp => {
                // Store stalls add memory time the CRIT proxy misses;
                // issue/memory overlap is compute the CU got "for free"
                // during memory waits and is credited back to core time.
                let base = ps_to_ns(c.crit_ps) + ps_to_ns(c.store_stall_ps);
                base - 0.5 * ps_to_ns(c.overlap_ps).min(base)
            }
        };
        t.clamp(0.0, epoch)
    }
}

/// Estimate a CU's `(S, I0)` from its epoch counters.
pub fn estimate_cu(model: EstModel, c: &EpochCounters) -> SensEstimate {
    let epoch_ns = ps_to_ns(c.epoch_ps);
    let i1 = c.instr as f64;
    if epoch_ns <= 0.0 || i1 <= 0.0 {
        return SensEstimate::default();
    }
    let f1 = c.freq_ghz;
    let t_async = model.t_async_ns(c);
    let t_core = epoch_ns - t_async;

    // Fixed work (i1) takes T(f2) = t_async + t_core * f1/f2; a fixed-time
    // epoch therefore commits I(f2) = i1 * epoch / T(f2).
    let i_at = |f2: f64| -> f64 {
        let t = t_async + t_core * f1 / f2;
        if t <= 1e-9 {
            i1
        } else {
            i1 * epoch_ns / t
        }
    };
    let (f_lo, f_hi) = (FREQS_GHZ[0], FREQS_GHZ[N_FREQ - 1]);
    let sens = (i_at(f_hi) - i_at(f_lo)) / (f_hi - f_lo);
    let i0 = (i1 - sens * f1).max(0.0);
    SensEstimate::new(sens, i0)
}

/// Wavefront-level STALL estimate for one slot — the native mirror of the
/// Pallas `wf_sensitivity` kernel (python/compile/kernels/sensitivity.py).
/// IPC is the epoch-wide commit rate (instr per epoch cycle at f).
#[inline]
pub fn estimate_wf(
    instr: f64,
    t_core_ns: f64,
    age_factor: f64,
    freq_ghz: f64,
    epoch_ns: f64,
) -> SensEstimate {
    const EPS: f64 = 1e-6;
    let cycles_epoch = epoch_ns * freq_ghz;
    let ipc = instr / cycles_epoch.max(EPS);
    let sens = ipc * t_core_ns * age_factor;
    // Per-WF intercept (clamped at CU aggregation, matching the kernel).
    let i0 = instr - sens * freq_ghz;
    SensEstimate::new(sens, i0)
}

/// Per-CU wavefront-aggregated estimates for a whole observation
/// (the update path of PCSTALL).  Returns (per-CU per-slot, per-CU sums).
pub fn estimate_wf_all(
    ob: &EpochObservation,
    _cfg: &SimConfig,
) -> (Vec<Vec<SensEstimate>>, Vec<SensEstimate>) {
    let mut per_wf = Vec::with_capacity(ob.cu.len());
    let mut per_cu = Vec::with_capacity(ob.cu.len());
    for c in 0..ob.cu.len() {
        let f = ob.cu[c].freq_ghz;
        let mut slots = Vec::with_capacity(ob.wf_instr[c].len());
        let mut sum_sens = 0.0;
        let mut sum_instr = 0.0;
        for w in 0..ob.wf_instr[c].len() {
            let e = estimate_wf(
                ob.wf_instr[c][w] as f64,
                ob.wf_core_ns[c][w] as f64,
                ob.wf_age_factor[c][w] as f64,
                f,
                ob.epoch_ns,
            );
            sum_sens += e.sens;
            sum_instr += ob.wf_instr[c][w] as f64;
            slots.push(e);
        }
        let i0_cu = (sum_instr - sum_sens * f).max(0.0);
        per_wf.push(slots);
        per_cu.push(SensEstimate::new(sum_sens, i0_cu));
    }
    (per_wf, per_cu)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::ns_to_ps;

    fn counters(
        instr: u64,
        epoch_ns: f64,
        f: f64,
        stall_ns: f64,
        lead_ns: f64,
        crit_ns: f64,
    ) -> EpochCounters {
        EpochCounters {
            instr,
            epoch_ps: ns_to_ps(epoch_ns),
            freq_ghz: f,
            stall_all_ps: ns_to_ps(stall_ns),
            lead_load_ps: ns_to_ps(lead_ns),
            crit_ps: ns_to_ps(crit_ns),
            ..EpochCounters::default()
        }
    }

    #[test]
    fn pure_compute_epoch_has_full_sensitivity() {
        // no async time: instructions scale ∝ f
        let c = counters(1700, 1000.0, 1.7, 0.0, 0.0, 0.0);
        for m in EstModel::all() {
            let e = estimate_cu(m, &c);
            // I(f) = 1000 * f exactly => S = 1000, I0 = 0
            assert!((e.sens - 1000.0).abs() < 1.0, "{m:?}: {e:?}");
            assert!(e.i0.abs() < 1.0, "{m:?}: {e:?}");
        }
    }

    #[test]
    fn fully_async_epoch_has_zero_sensitivity() {
        let c = counters(200, 1000.0, 1.7, 1000.0, 1000.0, 1000.0);
        for m in EstModel::all() {
            let e = estimate_cu(m, &c);
            assert!(e.sens.abs() < 1e-6, "{m:?}: {e:?}");
            assert!((e.i0 - 200.0).abs() < 1e-6);
        }
    }

    #[test]
    fn half_stalled_epoch_interpolates() {
        let c = counters(1000, 1000.0, 1.7, 500.0, 500.0, 500.0);
        let e = estimate_cu(EstModel::Stall, &c);
        assert!(e.sens > 100.0 && e.sens < 1000.0, "{e:?}");
        // prediction at f1 must reproduce the observation
        assert!((e.instr_at(1.7) - 1000.0).abs() < 1.0);
    }

    #[test]
    fn zero_instr_epoch_is_neutral() {
        let c = counters(0, 1000.0, 1.7, 100.0, 0.0, 0.0);
        for m in EstModel::all() {
            assert_eq!(estimate_cu(m, &c), SensEstimate::default());
        }
    }

    #[test]
    fn crisp_overlap_credit_raises_sensitivity() {
        let mut c = counters(1000, 1000.0, 1.7, 600.0, 600.0, 600.0);
        let no_overlap = estimate_cu(EstModel::Crisp, &c);
        c.overlap_ps = ns_to_ps(400.0);
        let with_overlap = estimate_cu(EstModel::Crisp, &c);
        assert!(
            with_overlap.sens > no_overlap.sens,
            "overlap credit must shift time toward core: {no_overlap:?} vs {with_overlap:?}"
        );
    }

    #[test]
    fn crisp_store_stalls_lower_sensitivity() {
        let mut c = counters(1000, 1000.0, 1.7, 300.0, 300.0, 300.0);
        let without = estimate_cu(EstModel::Crisp, &c);
        c.store_stall_ps = ns_to_ps(300.0);
        let with = estimate_cu(EstModel::Crisp, &c);
        assert!(with.sens < without.sens);
    }

    #[test]
    fn estimate_at_operating_point_is_consistent() {
        // All models must reproduce the measured I at the measured f.
        let c = counters(1234, 1000.0, 2.0, 313.0, 288.0, 300.0);
        for m in EstModel::all() {
            let e = estimate_cu(m, &c);
            assert!(
                (e.instr_at(2.0) - 1234.0).abs() < 2.0,
                "{m:?} inconsistent at f1: {e:?}"
            );
        }
    }

    #[test]
    fn wf_estimate_matches_kernel_semantics() {
        // ipc = instr / (epoch * f); sens = ipc * t_core * age
        let e = estimate_wf(800.0, 400.0, 0.5, 2.0, 1000.0);
        let ipc = 800.0 / (1000.0 * 2.0);
        assert!((e.sens - ipc * 400.0 * 0.5).abs() < 1e-9);
        assert!((e.i0 - (800.0 - e.sens * 2.0)).abs() < 1e-9);
    }

    #[test]
    fn wf_estimate_zero_core_time() {
        let e = estimate_wf(100.0, 0.0, 1.0, 2.0, 1000.0);
        assert!(e.sens.abs() < 1e-3);
        assert!((e.i0 - 100.0).abs() < 1e-3);
    }

    #[test]
    fn wf_estimate_fully_busy_wavefront_recovers_rate() {
        // WF always unstalled committing 1 instr/cycle at f=2 over a 1µs
        // epoch: sens = dI/df = epoch_ns
        let epoch = 1000.0;
        let f = 2.0;
        let e = estimate_wf(epoch * f, epoch, 1.0, f, epoch);
        assert!((e.sens - epoch).abs() < 1e-6, "{e:?}");
        assert!(e.i0.abs() < 1e-3);
    }
}
