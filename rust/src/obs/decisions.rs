//! Obs channel 3: per-epoch decision traces.
//!
//! Where channel 1 aggregates a run into totals, channel 3 keeps the
//! per-epoch, per-domain audit trail of what the DVFS manager actually
//! decided and what it cost: prediction vs outcome, the chosen ladder
//! state, and — for oracle-laddered policies — the *counterfactual
//! regret* of that choice (objective value at the chosen state minus at
//! the measured-ladder best state).  The trace answers the question the
//! scalar `mean_accuracy` cannot: *which* epochs and *which* PCs account
//! for one predictor beating another (paper §6.1).
//!
//! Determinism contract is identical to channel 1: samples derive from
//! simulated state only, sidecars (`decisions.csv` / `decisions.ndjson`)
//! carry no timestamps and are sorted by canonical
//! [`RunKey`](crate::exec::key::RunKey) text, then epoch, then domain —
//! byte-identical across reruns and `--jobs` values.

use std::path::Path;

use crate::stats::emit::{CsvTable, Json};

/// One per-domain DVFS decision at an epoch boundary (channel 3).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DecisionSample {
    /// Epoch index (same numbering as `EpochRecord::epoch`).
    pub epoch: u64,
    /// Clock-domain index.
    pub domain: usize,
    /// Modal epoch-start PC among the domain's active wavefronts,
    /// masked to the PC table's aliasing bucket
    /// ([`PcTables::bucket_base_pc`](crate::predictors::PcTables::bucket_base_pc)),
    /// ties broken toward the lowest PC.  Only meaningful when
    /// `has_pc` (PC-keyed policies with at least one active wavefront).
    pub pc: u32,
    pub has_pc: bool,
    /// Instructions predicted for this domain at the chosen state.
    pub pred_instr: f64,
    /// Chosen ladder state index.
    pub chosen: u8,
    /// Best state on the oracle's measured ladder for this epoch
    /// (equals `chosen` when the policy took no oracle sample).
    pub oracle_best: u8,
    /// Instructions the domain actually committed this epoch.
    pub actual_instr: f64,
    /// Epoch-level prediction accuracy (paper §6.1), repeated on every
    /// domain row of the epoch; NaN for static policies.
    pub accuracy: f64,
    /// This domain's no-issue fraction of the epoch (all three stall
    /// causes over CU-time).
    pub stall_frac: f64,
    /// Epoch-level energy in J (transition + CU energy), repeated on
    /// every domain row of the epoch.
    pub energy_j: f64,
    /// Counterfactual regret: objective value at the chosen state minus
    /// at `oracle_best` on the measured ladder.  ≥ 0 by construction;
    /// exactly 0 when no oracle sample exists and for `Policy::Oracle`
    /// (it minimized over its own ladder).
    pub regret: f64,
}

/// `decisions.csv` column order (the sidecar schema).
pub const DECISIONS_HEADER: [&str; 16] = [
    "key_hash",
    "workload",
    "policy",
    "objective",
    "epoch_ns",
    "epoch",
    "domain",
    "pc",
    "pred_instr",
    "chosen_freq",
    "oracle_best",
    "actual_instr",
    "accuracy",
    "stall_frac",
    "energy_j",
    "regret",
];

/// Fixed-precision float text — the byte-determinism idiom shared with
/// the sweep-plot emitter (`f64` Display is shortest-roundtrip and
/// therefore stable, but fixed precision keeps diffs column-aligned).
fn f3(v: f64) -> String {
    format!("{v:.3}")
}

fn f6(v: f64) -> String {
    format!("{v:.6}")
}

fn f10(v: f64) -> String {
    format!("{v:.10}")
}

fn e9(v: f64) -> String {
    format!("{v:.9e}")
}

/// Render one sample as a `decisions.csv` row (cell identity prefixed).
pub(crate) fn decision_csv_row(
    key_hash: &str,
    workload: &str,
    policy: &str,
    objective: &str,
    epoch_ns: f64,
    s: &DecisionSample,
) -> Vec<String> {
    vec![
        key_hash.to_string(),
        workload.to_string(),
        policy.to_string(),
        objective.to_string(),
        format!("{epoch_ns}"),
        s.epoch.to_string(),
        s.domain.to_string(),
        if s.has_pc { s.pc.to_string() } else { "-".into() },
        f3(s.pred_instr),
        s.chosen.to_string(),
        s.oracle_best.to_string(),
        f3(s.actual_instr),
        f10(s.accuracy),
        f6(s.stall_frac),
        e9(s.energy_j),
        e9(s.regret),
    ]
}

/// Render one sample as a `decisions.ndjson` object (one line each;
/// `Json::Num` renders NaN/Inf as `null`, which is what NDJSON
/// consumers expect).
pub(crate) fn decision_json(
    key_hash: &str,
    workload: &str,
    policy: &str,
    objective: &str,
    epoch_ns: f64,
    s: &DecisionSample,
) -> Json {
    Json::obj(vec![
        ("hash", Json::Str(key_hash.to_string())),
        ("workload", Json::Str(workload.to_string())),
        ("policy", Json::Str(policy.to_string())),
        ("objective", Json::Str(objective.to_string())),
        ("epoch_ns", Json::Num(epoch_ns)),
        ("epoch", Json::Num(s.epoch as f64)),
        ("domain", Json::Num(s.domain as f64)),
        (
            "pc",
            if s.has_pc {
                Json::Num(s.pc as f64)
            } else {
                Json::Null
            },
        ),
        ("pred_instr", Json::Num(s.pred_instr)),
        ("chosen_freq", Json::Num(s.chosen as f64)),
        ("oracle_best", Json::Num(s.oracle_best as f64)),
        ("actual_instr", Json::Num(s.actual_instr)),
        ("accuracy", Json::Num(s.accuracy)),
        ("stall_frac", Json::Num(s.stall_frac)),
        ("energy_j", Json::Num(s.energy_j)),
        ("regret", Json::Num(s.regret)),
    ])
}

/// One `decisions.csv` row joined with its cell identity (the parsed
/// form consumed by `obs report`, `obs diff`, and the timeline plot).
#[derive(Debug, Clone)]
pub struct DecisionRow {
    pub key_hash: String,
    pub workload: String,
    pub policy: String,
    pub objective: String,
    /// Kept as verbatim text: it is an alignment key, not arithmetic.
    pub epoch_ns: String,
    pub epoch: u64,
    pub domain: u64,
    /// `None` when the policy is not PC-keyed (the `-` column value).
    pub pc: Option<u32>,
    pub pred_instr: f64,
    pub chosen: u8,
    pub oracle_best: u8,
    pub actual_instr: f64,
    pub accuracy: f64,
    pub stall_frac: f64,
    pub energy_j: f64,
    pub regret: f64,
}

impl DecisionRow {
    /// Identity of the cell this row belongs to (one simulation).
    pub fn cell_id(&self) -> (String, String, String, String) {
        (
            self.workload.clone(),
            self.objective.clone(),
            self.epoch_ns.clone(),
            self.policy.clone(),
        )
    }
}

fn num<T: std::str::FromStr>(cell: &str, col: &str) -> Result<T, String> {
    cell.parse()
        .map_err(|_| format!("bad {col} value '{cell}' in decisions.csv"))
}

/// Parse a `decisions.csv` sidecar back into rows.
pub fn read_decisions(dir: &Path) -> Result<Vec<DecisionRow>, String> {
    let path = dir.join("decisions.csv");
    if !path.exists() {
        return Err(format!(
            "no {} (run with `--obs {}` — and `--no-cache`, cached cells emit no trace)",
            path.display(),
            dir.display()
        ));
    }
    let t = CsvTable::read(&path)?;
    let expect: Vec<String> = DECISIONS_HEADER.iter().map(|s| s.to_string()).collect();
    if t.header != expect {
        return Err(format!("{}: unexpected header {:?}", path.display(), t.header));
    }
    let mut out = Vec::with_capacity(t.rows.len());
    for r in &t.rows {
        out.push(DecisionRow {
            key_hash: r[0].clone(),
            workload: r[1].clone(),
            policy: r[2].clone(),
            objective: r[3].clone(),
            epoch_ns: r[4].clone(),
            epoch: num(&r[5], "epoch")?,
            domain: num(&r[6], "domain")?,
            pc: if r[7] == "-" { None } else { Some(num(&r[7], "pc")?) },
            pred_instr: num(&r[8], "pred_instr")?,
            chosen: num(&r[9], "chosen_freq")?,
            oracle_best: num(&r[10], "oracle_best")?,
            actual_instr: num(&r[11], "actual_instr")?,
            accuracy: num(&r[12], "accuracy")?,
            stall_frac: num(&r[13], "stall_frac")?,
            energy_j: num(&r[14], "energy_j")?,
            regret: num(&r[15], "regret")?,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> DecisionSample {
        DecisionSample {
            epoch: 3,
            domain: 1,
            pc: 128,
            has_pc: true,
            pred_instr: 1234.5,
            chosen: 7,
            oracle_best: 5,
            actual_instr: 1100.0,
            accuracy: 0.891,
            stall_frac: 0.25,
            energy_j: 1.5e-6,
            regret: 0.0,
        }
    }

    #[test]
    fn csv_row_matches_header_width_and_is_stable() {
        let s = sample();
        let a = decision_csv_row("beef", "comd", "PCSTALL", "ED2P", 1000.0, &s);
        let b = decision_csv_row("beef", "comd", "PCSTALL", "ED2P", 1000.0, &s);
        assert_eq!(a.len(), DECISIONS_HEADER.len());
        assert_eq!(a, b, "formatting must be deterministic");
        assert_eq!(a[4], "1000", "epoch_ns uses shortest-roundtrip text");
        assert_eq!(a[7], "128");
        assert_eq!(a[15], "0.000000000e0", "regret is fixed-precision");
    }

    #[test]
    fn non_pc_policies_emit_dash_and_null_pc() {
        let s = DecisionSample {
            has_pc: false,
            ..sample()
        };
        let row = decision_csv_row("h", "w", "CRISP", "ED2P", 1000.0, &s);
        assert_eq!(row[7], "-");
        let j = decision_json("h", "w", "CRISP", "ED2P", 1000.0, &s).render();
        assert!(j.contains("\"pc\":null"), "{j}");
    }

    #[test]
    fn csv_roundtrips_through_read() {
        let dir = std::env::temp_dir().join(format!("pcstall_dec_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut t = CsvTable::new(&DECISIONS_HEADER);
        let s = sample();
        t.push(decision_csv_row("beef", "comd", "PCSTALL", "ED2P", 1000.0, &s));
        let nan = DecisionSample {
            accuracy: f64::NAN,
            has_pc: false,
            ..sample()
        };
        t.push(decision_csv_row("beef", "comd", "STATIC-1.7", "ED2P", 1000.0, &nan));
        t.write(&dir.join("decisions.csv")).unwrap();
        let rows = read_decisions(&dir).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].pc, Some(128));
        assert_eq!(rows[0].chosen, 7);
        assert!((rows[0].accuracy - 0.891).abs() < 1e-9);
        assert!(rows[1].pc.is_none());
        assert!(rows[1].accuracy.is_nan(), "NaN accuracy must roundtrip");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_sidecar_error_mentions_no_cache() {
        let err = read_decisions(Path::new("/nonexistent-unused")).unwrap_err();
        assert!(err.contains("--no-cache"), "{err}");
    }
}
