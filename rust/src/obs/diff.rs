//! `pcstall obs diff <dirA> <dirB>` — align two decision traces and
//! report where the policies diverged.
//!
//! Alignment is two-stage.  Cells are first grouped by
//! `(workload, objective, epoch_ns)`; within a group, a policy present
//! in both dirs pairs with itself (a rerun-consistency pair — zero
//! divergence expected), and the leftover policies are paired in sorted
//! order (the cross-policy comparison, e.g. CRISP in dir A vs PCSTALL
//! in dir B over the same workload).  Paired cells then align row-wise
//! by `(epoch, domain)` — a *divergent pair* is an aligned row where
//! the chosen ladder state differs.  Regret sums on both sides
//! attribute the divergence: a diverging epoch where only one side
//! pays regret is an epoch that side's predictor got wrong.

use std::collections::BTreeMap;
use std::path::Path;

use crate::stats::emit::print_table;

use super::decisions::{read_decisions, DecisionRow};

/// One aligned-but-divergent row (the per-epoch attribution record).
#[derive(Debug, Clone)]
pub struct DivergentRow {
    pub workload: String,
    pub objective: String,
    pub epoch_ns: String,
    pub policy_a: String,
    pub policy_b: String,
    pub epoch: u64,
    pub domain: u64,
    pub chosen_a: u8,
    pub chosen_b: u8,
    pub regret_a: f64,
    pub regret_b: f64,
    pub accuracy_a: f64,
    pub accuracy_b: f64,
}

/// The outcome of aligning two decision traces.
#[derive(Debug, Clone, Default)]
pub struct DiffSummary {
    /// Cell pairs aligned (same-policy + cross-policy).
    pub cell_pairs: usize,
    pub same_policy_pairs: usize,
    pub cross_policy_pairs: usize,
    /// Cells with no counterpart in the other dir.
    pub unpaired_a: usize,
    pub unpaired_b: usize,
    /// Rows aligned by (cell pair, epoch, domain).
    pub rows_aligned: usize,
    /// Aligned rows whose chosen ladder state differs.
    pub divergent: usize,
    /// Rows present on only one side of a paired cell.
    pub only_a: usize,
    pub only_b: usize,
    /// Regret summed over aligned rows, per side.
    pub regret_a: f64,
    pub regret_b: f64,
    /// Worst divergent rows (by regret delta, then accuracy delta).
    pub top: Vec<DivergentRow>,
}

type CellGroups<'a> =
    BTreeMap<(String, String, String), BTreeMap<String, BTreeMap<(u64, u64), &'a DecisionRow>>>;

/// `(workload, objective, epoch_ns) -> policy -> (epoch, domain) -> row`.
fn group(rows: &[DecisionRow]) -> CellGroups<'_> {
    let mut g: CellGroups = BTreeMap::new();
    for r in rows {
        g.entry((r.workload.clone(), r.objective.clone(), r.epoch_ns.clone()))
            .or_default()
            .entry(r.policy.clone())
            .or_default()
            .insert((r.epoch, r.domain), r);
    }
    g
}

/// Align the decision traces under two obs dirs.
pub fn diff_decisions(dir_a: &Path, dir_b: &Path) -> Result<DiffSummary, String> {
    let rows_a = read_decisions(dir_a)?;
    let rows_b = read_decisions(dir_b)?;
    let ga = group(&rows_a);
    let gb = group(&rows_b);

    let mut s = DiffSummary::default();
    for (gkey, pols_a) in &ga {
        let Some(pols_b) = gb.get(gkey) else {
            s.unpaired_a += pols_a.len();
            continue;
        };
        // same-policy pairs first, then leftovers zipped in sorted order
        let mut pairs: Vec<(&str, &str)> = Vec::new();
        let mut left_a: Vec<&str> = Vec::new();
        for p in pols_a.keys() {
            if pols_b.contains_key(p) {
                pairs.push((p.as_str(), p.as_str()));
                s.same_policy_pairs += 1;
            } else {
                left_a.push(p.as_str());
            }
        }
        let left_b: Vec<&str> = pols_b
            .keys()
            .filter(|p| !pols_a.contains_key(*p))
            .map(String::as_str)
            .collect();
        let crossed = left_a.len().min(left_b.len());
        s.cross_policy_pairs += crossed;
        s.unpaired_a += left_a.len() - crossed;
        s.unpaired_b += left_b.len() - crossed;
        for i in 0..crossed {
            pairs.push((left_a[i], left_b[i]));
        }

        for (pa, pb) in pairs {
            s.cell_pairs += 1;
            let ca = &pols_a[pa];
            let cb = &pols_b[pb];
            for (rk, ra) in ca {
                let Some(rb) = cb.get(rk) else {
                    s.only_a += 1;
                    continue;
                };
                s.rows_aligned += 1;
                s.regret_a += ra.regret;
                s.regret_b += rb.regret;
                if ra.chosen != rb.chosen {
                    s.divergent += 1;
                    s.top.push(DivergentRow {
                        workload: gkey.0.clone(),
                        objective: gkey.1.clone(),
                        epoch_ns: gkey.2.clone(),
                        policy_a: pa.to_string(),
                        policy_b: pb.to_string(),
                        epoch: ra.epoch,
                        domain: ra.domain,
                        chosen_a: ra.chosen,
                        chosen_b: rb.chosen,
                        regret_a: ra.regret,
                        regret_b: rb.regret,
                        accuracy_a: ra.accuracy,
                        accuracy_b: rb.accuracy,
                    });
                }
            }
            s.only_b += cb.keys().filter(|k| !ca.contains_key(*k)).count();
        }
    }
    for (gkey, pols_b) in &gb {
        if !ga.contains_key(gkey) {
            s.unpaired_b += pols_b.len();
        }
    }

    // Attribution order: regret delta first (the energy cost of the
    // disagreement), accuracy delta as the tiebreak for regret-free
    // (non-oracle) traces, then a stable key.
    let key_of = |r: &DivergentRow| {
        (
            r.workload.clone(),
            r.objective.clone(),
            r.epoch_ns.clone(),
            r.policy_a.clone(),
            r.epoch,
            r.domain,
        )
    };
    s.top.sort_by(|a, b| {
        let da = (a.regret_a - a.regret_b).abs();
        let db = (b.regret_a - b.regret_b).abs();
        let acc_a = nan_zero(a.accuracy_a - a.accuracy_b).abs();
        let acc_b = nan_zero(b.accuracy_a - b.accuracy_b).abs();
        db.partial_cmp(&da)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| acc_b.partial_cmp(&acc_a).unwrap_or(std::cmp::Ordering::Equal))
            .then_with(|| key_of(a).cmp(&key_of(b)))
    });
    s.top.truncate(10);
    Ok(s)
}

fn nan_zero(x: f64) -> f64 {
    if x.is_finite() {
        x
    } else {
        0.0
    }
}

/// Print a [`DiffSummary`].  The `divergent pairs` line is the
/// greppable contract line (CI asserts on it).
pub fn print_diff(dir_a: &Path, dir_b: &Path, s: &DiffSummary) {
    println!("[obs diff] A={} B={}", dir_a.display(), dir_b.display());
    println!(
        "cell pairs aligned : {} ({} same-policy, {} cross-policy; unpaired {}+{})",
        s.cell_pairs, s.same_policy_pairs, s.cross_policy_pairs, s.unpaired_a, s.unpaired_b
    );
    println!(
        "rows aligned       : {} (only-A {}, only-B {})",
        s.rows_aligned, s.only_a, s.only_b
    );
    println!("divergent pairs    : {}", s.divergent);
    println!(
        "regret sum         : A {:.6e}  B {:.6e}",
        s.regret_a, s.regret_b
    );
    if s.top.is_empty() {
        println!("(no divergent rows)");
        return;
    }
    let rows: Vec<Vec<String>> = s
        .top
        .iter()
        .map(|r| {
            vec![
                format!("{}/{}@{}ns", r.workload, r.objective, r.epoch_ns),
                format!("{} vs {}", r.policy_a, r.policy_b),
                r.epoch.to_string(),
                r.domain.to_string(),
                format!("{} vs {}", r.chosen_a, r.chosen_b),
                format!("{:.3e} vs {:.3e}", r.regret_a, r.regret_b),
                format!("{:.3} vs {:.3}", r.accuracy_a, r.accuracy_b),
            ]
        })
        .collect();
    print_table(
        "top divergent rows (by regret delta, then accuracy delta)",
        &["cell", "policies", "epoch", "dom", "state", "regret", "accuracy"],
        &rows,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::decisions::{decision_csv_row, DecisionSample, DECISIONS_HEADER};
    use crate::stats::emit::CsvTable;
    use std::path::PathBuf;

    fn write_trace(tag: &str, cells: &[(&str, &str, Vec<DecisionSample>)]) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("pcstall_diff_{}_{}", std::process::id(), tag));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut t = CsvTable::new(&DECISIONS_HEADER);
        for (hash, policy, samples) in cells {
            for s in samples {
                t.push(decision_csv_row(hash, "comd", policy, "ED2P", 1000.0, s));
            }
        }
        t.write(&dir.join("decisions.csv")).unwrap();
        dir
    }

    fn sample(epoch: u64, chosen: u8, regret: f64) -> DecisionSample {
        DecisionSample {
            epoch,
            chosen,
            oracle_best: chosen,
            regret,
            accuracy: 0.9,
            ..Default::default()
        }
    }

    #[test]
    fn identical_dirs_have_zero_divergence() {
        let cells = [
            ("aa", "STATIC-1.7", vec![sample(0, 4, 0.0), sample(1, 4, 0.0)]),
            ("bb", "PCSTALL", vec![sample(0, 7, 0.0), sample(1, 6, 0.0)]),
        ];
        let a = write_trace("ida", &cells);
        let b = write_trace("idb", &cells);
        let s = diff_decisions(&a, &b).unwrap();
        assert_eq!(s.cell_pairs, 2);
        assert_eq!(s.same_policy_pairs, 2);
        assert_eq!(s.cross_policy_pairs, 0);
        assert_eq!(s.rows_aligned, 4);
        assert_eq!(s.divergent, 0, "identical traces must not diverge");
        assert_eq!((s.only_a, s.only_b), (0, 0));
        let _ = std::fs::remove_dir_all(&a);
        let _ = std::fs::remove_dir_all(&b);
    }

    #[test]
    fn cross_policy_cells_pair_and_report_divergence() {
        // dir A: STATIC baseline + CRISP; dir B: STATIC baseline + PCSTALL.
        // STATIC pairs with itself; CRISP pairs with PCSTALL.
        let a = write_trace(
            "xa",
            &[
                ("s", "STATIC-1.7", vec![sample(0, 4, 0.0)]),
                ("c", "CRISP", vec![sample(0, 3, 0.0), sample(1, 3, 0.0)]),
            ],
        );
        let b = write_trace(
            "xb",
            &[
                ("s", "STATIC-1.7", vec![sample(0, 4, 0.0)]),
                ("p", "PCSTALL", vec![sample(0, 7, 0.0), sample(1, 3, 0.0)]),
            ],
        );
        let s = diff_decisions(&a, &b).unwrap();
        assert_eq!(s.cell_pairs, 2);
        assert_eq!(s.cross_policy_pairs, 1);
        assert_eq!(s.rows_aligned, 3);
        assert_eq!(s.divergent, 1, "epoch 0 differs (3 vs 7), epoch 1 agrees");
        assert_eq!(s.top.len(), 1);
        assert_eq!((s.top[0].chosen_a, s.top[0].chosen_b), (3, 7));
        assert_eq!(s.top[0].policy_a, "CRISP");
        assert_eq!(s.top[0].policy_b, "PCSTALL");
        let _ = std::fs::remove_dir_all(&a);
        let _ = std::fs::remove_dir_all(&b);
    }

    #[test]
    fn regret_sums_attribute_sides_independently() {
        let a = write_trace("ra", &[("x", "ACCPC", vec![sample(0, 5, 0.25)])]);
        let b = write_trace("rb", &[("y", "ACCREAC", vec![sample(0, 2, 0.75)])]);
        let s = diff_decisions(&a, &b).unwrap();
        assert_eq!(s.divergent, 1);
        assert!((s.regret_a - 0.25).abs() < 1e-9);
        assert!((s.regret_b - 0.75).abs() < 1e-9);
        let _ = std::fs::remove_dir_all(&a);
        let _ = std::fs::remove_dir_all(&b);
    }
}
