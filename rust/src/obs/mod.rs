//! Observability: deterministic simulator counters, wall-clock spans,
//! and per-epoch decision traces.
//!
//! Three channels with deliberately different determinism contracts
//! (ISSUEs 6 and 7):
//!
//! * **Channel 1 — counters.**  The simulator unconditionally maintains
//!   cheap `u64` counters (stall breakdown in `sim::cu`, queue-depth
//!   histograms in `sim::memory`, PC-table traffic in
//!   `predictors::pc_table`); the DVFS manager samples them through the
//!   [`ObsSink`] trait at epoch boundaries only.  The default
//!   [`NoopSink`] keeps that boundary a single virtual call per epoch
//!   and the hot path branch-free, and because the counters themselves
//!   never feed back into timing, simulation results are bit-identical
//!   with the sink on or off.  Counter sidecars (`counters.json` /
//!   `counters.csv`) contain no timestamps and are keyed/sorted by the
//!   cell's canonical [`RunKey`](crate::exec::key::RunKey) text, so
//!   they are byte-deterministic across reruns and `--jobs` values.
//!
//! * **Channel 2 — spans.**  Wall-clock span timing in the exec pool
//!   (queue wait, run, cache read/write) and the harness cell stages
//!   (resolve, simulate, emit).  Spans are inherently nondeterministic
//!   and are therefore kept out of the counter sidecars entirely: they
//!   go to `timeline.ndjson`, a Chrome trace-event-format file (one
//!   complete `"ph":"X"` event per line) loadable in Perfetto or
//!   `chrome://tracing`.  Timestamps are microseconds relative to the
//!   recorder's construction instant — no absolute wall-clock values.
//!
//! * **Channel 3 — decision traces.**  One [`DecisionSample`] per
//!   domain per epoch: prediction vs outcome, the chosen ladder state,
//!   the modal PC, and counterfactual regret against the oracle's
//!   measured ladder (see [`decisions`]).  Same determinism contract
//!   as channel 1; sidecars are `decisions.csv` / `decisions.ndjson`.
//!
//! `pcstall obs report <dir>` summarizes all channels; `pcstall obs
//! diff <dirA> <dirB>` aligns two decision traces ([`diff`]).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::stats::emit::{CsvTable, Json};
use crate::stats::RunResult;

pub mod decisions;
pub mod diff;
pub mod report;

pub use decisions::{read_decisions, DecisionRow, DecisionSample, DECISIONS_HEADER};
pub use diff::{diff_decisions, print_diff, DiffSummary, DivergentRow};
pub use report::report;

/// Queue-depth histogram size shared by the L2-bank and DRAM-channel
/// histograms: bucket `k` counts accesses that waited about `k` service
/// slots; the last bucket aggregates everything deeper.
pub use crate::sim::memory::QUEUE_DEPTH_BUCKETS;

// ---------------------------------------------------------------------------
// Channel 1: deterministic counters
// ---------------------------------------------------------------------------

/// Deterministic per-run counter totals (channel 1).  Everything here
/// is derived from simulated time / event counts only — no wall clock.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunCounters {
    /// Epochs the manager ran.
    pub epochs: u64,
    /// Instructions committed (summed over CUs and epochs).
    pub instr: u64,
    /// CU cycles elapsed (summed over CUs and epochs).
    pub cycles: u64,
    /// CU cycles that issued an instruction.
    pub issued_cycles: u64,
    /// No-issue time blocked on a waitcnt (≥1 memory-blocked WF), ps.
    pub stall_waitcnt_ps: u64,
    /// No-issue time with loads in flight but nobody blocked yet, ps.
    pub stall_mem_outstanding_ps: u64,
    /// No-issue time with no memory involvement (ALU latency / empty
    /// issue slots), ps.
    pub stall_issue_empty_ps: u64,
    /// L2 accesses (L1-miss traffic).
    pub l2_accesses: u64,
    /// L2 tag hits / misses.
    pub l2_hits: u64,
    pub l2_misses: u64,
    /// Accesses that went to DRAM.
    pub dram_accesses: u64,
    /// L2-bank queue-depth histogram ([`QUEUE_DEPTH_BUCKETS`] buckets).
    pub l2_queue_depth_hist: Vec<u64>,
    /// DRAM-channel queue-depth histogram.
    pub dram_queue_depth_hist: Vec<u64>,
    /// PC-table lookup hits / misses, and destructive overwrites of a
    /// valid entry (the no-blend update path).
    pub pc_hits: u64,
    pub pc_misses: u64,
    pub pc_evictions: u64,
    /// DVFS frequency transitions actually programmed, per domain.
    pub transitions_per_domain: Vec<u64>,
}

impl RunCounters {
    /// Total no-issue time (the three stall causes partition it).
    pub fn stall_total_ps(&self) -> u64 {
        self.stall_waitcnt_ps + self.stall_mem_outstanding_ps + self.stall_issue_empty_ps
    }

    /// Total DVFS transitions across domains.
    pub fn transitions_total(&self) -> u64 {
        self.transitions_per_domain.iter().sum()
    }
}

/// Memory-side counter snapshot, produced by
/// [`Gpu::mem_counters`](crate::sim::gpu::Gpu::mem_counters).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MemCounters {
    pub l2_accesses: u64,
    pub l2_hits: u64,
    pub l2_misses: u64,
    pub dram_accesses: u64,
    pub l2_queue_depth_hist: Vec<u64>,
    pub dram_queue_depth_hist: Vec<u64>,
}

/// Per-epoch sample the DVFS manager hands to the sink (summed over
/// this epoch's CUs).
#[derive(Debug, Clone, Default)]
pub struct EpochSample {
    pub instr: u64,
    pub cycles: u64,
    pub issued_cycles: u64,
    pub stall_waitcnt_ps: u64,
    pub stall_mem_outstanding_ps: u64,
    pub stall_issue_empty_ps: u64,
    /// Domains whose frequency actually changed entering this epoch.
    pub switched_domains: Vec<usize>,
}

/// End-of-run sample: run-cumulative state that only makes sense as a
/// whole-run total (cache/PC-table counters survive epoch resets).
#[derive(Debug, Clone, Default)]
pub struct RunEndSample {
    pub mem: MemCounters,
    pub pc_hits: u64,
    pub pc_misses: u64,
    pub pc_evictions: u64,
    pub n_domains: usize,
}

/// Epoch-boundary observability sink.  The default impls are all no-ops
/// and `enabled()` is false, so the manager's hot loop pays one
/// predictable virtual call per epoch and nothing else.
pub trait ObsSink: Send {
    /// Gate: when false the manager skips building samples entirely.
    fn enabled(&self) -> bool {
        false
    }
    fn on_epoch(&mut self, _s: &EpochSample) {}
    /// One per-domain decision audit record (channel 3).
    fn on_decision(&mut self, _s: &DecisionSample) {}
    fn on_run_end(&mut self, _s: &RunEndSample) {}
    /// Accumulated totals, if this sink keeps any.
    fn counters(&self) -> Option<&RunCounters> {
        None
    }
    /// Accumulated decision trace, if this sink keeps one.
    fn decisions(&self) -> Option<&[DecisionSample]> {
        None
    }
}

/// The zero-overhead default sink.
#[derive(Debug, Clone, Copy, Default)]
pub struct NoopSink;

impl ObsSink for NoopSink {}

/// Accumulating sink: sums epoch samples into [`RunCounters`] and logs
/// decision samples in emission order (epoch-major, domain-minor).
#[derive(Debug, Clone, Default)]
pub struct CounterSink {
    counters: RunCounters,
    decisions: Vec<DecisionSample>,
}

impl CounterSink {
    pub fn new() -> Self {
        Self::default()
    }

    /// Consume the sink, handing the decision trace over to a recorder.
    pub fn take_decisions(&mut self) -> Vec<DecisionSample> {
        std::mem::take(&mut self.decisions)
    }
}

impl ObsSink for CounterSink {
    fn enabled(&self) -> bool {
        true
    }

    fn on_epoch(&mut self, s: &EpochSample) {
        let c = &mut self.counters;
        c.epochs += 1;
        c.instr += s.instr;
        c.cycles += s.cycles;
        c.issued_cycles += s.issued_cycles;
        c.stall_waitcnt_ps += s.stall_waitcnt_ps;
        c.stall_mem_outstanding_ps += s.stall_mem_outstanding_ps;
        c.stall_issue_empty_ps += s.stall_issue_empty_ps;
        for &d in &s.switched_domains {
            if c.transitions_per_domain.len() <= d {
                c.transitions_per_domain.resize(d + 1, 0);
            }
            c.transitions_per_domain[d] += 1;
        }
    }

    fn on_decision(&mut self, s: &DecisionSample) {
        self.decisions.push(s.clone());
    }

    fn on_run_end(&mut self, s: &RunEndSample) {
        let c = &mut self.counters;
        c.l2_accesses = s.mem.l2_accesses;
        c.l2_hits = s.mem.l2_hits;
        c.l2_misses = s.mem.l2_misses;
        c.dram_accesses = s.mem.dram_accesses;
        c.l2_queue_depth_hist = s.mem.l2_queue_depth_hist.clone();
        c.dram_queue_depth_hist = s.mem.dram_queue_depth_hist.clone();
        c.pc_hits = s.pc_hits;
        c.pc_misses = s.pc_misses;
        c.pc_evictions = s.pc_evictions;
        if c.transitions_per_domain.len() < s.n_domains {
            c.transitions_per_domain.resize(s.n_domains, 0);
        }
    }

    fn counters(&self) -> Option<&RunCounters> {
        Some(&self.counters)
    }

    fn decisions(&self) -> Option<&[DecisionSample]> {
        Some(&self.decisions)
    }
}

// ---------------------------------------------------------------------------
// Recorder: collects all channels for one CLI invocation
// ---------------------------------------------------------------------------

/// One recorded cell: counters + decision trace keyed by the canonical
/// RunKey text.
#[derive(Debug, Clone)]
struct CellRecord {
    key_hash: String,
    workload: String,
    policy: String,
    objective: String,
    /// Epoch length of the cell's config (a decision-trace column: the
    /// diff alignment key needs it, and it is not part of `RunResult`).
    epoch_ns: f64,
    counters: RunCounters,
    decisions: Vec<DecisionSample>,
}

/// One completed span (channel 2).
#[derive(Debug, Clone)]
struct SpanEvent {
    cat: String,
    name: String,
    /// Microseconds since recorder construction.
    ts_us: u64,
    dur_us: u64,
    /// Worker/lane id — becomes the trace `tid`.
    tid: u64,
}

/// Process-wide recorder behind `--obs <dir>`: cells land in a
/// `BTreeMap` keyed by canonical RunKey text (so emission order is
/// content-defined, not schedule-defined), spans in an append-only log.
#[derive(Debug)]
pub struct ObsRecorder {
    dir: PathBuf,
    t0: Instant,
    cells: Mutex<BTreeMap<String, CellRecord>>,
    spans: Mutex<Vec<SpanEvent>>,
    /// Batch accounting (the obs × cache interaction): cells that
    /// actually executed vs cells served from the result cache, which
    /// carry no sidecar records.
    cells_executed: AtomicU64,
    cells_cached: AtomicU64,
}

impl ObsRecorder {
    pub fn new(dir: PathBuf) -> Self {
        ObsRecorder {
            dir,
            t0: Instant::now(),
            cells: Mutex::new(BTreeMap::new()),
            spans: Mutex::new(Vec::new()),
            cells_executed: AtomicU64::new(0),
            cells_cached: AtomicU64::new(0),
        }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Record one executed cell's deterministic counters and decision
    /// trace.
    pub fn record_cell(
        &self,
        canonical: &str,
        hash: &str,
        r: &RunResult,
        counters: RunCounters,
        epoch_ns: f64,
        decisions: Vec<DecisionSample>,
    ) {
        let rec = CellRecord {
            key_hash: hash.to_string(),
            workload: r.workload.clone(),
            policy: r.policy.clone(),
            objective: r.objective.clone(),
            epoch_ns,
            counters,
            decisions,
        };
        self.cells.lock().unwrap().insert(canonical.to_string(), rec);
    }

    /// Batch accounting from the exec engine: `executed` cells ran (and
    /// will be recorded), `cached` were served by the result cache and
    /// are therefore *missing* from the sidecars.
    pub fn note_batch(&self, executed: u64, cached: u64) {
        self.cells_executed.fetch_add(executed, Ordering::Relaxed);
        self.cells_cached.fetch_add(cached, Ordering::Relaxed);
    }

    pub fn cells_executed(&self) -> u64 {
        self.cells_executed.load(Ordering::Relaxed)
    }

    pub fn cells_cached(&self) -> u64 {
        self.cells_cached.load(Ordering::Relaxed)
    }

    /// Record one wall-clock span (channel 2).
    pub fn add_span(&self, cat: &str, name: &str, start: Instant, end: Instant, tid: u64) {
        let ev = SpanEvent {
            cat: cat.to_string(),
            name: name.to_string(),
            ts_us: start.saturating_duration_since(self.t0).as_micros() as u64,
            dur_us: end.saturating_duration_since(start).as_micros() as u64,
            tid,
        };
        self.spans.lock().unwrap().push(ev);
    }

    pub fn cell_count(&self) -> usize {
        self.cells.lock().unwrap().len()
    }

    pub fn span_count(&self) -> usize {
        self.spans.lock().unwrap().len()
    }

    /// The counter sidecar document (deterministic: sorted by canonical
    /// key, no timestamps, integer-valued numbers).
    pub fn counters_json(&self) -> Json {
        let cells = self.cells.lock().unwrap();
        let items: Vec<Json> = cells
            .iter()
            .map(|(canonical, rec)| {
                Json::obj(vec![
                    ("key", Json::Str(canonical.clone())),
                    ("hash", Json::Str(rec.key_hash.clone())),
                    ("workload", Json::Str(rec.workload.clone())),
                    ("policy", Json::Str(rec.policy.clone())),
                    ("objective", Json::Str(rec.objective.clone())),
                    ("counters", counters_to_json(&rec.counters)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("schema", Json::Num(1.0)),
            ("cells_executed", Json::Num(self.cells_executed() as f64)),
            ("cells_cached", Json::Num(self.cells_cached() as f64)),
            ("cells", Json::Arr(items)),
        ])
    }

    fn counters_csv(&self) -> CsvTable {
        let mut t = CsvTable::new(&[
            "key_hash",
            "workload",
            "policy",
            "objective",
            "epochs",
            "instr",
            "cycles",
            "issued_cycles",
            "stall_waitcnt_ps",
            "stall_mem_outstanding_ps",
            "stall_issue_empty_ps",
            "l2_accesses",
            "l2_hits",
            "l2_misses",
            "dram_accesses",
            "pc_hits",
            "pc_misses",
            "pc_evictions",
            "transitions_per_domain",
            "l2_queue_depth_hist",
            "dram_queue_depth_hist",
        ]);
        let cells = self.cells.lock().unwrap();
        for rec in cells.values() {
            let c = &rec.counters;
            t.push(vec![
                rec.key_hash.clone(),
                rec.workload.clone(),
                rec.policy.clone(),
                rec.objective.clone(),
                c.epochs.to_string(),
                c.instr.to_string(),
                c.cycles.to_string(),
                c.issued_cycles.to_string(),
                c.stall_waitcnt_ps.to_string(),
                c.stall_mem_outstanding_ps.to_string(),
                c.stall_issue_empty_ps.to_string(),
                c.l2_accesses.to_string(),
                c.l2_hits.to_string(),
                c.l2_misses.to_string(),
                c.dram_accesses.to_string(),
                c.pc_hits.to_string(),
                c.pc_misses.to_string(),
                c.pc_evictions.to_string(),
                join_u64(&c.transitions_per_domain),
                join_u64(&c.l2_queue_depth_hist),
                join_u64(&c.dram_queue_depth_hist),
            ]);
        }
        t
    }

    /// The decision-trace CSV (channel 3): cells in canonical-key
    /// order, rows within a cell in emission order (epoch-major,
    /// domain-minor) — byte-deterministic like `counters.csv`.
    pub fn decisions_csv(&self) -> CsvTable {
        let mut t = CsvTable::new(&DECISIONS_HEADER);
        let cells = self.cells.lock().unwrap();
        for rec in cells.values() {
            for s in &rec.decisions {
                t.push(decisions::decision_csv_row(
                    &rec.key_hash,
                    &rec.workload,
                    &rec.policy,
                    &rec.objective,
                    rec.epoch_ns,
                    s,
                ));
            }
        }
        t
    }

    /// The decision-trace NDJSON: a header object (schema + the batch
    /// accounting of [`ObsRecorder::note_batch`]) followed by one
    /// decision object per line.
    fn decisions_ndjson_text(&self) -> String {
        let header = Json::obj(vec![
            ("schema", Json::Num(1.0)),
            ("channel", Json::Str("decisions".into())),
            ("cells_executed", Json::Num(self.cells_executed() as f64)),
            ("cells_cached", Json::Num(self.cells_cached() as f64)),
        ]);
        let mut out = header.render();
        out.push('\n');
        let cells = self.cells.lock().unwrap();
        for rec in cells.values() {
            for s in &rec.decisions {
                out.push_str(
                    &decisions::decision_json(
                        &rec.key_hash,
                        &rec.workload,
                        &rec.policy,
                        &rec.objective,
                        rec.epoch_ns,
                        s,
                    )
                    .render(),
                );
                out.push('\n');
            }
        }
        out
    }

    /// Chrome trace-event text: a JSON array with exactly one complete
    /// event object per line, so it is both NDJSON-ish (line tools work
    /// after stripping `[`/`]`/trailing commas) and directly loadable
    /// in Perfetto / `chrome://tracing`.
    fn timeline_text(&self) -> String {
        let mut spans = self.spans.lock().unwrap().clone();
        spans.sort_by(|a, b| {
            (a.ts_us, a.tid, &a.cat, &a.name).cmp(&(b.ts_us, b.tid, &b.cat, &b.name))
        });
        let mut out = String::from("[\n");
        for (i, s) in spans.iter().enumerate() {
            let ev = Json::obj(vec![
                ("name", Json::Str(s.name.clone())),
                ("cat", Json::Str(s.cat.clone())),
                ("ph", Json::Str("X".into())),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(s.tid as f64)),
                ("ts", Json::Num(s.ts_us as f64)),
                ("dur", Json::Num(s.dur_us as f64)),
            ]);
            out.push_str(&ev.render());
            out.push_str(if i + 1 < spans.len() { ",\n" } else { "\n" });
        }
        out.push_str("]\n");
        out
    }

    /// Write all artifacts under the recorder's directory; returns the
    /// paths written.
    pub fn write(&self) -> Result<Vec<PathBuf>, String> {
        std::fs::create_dir_all(&self.dir)
            .map_err(|e| format!("creating {}: {e}", self.dir.display()))?;
        let mut out = Vec::new();
        let jp = self.dir.join("counters.json");
        self.counters_json()
            .write(&jp)
            .map_err(|e| format!("writing {}: {e}", jp.display()))?;
        out.push(jp);
        let cp = self.dir.join("counters.csv");
        self.counters_csv()
            .write(&cp)
            .map_err(|e| format!("writing {}: {e}", cp.display()))?;
        out.push(cp);
        let dp = self.dir.join("decisions.csv");
        self.decisions_csv()
            .write(&dp)
            .map_err(|e| format!("writing {}: {e}", dp.display()))?;
        out.push(dp);
        let np = self.dir.join("decisions.ndjson");
        std::fs::write(&np, self.decisions_ndjson_text())
            .map_err(|e| format!("writing {}: {e}", np.display()))?;
        out.push(np);
        let tp = self.dir.join("timeline.ndjson");
        std::fs::write(&tp, self.timeline_text())
            .map_err(|e| format!("writing {}: {e}", tp.display()))?;
        out.push(tp);
        Ok(out)
    }
}

fn join_u64(xs: &[u64]) -> String {
    xs.iter()
        .map(|x| x.to_string())
        .collect::<Vec<_>>()
        .join("|")
}

fn counters_to_json(c: &RunCounters) -> Json {
    let n = |x: u64| Json::Num(x as f64);
    let arr = |xs: &[u64]| Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect());
    Json::obj(vec![
        ("epochs", n(c.epochs)),
        ("instr", n(c.instr)),
        ("cycles", n(c.cycles)),
        ("issued_cycles", n(c.issued_cycles)),
        ("stall_waitcnt_ps", n(c.stall_waitcnt_ps)),
        ("stall_mem_outstanding_ps", n(c.stall_mem_outstanding_ps)),
        ("stall_issue_empty_ps", n(c.stall_issue_empty_ps)),
        ("l2_accesses", n(c.l2_accesses)),
        ("l2_hits", n(c.l2_hits)),
        ("l2_misses", n(c.l2_misses)),
        ("dram_accesses", n(c.dram_accesses)),
        ("l2_queue_depth_hist", arr(&c.l2_queue_depth_hist)),
        ("dram_queue_depth_hist", arr(&c.dram_queue_depth_hist)),
        ("pc_hits", n(c.pc_hits)),
        ("pc_misses", n(c.pc_misses)),
        ("pc_evictions", n(c.pc_evictions)),
        ("transitions_per_domain", arr(&c.transitions_per_domain)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_result() -> RunResult {
        RunResult {
            workload: "comd".into(),
            policy: "pcstall".into(),
            objective: "ed2p".into(),
            records: vec![],
            total_energy_j: 1.0,
            total_time_ns: 1.0,
            total_instr: 1.0,
            mean_accuracy: 1.0,
            pc_hit_rate: 0.0,
            completed: true,
            serve: None,
        }
    }

    fn a_decision(epoch: u64, domain: usize) -> DecisionSample {
        DecisionSample {
            epoch,
            domain,
            chosen: 5,
            oracle_best: 5,
            accuracy: 0.75,
            ..Default::default()
        }
    }

    #[test]
    fn noop_sink_is_disabled_and_counterless() {
        let s = NoopSink;
        assert!(!s.enabled());
        assert!(s.counters().is_none());
        assert!(s.decisions().is_none());
    }

    #[test]
    fn counter_sink_accumulates_epochs_and_transitions() {
        let mut s = CounterSink::new();
        assert!(s.enabled());
        s.on_epoch(&EpochSample {
            instr: 10,
            cycles: 100,
            issued_cycles: 40,
            stall_waitcnt_ps: 7,
            stall_mem_outstanding_ps: 3,
            stall_issue_empty_ps: 2,
            switched_domains: vec![0, 2],
        });
        s.on_epoch(&EpochSample {
            instr: 5,
            switched_domains: vec![2],
            ..Default::default()
        });
        s.on_run_end(&RunEndSample {
            mem: MemCounters {
                l2_accesses: 9,
                l2_hits: 6,
                l2_misses: 3,
                dram_accesses: 3,
                l2_queue_depth_hist: vec![1, 2],
                dram_queue_depth_hist: vec![3],
            },
            pc_hits: 4,
            pc_misses: 2,
            pc_evictions: 1,
            n_domains: 4,
        });
        let c = s.counters().unwrap();
        assert_eq!(c.epochs, 2);
        assert_eq!(c.instr, 15);
        assert_eq!(c.stall_total_ps(), 12);
        assert_eq!(c.transitions_per_domain, vec![1, 0, 2, 0]);
        assert_eq!(c.transitions_total(), 3);
        assert_eq!(c.l2_hits, 6);
        assert_eq!(c.pc_evictions, 1);
    }

    #[test]
    fn counter_sink_logs_decisions_in_order() {
        let mut s = CounterSink::new();
        s.on_decision(&a_decision(0, 0));
        s.on_decision(&a_decision(0, 1));
        s.on_decision(&a_decision(1, 0));
        let d = s.decisions().unwrap();
        assert_eq!(d.len(), 3);
        assert_eq!((d[1].epoch, d[1].domain), (0, 1));
        assert_eq!(s.take_decisions().len(), 3);
        assert_eq!(s.decisions().unwrap().len(), 0, "take drains the log");
    }

    #[test]
    fn recorder_counters_json_is_key_sorted_and_stable() {
        let rec = ObsRecorder::new(PathBuf::from("/nonexistent-unused"));
        let c = RunCounters {
            epochs: 3,
            ..Default::default()
        };
        // inserted out of order; emission must sort by canonical key
        rec.record_cell("v1|wl=zz|cfg=02", "beef", &run_result(), c.clone(), 1000.0, vec![]);
        rec.record_cell("v1|wl=aa|cfg=01", "cafe", &run_result(), c, 1000.0, vec![]);
        let a = rec.counters_json().render();
        let b = rec.counters_json().render();
        assert_eq!(a, b, "re-rendering must be byte-identical");
        let first = a.find("wl=aa").unwrap();
        let second = a.find("wl=zz").unwrap();
        assert!(first < second, "cells must be canonical-key sorted");
        assert!(!a.contains("\"ts\""), "counter sidecar must carry no timestamps");
        assert!(a.contains("\"cells_executed\""), "batch accounting in header");
    }

    #[test]
    fn recorder_overwrite_same_key_is_idempotent() {
        let rec = ObsRecorder::new(PathBuf::from("/nonexistent-unused"));
        let c = RunCounters {
            epochs: 1,
            ..Default::default()
        };
        rec.record_cell("k", "h", &run_result(), c.clone(), 1000.0, vec![]);
        rec.record_cell("k", "h", &run_result(), c, 1000.0, vec![]);
        assert_eq!(rec.cell_count(), 1);
    }

    #[test]
    fn recorder_decision_sidecars_are_key_sorted_and_stable() {
        let rec = ObsRecorder::new(PathBuf::from("/nonexistent-unused"));
        let c = RunCounters::default();
        rec.note_batch(2, 1);
        rec.record_cell(
            "v1|wl=zz",
            "beef",
            &run_result(),
            c.clone(),
            1000.0,
            vec![a_decision(0, 0), a_decision(0, 1), a_decision(1, 0)],
        );
        rec.record_cell("v1|wl=aa", "cafe", &run_result(), c, 10000.0, vec![a_decision(0, 0)]);
        let t = rec.decisions_csv();
        assert_eq!(t.header, DECISIONS_HEADER.map(String::from).to_vec());
        assert_eq!(t.rows.len(), 4);
        // canonical-key order: the wl=aa cell's single row comes first
        assert_eq!(t.rows[0][0], "cafe");
        assert_eq!(t.rows[0][4], "10000");
        assert_eq!(t.rows[1][0], "beef");
        assert_eq!(t.to_string(), rec.decisions_csv().to_string());
        let nd = rec.decisions_ndjson_text();
        let first = nd.lines().next().unwrap();
        assert!(first.contains("\"cells_executed\":2"), "{first}");
        assert!(first.contains("\"cells_cached\":1"), "{first}");
        assert_eq!(nd.lines().count(), 1 + 4, "header + one line per sample");
        for line in nd.lines() {
            Json::parse(line).expect("every ndjson line parses standalone");
        }
    }

    #[test]
    fn timeline_is_chrome_trace_shaped() {
        let rec = ObsRecorder::new(PathBuf::from("/nonexistent-unused"));
        let t = rec.t0;
        rec.add_span(
            "exec",
            "pool.run",
            t + std::time::Duration::from_micros(5),
            t + std::time::Duration::from_micros(30),
            1,
        );
        rec.add_span("harness", "cell.simulate", t, t + std::time::Duration::from_micros(9), 0);
        let text = rec.timeline_text();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.first(), Some(&"["));
        assert_eq!(lines.last(), Some(&"]"));
        // one complete event per line, parseable after comma-stripping
        let ev = Json::parse(lines[1].trim_end_matches(',')).unwrap();
        assert_eq!(ev.get("ph").and_then(Json::as_str), Some("X"));
        // earliest span sorts first regardless of insertion order
        assert_eq!(ev.get("name").and_then(Json::as_str), Some("cell.simulate"));
        assert_eq!(ev.get("dur").and_then(Json::as_f64), Some(9.0));
        // the whole document is also one valid JSON array
        let doc = Json::parse(&text).unwrap();
        assert_eq!(doc.as_arr().map(<[Json]>::len), Some(2));
    }
}
