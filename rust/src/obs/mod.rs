//! Observability: deterministic simulator counters + wall-clock spans.
//!
//! Two channels with deliberately different determinism contracts
//! (ISSUE 6):
//!
//! * **Channel 1 — counters.**  The simulator unconditionally maintains
//!   cheap `u64` counters (stall breakdown in `sim::cu`, queue-depth
//!   histograms in `sim::memory`, PC-table traffic in
//!   `predictors::pc_table`); the DVFS manager samples them through the
//!   [`ObsSink`] trait at epoch boundaries only.  The default
//!   [`NoopSink`] keeps that boundary a single virtual call per epoch
//!   and the hot path branch-free, and because the counters themselves
//!   never feed back into timing, simulation results are bit-identical
//!   with the sink on or off.  Counter sidecars (`counters.json` /
//!   `counters.csv`) contain no timestamps and are keyed/sorted by the
//!   cell's canonical [`RunKey`](crate::exec::key::RunKey) text, so
//!   they are byte-deterministic across reruns and `--jobs` values.
//!
//! * **Channel 2 — spans.**  Wall-clock span timing in the exec pool
//!   (queue wait, run, cache read/write) and the harness cell stages
//!   (resolve, simulate, emit).  Spans are inherently nondeterministic
//!   and are therefore kept out of the counter sidecars entirely: they
//!   go to `timeline.ndjson`, a Chrome trace-event-format file (one
//!   complete `"ph":"X"` event per line) loadable in Perfetto or
//!   `chrome://tracing`.  Timestamps are microseconds relative to the
//!   recorder's construction instant — no absolute wall-clock values.
//!
//! `pcstall obs report <dir>` summarizes both channels.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Instant;

use crate::stats::emit::{print_table, CsvTable, Json};
use crate::stats::RunResult;

/// Queue-depth histogram size shared by the L2-bank and DRAM-channel
/// histograms: bucket `k` counts accesses that waited about `k` service
/// slots; the last bucket aggregates everything deeper.
pub use crate::sim::memory::QUEUE_DEPTH_BUCKETS;

// ---------------------------------------------------------------------------
// Channel 1: deterministic counters
// ---------------------------------------------------------------------------

/// Deterministic per-run counter totals (channel 1).  Everything here
/// is derived from simulated time / event counts only — no wall clock.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunCounters {
    /// Epochs the manager ran.
    pub epochs: u64,
    /// Instructions committed (summed over CUs and epochs).
    pub instr: u64,
    /// CU cycles elapsed (summed over CUs and epochs).
    pub cycles: u64,
    /// CU cycles that issued an instruction.
    pub issued_cycles: u64,
    /// No-issue time blocked on a waitcnt (≥1 memory-blocked WF), ps.
    pub stall_waitcnt_ps: u64,
    /// No-issue time with loads in flight but nobody blocked yet, ps.
    pub stall_mem_outstanding_ps: u64,
    /// No-issue time with no memory involvement (ALU latency / empty
    /// issue slots), ps.
    pub stall_issue_empty_ps: u64,
    /// L2 accesses (L1-miss traffic).
    pub l2_accesses: u64,
    /// L2 tag hits / misses.
    pub l2_hits: u64,
    pub l2_misses: u64,
    /// Accesses that went to DRAM.
    pub dram_accesses: u64,
    /// L2-bank queue-depth histogram ([`QUEUE_DEPTH_BUCKETS`] buckets).
    pub l2_queue_depth_hist: Vec<u64>,
    /// DRAM-channel queue-depth histogram.
    pub dram_queue_depth_hist: Vec<u64>,
    /// PC-table lookup hits / misses, and destructive overwrites of a
    /// valid entry (the no-blend update path).
    pub pc_hits: u64,
    pub pc_misses: u64,
    pub pc_evictions: u64,
    /// DVFS frequency transitions actually programmed, per domain.
    pub transitions_per_domain: Vec<u64>,
}

impl RunCounters {
    /// Total no-issue time (the three stall causes partition it).
    pub fn stall_total_ps(&self) -> u64 {
        self.stall_waitcnt_ps + self.stall_mem_outstanding_ps + self.stall_issue_empty_ps
    }

    /// Total DVFS transitions across domains.
    pub fn transitions_total(&self) -> u64 {
        self.transitions_per_domain.iter().sum()
    }
}

/// Memory-side counter snapshot, produced by
/// [`Gpu::mem_counters`](crate::sim::gpu::Gpu::mem_counters).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MemCounters {
    pub l2_accesses: u64,
    pub l2_hits: u64,
    pub l2_misses: u64,
    pub dram_accesses: u64,
    pub l2_queue_depth_hist: Vec<u64>,
    pub dram_queue_depth_hist: Vec<u64>,
}

/// Per-epoch sample the DVFS manager hands to the sink (summed over
/// this epoch's CUs).
#[derive(Debug, Clone, Default)]
pub struct EpochSample {
    pub instr: u64,
    pub cycles: u64,
    pub issued_cycles: u64,
    pub stall_waitcnt_ps: u64,
    pub stall_mem_outstanding_ps: u64,
    pub stall_issue_empty_ps: u64,
    /// Domains whose frequency actually changed entering this epoch.
    pub switched_domains: Vec<usize>,
}

/// End-of-run sample: run-cumulative state that only makes sense as a
/// whole-run total (cache/PC-table counters survive epoch resets).
#[derive(Debug, Clone, Default)]
pub struct RunEndSample {
    pub mem: MemCounters,
    pub pc_hits: u64,
    pub pc_misses: u64,
    pub pc_evictions: u64,
    pub n_domains: usize,
}

/// Epoch-boundary observability sink.  The default impls are all no-ops
/// and `enabled()` is false, so the manager's hot loop pays one
/// predictable virtual call per epoch and nothing else.
pub trait ObsSink: Send {
    /// Gate: when false the manager skips building samples entirely.
    fn enabled(&self) -> bool {
        false
    }
    fn on_epoch(&mut self, _s: &EpochSample) {}
    fn on_run_end(&mut self, _s: &RunEndSample) {}
    /// Accumulated totals, if this sink keeps any.
    fn counters(&self) -> Option<&RunCounters> {
        None
    }
}

/// The zero-overhead default sink.
#[derive(Debug, Clone, Copy, Default)]
pub struct NoopSink;

impl ObsSink for NoopSink {}

/// Accumulating sink: sums epoch samples into [`RunCounters`].
#[derive(Debug, Clone, Default)]
pub struct CounterSink {
    counters: RunCounters,
}

impl CounterSink {
    pub fn new() -> Self {
        Self::default()
    }
}

impl ObsSink for CounterSink {
    fn enabled(&self) -> bool {
        true
    }

    fn on_epoch(&mut self, s: &EpochSample) {
        let c = &mut self.counters;
        c.epochs += 1;
        c.instr += s.instr;
        c.cycles += s.cycles;
        c.issued_cycles += s.issued_cycles;
        c.stall_waitcnt_ps += s.stall_waitcnt_ps;
        c.stall_mem_outstanding_ps += s.stall_mem_outstanding_ps;
        c.stall_issue_empty_ps += s.stall_issue_empty_ps;
        for &d in &s.switched_domains {
            if c.transitions_per_domain.len() <= d {
                c.transitions_per_domain.resize(d + 1, 0);
            }
            c.transitions_per_domain[d] += 1;
        }
    }

    fn on_run_end(&mut self, s: &RunEndSample) {
        let c = &mut self.counters;
        c.l2_accesses = s.mem.l2_accesses;
        c.l2_hits = s.mem.l2_hits;
        c.l2_misses = s.mem.l2_misses;
        c.dram_accesses = s.mem.dram_accesses;
        c.l2_queue_depth_hist = s.mem.l2_queue_depth_hist.clone();
        c.dram_queue_depth_hist = s.mem.dram_queue_depth_hist.clone();
        c.pc_hits = s.pc_hits;
        c.pc_misses = s.pc_misses;
        c.pc_evictions = s.pc_evictions;
        if c.transitions_per_domain.len() < s.n_domains {
            c.transitions_per_domain.resize(s.n_domains, 0);
        }
    }

    fn counters(&self) -> Option<&RunCounters> {
        Some(&self.counters)
    }
}

// ---------------------------------------------------------------------------
// Recorder: collects both channels for one CLI invocation
// ---------------------------------------------------------------------------

/// One recorded cell: counters keyed by the canonical RunKey text.
#[derive(Debug, Clone)]
struct CellRecord {
    key_hash: String,
    workload: String,
    policy: String,
    objective: String,
    counters: RunCounters,
}

/// One completed span (channel 2).
#[derive(Debug, Clone)]
struct SpanEvent {
    cat: String,
    name: String,
    /// Microseconds since recorder construction.
    ts_us: u64,
    dur_us: u64,
    /// Worker/lane id — becomes the trace `tid`.
    tid: u64,
}

/// Process-wide recorder behind `--obs <dir>`: cells land in a
/// `BTreeMap` keyed by canonical RunKey text (so emission order is
/// content-defined, not schedule-defined), spans in an append-only log.
#[derive(Debug)]
pub struct ObsRecorder {
    dir: PathBuf,
    t0: Instant,
    cells: Mutex<BTreeMap<String, CellRecord>>,
    spans: Mutex<Vec<SpanEvent>>,
}

impl ObsRecorder {
    pub fn new(dir: PathBuf) -> Self {
        ObsRecorder {
            dir,
            t0: Instant::now(),
            cells: Mutex::new(BTreeMap::new()),
            spans: Mutex::new(Vec::new()),
        }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Record one executed cell's deterministic counters.
    pub fn record_cell(&self, canonical: &str, hash: &str, r: &RunResult, counters: RunCounters) {
        let rec = CellRecord {
            key_hash: hash.to_string(),
            workload: r.workload.clone(),
            policy: r.policy.clone(),
            objective: r.objective.clone(),
            counters,
        };
        self.cells.lock().unwrap().insert(canonical.to_string(), rec);
    }

    /// Record one wall-clock span (channel 2).
    pub fn add_span(&self, cat: &str, name: &str, start: Instant, end: Instant, tid: u64) {
        let ev = SpanEvent {
            cat: cat.to_string(),
            name: name.to_string(),
            ts_us: start.saturating_duration_since(self.t0).as_micros() as u64,
            dur_us: end.saturating_duration_since(start).as_micros() as u64,
            tid,
        };
        self.spans.lock().unwrap().push(ev);
    }

    pub fn cell_count(&self) -> usize {
        self.cells.lock().unwrap().len()
    }

    pub fn span_count(&self) -> usize {
        self.spans.lock().unwrap().len()
    }

    /// The counter sidecar document (deterministic: sorted by canonical
    /// key, no timestamps, integer-valued numbers).
    pub fn counters_json(&self) -> Json {
        let cells = self.cells.lock().unwrap();
        let items: Vec<Json> = cells
            .iter()
            .map(|(canonical, rec)| {
                Json::obj(vec![
                    ("key", Json::Str(canonical.clone())),
                    ("hash", Json::Str(rec.key_hash.clone())),
                    ("workload", Json::Str(rec.workload.clone())),
                    ("policy", Json::Str(rec.policy.clone())),
                    ("objective", Json::Str(rec.objective.clone())),
                    ("counters", counters_to_json(&rec.counters)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("schema", Json::Num(1.0)),
            ("cells", Json::Arr(items)),
        ])
    }

    fn counters_csv(&self) -> CsvTable {
        let mut t = CsvTable::new(&[
            "key_hash",
            "workload",
            "policy",
            "objective",
            "epochs",
            "instr",
            "cycles",
            "issued_cycles",
            "stall_waitcnt_ps",
            "stall_mem_outstanding_ps",
            "stall_issue_empty_ps",
            "l2_accesses",
            "l2_hits",
            "l2_misses",
            "dram_accesses",
            "pc_hits",
            "pc_misses",
            "pc_evictions",
            "transitions_per_domain",
            "l2_queue_depth_hist",
            "dram_queue_depth_hist",
        ]);
        let cells = self.cells.lock().unwrap();
        for rec in cells.values() {
            let c = &rec.counters;
            t.push(vec![
                rec.key_hash.clone(),
                rec.workload.clone(),
                rec.policy.clone(),
                rec.objective.clone(),
                c.epochs.to_string(),
                c.instr.to_string(),
                c.cycles.to_string(),
                c.issued_cycles.to_string(),
                c.stall_waitcnt_ps.to_string(),
                c.stall_mem_outstanding_ps.to_string(),
                c.stall_issue_empty_ps.to_string(),
                c.l2_accesses.to_string(),
                c.l2_hits.to_string(),
                c.l2_misses.to_string(),
                c.dram_accesses.to_string(),
                c.pc_hits.to_string(),
                c.pc_misses.to_string(),
                c.pc_evictions.to_string(),
                join_u64(&c.transitions_per_domain),
                join_u64(&c.l2_queue_depth_hist),
                join_u64(&c.dram_queue_depth_hist),
            ]);
        }
        t
    }

    /// Chrome trace-event text: a JSON array with exactly one complete
    /// event object per line, so it is both NDJSON-ish (line tools work
    /// after stripping `[`/`]`/trailing commas) and directly loadable
    /// in Perfetto / `chrome://tracing`.
    fn timeline_text(&self) -> String {
        let mut spans = self.spans.lock().unwrap().clone();
        spans.sort_by(|a, b| {
            (a.ts_us, a.tid, &a.cat, &a.name).cmp(&(b.ts_us, b.tid, &b.cat, &b.name))
        });
        let mut out = String::from("[\n");
        for (i, s) in spans.iter().enumerate() {
            let ev = Json::obj(vec![
                ("name", Json::Str(s.name.clone())),
                ("cat", Json::Str(s.cat.clone())),
                ("ph", Json::Str("X".into())),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(s.tid as f64)),
                ("ts", Json::Num(s.ts_us as f64)),
                ("dur", Json::Num(s.dur_us as f64)),
            ]);
            out.push_str(&ev.render());
            out.push_str(if i + 1 < spans.len() { ",\n" } else { "\n" });
        }
        out.push_str("]\n");
        out
    }

    /// Write all artifacts under the recorder's directory; returns the
    /// paths written.
    pub fn write(&self) -> Result<Vec<PathBuf>, String> {
        std::fs::create_dir_all(&self.dir)
            .map_err(|e| format!("creating {}: {e}", self.dir.display()))?;
        let mut out = Vec::new();
        let jp = self.dir.join("counters.json");
        self.counters_json()
            .write(&jp)
            .map_err(|e| format!("writing {}: {e}", jp.display()))?;
        out.push(jp);
        let cp = self.dir.join("counters.csv");
        self.counters_csv()
            .write(&cp)
            .map_err(|e| format!("writing {}: {e}", cp.display()))?;
        out.push(cp);
        let tp = self.dir.join("timeline.ndjson");
        std::fs::write(&tp, self.timeline_text())
            .map_err(|e| format!("writing {}: {e}", tp.display()))?;
        out.push(tp);
        Ok(out)
    }
}

fn join_u64(xs: &[u64]) -> String {
    xs.iter()
        .map(|x| x.to_string())
        .collect::<Vec<_>>()
        .join("|")
}

fn counters_to_json(c: &RunCounters) -> Json {
    let n = |x: u64| Json::Num(x as f64);
    let arr = |xs: &[u64]| Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect());
    Json::obj(vec![
        ("epochs", n(c.epochs)),
        ("instr", n(c.instr)),
        ("cycles", n(c.cycles)),
        ("issued_cycles", n(c.issued_cycles)),
        ("stall_waitcnt_ps", n(c.stall_waitcnt_ps)),
        ("stall_mem_outstanding_ps", n(c.stall_mem_outstanding_ps)),
        ("stall_issue_empty_ps", n(c.stall_issue_empty_ps)),
        ("l2_accesses", n(c.l2_accesses)),
        ("l2_hits", n(c.l2_hits)),
        ("l2_misses", n(c.l2_misses)),
        ("dram_accesses", n(c.dram_accesses)),
        ("l2_queue_depth_hist", arr(&c.l2_queue_depth_hist)),
        ("dram_queue_depth_hist", arr(&c.dram_queue_depth_hist)),
        ("pc_hits", n(c.pc_hits)),
        ("pc_misses", n(c.pc_misses)),
        ("pc_evictions", n(c.pc_evictions)),
        ("transitions_per_domain", arr(&c.transitions_per_domain)),
    ])
}

// ---------------------------------------------------------------------------
// `pcstall obs report`
// ---------------------------------------------------------------------------

fn get_u64(j: &Json, key: &str) -> u64 {
    j.get(key).and_then(Json::as_f64).unwrap_or(0.0) as u64
}

fn get_hist(j: &Json, key: &str) -> Vec<u64> {
    j.get(key)
        .and_then(Json::as_arr)
        .map(|a| a.iter().map(|x| x.as_f64().unwrap_or(0.0) as u64).collect())
        .unwrap_or_default()
}

fn add_hist(into: &mut Vec<u64>, from: &[u64]) {
    if into.len() < from.len() {
        into.resize(from.len(), 0);
    }
    for (a, &b) in into.iter_mut().zip(from) {
        *a += b;
    }
}

fn fmt_hist(h: &[u64]) -> String {
    let nonzero: Vec<String> = h
        .iter()
        .enumerate()
        .filter(|(_, &v)| v > 0)
        .map(|(i, v)| format!("{i}:{v}"))
        .collect();
    if nonzero.is_empty() {
        "-".into()
    } else {
        nonzero.join(" ")
    }
}

fn pct(part: u64, total: u64) -> String {
    if total == 0 {
        "-".into()
    } else {
        format!("{:.1}%", 100.0 * part as f64 / total as f64)
    }
}

/// Parse a counter sidecar back into per-cell totals.
fn read_counters(dir: &Path) -> Result<Vec<(String, RunCounters)>, String> {
    let path = dir.join("counters.json");
    let text = std::fs::read_to_string(&path).map_err(|e| {
        format!(
            "reading {}: {e} (run with `--obs {}` first)",
            path.display(),
            dir.display()
        )
    })?;
    let doc = Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
    let cells = doc
        .get("cells")
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("{}: no \"cells\" array", path.display()))?;
    let mut out = Vec::new();
    for cell in cells {
        let label = format!(
            "{}/{}/{}",
            cell.get("workload").and_then(Json::as_str).unwrap_or("?"),
            cell.get("policy").and_then(Json::as_str).unwrap_or("?"),
            cell.get("objective").and_then(Json::as_str).unwrap_or("?"),
        );
        let c = cell
            .get("counters")
            .ok_or_else(|| format!("{}: cell without counters", path.display()))?;
        let rc = RunCounters {
            epochs: get_u64(c, "epochs"),
            instr: get_u64(c, "instr"),
            cycles: get_u64(c, "cycles"),
            issued_cycles: get_u64(c, "issued_cycles"),
            stall_waitcnt_ps: get_u64(c, "stall_waitcnt_ps"),
            stall_mem_outstanding_ps: get_u64(c, "stall_mem_outstanding_ps"),
            stall_issue_empty_ps: get_u64(c, "stall_issue_empty_ps"),
            l2_accesses: get_u64(c, "l2_accesses"),
            l2_hits: get_u64(c, "l2_hits"),
            l2_misses: get_u64(c, "l2_misses"),
            dram_accesses: get_u64(c, "dram_accesses"),
            l2_queue_depth_hist: get_hist(c, "l2_queue_depth_hist"),
            dram_queue_depth_hist: get_hist(c, "dram_queue_depth_hist"),
            pc_hits: get_u64(c, "pc_hits"),
            pc_misses: get_u64(c, "pc_misses"),
            pc_evictions: get_u64(c, "pc_evictions"),
            transitions_per_domain: get_hist(c, "transitions_per_domain"),
        };
        out.push((label, rc));
    }
    Ok(out)
}

/// Aggregated span stats from `timeline.ndjson` (absent file → None).
fn read_spans(dir: &Path) -> Option<BTreeMap<(String, String), (u64, u64, u64)>> {
    let text = std::fs::read_to_string(dir.join("timeline.ndjson")).ok()?;
    // (cat, name) -> (count, total_us, max_us)
    let mut agg: BTreeMap<(String, String), (u64, u64, u64)> = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim().trim_end_matches(',');
        if line.is_empty() || line == "[" || line == "]" {
            continue;
        }
        let Ok(ev) = Json::parse(line) else { continue };
        let cat = ev.get("cat").and_then(Json::as_str).unwrap_or("?").to_string();
        let name = ev.get("name").and_then(Json::as_str).unwrap_or("?").to_string();
        let dur = get_u64(&ev, "dur");
        let e = agg.entry((cat, name)).or_insert((0, 0, 0));
        e.0 += 1;
        e.1 += dur;
        e.2 = e.2.max(dur);
    }
    Some(agg)
}

/// `pcstall obs report <dir>`: counter totals + top spans.
pub fn report(dir: &Path) -> Result<(), String> {
    let cells = read_counters(dir)?;
    println!("[obs report] {} — {} cell(s)", dir.display(), cells.len());

    let mut total = RunCounters::default();
    for (_, c) in &cells {
        total.epochs += c.epochs;
        total.instr += c.instr;
        total.cycles += c.cycles;
        total.issued_cycles += c.issued_cycles;
        total.stall_waitcnt_ps += c.stall_waitcnt_ps;
        total.stall_mem_outstanding_ps += c.stall_mem_outstanding_ps;
        total.stall_issue_empty_ps += c.stall_issue_empty_ps;
        total.l2_accesses += c.l2_accesses;
        total.l2_hits += c.l2_hits;
        total.l2_misses += c.l2_misses;
        total.dram_accesses += c.dram_accesses;
        add_hist(&mut total.l2_queue_depth_hist, &c.l2_queue_depth_hist);
        add_hist(&mut total.dram_queue_depth_hist, &c.dram_queue_depth_hist);
        total.pc_hits += c.pc_hits;
        total.pc_misses += c.pc_misses;
        total.pc_evictions += c.pc_evictions;
        add_hist(
            &mut total.transitions_per_domain,
            &c.transitions_per_domain,
        );
    }

    let stall = total.stall_total_ps();
    let rows = vec![
        vec!["epochs".into(), total.epochs.to_string(), String::new()],
        vec!["instr".into(), total.instr.to_string(), String::new()],
        vec![
            "issued_cycles / cycles".into(),
            format!("{} / {}", total.issued_cycles, total.cycles),
            pct(total.issued_cycles, total.cycles),
        ],
        vec![
            "stall: waitcnt".into(),
            format!("{} ps", total.stall_waitcnt_ps),
            pct(total.stall_waitcnt_ps, stall),
        ],
        vec![
            "stall: mem outstanding".into(),
            format!("{} ps", total.stall_mem_outstanding_ps),
            pct(total.stall_mem_outstanding_ps, stall),
        ],
        vec![
            "stall: issue empty".into(),
            format!("{} ps", total.stall_issue_empty_ps),
            pct(total.stall_issue_empty_ps, stall),
        ],
        vec![
            "l2 hits / accesses".into(),
            format!("{} / {}", total.l2_hits, total.l2_accesses),
            pct(total.l2_hits, total.l2_accesses),
        ],
        vec![
            "dram accesses".into(),
            total.dram_accesses.to_string(),
            pct(total.dram_accesses, total.l2_accesses),
        ],
        vec![
            "l2 queue-depth hist".into(),
            fmt_hist(&total.l2_queue_depth_hist),
            String::new(),
        ],
        vec![
            "dram queue-depth hist".into(),
            fmt_hist(&total.dram_queue_depth_hist),
            String::new(),
        ],
        vec![
            "pc table hits / lookups".into(),
            format!("{} / {}", total.pc_hits, total.pc_hits + total.pc_misses),
            pct(total.pc_hits, total.pc_hits + total.pc_misses),
        ],
        vec![
            "pc table evictions".into(),
            total.pc_evictions.to_string(),
            String::new(),
        ],
        vec![
            "dvfs transitions/domain".into(),
            fmt_hist(&total.transitions_per_domain),
            String::new(),
        ],
    ];
    print_table("counter totals", &["counter", "value", "share"], &rows);

    match read_spans(dir) {
        Some(agg) if !agg.is_empty() => {
            let mut spans: Vec<_> = agg.into_iter().collect();
            spans.sort_by(|a, b| b.1 .1.cmp(&a.1 .1).then_with(|| a.0.cmp(&b.0)));
            let rows: Vec<Vec<String>> = spans
                .iter()
                .take(12)
                .map(|((cat, name), (count, total_us, max_us))| {
                    vec![
                        format!("{cat}/{name}"),
                        count.to_string(),
                        format!("{:.3}", *total_us as f64 / 1e3),
                        format!("{:.3}", *total_us as f64 / 1e3 / (*count).max(1) as f64),
                        format!("{:.3}", *max_us as f64 / 1e3),
                    ]
                })
                .collect();
            print_table(
                "top spans (by total wall-clock)",
                &["span", "count", "total_ms", "mean_ms", "max_ms"],
                &rows,
            );
        }
        _ => println!("(no timeline.ndjson — span channel empty)"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_result() -> RunResult {
        RunResult {
            workload: "comd".into(),
            policy: "pcstall".into(),
            objective: "ed2p".into(),
            records: vec![],
            total_energy_j: 1.0,
            total_time_ns: 1.0,
            total_instr: 1.0,
            mean_accuracy: 1.0,
            pc_hit_rate: 0.0,
            completed: true,
        }
    }

    #[test]
    fn noop_sink_is_disabled_and_counterless() {
        let s = NoopSink;
        assert!(!s.enabled());
        assert!(s.counters().is_none());
    }

    #[test]
    fn counter_sink_accumulates_epochs_and_transitions() {
        let mut s = CounterSink::new();
        assert!(s.enabled());
        s.on_epoch(&EpochSample {
            instr: 10,
            cycles: 100,
            issued_cycles: 40,
            stall_waitcnt_ps: 7,
            stall_mem_outstanding_ps: 3,
            stall_issue_empty_ps: 2,
            switched_domains: vec![0, 2],
        });
        s.on_epoch(&EpochSample {
            instr: 5,
            switched_domains: vec![2],
            ..Default::default()
        });
        s.on_run_end(&RunEndSample {
            mem: MemCounters {
                l2_accesses: 9,
                l2_hits: 6,
                l2_misses: 3,
                dram_accesses: 3,
                l2_queue_depth_hist: vec![1, 2],
                dram_queue_depth_hist: vec![3],
            },
            pc_hits: 4,
            pc_misses: 2,
            pc_evictions: 1,
            n_domains: 4,
        });
        let c = s.counters().unwrap();
        assert_eq!(c.epochs, 2);
        assert_eq!(c.instr, 15);
        assert_eq!(c.stall_total_ps(), 12);
        assert_eq!(c.transitions_per_domain, vec![1, 0, 2, 0]);
        assert_eq!(c.transitions_total(), 3);
        assert_eq!(c.l2_hits, 6);
        assert_eq!(c.pc_evictions, 1);
    }

    #[test]
    fn recorder_counters_json_is_key_sorted_and_stable() {
        let rec = ObsRecorder::new(PathBuf::from("/nonexistent-unused"));
        let c = RunCounters {
            epochs: 3,
            ..Default::default()
        };
        // inserted out of order; emission must sort by canonical key
        rec.record_cell("v1|wl=zz|cfg=02", "beef", &run_result(), c.clone());
        rec.record_cell("v1|wl=aa|cfg=01", "cafe", &run_result(), c);
        let a = rec.counters_json().render();
        let b = rec.counters_json().render();
        assert_eq!(a, b, "re-rendering must be byte-identical");
        let first = a.find("wl=aa").unwrap();
        let second = a.find("wl=zz").unwrap();
        assert!(first < second, "cells must be canonical-key sorted");
        assert!(!a.contains("\"ts\""), "counter sidecar must carry no timestamps");
    }

    #[test]
    fn recorder_overwrite_same_key_is_idempotent() {
        let rec = ObsRecorder::new(PathBuf::from("/nonexistent-unused"));
        let c = RunCounters {
            epochs: 1,
            ..Default::default()
        };
        rec.record_cell("k", "h", &run_result(), c.clone());
        rec.record_cell("k", "h", &run_result(), c);
        assert_eq!(rec.cell_count(), 1);
    }

    #[test]
    fn timeline_is_chrome_trace_shaped() {
        let rec = ObsRecorder::new(PathBuf::from("/nonexistent-unused"));
        let t = rec.t0;
        rec.add_span(
            "exec",
            "pool.run",
            t + std::time::Duration::from_micros(5),
            t + std::time::Duration::from_micros(30),
            1,
        );
        rec.add_span("harness", "cell.simulate", t, t + std::time::Duration::from_micros(9), 0);
        let text = rec.timeline_text();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.first(), Some(&"["));
        assert_eq!(lines.last(), Some(&"]"));
        // one complete event per line, parseable after comma-stripping
        let ev = Json::parse(lines[1].trim_end_matches(',')).unwrap();
        assert_eq!(ev.get("ph").and_then(Json::as_str), Some("X"));
        // earliest span sorts first regardless of insertion order
        assert_eq!(ev.get("name").and_then(Json::as_str), Some("cell.simulate"));
        assert_eq!(ev.get("dur").and_then(Json::as_f64), Some(9.0));
        // the whole document is also one valid JSON array
        let doc = Json::parse(&text).unwrap();
        assert_eq!(doc.as_arr().map(<[Json]>::len), Some(2));
    }

    #[test]
    fn hist_formatting_skips_zero_buckets() {
        assert_eq!(fmt_hist(&[0, 3, 0, 1]), "1:3 3:1");
        assert_eq!(fmt_hist(&[0, 0]), "-");
        assert_eq!(pct(1, 4), "25.0%");
        assert_eq!(pct(0, 0), "-");
    }
}
