//! `pcstall obs report` — human-readable summary of all obs channels:
//! counter totals (channel 1), top wall-clock spans (channel 2), and —
//! when a `decisions.csv` sidecar is present — the decision-trace
//! section (channel 3): accuracy histogram, worst-regret epochs, and
//! the per-PC mispredict leaderboard joined against the PC-table
//! traffic counters.

use std::collections::BTreeMap;
use std::path::Path;

use crate::stats::emit::{print_table, Json};

use super::decisions::{read_decisions, DecisionRow};
use super::RunCounters;

pub(crate) fn get_u64(j: &Json, key: &str) -> u64 {
    j.get(key).and_then(Json::as_f64).unwrap_or(0.0) as u64
}

fn get_hist(j: &Json, key: &str) -> Vec<u64> {
    j.get(key)
        .and_then(Json::as_arr)
        .map(|a| a.iter().map(|x| x.as_f64().unwrap_or(0.0) as u64).collect())
        .unwrap_or_default()
}

fn add_hist(into: &mut Vec<u64>, from: &[u64]) {
    if into.len() < from.len() {
        into.resize(from.len(), 0);
    }
    for (a, &b) in into.iter_mut().zip(from) {
        *a += b;
    }
}

fn fmt_hist(h: &[u64]) -> String {
    let nonzero: Vec<String> = h
        .iter()
        .enumerate()
        .filter(|(_, &v)| v > 0)
        .map(|(i, v)| format!("{i}:{v}"))
        .collect();
    if nonzero.is_empty() {
        "-".into()
    } else {
        nonzero.join(" ")
    }
}

fn pct(part: u64, total: u64) -> String {
    if total == 0 {
        "-".into()
    } else {
        format!("{:.1}%", 100.0 * part as f64 / total as f64)
    }
}

/// Parse a counter sidecar back into per-cell totals, labelled
/// `workload/policy/objective`.
fn read_counters(dir: &Path) -> Result<Vec<(String, RunCounters)>, String> {
    let path = dir.join("counters.json");
    let text = std::fs::read_to_string(&path).map_err(|e| {
        format!(
            "reading {}: {e} (run with `--obs {}` first)",
            path.display(),
            dir.display()
        )
    })?;
    let doc = Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
    let cells = doc
        .get("cells")
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("{}: no \"cells\" array", path.display()))?;
    let mut out = Vec::new();
    for cell in cells {
        let label = format!(
            "{}/{}/{}",
            cell.get("workload").and_then(Json::as_str).unwrap_or("?"),
            cell.get("policy").and_then(Json::as_str).unwrap_or("?"),
            cell.get("objective").and_then(Json::as_str).unwrap_or("?"),
        );
        let c = cell
            .get("counters")
            .ok_or_else(|| format!("{}: cell without counters", path.display()))?;
        let rc = RunCounters {
            epochs: get_u64(c, "epochs"),
            instr: get_u64(c, "instr"),
            cycles: get_u64(c, "cycles"),
            issued_cycles: get_u64(c, "issued_cycles"),
            stall_waitcnt_ps: get_u64(c, "stall_waitcnt_ps"),
            stall_mem_outstanding_ps: get_u64(c, "stall_mem_outstanding_ps"),
            stall_issue_empty_ps: get_u64(c, "stall_issue_empty_ps"),
            l2_accesses: get_u64(c, "l2_accesses"),
            l2_hits: get_u64(c, "l2_hits"),
            l2_misses: get_u64(c, "l2_misses"),
            dram_accesses: get_u64(c, "dram_accesses"),
            l2_queue_depth_hist: get_hist(c, "l2_queue_depth_hist"),
            dram_queue_depth_hist: get_hist(c, "dram_queue_depth_hist"),
            pc_hits: get_u64(c, "pc_hits"),
            pc_misses: get_u64(c, "pc_misses"),
            pc_evictions: get_u64(c, "pc_evictions"),
            transitions_per_domain: get_hist(c, "transitions_per_domain"),
        };
        out.push((label, rc));
    }
    Ok(out)
}

/// Aggregated span stats from `timeline.ndjson` (absent file → None).
fn read_spans(dir: &Path) -> Option<BTreeMap<(String, String), (u64, u64, u64)>> {
    let text = std::fs::read_to_string(dir.join("timeline.ndjson")).ok()?;
    // (cat, name) -> (count, total_us, max_us)
    let mut agg: BTreeMap<(String, String), (u64, u64, u64)> = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim().trim_end_matches(',');
        if line.is_empty() || line == "[" || line == "]" {
            continue;
        }
        let Ok(ev) = Json::parse(line) else { continue };
        let cat = ev.get("cat").and_then(Json::as_str).unwrap_or("?").to_string();
        let name = ev.get("name").and_then(Json::as_str).unwrap_or("?").to_string();
        let dur = get_u64(&ev, "dur");
        let e = agg.entry((cat, name)).or_insert((0, 0, 0));
        e.0 += 1;
        e.1 += dur;
        e.2 = e.2.max(dur);
    }
    Some(agg)
}

/// `pcstall obs report <dir>`: counter totals + decision trace + spans.
pub fn report(dir: &Path) -> Result<(), String> {
    let cells = read_counters(dir)?;
    println!("[obs report] {} — {} cell(s)", dir.display(), cells.len());

    let mut total = RunCounters::default();
    for (_, c) in &cells {
        total.epochs += c.epochs;
        total.instr += c.instr;
        total.cycles += c.cycles;
        total.issued_cycles += c.issued_cycles;
        total.stall_waitcnt_ps += c.stall_waitcnt_ps;
        total.stall_mem_outstanding_ps += c.stall_mem_outstanding_ps;
        total.stall_issue_empty_ps += c.stall_issue_empty_ps;
        total.l2_accesses += c.l2_accesses;
        total.l2_hits += c.l2_hits;
        total.l2_misses += c.l2_misses;
        total.dram_accesses += c.dram_accesses;
        add_hist(&mut total.l2_queue_depth_hist, &c.l2_queue_depth_hist);
        add_hist(&mut total.dram_queue_depth_hist, &c.dram_queue_depth_hist);
        total.pc_hits += c.pc_hits;
        total.pc_misses += c.pc_misses;
        total.pc_evictions += c.pc_evictions;
        add_hist(
            &mut total.transitions_per_domain,
            &c.transitions_per_domain,
        );
    }

    let stall = total.stall_total_ps();
    let rows = vec![
        vec!["epochs".into(), total.epochs.to_string(), String::new()],
        vec!["instr".into(), total.instr.to_string(), String::new()],
        vec![
            "issued_cycles / cycles".into(),
            format!("{} / {}", total.issued_cycles, total.cycles),
            pct(total.issued_cycles, total.cycles),
        ],
        vec![
            "stall: waitcnt".into(),
            format!("{} ps", total.stall_waitcnt_ps),
            pct(total.stall_waitcnt_ps, stall),
        ],
        vec![
            "stall: mem outstanding".into(),
            format!("{} ps", total.stall_mem_outstanding_ps),
            pct(total.stall_mem_outstanding_ps, stall),
        ],
        vec![
            "stall: issue empty".into(),
            format!("{} ps", total.stall_issue_empty_ps),
            pct(total.stall_issue_empty_ps, stall),
        ],
        vec![
            "l2 hits / accesses".into(),
            format!("{} / {}", total.l2_hits, total.l2_accesses),
            pct(total.l2_hits, total.l2_accesses),
        ],
        vec![
            "dram accesses".into(),
            total.dram_accesses.to_string(),
            pct(total.dram_accesses, total.l2_accesses),
        ],
        vec![
            "l2 queue-depth hist".into(),
            fmt_hist(&total.l2_queue_depth_hist),
            String::new(),
        ],
        vec![
            "dram queue-depth hist".into(),
            fmt_hist(&total.dram_queue_depth_hist),
            String::new(),
        ],
        vec![
            "pc table hits / lookups".into(),
            format!("{} / {}", total.pc_hits, total.pc_hits + total.pc_misses),
            pct(total.pc_hits, total.pc_hits + total.pc_misses),
        ],
        vec![
            "pc table evictions".into(),
            total.pc_evictions.to_string(),
            String::new(),
        ],
        vec![
            "dvfs transitions/domain".into(),
            fmt_hist(&total.transitions_per_domain),
            String::new(),
        ],
    ];
    print_table("counter totals", &["counter", "value", "share"], &rows);

    match read_decisions(dir) {
        Ok(rows) if !rows.is_empty() => decision_section(&rows, &cells),
        _ => println!("(no decisions.csv — decision channel empty)"),
    }

    match read_spans(dir) {
        Some(agg) if !agg.is_empty() => {
            let mut spans: Vec<_> = agg.into_iter().collect();
            spans.sort_by(|a, b| b.1 .1.cmp(&a.1 .1).then_with(|| a.0.cmp(&b.0)));
            let rows: Vec<Vec<String>> = spans
                .iter()
                .take(12)
                .map(|((cat, name), (count, total_us, max_us))| {
                    vec![
                        format!("{cat}/{name}"),
                        count.to_string(),
                        format!("{:.3}", *total_us as f64 / 1e3),
                        format!("{:.3}", *total_us as f64 / 1e3 / (*count).max(1) as f64),
                        format!("{:.3}", *max_us as f64 / 1e3),
                    ]
                })
                .collect();
            print_table(
                "top spans (by total wall-clock)",
                &["span", "count", "total_ms", "mean_ms", "max_ms"],
                &rows,
            );
        }
        _ => println!("(no timeline.ndjson — span channel empty)"),
    }
    Ok(())
}

/// Relative mispredict magnitude of one row (1 − accuracy of that
/// domain's forecast, computed from the row's own pred/actual pair).
fn row_err(r: &DecisionRow) -> f64 {
    let m = r.pred_instr.max(r.actual_instr);
    if m < 1.0 {
        0.0
    } else {
        ((r.pred_instr - r.actual_instr).abs() / m).clamp(0.0, 1.0)
    }
}

/// Channel-3 report section: accuracy histogram, top worst-regret
/// epochs, per-PC mispredict leaderboard.
fn decision_section(rows: &[DecisionRow], counters: &[(String, RunCounters)]) {
    // -- accuracy histogram over per-epoch values (the accuracy column
    // repeats on every domain row of an epoch: dedupe by cell + epoch).
    let mut seen = std::collections::BTreeSet::new();
    let mut hist = [0u64; 10];
    let mut nan = 0u64;
    for r in rows {
        if !seen.insert((r.cell_id(), r.epoch)) {
            continue;
        }
        if r.accuracy.is_finite() {
            let b = ((r.accuracy * 10.0) as usize).min(9);
            hist[b] += 1;
        } else {
            nan += 1;
        }
    }
    let total_epochs: u64 = hist.iter().sum();
    let hist_rows: Vec<Vec<String>> = (0..10)
        .map(|b| {
            vec![
                format!("[{:.1}, {:.1})", b as f64 / 10.0, (b + 1) as f64 / 10.0),
                hist[b].to_string(),
                pct(hist[b], total_epochs),
            ]
        })
        .collect();
    print_table(
        &format!("decision trace: epoch accuracy histogram ({total_epochs} epochs, {nan} unscored)"),
        &["accuracy", "epochs", "share"],
        &hist_rows,
    );

    // -- top-K worst-regret (cell, epoch, domain) rows.
    let mut by_regret: Vec<&DecisionRow> = rows.iter().filter(|r| r.regret > 0.0).collect();
    by_regret.sort_by(|a, b| {
        b.regret
            .partial_cmp(&a.regret)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| (&a.workload, &a.policy, a.epoch).cmp(&(&b.workload, &b.policy, b.epoch)))
    });
    if by_regret.is_empty() {
        println!("(no nonzero regret — no oracle-laddered cells in this trace)");
    } else {
        let rrows: Vec<Vec<String>> = by_regret
            .iter()
            .take(8)
            .map(|r| {
                vec![
                    format!("{}/{}/{}", r.workload, r.policy, r.objective),
                    r.epoch.to_string(),
                    r.domain.to_string(),
                    format!("{} -> {}", r.chosen, r.oracle_best),
                    format!("{:.3e}", r.regret),
                    format!("{:.3}", r.accuracy),
                ]
            })
            .collect();
        print_table(
            "decision trace: worst-regret epochs",
            &["cell", "epoch", "dom", "chosen->best", "regret", "accuracy"],
            &rrows,
        );
    }

    // -- per-PC mispredict leaderboard, joined per cell against the
    // PC-table traffic counters from counters.json.
    let mut by_pc: BTreeMap<(String, u32), (u64, f64, f64)> = BTreeMap::new();
    for r in rows {
        let Some(pc) = r.pc else { continue };
        let label = format!("{}/{}/{}", r.workload, r.policy, r.objective);
        let e = by_pc.entry((label, pc)).or_insert((0, 0.0, 0.0));
        e.0 += 1;
        e.1 += row_err(r);
        e.2 += r.regret;
    }
    if by_pc.is_empty() {
        println!("(no PC-keyed cells in this trace — leaderboard empty)");
        return;
    }
    let pc_counts: BTreeMap<&str, &RunCounters> =
        counters.iter().map(|(l, c)| (l.as_str(), c)).collect();
    let mut board: Vec<_> = by_pc.into_iter().collect();
    // worst mean mispredict first; count then key breaks ties
    board.sort_by(|a, b| {
        let ea = a.1 .1 / a.1 .0 as f64;
        let eb = b.1 .1 / b.1 .0 as f64;
        eb.partial_cmp(&ea)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| b.1 .0.cmp(&a.1 .0))
            .then_with(|| a.0.cmp(&b.0))
    });
    let prows: Vec<Vec<String>> = board
        .iter()
        .take(10)
        .map(|((label, pc), (n, err_sum, regret_sum))| {
            let hit = pc_counts
                .get(label.as_str())
                .map(|c| pct(c.pc_hits, c.pc_hits + c.pc_misses))
                .unwrap_or_else(|| "-".into());
            vec![
                label.clone(),
                format!("0x{pc:x}"),
                n.to_string(),
                format!("{:.3}", err_sum / *n as f64),
                format!("{:.3e}", regret_sum),
                hit,
            ]
        })
        .collect();
    print_table(
        "decision trace: per-PC mispredict leaderboard",
        &["cell", "pc", "epochs", "mean_err", "regret", "cell_pc_hit%"],
        &prows,
    );
}
