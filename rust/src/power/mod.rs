//! Per-CU power model (paper §5 "Power Model").
//!
//! `P(f, rate) = (C1·V²·rate + C2·V²·f + L0·e^{LV(V−Vnom)}) / η(f)`
//!
//! * the first term is instruction-driven switching (activity ∝ committed
//!   instruction rate, the paper's performance-counter-based estimate),
//! * the second is clock-tree/pipeline switching that burns with every
//!   cycle regardless of useful work,
//! * leakage is exponential in voltage but nearly flat over the small IVR
//!   range (the paper's observation),
//! * η is the IVR conversion efficiency at the chosen state.
//!
//! The constants here **must** stay identical to
//! `python/compile/params.py`; `rust/tests/pjrt_parity.rs` executes the
//! AOT artifact against [`crate::dvfs::native`] to enforce it.

pub mod params;

pub use params::PowerParams;

/// Energy/power bookkeeping for one V/f domain over one epoch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EpochPower {
    /// Average total power over the epoch (W).
    pub power_w: f64,
    /// Energy consumed over the epoch (J).
    pub energy_j: f64,
}

impl PowerParams {
    /// Supply voltage on the IVR line at frequency `f_ghz`.
    #[inline]
    pub fn voltage(&self, f_ghz: f64) -> f64 {
        self.v0 + self.kv * (f_ghz - self.f_min_ghz)
    }

    /// IVR conversion efficiency at the state supplying `f_ghz`.
    #[inline]
    pub fn ivr_eta(&self, f_ghz: f64) -> f64 {
        self.eta0 + self.eta_slope * (f_ghz - self.f_min_ghz) / (self.f_max_ghz - self.f_min_ghz)
    }

    /// Total CU power at frequency `f_ghz` with committed instruction rate
    /// `rate_gips` (Giga-instructions per second = instructions per ns).
    #[inline]
    pub fn power_w(&self, f_ghz: f64, rate_gips: f64) -> f64 {
        let v = self.voltage(f_ghz);
        let v2 = v * v;
        let p_dyn = self.c1 * v2 * rate_gips + self.c2 * v2 * f_ghz;
        let p_leak = self.l0 * (self.lv * (v - self.v_nom)).exp();
        (p_dyn + p_leak) / self.ivr_eta(f_ghz)
    }

    /// Power + energy for an epoch of `epoch_ns` in which `instr`
    /// instructions were committed at `f_ghz`.
    pub fn epoch_power(&self, f_ghz: f64, instr: f64, epoch_ns: f64) -> EpochPower {
        let rate = instr / epoch_ns.max(1e-9);
        let p = self.power_w(f_ghz, rate);
        EpochPower {
            power_w: p,
            energy_j: p * epoch_ns * 1e-9,
        }
    }

    /// Energy cost of one V/f transition (charging/discharging the domain
    /// rail); amortized against the epoch by the manager.
    #[inline]
    pub fn transition_energy_j(&self, f_from_ghz: f64, f_to_ghz: f64) -> f64 {
        let dv = (self.voltage(f_to_ghz) - self.voltage(f_from_ghz)).abs();
        // E ≈ C_rail · V · ΔV; C_rail folded into a fitted constant.
        self.rail_cj * dv
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> PowerParams {
        PowerParams::default()
    }

    #[test]
    fn voltage_endpoints_match_paper_range() {
        let p = p();
        assert!((p.voltage(1.3) - 0.75).abs() < 1e-12);
        assert!((p.voltage(2.2) - 1.05).abs() < 1e-9);
    }

    #[test]
    fn power_monotonic_in_frequency_at_fixed_rate() {
        let p = p();
        let mut last = 0.0;
        for i in 0..10 {
            let f = 1.3 + 0.1 * i as f64;
            let w = p.power_w(f, 1.0);
            assert!(w > last, "power must rise with f: {w} !> {last}");
            last = w;
        }
    }

    #[test]
    fn power_monotonic_in_rate() {
        let p = p();
        assert!(p.power_w(1.7, 2.0) > p.power_w(1.7, 1.0));
    }

    #[test]
    fn cubic_scaling_shape() {
        // Dynamic power at max-vs-min state for a compute-bound phase
        // (rate ∝ f) should scale super-linearly (~V²f ⇒ ×(1.4)²×1.69 ≈ 3.3).
        let p = p();
        let lo = p.power_w(1.3, 1.3);
        let hi = p.power_w(2.2, 2.2);
        let ratio = hi / lo;
        assert!(
            (2.5..4.5).contains(&ratio),
            "compute-bound power ratio {ratio} outside plausible cubic band"
        );
    }

    #[test]
    fn leakage_flat_over_ivr_range() {
        // Paper: "leakage power at the different operating states does not
        // significantly vary across the small voltage range".
        let p = p();
        let leak = |f: f64| p.l0 * (p.lv * (p.voltage(f) - p.v_nom)).exp();
        assert!(leak(2.2) / leak(1.3) < 2.0);
    }

    #[test]
    fn epoch_energy_integrates_power() {
        let p = p();
        let e = p.epoch_power(1.7, 1700.0, 1000.0);
        assert!((e.energy_j - e.power_w * 1e-6).abs() < 1e-15);
    }

    #[test]
    fn transition_energy_zero_for_same_state() {
        let p = p();
        assert_eq!(p.transition_energy_j(1.7, 1.7), 0.0);
        assert!(p.transition_energy_j(1.3, 2.2) > 0.0);
    }

    #[test]
    fn per_cu_power_in_plausible_gpu_band() {
        // A compute-bound CU at 2.2 GHz should land in the single-digit
        // watt range (64 CUs ≈ a 200–350 W board).
        let p = p();
        let w = p.power_w(2.2, 2.2);
        assert!((2.0..8.0).contains(&w), "per-CU power {w} W implausible");
    }
}
