//! Power/V-f constants — the Rust mirror of `python/compile/params.py`.
//!
//! Keep the two files in lockstep; `rust/tests/pjrt_parity.rs` fails if
//! they drift (it compares the AOT artifact, built from the Python
//! constants, against the native implementation built from these).


/// Number of V/f states (paper §5: 1.3–2.2 GHz at 100 MHz steps).
pub const N_FREQ: usize = 10;

/// The discrete frequency ladder in GHz.
pub const FREQS_GHZ: [f64; N_FREQ] = [1.3, 1.4, 1.5, 1.6, 1.7, 1.8, 1.9, 2.0, 2.1, 2.2];

/// Paper's static normalization point (Figs. 15/17).
pub const F_STATIC_GHZ: f64 = 1.7;

/// Index of [`F_STATIC_GHZ`] in [`FREQS_GHZ`].
pub const F_STATIC_IDX: usize = 4;

/// Numerical floor shared with the kernels.
pub const EPS: f64 = 1e-6;

/// All tunable power-model constants.  `Default` gives the values baked
/// into the AOT artifact.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerParams {
    pub f_min_ghz: f64,
    pub f_max_ghz: f64,
    /// Voltage at `f_min` (V).
    pub v0: f64,
    /// Voltage slope (V per GHz).
    pub kv: f64,
    /// Leakage reference voltage (V).
    pub v_nom: f64,
    /// Instruction-driven switching (W per V² per Ginstr/s).
    pub c1: f64,
    /// Clock-tree switching (W per V² per GHz).
    pub c2: f64,
    /// Leakage magnitude at `v_nom` (W).
    pub l0: f64,
    /// Leakage exponential slope (1/V).
    pub lv: f64,
    /// IVR efficiency at the lowest state.
    pub eta0: f64,
    /// IVR efficiency rise from lowest to highest state.
    pub eta_slope: f64,
    /// Rail charge constant for transition energy (J per V).
    pub rail_cj: f64,
}

impl Default for PowerParams {
    fn default() -> Self {
        Self {
            f_min_ghz: 1.3,
            f_max_ghz: 2.2,
            v0: 0.75,
            kv: (1.05 - 0.75) / (2.2 - 1.3),
            v_nom: 0.90,
            c1: 0.9,
            c2: 0.6,
            l0: 0.35,
            lv: 2.0,
            eta0: 0.88,
            eta_slope: 0.05,
            rail_cj: 2e-9,
        }
    }
}

/// Nearest ladder index for an arbitrary frequency (clamped).
pub fn freq_index(f_ghz: f64) -> usize {
    let idx = ((f_ghz - FREQS_GHZ[0]) / 0.1).round() as isize;
    idx.clamp(0, (N_FREQ - 1) as isize) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_is_uniform_100mhz() {
        for w in FREQS_GHZ.windows(2) {
            assert!((w[1] - w[0] - 0.1).abs() < 1e-12);
        }
    }

    #[test]
    fn static_index_is_1p7() {
        assert!((FREQS_GHZ[F_STATIC_IDX] - F_STATIC_GHZ).abs() < 1e-12);
    }

    #[test]
    fn freq_index_roundtrip() {
        for (i, f) in FREQS_GHZ.iter().enumerate() {
            assert_eq!(freq_index(*f), i);
        }
        assert_eq!(freq_index(0.5), 0);
        assert_eq!(freq_index(9.9), N_FREQ - 1);
        assert_eq!(freq_index(1.74), 4);
    }
}
