//! Prediction mechanisms (paper §2.4, §4.3–4.4): last-value reactive,
//! the PC-indexed sensitivity table (PCSTALL), and the fork-pre-execute
//! oracle.

pub mod oracle;
pub mod pc_table;
pub mod storage;

pub use oracle::{OracleSample, OracleSampler};
pub use pc_table::{PcTables, ReactiveState};
