//! Fork-Pre-Execute oracle (paper §5.1, Fig. 13).
//!
//! At an epoch boundary the simulator state is snapshotted (the paper's
//! process *fork*), the upcoming epoch is pre-executed once per V/f
//! state with frequencies *shuffled* across domains (so every domain
//! visits every state exactly once across the 10 samples, and
//! cross-domain interference is averaged out), per-domain and per-slot
//! instruction counts are regressed against frequency, and the state is
//! restored for the real execution.
//!
//! This is both the ground-truth generator (ORACLE / ACCREAC / ACCPC in
//! Table III) and the measurement instrument for the characterization
//! experiments (Figs. 5–11).

use crate::dvfs::sensitivity::SensEstimate;
use crate::power::params::{FREQS_GHZ, N_FREQ};
use crate::sim::gpu::Gpu;
use crate::util::linreg;

/// Result of pre-executing one epoch at all ladder states.
#[derive(Debug, Clone)]
pub struct OracleSample {
    /// Accurate per-domain estimates of the sampled epoch.
    pub dom: Vec<SensEstimate>,
    /// Regression quality per domain.
    pub dom_r2: Vec<f64>,
    /// Measured instructions per domain at each ladder state
    /// (`[n_dom][N_FREQ]`), aligned to the shuffle.
    pub dom_instr_at: Vec<[f64; N_FREQ]>,
    /// Accurate per-CU, per-slot estimates (ACCPC's table payload).
    pub wf: Vec<Vec<SensEstimate>>,
    /// Per-CU, per-slot epoch-start PC/kernel (table update keys) and
    /// active flags, captured from the sampled epoch.
    pub wf_start_pc: Vec<Vec<u32>>,
    pub wf_start_kernel: Vec<Vec<u32>>,
    pub wf_active: Vec<Vec<bool>>,
}

/// The sampler.  Stateless; holds only tunables.
#[derive(Debug, Clone, Copy)]
pub struct OracleSampler {
    /// Number of sampling processes (paper: one per V/f state).
    pub n_samples: usize,
}

impl Default for OracleSampler {
    fn default() -> Self {
        OracleSampler { n_samples: N_FREQ }
    }
}

impl OracleSampler {
    /// Pre-execute the next epoch of `gpu` (left untouched — all work
    /// happens on clones, the in-process analogue of fork).
    pub fn sample(&self, gpu: &Gpu) -> OracleSample {
        let n_dom = gpu.n_domains();
        let n_cu = gpu.cfg.gpu.n_cu;
        let n_wf = gpu.cfg.gpu.n_wf;

        // sampled instruction counts: [sample][domain], [sample][cu][slot]
        let mut dom_instr = vec![vec![0f64; n_dom]; self.n_samples];
        let mut wf_instr = vec![vec![vec![0f32; n_wf]; n_cu]; self.n_samples];
        let mut dom_freq = vec![vec![0f64; n_dom]; self.n_samples];
        let mut keys: Option<(Vec<Vec<u32>>, Vec<Vec<u32>>, Vec<Vec<bool>>)> = None;

        for k in 0..self.n_samples {
            let mut sim = gpu.clone();
            // Shuffled assignment: domain d runs at state (d + k) mod 10.
            for d in 0..n_dom {
                let f = FREQS_GHZ[(d + k) % N_FREQ];
                sim.set_domain_frequency(d, f);
                dom_freq[k][d] = f;
            }
            let ob = sim.run_epoch();
            for d in 0..n_dom {
                dom_instr[k][d] = sim
                    .domain_cus(d)
                    .map(|c| sim.cus[c].counters.instr as f64)
                    .sum();
            }
            for c in 0..n_cu {
                for w in 0..n_wf {
                    wf_instr[k][c][w] = ob.wf_instr[c][w];
                }
            }
            if keys.is_none() {
                keys = Some((ob.wf_start_pc, ob.wf_start_kernel, ob.wf_active));
            }
        }

        // Per-domain regression over the (freq, instr) samples.
        let mut dom = Vec::with_capacity(n_dom);
        let mut dom_r2 = Vec::with_capacity(n_dom);
        let mut dom_instr_at = Vec::with_capacity(n_dom);
        for d in 0..n_dom {
            let xs: Vec<f64> = (0..self.n_samples).map(|k| dom_freq[k][d]).collect();
            let ys: Vec<f64> = (0..self.n_samples).map(|k| dom_instr[k][d]).collect();
            let (i0, s, r2) = linreg(&xs, &ys);
            dom.push(SensEstimate::new(s, i0.max(0.0)));
            dom_r2.push(r2);
            // reorder measurements onto the ladder
            let mut at = [0f64; N_FREQ];
            for k in 0..self.n_samples {
                let idx = crate::power::params::freq_index(dom_freq[k][d]);
                at[idx] = dom_instr[k][d];
            }
            dom_instr_at.push(at);
        }

        // Per-slot regression (all CUs of a domain share its frequency).
        let mut wf = Vec::with_capacity(n_cu);
        for c in 0..n_cu {
            let d = gpu.cu_domain(c);
            let xs: Vec<f64> = (0..self.n_samples).map(|k| dom_freq[k][d]).collect();
            let mut slots = Vec::with_capacity(n_wf);
            for w in 0..n_wf {
                let ys: Vec<f64> = (0..self.n_samples)
                    .map(|k| wf_instr[k][c][w] as f64)
                    .collect();
                let (i0, s, _) = linreg(&xs, &ys);
                slots.push(SensEstimate::new(s.max(0.0), i0.max(0.0)));
            }
            wf.push(slots);
        }

        let (wf_start_pc, wf_start_kernel, wf_active) = keys.unwrap();
        OracleSample {
            dom,
            dom_r2,
            dom_instr_at,
            wf,
            wf_start_pc,
            wf_start_kernel,
            wf_active,
        }
    }

    /// Validation metric (paper §5.1: 97.6% with 10 processes): compare
    /// each domain's regression prediction at its *re-executed* frequency
    /// with the instructions the real execution committed.
    pub fn validate(&self, gpu: &Gpu, chosen_freq_ghz: &[f64]) -> f64 {
        let sample = self.sample(gpu);
        let mut sim = gpu.clone();
        for (d, &f) in chosen_freq_ghz.iter().enumerate() {
            sim.set_domain_frequency(d, f);
        }
        sim.run_epoch();
        let mut accs = Vec::new();
        for d in 0..gpu.n_domains() {
            let actual: f64 = sim
                .domain_cus(d)
                .map(|c| sim.cus[c].counters.instr as f64)
                .sum();
            let predicted = sample.dom[d].instr_at(chosen_freq_ghz[d]);
            accs.push(crate::dvfs::sensitivity::prediction_accuracy(
                predicted, actual,
            ));
        }
        accs.iter().sum::<f64>() / accs.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::sim::gpu::KernelLaunch;
    use crate::sim::isa::{Op, Pattern, ProgramBuilder};
    use std::sync::Arc;

    fn gpu_with(compute: bool) -> Gpu {
        let mut cfg = SimConfig::small();
        cfg.gpu.n_cu = 4;
        cfg.gpu.n_wf = 8;
        let mut g = Gpu::new(cfg);
        let mut b = ProgramBuilder::new();
        if compute {
            b.with_loop(0, 5000, 0, |b| {
                b.push(Op::VAlu { cycles: 1 });
            });
        } else {
            b.with_loop(0, 5000, 0, |b| {
                b.push(Op::Load {
                    pattern: Pattern::Random {
                        region: 1,
                        working_set: 256 << 20,
                    },
                    fan: 1,
                });
                b.push(Op::WaitCnt { max: 0 });
            });
        }
        g.load_workload(
            vec![KernelLaunch {
                program: Arc::new(b.build(0, "t")),
                waves_per_cu: 16,
            }],
            1,
        );
        // settle one epoch so wavefronts are mid-flight
        g.run_epoch();
        g
    }

    #[test]
    fn sample_leaves_gpu_untouched() {
        let g = gpu_with(true);
        let before = g.total_instr();
        let now = g.now_ps;
        OracleSampler::default().sample(&g);
        assert_eq!(g.total_instr(), before);
        assert_eq!(g.now_ps, now);
    }

    #[test]
    fn compute_bound_epoch_regresses_high_sensitivity() {
        let g = gpu_with(true);
        let s = OracleSampler::default().sample(&g);
        for d in 0..g.n_domains() {
            assert!(
                s.dom[d].sens > 500.0,
                "domain {d} sens {} too low for pure compute",
                s.dom[d].sens
            );
            assert!(s.dom_r2[d] > 0.95, "R² {} too low", s.dom_r2[d]);
        }
    }

    #[test]
    fn memory_bound_epoch_regresses_low_sensitivity() {
        let g = gpu_with(false);
        let s = OracleSampler::default().sample(&g);
        let mean_sens: f64 =
            s.dom.iter().map(|e| e.sens).sum::<f64>() / s.dom.len() as f64;
        let mean_i0: f64 = s.dom.iter().map(|e| e.i0).sum::<f64>() / s.dom.len() as f64;
        assert!(
            mean_sens < 0.3 * mean_i0.max(1.0),
            "memory-bound sens {mean_sens} vs i0 {mean_i0}"
        );
    }

    #[test]
    fn shuffle_covers_every_state_per_domain() {
        let g = gpu_with(true);
        let s = OracleSampler::default().sample(&g);
        // dom_instr_at has a measurement at every ladder slot
        for d in 0..g.n_domains() {
            for k in 0..N_FREQ {
                assert!(
                    s.dom_instr_at[d][k] > 0.0,
                    "domain {d} state {k} never sampled"
                );
            }
        }
    }

    #[test]
    fn validation_accuracy_is_high() {
        let g = gpu_with(true);
        let freqs: Vec<f64> = (0..g.n_domains())
            .map(|d| FREQS_GHZ[d % N_FREQ])
            .collect();
        let acc = OracleSampler::default().validate(&g, &freqs);
        // paper reports 97.6% with 10 sampling processes
        assert!(acc > 0.90, "oracle validation accuracy {acc}");
    }

    #[test]
    fn per_wavefront_estimates_sum_to_domain_scale() {
        let g = gpu_with(true);
        let s = OracleSampler::default().sample(&g);
        let wf_total: f64 = s.wf.iter().flatten().map(|e| e.sens).sum();
        let dom_total: f64 = s.dom.iter().map(|e| e.sens).sum();
        // per-slot regressions are noisier but must be the same magnitude
        assert!(
            wf_total > 0.3 * dom_total && wf_total < 3.0 * dom_total.max(1.0),
            "wf {wf_total} vs dom {dom_total}"
        );
    }
}
