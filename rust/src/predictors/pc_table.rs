//! The PC-indexed sensitivity table (paper §4.4, Fig. 12) and the
//! last-value reactive predictor it is compared against (Fig. 3a).
//!
//! Table mechanics follow the paper: each wavefront indexes with its
//! *starting PC* for the update path and its *current PC* for the lookup
//! path; entries store the sensitivity of the time epoch that started at
//! that PC.  Instruction PCs are converted to byte addresses (4-byte
//! encoded ISA) before applying the configurable offset shift, so
//! `pc_offset_bits = 4` groups ~4 instructions per entry exactly as in
//! Fig. 11b.  Tables may be shared by several CUs (`pc_table_share`).

use crate::config::DvfsConfig;
use crate::dvfs::sensitivity::SensEstimate;

/// One table entry: the (S, I0) estimate of the epoch that began at this
/// PC bucket, plus a valid bit.
#[derive(Debug, Clone, Copy, Default)]
struct Entry {
    sens: f32,
    i0: f32,
    valid: bool,
}

/// One physical PC table instance.
#[derive(Debug, Clone)]
struct Table {
    entries: Vec<Entry>,
    mask: usize,
    offset_bits: u32,
    alpha: f32,
    pub hits: u64,
    pub misses: u64,
    /// Destructive overwrites of a valid entry (the no-blend update
    /// path) — the obs proxy for table pressure: with `alpha < 1`,
    /// live entries blend instead, so this only counts replacements.
    pub evictions: u64,
}

impl Table {
    fn new(n_entries: usize, offset_bits: u32, alpha: f64) -> Self {
        let n = n_entries.next_power_of_two().max(2);
        Table {
            entries: vec![Entry::default(); n],
            mask: n - 1,
            offset_bits,
            alpha: alpha as f32,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Fig. 12 indexing: byte-PC shifted by the offset, XOR-folded with
    /// the kernel id so distinct kernels do not systematically alias.
    #[inline]
    fn index(&self, kernel_id: u32, pc: u32) -> usize {
        let byte_pc = (pc as u64) << 2; // 4-byte encoded instructions
        let bucket = byte_pc >> self.offset_bits;
        (bucket as usize ^ (kernel_id as usize).wrapping_mul(0x9E37_79B9)) & self.mask
    }

    fn update(&mut self, kernel_id: u32, pc: u32, est: SensEstimate) {
        let idx = self.index(kernel_id, pc);
        let alpha = self.alpha;
        let e = &mut self.entries[idx];
        if e.valid && alpha < 1.0 {
            e.sens = alpha * est.sens as f32 + (1.0 - alpha) * e.sens;
            e.i0 = alpha * est.i0 as f32 + (1.0 - alpha) * e.i0;
        } else {
            let evicted = e.valid;
            e.sens = est.sens as f32;
            e.i0 = est.i0 as f32;
            e.valid = true;
            if evicted {
                self.evictions += 1;
            }
        }
    }

    fn lookup(&mut self, kernel_id: u32, pc: u32) -> Option<SensEstimate> {
        let e = self.entries[self.index(kernel_id, pc)];
        if e.valid {
            self.hits += 1;
            Some(SensEstimate::new(e.sens as f64, e.i0 as f64))
        } else {
            self.misses += 1;
            None
        }
    }
}

/// The full PCSTALL predictor state: one table per `pc_table_share` CUs
/// plus the per-slot last-value fallback used before an entry exists.
#[derive(Debug, Clone)]
pub struct PcTables {
    tables: Vec<Table>,
    share: usize,
    /// Per-CU, per-slot estimate of the elapsed epoch (miss fallback).
    last_wf: Vec<Vec<SensEstimate>>,
}

impl PcTables {
    pub fn new(cfg: &DvfsConfig, n_cu: usize, n_wf: usize) -> Self {
        let share = cfg.pc_table_share.max(1);
        let n_tables = n_cu.div_ceil(share);
        PcTables {
            tables: (0..n_tables)
                .map(|_| Table::new(cfg.pc_table_entries, cfg.pc_offset_bits, cfg.pc_update_alpha))
                .collect(),
            share,
            last_wf: vec![vec![SensEstimate::default(); n_wf]; n_cu],
        }
    }

    #[inline]
    fn table_of(&mut self, cu: usize) -> &mut Table {
        let i = cu / self.share;
        &mut self.tables[i]
    }

    /// Update path (end of epoch): store each wavefront's estimate under
    /// its epoch-start PC.
    pub fn update_wf(&mut self, cu: usize, kernel_id: u32, start_pc: u32, est: SensEstimate) {
        self.table_of(cu).update(kernel_id, start_pc, est);
    }

    /// Remember the slot's elapsed-epoch estimate (lookup-miss fallback).
    pub fn remember_last(&mut self, cu: usize, slot: usize, est: SensEstimate) {
        self.last_wf[cu][slot] = est;
    }

    /// Lookup path (start of epoch): predict a wavefront's next-epoch
    /// estimate from its current PC; fall back to the slot's last value.
    pub fn lookup_wf(&mut self, cu: usize, slot: usize, kernel_id: u32, pc: u32) -> SensEstimate {
        match self.table_of(cu).lookup(kernel_id, pc) {
            Some(e) => e,
            None => self.last_wf[cu][slot],
        }
    }

    /// Aggregate table hit-rate (the paper's 128-entry sizing argument).
    pub fn hit_rate(&self) -> f64 {
        let (h, m) = self
            .tables
            .iter()
            .fold((0u64, 0u64), |(h, m), t| (h + t.hits, m + t.misses));
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    pub fn n_tables(&self) -> usize {
        self.tables.len()
    }

    /// Mask an instruction PC down to the base PC of its aliasing
    /// bucket (the first instruction whose table slot it shares).  Two
    /// PCs with equal `bucket_base_pc` are the same entry to the table,
    /// so the decision trace groups mispredictions by this value rather
    /// than by raw PC.  Inverse of `Table::index`'s byte-shift:
    /// byte-PC = pc << 2, bucket = byte-PC >> offset_bits, so the
    /// instruction-PC granule is `offset_bits − 2` low bits.
    pub fn bucket_base_pc(&self, pc: u32) -> u32 {
        let offset_bits = match self.tables.first() {
            Some(t) => t.offset_bits,
            None => return pc,
        };
        let shift = offset_bits.saturating_sub(2).min(31);
        (pc >> shift) << shift
    }

    /// Aggregate (hits, misses, evictions) over all tables — the obs
    /// channel-1 PC-table counters.
    pub fn counts(&self) -> (u64, u64, u64) {
        self.tables.iter().fold((0, 0, 0), |(h, m, e), t| {
            (h + t.hits, m + t.misses, e + t.evictions)
        })
    }
}

/// Last-value (reactive) predictor state for CU-level models (Fig. 3a).
#[derive(Debug, Clone)]
pub struct ReactiveState {
    /// Per-CU estimate of the elapsed epoch.
    pub last_cu: Vec<SensEstimate>,
}

impl ReactiveState {
    pub fn new(n_cu: usize) -> Self {
        ReactiveState {
            last_cu: vec![SensEstimate::default(); n_cu],
        }
    }

    pub fn update(&mut self, cu: usize, est: SensEstimate) {
        self.last_cu[cu] = est;
    }

    /// Predict a domain as the sum of its member CUs' last estimates.
    pub fn predict_domain(&self, cus: std::ops::Range<usize>) -> SensEstimate {
        SensEstimate::sum(cus.map(|c| self.last_cu[c]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> DvfsConfig {
        DvfsConfig::default()
    }

    #[test]
    fn lookup_returns_updated_entry() {
        let mut t = PcTables::new(&cfg(), 2, 4);
        t.update_wf(0, 1, 100, SensEstimate::new(42.0, 7.0));
        let e = t.lookup_wf(0, 0, 1, 100);
        assert!((e.sens - 42.0).abs() < 1e-6);
        assert!((e.i0 - 7.0).abs() < 1e-6);
    }

    #[test]
    fn nearby_pcs_share_a_bucket() {
        // offset 4 bits over byte PCs = 4 instructions per bucket
        let mut t = PcTables::new(&cfg(), 1, 4);
        t.update_wf(0, 0, 100, SensEstimate::new(9.0, 1.0));
        // pc 101..103 are in the same 4-instruction bucket
        assert!((t.lookup_wf(0, 0, 0, 101).sens - 9.0).abs() < 1e-6);
        assert!((t.lookup_wf(0, 0, 0, 103).sens - 9.0).abs() < 1e-6);
        // pc 104 is the next bucket -> miss -> fallback (0)
        assert_eq!(t.lookup_wf(0, 0, 0, 104).sens, 0.0);
    }

    #[test]
    fn offset_zero_separates_adjacent_pcs() {
        let mut c = cfg();
        c.pc_offset_bits = 0;
        let mut t = PcTables::new(&c, 1, 4);
        t.update_wf(0, 0, 10, SensEstimate::new(5.0, 0.0));
        assert_eq!(t.lookup_wf(0, 0, 0, 11).sens, 0.0); // different bucket
    }

    #[test]
    fn bucket_base_pc_matches_table_aliasing() {
        // default offset 4 bits over byte PCs = 4 instructions per bucket
        let t = PcTables::new(&cfg(), 1, 4);
        assert_eq!(t.bucket_base_pc(100), 100);
        assert_eq!(t.bucket_base_pc(101), 100);
        assert_eq!(t.bucket_base_pc(103), 100);
        assert_eq!(t.bucket_base_pc(104), 104);
        // offset 0: every instruction PC is its own bucket
        let mut c = cfg();
        c.pc_offset_bits = 0;
        let t0 = PcTables::new(&c, 1, 4);
        assert_eq!(t0.bucket_base_pc(101), 101);
    }

    #[test]
    fn miss_falls_back_to_last_value() {
        let mut t = PcTables::new(&cfg(), 1, 4);
        t.remember_last(0, 2, SensEstimate::new(33.0, 3.0));
        let e = t.lookup_wf(0, 2, 0, 999);
        assert!((e.sens - 33.0).abs() < 1e-6);
        assert!(t.hit_rate() < 1.0);
    }

    #[test]
    fn different_kernels_do_not_collide_systematically() {
        let mut t = PcTables::new(&cfg(), 1, 4);
        t.update_wf(0, 0, 16, SensEstimate::new(1.0, 0.0));
        t.update_wf(0, 1, 16, SensEstimate::new(2.0, 0.0));
        // same PC in kernel 0 still sees its own entry
        assert!((t.lookup_wf(0, 0, 0, 16).sens - 1.0).abs() < 1e-6);
    }

    #[test]
    fn shared_tables_cover_multiple_cus() {
        let mut c = cfg();
        c.pc_table_share = 4;
        let mut t = PcTables::new(&c, 8, 4);
        assert_eq!(t.n_tables(), 2);
        // update from CU 0 is visible to CU 3 (same table)...
        t.update_wf(0, 0, 40, SensEstimate::new(11.0, 0.0));
        assert!((t.lookup_wf(3, 0, 0, 40).sens - 11.0).abs() < 1e-6);
        // ...but not to CU 4 (different table)
        assert_eq!(t.lookup_wf(4, 0, 0, 40).sens, 0.0);
    }

    #[test]
    fn ewma_update_blends() {
        let mut c = cfg();
        c.pc_update_alpha = 0.5;
        let mut t = PcTables::new(&c, 1, 4);
        t.update_wf(0, 0, 0, SensEstimate::new(10.0, 0.0));
        t.update_wf(0, 0, 0, SensEstimate::new(20.0, 0.0));
        assert!((t.lookup_wf(0, 0, 0, 0).sens - 15.0).abs() < 1e-6);
    }

    #[test]
    fn hit_rate_accumulates() {
        let mut t = PcTables::new(&cfg(), 1, 4);
        t.update_wf(0, 0, 0, SensEstimate::new(1.0, 0.0));
        t.lookup_wf(0, 0, 0, 0); // hit
        t.lookup_wf(0, 0, 0, 8); // different bucket -> miss
        assert!((t.hit_rate() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn counts_track_evictions_only_on_destructive_overwrite() {
        let mut c = cfg();
        c.pc_update_alpha = 0.5;
        let mut t = PcTables::new(&c, 1, 4);
        t.update_wf(0, 0, 0, SensEstimate::new(10.0, 0.0));
        t.update_wf(0, 0, 0, SensEstimate::new(20.0, 0.0)); // blends
        t.lookup_wf(0, 0, 0, 0); // hit
        t.lookup_wf(0, 0, 0, 8); // miss
        assert_eq!(t.counts(), (1, 1, 0));
        // alpha = 1 disables blending: rewriting a valid entry evicts
        let mut c1 = cfg();
        c1.pc_update_alpha = 1.0;
        let mut t1 = PcTables::new(&c1, 1, 4);
        t1.update_wf(0, 0, 0, SensEstimate::new(1.0, 0.0));
        assert_eq!(t1.counts().2, 0, "first fill is not an eviction");
        t1.update_wf(0, 0, 0, SensEstimate::new(2.0, 0.0));
        assert_eq!(t1.counts().2, 1);
    }

    #[test]
    fn reactive_predicts_domain_sum() {
        let mut r = ReactiveState::new(4);
        r.update(0, SensEstimate::new(1.0, 10.0));
        r.update(1, SensEstimate::new(2.0, 20.0));
        let d = r.predict_domain(0..2);
        assert_eq!((d.sens, d.i0), (3.0, 30.0));
    }
}
