//! Hardware storage overhead model (paper Table I).
//!
//! PCSTALL per instance: a 128-entry sensitivity table (8-bit quantized
//! sensitivity per entry), one starting-PC index register per wavefront
//! slot (index bits only), and one stall-time register per slot.  The
//! CU-level baselines only need a handful of counters.

use crate::config::DvfsConfig;

/// Storage breakdown in bytes for one predictor instance.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StorageOverhead {
    pub design: &'static str,
    pub items: Vec<(String, u64)>,
}

impl StorageOverhead {
    pub fn total_bytes(&self) -> u64 {
        self.items.iter().map(|(_, b)| *b).sum()
    }
}

/// Table I rows for every evaluated design.
pub fn table1(cfg: &DvfsConfig, n_wf: usize) -> Vec<StorageOverhead> {
    let entries = cfg.pc_table_entries as u64;
    let wf = n_wf as u64;
    vec![
        StorageOverhead {
            design: "PCSTALL",
            items: vec![
                // 8-bit quantized sensitivity per entry
                (format!("Sensitivity table ({entries} entries)"), entries),
                // index bits of the starting PC per slot: log2(entries) +
                // offset bits ≈ 11 bits → 1 byte of index per slot as in
                // the paper's "only index bits" note
                (format!("Starting-PC registers ({wf}x)"), wf),
                // 32-bit stall-time accumulator per slot
                (format!("Stall-time registers ({wf}x)"), 4 * wf),
            ],
        },
        StorageOverhead {
            design: "CRISP",
            items: vec![
                ("Critical-path timestamps".into(), 3 * 8),
                ("Store-stall counter".into(), 8),
                ("Overlap counter".into(), 8),
                ("Extrapolation registers".into(), 2 * 8),
            ],
        },
        StorageOverhead {
            design: "CRIT",
            items: vec![
                ("Critical-path timestamps".into(), 3 * 8),
                ("Async accumulator".into(), 8),
            ],
        },
        StorageOverhead {
            design: "LEAD",
            items: vec![
                ("Leading-load latency counter".into(), 8),
                ("In-flight load counter".into(), 2),
            ],
        },
        StorageOverhead {
            design: "STALL",
            items: vec![("Stall cycle counter".into(), 4)],
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcstall_matches_paper_total() {
        // Paper Table I: 128 + 40 + 160 = 328 bytes per instance.
        let t = table1(&DvfsConfig::default(), 40);
        let pcstall = &t[0];
        assert_eq!(pcstall.design, "PCSTALL");
        assert_eq!(pcstall.total_bytes(), 328);
    }

    #[test]
    fn baselines_are_tiny() {
        let t = table1(&DvfsConfig::default(), 40);
        for row in &t[1..] {
            assert!(
                row.total_bytes() < 64,
                "{} uses {} bytes",
                row.design,
                row.total_bytes()
            );
        }
        // STALL is the smallest (paper: 4 bytes)
        assert_eq!(t.last().unwrap().total_bytes(), 4);
    }

    #[test]
    fn overhead_scales_with_table_size() {
        let mut cfg = DvfsConfig::default();
        cfg.pc_table_entries = 256;
        let t = table1(&cfg, 40);
        assert_eq!(t[0].total_bytes(), 256 + 40 + 160);
    }
}
