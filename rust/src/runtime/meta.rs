//! Artifact metadata sidecar: shapes + constants hash emitted by
//! `python/compile/aot.py`.  A stale artifact fails loudly at load time
//! instead of silently mispredicting.
//!
//! The sidecar is JSON; this module includes a minimal JSON reader for
//! the flat fields we need (offline environment — no serde).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// Parsed metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub n_cu: usize,
    pub n_wf: usize,
    pub n_freq: usize,
    pub hlo_sha256: String,
}

/// `foo.hlo.txt` → `foo.meta.json`.
pub fn sidecar_path(artifact: &Path) -> PathBuf {
    let name = artifact
        .file_name()
        .and_then(|s| s.to_str())
        .unwrap_or_default();
    let base = name
        .strip_suffix(".hlo.txt")
        .unwrap_or(name.strip_suffix(".txt").unwrap_or(name));
    artifact.with_file_name(format!("{base}.meta.json"))
}

impl ArtifactMeta {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Ok(ArtifactMeta {
            n_cu: json_uint(&text, "n_cu").context("n_cu missing")?,
            n_wf: json_uint(&text, "n_wf").context("n_wf missing")?,
            n_freq: json_uint(&text, "n_freq").context("n_freq missing")?,
            hlo_sha256: json_string(&text, "hlo_sha256").context("hlo_sha256 missing")?,
        })
    }

    /// Cheap consistency checks against the HLO text itself.
    pub fn validate_against_hlo(&self, hlo_path: &Path) -> Result<()> {
        anyhow::ensure!(
            self.n_freq == crate::power::params::N_FREQ,
            "artifact built for {} V/f states, binary expects {}",
            self.n_freq,
            crate::power::params::N_FREQ
        );
        let text = std::fs::read_to_string(hlo_path)?;
        let shape = format!("f32[{},{}]", self.n_cu, self.n_wf);
        anyhow::ensure!(
            text.contains(&shape),
            "HLO does not contain the {shape} parameter the metadata promises — stale sidecar?"
        );
        Ok(())
    }
}

/// Extract `"key": <uint>` from flat JSON.
fn json_uint(text: &str, key: &str) -> Option<usize> {
    let needle = format!("\"{key}\"");
    let at = text.find(&needle)?;
    let rest = &text[at + needle.len()..];
    let rest = rest.trim_start().strip_prefix(':')?.trim_start();
    let end = rest
        .find(|c: char| !c.is_ascii_digit())
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// Extract `"key": "<string>"` from flat JSON.
fn json_string(text: &str, key: &str) -> Option<String> {
    let needle = format!("\"{key}\"");
    let at = text.find(&needle)?;
    let rest = &text[at + needle.len()..];
    let rest = rest.trim_start().strip_prefix(':')?.trim_start();
    let rest = rest.strip_prefix('"')?;
    let end = rest.find('"')?;
    Some(rest[..end].to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
  "artifact": "dvfs_step.hlo.txt",
  "n_cu": 64,
  "n_wf": 40,
  "n_dom": 64,
  "n_freq": 10,
  "hlo_sha256": "abc123def"
}"#;

    #[test]
    fn parses_flat_fields() {
        assert_eq!(json_uint(SAMPLE, "n_cu"), Some(64));
        assert_eq!(json_uint(SAMPLE, "n_wf"), Some(40));
        assert_eq!(json_uint(SAMPLE, "n_freq"), Some(10));
        assert_eq!(json_string(SAMPLE, "hlo_sha256").as_deref(), Some("abc123def"));
        assert_eq!(json_uint(SAMPLE, "missing"), None);
    }

    #[test]
    fn sidecar_path_strips_hlo_suffix() {
        assert_eq!(
            sidecar_path(Path::new("artifacts/dvfs_step.hlo.txt")),
            PathBuf::from("artifacts/dvfs_step.meta.json")
        );
    }

    #[test]
    fn load_roundtrip_via_tempfile() {
        let dir = std::env::temp_dir().join("pcstall_meta_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.meta.json");
        std::fs::write(&p, SAMPLE).unwrap();
        let m = ArtifactMeta::load(&p).unwrap();
        assert_eq!(m.n_cu, 64);
        assert_eq!(m.n_wf, 40);
        assert_eq!(m.hlo_sha256, "abc123def");
        std::fs::remove_file(&p).ok();
    }
}
