//! PJRT runtime bridge (the AOT hot path).
//!
//! Loads the HLO-text artifact produced by `python/compile/aot.py`,
//! compiles it once on the PJRT CPU client, and executes it at every
//! epoch boundary.  Python never runs at simulation time — the artifact
//! plus this module make the binary self-contained.
//!
//! Interchange is HLO *text* (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The real PJRT client needs the `xla` crate, which is not available in
//! the offline build.  It is gated behind the `pjrt` cargo feature; the
//! default build ships a stub [`PjrtBackend`] whose `load` fails with a
//! clear message so [`best_backend`] falls back to the native mirror.

pub mod meta;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::dvfs::native::{DvfsStepBackend, StepInputs, StepOutputs};
#[cfg(feature = "pjrt")]
use crate::power::params::N_FREQ;
use meta::ArtifactMeta;

/// Default artifact location relative to the repo root.
pub const DEFAULT_ARTIFACT: &str = "artifacts/dvfs_step.hlo.txt";

/// Locate the artifact from the current dir or ancestors (tests run from
/// various working directories).
pub fn find_artifact(explicit: Option<&Path>) -> Option<PathBuf> {
    if let Some(p) = explicit {
        return p.exists().then(|| p.to_path_buf());
    }
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let cand = dir.join(DEFAULT_ARTIFACT);
        if cand.exists() {
            return Some(cand);
        }
        if !dir.pop() {
            return None;
        }
    }
}

/// The PJRT-backed `dvfs_step` executor.
#[cfg(feature = "pjrt")]
pub struct PjrtBackend {
    exe: xla::PjRtLoadedExecutable,
    pub meta: ArtifactMeta,
}

#[cfg(feature = "pjrt")]
impl PjrtBackend {
    /// Load + compile the artifact at `path` (metadata sidecar expected
    /// next to it).
    pub fn load(path: &Path) -> Result<Self> {
        let meta_path = meta::sidecar_path(path);
        let meta = ArtifactMeta::load(&meta_path)
            .with_context(|| format!("reading {}", meta_path.display()))?;
        meta.validate_against_hlo(path)?;

        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compiling dvfs_step")?;
        Ok(PjrtBackend { exe, meta })
    }

    /// Load from the default search path.
    pub fn load_default() -> Result<Self> {
        let path = find_artifact(None)
            .context("artifacts/dvfs_step.hlo.txt not found — run `make artifacts`")?;
        Self::load(&path)
    }

    fn literal_2d(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
        anyhow::ensure!(data.len() == rows * cols, "shape mismatch");
        Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
    }

    fn literal_1d(data: &[f32]) -> xla::Literal {
        xla::Literal::vec1(data)
    }
}

#[cfg(feature = "pjrt")]
impl DvfsStepBackend for PjrtBackend {
    fn step(&mut self, inp: &StepInputs) -> Result<StepOutputs> {
        let (n_cu, n_wf) = (self.meta.n_cu, self.meta.n_wf);
        anyhow::ensure!(
            inp.n_cu <= n_cu && inp.n_wf <= n_wf,
            "inputs ({}x{}) exceed artifact shape ({n_cu}x{n_wf}) — rebuild with `make artifacts`",
            inp.n_cu,
            inp.n_wf
        );

        // Pad simulator shapes up to the artifact's static shapes.
        let pad2 = |src: &[f32], fill: f32| -> Vec<f32> {
            let mut v = vec![fill; n_cu * n_wf];
            for c in 0..inp.n_cu {
                let s = c * inp.n_wf;
                let d = c * n_wf;
                v[d..d + inp.n_wf].copy_from_slice(&src[s..s + inp.n_wf]);
            }
            v
        };
        let pad1 = |src: &[f32], fill: f32| -> Vec<f32> {
            let mut v = vec![fill; n_cu];
            v[..src.len().min(n_cu)].copy_from_slice(&src[..src.len().min(n_cu)]);
            v
        };

        let instr = Self::literal_2d(&pad2(&inp.instr, 0.0), n_cu, n_wf)?;
        let t_core = Self::literal_2d(&pad2(&inp.t_core_ns, 0.0), n_cu, n_wf)?;
        let age = Self::literal_2d(&pad2(&inp.age_factor, 1.0), n_cu, n_wf)?;
        let freq = Self::literal_1d(&pad1(&inp.freq_ghz, 1.7));
        let pred_sens = Self::literal_1d(&pad1(&inp.pred_sens, 0.0));
        let pred_i0 = Self::literal_1d(&pad1(&inp.pred_i0, 0.0));
        let mask = Self::literal_1d(&pad1(&inp.mask, 0.0));
        let n_exp = Self::literal_1d(&[inp.n_exp]);
        let epoch = Self::literal_1d(&[inp.epoch_ns]);

        let result = self
            .exe
            .execute::<xla::Literal>(&[
                instr, t_core, age, freq, pred_sens, pred_i0, mask, n_exp, epoch,
            ])?[0][0]
            .to_literal_sync()?;
        let outs = result.to_tuple()?;
        anyhow::ensure!(outs.len() == 7, "expected 7 outputs, got {}", outs.len());

        let take = |lit: &xla::Literal| -> Result<Vec<f32>> { Ok(lit.to_vec::<f32>()?) };
        let full = StepOutputs {
            sens_wf: take(&outs[0])?,
            sens_cu: take(&outs[1])?,
            i0_cu: take(&outs[2])?,
            pred_instr: take(&outs[3])?,
            power_w: take(&outs[4])?,
            ednp: take(&outs[5])?,
            best_idx: take(&outs[6])?,
        };

        // Slice padding back off for the caller's shapes.
        let mut out = StepOutputs::default();
        out.sens_wf = (0..inp.n_cu)
            .flat_map(|c| full.sens_wf[c * n_wf..c * n_wf + inp.n_wf].to_vec())
            .collect();
        out.sens_cu = full.sens_cu[..inp.n_cu].to_vec();
        out.i0_cu = full.i0_cu[..inp.n_cu].to_vec();
        out.pred_instr = full.pred_instr[..inp.n_cu * N_FREQ].to_vec();
        out.power_w = full.power_w[..inp.n_cu * N_FREQ].to_vec();
        out.ednp = full.ednp[..inp.n_cu * N_FREQ].to_vec();
        out.best_idx = full.best_idx[..inp.n_cu].to_vec();
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// Stub standing in for the PJRT executor when the `pjrt` feature is
/// off.  Keeps the public API (and everything compiled against it)
/// identical; `load` validates the artifact pair so stale sidecars still
/// fail loudly, then reports the missing runtime.
#[cfg(not(feature = "pjrt"))]
pub struct PjrtBackend {
    pub meta: ArtifactMeta,
}

#[cfg(not(feature = "pjrt"))]
impl PjrtBackend {
    pub fn load(path: &Path) -> Result<Self> {
        let meta_path = meta::sidecar_path(path);
        let meta = ArtifactMeta::load(&meta_path)
            .with_context(|| format!("reading {}", meta_path.display()))?;
        meta.validate_against_hlo(path)?;
        anyhow::bail!(
            "pcstall was built without the `pjrt` feature; cannot execute {} — \
             rebuild with `--features pjrt` (requires a vendored `xla` crate)",
            path.display()
        );
    }

    pub fn load_default() -> Result<Self> {
        let path = find_artifact(None)
            .context("artifacts/dvfs_step.hlo.txt not found — run `make artifacts`")?;
        Self::load(&path)
    }
}

#[cfg(not(feature = "pjrt"))]
impl DvfsStepBackend for PjrtBackend {
    fn step(&mut self, _inp: &StepInputs) -> Result<StepOutputs> {
        anyhow::bail!("pjrt backend stub cannot step (built without the `pjrt` feature)");
    }

    fn name(&self) -> &'static str {
        "pjrt-stub"
    }
}

/// Load the best available backend: PJRT if the artifact exists, native
/// otherwise (with a warning so CI logs show which path ran).
pub fn best_backend(artifact: Option<&Path>) -> Box<dyn DvfsStepBackend> {
    match find_artifact(artifact) {
        Some(path) => match PjrtBackend::load(&path) {
            Ok(b) => {
                eprintln!("[runtime] PJRT backend: {}", path.display());
                return Box::new(b);
            }
            Err(e) => {
                eprintln!("[runtime] PJRT load failed ({e:#}); falling back to native");
            }
        },
        None => {
            eprintln!("[runtime] no artifact found; using native backend (run `make artifacts`)");
        }
    }
    Box::new(crate::dvfs::native::NativeBackend::default())
}
