//! Compute unit: oldest-first wavefront scheduler, in-order per-wavefront
//! execution, asynchronous vector memory, and the per-epoch counters every
//! estimation model consumes.
//!
//! Timing discipline: the CU owns a picosecond-resolution local clock
//! aligned to its V/f-domain cycle grid.  Execution advances cycle by
//! cycle while work is issuable and *skips* directly to the next wake-up
//! event (memory response / VALU completion) when it is not — this keeps
//! memory-bound phases cheap to simulate without losing the interval
//! accounting the STALL/LEAD/CRIT/CRISP models need.
//!
//! Shared-state discipline: a stepping CU touches only its own fields.
//! L1 hits resolve locally; everything else crosses the [`MemPort`]
//! seam as a [`MemRequest`].  With a deferring port the CU is a pure
//! function of its own state over a quantum — the property that lets
//! the GPU step CUs on separate threads and still arbitrate the shared
//! hierarchy deterministically at the quantum barrier.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Arc;

use super::isa::{Instr, Op, Pattern, Program};
use super::memory::{Cache, MemPort, MemRequest};
use super::wavefront::{WaitState, Wavefront};
use super::cycle_ps;
use crate::config::GpuConfig;
use crate::util::{hash2, hash3};

/// A pending memory response.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemResponse {
    pub at_ps: u64,
    /// Tie-break sequence for deterministic ordering.
    pub seq: u64,
    pub slot: u8,
    pub is_store: bool,
    /// Leading load (no other loads in flight CU-wide at issue).
    pub leading: bool,
    pub issued_ps: u64,
}

impl Ord for MemResponse {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at_ps, self.seq).cmp(&(other.at_ps, other.seq))
    }
}

impl PartialOrd for MemResponse {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Per-epoch CU-level counters — the raw material for every CU-level
/// estimation model (paper §2.3).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EpochCounters {
    /// Instructions committed.
    pub instr: u64,
    /// Cycles with at least one instruction issued.
    pub issued_cycles: u64,
    /// Total CU cycles elapsed (incl. skipped-idle cycles).
    pub cycles: u64,
    /// STALL model: time with no issue while ≥1 WF memory-blocked (ps).
    pub stall_all_ps: u64,
    /// CRIT model: time the oldest active WF was memory-blocked (ps).
    pub crit_ps: u64,
    /// LEAD model: accumulated leading-load latency (ps).
    pub lead_load_ps: u64,
    /// CRISP: no-issue time attributable purely to store waits (ps).
    pub store_stall_ps: u64,
    /// CRISP: memory wait time overlapped with issue (ps).
    pub overlap_ps: u64,
    /// Actual accounted epoch duration (ps).
    pub epoch_ps: u64,
    /// Operating frequency during the epoch (GHz).
    pub freq_ghz: f64,
    /// Loads issued / L1 hits (phase diagnostics).
    pub loads: u64,
    pub l1_hits: u64,
    /// Obs stall breakdown: no-issue time with loads in flight but no
    /// WF blocked on a waitcnt yet (ps).  Together with `stall_all_ps`
    /// (waitcnt-blocked) and `issue_empty_ps` these partition the
    /// CU's total no-issue time by cause.
    pub mem_outstanding_ps: u64,
    /// Obs stall breakdown: no-issue time with no memory involvement
    /// at all — ALU latency / empty issue slots (ps).
    pub issue_empty_ps: u64,
}

/// One compute unit.
#[derive(Debug, Clone)]
pub struct Cu {
    pub id: usize,
    pub freq_ghz: f64,
    /// CU-local clock (ps, aligned to the cycle grid of the current freq).
    pub now_ps: u64,
    /// V/f transition blackout: no issue until this time.
    pub transition_until_ps: u64,
    pub wavefronts: Vec<Wavefront>,
    /// Active slots in age order (oldest first).
    order: Vec<u8>,
    responses: BinaryHeap<Reverse<MemResponse>>,
    resp_seq: u64,
    pub l1: Cache,
    pub counters: EpochCounters,
    /// Cumulative committed instructions (work-based termination).
    pub total_instr: u64,
    /// Time of the most recent instruction commit (completion timing).
    pub last_commit_ps: u64,
    /// Current kernel.
    program: Option<Arc<Program>>,
    /// Waves still to dispatch for the current kernel.
    pub pending_waves: u64,
    /// Completed waves for the current kernel.
    pub done_waves: u64,
    next_age: u64,
    next_global_id: u64,
    /// Scheduler shape.
    issue_width: usize,
    wf_per_wg: usize,
    l1_hit_cycles: u32,
    /// Cache-line size (address generation); mirrors the hierarchy's so
    /// the CU never needs the shared side while stepping.
    line_bytes: u64,
    /// CU-wide outstanding loads (leading-load detection).
    outstanding_loads_cu: u32,
    /// Memory-blocked WF count (STALL interval accounting).
    n_mem_waiting: u32,
    /// Memory-blocked WFs whose outstanding ops are stores only.
    n_store_only: u32,
}

impl Cu {
    pub fn new(id: usize, cfg: &GpuConfig, freq_ghz: f64) -> Self {
        Cu {
            id,
            freq_ghz,
            now_ps: 0,
            transition_until_ps: 0,
            wavefronts: (0..cfg.n_wf).map(|s| Wavefront::empty(s as u8)).collect(),
            order: Vec::with_capacity(cfg.n_wf),
            responses: BinaryHeap::new(),
            resp_seq: 0,
            l1: Cache::new(cfg.l1_bytes, cfg.l1_line, cfg.l1_ways),
            counters: EpochCounters::default(),
            total_instr: 0,
            last_commit_ps: 0,
            program: None,
            pending_waves: 0,
            done_waves: 0,
            next_age: 0,
            next_global_id: (id as u64) << 32,
            issue_width: cfg.issue_width.max(1),
            wf_per_wg: cfg.wf_per_wg.max(1),
            l1_hit_cycles: cfg.l1_hit_cycles,
            line_bytes: cfg.l1_line as u64,
            outstanding_loads_cu: 0,
            n_mem_waiting: 0,
            n_store_only: 0,
        }
    }

    /// Load a kernel and fill wavefront slots.
    pub fn load_kernel(&mut self, program: Arc<Program>, waves: u64) {
        self.program = Some(program);
        self.pending_waves = waves;
        self.done_waves = 0;
        self.l1.flush();
        // Drain any stale responses (previous kernel's slots are gone).
        self.responses.clear();
        self.outstanding_loads_cu = 0;
        self.n_mem_waiting = 0;
        self.n_store_only = 0;
        self.order.clear();
        for s in 0..self.wavefronts.len() {
            self.wavefronts[s] = Wavefront::empty(s as u8);
            if self.pending_waves > 0 {
                self.dispatch_into(s);
            }
        }
    }

    pub fn program(&self) -> Option<&Arc<Program>> {
        self.program.as_ref()
    }

    pub fn kernel_id(&self) -> u32 {
        self.program.as_ref().map(|p| p.kernel_id).unwrap_or(0)
    }

    /// All waves dispatched and completed?
    pub fn kernel_done(&self) -> bool {
        self.pending_waves == 0 && self.order.is_empty()
    }

    /// Number of currently active wavefronts.
    pub fn active_wavefronts(&self) -> usize {
        self.order.len()
    }

    fn dispatch_into(&mut self, slot: usize) {
        debug_assert!(self.pending_waves > 0);
        self.pending_waves -= 1;
        let age = self.next_age;
        self.next_age += 1;
        let gid = self.next_global_id;
        self.next_global_id += 1;
        self.wavefronts[slot].dispatch(gid, age, self.now_ps);
        self.order.push(slot as u8);
    }

    /// Change domain frequency.  Issue stalls for `transition_ps` when the
    /// state actually changes (IVR + FLL settling).
    pub fn set_frequency(&mut self, f_ghz: f64, transition_ps: u64) {
        if (f_ghz - self.freq_ghz).abs() > 1e-9 {
            self.freq_ghz = f_ghz;
            self.transition_until_ps = self.now_ps + transition_ps;
        }
    }

    /// Reset epoch counters; flush blocked-time accounting baselines.
    pub fn begin_epoch(&mut self) {
        self.counters = EpochCounters {
            freq_ghz: self.freq_ghz,
            ..EpochCounters::default()
        };
        let kid = self.kernel_id();
        let now = self.now_ps;
        for wf in &mut self.wavefronts {
            wf.begin_epoch(kid);
            if wf.active && wf.waiting != WaitState::None {
                wf.block_start_ps = now;
            }
        }
    }

    /// Flush partial blocked intervals at epoch end.
    pub fn end_epoch(&mut self) {
        let now = self.now_ps;
        for wf in &mut self.wavefronts {
            if wf.active && wf.block_start_ps < now {
                match wf.waiting {
                    WaitState::WaitCnt { .. } => {
                        wf.ep.stall_ps += now - wf.block_start_ps;
                        wf.block_start_ps = now;
                    }
                    WaitState::Barrier => {
                        wf.ep.barrier_ps += now - wf.block_start_ps;
                        wf.block_start_ps = now;
                    }
                    WaitState::None => {}
                }
            }
        }
    }

    /// Advance this CU to absolute time `t_end_ps`.  Always exits with
    /// `now_ps == t_end_ps` when a program is loaded — the GPU's quantum
    /// barrier relies on every CU landing exactly on the boundary.
    pub fn run_until<P: MemPort>(&mut self, t_end_ps: u64, port: &mut P) {
        let cyc = cycle_ps(self.freq_ghz);
        // Hoist the program out of the Option<Arc> — dereferencing it per
        // instruction costs ~10% of the whole simulator (§Perf).
        let program = match &self.program {
            Some(p) => p.clone(),
            None => return,
        };
        let instrs: &[Instr] = &program.instrs;
        while self.now_ps < t_end_ps {
            self.drain_responses();

            // V/f transition blackout: nothing issues.
            if self.transition_until_ps > self.now_ps {
                let t = self.transition_until_ps.min(t_end_ps);
                let dt = t - self.now_ps;
                self.account_interval(dt, false);
                self.counters.cycles += dt / cyc;
                self.now_ps = t;
                continue;
            }

            let issued = self.issue_cycle(instrs, port, cyc);
            let dt = cyc.min(t_end_ps - self.now_ps);
            self.account_interval(dt, issued > 0);
            self.counters.cycles += 1;
            if issued > 0 {
                self.counters.issued_cycles += 1;
            }
            self.now_ps += dt;

            // Nothing issued: skip ahead to the next possible event.
            if issued == 0 && self.now_ps < t_end_ps {
                let wake = self.next_wake(t_end_ps);
                if wake > self.now_ps {
                    // stay on the cycle grid
                    let steps = (wake - self.now_ps).div_ceil(cyc);
                    let target = (self.now_ps + steps * cyc).min(t_end_ps);
                    let dt2 = target - self.now_ps;
                    self.account_interval(dt2, false);
                    self.counters.cycles += dt2 / cyc;
                    self.now_ps = target;
                }
            }
        }
    }

    /// Earliest future event that could unblock issue.  `now_ps` has
    /// already advanced past the no-issue cycle, so a WF whose `busy_until`
    /// has just elapsed makes "now" the wake time (no skip allowed).
    fn next_wake(&self, t_end_ps: u64) -> u64 {
        let mut wake = t_end_ps;
        if let Some(Reverse(r)) = self.responses.peek() {
            wake = wake.min(r.at_ps);
        }
        for &s in &self.order {
            let wf = &self.wavefronts[s as usize];
            if wf.waiting == WaitState::None {
                if wf.busy_until_ps <= self.now_ps {
                    return self.now_ps; // ready right now — do not skip
                }
                wake = wake.min(wf.busy_until_ps);
            }
        }
        wake.max(self.now_ps)
    }

    /// Interval accounting for the CU-level estimation models.
    #[inline]
    fn account_interval(&mut self, dt: u64, issued: bool) {
        if dt == 0 {
            return;
        }
        self.counters.epoch_ps += dt;
        let n_load_waiting = self.n_mem_waiting - self.n_store_only;
        if !issued && self.n_mem_waiting > 0 {
            self.counters.stall_all_ps += dt;
            if n_load_waiting == 0 {
                self.counters.store_stall_ps += dt;
            }
        }
        // Obs stall breakdown: classify the remaining no-issue time
        // (not waitcnt-blocked) by whether memory is still in flight.
        if !issued && self.n_mem_waiting == 0 {
            if self.outstanding_loads_cu > 0 {
                self.counters.mem_outstanding_ps += dt;
            } else {
                self.counters.issue_empty_ps += dt;
            }
        }
        if issued && self.n_mem_waiting > 0 {
            self.counters.overlap_ps += dt;
        }
        // CRIT: oldest active WF memory-blocked.
        if let Some(&s) = self.order.first() {
            if self.wavefronts[s as usize].mem_waiting() {
                self.counters.crit_ps += dt;
            }
        }
    }

    /// Deliver all responses with `at_ps <= now`.
    fn drain_responses(&mut self) {
        while let Some(Reverse(r)) = self.responses.peek() {
            if r.at_ps > self.now_ps {
                break;
            }
            let r = self.responses.pop().unwrap().0;
            self.handle_response(r);
        }
    }

    fn handle_response(&mut self, r: MemResponse) {
        let now = self.now_ps;
        let was_store_only = self.wavefronts[r.slot as usize].store_only_waiting();
        {
            let wf = &mut self.wavefronts[r.slot as usize];
            if r.is_store {
                wf.outstanding_stores = wf.outstanding_stores.saturating_sub(1);
            } else {
                wf.outstanding_loads = wf.outstanding_loads.saturating_sub(1);
            }
        }
        if !r.is_store {
            self.outstanding_loads_cu = self.outstanding_loads_cu.saturating_sub(1);
            if r.leading {
                self.counters.lead_load_ps += now.saturating_sub(r.issued_ps);
            }
        }
        let wf = &mut self.wavefronts[r.slot as usize];
        let is_store_only = wf.store_only_waiting();
        if was_store_only && !is_store_only {
            self.n_store_only -= 1;
        } else if !was_store_only && is_store_only {
            self.n_store_only += 1;
        }
        // Unblock s_waitcnt if satisfied.
        if let WaitState::WaitCnt { max } = wf.waiting {
            if wf.outstanding() <= max {
                wf.ep.stall_ps += now.saturating_sub(wf.block_start_ps);
                wf.waiting = WaitState::None;
                wf.busy_until_ps = wf.busy_until_ps.max(now);
                self.n_mem_waiting -= 1;
                if is_store_only {
                    self.n_store_only -= 1;
                }
            }
        }
    }

    /// One issue cycle: pick up to `issue_width` ready WFs oldest-first.
    fn issue_cycle<P: MemPort>(&mut self, instrs: &[Instr], port: &mut P, cyc: u64) -> usize {
        let now = self.now_ps;
        let mut issued = 0usize;
        let mut i = 0usize;
        while i < self.order.len() {
            let slot = self.order[i] as usize;
            if !self.wavefronts[slot].ready(now) {
                i += 1;
                continue;
            }
            if issued < self.issue_width {
                issued += 1;
                self.wavefronts[slot].ep.issue_won += 1;
                let removed = self.execute(slot, instrs, port, cyc);
                // execute may remove `slot` from order (EndPgm without
                // redispatch); only advance when it didn't shift under us.
                if !removed {
                    i += 1;
                }
            } else {
                self.wavefronts[slot].ep.issue_lost += 1;
                i += 1;
            }
        }
        issued
    }

    /// Execute the instruction at `wf.pc`; returns true if the slot was
    /// removed from the age order (wavefront completed, no redispatch).
    fn execute<P: MemPort>(
        &mut self,
        slot: usize,
        instrs: &[Instr],
        port: &mut P,
        cyc: u64,
    ) -> bool {
        let op = instrs[self.wavefronts[slot].pc as usize].op;
        let now = self.now_ps;

        self.counters.instr += 1;
        self.total_instr += 1;
        self.last_commit_ps = now;
        self.wavefronts[slot].ep.instr += 1;

        match op {
            Op::VAlu { cycles } => {
                let wf = &mut self.wavefronts[slot];
                wf.busy_until_ps = now + cycles as u64 * cyc;
                wf.pc += 1;
            }
            Op::SAlu => {
                let wf = &mut self.wavefronts[slot];
                wf.busy_until_ps = now + cyc;
                wf.pc += 1;
            }
            Op::Load { pattern, fan } => {
                self.issue_mem(slot, pattern, fan, false, port, cyc);
            }
            Op::Store { pattern, fan } => {
                self.issue_mem(slot, pattern, fan, true, port, cyc);
            }
            Op::WaitCnt { max } => {
                let wf = &mut self.wavefronts[slot];
                wf.pc += 1;
                wf.busy_until_ps = now + cyc;
                if wf.outstanding() > max {
                    wf.waiting = WaitState::WaitCnt { max };
                    wf.block_start_ps = now;
                    self.n_mem_waiting += 1;
                    if wf.store_only_waiting() {
                        self.n_store_only += 1;
                    }
                }
            }
            Op::Barrier => {
                self.wavefronts[slot].pc += 1;
                self.wavefronts[slot].busy_until_ps = now + cyc;
                self.arrive_barrier(slot);
            }
            Op::LoopBegin {
                depth,
                trips,
                divergence,
            } => {
                let wf = &mut self.wavefronts[slot];
                let d = depth as usize;
                if !wf.loop_active[d] {
                    let div = if divergence == 0 {
                        0
                    } else {
                        // deterministic per-wavefront divergence in
                        // [-divergence, +divergence]
                        (hash2(wf.global_id, depth as u64) % (2 * divergence as u64 + 1)) as i64
                            - divergence as i64
                    };
                    wf.loop_count[d] = ((trips as i64 + div).max(1)) as u32;
                    wf.loop_active[d] = true;
                }
                wf.busy_until_ps = now + cyc;
                wf.pc += 1;
            }
            Op::LoopEnd { depth, target } => {
                let wf = &mut self.wavefronts[slot];
                let d = depth as usize;
                debug_assert!(wf.loop_active[d], "LoopEnd without LoopBegin");
                wf.loop_count[d] = wf.loop_count[d].saturating_sub(1);
                wf.busy_until_ps = now + cyc;
                if wf.loop_count[d] > 0 {
                    wf.pc = target;
                } else {
                    wf.loop_active[d] = false;
                    wf.pc += 1;
                }
            }
            Op::EndPgm => {
                return self.retire_wavefront(slot);
            }
        }
        false
    }

    fn issue_mem<P: MemPort>(
        &mut self,
        slot: usize,
        pattern: Pattern,
        fan: u8,
        is_store: bool,
        port: &mut P,
        cyc: u64,
    ) {
        let now = self.now_ps;
        let line_bytes = self.line_bytes;
        let leading = !is_store && self.outstanding_loads_cu == 0;

        // Fan-out: coalesced vector ops touch `fan` distinct lines; the
        // wavefront sees the *slowest* of them (one response at max lat).
        // L1 hits resolve locally into the latency floor; missing lines
        // cross the port for the shared hierarchy to price.
        let mut local_lat_ps = cyc;
        let mut lines: Vec<u64> = Vec::new();
        for f in 0..fan {
            let line = self.gen_line(slot, pattern, f, line_bytes);
            if self.l1.access(line) {
                self.counters.l1_hits += 1;
                local_lat_ps = local_lat_ps.max(self.l1_hit_cycles as u64 * cyc);
            } else {
                if lines.is_empty() {
                    lines.reserve_exact((fan - f) as usize);
                }
                lines.push(line);
            }
        }
        if !is_store {
            self.counters.loads += 1;
            self.outstanding_loads_cu += 1;
        }
        let wf = &mut self.wavefronts[slot];
        wf.access_counter = wf.access_counter.wrapping_add(fan as u32);
        if is_store {
            wf.outstanding_stores += 1;
        } else {
            wf.outstanding_loads += 1;
        }
        wf.busy_until_ps = now + cyc;
        wf.pc += 1;
        self.resp_seq += 1;
        let seq = self.resp_seq;
        if lines.is_empty() {
            // Every lane hit in L1: the response never leaves the CU.
            self.responses.push(Reverse(MemResponse {
                at_ps: now + local_lat_ps,
                seq,
                slot: slot as u8,
                is_store,
                leading,
                issued_ps: now,
            }));
        } else if let Some(at_ps) = port.submit(MemRequest {
            seq,
            issued_ps: now,
            slot: slot as u8,
            is_store,
            leading,
            local_lat_ps,
            lines,
        }) {
            self.responses.push(Reverse(MemResponse {
                at_ps,
                seq,
                slot: slot as u8,
                is_store,
                leading,
                issued_ps: now,
            }));
        }
        // submit() returning None means the request was deferred; the
        // quantum barrier services it and hands back a MemResponse via
        // push_response.
    }

    /// Deliver a barrier-serviced response for a request this CU
    /// submitted earlier in the quantum (ordering restored by the
    /// response heap's `(at_ps, seq)` key).
    pub(crate) fn push_response(&mut self, r: MemResponse) {
        self.responses.push(Reverse(r));
    }

    /// Deterministic address-stream generation (see `isa::Pattern`).
    fn gen_line(&self, slot: usize, pattern: Pattern, fan_idx: u8, line_bytes: u64) -> u64 {
        let wf = &self.wavefronts[slot];
        match pattern {
            Pattern::Strided {
                region,
                stride,
                working_set,
            } => {
                let ws = working_set.max(line_bytes as u32) as u64;
                let base = (region as u64) << 44;
                // Spread wavefronts through the region so they stream
                // disjoint-ish slices (coalesced workgroup behaviour).
                let lane_base = (hash2(wf.global_id, region as u64) % ws) & !(line_bytes - 1);
                let off = (lane_base
                    + wf.access_counter as u64 * stride as u64
                    + fan_idx as u64 * line_bytes)
                    % ws;
                (base + off) / line_bytes
            }
            Pattern::Random {
                region,
                working_set,
            } => {
                let ws = working_set.max(line_bytes as u32) as u64;
                let base = (region as u64) << 44;
                let h = hash3(
                    wf.global_id,
                    (wf.access_counter as u64) << 8 | fan_idx as u64,
                    region as u64,
                );
                (base + h % ws) / line_bytes
            }
        }
    }

    fn arrive_barrier(&mut self, slot: usize) {
        let wg = slot / self.wf_per_wg;
        let lo = wg * self.wf_per_wg;
        let hi = (lo + self.wf_per_wg).min(self.wavefronts.len());
        // Mark this WF as waiting first.
        {
            let wf = &mut self.wavefronts[slot];
            wf.waiting = WaitState::Barrier;
            wf.block_start_ps = self.now_ps;
        }
        // Release when every *active* WF of the workgroup has arrived.
        let all_arrived = (lo..hi).all(|s| {
            let wf = &self.wavefronts[s];
            !wf.active || wf.waiting == WaitState::Barrier
        });
        if all_arrived {
            let now = self.now_ps;
            for s in lo..hi {
                let wf = &mut self.wavefronts[s];
                if wf.active && wf.waiting == WaitState::Barrier {
                    wf.ep.barrier_ps += now.saturating_sub(wf.block_start_ps);
                    wf.waiting = WaitState::None;
                }
            }
        }
    }

    /// Wavefront finished: free or refill the slot.  Returns true if the
    /// slot left the age order.
    fn retire_wavefront(&mut self, slot: usize) -> bool {
        self.done_waves += 1;
        let pos = self
            .order
            .iter()
            .position(|&s| s as usize == slot)
            .expect("retiring WF must be in order list");
        self.order.remove(pos);
        self.wavefronts[slot].active = false;
        if self.pending_waves > 0 {
            self.dispatch_into(slot);
            // re-dispatched at the tail of the age order; slot index `pos`
            // no longer points at it, so tell the caller we shifted.
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::isa::ProgramBuilder;
    use crate::sim::memory::{DirectPort, MemSystem};
    use crate::sim::ns_to_ps;

    fn cfg() -> GpuConfig {
        let mut c = GpuConfig::default();
        c.n_wf = 8;
        c.issue_width = 1;
        c
    }

    fn compute_program(n: u16) -> Arc<Program> {
        let mut b = ProgramBuilder::new();
        b.with_loop(0, n, 0, |b| {
            b.push(Op::VAlu { cycles: 1 });
        });
        Arc::new(b.build(0, "compute"))
    }

    fn mem_program(trips: u16) -> Arc<Program> {
        let mut b = ProgramBuilder::new();
        b.with_loop(0, trips, 0, |b| {
            b.push(Op::Load {
                pattern: Pattern::Random {
                    region: 1,
                    working_set: 256 * 1024 * 1024,
                },
                fan: 1,
            });
            b.push(Op::WaitCnt { max: 0 });
            b.push(Op::VAlu { cycles: 1 });
        });
        Arc::new(b.build(1, "membound"))
    }

    fn run(cu: &mut Cu, mem: &mut MemSystem, t_ns: f64) {
        cu.begin_epoch();
        cu.run_until(cu.now_ps + ns_to_ps(t_ns), &mut DirectPort(mem));
        cu.end_epoch();
    }

    #[test]
    fn compute_bound_ipc_tracks_frequency() {
        let cfg = cfg();
        let mut mem = MemSystem::new(&cfg);
        let mut lo = Cu::new(0, &cfg, 1.3);
        let mut hi = Cu::new(1, &cfg, 2.2);
        lo.load_kernel(compute_program(10_000), 8);
        hi.load_kernel(compute_program(10_000), 8);
        run(&mut lo, &mut mem, 1000.0);
        run(&mut hi, &mut mem, 1000.0);
        let ratio = hi.counters.instr as f64 / lo.counters.instr as f64;
        let expect = 2.2 / 1.3;
        assert!(
            (ratio - expect).abs() / expect < 0.05,
            "instr ratio {ratio} vs frequency ratio {expect}"
        );
    }

    #[test]
    fn memory_bound_instr_insensitive_to_frequency() {
        let cfg = cfg();
        let mut mem_a = MemSystem::new(&cfg);
        let mut mem_b = MemSystem::new(&cfg);
        let mut lo = Cu::new(0, &cfg, 1.3);
        let mut hi = Cu::new(0, &cfg, 2.2);
        lo.load_kernel(mem_program(10_000), 8);
        hi.load_kernel(mem_program(10_000), 8);
        run(&mut lo, &mut mem_a, 5_000.0);
        run(&mut hi, &mut mem_b, 5_000.0);
        let ratio = hi.counters.instr as f64 / lo.counters.instr as f64;
        assert!(
            ratio < 1.25,
            "memory-bound workload scaled with frequency: ratio {ratio}"
        );
        // and it must have stalled substantially
        assert!(lo.counters.stall_all_ps > ns_to_ps(1_000.0));
    }

    #[test]
    fn stall_breakdown_partitions_no_issue_time() {
        let cfg = cfg();
        let mut mem = MemSystem::new(&cfg);
        let mut cu = Cu::new(0, &cfg, 1.3);
        cu.load_kernel(mem_program(10_000), 8);
        run(&mut cu, &mut mem, 5_000.0);
        let c = cu.counters;
        // A waitcnt-heavy kernel must show waitcnt stalls, and the three
        // causes never account for more time than the epoch itself.
        assert!(c.stall_all_ps > 0, "no waitcnt stall recorded");
        let breakdown = c.stall_all_ps + c.mem_outstanding_ps + c.issue_empty_ps;
        assert!(
            breakdown <= c.epoch_ps,
            "breakdown {breakdown} exceeds epoch {}",
            c.epoch_ps
        );
    }

    #[test]
    fn compute_bound_shows_no_memory_stall_causes() {
        let cfg = cfg();
        let mut mem = MemSystem::new(&cfg);
        let mut cu = Cu::new(0, &cfg, 2.0);
        cu.load_kernel(compute_program(10_000), 8);
        run(&mut cu, &mut mem, 1_000.0);
        assert_eq!(cu.counters.stall_all_ps, 0);
        assert_eq!(cu.counters.mem_outstanding_ps, 0);
    }

    #[test]
    fn waitcnt_blocks_until_response() {
        let cfg = cfg();
        let mut mem = MemSystem::new(&cfg);
        let mut cu = Cu::new(0, &cfg, 2.0);
        cu.load_kernel(mem_program(1), 1);
        run(&mut cu, &mut mem, 2_000.0);
        let wf = &cu.wavefronts[0];
        assert!(wf.ep.stall_ps > 0, "wavefront never stalled at waitcnt");
        assert!(cu.kernel_done());
    }

    #[test]
    fn oldest_first_priority_starves_young_under_width_1() {
        let cfg = cfg();
        let mut mem = MemSystem::new(&cfg);
        let mut cu = Cu::new(0, &cfg, 2.0);
        cu.load_kernel(compute_program(50_000), 8);
        run(&mut cu, &mut mem, 1_000.0);
        // With issue width 1 and pure compute (always-ready WFs), slot 0
        // (oldest) should win nearly all arbitration.
        let w0 = cu.wavefronts[0].ep.issue_won;
        let w7 = cu.wavefronts[7].ep.issue_won;
        assert!(w0 > 10 * w7.max(1), "oldest {w0} vs youngest {w7}");
        assert!(cu.wavefronts[7].ep.issue_lost > 0);
    }

    #[test]
    fn slot_redispatch_keeps_age_order() {
        let cfg = cfg();
        let mut mem = MemSystem::new(&cfg);
        let mut cu = Cu::new(0, &cfg, 2.0);
        cu.load_kernel(compute_program(5), 64);
        run(&mut cu, &mut mem, 10_000.0);
        assert!(cu.kernel_done(), "waves: done {}", cu.done_waves);
        assert_eq!(cu.done_waves, 64);
    }

    #[test]
    fn barrier_synchronizes_workgroup() {
        let cfg = cfg();
        let mut mem = MemSystem::new(&cfg);
        let mut cu = Cu::new(0, &cfg, 2.0);
        let mut b = ProgramBuilder::new();
        // Divergent pre-barrier work, then barrier, then uniform work.
        b.with_loop(0, 8, 4, |b| {
            b.push(Op::VAlu { cycles: 2 });
        });
        b.push(Op::Barrier);
        b.push(Op::VAlu { cycles: 1 });
        let p = Arc::new(b.build(0, "barrier"));
        cu.load_kernel(p, 4); // one workgroup (wf_per_wg = 4)
        run(&mut cu, &mut mem, 10_000.0);
        assert!(cu.kernel_done());
        // the fastest WF must have spent time at the barrier
        let max_barrier = cu.wavefronts.iter().map(|w| w.ep.barrier_ps).max().unwrap();
        assert!(max_barrier > 0, "no barrier wait observed");
    }

    #[test]
    fn frequency_transition_stalls_issue() {
        let cfg = cfg();
        let mut mem = MemSystem::new(&cfg);
        let mut a = Cu::new(0, &cfg, 1.7);
        let mut b = Cu::new(1, &cfg, 1.7);
        a.load_kernel(compute_program(50_000), 8);
        b.load_kernel(compute_program(50_000), 8);
        // a transitions (pays blackout), b stays
        a.set_frequency(1.8, ns_to_ps(100.0));
        b.set_frequency(1.7, ns_to_ps(100.0)); // same state: free
        run(&mut a, &mut mem, 1_000.0);
        run(&mut b, &mut mem, 1_000.0);
        let scaled_b = b.counters.instr as f64 * 1.8 / 1.7;
        assert!(
            (a.counters.instr as f64) < scaled_b * 0.98,
            "transition blackout did not cost work: {} vs {}",
            a.counters.instr,
            scaled_b
        );
    }

    #[test]
    fn leading_load_latency_accumulates() {
        let cfg = cfg();
        let mut mem = MemSystem::new(&cfg);
        let mut cu = Cu::new(0, &cfg, 2.0);
        cu.load_kernel(mem_program(100), 1);
        run(&mut cu, &mut mem, 50_000.0);
        assert!(cu.counters.lead_load_ps > 0);
        // single WF serial loads: every load is leading, so lead time
        // roughly tracks stall time
        let lead = cu.counters.lead_load_ps as f64;
        let stall = cu.counters.stall_all_ps as f64;
        assert!(lead >= 0.5 * stall, "lead {lead} vs stall {stall}");
    }

    #[test]
    fn counters_reset_each_epoch() {
        let cfg = cfg();
        let mut mem = MemSystem::new(&cfg);
        let mut cu = Cu::new(0, &cfg, 2.0);
        cu.load_kernel(compute_program(50_000), 8);
        run(&mut cu, &mut mem, 1_000.0);
        let first = cu.counters.instr;
        run(&mut cu, &mut mem, 1_000.0);
        assert!(cu.counters.instr > 0);
        assert!(cu.counters.instr <= first * 2, "epoch counters leaked");
        assert!(cu.total_instr >= first + cu.counters.instr);
    }

    #[test]
    fn clone_snapshot_replays_identically() {
        let cfg = cfg();
        let mut mem = MemSystem::new(&cfg);
        let mut cu = Cu::new(0, &cfg, 1.7);
        cu.load_kernel(mem_program(1_000), 8);
        run(&mut cu, &mut mem, 3_000.0);
        let (cu2, mut mem2) = (cu.clone(), mem.clone());
        let mut cu2 = cu2;
        run(&mut cu, &mut mem, 2_000.0);
        run(&mut cu2, &mut mem2, 2_000.0);
        assert_eq!(cu.counters.instr, cu2.counters.instr);
        assert_eq!(cu.now_ps, cu2.now_ps);
        assert_eq!(cu.total_instr, cu2.total_instr);
    }

    #[test]
    fn queue_port_defers_until_barrier_delivery() {
        use crate::sim::memory::QueuePort;
        let cfg = cfg();
        let mut cu = Cu::new(0, &cfg, 2.0);
        cu.load_kernel(mem_program(4), 1);
        let mut q = QueuePort::default();
        cu.begin_epoch();
        cu.run_until(ns_to_ps(1_000.0), &mut q);
        // the first load crossed the seam; the WF is waitcnt-blocked and
        // the CU still landed exactly on the quantum boundary
        assert!(!q.pending.is_empty(), "no request was deferred");
        assert_eq!(cu.now_ps, ns_to_ps(1_000.0));
        let instr_before = cu.counters.instr;
        // barrier: service the quantum's requests, deliver the responses
        let mut mem = MemSystem::new(&cfg);
        for r in q.pending.drain(..) {
            let at_ps = mem.service(&r);
            cu.push_response(MemResponse {
                at_ps,
                seq: r.seq,
                slot: r.slot,
                is_store: r.is_store,
                leading: r.leading,
                issued_ps: r.issued_ps,
            });
        }
        cu.run_until(ns_to_ps(5_000.0), &mut q);
        cu.end_epoch();
        assert!(
            cu.counters.instr > instr_before,
            "delivered response must unblock issue"
        );
    }

    #[test]
    fn issue_width_increases_throughput() {
        let mut c1 = cfg();
        c1.issue_width = 1;
        let mut c4 = cfg();
        c4.issue_width = 4;
        let mut mem1 = MemSystem::new(&c1);
        let mut mem4 = MemSystem::new(&c4);
        let mut a = Cu::new(0, &c1, 2.0);
        let mut b = Cu::new(0, &c4, 2.0);
        a.load_kernel(compute_program(50_000), 8);
        b.load_kernel(compute_program(50_000), 8);
        run(&mut a, &mut mem1, 1_000.0);
        run(&mut b, &mut mem4, 1_000.0);
        // VAlu{1} keeps a WF busy 1 cycle, so width-4 should approach 4x.
        let ratio = b.counters.instr as f64 / a.counters.instr as f64;
        assert!(ratio > 2.0, "issue width had no effect: ratio {ratio}");
    }
}
