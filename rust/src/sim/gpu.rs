//! Whole-GPU composition: CUs + shared memory system + V/f domains +
//! kernel dispatch, advanced epoch by epoch.
//!
//! Cross-CU coupling: CUs advance one *coupling quantum* at a time
//! (`GpuConfig::quantum_ns`, default 200 ns).  Within a quantum each CU
//! runs purely against its own state, depositing L1-missing accesses
//! into a per-CU [`QueuePort`]; at the quantum barrier the shared
//! [`MemSystem`] services every deferred request in fixed
//! `(cu_id, issue-order)` arbitration and the responses land back in
//! the CUs' heaps for the next quantum.  Because the arbitration point
//! is serial and its order is a pure function of simulation state, the
//! results — every counter, histogram bucket, and decision — are
//! byte-identical whether the CUs stepped on one thread or many
//! (`gpu.sim_threads`; threads only change wall-clock time, which is
//! why the key is excluded from run identity).  `quantum_ns` is the
//! documented accuracy/speed trade-off that replaces gem5's global
//! event queue (DESIGN.md §5) — memory latencies resolve no earlier
//! than the barrier, so shorter quanta tighten cross-CU coupling while
//! longer ones amortize more stepping per synchronization.

use std::sync::Arc;

use super::cu::{Cu, EpochCounters, MemResponse};
use super::isa::Program;
use super::memory::{MemSystem, QueuePort};
use super::ns_to_ps;
use crate::config::SimConfig;
use crate::power::params::F_STATIC_GHZ;

/// A kernel launch request: program + waves per CU.
#[derive(Debug, Clone)]
pub struct KernelLaunch {
    pub program: Arc<Program>,
    pub waves_per_cu: u64,
}

/// Full simulator state.  `Clone` is the snapshot primitive used by the
/// oracle's fork-pre-execute methodology.
#[derive(Debug, Clone)]
pub struct Gpu {
    pub cfg: SimConfig,
    pub cus: Vec<Cu>,
    pub mem: MemSystem,
    /// Global time (ps).
    pub now_ps: u64,
    /// Kernel queue (cycled `kernel_rounds` times).
    kernels: Vec<KernelLaunch>,
    kernel_cursor: usize,
    rounds_left: u32,
    /// Index of the kernel currently resident on the CUs.
    current_kernel: Option<usize>,
}

/// An opaque snapshot (restore = assignment).
pub type GpuSnapshot = Gpu;

/// Per-epoch, per-CU observation bundle handed to the DVFS layer.
#[derive(Debug, Clone)]
pub struct EpochObservation {
    /// CU-level counters.
    pub cu: Vec<EpochCounters>,
    /// Per-CU, per-slot wavefront stats (instr, stall, ...).
    pub wf_instr: Vec<Vec<f32>>,
    pub wf_core_ns: Vec<Vec<f32>>,
    pub wf_age_factor: Vec<Vec<f32>>,
    /// Starting PC / kernel of each slot at epoch start (PC-table keys).
    pub wf_start_pc: Vec<Vec<u32>>,
    pub wf_start_kernel: Vec<Vec<u32>>,
    pub wf_active: Vec<Vec<bool>>,
    /// Current PC / kernel of each slot at epoch *end* (lookup keys for
    /// the next epoch).
    pub wf_next_pc: Vec<Vec<u32>>,
    pub wf_next_kernel: Vec<Vec<u32>>,
    pub wf_next_active: Vec<Vec<bool>>,
    /// Epoch duration (ns).
    pub epoch_ns: f64,
}

impl Gpu {
    pub fn new(cfg: SimConfig) -> Self {
        let cus = (0..cfg.gpu.n_cu)
            .map(|i| Cu::new(i, &cfg.gpu, F_STATIC_GHZ))
            .collect();
        let mem = MemSystem::new(&cfg.gpu);
        Gpu {
            cfg,
            cus,
            mem,
            now_ps: 0,
            kernels: Vec::new(),
            kernel_cursor: 0,
            rounds_left: 0,
            current_kernel: None,
        }
    }

    /// Queue a workload: a kernel sequence repeated `rounds` times.
    pub fn load_workload(&mut self, kernels: Vec<KernelLaunch>, rounds: u32) {
        assert!(!kernels.is_empty(), "workload must have kernels");
        assert!(rounds > 0);
        self.kernels = kernels;
        self.kernel_cursor = 0;
        self.rounds_left = rounds;
        self.current_kernel = None;
        self.advance_kernel_queue();
    }

    /// Queue a workload onto a GPU that may have been idle: like
    /// [`Self::load_workload`], but first synchronizes every CU clock to
    /// the global clock.  A CU that has never run a program keeps
    /// `now_ps = 0` while idle epochs advance `Gpu::now_ps` (its
    /// `run_until` returns immediately without a program), so a serve-
    /// mode launch arriving at t > 0 would otherwise replay the CU from
    /// time zero — committing work "in the past" and corrupting both
    /// the epoch's instruction counts and the launch's latency.  CUs
    /// that already ran stay synced on their own (a drained CU burns
    /// empty issue cycles and tracks time without committing), so the
    /// `max` is a no-op for them.
    pub fn dispatch_workload(&mut self, kernels: Vec<KernelLaunch>, rounds: u32) {
        for cu in &mut self.cus {
            cu.now_ps = cu.now_ps.max(self.now_ps);
        }
        self.load_workload(kernels, rounds);
    }

    /// If the resident kernel is finished on all CUs, launch the next one.
    fn advance_kernel_queue(&mut self) {
        let all_done = self.cus.iter().all(|c| c.kernel_done());
        if !all_done {
            return;
        }
        if self.kernel_cursor >= self.kernels.len() {
            if self.rounds_left > 1 {
                self.rounds_left -= 1;
                self.kernel_cursor = 0;
            } else {
                self.current_kernel = None;
                return; // workload complete
            }
        }
        let launch = &self.kernels[self.kernel_cursor];
        for cu in &mut self.cus {
            cu.load_kernel(launch.program.clone(), launch.waves_per_cu);
        }
        // Kernel boundary: shared cache contents do not survive (distinct
        // launches in the paper's traces).
        self.mem.flush();
        self.current_kernel = Some(self.kernel_cursor);
        self.kernel_cursor += 1;
    }

    /// The loaded kernel queue (the trace-capture hook reads this).
    pub fn loaded_kernels(&self) -> &[KernelLaunch] {
        &self.kernels
    }

    /// Rounds remaining of the kernel queue.  Equals the loaded round
    /// count until the queue first wraps, so trace capture should read
    /// it before stepping epochs.
    pub fn loaded_rounds(&self) -> u32 {
        self.rounds_left
    }

    /// True when every queued kernel round has completed.
    pub fn workload_done(&self) -> bool {
        self.current_kernel.is_none() && self.cus.iter().all(|c| c.kernel_done())
    }

    /// Total committed instructions across CUs.
    pub fn total_instr(&self) -> u64 {
        self.cus.iter().map(|c| c.total_instr).sum()
    }

    /// Number of V/f domains.
    pub fn n_domains(&self) -> usize {
        self.cfg.n_domains()
    }

    /// CU index range of a domain.
    pub fn domain_cus(&self, dom: usize) -> std::ops::Range<usize> {
        let k = self.cfg.dvfs.cus_per_domain;
        let lo = dom * k;
        let hi = ((dom + 1) * k).min(self.cfg.gpu.n_cu);
        lo..hi
    }

    /// Domain of a CU.
    pub fn cu_domain(&self, cu: usize) -> usize {
        cu / self.cfg.dvfs.cus_per_domain
    }

    /// Set a domain's frequency (all constituent CUs switch together and
    /// pay the transition blackout if the state changed).
    pub fn set_domain_frequency(&mut self, dom: usize, f_ghz: f64) {
        let t_ps = ns_to_ps(self.cfg.dvfs.transition_latency_ns());
        for cu in self.domain_cus(dom) {
            self.cus[cu].set_frequency(f_ghz, t_ps);
        }
    }

    /// Set every domain to one frequency (static baselines).
    pub fn set_all_frequencies(&mut self, f_ghz: f64) {
        for d in 0..self.n_domains() {
            self.set_domain_frequency(d, f_ghz);
        }
    }

    pub fn domain_frequency(&self, dom: usize) -> f64 {
        let lo = self.domain_cus(dom).start;
        self.cus[lo].freq_ghz
    }

    /// Memory-side deterministic counters (obs channel 1): L2/DRAM
    /// traffic and queue-depth histograms, cumulative over the run.
    pub fn mem_counters(&self) -> crate::obs::MemCounters {
        self.mem.obs_counters()
    }

    /// CU-stepping threads for this simulation: the registry key, with
    /// 0 meaning "all available cores", capped at the CU count.
    fn effective_sim_threads(&self) -> usize {
        let n = match self.cfg.gpu.sim_threads {
            0 => std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1),
            n => n,
        };
        n.min(self.cus.len().max(1))
    }

    /// Run one fixed-time epoch and collect the observation bundle.
    pub fn run_epoch(&mut self) -> EpochObservation {
        let epoch_ps = ns_to_ps(self.cfg.dvfs.epoch_ns);
        let quantum_ps = ns_to_ps(self.cfg.gpu.quantum_ns).clamp(1, epoch_ps);
        let t_end = self.now_ps + epoch_ps;
        let threads = self.effective_sim_threads();

        for cu in &mut self.cus {
            cu.begin_epoch();
        }

        // One deferring port per CU.  The ports live here rather than in
        // `Gpu` — they are empty at every epoch boundary by construction,
        // which keeps `Clone`/snapshot/restore untouched by threading.
        let mut ports: Vec<QueuePort> =
            (0..self.cus.len()).map(|_| QueuePort::default()).collect();

        let mut t = self.now_ps;
        while t < t_end {
            let t_next = (t + quantum_ps).min(t_end);
            if threads <= 1 {
                for (cu, port) in self.cus.iter_mut().zip(ports.iter_mut()) {
                    cu.run_until(t_next, port);
                }
            } else {
                // Fork/join: each CU touches only its own state and its
                // own port, so any partition of the CU set produces the
                // same result; contiguous chunks keep spawn count equal
                // to the thread count.
                let chunk = self.cus.len().div_ceil(threads);
                std::thread::scope(|s| {
                    for (cus, ps) in self.cus.chunks_mut(chunk).zip(ports.chunks_mut(chunk)) {
                        s.spawn(move || {
                            for (cu, port) in cus.iter_mut().zip(ps.iter_mut()) {
                                cu.run_until(t_next, port);
                            }
                        });
                    }
                });
            }
            t = t_next;
            // Quantum barrier: the single deterministic arbitration
            // point for the shared hierarchy.
            self.service_quantum(&mut ports);
            // Kernel hand-over happens between quanta so all CUs launch
            // the next kernel at the same timestamp.
            self.for_each_done_kernel_advance(t);
        }

        for cu in &mut self.cus {
            cu.end_epoch();
        }
        self.now_ps = t_end;
        self.collect_observation()
    }

    /// Service every request deferred during the quantum in fixed
    /// `(cu_id, issue-order)` arbitration, delivering the responses into
    /// the owning CUs.  Runs serially — this is what makes hit/miss
    /// state, reservation clocks, and queue-depth histograms identical
    /// regardless of how many threads stepped the CUs.
    fn service_quantum(&mut self, ports: &mut [QueuePort]) {
        for (cu, port) in self.cus.iter_mut().zip(ports.iter_mut()) {
            for req in port.pending.drain(..) {
                let at_ps = self.mem.service(&req);
                cu.push_response(MemResponse {
                    at_ps,
                    seq: req.seq,
                    slot: req.slot,
                    is_store: req.is_store,
                    leading: req.leading,
                    issued_ps: req.issued_ps,
                });
            }
        }
    }

    /// Kernel hand-over at the quantum boundary `now_ps`: when the
    /// resident kernel has drained on every CU, launch the next one so
    /// all CUs start it at the same timestamp.
    fn for_each_done_kernel_advance(&mut self, now_ps: u64) {
        if self.current_kernel.is_some() && self.cus.iter().all(|c| c.kernel_done()) {
            debug_assert!(
                self.cus.iter().all(|c| c.now_ps == now_ps),
                "kernel hand-over must happen at a quantum boundary"
            );
            self.advance_kernel_queue();
        }
    }

    fn collect_observation(&self) -> EpochObservation {
        let n = self.cus.len();
        let mut ob = EpochObservation {
            cu: Vec::with_capacity(n),
            wf_instr: Vec::with_capacity(n),
            wf_core_ns: Vec::with_capacity(n),
            wf_age_factor: Vec::with_capacity(n),
            wf_start_pc: Vec::with_capacity(n),
            wf_start_kernel: Vec::with_capacity(n),
            wf_active: Vec::with_capacity(n),
            wf_next_pc: Vec::with_capacity(n),
            wf_next_kernel: Vec::with_capacity(n),
            wf_next_active: Vec::with_capacity(n),
            epoch_ns: self.cfg.dvfs.epoch_ns,
        };
        let epoch_ps = ns_to_ps(self.cfg.dvfs.epoch_ns);
        for cu in &self.cus {
            ob.cu.push(cu.counters);
            let kid = cu.kernel_id();
            let mut instr = Vec::with_capacity(cu.wavefronts.len());
            let mut core = Vec::with_capacity(cu.wavefronts.len());
            let mut age = Vec::with_capacity(cu.wavefronts.len());
            let mut spc = Vec::with_capacity(cu.wavefronts.len());
            let mut skid = Vec::with_capacity(cu.wavefronts.len());
            let mut act = Vec::with_capacity(cu.wavefronts.len());
            let mut npc = Vec::with_capacity(cu.wavefronts.len());
            let mut nkid = Vec::with_capacity(cu.wavefronts.len());
            let mut nact = Vec::with_capacity(cu.wavefronts.len());
            // Relative age factor: raw arbitration win-rates, normalized
            // by the CU's instruction-weighted mean so the factor
            // *redistributes* sensitivity across contending wavefronts
            // without deflating the CU aggregate (paper §4.4: estimates
            // are "normalized depending on the relative age").
            let mut wsum = 0f64;
            let mut isum = 0f64;
            for wf in &cu.wavefronts {
                wsum += wf.ep.age_factor() * wf.ep.instr as f64;
                isum += wf.ep.instr as f64;
            }
            let mean_age = if isum > 0.0 { wsum / isum } else { 1.0 };
            for wf in &cu.wavefronts {
                instr.push(wf.ep.instr as f32);
                core.push(super::ps_to_ns(wf.ep.core_ps(epoch_ps)) as f32);
                age.push((wf.ep.age_factor() / mean_age.max(1e-6)) as f32);
                spc.push(wf.ep.start_pc);
                skid.push(wf.ep.start_kernel);
                act.push(wf.ep.active_at_start);
                npc.push(wf.pc);
                nkid.push(kid);
                nact.push(wf.active);
            }
            ob.wf_instr.push(instr);
            ob.wf_core_ns.push(core);
            ob.wf_age_factor.push(age);
            ob.wf_start_pc.push(spc);
            ob.wf_start_kernel.push(skid);
            ob.wf_active.push(act);
            ob.wf_next_pc.push(npc);
            ob.wf_next_kernel.push(nkid);
            ob.wf_next_active.push(nact);
        }
        ob
    }

    /// Snapshot the full simulator state (the oracle's "fork").
    pub fn snapshot(&self) -> GpuSnapshot {
        self.clone()
    }

    /// Restore from a snapshot.
    pub fn restore(&mut self, snap: &GpuSnapshot) {
        *self = snap.clone();
    }

    /// Time of the last instruction commit anywhere on the GPU (ns) —
    /// the un-quantized completion time for fixed-work runs.
    pub fn last_commit_ns(&self) -> f64 {
        super::ps_to_ns(self.cus.iter().map(|c| c.last_commit_ps).max().unwrap_or(0))
    }

    /// Per-domain committed instructions for the *last* epoch.
    pub fn domain_epoch_instr(&self) -> Vec<f64> {
        (0..self.n_domains())
            .map(|d| {
                self.domain_cus(d)
                    .map(|c| self.cus[c].counters.instr as f64)
                    .sum()
            })
            .collect()
    }
}

impl EpochObservation {
    /// Aggregate CU values to domain granularity (sensitivities are
    /// commutative — paper §4.2).
    pub fn domain_sum(&self, per_cu: &[f64], cus_per_domain: usize) -> Vec<f64> {
        per_cu
            .chunks(cus_per_domain)
            .map(|c| c.iter().sum())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::isa::{Op, Pattern, ProgramBuilder};

    fn small_cfg() -> SimConfig {
        let mut c = SimConfig::small();
        c.gpu.n_cu = 4;
        c.gpu.n_wf = 8;
        c
    }

    fn compute_kernel(trips: u16) -> KernelLaunch {
        let mut b = ProgramBuilder::new();
        b.with_loop(0, trips, 0, |b| {
            b.push(Op::VAlu { cycles: 1 });
        });
        KernelLaunch {
            program: Arc::new(b.build(0, "compute")),
            waves_per_cu: 16,
        }
    }

    fn mem_kernel(trips: u16) -> KernelLaunch {
        let mut b = ProgramBuilder::new();
        b.with_loop(0, trips, 0, |b| {
            b.push(Op::Load {
                pattern: Pattern::Random {
                    region: 2,
                    working_set: 128 * 1024 * 1024,
                },
                fan: 1,
            });
            b.push(Op::WaitCnt { max: 0 });
        });
        KernelLaunch {
            program: Arc::new(b.build(1, "mem")),
            waves_per_cu: 16,
        }
    }

    #[test]
    fn epoch_advances_global_time() {
        let mut g = Gpu::new(small_cfg());
        g.load_workload(vec![compute_kernel(1000)], 1);
        let ob = g.run_epoch();
        assert_eq!(g.now_ps, ns_to_ps(1000.0));
        assert_eq!(ob.cu.len(), 4);
        assert!(ob.cu.iter().all(|c| c.instr > 0));
    }

    #[test]
    fn kernel_queue_cycles_through_rounds() {
        let mut g = Gpu::new(small_cfg());
        g.load_workload(vec![compute_kernel(3), mem_kernel(2)], 2);
        for _ in 0..400 {
            g.run_epoch();
            if g.workload_done() {
                break;
            }
        }
        assert!(g.workload_done(), "workload did not finish");
        // every CU completed 2 rounds x 2 kernels x 16 waves
        for cu in &g.cus {
            assert!(cu.kernel_done());
        }
    }

    #[test]
    fn domain_mapping_partitions_cus() {
        let mut cfg = small_cfg();
        cfg.dvfs.cus_per_domain = 2;
        let g = Gpu::new(cfg);
        assert_eq!(g.n_domains(), 2);
        assert_eq!(g.domain_cus(0), 0..2);
        assert_eq!(g.domain_cus(1), 2..4);
        assert_eq!(g.cu_domain(3), 1);
    }

    #[test]
    fn domain_frequency_applies_to_members_only() {
        let mut cfg = small_cfg();
        cfg.dvfs.cus_per_domain = 2;
        let mut g = Gpu::new(cfg);
        g.load_workload(vec![compute_kernel(100)], 1);
        g.set_domain_frequency(1, 2.2);
        assert_eq!(g.cus[0].freq_ghz, F_STATIC_GHZ);
        assert_eq!(g.cus[2].freq_ghz, 2.2);
        assert_eq!(g.cus[3].freq_ghz, 2.2);
        assert_eq!(g.domain_frequency(1), 2.2);
    }

    #[test]
    fn snapshot_restore_is_bit_identical_replay() {
        let mut g = Gpu::new(small_cfg());
        g.load_workload(vec![mem_kernel(500), compute_kernel(500)], 2);
        g.run_epoch();
        let snap = g.snapshot();

        let ob_a = g.run_epoch();
        let instr_a: Vec<u64> = g.cus.iter().map(|c| c.total_instr).collect();

        g.restore(&snap);
        let ob_b = g.run_epoch();
        let instr_b: Vec<u64> = g.cus.iter().map(|c| c.total_instr).collect();

        assert_eq!(instr_a, instr_b);
        assert_eq!(ob_a.wf_instr, ob_b.wf_instr);
        assert_eq!(ob_a.cu, ob_b.cu);
    }

    #[test]
    fn different_frequencies_after_restore_diverge() {
        let mut g = Gpu::new(small_cfg());
        g.load_workload(vec![compute_kernel(5000)], 4);
        g.run_epoch();
        let snap = g.snapshot();
        let base = g.total_instr();

        g.set_all_frequencies(1.3);
        g.run_epoch();
        let lo = g.total_instr() - base;

        g.restore(&snap);
        g.set_all_frequencies(2.2);
        g.run_epoch();
        let hi = g.total_instr() - base;

        assert!(
            hi as f64 > lo as f64 * 1.3,
            "frequency had no effect on compute workload: {lo} vs {hi}"
        );
    }

    #[test]
    fn observation_shapes_match_config() {
        let mut g = Gpu::new(small_cfg());
        g.load_workload(vec![compute_kernel(100)], 1);
        let ob = g.run_epoch();
        assert_eq!(ob.wf_instr.len(), 4);
        assert_eq!(ob.wf_instr[0].len(), 8);
        assert_eq!(ob.epoch_ns, 1000.0);
        // all slots busy with pure compute: every wavefront committed work
        assert!(ob.wf_instr[0].iter().any(|&x| x > 0.0));
    }

    #[test]
    fn domain_sum_aggregates() {
        let ob = EpochObservation {
            cu: vec![],
            wf_instr: vec![],
            wf_core_ns: vec![],
            wf_age_factor: vec![],
            wf_start_pc: vec![],
            wf_start_kernel: vec![],
            wf_active: vec![],
            wf_next_pc: vec![],
            wf_next_kernel: vec![],
            wf_next_active: vec![],
            epoch_ns: 1000.0,
        };
        assert_eq!(
            ob.domain_sum(&[1.0, 2.0, 3.0, 4.0], 2),
            vec![3.0, 7.0]
        );
        assert_eq!(ob.domain_sum(&[1.0, 2.0, 3.0], 2), vec![3.0, 3.0]);
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let run_with = |threads: usize| {
            let mut cfg = small_cfg();
            cfg.gpu.sim_threads = threads;
            let mut g = Gpu::new(cfg);
            g.load_workload(vec![mem_kernel(200), compute_kernel(200)], 2);
            let mut obs = Vec::new();
            for _ in 0..6 {
                obs.push(g.run_epoch());
            }
            (obs, g)
        };
        let (obs1, g1) = run_with(1);
        let (obs4, g4) = run_with(4);
        let (obs0, g0) = run_with(0); // auto: all cores
        for ((a, b), c) in obs1.iter().zip(&obs4).zip(&obs0) {
            assert_eq!(a.cu, b.cu, "per-CU counters depend on thread count");
            assert_eq!(a.cu, c.cu);
            assert_eq!(a.wf_instr, b.wf_instr);
            assert_eq!(a.wf_next_pc, b.wf_next_pc);
        }
        assert_eq!(g1.total_instr(), g4.total_instr());
        assert_eq!(g1.mem_counters(), g4.mem_counters());
        assert_eq!(g1.mem_counters(), g0.mem_counters());
        assert_eq!(g1.now_ps, g4.now_ps);
    }

    #[test]
    fn dispatch_after_idle_starts_at_the_global_clock() {
        // serve mode: the GPU idles (epochs advance, no workload) until
        // the first arrival; the dispatched kernel must start at the
        // global clock, not replay the CUs from time zero
        let mut g = Gpu::new(small_cfg());
        for _ in 0..3 {
            g.run_epoch(); // idle epochs
        }
        assert_eq!(g.now_ps, ns_to_ps(3000.0));
        assert_eq!(g.total_instr(), 0);
        assert!(g.workload_done(), "an empty queue counts as done");
        g.dispatch_workload(vec![compute_kernel(50)], 1);
        for cu in &g.cus {
            assert_eq!(cu.now_ps, ns_to_ps(3000.0), "CU clock must sync to dispatch time");
        }
        let mut epochs = 0;
        while !g.workload_done() && epochs < 1000 {
            g.run_epoch();
            epochs += 1;
        }
        assert!(g.workload_done());
        assert!(g.total_instr() > 0);
        // no instruction committed before the dispatch timestamp
        assert!(
            g.last_commit_ns() >= 3000.0,
            "work committed in the past: {}",
            g.last_commit_ns()
        );
    }

    #[test]
    fn workload_done_time_shrinks_with_frequency() {
        let mut run_at = |f: f64| {
            let mut g = Gpu::new(small_cfg());
            g.load_workload(vec![compute_kernel(2000)], 1);
            g.set_all_frequencies(f);
            let mut epochs = 0;
            while !g.workload_done() && epochs < 10_000 {
                g.run_epoch();
                epochs += 1;
            }
            assert!(g.workload_done());
            epochs
        };
        let slow = run_at(1.3);
        let fast = run_at(2.2);
        assert!(fast < slow, "fast {fast} !< slow {slow}");
    }
}
