//! GCN-flavoured micro-ISA.
//!
//! The instruction set is deliberately small: it carries exactly the
//! semantics the paper's estimation models observe — multi-cycle vector
//! ALU ops, asynchronous vector memory with outstanding-counter
//! `WaitCnt` barriers, workgroup barriers, and loops (whose PC-repetitive
//! structure is what PCSTALL exploits).


/// Memory access pattern of a vector load/store.  Addresses are generated
/// statelessly from `(global wavefront id, pc, per-WF access counter)` so
/// re-executing the same work at a different frequency touches the same
/// lines — a prerequisite for the oracle's I-vs-f regression.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pattern {
    /// Strided streaming within a working set (unit `stride` in bytes).
    /// Models coalesced regular kernels (dgemm tiles, comd neighbour
    /// loops).  Small strides revisit lines (L1 hits).
    Strided {
        region: u8,
        stride: u32,
        working_set: u32,
    },
    /// Uniform-random within a working set — models xsbench-style table
    /// lookups.  `working_set` ≫ L2 makes it DRAM-latency bound.
    Random { region: u8, working_set: u32 },
}

/// One machine operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    /// Vector ALU op occupying the wavefront for `cycles` CU cycles.
    VAlu { cycles: u8 },
    /// Scalar ALU op (1 cycle).
    SAlu,
    /// Asynchronous vector load: issues in 1 cycle, bumps the outstanding
    /// counter, response arrives later.  `fan` models memory divergence
    /// (number of distinct lines the 64 lanes touch after coalescing).
    Load { pattern: Pattern, fan: u8 },
    /// Asynchronous vector store.
    Store { pattern: Pattern, fan: u8 },
    /// `s_waitcnt`: block until outstanding (loads+stores) <= `max`.
    WaitCnt { max: u8 },
    /// Workgroup barrier.
    Barrier,
    /// Loop prologue: on first encounter at `depth`, arm the per-WF trip
    /// counter with `trips ± divergence` (per-wavefront hash).
    LoopBegin {
        depth: u8,
        trips: u16,
        divergence: u16,
    },
    /// Loop back-edge: decrement counter at `depth`; jump to `target` while
    /// it stays positive.
    LoopEnd { depth: u8, target: u32 },
    /// Wavefront completes and frees its slot.
    EndPgm,
}

/// Maximum loop nesting supported per wavefront.
pub const MAX_LOOP_DEPTH: usize = 4;

/// Instruction = op (PCs are instruction indices; byte PCs are derived as
/// `pc * 4` to mirror the paper's 4-byte-encoded ISA when indexing the
/// PC table).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Instr {
    pub op: Op,
}

impl From<Op> for Instr {
    fn from(op: Op) -> Self {
        Instr { op }
    }
}

/// A GPU kernel: a straight-line instruction vector with structured loops.
#[derive(Debug, Clone, PartialEq)]
pub struct Program {
    /// Unique kernel id within a workload (hashes into the PC table so
    /// distinct kernels don't systematically alias).
    pub kernel_id: u32,
    pub instrs: Vec<Instr>,
    /// Human-readable tag for traces.
    pub name: String,
}

impl Program {
    pub fn new(kernel_id: u32, name: impl Into<String>, instrs: Vec<Instr>) -> Self {
        let p = Program {
            kernel_id,
            instrs,
            name: name.into(),
        };
        p.validate().expect("invalid program");
        p
    }

    /// Structural validation: loop targets in range, depths within bounds,
    /// terminated by EndPgm, no fall-through past the end.
    pub fn validate(&self) -> Result<(), String> {
        if self.instrs.is_empty() {
            return Err("empty program".into());
        }
        match self.instrs.last().unwrap().op {
            Op::EndPgm => {}
            _ => return Err("program must end with EndPgm".into()),
        }
        for (pc, ins) in self.instrs.iter().enumerate() {
            match ins.op {
                Op::LoopBegin { depth, .. } => {
                    if depth as usize >= MAX_LOOP_DEPTH {
                        return Err(format!("pc {pc}: loop depth {depth} too deep"));
                    }
                }
                Op::LoopEnd { depth, target } => {
                    if depth as usize >= MAX_LOOP_DEPTH {
                        return Err(format!("pc {pc}: loop depth {depth} too deep"));
                    }
                    if target as usize >= pc {
                        return Err(format!("pc {pc}: loop target {target} not backwards"));
                    }
                }
                Op::VAlu { cycles } => {
                    if cycles == 0 {
                        return Err(format!("pc {pc}: zero-cycle VAlu"));
                    }
                }
                Op::Load { fan, .. } | Op::Store { fan, .. } => {
                    if fan == 0 {
                        return Err(format!("pc {pc}: zero-fan memory op"));
                    }
                }
                _ => {}
            }
        }
        Ok(())
    }

    /// Static instruction count (footprint for PC-table sizing, Table I).
    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }
}

/// Convenience builder used by the workload generators.
#[derive(Default)]
pub struct ProgramBuilder {
    instrs: Vec<Instr>,
}

impl ProgramBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, op: Op) -> &mut Self {
        self.instrs.push(op.into());
        self
    }

    /// Current pc (index of next instruction).
    pub fn pc(&self) -> u32 {
        self.instrs.len() as u32
    }

    /// `body` emits the loop body; trips may diverge per wavefront.
    pub fn with_loop(
        &mut self,
        depth: u8,
        trips: u16,
        divergence: u16,
        body: impl FnOnce(&mut Self),
    ) -> &mut Self {
        self.push(Op::LoopBegin {
            depth,
            trips,
            divergence,
        });
        let target = self.pc();
        body(self);
        self.push(Op::LoopEnd { depth, target });
        self
    }

    pub fn build(mut self, kernel_id: u32, name: impl Into<String>) -> Program {
        self.instrs.push(Op::EndPgm.into());
        Program::new(kernel_id, name, self.instrs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn valu() -> Op {
        Op::VAlu { cycles: 4 }
    }

    #[test]
    fn builder_emits_terminated_program() {
        let mut b = ProgramBuilder::new();
        b.push(valu());
        let p = b.build(0, "t");
        assert_eq!(p.instrs.len(), 2);
        assert_eq!(p.instrs[1].op, Op::EndPgm);
        assert!(p.validate().is_ok());
    }

    #[test]
    fn builder_loop_targets_are_backwards() {
        let mut b = ProgramBuilder::new();
        b.with_loop(0, 10, 2, |b| {
            b.push(valu());
            b.push(Op::WaitCnt { max: 0 });
        });
        let p = b.build(1, "loop");
        match p.instrs[3].op {
            Op::LoopEnd { target, depth } => {
                assert_eq!(target, 1);
                assert_eq!(depth, 0);
            }
            other => panic!("expected LoopEnd, got {other:?}"),
        }
    }

    #[test]
    fn validate_rejects_unterminated() {
        let p = Program {
            kernel_id: 0,
            name: "bad".into(),
            instrs: vec![valu().into()],
        };
        assert!(p.validate().is_err());
    }

    #[test]
    fn validate_rejects_forward_loop_target() {
        let p = Program {
            kernel_id: 0,
            name: "bad".into(),
            instrs: vec![
                Instr::from(Op::LoopEnd { depth: 0, target: 5 }),
                Instr::from(Op::EndPgm),
            ],
        };
        assert!(p.validate().is_err());
    }

    #[test]
    fn validate_rejects_deep_nesting() {
        let p = Program {
            kernel_id: 0,
            name: "bad".into(),
            instrs: vec![
                Instr::from(Op::LoopBegin {
                    depth: 4,
                    trips: 1,
                    divergence: 0,
                }),
                Instr::from(Op::EndPgm),
            ],
        };
        assert!(p.validate().is_err());
    }

    #[test]
    fn validate_rejects_zero_cycle_valu_and_zero_fan() {
        let p = Program {
            kernel_id: 0,
            name: "bad".into(),
            instrs: vec![Instr::from(Op::VAlu { cycles: 0 }), Instr::from(Op::EndPgm)],
        };
        assert!(p.validate().is_err());
        let q = Program {
            kernel_id: 0,
            name: "bad".into(),
            instrs: vec![
                Instr::from(Op::Load {
                    pattern: Pattern::Random {
                        region: 0,
                        working_set: 1024,
                    },
                    fan: 0,
                }),
                Instr::from(Op::EndPgm),
            ],
        };
        assert!(q.validate().is_err());
    }
}
