//! Memory hierarchy: per-CU L1, shared sliced L2 (fixed 1.6 GHz domain),
//! and DRAM with bandwidth queueing.
//!
//! Contention model: L2 slices and the DRAM channel keep *reservation
//! clocks* (`next_free_ps`).  Each access reserves its service slot, so
//! queueing delay emerges from aggregate request rate — this is what
//! produces the paper's second-order effects (e.g. FwdSoft's L2 thrashing
//! at high frequency, §6.2) without a full MSHR model.
//!
//! The CU↔memory seam is the [`MemPort`] trait: CUs never hold `&mut
//! MemSystem` while stepping.  During a coupling quantum a CU submits
//! [`MemRequest`]s through its port; with a [`QueuePort`] the requests
//! are buffered and serviced at the quantum barrier in fixed
//! `(cu_id, issue-order)` arbitration ([`MemSystem::service`]), so the
//! shared hierarchy sees one deterministic request order regardless of
//! how many threads stepped the CUs.  [`DirectPort`] services requests
//! synchronously against a `MemSystem` — the zero-deferral path used by
//! unit tests that want latencies resolved at issue time.
//!
//! The L2 is address-interleaved into per-slice state (`slice = line %
//! n_slices`, one slice per configured bank): each slice owns its tag
//! array and reservation clock, so the
//! global structure is a plain `Vec` of independent slices.  Slice-local
//! line addresses (`line / n_slices`) keep the per-slice set mapping a
//! bijection of the old single-cache set mapping whenever the slice
//! count divides the set count ([`slice_interleave_is_exact`] — true
//! for all shipped configs, and [`MemSystem::new`] warns when a `--set`
//! override breaks it).

use crate::config::GpuConfig;

/// Set-associative cache with per-set round-robin-over-LRU replacement.
/// Only tags are modeled; data never matters for timing.
#[derive(Debug, Clone, PartialEq)]
pub struct Cache {
    sets: usize,
    ways: usize,
    /// tags\[set * ways + way\] — line address + 1 (0 = invalid).
    tags: Vec<u64>,
    /// LRU stamps (bumped on hit/fill).
    stamps: Vec<u32>,
    clock: u32,
    pub hits: u64,
    pub misses: u64,
}

impl Cache {
    pub fn new(total_bytes: usize, line_bytes: usize, ways: usize) -> Self {
        let lines = (total_bytes / line_bytes).max(1);
        let ways = ways.min(lines).max(1);
        let sets = (lines / ways).max(1);
        Cache {
            sets,
            ways,
            tags: vec![0; sets * ways],
            stamps: vec![0; sets * ways],
            clock: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// Probe and fill: returns true on hit.  `line` is the line address
    /// (byte address / line size).
    pub fn access(&mut self, line: u64) -> bool {
        self.clock = self.clock.wrapping_add(1);
        let set = (line as usize) % self.sets;
        let base = set * self.ways;
        let tag = line + 1;
        // hit?
        for w in 0..self.ways {
            if self.tags[base + w] == tag {
                self.stamps[base + w] = self.clock;
                self.hits += 1;
                return true;
            }
        }
        // miss: fill invalid way if any, else evict true LRU
        self.misses += 1;
        let mut victim = 0;
        let mut victim_age = 0u32;
        for w in 0..self.ways {
            if self.tags[base + w] == 0 {
                victim = w;
                break;
            }
            let age = self.clock.wrapping_sub(self.stamps[base + w]);
            if age >= victim_age {
                victim = w;
                victim_age = age;
            }
        }
        self.tags[base + victim] = tag;
        self.stamps[base + victim] = self.clock;
        false
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Invalidate everything (kernel boundary flush).
    pub fn flush(&mut self) {
        self.tags.iter_mut().for_each(|t| *t = 0);
    }
}

/// Queue-depth histogram size: bucket `k` counts accesses whose
/// queueing delay was about `k` service slots; the last bucket
/// aggregates everything deeper.
pub const QUEUE_DEPTH_BUCKETS: usize = 16;

/// Outcome classification for stats.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemLevel {
    L1,
    L2,
    Dram,
}

/// One L1-missing memory instruction, as a CU hands it across the
/// [`MemPort`] seam.  Everything the shared hierarchy needs to resolve
/// the completion time is captured at issue: the CU-side floor latency
/// (`local_lat_ps`: the issue cycle and any L1-hit lanes of the fan)
/// and the L1-missing line addresses in lane order.
#[derive(Debug, Clone, PartialEq)]
pub struct MemRequest {
    /// CU-local response sequence number (heap tie-break).
    pub seq: u64,
    /// Absolute issue time.
    pub issued_ps: u64,
    /// Wavefront slot that issued the instruction.
    pub slot: u8,
    pub is_store: bool,
    /// Leading wavefront (per-kernel stall attribution).
    pub leading: bool,
    /// CU-side latency floor in ps (issue cycle, L1-hit lanes).
    pub local_lat_ps: u64,
    /// L1-missing line addresses, in lane order.
    pub lines: Vec<u64>,
}

/// The CU↔memory seam.  `submit` either resolves the request now and
/// returns its completion time (`Some(at_ps)`) or buffers it for
/// barrier-time arbitration (`None`); in the latter case the owner of
/// the queue delivers the response into the CU after servicing.
pub trait MemPort {
    fn submit(&mut self, req: MemRequest) -> Option<u64>;
}

/// Zero-deferral port: services each request against the wrapped
/// [`MemSystem`] at issue time.  Single-CU semantics (unit tests, and
/// any caller that steps exactly one CU against a private hierarchy).
pub struct DirectPort<'a>(pub &'a mut MemSystem);

impl MemPort for DirectPort<'_> {
    fn submit(&mut self, req: MemRequest) -> Option<u64> {
        Some(self.0.service(&req))
    }
}

/// Deferring port: one per CU per quantum.  Requests accumulate in
/// issue order and are serviced at the quantum barrier in `(cu_id,
/// issue-order)` arbitration by the GPU, which makes the shared-memory
/// request order — and therefore every hit/miss bit and histogram
/// bucket — independent of the CU-stepping thread count.
#[derive(Debug, Clone, Default)]
pub struct QueuePort {
    pub pending: Vec<MemRequest>,
}

impl MemPort for QueuePort {
    fn submit(&mut self, req: MemRequest) -> Option<u64> {
        self.pending.push(req);
        None
    }
}

/// One address-interleaved L2 slice: its share of the tag state and its
/// own service-reservation clock.  Slices are fully independent — the
/// bank-conflict behavior of the old monolithic cache falls out of the
/// address interleave.
#[derive(Debug, Clone, PartialEq)]
struct MemSlice {
    cache: Cache,
    next_free_ps: u64,
}

/// The shared (CU-external) part of the hierarchy.
#[derive(Debug, Clone, PartialEq)]
pub struct MemSystem {
    /// Address-interleaved L2: `slice = line % slices.len()`.
    slices: Vec<MemSlice>,
    l2_hit_ps: u64,
    l2_service_ps: u64,
    dram_ps: u64,
    /// ps to move one line across the DRAM channel.
    dram_line_ps: u64,
    line_bytes: usize,
    /// DRAM channel reservation clock (one shared channel).
    dram_next_free_ps: u64,
    /// Counters.
    pub l2_accesses: u64,
    pub dram_accesses: u64,
    /// Queue-depth histograms (delay quantized in service slots,
    /// [`QUEUE_DEPTH_BUCKETS`] buckets).  Maintained unconditionally —
    /// they never feed back into timing, so simulation results are
    /// identical whether anyone reads them.
    pub l2_queue_depth_hist: Vec<u64>,
    pub dram_queue_depth_hist: Vec<u64>,
}

/// True when `l2_banks` divides both the monolithic L2 set count and
/// `l2_bytes` — the precondition under which the per-slice interleave
/// reproduces the monolithic cache's set mapping (a bijection) and
/// splits its capacity exactly.  Holds for every shipped config; a
/// `--set` override can break it, in which case the slice-local set
/// mapping diverges from the monolithic one and `l2_bytes / l2_banks`
/// truncates capacity.
pub fn slice_interleave_is_exact(cfg: &GpuConfig) -> bool {
    let line = cfg.l1_line.max(1);
    let n = cfg.l2_banks.max(1);
    // Mirror Cache::new's geometry derivation for the monolithic cache.
    let lines = (cfg.l2_bytes / line).max(1);
    let ways = cfg.l2_ways.min(lines).max(1);
    let sets = (lines / ways).max(1);
    sets % n == 0 && cfg.l2_bytes % n == 0
}

impl MemSystem {
    pub fn new(cfg: &GpuConfig) -> Self {
        let line = cfg.l1_line;
        let n_slices = cfg.l2_banks.max(1);
        if !slice_interleave_is_exact(cfg) {
            // Warn (once per process) instead of silently remapping:
            // results stay deterministic, but they no longer match a
            // monolithic cache of the configured geometry.
            static WARNED: std::sync::Once = std::sync::Once::new();
            WARNED.call_once(|| {
                eprintln!(
                    "warning: gpu.l2_banks = {} does not divide the L2 set count and/or \
                     gpu.l2_bytes = {}; per-slice capacity truncates to {} bytes and the \
                     sliced set mapping diverges from the monolithic cache",
                    n_slices,
                    cfg.l2_bytes,
                    cfg.l2_bytes / n_slices,
                );
            });
        }
        MemSystem {
            slices: (0..n_slices)
                .map(|_| MemSlice {
                    cache: Cache::new(cfg.l2_bytes / n_slices, line, cfg.l2_ways),
                    next_free_ps: 0,
                })
                .collect(),
            l2_hit_ps: super::ns_to_ps(cfg.l2_hit_ns),
            l2_service_ps: super::ns_to_ps(cfg.l2_service_ns),
            dram_ps: super::ns_to_ps(cfg.dram_ns),
            dram_line_ps: ((line as f64 / cfg.dram_bw_bytes_per_ns) * super::PS_PER_NS as f64)
                .round()
                .max(1.0) as u64,
            line_bytes: line,
            dram_next_free_ps: 0,
            l2_accesses: 0,
            dram_accesses: 0,
            l2_queue_depth_hist: vec![0; QUEUE_DEPTH_BUCKETS],
            dram_queue_depth_hist: vec![0; QUEUE_DEPTH_BUCKETS],
        }
    }

    /// Service an L1 miss for `line` at absolute time `now_ps`.
    /// Returns (total latency in ps, deepest level touched).
    pub fn access(&mut self, line: u64, now_ps: u64) -> (u64, MemLevel) {
        self.l2_accesses += 1;
        let n = self.slices.len() as u64;
        let slice = &mut self.slices[(line % n) as usize];
        // Reserve the slice: queueing delay if it is busy.
        let start = slice.next_free_ps.max(now_ps);
        slice.next_free_ps = start + self.l2_service_ps;
        let queue = start - now_ps;
        let depth = (queue / self.l2_service_ps.max(1)) as usize;
        self.l2_queue_depth_hist[depth.min(QUEUE_DEPTH_BUCKETS - 1)] += 1;

        // Slice-local line address: within a slice, `line / n` is
        // unique per global line, and the induced set index matches the
        // old monolithic mapping whenever n divides the set count.
        if slice.cache.access(line / n) {
            (queue + self.l2_hit_ps, MemLevel::L2)
        } else {
            self.dram_accesses += 1;
            // Reserve the DRAM channel after L2 lookup completes.
            let at_dram = start + self.l2_hit_ps;
            let dstart = self.dram_next_free_ps.max(at_dram);
            self.dram_next_free_ps = dstart + self.dram_line_ps;
            let dqueue = dstart - at_dram;
            let ddepth = (dqueue / self.dram_line_ps.max(1)) as usize;
            self.dram_queue_depth_hist[ddepth.min(QUEUE_DEPTH_BUCKETS - 1)] += 1;
            // Row-buffer locality variance: DRAM latency varies ±30% per
            // line (address-keyed on the *global* line, so identical
            // across re-executions at different frequencies — required
            // by the oracle regression).  This de-synchronizes wavefront
            // convoys the way real DRAM timing jitter does.
            let jitter =
                0.7 + 0.6 * (crate::util::mix(line) >> 11) as f64 / (1u64 << 53) as f64;
            let dram = (self.dram_ps as f64 * jitter) as u64;
            (queue + self.l2_hit_ps + dqueue + dram, MemLevel::Dram)
        }
    }

    /// Resolve one deferred [`MemRequest`]: the completion time is the
    /// issue time plus the slowest lane — the CU-side floor or any of
    /// the L1-missing lines, serviced here in lane order.
    pub fn service(&mut self, req: &MemRequest) -> u64 {
        let mut lat = req.local_lat_ps;
        for &line in &req.lines {
            let (l, _) = self.access(line, req.issued_ps);
            lat = lat.max(l);
        }
        req.issued_ps + lat
    }

    pub fn line_bytes(&self) -> usize {
        self.line_bytes
    }

    /// Aggregate L2 hits across slices (fixed slice order).
    pub fn l2_hits(&self) -> u64 {
        self.slices.iter().map(|s| s.cache.hits).sum()
    }

    /// Aggregate L2 misses across slices (fixed slice order).
    pub fn l2_misses(&self) -> u64 {
        self.slices.iter().map(|s| s.cache.misses).sum()
    }

    /// Snapshot the memory-side deterministic counters (obs channel 1).
    pub fn obs_counters(&self) -> crate::obs::MemCounters {
        crate::obs::MemCounters {
            l2_accesses: self.l2_accesses,
            l2_hits: self.l2_hits(),
            l2_misses: self.l2_misses(),
            dram_accesses: self.dram_accesses,
            l2_queue_depth_hist: self.l2_queue_depth_hist.clone(),
            dram_queue_depth_hist: self.dram_queue_depth_hist.clone(),
        }
    }

    /// Kernel-boundary flush (cold caches per kernel, like the paper's
    /// distinct kernel launches).
    pub fn flush(&mut self) {
        for s in &mut self.slices {
            s.cache.flush();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> GpuConfig {
        GpuConfig::default()
    }

    #[test]
    fn cache_hits_after_fill() {
        let mut c = Cache::new(1024, 64, 4);
        assert!(!c.access(10));
        assert!(c.access(10));
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
    }

    #[test]
    fn cache_evicts_lru_within_set() {
        // 4 sets x 2 ways of 64B lines = 512B cache
        let mut c = Cache::new(512, 64, 2);
        // lines 0, 4, 8 all map to set 0 (line % 4)
        assert!(!c.access(0));
        assert!(!c.access(4));
        assert!(c.access(0)); // refresh 0 -> 4 becomes LRU
        assert!(!c.access(8)); // evicts 4
        assert!(c.access(0));
        assert!(!c.access(4)); // was evicted
    }

    #[test]
    fn cache_flush_invalidates() {
        let mut c = Cache::new(1024, 64, 4);
        c.access(1);
        c.flush();
        assert!(!c.access(1));
    }

    #[test]
    fn working_set_larger_than_cache_thrashes() {
        let mut c = Cache::new(1024, 64, 4); // 16 lines
        // stream 64 distinct lines twice: second pass still misses
        for _ in 0..2 {
            for l in 0..64u64 {
                c.access(l);
            }
        }
        assert!(c.hit_rate() < 0.05, "hit rate {}", c.hit_rate());
    }

    #[test]
    fn working_set_smaller_than_cache_hits() {
        let mut c = Cache::new(4096, 64, 4); // 64 lines
        for _ in 0..4 {
            for l in 0..32u64 {
                c.access(l);
            }
        }
        assert!(c.hit_rate() > 0.7, "hit rate {}", c.hit_rate());
    }

    #[test]
    fn l2_hit_faster_than_dram() {
        let mut m = MemSystem::new(&cfg());
        let (miss_lat, lvl) = m.access(42, 0);
        assert_eq!(lvl, MemLevel::Dram);
        let (hit_lat, lvl2) = m.access(42, 1_000_000);
        assert_eq!(lvl2, MemLevel::L2);
        assert!(hit_lat < miss_lat);
    }

    #[test]
    fn bank_contention_queues() {
        let mut m = MemSystem::new(&cfg());
        // Same line (same slice), back-to-back at the same instant: the
        // second access must queue behind the first's service slot.
        let (a, _) = m.access(7, 0);
        let (b, _) = m.access(7, 0);
        assert!(b > a - m.dram_ps || b >= a, "no queueing observed");
        // third queues even more
        let (c1, _) = m.access(7, 0);
        assert!(c1 >= b);
    }

    #[test]
    fn different_banks_do_not_queue() {
        let mut m = MemSystem::new(&cfg());
        m.access(0, 0);
        // warm both lines so both are L2 hits, then compare queueing
        m.access(1, 0);
        let (a, _) = m.access(0, 1_000_000);
        let (b, _) = m.access(1, 1_000_000);
        assert_eq!(a, b, "independent slices must not interfere");
    }

    #[test]
    fn dram_bandwidth_queues_under_burst() {
        let mut m = MemSystem::new(&cfg());
        // Unique lines in distinct slices, all missing to DRAM at t=0:
        // later ones must see growing channel queue delay.
        let first = m.access(0, 0).0;
        let mut last = first;
        for l in 1..64u64 {
            last = m.access(l * 1000 + l, 0).0;
        }
        assert!(last > first, "no DRAM channel queueing: {first} vs {last}");
    }

    #[test]
    fn queue_depth_histograms_see_contention() {
        let mut m = MemSystem::new(&cfg());
        // 64 back-to-back accesses to one slice at t=0: queue depth grows
        // monotonically, so buckets past 0 must fill (capped at the top).
        for _ in 0..64 {
            m.access(7, 0);
        }
        assert_eq!(m.l2_queue_depth_hist.len(), QUEUE_DEPTH_BUCKETS);
        assert_eq!(m.l2_queue_depth_hist.iter().sum::<u64>(), 64);
        assert!(
            m.l2_queue_depth_hist[1..].iter().sum::<u64>() > 0,
            "no queueing recorded: {:?}",
            m.l2_queue_depth_hist
        );
        let obs = m.obs_counters();
        assert_eq!(obs.l2_accesses, 64);
        assert_eq!(obs.l2_queue_depth_hist, m.l2_queue_depth_hist);
        assert_eq!(obs.l2_hits + obs.l2_misses, 64);
    }

    #[test]
    fn uncontended_access_lands_in_bucket_zero() {
        let mut m = MemSystem::new(&cfg());
        m.access(3, 0);
        assert_eq!(m.l2_queue_depth_hist[0], 1);
        assert_eq!(m.l2_queue_depth_hist[1..].iter().sum::<u64>(), 0);
    }

    #[test]
    fn memsystem_clone_is_independent() {
        let mut a = MemSystem::new(&cfg());
        a.access(3, 0);
        let mut b = a.clone();
        b.access(4, 0);
        assert_eq!(a.l2_accesses, 1);
        assert_eq!(b.l2_accesses, 2);
    }

    #[test]
    fn slice_interleave_matches_monolithic_set_mapping() {
        // With the default config the slice count (16) divides the old
        // monolithic set count (4096), so distinct lines that collided
        // in one old set must still collide in one (slice, set') and
        // lines from distinct old sets must stay apart.  Probe with an
        // eviction experiment: default ways = 16, so 17 lines mapping
        // to the same old set must thrash while 16 stay resident.
        let c = cfg();
        let old_sets = (c.l2_bytes / c.l1_line / c.l2_ways) as u64;
        let mut m = MemSystem::new(&c);
        // 16 same-set lines: fill, then re-touch — all hits
        for l in 0..16u64 {
            m.access(l * old_sets + 5, 0);
        }
        for l in 0..16u64 {
            m.access(l * old_sets + 5, 0);
        }
        assert_eq!(m.l2_hits(), 16, "16-way set must hold 16 lines");
        // a 17th same-set line must evict
        m.access(16 * old_sets + 5, 0);
        assert_eq!(m.l2_misses(), 17);
    }

    #[test]
    fn slice_interleave_exactness_is_detected() {
        // shipped configs split exactly
        assert!(slice_interleave_is_exact(&cfg()));
        assert!(slice_interleave_is_exact(
            &crate::config::SimConfig::default().gpu
        ));
        assert!(slice_interleave_is_exact(
            &crate::config::SimConfig::small().gpu
        ));
        // a bank count that divides neither the set count nor the byte
        // count is flagged (3 never divides a power-of-two geometry)
        let mut c = cfg();
        c.l2_banks = 3;
        assert!(!slice_interleave_is_exact(&c));
        // dividing the bytes but not the sets is still inexact: the
        // default is 4 MiB / 64 B lines / 16 ways = 4096 sets, so 64
        // banks divides both but 8192 banks exceeds the set count while
        // still dividing the byte count
        let mut c = cfg();
        c.l2_banks = 64;
        assert!(slice_interleave_is_exact(&c));
        c.l2_banks = 8192;
        assert!(!slice_interleave_is_exact(&c));
    }

    #[test]
    fn direct_port_resolves_at_issue_time() {
        let c = cfg();
        let mut m = MemSystem::new(&c);
        let mut port = DirectPort(&mut m);
        let at = port.submit(MemRequest {
            seq: 0,
            issued_ps: 1000,
            slot: 0,
            is_store: false,
            leading: true,
            local_lat_ps: 10,
            lines: vec![42],
        });
        let at = at.expect("DirectPort must resolve synchronously");
        assert!(at > 1000 + 10, "a DRAM miss must dominate the local floor");
        assert_eq!(m.l2_accesses, 1);
    }

    #[test]
    fn queue_port_defers_then_service_matches_direct() {
        let c = cfg();
        let reqs: Vec<MemRequest> = (0..8u64)
            .map(|i| MemRequest {
                seq: i,
                issued_ps: i * 100,
                slot: (i % 4) as u8,
                is_store: i % 2 == 0,
                leading: i == 0,
                local_lat_ps: 7,
                lines: vec![i * 3, i * 3 + 1],
            })
            .collect();

        // direct: serviced one by one at issue time
        let mut m_direct = MemSystem::new(&c);
        let direct: Vec<u64> = reqs
            .iter()
            .map(|r| {
                DirectPort(&mut m_direct)
                    .submit(r.clone())
                    .expect("synchronous")
            })
            .collect();

        // queued: buffered, then drained in issue order at the barrier
        let mut m_queued = MemSystem::new(&c);
        let mut q = QueuePort::default();
        for r in &reqs {
            assert!(q.submit(r.clone()).is_none(), "QueuePort must defer");
        }
        assert_eq!(q.pending.len(), reqs.len());
        let queued: Vec<u64> = q.pending.drain(..).map(|r| m_queued.service(&r)).collect();

        // same request order => identical completion times and state
        assert_eq!(direct, queued);
        assert_eq!(m_direct, m_queued);
    }

    #[test]
    fn service_floors_at_local_latency() {
        let mut m = MemSystem::new(&cfg());
        // warm the line so the memory-side latency is a cheap L2 hit
        m.access(9, 0);
        let at = m.service(&MemRequest {
            seq: 1,
            issued_ps: 1_000_000,
            slot: 0,
            is_store: false,
            leading: false,
            local_lat_ps: 50_000_000, // 50 µs floor dwarfs any L2 hit
            lines: vec![9],
        });
        assert_eq!(at, 1_000_000 + 50_000_000);
        // and a request with no missing lines is purely the local floor
        let at2 = m.service(&MemRequest {
            seq: 2,
            issued_ps: 500,
            slot: 0,
            is_store: true,
            leading: false,
            local_lat_ps: 80,
            lines: vec![],
        });
        assert_eq!(at2, 580);
    }
}
