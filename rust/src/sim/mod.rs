//! The substrate: a deterministic, snapshot-able, wavefront-level GPU
//! timing simulator — the stand-in for the paper's gem5 GCN3 model.
//!
//! Key properties:
//!
//! * **Wavefront-true execution.**  Each CU hosts up to `n_wf` wavefronts
//!   with private PCs, executing in-order with asynchronous vector memory
//!   (`Load`/`Store` increment an outstanding counter; `WaitCnt` blocks —
//!   the `s_waitcnt` semantics the paper's STALL model measures).
//! * **Oldest-first scheduling** (GCN policy the paper attributes the
//!   inter-wavefront contention variation to, Fig. 11a).
//! * **Per-CU V/f domains.**  Each CU runs on its own clock; memory/L2
//!   stay in a fixed 1.6 GHz domain.  Integer picosecond timestamps keep
//!   cross-frequency runs exactly comparable and snapshots deterministic.
//! * **Snapshot/restore** = `Clone`: the in-process equivalent of the
//!   paper's fork-pre-execute methodology (§5.1, Fig. 13).

pub mod cu;
pub mod gpu;
pub mod isa;
pub mod memory;
pub mod wavefront;

pub use cu::{Cu, EpochCounters};
pub use gpu::{Gpu, GpuSnapshot};
pub use isa::{Instr, Op, Pattern, Program};
pub use wavefront::{WaitState, Wavefront};

/// Picoseconds per nanosecond — the simulator's internal clock unit.
pub const PS_PER_NS: u64 = 1000;

/// Convert ns (config-facing) to ps (internal).
#[inline]
pub fn ns_to_ps(ns: f64) -> u64 {
    (ns * PS_PER_NS as f64).round() as u64
}

/// Convert ps (internal) to ns (stats-facing).
#[inline]
pub fn ps_to_ns(ps: u64) -> f64 {
    ps as f64 / PS_PER_NS as f64
}

/// Cycle period in ps for a domain frequency in GHz.
#[inline]
pub fn cycle_ps(freq_ghz: f64) -> u64 {
    (PS_PER_NS as f64 / freq_ghz).round().max(1.0) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_conversions_roundtrip() {
        assert_eq!(ns_to_ps(1.0), 1000);
        assert_eq!(ps_to_ns(1500), 1.5);
        assert_eq!(ns_to_ps(ps_to_ns(123_456)), 123_456);
    }

    #[test]
    fn cycle_period_matches_frequency() {
        assert_eq!(cycle_ps(1.0), 1000);
        assert_eq!(cycle_ps(2.0), 500);
        // 1.3 GHz -> 769.23 ps, rounds to 769
        assert_eq!(cycle_ps(1.3), 769);
    }
}
