//! Per-wavefront architectural + bookkeeping state.


use super::isa::MAX_LOOP_DEPTH;

/// Why a wavefront cannot issue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WaitState {
    /// Ready (may still be busy finishing a multi-cycle VALU).
    None,
    /// Blocked at `s_waitcnt` until outstanding (loads+stores) <= max.
    WaitCnt { max: u8 },
    /// Blocked at a workgroup barrier.
    Barrier,
}

/// Per-epoch statistics for one wavefront slot — exactly the inputs the
/// wavefront-level STALL estimator (and the Pallas kernel) consumes.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct WfEpochStats {
    /// Instructions committed this epoch.
    pub instr: u64,
    /// Time blocked at `s_waitcnt` (ps).
    pub stall_ps: u64,
    /// Time blocked at barriers (ps).
    pub barrier_ps: u64,
    /// Cycles the WF was ready but lost issue arbitration to older WFs.
    pub issue_lost: u64,
    /// Cycles the WF won issue arbitration.
    pub issue_won: u64,
    /// PC at the *start* of the epoch (the PCSTALL table key).
    pub start_pc: u32,
    /// Kernel id at epoch start (hashed into the table index).
    pub start_kernel: u32,
    /// Whether the slot held an active wavefront at epoch start.
    pub active_at_start: bool,
}

impl WfEpochStats {
    /// Core (non-stalled) time within an epoch of `epoch_ps`.
    pub fn core_ps(&self, epoch_ps: u64) -> u64 {
        epoch_ps.saturating_sub(self.stall_ps + self.barrier_ps)
    }

    /// Scheduling-contention factor in (0, 1]: fraction of issue attempts
    /// won.  The paper normalizes the sensitivity estimate by wavefront
    /// age; with oldest-first arbitration, observed win rate *is* the
    /// realized scheduling preference.
    pub fn age_factor(&self) -> f64 {
        let total = self.issue_won + self.issue_lost;
        if total == 0 {
            1.0
        } else {
            (self.issue_won as f64 / total as f64).max(0.05)
        }
    }
}

/// One wavefront slot in a CU.
///
/// Field order is perf-relevant: the scheduler's per-cycle ready scan
/// reads `busy_until_ps` / `waiting` / `active` for every slot, so those
/// live together at the head of the struct (one cache line for the hot
/// part; epoch stats trail behind).
#[derive(Debug, Clone)]
pub struct Wavefront {
    /// CU-local time (ps) until which the WF is executing a multi-cycle op.
    pub busy_until_ps: u64,
    pub waiting: WaitState,
    /// Is there a live wavefront in this slot?
    pub active: bool,
    /// Slot index within the CU.
    pub slot: u8,
    pub outstanding_loads: u8,
    pub outstanding_stores: u8,
    pub pc: u32,
    /// Dispatch sequence number — global arbitration age (lower = older).
    pub age: u64,
    /// Unique id of the wavefront instance (stable across snapshots; used
    /// for address-stream generation).
    pub global_id: u64,
    /// Timestamp when the current block began (for stall accounting).
    pub block_start_ps: u64,
    /// Structured-loop trip counters.
    pub loop_count: [u32; MAX_LOOP_DEPTH],
    pub loop_active: [bool; MAX_LOOP_DEPTH],
    /// Monotone per-WF memory access counter (address generation).
    pub access_counter: u32,
    /// Per-epoch stats.
    pub ep: WfEpochStats,
}

impl Wavefront {
    pub fn empty(slot: u8) -> Self {
        Wavefront {
            busy_until_ps: 0,
            waiting: WaitState::None,
            active: false,
            slot,
            outstanding_loads: 0,
            outstanding_stores: 0,
            pc: 0,
            age: u64::MAX,
            global_id: 0,
            block_start_ps: 0,
            loop_count: [0; MAX_LOOP_DEPTH],
            loop_active: [false; MAX_LOOP_DEPTH],
            access_counter: 0,
            ep: WfEpochStats::default(),
        }
    }

    /// (Re-)dispatch a wavefront instance into this slot.
    pub fn dispatch(&mut self, global_id: u64, age: u64, now_ps: u64) {
        self.age = age;
        self.global_id = global_id;
        self.active = true;
        self.pc = 0;
        self.busy_until_ps = now_ps;
        self.outstanding_loads = 0;
        self.outstanding_stores = 0;
        self.waiting = WaitState::None;
        self.block_start_ps = 0;
        self.loop_count = [0; MAX_LOOP_DEPTH];
        self.loop_active = [false; MAX_LOOP_DEPTH];
        self.access_counter = 0;
        // ep stats intentionally preserved: a slot's epoch record spans
        // dispatches within the epoch.
    }

    #[inline]
    pub fn outstanding(&self) -> u8 {
        self.outstanding_loads + self.outstanding_stores
    }

    /// Ready to be *picked* by the scheduler at time `now`.
    #[inline]
    pub fn ready(&self, now_ps: u64) -> bool {
        self.active && self.waiting == WaitState::None && self.busy_until_ps <= now_ps
    }

    /// Blocked specifically on memory (the STALL condition).
    #[inline]
    pub fn mem_waiting(&self) -> bool {
        matches!(self.waiting, WaitState::WaitCnt { .. })
    }

    /// Blocked with only stores outstanding (the CRISP store-stall case).
    #[inline]
    pub fn store_only_waiting(&self) -> bool {
        self.mem_waiting() && self.outstanding_loads == 0 && self.outstanding_stores > 0
    }

    /// Reset epoch stats, capturing the starting PC for the PC predictor.
    pub fn begin_epoch(&mut self, kernel_id: u32) {
        self.ep = WfEpochStats {
            start_pc: self.pc,
            start_kernel: kernel_id,
            active_at_start: self.active,
            ..WfEpochStats::default()
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_slot_is_not_ready() {
        let wf = Wavefront::empty(3);
        assert!(!wf.ready(0));
        assert!(!wf.active);
    }

    #[test]
    fn dispatch_resets_architectural_state() {
        let mut wf = Wavefront::empty(0);
        wf.pc = 55;
        wf.outstanding_loads = 3;
        wf.loop_active[1] = true;
        wf.dispatch(7, 42, 100);
        assert!(wf.active);
        assert_eq!(wf.pc, 0);
        assert_eq!(wf.outstanding(), 0);
        assert!(!wf.loop_active[1]);
        assert_eq!(wf.age, 42);
        assert!(wf.ready(100));
        assert!(!wf.ready(99));
    }

    #[test]
    fn waitcnt_blocks_and_classifies() {
        let mut wf = Wavefront::empty(0);
        wf.dispatch(1, 1, 0);
        wf.outstanding_stores = 2;
        wf.waiting = WaitState::WaitCnt { max: 0 };
        assert!(!wf.ready(10));
        assert!(wf.mem_waiting());
        assert!(wf.store_only_waiting());
        wf.outstanding_loads = 1;
        assert!(!wf.store_only_waiting());
    }

    #[test]
    fn age_factor_bounds() {
        let mut s = WfEpochStats::default();
        assert_eq!(s.age_factor(), 1.0);
        s.issue_won = 1;
        s.issue_lost = 3;
        assert!((s.age_factor() - 0.25).abs() < 1e-12);
        s.issue_won = 0;
        s.issue_lost = 100;
        assert!(s.age_factor() >= 0.05);
    }

    #[test]
    fn core_time_subtracts_stalls() {
        let mut s = WfEpochStats::default();
        s.stall_ps = 300;
        s.barrier_ps = 200;
        assert_eq!(s.core_ps(1000), 500);
        assert_eq!(s.core_ps(400), 0); // saturates
    }

    #[test]
    fn begin_epoch_captures_pc() {
        let mut wf = Wavefront::empty(0);
        wf.dispatch(1, 1, 0);
        wf.pc = 17;
        wf.ep.instr = 99;
        wf.begin_epoch(3);
        assert_eq!(wf.ep.instr, 0);
        assert_eq!(wf.ep.start_pc, 17);
        assert_eq!(wf.ep.start_kernel, 3);
        assert!(wf.ep.active_at_start);
    }
}
