//! Self-contained micro-benchmark driver (criterion is unavailable in
//! this offline environment).  Provides warmup, repeated timed samples,
//! median/MAD reporting, and a machine-readable trajectory emitter
//! ([`write_bench_json`]) so CI can archive per-commit bench results;
//! used by every target in `rust/benches/`.

use std::path::Path;
use std::time::{Duration, Instant};

use crate::stats::emit::Json;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples_ns: Vec<f64>,
}

impl BenchResult {
    pub fn median_ns(&self) -> f64 {
        percentile(&self.samples_ns, 0.5)
    }

    pub fn p10_ns(&self) -> f64 {
        percentile(&self.samples_ns, 0.1)
    }

    pub fn p90_ns(&self) -> f64 {
        percentile(&self.samples_ns, 0.9)
    }

    /// Summary object for the bench-trajectory artifact: the quantiles
    /// plus the sample count, but not the raw samples (keeps per-commit
    /// artifacts small and diffable).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("median_ns", Json::Num(self.median_ns())),
            ("p10_ns", Json::Num(self.p10_ns())),
            ("p90_ns", Json::Num(self.p90_ns())),
            ("samples", Json::Num(self.samples_ns.len() as f64)),
        ])
    }

    pub fn report(&self) {
        println!(
            "{:<44} median {:>12}  p10 {:>12}  p90 {:>12}  ({} samples)",
            self.name,
            fmt_ns(self.median_ns()),
            fmt_ns(self.p10_ns()),
            fmt_ns(self.p90_ns()),
            self.samples_ns.len()
        );
    }
}

fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((v.len() - 1) as f64 * q).round() as usize;
    v[idx]
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Run `f` repeatedly: a warmup pass, then timed samples until both
/// `min_samples` and `min_total` are reached (or `max_samples`).
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    bench_cfg(name, Duration::from_millis(200), 10, 200, &mut f)
}

/// Fully parameterized variant for slow end-to-end benches.
pub fn bench_cfg<F: FnMut()>(
    name: &str,
    min_total: Duration,
    min_samples: usize,
    max_samples: usize,
    f: &mut F,
) -> BenchResult {
    // warmup
    f();
    let mut samples = Vec::new();
    let start = Instant::now();
    while (samples.len() < min_samples || start.elapsed() < min_total)
        && samples.len() < max_samples
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    let r = BenchResult {
        name: name.to_string(),
        samples_ns: samples,
    };
    r.report();
    r
}

/// Write the bench-trajectory artifact: schema-versioned JSON with one
/// entry per target.  The bytes carry no timestamps — run metadata
/// (commit, host, …) is passed in by the caller so the file stays
/// deterministic for a fixed `meta` + result set.
pub fn write_bench_json(
    path: &Path,
    suite: &str,
    meta: &[(&str, &str)],
    results: &[BenchResult],
) -> std::io::Result<()> {
    let meta_obj = Json::Obj(
        meta.iter()
            .map(|(k, v)| (k.to_string(), Json::Str(v.to_string())))
            .collect(),
    );
    let j = Json::obj(vec![
        ("schema", Json::Num(1.0)),
        ("suite", Json::Str(suite.to_string())),
        ("meta", meta_obj),
        (
            "targets",
            Json::Arr(results.iter().map(BenchResult::to_json).collect()),
        ),
    ]);
    j.write(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut n = 0u64;
        let r = bench_cfg(
            "noop",
            Duration::from_millis(1),
            3,
            16,
            &mut || n += 1,
        );
        assert!(r.samples_ns.len() >= 3);
        assert!(n as usize >= r.samples_ns.len());
        assert!(r.median_ns() >= 0.0);
    }

    #[test]
    fn percentiles_ordered() {
        let r = BenchResult {
            name: "t".into(),
            samples_ns: vec![5.0, 1.0, 3.0, 2.0, 4.0],
        };
        assert!(r.p10_ns() <= r.median_ns());
        assert!(r.median_ns() <= r.p90_ns());
        assert_eq!(r.median_ns(), 3.0);
    }

    #[test]
    fn bench_json_is_deterministic_and_timestamp_free() {
        let rs = [
            BenchResult {
                name: "epoch mixed".into(),
                samples_ns: vec![3.0, 1.0, 2.0],
            },
            BenchResult {
                name: "dvfs_step".into(),
                samples_ns: vec![10.0],
            },
        ];
        let dir = std::env::temp_dir().join(format!("pcstall_bench_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let p1 = dir.join("a.json");
        let p2 = dir.join("b.json");
        write_bench_json(&p1, "sim_hotpath", &[("commit", "abc123")], &rs).unwrap();
        write_bench_json(&p2, "sim_hotpath", &[("commit", "abc123")], &rs).unwrap();
        let a = std::fs::read_to_string(&p1).unwrap();
        let b = std::fs::read_to_string(&p2).unwrap();
        assert_eq!(a, b, "same inputs must give identical bytes");
        assert!(a.contains("\"schema\":1"));
        assert!(a.contains("\"suite\":\"sim_hotpath\""));
        assert!(a.contains("\"commit\":\"abc123\""));
        assert!(a.contains("\"median_ns\":2"));
        assert!(a.contains("\"samples\":3"));
        assert!(!a.contains("\"ts\""), "no timestamps in the bytes");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.0e9), "3.00 s");
    }
}
