//! Minimal CSV/JSON emitters (offline environment — no serde).

use std::fmt::Write as _;
use std::io;
use std::path::Path;

/// A rectangular CSV table.
#[derive(Debug, Clone, Default)]
pub struct CsvTable {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl CsvTable {
    pub fn new(header: &[&str]) -> Self {
        CsvTable {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Table with an owned header (dynamic schemas, e.g. the config-axis
    /// columns of `pcstall sweep`).
    pub fn with_header(header: Vec<String>) -> Self {
        CsvTable {
            header,
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.header.len(), "ragged CSV row");
        self.rows.push(row);
    }

    /// Index of the named header column (schema lookups in consumers
    /// like the sweep merger and `sweep plot`).
    pub fn col(&self, name: &str) -> Option<usize> {
        self.header.iter().position(|h| h == name)
    }

    /// Render to CSV text.  (An inherent method rather than `Display`:
    /// this is a file encoding, not a human-facing representation.)
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.header.join(","));
        for r in &self.rows {
            let escaped: Vec<String> = r.iter().map(|c| escape_csv(c)).collect();
            let _ = writeln!(out, "{}", escaped.join(","));
        }
        out
    }

    pub fn write(&self, path: &Path) -> io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_string())
    }

    /// Inverse of [`CsvTable::to_string`]: parse CSV text (quoted cells
    /// with `""` escapes, no embedded newlines) back into a table.
    /// Ragged rows are an error — sweep-shard merging must never
    /// silently mix schemas.
    pub fn parse(text: &str) -> Result<CsvTable, String> {
        let mut lines = text.lines().enumerate();
        let (_, first) = lines.next().ok_or("empty CSV document")?;
        let header = split_csv_line(first)?;
        if header.is_empty() {
            return Err("empty CSV header".into());
        }
        let mut rows = Vec::new();
        for (lineno, line) in lines {
            let row = split_csv_line(line).map_err(|e| format!("line {}: {e}", lineno + 1))?;
            if row.len() != header.len() {
                return Err(format!(
                    "line {}: {} cell(s), header has {}",
                    lineno + 1,
                    row.len(),
                    header.len()
                ));
            }
            rows.push(row);
        }
        Ok(CsvTable { header, rows })
    }

    /// Load a CSV file written by [`CsvTable::write`].
    pub fn read(path: &Path) -> Result<CsvTable, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        CsvTable::parse(&text).map_err(|e| format!("{}: {e}", path.display()))
    }
}

/// Replace everything but `[A-Za-z0-9]` with `_`: the file-stem /
/// identifier sanitizer shared by sweep plan names and plot script
/// names (one definition, so `sweep_<name>.csv` and the emitted
/// `<stem>_<metric>.gnuplot` can never disagree on sanitization).
pub fn sanitize_ident(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect()
}

/// Split one CSV line into unescaped cells.
fn split_csv_line(line: &str) -> Result<Vec<String>, String> {
    let mut cells = Vec::new();
    let mut cell = String::new();
    let mut chars = line.chars().peekable();
    loop {
        match chars.peek().copied() {
            Some('"') => {
                // quoted cell: consume to the closing quote, "" unescapes
                chars.next();
                loop {
                    match chars.next() {
                        Some('"') => {
                            if chars.peek() == Some(&'"') {
                                chars.next();
                                cell.push('"');
                            } else {
                                break;
                            }
                        }
                        Some(c) => cell.push(c),
                        None => return Err("unterminated quoted cell".into()),
                    }
                }
                match chars.next() {
                    None => {
                        cells.push(std::mem::take(&mut cell));
                        return Ok(cells);
                    }
                    Some(',') => cells.push(std::mem::take(&mut cell)),
                    Some(c) => return Err(format!("unexpected '{c}' after quoted cell")),
                }
            }
            _ => {
                // bare cell: read to the next comma or end of line
                loop {
                    match chars.next() {
                        None => {
                            cells.push(std::mem::take(&mut cell));
                            return Ok(cells);
                        }
                        Some(',') => {
                            cells.push(std::mem::take(&mut cell));
                            break;
                        }
                        Some('"') => return Err("stray '\"' in unquoted cell".into()),
                        Some(c) => cell.push(c),
                    }
                }
            }
        }
    }
}

fn escape_csv(cell: &str) -> String {
    if cell.contains(',') || cell.contains('"') || cell.contains('\n') {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

/// A tiny JSON value builder — enough for result dumps.
#[derive(Debug, Clone)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn nums(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.render_into(&mut s);
        s
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).render_into(out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }

    pub fn write(&self, path: &Path) -> io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.render())
    }
}

/// Fixed-width terminal table printer for experiment output.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for r in rows {
        for (i, c) in r.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(c.len());
            }
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            let _ = write!(s, "{:<w$}  ", c, w = widths.get(i).copied().unwrap_or(8));
        }
        println!("{}", s.trim_end());
    };
    line(&header.iter().map(|h| h.to_string()).collect::<Vec<_>>());
    line(&widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>());
    for r in rows {
        line(r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = CsvTable::new(&["a", "b"]);
        t.push(vec!["1".into(), "x,y".into()]);
        let s = t.to_string();
        assert_eq!(s, "a,b\n1,\"x,y\"\n");
    }

    #[test]
    #[should_panic]
    fn csv_rejects_ragged_rows() {
        let mut t = CsvTable::new(&["a", "b"]);
        t.push(vec!["1".into()]);
    }

    #[test]
    fn csv_parse_inverts_to_string() {
        let mut t = CsvTable::new(&["a", "b", "c"]);
        t.push(vec!["1".into(), "x,y".into(), "he said \"hi\"".into()]);
        t.push(vec!["".into(), "plain".into(), "2.5".into()]);
        let back = CsvTable::parse(&t.to_string()).unwrap();
        assert_eq!(back.header, t.header);
        assert_eq!(back.rows, t.rows);
        // and re-rendering is byte-identical (merge determinism depends
        // on parse → render being the identity)
        assert_eq!(back.to_string(), t.to_string());
    }

    #[test]
    fn csv_parse_rejects_malformed() {
        assert!(CsvTable::parse("").is_err());
        assert!(CsvTable::parse("a,b\n1\n").is_err(), "ragged row accepted");
        assert!(CsvTable::parse("a\n\"unterminated\n").is_err());
        assert!(CsvTable::parse("a\n\"x\"y\n").is_err());
        assert!(CsvTable::parse("a\nx\"y\n").is_err());
    }

    #[test]
    fn csv_parse_header_only() {
        let t = CsvTable::parse("a,b\n").unwrap();
        assert_eq!(t.header, vec!["a", "b"]);
        assert!(t.rows.is_empty());
    }

    #[test]
    fn csv_col_lookup() {
        let t = CsvTable::new(&["epoch_us", "seed", "accuracy"]);
        assert_eq!(t.col("seed"), Some(1));
        assert_eq!(t.col("accuracy"), Some(2));
        assert_eq!(t.col("nope"), None);
    }

    #[test]
    fn json_renders_nested() {
        let j = Json::obj(vec![
            ("name", Json::Str("pc\"stall".into())),
            ("xs", Json::nums(&[1.0, 2.5])),
            ("ok", Json::Bool(true)),
            ("nan", Json::Num(f64::NAN)),
        ]);
        assert_eq!(
            j.render(),
            r#"{"name":"pc\"stall","xs":[1,2.5],"ok":true,"nan":null}"#
        );
    }

    #[test]
    fn json_escapes_control_chars() {
        let j = Json::Str("a\nb\u{1}".into());
        assert_eq!(j.render(), "\"a\\nb\\u0001\"");
    }
}
