//! JSON (de)serialization for run results — the payload format of the
//! exec result cache (`results/cache/<hash>.json`).
//!
//! The emitter half lives on [`Json`] in [`emit`](crate::stats::emit);
//! this module adds the missing half: a small recursive-descent parser
//! (`Json::parse`) plus `RunResult`/`EpochRecord` conversions.
//!
//! Non-finite floats have no JSON representation; the emitter writes
//! them as `null` and [`Json::num_or_nan`] reads `null` back as NaN, so
//! a `RunResult` with `mean_accuracy = NaN` (static policies) round-trips
//! without ever placing `NaN`/`inf` tokens in a cache file.

use crate::stats::emit::Json;
use crate::stats::{EpochRecord, RunResult};

// ---------------------------------------------------------------------------
// Parser + accessors
// ---------------------------------------------------------------------------

impl Json {
    /// Parse a JSON document.  Supports the full value grammar emitted
    /// by [`Json::render`] (objects keep their key order).
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        if let Json::Obj(pairs) = self {
            pairs.iter().find(|(k, _)| k.as_str() == key).map(|(_, v)| v)
        } else {
            None
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        if let Json::Num(x) = self {
            Some(*x)
        } else {
            None
        }
    }

    /// Number, with `null` (the encoding of NaN/inf) read back as NaN.
    pub fn num_or_nan(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            Json::Null => Some(f64::NAN),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        if let Json::Str(s) = self {
            Some(s.as_str())
        } else {
            None
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        if let Json::Bool(b) = self {
            Some(*b)
        } else {
            None
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        if let Json::Arr(items) = self {
            Some(items.as_slice())
        } else {
            None
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(format!("expected '{s}' at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected input at byte {}", self.pos)),
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| format!("bad number at byte {start}"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number '{text}' at byte {start}"))
    }

    fn hex4(&mut self) -> Result<u32, String> {
        if self.pos + 4 > self.bytes.len() {
            return Err("truncated \\u escape".into());
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| "bad \\u escape".to_string())?;
        let v = u32::from_str_radix(s, 16).map_err(|_| format!("bad \\u escape '{s}'"))?;
        self.pos += 4;
        Ok(v)
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or("unterminated string")? {
                b'"' => {
                    self.pos += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.pos += 1;
                    let e = self.peek().ok_or("truncated escape")?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let cp = self.hex4()?;
                            if (0xD800..0xDC00).contains(&cp) {
                                // surrogate pair
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err("invalid low surrogate".into());
                                }
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                out.push(char::from_u32(c).ok_or("invalid surrogate pair")?);
                            } else {
                                out.push(char::from_u32(cp).ok_or("invalid codepoint")?);
                            }
                        }
                        _ => return Err(format!("bad escape \\{}", e as char)),
                    }
                }
                _ => {
                    // plain character (possibly multi-byte UTF-8)
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid utf-8 in string".to_string())?;
                    let c = rest.chars().next().ok_or("unterminated string")?;
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        self.skip_ws();
        let mut items = Vec::new();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                    self.skip_ws();
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        self.skip_ws();
        let mut pairs = Vec::new();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let value = self.value()?;
            pairs.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                    self.skip_ws();
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// RunResult <-> Json
// ---------------------------------------------------------------------------

fn record_to_json(r: &EpochRecord) -> Json {
    Json::obj(vec![
        ("epoch", Json::Num(r.epoch as f64)),
        ("t_ns", Json::Num(r.t_ns)),
        (
            "freq_idx",
            Json::Arr(r.freq_idx.iter().map(|&k| Json::Num(k as f64)).collect()),
        ),
        ("instr", Json::Num(r.instr)),
        ("energy_j", Json::Num(r.energy_j)),
        ("accuracy", Json::Num(r.accuracy)),
        (
            "dom_sens",
            Json::Arr(r.dom_sens.iter().map(|&s| Json::Num(s as f64)).collect()),
        ),
    ])
}

fn num_field(j: &Json, key: &str) -> Result<f64, String> {
    j.get(key)
        .and_then(|v| v.num_or_nan())
        .ok_or_else(|| format!("missing number field '{key}'"))
}

fn record_from_json(j: &Json) -> Result<EpochRecord, String> {
    let freq_idx = j
        .get("freq_idx")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| "missing 'freq_idx'".to_string())?
        .iter()
        .map(|v| v.as_f64().map(|x| x as u8))
        .collect::<Option<Vec<u8>>>()
        .ok_or_else(|| "non-numeric 'freq_idx' entry".to_string())?;
    let dom_sens = j
        .get("dom_sens")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| "missing 'dom_sens'".to_string())?
        .iter()
        .map(|v| v.num_or_nan().map(|x| x as f32))
        .collect::<Option<Vec<f32>>>()
        .ok_or_else(|| "non-numeric 'dom_sens' entry".to_string())?;
    Ok(EpochRecord {
        epoch: num_field(j, "epoch")? as u64,
        t_ns: num_field(j, "t_ns")?,
        freq_idx,
        instr: num_field(j, "instr")?,
        energy_j: num_field(j, "energy_j")?,
        accuracy: num_field(j, "accuracy")?,
        dom_sens,
    })
}

impl RunResult {
    /// Serialize for the result cache.  Non-finite floats are emitted as
    /// `null` by the renderer, keeping the document valid JSON.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("workload", Json::Str(self.workload.clone())),
            ("policy", Json::Str(self.policy.clone())),
            ("objective", Json::Str(self.objective.clone())),
            ("total_energy_j", Json::Num(self.total_energy_j)),
            ("total_time_ns", Json::Num(self.total_time_ns)),
            ("total_instr", Json::Num(self.total_instr)),
            ("mean_accuracy", Json::Num(self.mean_accuracy)),
            ("pc_hit_rate", Json::Num(self.pc_hit_rate)),
            ("completed", Json::Bool(self.completed)),
        ];
        // The serve object is present only for serve-mode runs: batch
        // documents keep their v2-era shape byte-for-byte.
        if let Some(s) = &self.serve {
            fields.push((
                "serve",
                Json::obj(vec![
                    ("launches", Json::Num(s.launches as f64)),
                    ("completed_launches", Json::Num(s.completed_launches as f64)),
                    ("p50_us", Json::Num(s.p50_us)),
                    ("p99_us", Json::Num(s.p99_us)),
                    ("mean_latency_us", Json::Num(s.mean_latency_us)),
                    ("deadline_miss_rate", Json::Num(s.deadline_miss_rate)),
                    ("throughput_per_ms", Json::Num(s.throughput_per_ms)),
                    ("mean_queue_depth", Json::Num(s.mean_queue_depth)),
                ]),
            ));
        }
        fields.push((
            "records",
            Json::Arr(self.records.iter().map(record_to_json).collect()),
        ));
        Json::obj(fields)
    }

    /// Inverse of [`RunResult::to_json`].
    pub fn from_json(j: &Json) -> Result<RunResult, String> {
        let str_field = |key: &str| -> Result<String, String> {
            j.get(key)
                .and_then(|v| v.as_str())
                .map(|s| s.to_string())
                .ok_or_else(|| format!("missing string field '{key}'"))
        };
        let records = j
            .get("records")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| "missing 'records'".to_string())?
            .iter()
            .map(record_from_json)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(RunResult {
            workload: str_field("workload")?,
            policy: str_field("policy")?,
            objective: str_field("objective")?,
            records,
            total_energy_j: num_field(j, "total_energy_j")?,
            total_time_ns: num_field(j, "total_time_ns")?,
            total_instr: num_field(j, "total_instr")?,
            mean_accuracy: num_field(j, "mean_accuracy")?,
            pc_hit_rate: num_field(j, "pc_hit_rate")?,
            completed: j
                .get("completed")
                .and_then(|v| v.as_bool())
                .ok_or_else(|| "missing 'completed'".to_string())?,
            serve: match j.get("serve") {
                None => None,
                Some(s) => Some(crate::stats::ServeStats {
                    launches: num_field(s, "launches")? as u64,
                    completed_launches: num_field(s, "completed_launches")? as u64,
                    p50_us: num_field(s, "p50_us")?,
                    p99_us: num_field(s, "p99_us")?,
                    mean_latency_us: num_field(s, "mean_latency_us")?,
                    deadline_miss_rate: num_field(s, "deadline_miss_rate")?,
                    throughput_per_ms: num_field(s, "throughput_per_ms")?,
                    mean_queue_depth: num_field(s, "mean_queue_depth")?,
                }),
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunResult {
        RunResult {
            workload: "comd".into(),
            policy: "STATIC-1.7".into(),
            objective: "ED2P".into(),
            records: vec![
                EpochRecord {
                    epoch: 0,
                    t_ns: 1000.0,
                    freq_idx: vec![4, 4, 9, 0],
                    instr: 12345.5,
                    energy_j: 1.25e-6,
                    accuracy: f64::NAN, // static policy: no prediction
                    dom_sens: vec![0.0, 1.5, 2.25, 0.125],
                },
                EpochRecord {
                    epoch: 1,
                    t_ns: 2000.0,
                    freq_idx: vec![4, 4, 4, 4],
                    instr: 9999.0,
                    energy_j: 1.5e-6,
                    accuracy: 0.875,
                    dom_sens: vec![3.5, 0.0, 0.0, 7.75],
                },
            ],
            total_energy_j: 2.75e-6,
            total_time_ns: 2000.0,
            total_instr: 22344.5,
            mean_accuracy: f64::NAN,
            pc_hit_rate: 0.0,
            completed: false,
            serve: None,
        }
    }

    #[test]
    fn roundtrip_preserves_every_field() {
        let r = sample();
        let text = r.to_json().render();
        let back = RunResult::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.workload, r.workload);
        assert_eq!(back.policy, r.policy);
        assert_eq!(back.objective, r.objective);
        assert_eq!(back.total_energy_j, r.total_energy_j);
        assert_eq!(back.total_time_ns, r.total_time_ns);
        assert_eq!(back.total_instr, r.total_instr);
        assert!(back.mean_accuracy.is_nan());
        assert_eq!(back.pc_hit_rate, r.pc_hit_rate);
        assert_eq!(back.completed, r.completed);
        assert_eq!(back.records.len(), r.records.len());
        for (a, b) in back.records.iter().zip(&r.records) {
            assert_eq!(a.epoch, b.epoch);
            assert_eq!(a.t_ns, b.t_ns);
            assert_eq!(a.freq_idx, b.freq_idx);
            assert_eq!(a.instr, b.instr);
            assert_eq!(a.energy_j, b.energy_j);
            assert_eq!(a.accuracy.is_nan(), b.accuracy.is_nan());
            if !b.accuracy.is_nan() {
                assert_eq!(a.accuracy, b.accuracy);
            }
            assert_eq!(a.dom_sens, b.dom_sens);
        }
    }

    #[test]
    fn serve_stats_roundtrip_and_stay_optional() {
        // batch documents carry no "serve" key at all (cache back-compat
        // within a schema version)
        let batch = sample();
        assert!(!batch.to_json().render().contains("\"serve\""));
        assert!(RunResult::from_json(&Json::parse(&batch.to_json().render()).unwrap())
            .unwrap()
            .serve
            .is_none());
        // serve documents round-trip every latency field, incl. NaN p50
        // for a run where nothing completed (renders as null)
        let mut r = sample();
        r.serve = Some(crate::stats::ServeStats {
            launches: 24,
            completed_launches: 23,
            p50_us: 120.5,
            p99_us: 380.25,
            mean_latency_us: 140.0,
            deadline_miss_rate: 1.0 / 24.0,
            throughput_per_ms: 0.75,
            mean_queue_depth: 1.5,
        });
        let back = RunResult::from_json(&Json::parse(&r.to_json().render()).unwrap()).unwrap();
        assert_eq!(back.serve, r.serve);
        r.serve.as_mut().unwrap().p50_us = f64::NAN;
        let back = RunResult::from_json(&Json::parse(&r.to_json().render()).unwrap()).unwrap();
        assert!(back.serve.unwrap().p50_us.is_nan());
    }

    #[test]
    fn serialized_form_is_nan_and_inf_free() {
        // JSON has no NaN/Infinity tokens: the emitter must map every
        // non-finite float to null, and the parser must accept nothing
        // resembling them.
        let mut r = sample();
        r.total_instr = f64::INFINITY;
        let text = r.to_json().render();
        assert!(!text.contains("NaN") && !text.contains("nan"));
        assert!(!text.contains("inf") && !text.contains("Inf"));
        let back = RunResult::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert!(back.total_instr.is_nan()); // null reads back as NaN
    }

    #[test]
    fn float_values_roundtrip_exactly() {
        for &x in &[
            0.1,
            1.0 / 3.0,
            1.7976931348623157e308,
            5e-324,
            -2.5,
            123456789.123456789,
        ] {
            let text = Json::Num(x).render();
            let back = Json::parse(&text).unwrap().as_f64().unwrap();
            assert_eq!(back, x, "{x} rendered as {text}");
        }
    }

    #[test]
    fn parser_handles_nesting_and_escapes() {
        let j = Json::parse(r#"{ "a" : [1, -2.5e3, null, "x\n\"yA"], "b": {} }"#).unwrap();
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[1].as_f64(), Some(-2500.0));
        assert!(arr[2].num_or_nan().unwrap().is_nan());
        assert_eq!(arr[3].as_str(), Some("x\n\"yA"));
        assert!(matches!(j.get("b"), Some(Json::Obj(p)) if p.is_empty()));
    }

    #[test]
    fn parser_rejects_garbage() {
        for bad in ["", "{", "[1,", "tru", "1.2.3", "{\"a\":}", "\"unterminated", "[] []"] {
            assert!(Json::parse(bad).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn parser_accepts_emitter_output() {
        // cross-check against the existing renderer's quirks
        let j = Json::obj(vec![
            ("s", Json::Str("pc\"stall\n\u{1}".into())),
            ("xs", Json::nums(&[1.0, 2.5, f64::NAN])),
        ]);
        let text = j.render();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("s").unwrap().as_str(), Some("pc\"stall\n\u{1}"));
        let xs = back.get("xs").unwrap().as_arr().unwrap();
        assert!(xs[2].num_or_nan().unwrap().is_nan());
    }
}
