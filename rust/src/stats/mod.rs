//! Run statistics: per-epoch records, energy/delay integration, the
//! ED^nP metrics, and CSV/JSON emitters used by the experiment harness.

pub mod bench;
pub mod emit;
pub mod json;
pub mod plot;

/// One epoch's aggregate record.
#[derive(Debug, Clone)]
pub struct EpochRecord {
    /// Epoch index.
    pub epoch: u64,
    /// Global time at epoch end (ns).
    pub t_ns: f64,
    /// Ladder index per domain at which the epoch ran.
    pub freq_idx: Vec<u8>,
    /// Instructions committed (whole GPU).
    pub instr: f64,
    /// Energy consumed this epoch (J), incl. transition energy.
    pub energy_j: f64,
    /// Mean per-domain prediction accuracy for this epoch (NaN when the
    /// policy makes no prediction, e.g. static).
    pub accuracy: f64,
    /// Per-domain estimated sensitivity used for the selection.
    pub dom_sens: Vec<f32>,
}

/// Queue/latency summary of a serve-mode run (continuous arrival
/// traffic).  `None` for batch (epochs/completion) runs.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeStats {
    /// Launches offered by the arrival process.
    pub launches: u64,
    /// Launches that finished before the run's epoch cap.
    pub completed_launches: u64,
    /// Median per-launch latency (arrival → last commit), µs.  Only
    /// completed launches contribute; NaN when none completed.
    pub p50_us: f64,
    /// 99th-percentile per-launch latency (nearest-rank), µs.
    pub p99_us: f64,
    /// Mean per-launch latency over completed launches, µs.
    pub mean_latency_us: f64,
    /// Fraction of offered launches that missed `serve.deadline_us`
    /// (unfinished launches count as misses).
    pub deadline_miss_rate: f64,
    /// Completed launches per simulated millisecond.
    pub throughput_per_ms: f64,
    /// Mean queue depth sampled at epoch boundaries (dispatched job
    /// included), a congestion indicator.
    pub mean_queue_depth: f64,
}

/// Whole-run summary.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub workload: String,
    pub policy: String,
    pub objective: String,
    pub records: Vec<EpochRecord>,
    pub total_energy_j: f64,
    pub total_time_ns: f64,
    pub total_instr: f64,
    /// Mean prediction accuracy over predicting epochs (NaN if none).
    pub mean_accuracy: f64,
    /// PC-table hit rate over the run (0 for designs without a table).
    pub pc_hit_rate: f64,
    /// Did the workload run to completion (fixed-work runs)?
    pub completed: bool,
    /// Serve-mode latency/queue summary (`RunMode::Serve` runs only).
    pub serve: Option<ServeStats>,
}

impl RunResult {
    /// Energy·Delay^n product for the fixed work this run completed.
    /// Units: J·s^n.
    pub fn ednp(&self, n: u32) -> f64 {
        let d_s = self.total_time_ns * 1e-9;
        self.total_energy_j * d_s.powi(n as i32)
    }

    pub fn edp(&self) -> f64 {
        self.ednp(1)
    }

    pub fn ed2p(&self) -> f64 {
        self.ednp(2)
    }

    /// Fraction of CU·epochs spent at each ladder state (Fig. 16).
    pub fn freq_time_share(&self) -> [f64; crate::power::params::N_FREQ] {
        let mut share = [0f64; crate::power::params::N_FREQ];
        let mut total = 0f64;
        for r in &self.records {
            for &idx in &r.freq_idx {
                share[idx as usize] += 1.0;
                total += 1.0;
            }
        }
        if total > 0.0 {
            for s in &mut share {
                *s /= total;
            }
        }
        share
    }

    /// Mean relative sensitivity change across consecutive epochs
    /// (Fig. 7), averaged over domains.
    pub fn mean_sens_change(&self) -> f64 {
        let mut sum = 0f64;
        let mut n = 0u64;
        for w in self.records.windows(2) {
            for (a, b) in w[0].dom_sens.iter().zip(&w[1].dom_sens) {
                // only count epochs where the domain did meaningful work
                if a.abs() + b.abs() > 1.0 {
                    sum += crate::dvfs::sensitivity::relative_change(*a as f64, *b as f64);
                    n += 1;
                }
            }
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(epoch: u64, idxs: Vec<u8>, sens: Vec<f32>) -> EpochRecord {
        EpochRecord {
            epoch,
            t_ns: epoch as f64 * 1000.0,
            freq_idx: idxs,
            instr: 100.0,
            energy_j: 1e-6,
            accuracy: 0.9,
            dom_sens: sens,
        }
    }

    fn result(records: Vec<EpochRecord>) -> RunResult {
        RunResult {
            workload: "t".into(),
            policy: "p".into(),
            objective: "o".into(),
            records,
            total_energy_j: 2.0,
            total_time_ns: 3e9,
            total_instr: 1000.0,
            mean_accuracy: 0.9,
            pc_hit_rate: 0.0,
            completed: true,
            serve: None,
        }
    }

    #[test]
    fn ednp_products() {
        let r = result(vec![]);
        assert!((r.edp() - 2.0 * 3.0).abs() < 1e-9);
        assert!((r.ed2p() - 2.0 * 9.0).abs() < 1e-9);
    }

    #[test]
    fn freq_time_share_sums_to_one() {
        let r = result(vec![
            rec(0, vec![0, 9], vec![0.0, 0.0]),
            rec(1, vec![9, 9], vec![0.0, 0.0]),
        ]);
        let share = r.freq_time_share();
        assert!((share.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((share[9] - 0.75).abs() < 1e-12);
        assert!((share[0] - 0.25).abs() < 1e-12);
    }

    #[test]
    fn sens_change_metric() {
        let r = result(vec![
            rec(0, vec![0], vec![100.0]),
            rec(1, vec![0], vec![150.0]),
            rec(2, vec![0], vec![150.0]),
        ]);
        // changes: 0.4 then 0.0 → mean 0.2
        assert!((r.mean_sens_change() - 0.2).abs() < 1e-9);
    }

    #[test]
    fn sens_change_ignores_idle_domains() {
        let r = result(vec![rec(0, vec![0], vec![0.0]), rec(1, vec![0], vec![0.0])]);
        assert_eq!(r.mean_sens_change(), 0.0);
    }
}
