//! `pcstall sweep plot`: figure-script emission from merged sweep CSVs.
//!
//! Takes the merged CSV a sweep plan wrote (`sweep_<name>.csv`, schema
//! [`crate::harness::sweep::sweep_header`]), groups it by the plan's
//! axes, and emits two self-contained figure scripts next to it:
//!
//! * `<stem>_<metric>.gnuplot` — the data inlined as gnuplot
//!   datablocks, rendered with `gnuplot <file>`;
//! * `<stem>_<metric>.py` — a matplotlib fallback carrying the same
//!   aggregated data, rendered with `python3 <file>`.
//!
//! ## Grouping (axis inference)
//!
//! The grid axes are discovered from the CSV itself: every column left
//! of `improvement_pct` that is not a role column (`workload`, `design`,
//! `objective`, `seed`) is a numeric grid axis — `epoch_us`,
//! `cus_per_domain`, and one column per plan `[axis]` config dimension.
//! The **x axis** is the grid axis with the most distinct values; ties
//! prefer the plan's declared config axes (the knob the plan explicitly
//! swept), then the paper's canonical epoch axis, then granularity.
//! One **panel** is emitted per (objective, values-of-the-other-axes),
//! one **series** per design, and the remaining population axes
//! (`seed`, `workload`) are aggregated per x position into a mean line
//! inside a band — min–max by default, inter-quartile with
//! [`Band::Iqr`] (`--band iqr`, the sane envelope once populations grow
//! past ~20 seeds).
//!
//! ## Determinism
//!
//! Script bytes are a pure function of the CSV content and the band
//! choice: groups are sorted (never hash-ordered), floats print at
//! fixed precision, x labels are carried verbatim from the CSV, and no
//! timestamp, path, or hostname leaks into the output.  Re-plotting the
//! same CSV — in any row order — is byte-identical, which CI gates on.

use std::cmp::Ordering;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use crate::stats::emit::{sanitize_ident as ident, CsvTable};

/// Metric column plotted when `--metric` is not given.
pub const DEFAULT_METRIC: &str = "accuracy";

/// Role columns every sweep CSV must carry (the `seed` column is
/// optional so CSVs predating the seed axis still plot).
const AXIS_COLS: [&str; 5] = ["epoch_us", "cus_per_domain", "workload", "design", "objective"];

/// The first metric column of every sweep CSV — everything left of it
/// is a grid coordinate (built-in axes, roles, config-axis columns).
const FIRST_METRIC: &str = "improvement_pct";

/// The population envelope drawn around each series' mean line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Band {
    /// Full min–max envelope (default).
    MinMax,
    /// 25th–75th percentile envelope (`--band iqr`) — outlier-robust
    /// for populations past ~20 seeds.
    Iqr,
}

impl Band {
    /// Parse the CLI form (`minmax` | `iqr`).
    pub fn parse(s: &str) -> anyhow::Result<Band> {
        match s {
            "minmax" => Ok(Band::MinMax),
            "iqr" => Ok(Band::Iqr),
            _ => anyhow::bail!("unknown band '{s}' (expected: minmax | iqr)"),
        }
    }

    /// Label used in figure titles.
    fn label(self) -> &'static str {
        match self {
            Band::MinMax => "min-max",
            Band::Iqr => "iqr",
        }
    }
}

/// One aggregated x position of a series: the population's mean and
/// band envelope (min–max or IQR) at that grid point.
#[derive(Debug, Clone, PartialEq)]
pub struct BandPoint {
    pub x: f64,
    /// The x cell verbatim from the CSV (emitted as-is — re-formatting
    /// floats could drift bytes between runs).
    pub x_label: String,
    pub mean: f64,
    /// Lower band edge (population min, or 25th percentile for IQR).
    pub min: f64,
    /// Upper band edge (population max, or 75th percentile for IQR).
    pub max: f64,
    /// Population size aggregated into this point.
    pub n: usize,
}

/// One design's line (+band) inside a panel.
#[derive(Debug, Clone)]
pub struct Series {
    pub design: String,
    pub points: Vec<BandPoint>,
}

/// One subplot: a fixed (objective, other-axes values) slice.
#[derive(Debug, Clone)]
pub struct Panel {
    pub objective: String,
    /// Values of [`PlotSpec::panel_cols`], aligned by index.
    pub fixed: Vec<String>,
    pub series: Vec<Series>,
}

/// A fully-aggregated figure: everything the script emitters need.
#[derive(Debug, Clone)]
pub struct PlotSpec {
    /// Sanitized CSV stem — becomes the script/png base name.
    pub name: String,
    pub metric: String,
    /// The inferred x grid axis (`epoch_us`, `cus_per_domain`, or a
    /// config-axis column like `dvfs.transition_ns`).
    pub x_col: String,
    /// The non-x grid axes pinned per panel, in column order.
    pub panel_cols: Vec<String>,
    /// Population column the band aggregates over (`seed`, `workload`),
    /// empty when every group is a single run (degenerate band).
    pub band_over: Option<String>,
    pub band: Band,
    /// Largest population aggregated into any one point.
    pub population: usize,
    pub panels: Vec<Panel>,
}

impl PlotSpec {
    /// Script/PNG base name: `<csv-stem>_<metric>`, with an `_iqr`
    /// suffix for the IQR band so the two variants never clobber.
    pub fn base_name(&self) -> String {
        let mut base = format!("{}_{}", self.name, ident(&self.metric));
        if self.band == Band::Iqr {
            base.push_str("_iqr");
        }
        base
    }
}

/// Fixed-precision float for script bytes (deterministic, locale-free).
fn num(v: f64) -> String {
    format!("{v:.6}")
}

/// Deterministic linear-interpolation quantile of an ascending-sorted,
/// finite, non-empty slice.
fn quantile(sorted: &[f64], q: f64) -> f64 {
    let last = sorted.len() - 1;
    let pos = q * last as f64;
    let i = pos.floor() as usize;
    let frac = pos - i as f64;
    if i < last {
        sorted[i] * (1.0 - frac) + sorted[i + 1] * frac
    } else {
        sorted[last]
    }
}

/// Numeric-aware ordering for axis values carried as CSV text.
fn numeric_cmp(a: &str, b: &str) -> Ordering {
    match (a.parse::<f64>(), b.parse::<f64>()) {
        (Ok(x), Ok(y)) => x.partial_cmp(&y).unwrap_or(Ordering::Equal),
        _ => a.cmp(b),
    }
}

/// Build the aggregated figure from a merged sweep CSV.
pub fn plot_spec(
    table: &CsvTable,
    name: &str,
    metric: &str,
    band: Band,
) -> anyhow::Result<PlotSpec> {
    let col = |n: &str| table.col(n);
    for c in AXIS_COLS {
        anyhow::ensure!(
            col(c).is_some(),
            "not a sweep CSV: missing '{c}' column (header: {})",
            table.header.join(",")
        );
    }
    anyhow::ensure!(
        col("row").is_none(),
        "this is a sweep *part* file — combine the part set with `pcstall sweep merge` first"
    );
    anyhow::ensure!(!table.rows.is_empty(), "sweep CSV has no data rows");
    let metric_start = col(FIRST_METRIC).ok_or_else(|| {
        anyhow::anyhow!(
            "not a sweep CSV: missing '{FIRST_METRIC}' column (header: {})",
            table.header.join(",")
        )
    })?;
    // grid axes: every coordinate column that is not a role column —
    // epoch_us, cus_per_domain, plus one column per plan config axis
    let is_role = |h: &str| matches!(h, "workload" | "design" | "objective" | "seed");
    let grid_axes: Vec<(String, usize)> = table.header[..metric_start]
        .iter()
        .enumerate()
        .filter(|(_, h)| !is_role(h))
        .map(|(i, h)| (h.clone(), i))
        .collect();
    let metric_idx = match col(metric) {
        Some(i) if i >= metric_start => i,
        Some(_) => anyhow::bail!("'{metric}' is a grid axis, not a plottable metric"),
        None => {
            // name the columns that would have worked
            let numeric: Vec<&str> = table
                .header
                .iter()
                .enumerate()
                .filter(|(i, _)| *i >= metric_start)
                .filter(|(i, _)| table.rows.iter().all(|r| r[*i].parse::<f64>().is_ok()))
                .map(|(_, h)| h.as_str())
                .collect();
            anyhow::bail!(
                "no '{metric}' column in the CSV; plottable metrics: {}",
                numeric.join(", ")
            );
        }
    };

    let distinct = |idx: usize| {
        let mut vals: Vec<&str> = table.rows.iter().map(|r| r[idx].as_str()).collect();
        vals.sort_unstable();
        vals.dedup();
        vals.len()
    };
    // x = the grid axis that varies the most; ties prefer the plan's
    // declared config axes (in column order), then the paper's
    // canonical epoch axis, then granularity.
    let mut candidates: Vec<(String, usize)> = grid_axes
        .iter()
        .filter(|(h, _)| h != "epoch_us" && h != "cus_per_domain")
        .cloned()
        .collect();
    candidates.push(("epoch_us".into(), col("epoch_us").expect("checked above")));
    candidates.push((
        "cus_per_domain".into(),
        col("cus_per_domain").expect("checked above"),
    ));
    let max_distinct = candidates
        .iter()
        .map(|(_, i)| distinct(*i))
        .max()
        .expect("candidates non-empty");
    let (x_col, x_idx) = candidates
        .iter()
        .find(|(_, i)| distinct(*i) == max_distinct)
        .expect("max came from the list")
        .clone();
    let panel_axes: Vec<(String, usize)> =
        grid_axes.iter().filter(|(h, _)| *h != x_col).cloned().collect();

    let (wl_idx, design_idx) = (col("workload").unwrap(), col("design").unwrap());
    let obj_idx = col("objective").unwrap();
    let seed_idx = col("seed");

    // (objective, panel-axes values) -> design -> x label -> metric
    // values.  String-keyed BTreeMaps give a deterministic build order;
    // the real (numeric-aware) ordering is applied on the sorted Vecs
    // below.
    type XMap = std::collections::BTreeMap<String, Vec<f64>>;
    type SeriesMap = std::collections::BTreeMap<String, XMap>;
    type PanelKey = (String, Vec<String>);
    let mut groups: std::collections::BTreeMap<PanelKey, SeriesMap> =
        std::collections::BTreeMap::new();
    let mut band_cols: Vec<&str> = Vec::new();
    let mut seen_pop: Vec<(String, String)> = Vec::new(); // (seed, workload) pairs
    for (lineno, row) in table.rows.iter().enumerate() {
        let v: f64 = row[metric_idx].parse().map_err(|_| {
            anyhow::anyhow!(
                "row {}: '{}' is not a number in metric column '{metric}'",
                lineno + 2,
                row[metric_idx]
            )
        })?;
        let x: f64 = row[x_idx].parse().unwrap_or(f64::NAN);
        anyhow::ensure!(
            x.is_finite(),
            "row {}: bad {x_col} value '{}'",
            lineno + 2,
            row[x_idx]
        );
        seen_pop.push((
            seed_idx.map(|i| row[i].clone()).unwrap_or_default(),
            row[wl_idx].clone(),
        ));
        let fixed: Vec<String> = panel_axes.iter().map(|(_, i)| row[*i].clone()).collect();
        let vals = groups
            .entry((row[obj_idx].clone(), fixed))
            .or_default()
            .entry(row[design_idx].clone())
            .or_default()
            .entry(row[x_idx].clone())
            .or_default();
        // non-finite metric cells (a design that never predicts has NaN
        // accuracy) drop out of the band rather than poisoning it
        if v.is_finite() {
            vals.push(v);
        }
    }
    let varies = |f: fn(&(String, String)) -> &String| {
        let mut vals: Vec<&String> = seen_pop.iter().map(f).collect();
        vals.sort_unstable();
        vals.dedup();
        vals.len() > 1
    };
    if seed_idx.is_some() && varies(|p| &p.0) {
        band_cols.push("seed");
    } else if varies(|p| &p.1) {
        band_cols.push("workload");
    }

    let mut population = 0usize;
    let mut panels: Vec<Panel> = Vec::new();
    for ((objective, fixed), designs) in groups {
        let mut series: Vec<Series> = Vec::new();
        for (design, xs) in designs {
            let mut points: Vec<BandPoint> = Vec::new();
            for (x_label, mut vals) in xs {
                if vals.is_empty() {
                    continue; // every population member was non-finite
                }
                vals.sort_by(|a, b| a.partial_cmp(b).expect("finite metric values"));
                let (lo, hi) = match band {
                    Band::MinMax => (vals[0], vals[vals.len() - 1]),
                    Band::Iqr => (quantile(&vals, 0.25), quantile(&vals, 0.75)),
                };
                let mean = vals.iter().sum::<f64>() / vals.len() as f64;
                population = population.max(vals.len());
                points.push(BandPoint {
                    x: x_label.parse().expect("validated above"),
                    x_label,
                    mean,
                    min: lo,
                    max: hi,
                    n: vals.len(),
                });
            }
            points.sort_by(|a, b| a.x.partial_cmp(&b.x).expect("finite x"));
            if !points.is_empty() {
                series.push(Series { design, points });
            }
        }
        if !series.is_empty() {
            panels.push(Panel {
                objective,
                fixed,
                series,
            });
        }
    }
    // numeric panel order (BTreeMap gave lexicographic: "16" < "2")
    panels.sort_by(|a, b| {
        a.objective.cmp(&b.objective).then_with(|| {
            for (x, y) in a.fixed.iter().zip(&b.fixed) {
                let ord = numeric_cmp(x, y);
                if ord != Ordering::Equal {
                    return ord;
                }
            }
            Ordering::Equal
        })
    });
    anyhow::ensure!(
        !panels.is_empty(),
        "nothing to plot: every '{metric}' value in the CSV is non-finite"
    );
    Ok(PlotSpec {
        name: ident(name),
        metric: metric.to_string(),
        x_col,
        panel_cols: panel_axes.into_iter().map(|(h, _)| h).collect(),
        band_over: band_cols.first().map(|s| s.to_string()),
        band,
        population,
        panels,
    })
}

/// Grid layout: up to 3 panels per row.
fn layout(n: usize) -> (usize, usize) {
    let cols = n.clamp(1, 3);
    (n.div_ceil(cols), cols)
}

fn x_axis_label(x_col: &str) -> String {
    match x_col {
        "cus_per_domain" => "CUs per V/f domain".into(),
        "epoch_us" => "epoch length (us)".into(),
        other => other.to_string(),
    }
}

/// Log base for the x axis: the built-in axes keep their canonical
/// bases; config axes go log-10 when the data spans a decade, linear
/// otherwise (a pure function of the aggregated points — deterministic).
fn x_log_base(spec: &PlotSpec) -> Option<u32> {
    match spec.x_col.as_str() {
        "epoch_us" => Some(10),
        "cus_per_domain" => Some(2),
        _ => {
            let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
            for panel in &spec.panels {
                for s in &panel.series {
                    for pt in &s.points {
                        lo = lo.min(pt.x);
                        hi = hi.max(pt.x);
                    }
                }
            }
            if lo > 0.0 && hi / lo >= 10.0 {
                Some(10)
            } else {
                None
            }
        }
    }
}

fn panel_title(spec: &PlotSpec, p: &Panel) -> String {
    let mut parts = vec![p.objective.clone()];
    for (col, val) in spec.panel_cols.iter().zip(&p.fixed) {
        parts.push(match col.as_str() {
            "cus_per_domain" => format!("{val} CU/domain"),
            "epoch_us" => format!("epoch {val} us"),
            other => format!("{other}={val}"),
        });
    }
    parts.join(", ")
}

fn figure_title(spec: &PlotSpec) -> String {
    match &spec.band_over {
        Some(col) => format!(
            "{}: {} (band: {} over {col}, n={})",
            spec.name,
            spec.metric,
            spec.band.label(),
            spec.population
        ),
        None => format!("{}: {}", spec.name, spec.metric),
    }
}

/// Render the self-contained gnuplot script.
pub fn render_gnuplot(spec: &PlotSpec) -> String {
    let (rows, cols) = layout(spec.panels.len());
    let (w, h) = (520 * cols, 390 * rows);
    let png = format!("{}.png", spec.base_name());
    let mut out = String::new();
    let _ = writeln!(out, "# {} — generated by `pcstall sweep plot`", figure_title(spec));
    let _ = writeln!(out, "# render: gnuplot <this file>   (writes {png} into the cwd)");
    let _ = writeln!(out, "# columns: x mean min max n");
    let _ = writeln!(
        out,
        "if (strstrt(GPVAL_TERMINALS, \"pngcairo\") > 0) {{\n    set terminal pngcairo size {w},{h} font \"sans,10\" noenhanced\n}} else {{\n    set terminal png size {w},{h} noenhanced\n}}"
    );
    let _ = writeln!(out, "set output \"{png}\"");
    let _ = writeln!(
        out,
        "set multiplot layout {rows},{cols} title \"{}\"",
        figure_title(spec)
    );
    match x_log_base(spec) {
        Some(base) => {
            let _ = writeln!(out, "set logscale x {base}");
        }
        None => {
            let _ = writeln!(out, "unset logscale x");
        }
    }
    let _ = writeln!(out, "set xlabel \"{}\"", x_axis_label(&spec.x_col));
    let _ = writeln!(out, "set ylabel \"{}\"", spec.metric);
    let _ = writeln!(out, "set key bottom left");
    let _ = writeln!(out, "set grid");
    for (pi, panel) in spec.panels.iter().enumerate() {
        let _ = writeln!(out);
        // one datablock per series: x mean min max n (design named in
        // the plot clause title)
        for (si, s) in panel.series.iter().enumerate() {
            let _ = writeln!(out, "$p{pi}_s{si} << EOD");
            for pt in &s.points {
                let _ = writeln!(
                    out,
                    "{} {} {} {} {}",
                    pt.x_label,
                    num(pt.mean),
                    num(pt.min),
                    num(pt.max),
                    pt.n
                );
            }
            let _ = writeln!(out, "EOD");
        }
        let _ = writeln!(out, "set title \"{}\"", panel_title(spec, panel));
        let mut clauses: Vec<String> = Vec::new();
        for (si, s) in panel.series.iter().enumerate() {
            let lc = si + 1;
            clauses.push(format!(
                "$p{pi}_s{si} using 1:3:4 with filledcurves fs transparent solid 0.15 lc {lc} notitle"
            ));
            clauses.push(format!(
                "$p{pi}_s{si} using 1:2 with linespoints pt 7 lc {lc} title \"{}\"",
                s.design
            ));
        }
        let _ = writeln!(out, "plot {}", clauses.join(", \\\n     "));
    }
    let _ = writeln!(out, "\nunset multiplot");
    out
}

/// Render the matplotlib fallback script.
pub fn render_matplotlib(spec: &PlotSpec) -> String {
    let (rows, cols) = layout(spec.panels.len());
    let png = format!("{}.png", spec.base_name());
    let mut out = String::new();
    let _ = writeln!(out, "#!/usr/bin/env python3");
    let _ = writeln!(out, "# {} — generated by `pcstall sweep plot`", figure_title(spec));
    let _ = writeln!(out, "# render: python3 <this file>   (writes {png} into the cwd)");
    let _ = writeln!(
        out,
        "# DATA: [(panel_title, [(design, [(x, mean, min, max, n), ...]), ...]), ...]"
    );
    let _ = writeln!(out, "DATA = [");
    for panel in &spec.panels {
        let _ = writeln!(out, "    (\"{}\", [", panel_title(spec, panel));
        for s in &panel.series {
            let _ = writeln!(out, "        (\"{}\", [", s.design);
            for pt in &s.points {
                let _ = writeln!(
                    out,
                    "            ({}, {}, {}, {}, {}),",
                    pt.x_label,
                    num(pt.mean),
                    num(pt.min),
                    num(pt.max),
                    pt.n
                );
            }
            let _ = writeln!(out, "        ]),");
        }
        let _ = writeln!(out, "    ]),");
    }
    let _ = writeln!(out, "]");
    let xscale = match x_log_base(spec) {
        Some(base) => format!("ax.set_xscale(\"log\", base={base})"),
        None => "ax.set_xscale(\"linear\")".to_string(),
    };
    let _ = writeln!(
        out,
        r#"
def main():
    import matplotlib
    matplotlib.use("Agg")
    import matplotlib.pyplot as plt
    rows, cols = {rows}, {cols}
    fig, axes = plt.subplots(rows, cols, figsize=(5.2 * cols, 3.9 * rows), squeeze=False)
    for i, (title, series) in enumerate(DATA):
        ax = axes[i // cols][i % cols]
        for label, pts in series:
            xs = [p[0] for p in pts]
            ax.fill_between(xs, [p[2] for p in pts], [p[3] for p in pts], alpha=0.15)
            ax.plot(xs, [p[1] for p in pts], marker="o", label=label)
        {xscale}
        ax.set_title(title)
        ax.set_xlabel("{xlabel}")
        ax.set_ylabel("{metric}")
        ax.grid(True, alpha=0.4)
        ax.legend(loc="lower left")
    for j in range(len(DATA), rows * cols):
        axes[j // cols][j % cols].axis("off")
    fig.suptitle("{title}")
    fig.tight_layout()
    fig.savefig("{png}", dpi=150)
    print("wrote {png}")


if __name__ == "__main__":
    main()"#,
        rows = rows,
        cols = cols,
        xscale = xscale,
        xlabel = x_axis_label(&spec.x_col),
        metric = spec.metric,
        title = figure_title(spec),
        png = png,
    );
    out
}

/// Read `csv`, aggregate, and write the script pair.  Returns
/// `(gnuplot_path, matplotlib_path)`.  Scripts land next to the CSV
/// unless `out_dir` redirects them.
pub fn emit_plot_scripts(
    csv: &Path,
    metric: &str,
    band: Band,
    out_dir: Option<&Path>,
) -> anyhow::Result<(PathBuf, PathBuf)> {
    let table = CsvTable::read(csv).map_err(anyhow::Error::msg)?;
    let stem = csv
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("sweep");
    let spec = plot_spec(&table, stem, metric, band)?;
    let dir = match out_dir {
        Some(d) => d.to_path_buf(),
        None => csv.parent().unwrap_or_else(|| Path::new(".")).to_path_buf(),
    };
    std::fs::create_dir_all(&dir)
        .map_err(|e| anyhow::anyhow!("creating {}: {e}", dir.display()))?;
    let base = spec.base_name();
    let gp = dir.join(format!("{base}.gnuplot"));
    let py = dir.join(format!("{base}.py"));
    std::fs::write(&gp, render_gnuplot(&spec))
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", gp.display()))?;
    std::fs::write(&py, render_matplotlib(&spec))
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", py.display()))?;
    Ok((gp, py))
}

// ---------------------------------------------------------------------------
// `pcstall obs plot`: decision-trace timeline figures
// ---------------------------------------------------------------------------

/// Panels per decision-timeline figure; cells beyond the cap are dropped
/// (reported on stdout — never silently).
const MAX_TIMELINE_PANELS: usize = 6;

/// One cell's aggregated timeline: per epoch, the epoch-level accuracy
/// and the mean chosen frequency (GHz) across domains.
struct CellTimeline {
    title: String,
    /// `(epoch, accuracy, mean_ghz)` sorted by epoch.
    points: Vec<(u64, f64, f64)>,
}

/// gnuplot missing-data token for non-finite values.
fn gnum(v: f64) -> String {
    if v.is_finite() {
        num(v)
    } else {
        "NaN".into()
    }
}

/// Python literal for possibly-non-finite values (`nan` is defined in
/// the emitted script's prologue).
fn pynum(v: f64) -> String {
    if v.is_finite() {
        num(v)
    } else {
        "nan".into()
    }
}

fn decision_timelines(rows: &[crate::obs::DecisionRow]) -> Vec<CellTimeline> {
    use std::collections::BTreeMap;
    // cell -> epoch -> (accuracy, freq sum, domain count)
    let mut cells: BTreeMap<(String, String, String, String), BTreeMap<u64, (f64, f64, usize)>> =
        BTreeMap::new();
    for r in rows {
        let e = cells
            .entry(r.cell_id())
            .or_default()
            .entry(r.epoch)
            .or_insert((f64::NAN, 0.0, 0));
        e.0 = r.accuracy; // epoch-level, identical on every domain row
        e.1 += crate::power::params::FREQS_GHZ[(r.chosen as usize).min(
            crate::power::params::N_FREQ - 1,
        )];
        e.2 += 1;
    }
    cells
        .into_iter()
        .map(|((wl, obj, ens, pol), epochs)| CellTimeline {
            title: format!("{wl} {pol} {obj} @{ens}ns"),
            points: epochs
                .into_iter()
                .map(|(ep, (acc, fsum, n))| (ep, acc, fsum / n.max(1) as f64))
                .collect(),
        })
        .collect()
}

fn render_timeline_gnuplot(panels: &[CellTimeline]) -> String {
    let (rows, cols) = layout(panels.len());
    let (w, h) = (520 * cols, 390 * rows);
    let mut out = String::new();
    let _ = writeln!(out, "# decision-trace timeline — generated by `pcstall obs plot`");
    let _ = writeln!(
        out,
        "# render: gnuplot <this file>   (writes decisions_timeline.png into the cwd)"
    );
    let _ = writeln!(out, "# columns: epoch accuracy mean_chosen_ghz");
    let _ = writeln!(
        out,
        "if (strstrt(GPVAL_TERMINALS, \"pngcairo\") > 0) {{\n    set terminal pngcairo size {w},{h} font \"sans,10\" noenhanced\n}} else {{\n    set terminal png size {w},{h} noenhanced\n}}"
    );
    let _ = writeln!(out, "set output \"decisions_timeline.png\"");
    let _ = writeln!(
        out,
        "set multiplot layout {rows},{cols} title \"decision trace: accuracy + chosen frequency vs epoch\""
    );
    let _ = writeln!(out, "set xlabel \"epoch\"");
    let _ = writeln!(out, "set ylabel \"accuracy\"");
    let _ = writeln!(out, "set y2label \"mean chosen GHz\"");
    let _ = writeln!(out, "set yrange [0:1.05]");
    let _ = writeln!(out, "set y2range [1.2:2.3]");
    let _ = writeln!(out, "set ytics nomirror");
    let _ = writeln!(out, "set y2tics");
    let _ = writeln!(out, "set key bottom right");
    let _ = writeln!(out, "set grid");
    for (pi, p) in panels.iter().enumerate() {
        let _ = writeln!(out);
        let _ = writeln!(out, "$c{pi} << EOD");
        for &(ep, acc, ghz) in &p.points {
            let _ = writeln!(out, "{ep} {} {}", gnum(acc), gnum(ghz));
        }
        let _ = writeln!(out, "EOD");
        let _ = writeln!(out, "set title \"{}\"", p.title);
        let _ = writeln!(
            out,
            "plot $c{pi} using 1:2 with linespoints pt 7 lc 1 title \"accuracy\", \\\n     $c{pi} using 1:3 axes x1y2 with steps lc 2 title \"chosen GHz\""
        );
    }
    let _ = writeln!(out, "\nunset multiplot");
    out
}

fn render_timeline_matplotlib(panels: &[CellTimeline]) -> String {
    let (rows, cols) = layout(panels.len());
    let mut out = String::new();
    let _ = writeln!(out, "#!/usr/bin/env python3");
    let _ = writeln!(out, "# decision-trace timeline — generated by `pcstall obs plot`");
    let _ = writeln!(
        out,
        "# render: python3 <this file>   (writes decisions_timeline.png into the cwd)"
    );
    let _ = writeln!(out, "# DATA: [(title, [(epoch, accuracy, mean_chosen_ghz), ...]), ...]");
    let _ = writeln!(out, "nan = float(\"nan\")");
    let _ = writeln!(out, "DATA = [");
    for p in panels {
        let _ = writeln!(out, "    (\"{}\", [", p.title);
        for &(ep, acc, ghz) in &p.points {
            let _ = writeln!(out, "        ({ep}, {}, {}),", pynum(acc), pynum(ghz));
        }
        let _ = writeln!(out, "    ]),");
    }
    let _ = writeln!(out, "]");
    let _ = writeln!(
        out,
        r#"
def main():
    import matplotlib
    matplotlib.use("Agg")
    import matplotlib.pyplot as plt
    rows, cols = {rows}, {cols}
    fig, axes = plt.subplots(rows, cols, figsize=(5.2 * cols, 3.9 * rows), squeeze=False)
    for i, (title, pts) in enumerate(DATA):
        ax = axes[i // cols][i % cols]
        xs = [p[0] for p in pts]
        ax.plot(xs, [p[1] for p in pts], marker="o", label="accuracy")
        ax.set_ylim(0, 1.05)
        ax2 = ax.twinx()
        ax2.step(xs, [p[2] for p in pts], where="post", color="tab:orange", label="chosen GHz")
        ax2.set_ylim(1.2, 2.3)
        ax.set_title(title)
        ax.set_xlabel("epoch")
        ax.set_ylabel("accuracy")
        ax2.set_ylabel("mean chosen GHz")
        ax.grid(True, alpha=0.4)
    for j in range(len(DATA), rows * cols):
        axes[j // cols][j % cols].axis("off")
    fig.suptitle("decision trace: accuracy + chosen frequency vs epoch")
    fig.tight_layout()
    fig.savefig("decisions_timeline.png", dpi=150)
    print("wrote decisions_timeline.png")


if __name__ == "__main__":
    main()"#,
        rows = rows,
        cols = cols,
    );
    out
}

/// Read an obs dir's `decisions.csv` and emit the timeline script pair
/// (`decisions_timeline.{gnuplot,py}`) — accuracy and mean chosen
/// frequency vs epoch, one panel per cell (first
/// [`MAX_TIMELINE_PANELS`]; any dropped cells are reported on stdout).
/// Scripts land in the obs dir unless `out_dir` redirects them.  Bytes
/// are a pure function of the CSV content — byte-identical on re-plot.
pub fn emit_decision_timeline(
    obs_dir: &Path,
    out_dir: Option<&Path>,
) -> anyhow::Result<(PathBuf, PathBuf)> {
    let rows = crate::obs::read_decisions(obs_dir).map_err(anyhow::Error::msg)?;
    anyhow::ensure!(
        !rows.is_empty(),
        "decisions.csv in {} has no rows (cached cells emit no trace — rerun with --no-cache)",
        obs_dir.display()
    );
    let mut panels = decision_timelines(&rows);
    if panels.len() > MAX_TIMELINE_PANELS {
        println!(
            "(plotting first {MAX_TIMELINE_PANELS} of {} cells — narrow the run for the rest)",
            panels.len()
        );
        panels.truncate(MAX_TIMELINE_PANELS);
    }
    let dir = match out_dir {
        Some(d) => d.to_path_buf(),
        None => obs_dir.to_path_buf(),
    };
    std::fs::create_dir_all(&dir)
        .map_err(|e| anyhow::anyhow!("creating {}: {e}", dir.display()))?;
    let gp = dir.join("decisions_timeline.gnuplot");
    let py = dir.join("decisions_timeline.py");
    std::fs::write(&gp, render_timeline_gnuplot(&panels))
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", gp.display()))?;
    std::fs::write(&py, render_timeline_matplotlib(&panels))
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", py.display()))?;
    Ok((gp, py))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::sweep::{sweep_header, SWEEP_HEADER};

    /// A seed-population CSV: 2 designs x 2 epochs x 3 seeds, 1 panel.
    fn population_table() -> CsvTable {
        let mut t = CsvTable::new(&SWEEP_HEADER);
        for (design, base) in [("crisp", 0.6), ("pcstall", 0.8)] {
            for (ei, epoch) in ["1", "10"].iter().enumerate() {
                for seed in 1..=3u64 {
                    let acc = base + 0.01 * seed as f64 - 0.05 * ei as f64;
                    t.push(vec![
                        epoch.to_string(),
                        "1".into(),
                        format!("synth:{seed}"),
                        seed.to_string(),
                        design.into(),
                        "ed2p".into(),
                        "12.00".into(),
                        "0.8800".into(),
                        "1.0000e-3".into(),
                        "0.0400".into(),
                        format!("{acc:.3}"),
                    ]);
                }
            }
        }
        t
    }

    /// A config-axis CSV (schema from `sweep_header`): 2 transition
    /// latencies x 2 epochs x 2 workloads, 1 design.
    fn transition_table() -> CsvTable {
        let mut t =
            CsvTable::with_header(sweep_header(&["dvfs.transition_ns".to_string()], false));
        for lat in ["5.0", "1000.0"] {
            for epoch in ["1", "10"] {
                for wl in ["comd", "synth:11"] {
                    let imp = if lat == "5.0" { "20.00" } else { "8.00" };
                    t.push(vec![
                        epoch.into(),
                        "1".into(),
                        wl.into(),
                        "-".into(),
                        "pcstall".into(),
                        "ed2p".into(),
                        lat.into(),
                        imp.into(),
                        "0.8800".into(),
                        "1.0000e-3".into(),
                        "0.0400".into(),
                        "0.900".into(),
                    ]);
                }
            }
        }
        t
    }

    #[test]
    fn aggregates_the_seed_population() {
        let spec = plot_spec(&population_table(), "sweep_pop", "accuracy", Band::MinMax).unwrap();
        assert_eq!(spec.x_col, "epoch_us");
        assert_eq!(spec.panel_cols, vec!["cus_per_domain"]);
        assert_eq!(spec.band_over.as_deref(), Some("seed"));
        assert_eq!(spec.population, 3);
        assert_eq!(spec.panels.len(), 1);
        let panel = &spec.panels[0];
        assert_eq!(panel.objective, "ed2p");
        assert_eq!(panel.fixed, vec!["1"]);
        // series sorted by design name
        let designs: Vec<&str> = panel.series.iter().map(|s| s.design.as_str()).collect();
        assert_eq!(designs, vec!["crisp", "pcstall"]);
        // band stats at epoch 1 for crisp: 0.61, 0.62, 0.63
        let p = &panel.series[0].points[0];
        assert_eq!(p.x_label, "1");
        assert_eq!(p.n, 3);
        assert!((p.mean - 0.62).abs() < 1e-9, "{}", p.mean);
        assert!((p.min - 0.61).abs() < 1e-9);
        assert!((p.max - 0.63).abs() < 1e-9);
        // x sorted numerically
        assert!(panel.series[0].points[0].x < panel.series[0].points[1].x);
    }

    #[test]
    fn iqr_band_narrows_the_envelope_deterministically() {
        // 5 seeds at one grid point: values 0.1, 0.2, 0.3, 0.4, 0.5
        let mut t = CsvTable::new(&SWEEP_HEADER);
        for seed in 1..=5u64 {
            t.push(vec![
                "1".into(),
                "1".into(),
                format!("synth:{seed}"),
                seed.to_string(),
                "pcstall".into(),
                "ed2p".into(),
                "10.00".into(),
                "0.9000".into(),
                "1.0000e-3".into(),
                "0.0400".into(),
                format!("0.{seed}"),
            ]);
        }
        let spec = plot_spec(&t, "s", "accuracy", Band::Iqr).unwrap();
        let p = &spec.panels[0].series[0].points[0];
        assert!((p.mean - 0.3).abs() < 1e-9);
        assert!((p.min - 0.2).abs() < 1e-9, "25th pct of 0.1..0.5: {}", p.min);
        assert!((p.max - 0.4).abs() < 1e-9, "75th pct of 0.1..0.5: {}", p.max);
        // row order does not change the quantiles or the script bytes
        let mut rev = t.clone();
        rev.rows.reverse();
        let spec2 = plot_spec(&rev, "s", "accuracy", Band::Iqr).unwrap();
        assert_eq!(render_gnuplot(&spec), render_gnuplot(&spec2));
        assert_eq!(render_matplotlib(&spec), render_matplotlib(&spec2));
        // titles and file names carry the band choice
        assert!(render_gnuplot(&spec).contains("band: iqr over seed, n=5"));
        assert_eq!(spec.base_name(), "s_accuracy_iqr");
        // the min-max envelope of the same data is wider
        let mm = plot_spec(&t, "s", "accuracy", Band::MinMax).unwrap();
        let q = &mm.panels[0].series[0].points[0];
        assert!(q.min < p.min && q.max > p.max);
        assert_eq!(mm.base_name(), "s_accuracy");
    }

    #[test]
    fn quantile_interpolates_and_handles_tiny_populations() {
        assert_eq!(quantile(&[7.0], 0.25), 7.0);
        assert_eq!(quantile(&[1.0, 2.0], 0.25), 1.25);
        assert_eq!(quantile(&[1.0, 2.0], 0.75), 1.75);
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((quantile(&xs, 0.25) - 1.75).abs() < 1e-12);
        assert!((quantile(&xs, 0.75) - 3.25).abs() < 1e-12);
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
    }

    #[test]
    fn infers_a_config_axis_as_x_and_pins_the_rest_per_panel() {
        // transition latency ties the epoch axis at 2 distinct values;
        // the declared config axis wins the tie and becomes x, epochs
        // become panels, and the workload pair becomes the band
        let spec =
            plot_spec(&transition_table(), "sweep_lat", "improvement_pct", Band::MinMax).unwrap();
        assert_eq!(spec.x_col, "dvfs.transition_ns");
        assert_eq!(spec.panel_cols, vec!["epoch_us", "cus_per_domain"]);
        assert_eq!(spec.band_over.as_deref(), Some("workload"));
        assert_eq!(spec.panels.len(), 2, "one panel per epoch length");
        assert_eq!(spec.panels[0].fixed, vec!["1", "1"]);
        assert_eq!(spec.panels[1].fixed, vec!["10", "1"]);
        // x sorted numerically: 5.0 before 1000.0
        let pts = &spec.panels[0].series[0].points;
        assert_eq!(pts[0].x_label, "5.0");
        assert_eq!(pts[1].x_label, "1000.0");
        let gp = render_gnuplot(&spec);
        assert!(gp.contains("set xlabel \"dvfs.transition_ns\""), "{gp}");
        assert!(gp.contains("set logscale x 10"), "spans a decade: {gp}");
        assert!(gp.contains("ed2p, epoch 1 us, 1 CU/domain"), "{gp}");
    }

    #[test]
    fn scripts_are_deterministic_and_row_order_independent() {
        let t = population_table();
        let spec = plot_spec(&t, "sweep_pop", "accuracy", Band::MinMax).unwrap();
        let (gp1, py1) = (render_gnuplot(&spec), render_matplotlib(&spec));
        // same CSV, reversed row order
        let mut rev = t.clone();
        rev.rows.reverse();
        let spec2 = plot_spec(&rev, "sweep_pop", "accuracy", Band::MinMax).unwrap();
        assert_eq!(gp1, render_gnuplot(&spec2));
        assert_eq!(py1, render_matplotlib(&spec2));
        // and a second render of the same spec is byte-identical
        assert_eq!(gp1, render_gnuplot(&spec));
        // the scripts are self-contained: datablocks inline, png named
        assert!(gp1.contains("$p0_s0 << EOD"));
        assert!(gp1.contains("set output \"sweep_pop_accuracy.png\""));
        assert!(gp1.contains("min-max over seed, n=3"));
        assert!(py1.contains("DATA = ["));
        assert!(py1.contains("sweep_pop_accuracy.png"));
    }

    #[test]
    fn infers_the_granularity_axis_when_epochs_are_pinned() {
        let mut t = CsvTable::new(&SWEEP_HEADER);
        for gran in ["1", "2", "4"] {
            t.push(vec![
                "1".into(),
                gran.into(),
                "comd".into(),
                "-".into(),
                "pcstall".into(),
                "ed2p".into(),
                "10.00".into(),
                "0.9000".into(),
                "1.0000e-3".into(),
                "0.0400".into(),
                "0.900".into(),
            ]);
        }
        let spec = plot_spec(&t, "sweep_gran", "improvement_pct", Band::MinMax).unwrap();
        assert_eq!(spec.x_col, "cus_per_domain");
        assert_eq!(spec.panel_cols, vec!["epoch_us"]);
        assert_eq!(spec.band_over, None, "single workload, no population");
        let gp = render_gnuplot(&spec);
        assert!(gp.contains("set logscale x 2"));
        assert!(gp.contains("CUs per V/f domain"));
    }

    #[test]
    fn panels_sort_numerically_not_lexicographically() {
        // 4 epochs vary more than 3 grans, so epoch is x and the
        // granularity values become panels — in numeric order
        let mut t = CsvTable::new(&SWEEP_HEADER);
        for gran in ["16", "2", "1"] {
            for epoch in ["1", "10", "50", "100"] {
                t.push(vec![
                    epoch.into(),
                    gran.into(),
                    "comd".into(),
                    "-".into(),
                    "pcstall".into(),
                    "ed2p".into(),
                    "10.00".into(),
                    "0.9000".into(),
                    "1.0000e-3".into(),
                    "0.0400".into(),
                    "0.900".into(),
                ]);
            }
        }
        let spec = plot_spec(&t, "s", "accuracy", Band::MinMax).unwrap();
        let fixed: Vec<&str> = spec.panels.iter().map(|p| p.fixed[0].as_str()).collect();
        assert_eq!(fixed, vec!["1", "2", "16"]);
    }

    #[test]
    fn non_finite_metric_cells_drop_out_of_the_band() {
        let mut t = population_table();
        // a static-like design that never predicts: all-NaN accuracy
        for epoch in ["1", "10"] {
            t.push(vec![
                epoch.into(),
                "1".into(),
                "synth:1".into(),
                "1".into(),
                "static-1.7".into(),
                "ed2p".into(),
                "0.00".into(),
                "1.0000".into(),
                "1.0000e-3".into(),
                "0.0400".into(),
                "NaN".into(),
            ]);
        }
        let spec = plot_spec(&t, "s", "accuracy", Band::MinMax).unwrap();
        let designs: Vec<&str> = spec.panels[0]
            .series
            .iter()
            .map(|s| s.design.as_str())
            .collect();
        assert_eq!(
            designs,
            vec!["crisp", "pcstall"],
            "all-NaN series must disappear, not plot as zeros"
        );
    }

    #[test]
    fn rejects_non_sweep_csvs_and_unknown_metrics() {
        let bogus = CsvTable::new(&["a", "b"]);
        assert!(plot_spec(&bogus, "x", "accuracy", Band::MinMax).is_err());

        let empty = CsvTable::new(&SWEEP_HEADER);
        assert!(plot_spec(&empty, "x", "accuracy", Band::MinMax).is_err());

        let err = plot_spec(&population_table(), "x", "nope", Band::MinMax)
            .unwrap_err()
            .to_string();
        assert!(err.contains("accuracy"), "should list metrics: {err}");

        let err = plot_spec(&population_table(), "x", "workload", Band::MinMax)
            .unwrap_err()
            .to_string();
        assert!(err.contains("axis"), "{err}");

        // a config-axis column is a coordinate, not a metric
        let err = plot_spec(&transition_table(), "x", "dvfs.transition_ns", Band::MinMax)
            .unwrap_err()
            .to_string();
        assert!(err.contains("axis"), "{err}");

        // a part file must be merged before plotting
        let mut header = vec!["row".to_string()];
        header.extend(SWEEP_HEADER.iter().map(|s| s.to_string()));
        let part = CsvTable::with_header(header);
        let err = plot_spec(&part, "x", "accuracy", Band::MinMax)
            .unwrap_err()
            .to_string();
        assert!(err.contains("merge"), "{err}");

        assert!(Band::parse("minmax").is_ok());
        assert!(Band::parse("iqr").is_ok());
        assert!(Band::parse("quartile").is_err());
    }

    #[test]
    fn emit_writes_the_script_pair() {
        let dir = std::env::temp_dir().join(format!("pcstall_plot_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let csv = dir.join("sweep_pop.csv");
        population_table().write(&csv).unwrap();
        let (gp, py) = emit_plot_scripts(&csv, DEFAULT_METRIC, Band::MinMax, None).unwrap();
        assert_eq!(gp, dir.join("sweep_pop_accuracy.gnuplot"));
        assert_eq!(py, dir.join("sweep_pop_accuracy.py"));
        let first = std::fs::read(&gp).unwrap();
        // re-emitting is byte-identical (the CI determinism gate)
        let sub = dir.join("again");
        let (gp2, _) = emit_plot_scripts(&csv, DEFAULT_METRIC, Band::MinMax, Some(&sub)).unwrap();
        assert_eq!(std::fs::read(&gp2).unwrap(), first);
        // the IQR variant lands under its own suffix
        let (gp3, py3) = emit_plot_scripts(&csv, DEFAULT_METRIC, Band::Iqr, Some(&sub)).unwrap();
        assert_eq!(gp3, sub.join("sweep_pop_accuracy_iqr.gnuplot"));
        assert_eq!(py3, sub.join("sweep_pop_accuracy_iqr.py"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn decision_timeline_scripts_are_deterministic() {
        use crate::obs::decisions::{decision_csv_row, DECISIONS_HEADER};
        use crate::obs::DecisionSample;

        let dir = std::env::temp_dir().join(format!("pcstall_dplot_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut t = CsvTable::new(&DECISIONS_HEADER);
        for (policy, hash) in [("CRISP", "aaaa"), ("PCSTALL", "bbbb")] {
            for epoch in 0..3u64 {
                for domain in 0..2usize {
                    let s = DecisionSample {
                        epoch,
                        domain,
                        chosen: (4 + epoch as u8) % 10,
                        oracle_best: 4,
                        accuracy: if epoch == 0 { f64::NAN } else { 0.8 },
                        ..Default::default()
                    };
                    t.push(decision_csv_row(hash, "comd", policy, "ED2P", 1000.0, &s));
                }
            }
        }
        t.write(&dir.join("decisions.csv")).unwrap();

        let (gp, py) = emit_decision_timeline(&dir, None).unwrap();
        assert_eq!(gp, dir.join("decisions_timeline.gnuplot"));
        let gp_bytes = std::fs::read(&gp).unwrap();
        let py_bytes = std::fs::read(&py).unwrap();
        let text = String::from_utf8(gp_bytes.clone()).unwrap();
        assert!(text.contains("comd CRISP ED2P @1000ns"), "{text}");
        assert!(text.contains("comd PCSTALL ED2P @1000ns"));
        // NaN accuracy renders as gnuplot's missing-data token
        assert!(text.contains("0 NaN"), "{text}");
        // the python twin defines nan before using it
        let py_text = String::from_utf8(py_bytes.clone()).unwrap();
        assert!(py_text.contains("nan = float(\"nan\")"));
        assert!(py_text.contains("(0, nan,"), "{py_text}");
        // re-emitting into another dir is byte-identical
        let sub = dir.join("again");
        let (gp2, py2) = emit_decision_timeline(&dir, Some(&sub)).unwrap();
        assert_eq!(std::fs::read(&gp2).unwrap(), gp_bytes);
        assert_eq!(std::fs::read(&py2).unwrap(), py_bytes);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
