//! `pcstall sweep plot`: figure-script emission from merged sweep CSVs.
//!
//! Takes the merged CSV a sweep plan wrote (`sweep_<name>.csv`, schema
//! [`crate::harness::sweep::SWEEP_HEADER`]), groups it by the plan's
//! axes, and emits two self-contained figure scripts next to it:
//!
//! * `<stem>_<metric>.gnuplot` — the data inlined as gnuplot
//!   datablocks, rendered with `gnuplot <file>`;
//! * `<stem>_<metric>.py` — a matplotlib fallback carrying the same
//!   aggregated data, rendered with `python3 <file>`.
//!
//! ## Grouping (axis inference)
//!
//! The **x axis** is whichever numeric grid axis actually varies in the
//! CSV — epoch length when the plan swept epochs, domain granularity
//! when it swept granularity (ties go to the epoch axis).  One **panel**
//! is emitted per (objective, value-of-the-other-axis), one **series**
//! per design, and the remaining population axes (`seed`, `workload`)
//! are aggregated per x position into mean / min / max — the
//! seed-population accuracy figure the ROADMAP calls for renders as a
//! mean line inside a min–max band over the seeds.
//!
//! ## Determinism
//!
//! Script bytes are a pure function of the CSV content: groups are
//! sorted (never hash-ordered), floats print at fixed precision, x
//! labels are carried verbatim from the CSV, and no timestamp, path, or
//! hostname leaks into the output.  Re-plotting the same CSV — in any
//! row order — is byte-identical, which CI gates on.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use crate::stats::emit::{sanitize_ident as ident, CsvTable};

/// Metric column plotted when `--metric` is not given.
pub const DEFAULT_METRIC: &str = "accuracy";

/// Grid-axis columns a sweep CSV must carry (the `seed` column is
/// optional so CSVs predating the seed axis still plot).
const AXIS_COLS: [&str; 5] = ["epoch_us", "cus_per_domain", "workload", "design", "objective"];

/// One aggregated x position of a series: the population's mean and
/// min–max envelope at that grid point.
#[derive(Debug, Clone, PartialEq)]
pub struct BandPoint {
    pub x: f64,
    /// The x cell verbatim from the CSV (emitted as-is — re-formatting
    /// floats could drift bytes between runs).
    pub x_label: String,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    /// Population size aggregated into this point.
    pub n: usize,
}

/// One design's line (+band) inside a panel.
#[derive(Debug, Clone)]
pub struct Series {
    pub design: String,
    pub points: Vec<BandPoint>,
}

/// One subplot: a fixed (objective, other-axis value) slice.
#[derive(Debug, Clone)]
pub struct Panel {
    pub objective: String,
    /// Value of the non-x grid axis this panel pins (`cus_per_domain`
    /// when x is the epoch axis, and vice versa).
    pub fixed: String,
    pub series: Vec<Series>,
}

/// A fully-aggregated figure: everything the script emitters need.
#[derive(Debug, Clone)]
pub struct PlotSpec {
    /// Sanitized CSV stem — becomes the script/png base name.
    pub name: String,
    pub metric: String,
    /// `epoch_us` or `cus_per_domain` (inferred).
    pub x_col: String,
    /// The pinned per-panel axis (the other one of the pair).
    pub panel_col: String,
    /// Population column the band aggregates over (`seed`, `workload`),
    /// empty when every group is a single run (degenerate band).
    pub band_over: Option<String>,
    /// Largest population aggregated into any one point.
    pub population: usize,
    pub panels: Vec<Panel>,
}


/// Fixed-precision float for script bytes (deterministic, locale-free).
fn num(v: f64) -> String {
    format!("{v:.6}")
}

/// Build the aggregated figure from a merged sweep CSV.
pub fn plot_spec(table: &CsvTable, name: &str, metric: &str) -> anyhow::Result<PlotSpec> {
    let col = |n: &str| table.col(n);
    for c in AXIS_COLS {
        anyhow::ensure!(
            col(c).is_some(),
            "not a sweep CSV: missing '{c}' column (header: {})",
            table.header.join(",")
        );
    }
    anyhow::ensure!(!table.rows.is_empty(), "sweep CSV has no data rows");
    anyhow::ensure!(
        !AXIS_COLS.contains(&metric) && metric != "seed",
        "'{metric}' is a grid axis, not a plottable metric"
    );
    let metric_idx = col(metric).ok_or_else(|| {
        // name the columns that would have worked
        let numeric: Vec<&str> = table
            .header
            .iter()
            .enumerate()
            .filter(|(i, h)| {
                !AXIS_COLS.contains(&h.as_str())
                    && h.as_str() != "seed"
                    && table.rows.iter().all(|r| r[*i].parse::<f64>().is_ok())
            })
            .map(|(_, h)| h.as_str())
            .collect();
        anyhow::anyhow!(
            "no '{metric}' column in the CSV; plottable metrics: {}",
            numeric.join(", ")
        )
    })?;

    let (epoch_idx, gran_idx) = (col("epoch_us").unwrap(), col("cus_per_domain").unwrap());
    let (wl_idx, design_idx) = (col("workload").unwrap(), col("design").unwrap());
    let obj_idx = col("objective").unwrap();
    let seed_idx = col("seed");

    let distinct = |idx: usize| {
        let mut vals: Vec<&str> = table.rows.iter().map(|r| r[idx].as_str()).collect();
        vals.sort_unstable();
        vals.dedup();
        vals.len()
    };
    // x = the grid axis that actually varies; ties go to the epoch axis
    // (the paper's canonical x).
    let (x_idx, panel_idx, x_col, panel_col) = if distinct(epoch_idx) >= distinct(gran_idx) {
        (epoch_idx, gran_idx, "epoch_us", "cus_per_domain")
    } else {
        (gran_idx, epoch_idx, "cus_per_domain", "epoch_us")
    };

    // (objective, panel value) -> design -> x label -> metric values.
    // String-keyed BTreeMaps give a deterministic build order; the real
    // (numeric-aware) ordering is applied on the sorted Vecs below.
    type XMap = std::collections::BTreeMap<String, Vec<f64>>;
    type SeriesMap = std::collections::BTreeMap<String, XMap>;
    let mut groups: std::collections::BTreeMap<(String, String), SeriesMap> =
        std::collections::BTreeMap::new();
    let mut band_cols: Vec<&str> = Vec::new();
    let mut seen_pop: Vec<(String, String)> = Vec::new(); // (seed, workload) pairs
    for (lineno, row) in table.rows.iter().enumerate() {
        let v: f64 = row[metric_idx].parse().map_err(|_| {
            anyhow::anyhow!(
                "row {}: '{}' is not a number in metric column '{metric}'",
                lineno + 2,
                row[metric_idx]
            )
        })?;
        let x: f64 = row[x_idx].parse().unwrap_or(f64::NAN);
        anyhow::ensure!(
            x.is_finite(),
            "row {}: bad {x_col} value '{}'",
            lineno + 2,
            row[x_idx]
        );
        seen_pop.push((
            seed_idx.map(|i| row[i].clone()).unwrap_or_default(),
            row[wl_idx].clone(),
        ));
        let vals = groups
            .entry((row[obj_idx].clone(), row[panel_idx].clone()))
            .or_default()
            .entry(row[design_idx].clone())
            .or_default()
            .entry(row[x_idx].clone())
            .or_default();
        // non-finite metric cells (a design that never predicts has NaN
        // accuracy) drop out of the band rather than poisoning it
        if v.is_finite() {
            vals.push(v);
        }
    }
    let varies = |f: fn(&(String, String)) -> &String| {
        let mut vals: Vec<&String> = seen_pop.iter().map(f).collect();
        vals.sort_unstable();
        vals.dedup();
        vals.len() > 1
    };
    if seed_idx.is_some() && varies(|p| &p.0) {
        band_cols.push("seed");
    } else if varies(|p| &p.1) {
        band_cols.push("workload");
    }

    let mut population = 0usize;
    let mut panels: Vec<Panel> = Vec::new();
    for ((objective, fixed), designs) in groups {
        let mut series: Vec<Series> = Vec::new();
        for (design, xs) in designs {
            let mut points: Vec<BandPoint> = Vec::new();
            for (x_label, vals) in xs {
                if vals.is_empty() {
                    continue; // every population member was non-finite
                }
                let (mut lo, mut hi, mut sum) = (f64::INFINITY, f64::NEG_INFINITY, 0.0);
                for &v in &vals {
                    lo = lo.min(v);
                    hi = hi.max(v);
                    sum += v;
                }
                population = population.max(vals.len());
                points.push(BandPoint {
                    x: x_label.parse().expect("validated above"),
                    x_label,
                    mean: sum / vals.len() as f64,
                    min: lo,
                    max: hi,
                    n: vals.len(),
                });
            }
            points.sort_by(|a, b| a.x.partial_cmp(&b.x).expect("finite x"));
            if !points.is_empty() {
                series.push(Series { design, points });
            }
        }
        if !series.is_empty() {
            panels.push(Panel {
                objective,
                fixed,
                series,
            });
        }
    }
    // numeric panel order (BTreeMap gave lexicographic: "16" < "2")
    panels.sort_by(|a, b| {
        a.objective.cmp(&b.objective).then(
            a.fixed
                .parse::<f64>()
                .unwrap_or(f64::MAX)
                .partial_cmp(&b.fixed.parse::<f64>().unwrap_or(f64::MAX))
                .expect("panel keys are finite or MAX"),
        )
    });
    anyhow::ensure!(
        !panels.is_empty(),
        "nothing to plot: every '{metric}' value in the CSV is non-finite"
    );
    Ok(PlotSpec {
        name: ident(name),
        metric: metric.to_string(),
        x_col: x_col.into(),
        panel_col: panel_col.into(),
        band_over: band_cols.first().map(|s| s.to_string()),
        population,
        panels,
    })
}

/// Grid layout: up to 3 panels per row.
fn layout(n: usize) -> (usize, usize) {
    let cols = n.clamp(1, 3);
    (n.div_ceil(cols), cols)
}

fn x_axis_label(x_col: &str) -> &'static str {
    match x_col {
        "cus_per_domain" => "CUs per V/f domain",
        _ => "epoch length (us)",
    }
}

fn panel_title(spec: &PlotSpec, p: &Panel) -> String {
    match spec.panel_col.as_str() {
        "cus_per_domain" => format!("{}, {} CU/domain", p.objective, p.fixed),
        _ => format!("{}, epoch {} us", p.objective, p.fixed),
    }
}

fn figure_title(spec: &PlotSpec) -> String {
    match &spec.band_over {
        Some(col) => format!(
            "{}: {} (band: min-max over {col}, n={})",
            spec.name, spec.metric, spec.population
        ),
        None => format!("{}: {}", spec.name, spec.metric),
    }
}

/// Render the self-contained gnuplot script.
pub fn render_gnuplot(spec: &PlotSpec) -> String {
    let (rows, cols) = layout(spec.panels.len());
    let (w, h) = (520 * cols, 390 * rows);
    let png = format!("{}_{}.png", spec.name, ident(&spec.metric));
    let mut out = String::new();
    let _ = writeln!(out, "# {} — generated by `pcstall sweep plot`", figure_title(spec));
    let _ = writeln!(out, "# render: gnuplot <this file>   (writes {png} into the cwd)");
    let _ = writeln!(out, "# columns: x mean min max n");
    let _ = writeln!(
        out,
        "if (strstrt(GPVAL_TERMINALS, \"pngcairo\") > 0) {{\n    set terminal pngcairo size {w},{h} font \"sans,10\" noenhanced\n}} else {{\n    set terminal png size {w},{h} noenhanced\n}}"
    );
    let _ = writeln!(out, "set output \"{png}\"");
    let _ = writeln!(
        out,
        "set multiplot layout {rows},{cols} title \"{}\"",
        figure_title(spec)
    );
    if spec.x_col == "cus_per_domain" {
        let _ = writeln!(out, "set logscale x 2");
    } else {
        let _ = writeln!(out, "set logscale x 10");
    }
    let _ = writeln!(out, "set xlabel \"{}\"", x_axis_label(&spec.x_col));
    let _ = writeln!(out, "set ylabel \"{}\"", spec.metric);
    let _ = writeln!(out, "set key bottom left");
    let _ = writeln!(out, "set grid");
    for (pi, panel) in spec.panels.iter().enumerate() {
        let _ = writeln!(out);
        // one datablock per series: x mean min max n (design named in
        // the plot clause title)
        for (si, s) in panel.series.iter().enumerate() {
            let _ = writeln!(out, "$p{pi}_s{si} << EOD");
            for pt in &s.points {
                let _ = writeln!(
                    out,
                    "{} {} {} {} {}",
                    pt.x_label,
                    num(pt.mean),
                    num(pt.min),
                    num(pt.max),
                    pt.n
                );
            }
            let _ = writeln!(out, "EOD");
        }
        let _ = writeln!(out, "set title \"{}\"", panel_title(spec, panel));
        let mut clauses: Vec<String> = Vec::new();
        for (si, s) in panel.series.iter().enumerate() {
            let lc = si + 1;
            clauses.push(format!(
                "$p{pi}_s{si} using 1:3:4 with filledcurves fs transparent solid 0.15 lc {lc} notitle"
            ));
            clauses.push(format!(
                "$p{pi}_s{si} using 1:2 with linespoints pt 7 lc {lc} title \"{}\"",
                s.design
            ));
        }
        let _ = writeln!(out, "plot {}", clauses.join(", \\\n     "));
    }
    let _ = writeln!(out, "\nunset multiplot");
    out
}

/// Render the matplotlib fallback script.
pub fn render_matplotlib(spec: &PlotSpec) -> String {
    let (rows, cols) = layout(spec.panels.len());
    let png = format!("{}_{}.png", spec.name, ident(&spec.metric));
    let mut out = String::new();
    let _ = writeln!(out, "#!/usr/bin/env python3");
    let _ = writeln!(out, "# {} — generated by `pcstall sweep plot`", figure_title(spec));
    let _ = writeln!(out, "# render: python3 <this file>   (writes {png} into the cwd)");
    let _ = writeln!(
        out,
        "# DATA: [(panel_title, [(design, [(x, mean, min, max, n), ...]), ...]), ...]"
    );
    let _ = writeln!(out, "DATA = [");
    for panel in &spec.panels {
        let _ = writeln!(out, "    (\"{}\", [", panel_title(spec, panel));
        for s in &panel.series {
            let _ = writeln!(out, "        (\"{}\", [", s.design);
            for pt in &s.points {
                let _ = writeln!(
                    out,
                    "            ({}, {}, {}, {}, {}),",
                    pt.x_label,
                    num(pt.mean),
                    num(pt.min),
                    num(pt.max),
                    pt.n
                );
            }
            let _ = writeln!(out, "        ]),");
        }
        let _ = writeln!(out, "    ]),");
    }
    let _ = writeln!(out, "]");
    let log_base = if spec.x_col == "cus_per_domain" { 2 } else { 10 };
    let _ = writeln!(
        out,
        r#"
def main():
    import matplotlib
    matplotlib.use("Agg")
    import matplotlib.pyplot as plt
    rows, cols = {rows}, {cols}
    fig, axes = plt.subplots(rows, cols, figsize=(5.2 * cols, 3.9 * rows), squeeze=False)
    for i, (title, series) in enumerate(DATA):
        ax = axes[i // cols][i % cols]
        for label, pts in series:
            xs = [p[0] for p in pts]
            ax.fill_between(xs, [p[2] for p in pts], [p[3] for p in pts], alpha=0.15)
            ax.plot(xs, [p[1] for p in pts], marker="o", label=label)
        ax.set_xscale("log", base={log_base})
        ax.set_title(title)
        ax.set_xlabel("{xlabel}")
        ax.set_ylabel("{metric}")
        ax.grid(True, alpha=0.4)
        ax.legend(loc="lower left")
    for j in range(len(DATA), rows * cols):
        axes[j // cols][j % cols].axis("off")
    fig.suptitle("{title}")
    fig.tight_layout()
    fig.savefig("{png}", dpi=150)
    print("wrote {png}")


if __name__ == "__main__":
    main()"#,
        rows = rows,
        cols = cols,
        log_base = log_base,
        xlabel = x_axis_label(&spec.x_col),
        metric = spec.metric,
        title = figure_title(spec),
        png = png,
    );
    out
}

/// Read `csv`, aggregate, and write the script pair.  Returns
/// `(gnuplot_path, matplotlib_path)`.  Scripts land next to the CSV
/// unless `out_dir` redirects them.
pub fn emit_plot_scripts(
    csv: &Path,
    metric: &str,
    out_dir: Option<&Path>,
) -> anyhow::Result<(PathBuf, PathBuf)> {
    let table = CsvTable::read(csv).map_err(anyhow::Error::msg)?;
    let stem = csv
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("sweep");
    let spec = plot_spec(&table, stem, metric)?;
    let dir = match out_dir {
        Some(d) => d.to_path_buf(),
        None => csv.parent().unwrap_or_else(|| Path::new(".")).to_path_buf(),
    };
    std::fs::create_dir_all(&dir)
        .map_err(|e| anyhow::anyhow!("creating {}: {e}", dir.display()))?;
    let base = format!("{}_{}", spec.name, ident(metric));
    let gp = dir.join(format!("{base}.gnuplot"));
    let py = dir.join(format!("{base}.py"));
    std::fs::write(&gp, render_gnuplot(&spec))
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", gp.display()))?;
    std::fs::write(&py, render_matplotlib(&spec))
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", py.display()))?;
    Ok((gp, py))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::sweep::SWEEP_HEADER;

    /// A seed-population CSV: 2 designs x 2 epochs x 3 seeds, 1 panel.
    fn population_table() -> CsvTable {
        let mut t = CsvTable::new(&SWEEP_HEADER);
        for (design, base) in [("crisp", 0.6), ("pcstall", 0.8)] {
            for (ei, epoch) in ["1", "10"].iter().enumerate() {
                for seed in 1..=3u64 {
                    let acc = base + 0.01 * seed as f64 - 0.05 * ei as f64;
                    t.push(vec![
                        epoch.to_string(),
                        "1".into(),
                        format!("synth:{seed}"),
                        seed.to_string(),
                        design.into(),
                        "ed2p".into(),
                        "12.00".into(),
                        "0.8800".into(),
                        "1.0000e-3".into(),
                        "0.0400".into(),
                        format!("{acc:.3}"),
                    ]);
                }
            }
        }
        t
    }

    #[test]
    fn aggregates_the_seed_population() {
        let spec = plot_spec(&population_table(), "sweep_pop", "accuracy").unwrap();
        assert_eq!(spec.x_col, "epoch_us");
        assert_eq!(spec.panel_col, "cus_per_domain");
        assert_eq!(spec.band_over.as_deref(), Some("seed"));
        assert_eq!(spec.population, 3);
        assert_eq!(spec.panels.len(), 1);
        let panel = &spec.panels[0];
        assert_eq!(panel.objective, "ed2p");
        assert_eq!(panel.fixed, "1");
        // series sorted by design name
        let designs: Vec<&str> = panel.series.iter().map(|s| s.design.as_str()).collect();
        assert_eq!(designs, vec!["crisp", "pcstall"]);
        // band stats at epoch 1 for crisp: 0.61, 0.62, 0.63
        let p = &panel.series[0].points[0];
        assert_eq!(p.x_label, "1");
        assert_eq!(p.n, 3);
        assert!((p.mean - 0.62).abs() < 1e-9, "{}", p.mean);
        assert!((p.min - 0.61).abs() < 1e-9);
        assert!((p.max - 0.63).abs() < 1e-9);
        // x sorted numerically
        assert!(panel.series[0].points[0].x < panel.series[0].points[1].x);
    }

    #[test]
    fn scripts_are_deterministic_and_row_order_independent() {
        let t = population_table();
        let spec = plot_spec(&t, "sweep_pop", "accuracy").unwrap();
        let (gp1, py1) = (render_gnuplot(&spec), render_matplotlib(&spec));
        // same CSV, reversed row order
        let mut rev = t.clone();
        rev.rows.reverse();
        let spec2 = plot_spec(&rev, "sweep_pop", "accuracy").unwrap();
        assert_eq!(gp1, render_gnuplot(&spec2));
        assert_eq!(py1, render_matplotlib(&spec2));
        // and a second render of the same spec is byte-identical
        assert_eq!(gp1, render_gnuplot(&spec));
        // the scripts are self-contained: datablocks inline, png named
        assert!(gp1.contains("$p0_s0 << EOD"));
        assert!(gp1.contains("set output \"sweep_pop_accuracy.png\""));
        assert!(gp1.contains("min-max over seed, n=3"));
        assert!(py1.contains("DATA = ["));
        assert!(py1.contains("sweep_pop_accuracy.png"));
    }

    #[test]
    fn infers_the_granularity_axis_when_epochs_are_pinned() {
        let mut t = CsvTable::new(&SWEEP_HEADER);
        for gran in ["1", "2", "4"] {
            t.push(vec![
                "1".into(),
                gran.into(),
                "comd".into(),
                "-".into(),
                "pcstall".into(),
                "ed2p".into(),
                "10.00".into(),
                "0.9000".into(),
                "1.0000e-3".into(),
                "0.0400".into(),
                "0.900".into(),
            ]);
        }
        let spec = plot_spec(&t, "sweep_gran", "improvement_pct").unwrap();
        assert_eq!(spec.x_col, "cus_per_domain");
        assert_eq!(spec.panel_col, "epoch_us");
        assert_eq!(spec.band_over, None, "single workload, no population");
        let gp = render_gnuplot(&spec);
        assert!(gp.contains("set logscale x 2"));
        assert!(gp.contains("CUs per V/f domain"));
    }

    #[test]
    fn panels_sort_numerically_not_lexicographically() {
        // 4 epochs vary more than 3 grans, so epoch is x and the
        // granularity values become panels — in numeric order
        let mut t = CsvTable::new(&SWEEP_HEADER);
        for gran in ["16", "2", "1"] {
            for epoch in ["1", "10", "50", "100"] {
                t.push(vec![
                    epoch.into(),
                    gran.into(),
                    "comd".into(),
                    "-".into(),
                    "pcstall".into(),
                    "ed2p".into(),
                    "10.00".into(),
                    "0.9000".into(),
                    "1.0000e-3".into(),
                    "0.0400".into(),
                    "0.900".into(),
                ]);
            }
        }
        let spec = plot_spec(&t, "s", "accuracy").unwrap();
        let fixed: Vec<&str> = spec.panels.iter().map(|p| p.fixed.as_str()).collect();
        assert_eq!(fixed, vec!["1", "2", "16"]);
    }

    #[test]
    fn non_finite_metric_cells_drop_out_of_the_band() {
        let mut t = population_table();
        // a static-like design that never predicts: all-NaN accuracy
        for epoch in ["1", "10"] {
            t.push(vec![
                epoch.into(),
                "1".into(),
                "synth:1".into(),
                "1".into(),
                "static-1.7".into(),
                "ed2p".into(),
                "0.00".into(),
                "1.0000".into(),
                "1.0000e-3".into(),
                "0.0400".into(),
                "NaN".into(),
            ]);
        }
        let spec = plot_spec(&t, "s", "accuracy").unwrap();
        let designs: Vec<&str> = spec.panels[0]
            .series
            .iter()
            .map(|s| s.design.as_str())
            .collect();
        assert_eq!(
            designs,
            vec!["crisp", "pcstall"],
            "all-NaN series must disappear, not plot as zeros"
        );
    }

    #[test]
    fn rejects_non_sweep_csvs_and_unknown_metrics() {
        let bogus = CsvTable::new(&["a", "b"]);
        assert!(plot_spec(&bogus, "x", "accuracy").is_err());

        let empty = CsvTable::new(&SWEEP_HEADER);
        assert!(plot_spec(&empty, "x", "accuracy").is_err());

        let err = plot_spec(&population_table(), "x", "nope")
            .unwrap_err()
            .to_string();
        assert!(err.contains("accuracy"), "should list metrics: {err}");

        let err = plot_spec(&population_table(), "x", "workload")
            .unwrap_err()
            .to_string();
        assert!(err.contains("axis"), "{err}");
    }

    #[test]
    fn emit_writes_the_script_pair() {
        let dir = std::env::temp_dir().join(format!("pcstall_plot_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let csv = dir.join("sweep_pop.csv");
        population_table().write(&csv).unwrap();
        let (gp, py) = emit_plot_scripts(&csv, DEFAULT_METRIC, None).unwrap();
        assert_eq!(gp, dir.join("sweep_pop_accuracy.gnuplot"));
        assert_eq!(py, dir.join("sweep_pop_accuracy.py"));
        let first = std::fs::read(&gp).unwrap();
        // re-emitting is byte-identical (the CI determinism gate)
        let sub = dir.join("again");
        let (gp2, _) = emit_plot_scripts(&csv, DEFAULT_METRIC, Some(&sub)).unwrap();
        assert_eq!(std::fs::read(&gp2).unwrap(), first);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
