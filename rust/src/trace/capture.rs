//! Record a workload's executed instruction stream to a [`Trace`].
//!
//! The simulator's dynamic behaviour is a pure function of the program
//! stream plus launch geometry: memory addresses and loop-trip
//! divergence are generated *statelessly* from `(wavefront id, pc,
//! access counter)` hashes (see [`crate::util::mix`]), so the per-kernel
//! records with their loop/barrier markers — together with waves-per-CU
//! and the round count — are a complete record of everything the GPU
//! will execute.  Replaying a capture therefore reproduces the original
//! run bit-for-bit (epoch instruction counts, energy, ED²P), which
//! `tests/trace_roundtrip.rs` asserts.
//!
//! Three capture points are provided: [`capture_workload`] records a
//! workload spec as dispatched, [`capture_gpu`] hooks a live simulator
//! and records whatever kernel queue is currently loaded, and
//! [`capture_recorded`] assembles the event stream of an instrumented
//! execution (the `workloads::exec` frontend) into a valid trace,
//! inserting the waitcnt discipline and loop pairing the format
//! requires.

use crate::sim::gpu::Gpu;
use crate::sim::isa::{Op, MAX_LOOP_DEPTH};
use crate::trace::format::{sanitize_name, sanitize_source, Trace, TraceKernel};
use crate::trace::ingest::{classify_pattern, normalize_waves, WAIT_EVERY};
use crate::workloads::WorkloadSpec;

/// Record a workload spec's full dispatch stream.
pub fn capture_workload(spec: &WorkloadSpec) -> Trace {
    let kernels = spec
        .kernels
        .iter()
        .enumerate()
        .map(|(i, k)| {
            let prog = k.lower(i as u32);
            TraceKernel {
                kernel_id: i as u32,
                name: sanitize_name(&k.name),
                waves_per_cu: k.waves_per_cu,
                records: prog.instrs.iter().map(|ins| ins.op).collect(),
            }
        })
        .collect();
    Trace {
        name: sanitize_name(&spec.name),
        source: format!("capture:{}", spec.name),
        rounds: spec.rounds,
        kernels,
    }
}

/// Record the kernel queue loaded into a live simulator.  Call before
/// stepping epochs: the round counter reflects rounds *remaining*.
pub fn capture_gpu(gpu: &Gpu, name: &str) -> Trace {
    let kernels = gpu
        .loaded_kernels()
        .iter()
        .map(|launch| TraceKernel {
            kernel_id: launch.program.kernel_id,
            name: sanitize_name(&launch.program.name),
            waves_per_cu: launch.waves_per_cu,
            records: launch.program.instrs.iter().map(|ins| ins.op).collect(),
        })
        .collect();
    let name = sanitize_name(name);
    Trace {
        source: format!("capture:{name}"),
        name,
        rounds: gpu.loaded_rounds().max(1),
        kernels,
    }
}

/// Record a catalog workload by name at a given length multiplier.
pub fn capture_named(name: &str, waves: f64) -> anyhow::Result<Trace> {
    anyhow::ensure!(
        crate::workloads::names().iter().any(|n| *n == name),
        "unknown workload '{name}' (see `pcstall list`)"
    );
    let mut t = capture_workload(&crate::workloads::build(name, waves));
    t.source = format!("capture:{name}@waves={waves}");
    Ok(t)
}

/// One event recorded by an instrumented execution.  The stream is the
/// *representative wavefront's* first pass through the kernel: loop
/// bodies are recorded once with their executed trip counts, memory
/// events reference a static site (so classification can pool address
/// observations across every execution of that site), and arithmetic is
/// recorded per warp-wide operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecEvent {
    /// Arithmetic: a vector op of `cycles` issue cost, or a scalar op.
    Alu { vector: bool, cycles: u8 },
    /// A warp memory access at static site `site` (index into the
    /// kernel's site table); `fan` = distinct 64-byte lines the lanes
    /// touched on the recorded execution.
    Mem { store: bool, site: u32, fan: u8 },
    Barrier,
    /// Loop prologue (`trips` = executed iterations); nesting depth and
    /// the back-edge target are derived during assembly.
    LoopBegin { trips: u16 },
    LoopEnd,
}

/// Classification summary of one static memory site, pooled over every
/// execution the recorder observed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemSite {
    pub region: u8,
    /// Inferred per-access address advance in bytes (already clamped to
    /// the 4..=4096 range the classifier expects).
    pub stride: u32,
    /// Footprint of the backing allocation in bytes.
    pub working_set: u32,
}

/// One kernel's recorded stream plus its launch geometry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RecordedKernel {
    pub name: String,
    /// Total 64-lane wavefronts the launch covers (normalized to
    /// waves-per-CU during assembly).
    pub total_waves: u64,
    pub events: Vec<RecEvent>,
    pub sites: Vec<MemSite>,
}

/// Assemble recorded kernel streams into a validated [`Trace`].
///
/// The assembly owns the format's structural obligations so recorders
/// don't have to: memory runs are bounded by inserting `waitcnt 16`
/// every [`WAIT_EVERY`] memory ops, outstanding memory is drained
/// (`waitcnt 0`) before barriers, loop back-edges, and program end, and
/// loop markers are paired with their depth and target derived from the
/// open-loop stack.
pub fn capture_recorded(
    name: &str,
    source: &str,
    recorded: &[RecordedKernel],
) -> anyhow::Result<Trace> {
    anyhow::ensure!(!recorded.is_empty(), "capture_recorded: no kernels");
    let kernels = recorded
        .iter()
        .enumerate()
        .map(|(i, k)| assemble_recorded(k, i as u32))
        .collect::<anyhow::Result<Vec<_>>>()?;
    let trace = Trace {
        name: sanitize_name(name),
        source: sanitize_source(source),
        rounds: 1,
        kernels,
    };
    trace
        .validate()
        .map_err(|e| anyhow::anyhow!("recorded trace '{name}' invalid: {e}"))?;
    Ok(trace)
}

fn assemble_recorded(k: &RecordedKernel, kernel_id: u32) -> anyhow::Result<TraceKernel> {
    let mut out: Vec<Op> = Vec::with_capacity(k.events.len() + 8);
    let mut mem_run = 0usize;
    // pc of each open LoopBegin; stack depth = loop nesting depth
    let mut open: Vec<u32> = Vec::new();
    fn drain(out: &mut Vec<Op>, mem_run: &mut usize) {
        if *mem_run > 0 {
            out.push(Op::WaitCnt { max: 0 });
            *mem_run = 0;
        }
    }
    for (i, ev) in k.events.iter().enumerate() {
        match *ev {
            RecEvent::Alu { vector: true, cycles } => out.push(Op::VAlu { cycles: cycles.max(1) }),
            RecEvent::Alu { vector: false, .. } => out.push(Op::SAlu),
            RecEvent::Mem { store, site, fan } => {
                let s = k.sites.get(site as usize).ok_or_else(|| {
                    anyhow::anyhow!("kernel {}: event {i} references unknown site {site}", k.name)
                })?;
                let pattern = classify_pattern(s.region, s.stride, s.working_set);
                let fan = fan.clamp(1, 16);
                out.push(if store {
                    Op::Store { pattern, fan }
                } else {
                    Op::Load { pattern, fan }
                });
                mem_run += 1;
                if mem_run >= WAIT_EVERY {
                    out.push(Op::WaitCnt { max: 16 });
                    mem_run = 0;
                }
            }
            RecEvent::Barrier => {
                drain(&mut out, &mut mem_run);
                out.push(Op::Barrier);
            }
            RecEvent::LoopBegin { trips } => {
                anyhow::ensure!(
                    open.len() < MAX_LOOP_DEPTH,
                    "kernel {}: loop nesting exceeds depth {MAX_LOOP_DEPTH}",
                    k.name
                );
                open.push(out.len() as u32);
                out.push(Op::LoopBegin {
                    depth: open.len() as u8 - 1,
                    trips: trips.max(1),
                    divergence: 0,
                });
            }
            RecEvent::LoopEnd => {
                let begin = open.pop().ok_or_else(|| {
                    anyhow::anyhow!("kernel {}: event {i}: LoopEnd without open loop", k.name)
                })?;
                anyhow::ensure!(
                    out.len() as u32 > begin + 1,
                    "kernel {}: empty loop body at pc {begin}",
                    k.name
                );
                // a loop body that issued memory must drain inside the
                // body (the format bounds outstanding memory per trip)
                drain(&mut out, &mut mem_run);
                out.push(Op::LoopEnd {
                    depth: open.len() as u8,
                    target: begin + 1,
                });
            }
        }
    }
    anyhow::ensure!(
        open.is_empty(),
        "kernel {}: {} unterminated loop(s)",
        k.name,
        open.len()
    );
    drain(&mut out, &mut mem_run);
    out.push(Op::EndPgm);
    Ok(TraceKernel {
        kernel_id,
        name: sanitize_name(&k.name),
        waves_per_cu: normalize_waves(k.total_waves),
        records: out,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::workloads;

    #[test]
    fn every_catalog_workload_captures_to_a_valid_trace() {
        for name in workloads::names() {
            let t = capture_workload(&workloads::build(name, 0.1));
            t.validate()
                .unwrap_or_else(|e| panic!("capture of {name} invalid: {e}"));
            assert_eq!(t.name, name);
        }
    }

    #[test]
    fn capture_preserves_programs_exactly() {
        let spec = workloads::build("dgemm", 0.1);
        let t = capture_workload(&spec);
        let direct = spec.launches();
        let replay = t.launches_scaled(1.0);
        assert_eq!(t.rounds, spec.rounds);
        assert_eq!(direct.len(), replay.len());
        for (d, r) in direct.iter().zip(&replay) {
            assert_eq!(d.waves_per_cu, r.waves_per_cu);
            assert_eq!(*d.program, *r.program);
        }
    }

    #[test]
    fn capture_gpu_matches_capture_workload() {
        let spec = workloads::build("comd", 0.1);
        let mut gpu = Gpu::new(SimConfig::small());
        gpu.load_workload(spec.launches(), spec.rounds);
        let live = capture_gpu(&gpu, "comd");
        let offline = capture_workload(&spec);
        assert_eq!(live.kernels, offline.kernels);
        assert_eq!(live.rounds, offline.rounds);
    }

    #[test]
    fn capture_dyn_count_matches_spec_accounting() {
        let spec = workloads::build("hacc", 1.0);
        let t = capture_workload(&spec);
        for (k, tk) in spec.kernels.iter().zip(&t.kernels) {
            assert_eq!(
                crate::trace::format::dyn_instrs_per_wave(&tk.records) as usize,
                k.dyn_instrs_per_wave(),
                "kernel {}",
                tk.name
            );
        }
    }

    #[test]
    fn capture_named_rejects_unknown() {
        assert!(capture_named("nope", 1.0).is_err());
        assert!(capture_named("comd", 0.1).is_ok());
    }

    fn site() -> MemSite {
        MemSite { region: 1, stride: 64, working_set: 1 << 20 }
    }

    #[test]
    fn recorded_stream_assembles_with_waitcnt_discipline() {
        let k = RecordedKernel {
            name: "rec".into(),
            total_waves: 128,
            events: vec![
                RecEvent::Alu { vector: true, cycles: 4 },
                RecEvent::LoopBegin { trips: 10 },
                RecEvent::Mem { store: false, site: 0, fan: 4 },
                RecEvent::Alu { vector: false, cycles: 0 },
                RecEvent::Mem { store: true, site: 0, fan: 1 },
                RecEvent::LoopEnd,
                RecEvent::Barrier,
            ],
            sites: vec![site()],
        };
        let t = capture_recorded("rec", "exec:rec:1", &[k]).unwrap();
        let ops = &t.kernels[0].records;
        use Op::*;
        assert!(matches!(ops[0], VAlu { cycles: 4 }));
        assert!(matches!(ops[1], LoopBegin { depth: 0, trips: 10, .. }));
        assert!(matches!(ops[2], Load { fan: 4, .. }));
        assert!(matches!(ops[3], SAlu));
        assert!(matches!(ops[4], Store { fan: 1, .. }));
        // body issued memory: drained inside the body before the back-edge
        assert!(matches!(ops[5], WaitCnt { max: 0 }));
        assert!(matches!(ops[6], LoopEnd { depth: 0, target: 2 }));
        assert!(matches!(ops[7], Barrier));
        assert!(matches!(ops[8], EndPgm));
        // 128 total waves on 64 CUs = 2 per CU
        assert_eq!(t.kernels[0].waves_per_cu, 2);
    }

    #[test]
    fn long_recorded_mem_runs_are_bounded() {
        let k = RecordedKernel {
            name: "runs".into(),
            total_waves: 64,
            events: (0..40)
                .map(|_| RecEvent::Mem { store: false, site: 0, fan: 16 })
                .collect(),
            sites: vec![site()],
        };
        let t = capture_recorded("runs", "exec:runs:1", &[k]).unwrap();
        let waits = t.kernels[0]
            .records
            .iter()
            .filter(|op| matches!(op, Op::WaitCnt { .. }))
            .count();
        // 40 loads: waitcnt 16 at 16 and 32, drain before endpgm
        assert_eq!(waits, 3);
    }

    #[test]
    fn recorded_random_sites_classify_random() {
        let k = RecordedKernel {
            name: "gather".into(),
            total_waves: 64,
            events: vec![RecEvent::Mem { store: false, site: 0, fan: 16 }],
            sites: vec![MemSite { region: 2, stride: 4096, working_set: 1 << 22 }],
        };
        let t = capture_recorded("gather", "exec:gather:1", &[k]).unwrap();
        assert!(matches!(
            t.kernels[0].records[0],
            Op::Load { pattern: crate::sim::isa::Pattern::Random { .. }, .. }
        ));
    }

    #[test]
    fn recorded_stream_structural_errors() {
        let bad_site = RecordedKernel {
            name: "k".into(),
            total_waves: 1,
            events: vec![RecEvent::Mem { store: false, site: 9, fan: 1 }],
            sites: vec![site()],
        };
        assert!(capture_recorded("k", "exec:k:1", &[bad_site]).is_err());
        let unbalanced = RecordedKernel {
            name: "k".into(),
            total_waves: 1,
            events: vec![RecEvent::LoopBegin { trips: 2 }],
            sites: vec![],
        };
        assert!(capture_recorded("k", "exec:k:1", &[unbalanced]).is_err());
        let stray_end = RecordedKernel {
            name: "k".into(),
            total_waves: 1,
            events: vec![RecEvent::LoopEnd],
            sites: vec![],
        };
        assert!(capture_recorded("k", "exec:k:1", &[stray_end]).is_err());
        let empty_body = RecordedKernel {
            name: "k".into(),
            total_waves: 1,
            events: vec![RecEvent::LoopBegin { trips: 2 }, RecEvent::LoopEnd],
            sites: vec![],
        };
        assert!(capture_recorded("k", "exec:k:1", &[empty_body]).is_err());
        assert!(capture_recorded("empty", "exec:e:1", &[]).is_err());
    }
}
