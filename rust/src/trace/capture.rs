//! Record a workload's executed instruction stream to a [`Trace`].
//!
//! The simulator's dynamic behaviour is a pure function of the program
//! stream plus launch geometry: memory addresses and loop-trip
//! divergence are generated *statelessly* from `(wavefront id, pc,
//! access counter)` hashes (see [`crate::util::mix`]), so the per-kernel
//! records with their loop/barrier markers — together with waves-per-CU
//! and the round count — are a complete record of everything the GPU
//! will execute.  Replaying a capture therefore reproduces the original
//! run bit-for-bit (epoch instruction counts, energy, ED²P), which
//! `tests/trace_roundtrip.rs` asserts.
//!
//! Two capture points are provided: [`capture_workload`] records a
//! workload spec as dispatched, and [`capture_gpu`] hooks a live
//! simulator and records whatever kernel queue is currently loaded.

use crate::sim::gpu::Gpu;
use crate::trace::format::{sanitize_name, Trace, TraceKernel};
use crate::workloads::WorkloadSpec;

/// Record a workload spec's full dispatch stream.
pub fn capture_workload(spec: &WorkloadSpec) -> Trace {
    let kernels = spec
        .kernels
        .iter()
        .enumerate()
        .map(|(i, k)| {
            let prog = k.lower(i as u32);
            TraceKernel {
                kernel_id: i as u32,
                name: sanitize_name(&k.name),
                waves_per_cu: k.waves_per_cu,
                records: prog.instrs.iter().map(|ins| ins.op).collect(),
            }
        })
        .collect();
    Trace {
        name: sanitize_name(&spec.name),
        source: format!("capture:{}", spec.name),
        rounds: spec.rounds,
        kernels,
    }
}

/// Record the kernel queue loaded into a live simulator.  Call before
/// stepping epochs: the round counter reflects rounds *remaining*.
pub fn capture_gpu(gpu: &Gpu, name: &str) -> Trace {
    let kernels = gpu
        .loaded_kernels()
        .iter()
        .map(|launch| TraceKernel {
            kernel_id: launch.program.kernel_id,
            name: sanitize_name(&launch.program.name),
            waves_per_cu: launch.waves_per_cu,
            records: launch.program.instrs.iter().map(|ins| ins.op).collect(),
        })
        .collect();
    let name = sanitize_name(name);
    Trace {
        source: format!("capture:{name}"),
        name,
        rounds: gpu.loaded_rounds().max(1),
        kernels,
    }
}

/// Record a catalog workload by name at a given length multiplier.
pub fn capture_named(name: &str, waves: f64) -> anyhow::Result<Trace> {
    anyhow::ensure!(
        crate::workloads::names().iter().any(|n| *n == name),
        "unknown workload '{name}' (see `pcstall list`)"
    );
    let mut t = capture_workload(&crate::workloads::build(name, waves));
    t.source = format!("capture:{name}@waves={waves}");
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::workloads;

    #[test]
    fn every_catalog_workload_captures_to_a_valid_trace() {
        for name in workloads::names() {
            let t = capture_workload(&workloads::build(name, 0.1));
            t.validate()
                .unwrap_or_else(|e| panic!("capture of {name} invalid: {e}"));
            assert_eq!(t.name, name);
        }
    }

    #[test]
    fn capture_preserves_programs_exactly() {
        let spec = workloads::build("dgemm", 0.1);
        let t = capture_workload(&spec);
        let direct = spec.launches();
        let replay = t.launches_scaled(1.0);
        assert_eq!(t.rounds, spec.rounds);
        assert_eq!(direct.len(), replay.len());
        for (d, r) in direct.iter().zip(&replay) {
            assert_eq!(d.waves_per_cu, r.waves_per_cu);
            assert_eq!(*d.program, *r.program);
        }
    }

    #[test]
    fn capture_gpu_matches_capture_workload() {
        let spec = workloads::build("comd", 0.1);
        let mut gpu = Gpu::new(SimConfig::small());
        gpu.load_workload(spec.launches(), spec.rounds);
        let live = capture_gpu(&gpu, "comd");
        let offline = capture_workload(&spec);
        assert_eq!(live.kernels, offline.kernels);
        assert_eq!(live.rounds, offline.rounds);
    }

    #[test]
    fn capture_dyn_count_matches_spec_accounting() {
        let spec = workloads::build("hacc", 1.0);
        let t = capture_workload(&spec);
        for (k, tk) in spec.kernels.iter().zip(&t.kernels) {
            assert_eq!(
                crate::trace::format::dyn_instrs_per_wave(&tk.records) as usize,
                k.dyn_instrs_per_wave(),
                "kernel {}",
                tk.name
            );
        }
    }

    #[test]
    fn capture_named_rejects_unknown() {
        assert!(capture_named("nope", 1.0).is_err());
        assert!(capture_named("comd", 0.1).is_ok());
    }
}
