//! Structural comparison of two traces.
//!
//! `pcstall trace diff <a> <b>` aligns kernels by position and compares
//! each pair on three axes: opcode mix (the [`KernelStats`] counters),
//! a stride histogram of memory ops (power-of-two buckets plus a
//! `random` bucket), and lengths (static records, dynamic instructions
//! per wave, waves per CU).  The rendering ends with a greppable
//! `divergent: N` summary line — `0` means structurally identical
//! streams, which is how CI asserts `exec:` lowering determinism.

use std::collections::BTreeMap;

use crate::sim::isa::{Op, Pattern};
use crate::trace::format::{KernelStats, Trace, TraceKernel};

/// Bucket label for one memory op's access pattern.
fn stride_bucket(op: &Op) -> Option<String> {
    let pattern = match op {
        Op::Load { pattern, .. } | Op::Store { pattern, .. } => pattern,
        _ => return None,
    };
    Some(match pattern {
        Pattern::Random { .. } => "random".to_string(),
        Pattern::Strided { stride, .. } => {
            format!("<={}", stride.next_power_of_two().max(4))
        }
    })
}

/// Stride histogram of a kernel's memory ops: bucket label -> count.
fn stride_histogram(k: &TraceKernel) -> BTreeMap<String, usize> {
    let mut h = BTreeMap::new();
    for op in &k.records {
        if let Some(b) = stride_bucket(op) {
            *h.entry(b).or_insert(0) += 1;
        }
    }
    h
}

/// Comparison of one aligned kernel pair (or an unpaired extra).
pub struct KernelDiff {
    pub index: usize,
    pub a_name: Option<String>,
    pub b_name: Option<String>,
    /// Human-readable mismatch axes; empty = structurally identical.
    pub mismatches: Vec<String>,
    lines: Vec<String>,
}

/// Full diff of two traces.
pub struct TraceDiff {
    pub kernels: Vec<KernelDiff>,
    pub rounds: (u32, u32),
    /// Divergent kernel pairs + unpaired extras + a rounds mismatch.
    pub divergent: usize,
}

fn fmt_stats(a: &KernelStats, b: &KernelStats) -> (String, bool) {
    let fields = [
        ("valu", a.valu, b.valu),
        ("salu", a.salu, b.salu),
        ("load", a.loads, b.loads),
        ("store", a.stores, b.stores),
        ("wait", a.waitcnts, b.waitcnts),
        ("barrier", a.barriers, b.barriers),
        ("loop", a.loops, b.loops),
    ];
    let mut same = true;
    let mut parts = Vec::new();
    for (name, x, y) in fields {
        if x == y {
            parts.push(format!("{name} {x}"));
        } else {
            same = false;
            parts.push(format!("{name} {x}->{y}"));
        }
    }
    (parts.join(" "), same)
}

fn fmt_hist(a: &BTreeMap<String, usize>, b: &BTreeMap<String, usize>) -> (String, bool) {
    let mut keys: Vec<&String> = a.keys().chain(b.keys()).collect();
    keys.sort();
    keys.dedup();
    if keys.is_empty() {
        return ("(no memory ops)".to_string(), true);
    }
    let mut same = true;
    let mut parts = Vec::new();
    for k in keys {
        let (x, y) = (a.get(k).copied().unwrap_or(0), b.get(k).copied().unwrap_or(0));
        if x == y {
            parts.push(format!("{k}:{x}"));
        } else {
            same = false;
            parts.push(format!("{k}:{x}->{y}"));
        }
    }
    (parts.join(" "), same)
}

fn diff_pair(index: usize, a: &TraceKernel, b: &TraceKernel) -> KernelDiff {
    let mut mismatches = Vec::new();
    let mut lines = Vec::new();
    if a.name != b.name {
        mismatches.push("name".to_string());
    }
    let (mix, mix_same) = fmt_stats(&a.stats(), &b.stats());
    if !mix_same {
        mismatches.push("opcode mix".to_string());
    }
    lines.push(format!("  opcode mix : {mix}"));
    let (hist, hist_same) = fmt_hist(&stride_histogram(a), &stride_histogram(b));
    if !hist_same {
        mismatches.push("stride histogram".to_string());
    }
    lines.push(format!("  strides    : {hist}"));
    let (sa, sb) = (a.stats(), b.stats());
    let lens = [
        ("static", sa.static_records as u64, sb.static_records as u64),
        ("dyn/wave", sa.dyn_per_wave, sb.dyn_per_wave),
        ("waves/cu", a.waves_per_cu, b.waves_per_cu),
    ];
    let mut len_parts = Vec::new();
    let mut len_same = true;
    for (name, x, y) in lens {
        if x == y {
            len_parts.push(format!("{name} {x}"));
        } else {
            len_same = false;
            len_parts.push(format!("{name} {x}->{y}"));
        }
    }
    if !len_same {
        mismatches.push("length".to_string());
    }
    lines.push(format!("  length     : {}", len_parts.join(" ")));
    KernelDiff {
        index,
        a_name: Some(a.name.clone()),
        b_name: Some(b.name.clone()),
        mismatches,
        lines,
    }
}

/// Compare two traces kernel-by-kernel (aligned by position).
pub fn diff(a: &Trace, b: &Trace) -> TraceDiff {
    let n = a.kernels.len().max(b.kernels.len());
    let mut kernels = Vec::with_capacity(n);
    for i in 0..n {
        match (a.kernels.get(i), b.kernels.get(i)) {
            (Some(ka), Some(kb)) => kernels.push(diff_pair(i, ka, kb)),
            (Some(ka), None) => kernels.push(KernelDiff {
                index: i,
                a_name: Some(ka.name.clone()),
                b_name: None,
                mismatches: vec!["only in a".to_string()],
                lines: Vec::new(),
            }),
            (None, Some(kb)) => kernels.push(KernelDiff {
                index: i,
                a_name: None,
                b_name: Some(kb.name.clone()),
                mismatches: vec!["only in b".to_string()],
                lines: Vec::new(),
            }),
            (None, None) => unreachable!(),
        }
    }
    let mut divergent = kernels.iter().filter(|k| !k.mismatches.is_empty()).count();
    if a.rounds != b.rounds {
        divergent += 1;
    }
    TraceDiff { kernels, rounds: (a.rounds, b.rounds), divergent }
}

impl TraceDiff {
    /// Render the human-facing report; the final line is always
    /// `divergent: N`.
    pub fn render(&self, a_label: &str, b_label: &str) -> String {
        let mut out = String::new();
        out.push_str(&format!("trace diff: {a_label} vs {b_label}\n"));
        for k in &self.kernels {
            let names = match (&k.a_name, &k.b_name) {
                (Some(a), Some(b)) if a == b => format!("'{a}'"),
                (Some(a), Some(b)) => format!("'{a}' vs '{b}'"),
                (Some(a), None) => format!("'{a}' (only in a)"),
                (None, Some(b)) => format!("'{b}' (only in b)"),
                (None, None) => String::new(),
            };
            let verdict = if k.mismatches.is_empty() {
                "identical".to_string()
            } else {
                format!("DIVERGES: {}", k.mismatches.join(", "))
            };
            out.push_str(&format!("kernel {} {names}: {verdict}\n", k.index));
            for l in &k.lines {
                out.push_str(l);
                out.push('\n');
            }
        }
        if self.rounds.0 == self.rounds.1 {
            out.push_str(&format!("rounds: {}\n", self.rounds.0));
        } else {
            out.push_str(&format!("rounds: {} -> {} (DIVERGES)\n", self.rounds.0, self.rounds.1));
        }
        out.push_str(&format!("divergent: {}\n", self.divergent));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::capture::capture_workload;

    #[test]
    fn self_diff_is_zero_divergent() {
        let t = capture_workload(&crate::workloads::build("dgemm", 0.05));
        let d = diff(&t, &t);
        assert_eq!(d.divergent, 0);
        let text = d.render("a", "b");
        assert!(text.ends_with("divergent: 0\n"), "{text}");
        assert!(text.contains("identical"));
    }

    #[test]
    fn structural_changes_are_counted_and_named() {
        // two-kernel trace, so dropping one leaves an unpaired extra
        let t = crate::workloads::exec::lower("reduce", 4096).unwrap();
        let mut edited = t.clone();
        edited.kernels[0].waves_per_cu += 1;
        edited.kernels.pop();
        edited.rounds += 1;
        let d = diff(&t, &edited);
        // kernel 0 length mismatch + one unpaired kernel + rounds
        assert_eq!(d.divergent, 3, "{}", d.render("a", "b"));
        let text = d.render("a", "b");
        assert!(text.contains("DIVERGES: length"));
        assert!(text.contains("only in a"));
        assert!(text.ends_with("divergent: 3\n"));
    }

    #[test]
    fn different_workloads_diverge_on_mix() {
        let a = capture_workload(&crate::workloads::build("dgemm", 0.05));
        let b = capture_workload(&crate::workloads::build("comd", 0.05));
        let d = diff(&a, &b);
        assert!(d.divergent > 0);
    }

    #[test]
    fn exec_lowerings_self_compare_clean() {
        let a = crate::workloads::exec::lower("stencil2d", 128).unwrap();
        let b = crate::workloads::exec::lower("stencil2d", 128).unwrap();
        assert_eq!(diff(&a, &b).divergent, 0);
        let c = crate::workloads::exec::lower("stencil2d", 256).unwrap();
        assert!(diff(&a, &c).divergent > 0, "size change must show up");
    }
}
