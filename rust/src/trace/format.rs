//! Versioned wavefront instruction-trace format.
//!
//! A trace is a complete, self-contained description of a workload's
//! executed instruction streams: per-kernel records (PC, op kind,
//! latency/pattern/fan, loop and barrier markers) plus the launch
//! geometry (waves per CU, kernel rounds).  Because the simulator's
//! dynamic behaviour is a pure function of this information — addresses
//! and loop-trip divergence are generated statelessly from
//! `(wavefront id, pc, counter)` hashes — replaying a trace reproduces
//! the recorded run bit-for-bit.
//!
//! Two on-disk encodings share one in-memory model:
//!
//! * a **text form** (`#pcstall-trace v1` header) for hand-authoring and
//!   diffing — one record per line, `#` comments, optional explicit PCs;
//! * a **binary form** (`PCSTRCv1` magic) with length-prefixed strings
//!   and record vectors, for scale.
//!
//! [`Trace::decode`] sniffs the magic and accepts either.  All decode
//! paths validate structurally (loop nesting, backward targets,
//! terminating `endpgm`, bounded outstanding-memory runs) and fail with
//! a positioned error — never a panic — on corrupt or truncated input.

use std::fmt::Write as _;
use std::path::Path;
use std::sync::Arc;

use anyhow::Context as _;

use crate::exec::key::fnv1a128_hex;
use crate::sim::gpu::KernelLaunch;
use crate::sim::isa::{Instr, Op, Pattern, Program, MAX_LOOP_DEPTH};

/// Bump when the record encoding or its simulator semantics change.
pub const TRACE_FORMAT_VERSION: u32 = 1;

/// First line of the text encoding.
pub const TEXT_HEADER: &str = "#pcstall-trace v1";

/// Magic prefix of the binary encoding.
pub const BIN_MAGIC: &[u8; 8] = b"PCSTRCv1";

/// Sanity caps: decode fails (rather than allocating absurdly) past these.
pub const MAX_KERNELS: usize = 4096;
pub const MAX_RECORDS_PER_KERNEL: usize = 1 << 20;
const MAX_NAME_LEN: usize = 256;

/// Maximum memory ops allowed without an intervening `s_waitcnt`: the
/// per-wavefront outstanding counters are `u8`, so an unbounded run of
/// loads could overflow them mid-simulation.
pub const MAX_MEM_RUN: usize = 64;

/// One kernel's recorded stream.  A record's PC is its index; the stream
/// must terminate with [`Op::EndPgm`].
#[derive(Debug, Clone, PartialEq)]
pub struct TraceKernel {
    pub kernel_id: u32,
    pub name: String,
    pub waves_per_cu: u64,
    pub records: Vec<Op>,
}

/// A trace: named kernel streams cycled `rounds` times.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    pub name: String,
    /// Provenance tag: `capture:<workload>`, `synth:seed=<s>`,
    /// `ingest:<file>`, or `hand`.
    pub source: String,
    pub rounds: u32,
    pub kernels: Vec<TraceKernel>,
}

/// Aggregate shape of one kernel stream (`pcstall trace info`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KernelStats {
    pub static_records: usize,
    /// Dynamic instructions per wavefront at mean loop trip counts.
    pub dyn_per_wave: u64,
    pub valu: usize,
    pub salu: usize,
    pub loads: usize,
    pub stores: usize,
    pub waitcnts: usize,
    pub barriers: usize,
    pub loops: usize,
}

impl TraceKernel {
    /// Reconstruct the executable [`Program`] this stream describes.
    pub fn to_program(&self) -> Program {
        Program {
            kernel_id: self.kernel_id,
            name: self.name.clone(),
            instrs: self.records.iter().map(|&op| Instr::from(op)).collect(),
        }
    }

    /// Structural validation: program-level checks plus the trace-specific
    /// outstanding-memory bound.
    pub fn validate(&self) -> Result<(), String> {
        check_name(&self.name).map_err(|e| format!("kernel {}: {e}", self.kernel_id))?;
        if self.waves_per_cu == 0 {
            return Err(format!("kernel {}: waves_per_cu must be >= 1", self.name));
        }
        if self.records.len() > MAX_RECORDS_PER_KERNEL {
            return Err(format!(
                "kernel {}: {} records exceeds the {} cap",
                self.name,
                self.records.len(),
                MAX_RECORDS_PER_KERNEL
            ));
        }
        self.to_program()
            .validate()
            .map_err(|e| format!("kernel {}: {e}", self.name))?;
        check_loops(&self.records).map_err(|e| format!("kernel {}: {e}", self.name))?;
        check_mem_runs(&self.records).map_err(|e| format!("kernel {}: {e}", self.name))
    }

    pub fn stats(&self) -> KernelStats {
        let mut s = KernelStats {
            static_records: self.records.len(),
            dyn_per_wave: dyn_instrs_per_wave(&self.records),
            ..KernelStats::default()
        };
        for op in &self.records {
            match op {
                Op::VAlu { .. } => s.valu += 1,
                Op::SAlu => s.salu += 1,
                Op::Load { .. } => s.loads += 1,
                Op::Store { .. } => s.stores += 1,
                Op::WaitCnt { .. } => s.waitcnts += 1,
                Op::Barrier => s.barriers += 1,
                Op::LoopBegin { .. } => s.loops += 1,
                Op::LoopEnd { .. } | Op::EndPgm => {}
            }
        }
        s
    }
}

impl Trace {
    /// Whole-trace validation (applied by every decode path).
    pub fn validate(&self) -> Result<(), String> {
        check_name(&self.name).map_err(|e| format!("trace name: {e}"))?;
        check_source(&self.source)?;
        if self.rounds == 0 {
            return Err("rounds must be >= 1".into());
        }
        if self.kernels.is_empty() {
            return Err("trace has no kernels".into());
        }
        if self.kernels.len() > MAX_KERNELS {
            return Err(format!(
                "{} kernels exceeds the {} cap",
                self.kernels.len(),
                MAX_KERNELS
            ));
        }
        for k in &self.kernels {
            k.validate()?;
        }
        Ok(())
    }

    /// Lower to the launch list the simulator consumes, scaling each
    /// kernel's waves-per-CU by `waves` (the workload-length knob the
    /// catalog generators expose).  The trace must already be validated.
    pub fn launches_scaled(&self, waves: f64) -> Vec<KernelLaunch> {
        self.kernels
            .iter()
            .map(|k| KernelLaunch {
                program: Arc::new(k.to_program()),
                waves_per_cu: ((k.waves_per_cu as f64 * waves).round() as u64).max(1),
            })
            .collect()
    }

    /// Content hash (32 hex chars) over the canonical text rendering
    /// *minus the provenance tag* — stable across text/binary
    /// re-encodings and across where a stream was recorded or ingested
    /// from, changed by any semantic edit (records, geometry, rounds,
    /// name).  This is what [`crate::exec::key::RunKey`] fingerprints,
    /// so semantically identical traces share one cache identity.
    pub fn content_hash(&self) -> String {
        fnv1a128_hex(self.render_text(false).as_bytes())
    }

    /// Total dynamic instructions per CU at mean trips (info output).
    pub fn dyn_instrs_per_cu(&self) -> u64 {
        let per_round: u64 = self
            .kernels
            .iter()
            .map(|k| dyn_instrs_per_wave(&k.records).saturating_mul(k.waves_per_cu))
            .fold(0u64, u64::saturating_add);
        per_round.saturating_mul(self.rounds as u64)
    }

    // ---------------- text encoding ----------------

    /// Canonical text rendering.
    pub fn to_text(&self) -> String {
        self.render_text(true)
    }

    /// `to_text` with the provenance line optional: the content-hash
    /// preimage omits it so provenance never splits cache identity.
    fn render_text(&self, include_source: bool) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{TEXT_HEADER}");
        let _ = writeln!(out, "name {}", self.name);
        if include_source {
            let _ = writeln!(out, "source {}", self.source);
        }
        let _ = writeln!(out, "rounds {}", self.rounds);
        for k in &self.kernels {
            let _ = writeln!(out, "kernel {} {} {}", k.kernel_id, k.name, k.waves_per_cu);
            for (pc, op) in k.records.iter().enumerate() {
                let _ = writeln!(out, "  {pc} {}", render_op(op));
            }
            let _ = writeln!(out, "end");
        }
        out
    }

    /// Parse the text encoding.  Errors carry 1-based line numbers.
    pub fn parse_text(text: &str) -> Result<Trace, String> {
        let mut lines = text.lines().enumerate();
        // header: first non-blank raw line, before comment stripping
        let header = loop {
            match lines.next() {
                Some((_, l)) if l.trim().is_empty() => continue,
                Some((_, l)) => break l.trim().to_string(),
                None => return Err("empty trace file".into()),
            }
        };
        if header != TEXT_HEADER {
            return Err(format!(
                "bad header '{header}' (expected '{TEXT_HEADER}')"
            ));
        }

        let mut name: Option<String> = None;
        let mut source: Option<String> = None;
        let mut rounds: Option<u32> = None;
        let mut kernels: Vec<TraceKernel> = Vec::new();
        // (kernel under construction)
        let mut cur: Option<TraceKernel> = None;

        for (i, raw) in lines {
            let n = i + 1; // 1-based for messages
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            if cur.is_none() {
                match toks[0] {
                    "name" => {
                        let v = toks
                            .get(1)
                            .ok_or_else(|| format!("line {n}: 'name' needs a value"))?;
                        if name.replace(v.to_string()).is_some() {
                            return Err(format!("line {n}: duplicate 'name'"));
                        }
                    }
                    "source" => {
                        let v = line["source".len()..].trim().to_string();
                        if source.replace(v).is_some() {
                            return Err(format!("line {n}: duplicate 'source'"));
                        }
                    }
                    "rounds" => {
                        let v = parse_int::<u32>(toks.get(1).copied(), "rounds", n)?;
                        if rounds.replace(v).is_some() {
                            return Err(format!("line {n}: duplicate 'rounds'"));
                        }
                    }
                    "kernel" => {
                        if toks.len() != 4 {
                            return Err(format!(
                                "line {n}: expected 'kernel <id> <name> <waves_per_cu>'"
                            ));
                        }
                        cur = Some(TraceKernel {
                            kernel_id: parse_int::<u32>(Some(toks[1]), "kernel id", n)?,
                            name: toks[2].to_string(),
                            waves_per_cu: parse_int::<u64>(Some(toks[3]), "waves_per_cu", n)?,
                            records: Vec::new(),
                        });
                    }
                    other => {
                        return Err(format!(
                            "line {n}: unexpected '{other}' outside a kernel block"
                        ));
                    }
                }
            } else if toks[0] == "end" {
                kernels.push(cur.take().expect("kernel block open"));
            } else {
                let k = cur.as_mut().expect("kernel block open");
                // record line: optional leading explicit PC
                let mut toks = toks.as_slice();
                if let Ok(pc) = toks[0].parse::<u32>() {
                    if pc as usize != k.records.len() {
                        return Err(format!(
                            "line {n}: pc {pc} out of order (expected {})",
                            k.records.len()
                        ));
                    }
                    toks = &toks[1..];
                    if toks.is_empty() {
                        return Err(format!("line {n}: pc with no instruction"));
                    }
                }
                if k.records.len() >= MAX_RECORDS_PER_KERNEL {
                    return Err(format!(
                        "line {n}: kernel exceeds {MAX_RECORDS_PER_KERNEL} records"
                    ));
                }
                k.records.push(parse_op(toks, n)?);
            }
        }
        if cur.is_some() {
            return Err("unterminated kernel block (missing 'end')".into());
        }
        let t = Trace {
            name: name.ok_or("missing 'name' line")?,
            source: source.unwrap_or_else(|| "hand".into()),
            rounds: rounds.ok_or("missing 'rounds' line")?,
            kernels,
        };
        t.validate()?;
        Ok(t)
    }

    // ---------------- binary encoding ----------------

    /// Length-prefixed binary rendering (`PCSTRCv1` magic, little-endian).
    pub fn to_binary(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(64 + self.kernels.len() * 64);
        b.extend_from_slice(BIN_MAGIC);
        put_u32(&mut b, TRACE_FORMAT_VERSION);
        put_str(&mut b, &self.name);
        put_str(&mut b, &self.source);
        put_u32(&mut b, self.rounds);
        put_u32(&mut b, self.kernels.len() as u32);
        for k in &self.kernels {
            put_u32(&mut b, k.kernel_id);
            put_str(&mut b, &k.name);
            put_u64(&mut b, k.waves_per_cu);
            put_u32(&mut b, k.records.len() as u32);
            for op in &k.records {
                put_op(&mut b, op);
            }
        }
        b
    }

    /// Parse the binary encoding.  Errors carry byte offsets.
    pub fn parse_binary(bytes: &[u8]) -> Result<Trace, String> {
        let mut c = Cursor::new(bytes);
        let magic = c.take(BIN_MAGIC.len())?;
        if magic != BIN_MAGIC {
            return Err("bad magic (not a pcstall binary trace)".into());
        }
        let version = c.u32()?;
        if version != TRACE_FORMAT_VERSION {
            return Err(format!(
                "unsupported trace format version {version} (this build reads v{TRACE_FORMAT_VERSION})"
            ));
        }
        let name = c.str()?;
        let source = c.str()?;
        let rounds = c.u32()?;
        let n_kernels = c.u32()? as usize;
        if n_kernels > MAX_KERNELS {
            return Err(format!("{n_kernels} kernels exceeds the {MAX_KERNELS} cap"));
        }
        let mut kernels = Vec::with_capacity(n_kernels);
        for _ in 0..n_kernels {
            let kernel_id = c.u32()?;
            let kname = c.str()?;
            let waves_per_cu = c.u64()?;
            let n_records = c.u32()? as usize;
            if n_records > MAX_RECORDS_PER_KERNEL {
                return Err(format!(
                    "kernel {kname}: {n_records} records exceeds the {MAX_RECORDS_PER_KERNEL} cap"
                ));
            }
            let mut records = Vec::with_capacity(n_records);
            for _ in 0..n_records {
                records.push(take_op(&mut c)?);
            }
            kernels.push(TraceKernel {
                kernel_id,
                name: kname,
                waves_per_cu,
                records,
            });
        }
        if c.remaining() != 0 {
            return Err(format!("{} trailing bytes after trace body", c.remaining()));
        }
        let t = Trace {
            name,
            source,
            rounds,
            kernels,
        };
        t.validate()?;
        Ok(t)
    }

    /// Decode either encoding (sniffs the binary magic).
    pub fn decode(bytes: &[u8]) -> Result<Trace, String> {
        if bytes.starts_with(BIN_MAGIC) {
            Self::parse_binary(bytes)
        } else {
            let text = std::str::from_utf8(bytes)
                .map_err(|_| "not a pcstall trace: no binary magic and not UTF-8 text".to_string())?;
            Self::parse_text(text)
        }
    }

    /// Load from disk with path-qualified errors.
    pub fn load(path: &Path) -> anyhow::Result<Trace> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading trace {}", path.display()))?;
        Self::decode(&bytes)
            .map_err(|e| anyhow::anyhow!("invalid trace {}: {e}", path.display()))
    }

    /// Save in the chosen encoding (directories created as needed).
    pub fn save(&self, path: &Path, binary: bool) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating {}", dir.display()))?;
            }
        }
        let bytes = if binary {
            self.to_binary()
        } else {
            self.to_text().into_bytes()
        };
        std::fs::write(path, bytes)
            .with_context(|| format!("writing trace {}", path.display()))
    }
}

/// Names travel through the whitespace-tokenized text form, so they must
/// be single printable-ASCII tokens.
fn check_name(name: &str) -> Result<(), String> {
    if name.is_empty() || name.len() > MAX_NAME_LEN {
        return Err(format!("name must be 1..={MAX_NAME_LEN} chars"));
    }
    if !name.bytes().all(|b| b.is_ascii_graphic() && b != b'#') {
        return Err(format!(
            "name '{name}' has characters outside printable ASCII (or '#')"
        ));
    }
    Ok(())
}

/// Replace characters a trace name cannot carry (ingest of mangled
/// symbol names, etc.).
pub fn sanitize_name(name: &str) -> String {
    let mut s: String = name
        .chars()
        .map(|c| {
            if c.is_ascii_graphic() && c != '#' {
                c
            } else {
                '_'
            }
        })
        .collect();
    if s.is_empty() {
        s.push('k');
    }
    s.truncate(MAX_NAME_LEN);
    s
}

/// Cap for the provenance tag — comfortably under the binary string
/// cap so both encodings round-trip it unmodified.
const MAX_SOURCE_LEN: usize = 1024;

/// The `source` tag rides the text form as the rest of its line, so it
/// may contain spaces — but '#' (comment), newlines / control chars
/// (line structure), and over-cap lengths would change the canonical
/// text the content hash is computed over, or break round-tripping.
fn check_source(source: &str) -> Result<(), String> {
    if source.len() > MAX_SOURCE_LEN {
        return Err(format!(
            "source tag exceeds {MAX_SOURCE_LEN} bytes"
        ));
    }
    if !source.bytes().all(|b| (b.is_ascii_graphic() || b == b' ') && b != b'#') {
        return Err(
            "source tag has characters outside printable ASCII (or contains '#')".into(),
        );
    }
    if source.trim() != source {
        // the text parser trims the rest-of-line value, so padding
        // would not survive a round trip (and would shift the hash)
        return Err("source tag has leading/trailing whitespace".into());
    }
    Ok(())
}

/// Make an arbitrary label (e.g. an ingest file path) a legal `source`
/// tag: bad characters become '_', over-cap input is truncated.
pub fn sanitize_source(source: &str) -> String {
    let mut s: String = source
        .chars()
        .map(|c| {
            if (c.is_ascii_graphic() || c == ' ') && c != '#' {
                c
            } else {
                '_'
            }
        })
        .collect();
    s.truncate(MAX_SOURCE_LEN);
    s.trim().to_string()
}

/// Dynamic instructions one wavefront executes at mean trip counts.
pub fn dyn_instrs_per_wave(records: &[Op]) -> u64 {
    let mut mult: u128 = 1;
    let mut stack: Vec<u128> = Vec::new();
    let mut total: u128 = 0;
    for op in records {
        match *op {
            Op::LoopBegin { trips, .. } => {
                total += mult;
                stack.push(mult);
                mult = mult.saturating_mul(trips.max(1) as u128);
            }
            Op::LoopEnd { .. } => {
                total += mult;
                mult = stack.pop().unwrap_or(1);
            }
            _ => total += mult,
        }
    }
    total.min(u64::MAX as u128) as u64
}

/// Reject malformed loop structure the simulator only catches with a
/// debug assertion: every `LoopEnd` at depth `d` must be preceded by a
/// still-armed `LoopBegin` at `d`, and its backedge must jump past that
/// `LoopBegin` (the builder convention: target = begin-pc + 1).
/// Execution is linear apart from these backedges, so a linear
/// arm/consume scan mirrors the runtime state exactly.
fn check_loops(records: &[Op]) -> Result<(), String> {
    let mut armed_at: [Option<usize>; MAX_LOOP_DEPTH] = [None; MAX_LOOP_DEPTH];
    for (pc, op) in records.iter().enumerate() {
        match *op {
            Op::LoopBegin { depth, .. } => {
                let d = depth as usize; // bound already checked by Program::validate
                if armed_at[d].is_some() {
                    return Err(format!(
                        "pc {pc}: LoopBegin at depth {depth} while that depth is already active"
                    ));
                }
                armed_at[d] = Some(pc);
            }
            Op::LoopEnd { depth, target } => {
                let d = depth as usize;
                let Some(begin) = armed_at[d].take() else {
                    return Err(format!(
                        "pc {pc}: LoopEnd at depth {depth} without a matching LoopBegin"
                    ));
                };
                if (target as usize) <= begin {
                    return Err(format!(
                        "pc {pc}: loop target {target} jumps before its LoopBegin at pc {begin}"
                    ));
                }
            }
            _ => {}
        }
    }
    Ok(())
}

/// Reject streams that could overflow the u8 outstanding-memory counters:
/// long runs without a *draining* wait, and loop bodies that issue
/// memory but never drain (outstanding ops accumulate across trips).
///
/// A `WaitCnt { max }` only guarantees outstanding <= `max` afterwards,
/// so the scan clamps the running bound to `max` rather than resetting
/// it — `waitcnt 255` never blocks and therefore drains nothing.  With
/// both rules the worst in-flight count is ~2·[`MAX_MEM_RUN`], well
/// under the u8 cap of 255.
fn check_mem_runs(records: &[Op]) -> Result<(), String> {
    let mut run = 0usize;
    for (pc, op) in records.iter().enumerate() {
        match *op {
            Op::Load { .. } | Op::Store { .. } => {
                run += 1;
                if run > MAX_MEM_RUN {
                    return Err(format!(
                        "pc {pc}: more than {MAX_MEM_RUN} memory ops without a draining \
                         s_waitcnt (outstanding counters would overflow)"
                    ));
                }
            }
            Op::WaitCnt { max } => run = run.min(max as usize),
            Op::LoopEnd { target, .. } => {
                let body = &records[target as usize..pc];
                let mem = body
                    .iter()
                    .any(|o| matches!(o, Op::Load { .. } | Op::Store { .. }));
                let drains = body
                    .iter()
                    .any(|o| matches!(o, Op::WaitCnt { max } if (*max as usize) <= MAX_MEM_RUN));
                if mem && !drains {
                    return Err(format!(
                        "pc {pc}: loop body issues memory but contains no s_waitcnt \
                         with max <= {MAX_MEM_RUN}"
                    ));
                }
            }
            _ => {}
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Text op codec
// ---------------------------------------------------------------------------

fn render_pattern(p: &Pattern) -> String {
    match p {
        Pattern::Strided {
            region,
            stride,
            working_set,
        } => format!("strided {region} {stride} {working_set}"),
        Pattern::Random {
            region,
            working_set,
        } => format!("random {region} {working_set}"),
    }
}

fn render_op(op: &Op) -> String {
    match *op {
        Op::VAlu { cycles } => format!("valu {cycles}"),
        Op::SAlu => "salu".into(),
        Op::Load { pattern, fan } => format!("load {} {fan}", render_pattern(&pattern)),
        Op::Store { pattern, fan } => format!("store {} {fan}", render_pattern(&pattern)),
        Op::WaitCnt { max } => format!("waitcnt {max}"),
        Op::Barrier => "barrier".into(),
        Op::LoopBegin {
            depth,
            trips,
            divergence,
        } => format!("loop {depth} {trips} {divergence}"),
        Op::LoopEnd { depth, target } => format!("endloop {depth} {target}"),
        Op::EndPgm => "endpgm".into(),
    }
}

fn parse_int<T: std::str::FromStr>(tok: Option<&str>, what: &str, line: usize) -> Result<T, String> {
    let tok = tok.ok_or_else(|| format!("line {line}: missing {what}"))?;
    tok.parse::<T>()
        .map_err(|_| format!("line {line}: bad {what} '{tok}'"))
}

/// Parse a pattern starting at `toks[0]`; returns (pattern, tokens used).
fn parse_pattern(toks: &[&str], line: usize) -> Result<(Pattern, usize), String> {
    match toks.first().copied() {
        Some("strided") => Ok((
            Pattern::Strided {
                region: parse_int(toks.get(1).copied(), "region", line)?,
                stride: parse_int(toks.get(2).copied(), "stride", line)?,
                working_set: parse_int(toks.get(3).copied(), "working_set", line)?,
            },
            4,
        )),
        Some("random") => Ok((
            Pattern::Random {
                region: parse_int(toks.get(1).copied(), "region", line)?,
                working_set: parse_int(toks.get(2).copied(), "working_set", line)?,
            },
            3,
        )),
        other => Err(format!(
            "line {line}: expected pattern 'strided'/'random', got {other:?}"
        )),
    }
}

fn parse_op(toks: &[&str], line: usize) -> Result<Op, String> {
    let exact = |want: usize| -> Result<(), String> {
        if toks.len() == want {
            Ok(())
        } else {
            Err(format!(
                "line {line}: '{}' takes {} operand(s), got {}",
                toks[0],
                want - 1,
                toks.len() - 1
            ))
        }
    };
    match toks[0] {
        "valu" => {
            exact(2)?;
            Ok(Op::VAlu {
                cycles: parse_int(toks.get(1).copied(), "cycles", line)?,
            })
        }
        "salu" => {
            exact(1)?;
            Ok(Op::SAlu)
        }
        "load" | "store" => {
            let (pattern, used) = parse_pattern(&toks[1..], line)?;
            exact(1 + used + 1)?;
            let fan = parse_int(toks.get(1 + used).copied(), "fan", line)?;
            Ok(if toks[0] == "load" {
                Op::Load { pattern, fan }
            } else {
                Op::Store { pattern, fan }
            })
        }
        "waitcnt" => {
            exact(2)?;
            Ok(Op::WaitCnt {
                max: parse_int(toks.get(1).copied(), "max", line)?,
            })
        }
        "barrier" => {
            exact(1)?;
            Ok(Op::Barrier)
        }
        "loop" => {
            exact(4)?;
            Ok(Op::LoopBegin {
                depth: parse_int(toks.get(1).copied(), "depth", line)?,
                trips: parse_int(toks.get(2).copied(), "trips", line)?,
                divergence: parse_int(toks.get(3).copied(), "divergence", line)?,
            })
        }
        "endloop" => {
            exact(3)?;
            Ok(Op::LoopEnd {
                depth: parse_int(toks.get(1).copied(), "depth", line)?,
                target: parse_int(toks.get(2).copied(), "target", line)?,
            })
        }
        "endpgm" => {
            exact(1)?;
            Ok(Op::EndPgm)
        }
        other => Err(format!("line {line}: unknown instruction '{other}'")),
    }
}

// ---------------------------------------------------------------------------
// Binary op codec
// ---------------------------------------------------------------------------

const TAG_VALU: u8 = 0;
const TAG_SALU: u8 = 1;
const TAG_LOAD: u8 = 2;
const TAG_STORE: u8 = 3;
const TAG_WAITCNT: u8 = 4;
const TAG_BARRIER: u8 = 5;
const TAG_LOOP: u8 = 6;
const TAG_ENDLOOP: u8 = 7;
const TAG_ENDPGM: u8 = 8;

const PAT_STRIDED: u8 = 0;
const PAT_RANDOM: u8 = 1;

fn put_u16(b: &mut Vec<u8>, v: u16) {
    b.extend_from_slice(&v.to_le_bytes());
}
fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}
/// Cap for length-prefixed strings (names are further capped by
/// [`check_name`]; `source` labels may be longer, e.g. ingest paths).
const MAX_STR_LEN: usize = 4096;

fn put_str(b: &mut Vec<u8>, s: &str) {
    // truncate on a char boundary so the reader always sees valid UTF-8
    let mut end = s.len().min(MAX_STR_LEN);
    while end > 0 && !s.is_char_boundary(end) {
        end -= 1;
    }
    put_u16(b, end as u16);
    b.extend_from_slice(&s.as_bytes()[..end]);
}

fn put_pattern(b: &mut Vec<u8>, p: &Pattern) {
    match *p {
        Pattern::Strided {
            region,
            stride,
            working_set,
        } => {
            b.push(PAT_STRIDED);
            b.push(region);
            put_u32(b, stride);
            put_u32(b, working_set);
        }
        Pattern::Random {
            region,
            working_set,
        } => {
            b.push(PAT_RANDOM);
            b.push(region);
            put_u32(b, working_set);
        }
    }
}

fn put_op(b: &mut Vec<u8>, op: &Op) {
    match *op {
        Op::VAlu { cycles } => {
            b.push(TAG_VALU);
            b.push(cycles);
        }
        Op::SAlu => b.push(TAG_SALU),
        Op::Load { pattern, fan } => {
            b.push(TAG_LOAD);
            put_pattern(b, &pattern);
            b.push(fan);
        }
        Op::Store { pattern, fan } => {
            b.push(TAG_STORE);
            put_pattern(b, &pattern);
            b.push(fan);
        }
        Op::WaitCnt { max } => {
            b.push(TAG_WAITCNT);
            b.push(max);
        }
        Op::Barrier => b.push(TAG_BARRIER),
        Op::LoopBegin {
            depth,
            trips,
            divergence,
        } => {
            b.push(TAG_LOOP);
            b.push(depth);
            put_u16(b, trips);
            put_u16(b, divergence);
        }
        Op::LoopEnd { depth, target } => {
            b.push(TAG_ENDLOOP);
            b.push(depth);
            put_u32(b, target);
        }
        Op::EndPgm => b.push(TAG_ENDPGM),
    }
}

struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(bytes: &'a [u8]) -> Self {
        Cursor { bytes, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.bytes.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.remaining() < n {
            return Err(format!(
                "truncated trace: wanted {n} bytes at offset {}, {} left",
                self.pos,
                self.remaining()
            ));
        }
        let out = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, String> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String, String> {
        let len = self.u16()? as usize;
        if len > MAX_STR_LEN {
            return Err(format!(
                "string length {len} at offset {} exceeds the {MAX_STR_LEN} cap",
                self.pos
            ));
        }
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| format!("invalid UTF-8 string at offset {}", self.pos))
    }
}

fn take_pattern(c: &mut Cursor) -> Result<Pattern, String> {
    match c.u8()? {
        PAT_STRIDED => Ok(Pattern::Strided {
            region: c.u8()?,
            stride: c.u32()?,
            working_set: c.u32()?,
        }),
        PAT_RANDOM => Ok(Pattern::Random {
            region: c.u8()?,
            working_set: c.u32()?,
        }),
        other => Err(format!("unknown pattern tag {other}")),
    }
}

fn take_op(c: &mut Cursor) -> Result<Op, String> {
    match c.u8()? {
        TAG_VALU => Ok(Op::VAlu { cycles: c.u8()? }),
        TAG_SALU => Ok(Op::SAlu),
        TAG_LOAD => Ok(Op::Load {
            pattern: take_pattern(c)?,
            fan: c.u8()?,
        }),
        TAG_STORE => Ok(Op::Store {
            pattern: take_pattern(c)?,
            fan: c.u8()?,
        }),
        TAG_WAITCNT => Ok(Op::WaitCnt { max: c.u8()? }),
        TAG_BARRIER => Ok(Op::Barrier),
        TAG_LOOP => Ok(Op::LoopBegin {
            depth: c.u8()?,
            trips: c.u16()?,
            divergence: c.u16()?,
        }),
        TAG_ENDLOOP => Ok(Op::LoopEnd {
            depth: c.u8()?,
            target: c.u32()?,
        }),
        TAG_ENDPGM => Ok(Op::EndPgm),
        other => Err(format!("unknown op tag {other}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stream() -> Vec<Op> {
        vec![
            Op::SAlu,
            Op::SAlu,
            Op::LoopBegin {
                depth: 0,
                trips: 6,
                divergence: 2,
            },
            Op::Load {
                pattern: Pattern::Strided {
                    region: 1,
                    stride: 64,
                    working_set: 1 << 20,
                },
                fan: 2,
            },
            Op::WaitCnt { max: 0 },
            Op::VAlu { cycles: 4 },
            Op::Store {
                pattern: Pattern::Random {
                    region: 9,
                    working_set: 1 << 24,
                },
                fan: 1,
            },
            Op::WaitCnt { max: 0 },
            Op::Barrier,
            Op::LoopEnd {
                depth: 0,
                target: 3,
            },
            Op::EndPgm,
        ]
    }

    fn a_trace() -> Trace {
        Trace {
            name: "t0".into(),
            source: "hand".into(),
            rounds: 2,
            kernels: vec![TraceKernel {
                kernel_id: 0,
                name: "k".into(),
                waves_per_cu: 8,
                records: stream(),
            }],
        }
    }

    #[test]
    fn text_roundtrip_is_identity() {
        let t = a_trace();
        let back = Trace::parse_text(&t.to_text()).unwrap();
        assert_eq!(t, back);
        assert_eq!(t.content_hash(), back.content_hash());
    }

    #[test]
    fn binary_roundtrip_is_identity() {
        let t = a_trace();
        let back = Trace::parse_binary(&t.to_binary()).unwrap();
        assert_eq!(t, back);
        assert_eq!(t.content_hash(), back.content_hash());
    }

    #[test]
    fn decode_sniffs_both_encodings() {
        let t = a_trace();
        assert_eq!(Trace::decode(&t.to_binary()).unwrap(), t);
        assert_eq!(Trace::decode(t.to_text().as_bytes()).unwrap(), t);
    }

    #[test]
    fn text_accepts_implicit_pcs_and_comments() {
        let text = "\n#pcstall-trace v1\nname x # inline\nrounds 1\n\
                    kernel 3 demo 4\n  salu\n  valu 2  # fma\n  endpgm\nend\n";
        let t = Trace::parse_text(text).unwrap();
        assert_eq!(t.kernels[0].kernel_id, 3);
        assert_eq!(t.kernels[0].records.len(), 3);
        assert_eq!(t.source, "hand");
    }

    #[test]
    fn truncated_binary_errors_cleanly_at_every_length() {
        let full = a_trace().to_binary();
        for cut in 0..full.len() {
            let r = Trace::parse_binary(&full[..cut]);
            assert!(r.is_err(), "cut at {cut} did not error");
        }
    }

    #[test]
    fn corrupt_inputs_error_not_panic() {
        let cases: [&[u8]; 7] = [
            b"garbage",
            b"#pcstall-trace v1\nname x\nrounds 0\nkernel 0 k 1\n endpgm\nend\n",
            b"#pcstall-trace v1\nname x\nrounds 1\n", // no kernels
            b"#pcstall-trace v1\nname x\nrounds 1\nkernel 0 k 1\n  bogus\nend\n",
            b"#pcstall-trace v1\nname x\nrounds 1\nkernel 0 k 1\n  valu 2\nend\n", // no endpgm
            b"#pcstall-trace v2\nname x\nrounds 1\n",                              // bad header
            b"PCSTRCv1\xff\xff\xff\xff",                                           // bad version
        ];
        for bad in cases {
            assert!(Trace::decode(bad).is_err());
        }
    }

    #[test]
    fn forward_loop_target_rejected() {
        let text = "#pcstall-trace v1\nname x\nrounds 1\nkernel 0 k 1\n\
                    endloop 0 5\nendpgm\nend\n";
        assert!(Trace::parse_text(text).is_err());
    }

    #[test]
    fn explicit_pc_must_match_index() {
        let text = "#pcstall-trace v1\nname x\nrounds 1\nkernel 0 k 1\n\
                    0 salu\n2 endpgm\nend\n";
        let e = Trace::parse_text(text).unwrap_err();
        assert!(e.contains("out of order"), "{e}");
    }

    #[test]
    fn unbounded_mem_runs_rejected() {
        // linear run over the cap
        let mut records = Vec::new();
        for _ in 0..(MAX_MEM_RUN + 1) {
            records.push(Op::Load {
                pattern: Pattern::Strided {
                    region: 0,
                    stride: 64,
                    working_set: 1 << 20,
                },
                fan: 1,
            });
        }
        records.push(Op::EndPgm);
        let mut t = a_trace();
        t.kernels[0].records = records;
        assert!(t.validate().is_err());

        // loop body with memory but no waitcnt
        let mut t = a_trace();
        t.kernels[0].records = vec![
            Op::LoopBegin {
                depth: 0,
                trips: 100,
                divergence: 0,
            },
            Op::Load {
                pattern: Pattern::Strided {
                    region: 0,
                    stride: 64,
                    working_set: 1 << 20,
                },
                fan: 1,
            },
            Op::LoopEnd {
                depth: 0,
                target: 1,
            },
            Op::EndPgm,
        ];
        let e = t.validate().unwrap_err();
        assert!(e.contains("no s_waitcnt"), "{e}");
    }

    #[test]
    fn non_draining_waitcnts_do_not_satisfy_the_mem_bound() {
        let load = Op::Load {
            pattern: Pattern::Strided {
                region: 0,
                stride: 64,
                working_set: 1 << 20,
            },
            fan: 1,
        };
        // `waitcnt 255` never blocks: alternating 40-load runs with it
        // must still trip the linear bound (40 + 40 > 64)
        let mut records = Vec::new();
        for _ in 0..40 {
            records.push(load);
        }
        records.push(Op::WaitCnt { max: 255 });
        for _ in 0..40 {
            records.push(load);
        }
        records.push(Op::WaitCnt { max: 0 });
        records.push(Op::EndPgm);
        let mut t = a_trace();
        t.kernels[0].records = records;
        assert!(t.validate().is_err());

        // a loop body whose only waitcnt has max > MAX_MEM_RUN drains
        // nothing across trips
        let mut t = a_trace();
        t.kernels[0].records = vec![
            Op::LoopBegin {
                depth: 0,
                trips: 100,
                divergence: 0,
            },
            load,
            Op::WaitCnt { max: 255 },
            Op::LoopEnd {
                depth: 0,
                target: 1,
            },
            Op::EndPgm,
        ];
        let e = t.validate().unwrap_err();
        assert!(e.contains("max <= "), "{e}");

        // a clamping (but non-zero) waitcnt is a legal drain point
        let mut records = Vec::new();
        for _ in 0..40 {
            records.push(load);
        }
        records.push(Op::WaitCnt { max: 16 });
        for _ in 0..40 {
            records.push(load);
        }
        records.push(Op::WaitCnt { max: 0 });
        records.push(Op::EndPgm);
        let mut t = a_trace();
        t.kernels[0].records = records;
        assert!(t.validate().is_ok(), "{:?}", t.validate());
    }

    #[test]
    fn unmatched_or_misdirected_loops_rejected() {
        // endloop with no armed loop (valid per Program::validate, but
        // would trip the simulator's debug assertion)
        let text = "#pcstall-trace v1\nname x\nrounds 1\nkernel 0 k 1\n\
                    salu\nendloop 0 0\nendpgm\nend\n";
        let e = Trace::parse_text(text).unwrap_err();
        assert!(e.contains("without a matching LoopBegin"), "{e}");

        // consumed twice: sequential endloops for one begin
        let text = "#pcstall-trace v1\nname x\nrounds 1\nkernel 0 k 1\n\
                    loop 0 3 0\nvalu 1\nendloop 0 1\nendloop 0 1\nendpgm\nend\n";
        assert!(Trace::parse_text(text).is_err());

        // backedge jumping to (or before) its own LoopBegin
        let text = "#pcstall-trace v1\nname x\nrounds 1\nkernel 0 k 1\n\
                    loop 0 3 0\nvalu 1\nendloop 0 0\nendpgm\nend\n";
        let e = Trace::parse_text(text).unwrap_err();
        assert!(e.contains("jumps before"), "{e}");

        // re-arming an already-active depth
        let text = "#pcstall-trace v1\nname x\nrounds 1\nkernel 0 k 1\n\
                    loop 0 3 0\nloop 0 2 0\nvalu 1\nendloop 0 2\nendloop 0 1\nendpgm\nend\n";
        assert!(Trace::parse_text(text).is_err());
    }

    #[test]
    fn source_tags_are_validated_and_sanitizable() {
        let mut t = a_trace();
        t.source = "bad#tag".into();
        assert!(t.validate().is_err());
        t.source = "has\nnewline".into();
        assert!(t.validate().is_err());
        t.source = " padded ".into();
        assert!(t.validate().is_err());
        t.source = "x".repeat(5000);
        assert!(t.validate().is_err());
        t.source = sanitize_source("ingest:runs#3/\nlong path.traceg ");
        assert!(t.validate().is_ok(), "{}", t.source);
        // sanitized sources survive both encodings unchanged
        let a = Trace::parse_binary(&t.to_binary()).unwrap();
        let b = Trace::parse_text(&t.to_text()).unwrap();
        assert_eq!(a.source, t.source);
        assert_eq!(b.source, t.source);
        assert_eq!(a.content_hash(), b.content_hash());
    }

    #[test]
    fn content_hash_tracks_semantic_edits() {
        let a = a_trace();
        let mut b = a.clone();
        b.kernels[0].waves_per_cu = 9;
        let mut c = a.clone();
        if let Op::VAlu { cycles } = &mut c.kernels[0].records[5] {
            *cycles = 5;
        }
        assert_ne!(a.content_hash(), b.content_hash());
        assert_ne!(a.content_hash(), c.content_hash());
        assert_eq!(a.content_hash(), a.clone().content_hash());
    }

    #[test]
    fn content_hash_ignores_provenance() {
        // identical streams ingested/recorded from different places
        // must share one cache identity
        let a = a_trace();
        let mut b = a.clone();
        b.source = "ingest:somewhere/else.traceg".into();
        assert_ne!(a.to_text(), b.to_text());
        assert_eq!(a.content_hash(), b.content_hash());
    }

    #[test]
    fn dyn_instrs_expand_loops_at_mean_trips() {
        // salu salu loop(6) [load wait valu store wait barrier] endloop endpgm
        // = 2 + 1 + 6*(6 + 1) + 1 = 46
        assert_eq!(dyn_instrs_per_wave(&stream()), 46);
    }

    #[test]
    fn launches_scale_waves_with_floor_one() {
        let t = a_trace();
        let l = t.launches_scaled(0.01);
        assert_eq!(l[0].waves_per_cu, 1);
        let l = t.launches_scaled(2.0);
        assert_eq!(l[0].waves_per_cu, 16);
        assert!(l[0].program.validate().is_ok());
        assert_eq!(l[0].program.kernel_id, 0);
    }

    #[test]
    fn stats_count_op_kinds() {
        let t = a_trace();
        let s = t.kernels[0].stats();
        assert_eq!(s.static_records, 11);
        assert_eq!(
            (s.valu, s.salu, s.loads, s.stores, s.waitcnts, s.barriers, s.loops),
            (1, 2, 1, 1, 2, 1, 1)
        );
        assert_eq!(s.dyn_per_wave, 46);
    }

    #[test]
    fn sanitize_name_makes_tokens() {
        assert_eq!(sanitize_name("a b#c"), "a_b_c");
        assert_eq!(sanitize_name(""), "k");
        assert_eq!(sanitize_name("_Z6vecAddPdS_S_"), "_Z6vecAddPdS_S_");
    }

    #[test]
    fn save_load_roundtrip_on_disk() {
        let dir = std::env::temp_dir().join(format!("pcstall_trace_fmt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let t = a_trace();
        for (file, binary) in [("t.trace", false), ("t.tracebin", true)] {
            let path = dir.join(file);
            t.save(&path, binary).unwrap();
            let back = Trace::load(&path).unwrap();
            assert_eq!(back, t);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
