//! Ingest external accel-sim-style kernel traces onto the pcstall ISA.
//!
//! Accel-sim / gpucachesim record one file per kernel: `-key = value`
//! header lines (kernel name/id, grid and block dimensions), then
//! per-warp dynamic instruction blocks:
//!
//! ```text
//! -kernel name = _Z6vecAddPdS_S_
//! -grid dim = (160,1,1)
//! -block dim = (1024,1,1)
//! #BEGIN_TB
//! thread block = 0,0,0
//! warp = 0
//! insts = 5
//! 0000 ffffffff 1 R1 IMAD.MOV.U32 2 R1 R255
//! 0010 ffffffff 1 R2 LDG.E.64 1 R2 8 1 0x7f0d5b000000
//! ...
//! #END_TB
//! ```
//!
//! The lowering takes the **first warp block of each kernel section** as
//! the representative stream (accel-sim streams are already dynamic:
//! loops arrive unrolled, so no loop reconstruction is attempted), maps
//! each SASS opcode onto the [`Op`] micro-ISA by its leading mnemonic
//! segment, derives strides/divergence from any listed addresses, and
//! derives waves-per-CU from the grid geometry normalized to the paper's
//! 64-CU part.  Memory-level parallelism is bounded by inserting
//! `s_waitcnt` every [`WAIT_EVERY`] memory ops (the trace format rejects
//! unbounded outstanding runs).

use crate::sim::isa::{Op, Pattern};
use crate::trace::format::{
    sanitize_name, sanitize_source, Trace, TraceKernel, MAX_RECORDS_PER_KERNEL,
};

/// Insert `s_waitcnt 16` after this many memory ops without one.
pub const WAIT_EVERY: usize = 16;

/// Waves-per-CU cap for ingested kernels (huge grids would otherwise
/// make completion runs impractically long).
pub const WAVES_PER_CU_CAP: u64 = 128;

/// CU count used to normalize grid geometry to waves-per-CU.
const NORM_CUS: u64 = 64;

/// Classify a memory site from an inferred per-access stride.  Strides
/// at or beyond 2048 bytes are effectively uncorrelated from the cache's
/// point of view and are modelled as [`Pattern::Random`]; anything
/// tighter stays [`Pattern::Strided`] (floored at 4 bytes, one word).
/// Shared by the accel-sim ingest path and the `workloads::exec`
/// recorder so both lowerings agree on what "random" means.
pub fn classify_pattern(region: u8, stride_guess: u32, working_set: u32) -> Pattern {
    if stride_guess >= 2048 {
        Pattern::Random { region, working_set }
    } else {
        Pattern::Strided {
            region,
            stride: stride_guess.max(4),
            working_set,
        }
    }
}

/// Memory divergence of one warp access: distinct 64-byte lines among
/// the observed lane addresses, clamped to the simulator's 1..=16 fan
/// range (no observations coalesce to a single line).
pub fn fan_from_addrs(addrs: &[u64]) -> u8 {
    let mut lines: Vec<u64> = addrs.iter().map(|a| a >> 6).collect();
    lines.sort_unstable();
    lines.dedup();
    lines.len().clamp(1, 16) as u8
}

/// Normalize a total 64-lane wavefront count to a waves-per-CU figure
/// on the reference 64-CU part, capped at [`WAVES_PER_CU_CAP`] so huge
/// grids stay simulable.
pub fn normalize_waves(total_waves: u64) -> u64 {
    (total_waves.max(1).div_ceil(NORM_CUS)).clamp(1, WAVES_PER_CU_CAP)
}

/// An ingested trace plus non-fatal notes (truncations, defaults used).
#[derive(Debug)]
pub struct Ingested {
    pub trace: Trace,
    pub warnings: Vec<String>,
}

/// Parse accel-sim-style kernel-trace text.  `label` tags provenance
/// (usually the source file name).
pub fn parse_accelsim(text: &str, label: &str) -> Result<Ingested, String> {
    let mut warnings = Vec::new();
    let mut kernels: Vec<TraceKernel> = Vec::new();
    let mut cur: Option<Section> = None;

    for (i, raw) in text.lines().enumerate() {
        let n = i + 1;
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('-') {
            // header line: "-kernel name = X" / "-grid dim = (a,b,c)" ...
            let (key, value) = match rest.split_once('=') {
                Some((k, v)) => (k.trim().to_ascii_lowercase(), v.trim()),
                None => continue, // e.g. bare directives; ignore
            };
            match key.as_str() {
                "kernel name" => {
                    // a new kernel section begins
                    if let Some(sec) = cur.take() {
                        kernels.push(sec.finish(kernels.len() as u32, &mut warnings)?);
                    }
                    cur = Some(Section::new(value));
                }
                "kernel id" => {
                    if let Some(sec) = cur.as_mut() {
                        sec.kernel_id = value.parse::<u32>().ok();
                    }
                }
                "grid dim" => {
                    if let Some(sec) = cur.as_mut() {
                        sec.grid = parse_dim3(value)
                            .ok_or_else(|| format!("line {n}: bad grid dim '{value}'"))?;
                    }
                }
                "block dim" => {
                    if let Some(sec) = cur.as_mut() {
                        sec.block = parse_dim3(value)
                            .ok_or_else(|| format!("line {n}: bad block dim '{value}'"))?;
                    }
                }
                _ => {} // shmem, nregs, binary version, ... — irrelevant here
            }
            continue;
        }
        let Some(sec) = cur.as_mut() else {
            // instruction-ish line before any kernel header
            if line.starts_with('#') || line.contains('=') {
                continue;
            }
            return Err(format!(
                "line {n}: instruction line before any '-kernel name' header"
            ));
        };
        if line.starts_with("#BEGIN_TB") || line.starts_with("#END_TB") {
            continue;
        }
        if let Some(rest) = line.strip_prefix("warp") {
            let idx = rest.trim_start_matches(['=', ' ']).trim();
            sec.in_first_warp = !sec.first_warp_done && idx.parse::<u64>() == Ok(0);
            if sec.in_first_warp {
                sec.first_warp_done = true;
            }
            continue;
        }
        if line.starts_with("thread block") || line.starts_with("insts") {
            continue;
        }
        if sec.in_first_warp {
            if sec.records.len() >= MAX_RECORDS_PER_KERNEL - 2 {
                if !sec.truncated {
                    sec.truncated = true;
                    warnings.push(format!(
                        "kernel {}: stream truncated at {} records",
                        sec.name,
                        sec.records.len()
                    ));
                }
                continue;
            }
            sec.push_line(line, n)?;
        }
    }
    if let Some(sec) = cur.take() {
        kernels.push(sec.finish(kernels.len() as u32, &mut warnings)?);
    }
    if kernels.is_empty() {
        return Err("no '-kernel name' sections found (is this an accel-sim kernel trace?)".into());
    }

    let name = sanitize_name(
        &kernels
            .first()
            .map(|k| k.name.clone())
            .unwrap_or_else(|| "ingest".into()),
    );
    let trace = Trace {
        name,
        source: sanitize_source(&format!("ingest:{label}")),
        rounds: 1,
        kernels,
    };
    trace.validate()?;
    Ok(Ingested { trace, warnings })
}

/// One `-kernel name` section under construction.
struct Section {
    name: String,
    kernel_id: Option<u32>,
    grid: (u64, u64, u64),
    block: (u64, u64, u64),
    records: Vec<Op>,
    /// Memory ops since the last waitcnt (bounded by [`WAIT_EVERY`]).
    mem_run: usize,
    /// Per-kernel address observations (stride/working-set estimation).
    last_addr: Option<u64>,
    stride_guess: u32,
    addr_min: u64,
    addr_max: u64,
    in_first_warp: bool,
    first_warp_done: bool,
    truncated: bool,
}

impl Section {
    fn new(name: &str) -> Section {
        Section {
            name: sanitize_name(name),
            kernel_id: None,
            grid: (1, 1, 1),
            block: (64, 1, 1),
            records: Vec::new(),
            mem_run: 0,
            last_addr: None,
            stride_guess: 64,
            addr_min: u64::MAX,
            addr_max: 0,
            in_first_warp: false,
            first_warp_done: false,
            truncated: false,
        }
    }

    /// Total 64-lane wavefronts the grid launches, normalized to a
    /// per-CU count on the reference 64-CU part.
    fn waves_per_cu(&self) -> u64 {
        let threads_per_block = (self.block.0 * self.block.1 * self.block.2).max(1);
        let blocks = (self.grid.0 * self.grid.1 * self.grid.2).max(1);
        let waves = blocks.saturating_mul(threads_per_block.div_ceil(64));
        normalize_waves(waves)
    }

    fn push(&mut self, op: Op) {
        match op {
            Op::Load { .. } | Op::Store { .. } => {
                self.records.push(op);
                self.mem_run += 1;
                if self.mem_run >= WAIT_EVERY {
                    self.records.push(Op::WaitCnt { max: 16 });
                    self.mem_run = 0;
                }
            }
            Op::WaitCnt { .. } => {
                self.records.push(op);
                self.mem_run = 0;
            }
            Op::Barrier | Op::EndPgm => {
                if self.mem_run > 0 {
                    self.records.push(Op::WaitCnt { max: 0 });
                    self.mem_run = 0;
                }
                self.records.push(op);
            }
            op => self.records.push(op),
        }
    }

    /// Lower one instruction line.
    fn push_line(&mut self, line: &str, n: usize) -> Result<(), String> {
        let toks: Vec<&str> = line.split_whitespace().collect();
        // PC mask dest_num [dest regs] opcode ...
        if toks.len() < 4 {
            return Err(format!(
                "line {n}: instruction line too short: '{line}'"
            ));
        }
        let dest_num: usize = toks[2]
            .parse()
            .map_err(|_| format!("line {n}: bad dest-register count '{}'", toks[2]))?;
        let opcode_idx = 3 + dest_num;
        let opcode = *toks
            .get(opcode_idx)
            .ok_or_else(|| format!("line {n}: missing opcode after {dest_num} dest regs"))?;

        // address observations (any trailing 0x… tokens)
        let addrs: Vec<u64> = toks[opcode_idx..]
            .iter()
            .filter_map(|t| {
                t.strip_prefix("0x")
                    .and_then(|h| u64::from_str_radix(h, 16).ok())
            })
            .collect();
        if let (Some(&first), Some(&second)) = (addrs.first(), addrs.get(1)) {
            let d = second.abs_diff(first);
            if d > 0 {
                self.stride_guess = d.clamp(4, 4096) as u32;
            }
        }
        if let Some(&first) = addrs.first() {
            if let Some(prev) = self.last_addr {
                let d = first.abs_diff(prev);
                if d > 0 && addrs.len() == 1 {
                    self.stride_guess = d.clamp(4, 4096) as u32;
                }
            }
            self.last_addr = Some(first);
            for &a in &addrs {
                self.addr_min = self.addr_min.min(a);
                self.addr_max = self.addr_max.max(a);
            }
        }
        // memory divergence: distinct 64-byte lines among listed lanes
        let fan = fan_from_addrs(&addrs);

        let base = opcode.split('.').next().unwrap_or(opcode);
        let op = classify(base, self.pattern(), fan);
        self.push(op);
        Ok(())
    }

    /// Current working pattern for this kernel's global memory ops.
    fn pattern(&self) -> Pattern {
        let span = if self.addr_max > self.addr_min {
            self.addr_max - self.addr_min
        } else {
            0
        };
        let working_set = span.clamp(1 << 20, 256 << 20) as u32;
        let region = (self.kernel_id.unwrap_or(0) % 250) as u8;
        classify_pattern(region, self.stride_guess, working_set)
    }

    fn finish(mut self, fallback_id: u32, warnings: &mut Vec<String>) -> Result<TraceKernel, String> {
        if self.records.is_empty() {
            warnings.push(format!(
                "kernel {}: no warp-0 instructions found; emitting a stub",
                self.name
            ));
            self.records.push(Op::SAlu);
        }
        if self.mem_run > 0 {
            self.records.push(Op::WaitCnt { max: 0 });
        }
        if !matches!(self.records.last(), Some(Op::EndPgm)) {
            self.records.push(Op::EndPgm);
        }
        Ok(TraceKernel {
            kernel_id: self.kernel_id.unwrap_or(fallback_id),
            name: self.name.clone(),
            waves_per_cu: self.waves_per_cu(),
            records: self.records,
        })
    }
}

/// Map a leading SASS mnemonic segment to the micro-ISA.
fn classify(base: &str, pattern: Pattern, fan: u8) -> Op {
    match base {
        "EXIT" | "RET" => Op::EndPgm,
        "BAR" | "BARRIER" => Op::Barrier,
        "MEMBAR" | "DEPBAR" | "ERRBAR" | "CCTL" | "CCTLL" => Op::WaitCnt { max: 0 },
        // global/local-through-L2 memory
        "LDG" | "LD" | "LDL" => Op::Load { pattern, fan },
        "STG" | "ST" | "STL" | "RED" | "ATOM" | "ATOMG" | "ATOMS" => Op::Store { pattern, fan },
        // shared memory: on-chip, latency comparable to a slow ALU op
        "LDS" | "LDSM" | "STS" => Op::VAlu { cycles: 4 },
        // long-latency math
        "MUFU" => Op::VAlu { cycles: 8 },
        "FFMA" | "FMA" | "DFMA" | "DMUL" | "DADD" | "FMUL" | "FADD" | "HFMA2" | "HMUL2"
        | "HADD2" | "FSEL" => Op::VAlu { cycles: 4 },
        // scalar-ish / control flow: 1-cycle scalar pipe
        "S2R" | "CS2R" | "NOP" | "BRA" | "JMP" | "CAL" | "RETL" | "BSSY" | "BSYNC" | "BMOV"
        | "VOTE" | "PLOP3" => Op::SAlu,
        // everything else: short vector integer/move op
        _ => Op::VAlu { cycles: 1 },
    }
}

/// Parse `(a,b,c)` or `a,b,c`.
fn parse_dim3(s: &str) -> Option<(u64, u64, u64)> {
    let s = s.trim().trim_start_matches('(').trim_end_matches(')');
    let mut it = s.split(',').map(|t| t.trim().parse::<u64>());
    let a = it.next()?.ok()?;
    let b = it.next().unwrap_or(Ok(1)).ok()?;
    let c = it.next().unwrap_or(Ok(1)).ok()?;
    Some((a.max(1), b.max(1), c.max(1)))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
-kernel name = _Z6vecAddPdS_S_
-kernel id = 1
-grid dim = (160,1,1)
-block dim = (1024,1,1)
-shmem = 0

#BEGIN_TB

thread block = 0,0,0

warp = 0
insts = 8
0000 ffffffff 1 R1 IMAD.MOV.U32 2 R1 R255
0010 ffffffff 1 R2 S2R 0
0020 ffffffff 1 R4 LDG.E.64 1 R2 8 1 0x7f0d5b000000
0030 ffffffff 1 R6 LDG.E.64 1 R4 8 1 0x7f0d5b000040
0040 ffffffff 1 R8 DADD 2 R4 R6
0050 ffffffff 0 BAR.SYNC 0
0060 ffffffff 0 STG.E.64 2 R8 R10 8 1 0x7f0d5c000000
0070 ffffffff 0 EXIT 0

warp = 1
insts = 2
0000 ffffffff 1 R1 IMAD.MOV.U32 2 R1 R255
0070 ffffffff 0 EXIT 0

#END_TB
";

    #[test]
    fn sample_lowers_to_expected_op_kinds() {
        let ing = parse_accelsim(SAMPLE, "sample").unwrap();
        assert_eq!(ing.trace.kernels.len(), 1);
        let k = &ing.trace.kernels[0];
        assert_eq!(k.kernel_id, 1);
        assert_eq!(k.name, "_Z6vecAddPdS_S_");
        let kinds: Vec<&'static str> = k
            .records
            .iter()
            .map(|op| match op {
                Op::VAlu { .. } => "valu",
                Op::SAlu => "salu",
                Op::Load { .. } => "load",
                Op::Store { .. } => "store",
                Op::WaitCnt { .. } => "wait",
                Op::Barrier => "barrier",
                Op::LoopBegin { .. } => "loop",
                Op::LoopEnd { .. } => "endloop",
                Op::EndPgm => "end",
            })
            .collect();
        // IMAD→valu, S2R→salu, 2×LDG→load, DADD→valu, BAR→wait+barrier
        // (wait inserted to drain outstanding loads), STG→store,
        // EXIT→endpgm with a drain wait before it
        assert_eq!(
            kinds,
            vec![
                "valu", "salu", "load", "load", "valu", "wait", "barrier", "store", "wait", "end"
            ]
        );
        ing.trace.validate().unwrap();
        assert!(ing.warnings.is_empty(), "{:?}", ing.warnings);
    }

    #[test]
    fn second_warp_is_ignored_but_geometry_counts_all() {
        let ing = parse_accelsim(SAMPLE, "sample").unwrap();
        let k = &ing.trace.kernels[0];
        // 160 blocks x 1024 threads = 2560 waves of 64 lanes / 64 CUs = 40
        assert_eq!(k.waves_per_cu, 40);
        // only warp 0's 8 instructions were lowered (plus inserted waits)
        assert!(k.records.len() <= 11);
    }

    #[test]
    fn stride_is_derived_from_addresses() {
        let ing = parse_accelsim(SAMPLE, "sample").unwrap();
        let k = &ing.trace.kernels[0];
        let strides: Vec<u32> = k
            .records
            .iter()
            .filter_map(|op| match op {
                Op::Load {
                    pattern: Pattern::Strided { stride, .. },
                    ..
                } => Some(*stride),
                _ => None,
            })
            .collect();
        // second load observes the 0x40 delta from the first
        assert_eq!(strides.last(), Some(&64));
    }

    #[test]
    fn long_mem_runs_get_waitcnts_inserted() {
        let mut text = String::from(
            "-kernel name = k\n-grid dim = (1,1,1)\n-block dim = (64,1,1)\nwarp = 0\n",
        );
        for i in 0..40 {
            text.push_str(&format!(
                "{i:04x} ffffffff 1 R2 LDG.E.64 1 R2 8 1 0x{:x}\n",
                0x1000 + i * 64
            ));
        }
        text.push_str("0fff ffffffff 0 EXIT 0\n");
        let ing = parse_accelsim(&text, "t").unwrap();
        ing.trace.validate().unwrap();
        let waits = ing.trace.kernels[0]
            .records
            .iter()
            .filter(|op| matches!(op, Op::WaitCnt { .. }))
            .count();
        assert!(waits >= 2, "expected inserted waitcnts, got {waits}");
    }

    #[test]
    fn multiple_kernel_sections() {
        let text = "\
-kernel name = alpha
-grid dim = (1,1,1)
-block dim = (64,1,1)
warp = 0
0000 ffffffff 1 R1 FFMA 2 R1 R2
0010 ffffffff 0 EXIT 0
-kernel name = beta
-grid dim = (2,1,1)
-block dim = (64,1,1)
warp = 0
0000 ffffffff 1 R1 MOV 1 R1
0010 ffffffff 0 EXIT 0
";
        let ing = parse_accelsim(text, "t").unwrap();
        assert_eq!(ing.trace.kernels.len(), 2);
        assert_eq!(ing.trace.kernels[0].name, "alpha");
        assert_eq!(ing.trace.kernels[1].name, "beta");
        assert_eq!(ing.trace.rounds, 1);
    }

    #[test]
    fn shared_classifier_helpers() {
        assert_eq!(
            classify_pattern(3, 64, 1 << 20),
            Pattern::Strided { region: 3, stride: 64, working_set: 1 << 20 }
        );
        assert_eq!(
            classify_pattern(3, 0, 1 << 20),
            Pattern::Strided { region: 3, stride: 4, working_set: 1 << 20 }
        );
        assert_eq!(
            classify_pattern(7, 2048, 1 << 20),
            Pattern::Random { region: 7, working_set: 1 << 20 }
        );
        assert_eq!(fan_from_addrs(&[]), 1);
        assert_eq!(fan_from_addrs(&[0, 4, 8, 60]), 1); // one 64B line
        assert_eq!(fan_from_addrs(&[0, 64, 128]), 3);
        let scattered: Vec<u64> = (0..64).map(|i| i * 4096).collect();
        assert_eq!(fan_from_addrs(&scattered), 16); // clamped
        assert_eq!(normalize_waves(0), 1);
        assert_eq!(normalize_waves(64), 1);
        assert_eq!(normalize_waves(65), 2);
        assert_eq!(normalize_waves(1 << 40), WAVES_PER_CU_CAP);
    }

    #[test]
    fn garbage_errors_cleanly() {
        assert!(parse_accelsim("0000 not-a-trace", "t").is_err());
        assert!(parse_accelsim("", "t").is_err());
        assert!(
            parse_accelsim("-kernel name = k\n-grid dim = (x,1,1)\n", "t").is_err()
        );
    }
}
