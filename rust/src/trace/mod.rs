//! Trace subsystem: capture, ingest, synthesize, and replay wavefront
//! instruction traces as first-class workloads.
//!
//! The catalog generators ([`crate::workloads`]) cover the paper's 16
//! Table-II applications; this subsystem opens the workload space to
//! arbitrary instruction streams, the way accel-sim-style simulators
//! scale to real applications:
//!
//! * [`format`] — the versioned trace model with a hand-authorable text
//!   encoding and a length-prefixed binary encoding, structural
//!   validation, and content hashing;
//! * [`capture`] — record any workload's executed stream (from a spec,
//!   from a live simulator, or from an instrumented `workloads::exec`
//!   kernel execution) to a trace;
//! * [`ingest`] — lower external accel-sim-style kernel traces onto the
//!   [`crate::sim::isa`] micro-ISA;
//! * [`synth`] — seeded generator fuzzing randomized trace workloads for
//!   scenario diversity;
//! * [`diff`] — structural comparison of two traces (opcode mix, stride
//!   histograms, lengths) with a greppable `divergent: N` summary.
//!
//! Traces plug into everything that accepts a workload name via
//! [`crate::workloads::WorkloadSource`] (`trace:<path>` /
//! `synth:<seed>` / `exec:<kernel>:<size>` specs), and the sweep engine
//! fingerprints the trace *content hash* in its
//! [`crate::exec::key::RunKey`]s, so cached results can never be served
//! for an edited trace file.

pub mod capture;
pub mod diff;
pub mod format;
pub mod ingest;
pub mod synth;

pub use capture::{capture_gpu, capture_named, capture_recorded, capture_workload};
pub use diff::diff;
pub use format::{Trace, TraceKernel};
pub use ingest::parse_accelsim;
pub use synth::synthesize;
