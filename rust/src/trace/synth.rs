//! Seeded trace synthesizer: fuzzes randomized-but-valid trace workloads
//! for scenario diversity beyond the 16 Table-II generators.
//!
//! Every structural choice (kernel count, loop shape, phase mix, memory
//! pattern, divergence, launch geometry) is drawn from one
//! [`SplitMix64`] stream keyed by the seed, so `synthesize(s)` is a pure
//! function: the same seed always yields byte-identical traces, and a
//! synthesized trace saved to disk replays exactly like `synth:<seed>`.
//!
//! Generation is correct by construction — loops are emitted as
//! `LoopBegin`/body/`LoopEnd` sandwiches with backward targets, every
//! memory batch closes with `s_waitcnt`, and the result is run through
//! [`Trace::validate`] before being returned.

use crate::sim::isa::{Op, Pattern};
use crate::trace::format::{Trace, TraceKernel};
use crate::util::{hash2, SplitMix64};

/// Domain-separation tag so synth streams never collide with workload
/// seeds ("trace" in ASCII).
const SYNTH_TAG: u64 = 0x7472_6163_65;

/// Generate a randomized trace workload from `seed`.
pub fn synthesize(seed: u64) -> Trace {
    let mut rng = SplitMix64::new(hash2(seed, SYNTH_TAG));
    let n_kernels = 1 + rng.next_below(3) as usize;
    let kernels = (0..n_kernels)
        .map(|i| synth_kernel(&mut rng, i as u32))
        .collect();
    let t = Trace {
        name: format!("synth{seed}"),
        source: format!("synth:seed={seed}"),
        rounds: 1 + rng.next_below(4) as u32,
        kernels,
    };
    t.validate().expect("synthesizer produced an invalid trace");
    t
}

fn synth_kernel(rng: &mut SplitMix64, kernel_id: u32) -> TraceKernel {
    let mut rec: Vec<Op> = vec![Op::SAlu, Op::SAlu];

    // optional divergent warm-up loop (desynchronizes wavefronts)
    if rng.next_below(2) == 1 {
        let stagger = 8 + rng.next_below(57) as u16; // 8..=64
        rec.push(Op::LoopBegin {
            depth: 3,
            trips: stagger,
            divergence: stagger.saturating_sub(1),
        });
        let target = rec.len() as u32;
        rec.push(Op::VAlu {
            cycles: 4 + rng.next_below(12) as u8,
        });
        rec.push(Op::LoopEnd { depth: 3, target });
    }

    // main loop: 1..=3 phases per iteration, optional nested inner loop
    let trips = 4 + rng.next_below(28) as u16; // 4..=31
    let divergence = rng.next_below(1 + trips as u64 / 2) as u16;
    rec.push(Op::LoopBegin {
        depth: 0,
        trips,
        divergence,
    });
    let target = rec.len() as u32;
    let n_phases = 1 + rng.next_below(3);
    for _ in 0..n_phases {
        if rng.next_below(4) == 0 {
            // nested short loop around a phase
            let inner_trips = 2 + rng.next_below(5) as u16;
            rec.push(Op::LoopBegin {
                depth: 1,
                trips: inner_trips,
                divergence: rng.next_below(2) as u16,
            });
            let inner_target = rec.len() as u32;
            synth_phase(rng, kernel_id, &mut rec);
            rec.push(Op::LoopEnd {
                depth: 1,
                target: inner_target,
            });
        } else {
            synth_phase(rng, kernel_id, &mut rec);
        }
    }
    if rng.next_below(4) == 0 {
        rec.push(Op::Barrier);
    }
    rec.push(Op::LoopEnd { depth: 0, target });
    rec.push(Op::EndPgm);

    TraceKernel {
        kernel_id,
        name: format!("synth{kernel_id}"),
        waves_per_cu: 8 + rng.next_below(57), // 8..=64
        records: rec,
    }
}

/// One phase: a compute burst, a memory batch sequence, or a mix.
/// Memory batches always close with `s_waitcnt 0`, keeping outstanding
/// counters bounded regardless of loop nesting.
fn synth_phase(rng: &mut SplitMix64, kernel_id: u32, rec: &mut Vec<Op>) {
    let kind = rng.next_below(3);
    let pattern = synth_pattern(rng, kernel_id);
    let fan = 1 + rng.next_below(4) as u8;
    let valu_cycles = 1 + rng.next_below(6) as u8;
    let valu = match kind {
        0 => 4 + rng.next_below(60) as usize, // compute
        1 => 0,                               // memory
        _ => 2 + rng.next_below(24) as usize, // mixed
    };
    let mem = if kind == 0 {
        0
    } else {
        1 + rng.next_below(12) as usize
    };
    let batch = 1 + rng.next_below(8) as usize;
    let stores = rng.next_below(3) == 0; // some phases write

    let mut mem_left = mem;
    let mut valu_left = valu;
    let batches = mem.div_ceil(batch.max(1));
    let valu_per_batch = valu / (batches + 1);
    for _ in 0..batches {
        for _ in 0..batch.min(mem_left) {
            rec.push(if stores {
                Op::Store { pattern, fan }
            } else {
                Op::Load { pattern, fan }
            });
        }
        mem_left = mem_left.saturating_sub(batch);
        for _ in 0..valu_per_batch.min(valu_left) {
            rec.push(Op::VAlu {
                cycles: valu_cycles,
            });
        }
        valu_left -= valu_per_batch.min(valu_left);
        rec.push(Op::WaitCnt { max: 0 });
    }
    for _ in 0..valu_left {
        rec.push(Op::VAlu {
            cycles: valu_cycles,
        });
    }
}

fn synth_pattern(rng: &mut SplitMix64, kernel_id: u32) -> Pattern {
    let region = ((kernel_id as u64 * 8 + rng.next_below(8)) % 250) as u8;
    let working_set = 1u32 << (20 + rng.next_below(8)); // 1 MB .. 128 MB
    if rng.next_below(3) == 0 {
        Pattern::Random {
            region,
            working_set,
        }
    } else {
        Pattern::Strided {
            region,
            stride: 64 << rng.next_below(3), // 64/128/256
            working_set,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthesis_is_deterministic() {
        for seed in [0u64, 1, 7, 0xdead_beef] {
            let a = synthesize(seed);
            let b = synthesize(seed);
            assert_eq!(a, b);
            assert_eq!(a.content_hash(), b.content_hash());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(synthesize(1), synthesize(2));
        assert_ne!(
            synthesize(1).content_hash(),
            synthesize(2).content_hash()
        );
    }

    #[test]
    fn a_seed_sweep_is_always_valid() {
        for seed in 0..64u64 {
            let t = synthesize(seed);
            t.validate()
                .unwrap_or_else(|e| panic!("seed {seed} invalid: {e}"));
            assert!(!t.kernels.is_empty());
            for k in &t.kernels {
                assert!(k.waves_per_cu >= 8);
                assert!(matches!(k.records.last(), Some(Op::EndPgm)));
            }
        }
    }

    #[test]
    fn synth_traces_roundtrip_both_encodings() {
        let t = synthesize(42);
        assert_eq!(
            crate::trace::format::Trace::parse_text(&t.to_text()).unwrap(),
            t
        );
        assert_eq!(
            crate::trace::format::Trace::parse_binary(&t.to_binary()).unwrap(),
            t
        );
    }

    #[test]
    fn synth_traces_simulate_and_commit_work() {
        use crate::config::SimConfig;
        use crate::sim::gpu::Gpu;
        let t = synthesize(9);
        let mut gpu = Gpu::new(SimConfig::small());
        gpu.load_workload(t.launches_scaled(0.25), t.rounds);
        for _ in 0..4 {
            gpu.run_epoch();
        }
        assert!(gpu.total_instr() > 0, "synthesized trace committed nothing");
    }
}
