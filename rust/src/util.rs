//! Small deterministic utilities shared across the crate.
//!
//! The simulator is *fully deterministic*: all pseudo-randomness flows
//! through [`SplitMix64`] streams seeded from explicit values, so a
//! snapshot/restore (the oracle's "fork") replays bit-identically.

/// SplitMix64 PRNG — tiny, fast, and serializable (one u64 of state).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        mix(self.state)
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // 128-bit multiply avoids modulo bias well enough for simulation.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }
}

/// Stateless hash used for address-stream generation: the address of the
/// i-th access of a given (cu, wf, pc) triple never depends on execution
/// interleaving, which keeps cross-frequency re-execution comparable.
#[inline]
pub fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Combine hash inputs (order-sensitive).
#[inline]
pub fn hash2(a: u64, b: u64) -> u64 {
    mix(a.wrapping_mul(0x9E3779B97F4A7C15) ^ b)
}

#[inline]
pub fn hash3(a: u64, b: u64, c: u64) -> u64 {
    hash2(hash2(a, b), c)
}

/// Geometric mean of strictly positive values; returns NaN for empty input.
pub fn geomean(values: &[f64]) -> f64 {
    if values.is_empty() {
        return f64::NAN;
    }
    let s: f64 = values.iter().map(|v| v.max(1e-300).ln()).sum();
    (s / values.len() as f64).exp()
}

/// Ordinary least squares fit `y = a + b x`; returns (intercept, slope, r2).
pub fn linreg(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.len() < 2 {
        return (ys.first().copied().unwrap_or(0.0), 0.0, 1.0);
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let syy: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    if sxx <= 0.0 {
        return (my, 0.0, 1.0);
    }
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy <= 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (a, b, r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_seeds_diverge() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn next_below_in_range() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            assert!(r.next_below(10) < 10);
        }
    }

    #[test]
    fn next_f64_in_unit_interval() {
        let mut r = SplitMix64::new(9);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn hash_is_stable() {
        assert_eq!(hash2(1, 2), hash2(1, 2));
        assert_ne!(hash2(1, 2), hash2(2, 1));
        assert_ne!(hash3(1, 2, 3), hash3(1, 3, 2));
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[4.0, 1.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[3.0]) - 3.0).abs() < 1e-12);
        assert!(geomean(&[]).is_nan());
    }

    #[test]
    fn linreg_recovers_line() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b, r2) = linreg(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn linreg_degenerate_inputs() {
        let (a, b, _) = linreg(&[1.0], &[5.0]);
        assert_eq!((a, b), (5.0, 0.0));
        let (a, b, _) = linreg(&[2.0, 2.0], &[1.0, 3.0]);
        assert_eq!(b, 0.0);
        assert_eq!(a, 2.0);
    }
}
