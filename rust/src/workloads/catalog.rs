//! The 16-application catalog (paper Table II): 9 HPC apps + 7 MI apps.
//!
//! Each builder encodes the paper's reported character for that app —
//! see the table in DESIGN.md §2.2 for the paper-evidence → generator
//! mapping.  Region ids keep address spaces distinct across kernels.

use crate::sim::isa::Pattern;

use super::spec::{KernelSpec, PhaseSpec, WorkloadSpec};

/// A built workload plus its provenance notes.
pub type Workload = WorkloadSpec;

const MB: u32 = 1 << 20;

fn strided(region: u8, stride: u32, ws: u32) -> Pattern {
    Pattern::Strided {
        region,
        stride,
        working_set: ws,
    }
}

fn random(region: u8, ws: u32) -> Pattern {
    Pattern::Random {
        region,
        working_set: ws,
    }
}

/// All workload names in Table II order (HPC then MI).
pub fn names() -> Vec<&'static str> {
    vec![
        "comd", "hpgmg", "lulesh", "minife", "xsbench", "hacc", "quickS", "pennant", "snapc",
        "dgemm", "BwdBN", "BwdPool", "BwdSoft", "FwdBN", "FwdPool", "FwdSoft",
    ]
}

/// Phase-granularity divisor: each kernel's loop body is split into
/// `PHASE_SCALE`x shorter iterations (same total work via `trips` x
/// `PHASE_SCALE`).  Keeps phase alternation well below the 1 µs epoch so
/// epochs sample phase *mixtures* rather than whole phases — matching the
/// paper's reported variability magnitudes.
const PHASE_SCALE: u16 = 1;

fn rescale(mut spec: WorkloadSpec) -> WorkloadSpec {
    let d = PHASE_SCALE;
    for k in &mut spec.kernels {
        for p in &mut k.phases {
            if p.valu > 0 {
                p.valu = (p.valu / d).max(2);
            }
            if p.loads > 0 {
                p.loads = (p.loads / d).max(1);
            }
            if p.stores > 0 {
                p.stores = (p.stores / d).max(1);
            }
            let mem = p.loads + p.stores;
            if mem > 0 {
                p.waitcnt_batch = p.waitcnt_batch.min(mem as u8).max(1);
            }
        }
        k.trips = k.trips.saturating_mul(d);
    }
    spec
}

/// Build a workload by name.  `waves_scale` multiplies waves-per-CU
/// (completion-run length knob); panics on unknown names (the CLI
/// validates first).
pub fn build(name: &str, waves_scale: f64) -> Workload {
    let w = |base: u64| ((base as f64 * waves_scale).round() as u64).max(1);
    rescale(match name {
        // ---------------- HPC (ECP proxy apps) ----------------
        // Molecular dynamics: alternating neighbour-list streaming and
        // force computation; the paper's Fig. 5 linearity example.
        "comd" => WorkloadSpec {
            name: name.into(),
            kernels: vec![KernelSpec {
                name: "force".into(),
                phases: vec![
                    PhaseSpec::mixed(60, 2, 8, strided(1, 64, 8 * MB), 1, 4),
                    PhaseSpec::compute(90, 2),
                    PhaseSpec::memory(6, 2, strided(2, 64, 8 * MB), 1, 4),
                ],
                trips: 24,
                divergence: 4,
                barrier: false,
                waves_per_cu: w(96),
                stagger: 64,
            }],
            rounds: 8,
        },
        // Full multigrid: long-stride streaming, L2-miss heavy, little
        // compute — the paper's low-frequency resident (Fig. 16).
        "hpgmg" => WorkloadSpec {
            name: name.into(),
            kernels: vec![KernelSpec {
                name: "smooth".into(),
                phases: vec![
                    PhaseSpec::memory(24, 6, strided(3, 256, 64 * MB), 1, 6),
                    PhaseSpec::compute(12, 1),
                ],
                trips: 20,
                divergence: 2,
                barrier: false,
                waves_per_cu: w(64),
                stagger: 64,
            }],
            rounds: 8,
        },
        // Shock hydro: 27 distinct kernels with varying mixes.
        "lulesh" => WorkloadSpec {
            name: name.into(),
            kernels: (0..27)
                .map(|i| {
                    // deterministic per-kernel mix: sweep compute share
                    let c = 20 + (i * 13) % 120;
                    let m = 4 + (i * 7) % 16;
                    KernelSpec {
                        name: format!("k{i}"),
                        phases: vec![PhaseSpec::mixed(
                            c as u16,
                            2,
                            m as u16,
                            strided(4 + (i % 4) as u8, 64, 16 * MB),
                            1,
                            4,
                        )],
                        trips: 10,
                        divergence: 2,
                        barrier: false,
                        waves_per_cu: w(24),
                stagger: 64,
                    }
                })
                .collect(),
            rounds: 2,
        },
        // Finite element: indexed gathers + short FMA chains.
        "minife" => WorkloadSpec {
            name: name.into(),
            kernels: (0..3)
                .map(|i| KernelSpec {
                    name: format!("spmv{i}"),
                    phases: vec![
                        PhaseSpec::mixed(24, 2, 10, random(8 + i as u8, 32 * MB), 2, 5),
                        PhaseSpec::compute(30, 2),
                    ],
                    trips: 16,
                    divergence: 3,
                    barrier: false,
                    waves_per_cu: w(48),
                stagger: 64,
                })
                .collect(),
            rounds: 4,
        },
        // Monte Carlo transport: random table lookups, DRAM-latency
        // bound, near-zero sensitivity (Fig. 6d).
        "xsbench" => WorkloadSpec {
            name: name.into(),
            kernels: vec![KernelSpec {
                name: "xs_lookup".into(),
                phases: vec![PhaseSpec::mixed(6, 1, 16, random(12, 256 * MB), 2, 2)],
                trips: 24,
                divergence: 6,
                barrier: false,
                waves_per_cu: w(64),
                stagger: 64,
            }],
            rounds: 8,
        },
        // Cosmology: FMA-dense force kernels, high sensitivity (Fig. 6b).
        "hacc" => WorkloadSpec {
            name: name.into(),
            kernels: vec![
                KernelSpec {
                    name: "step".into(),
                    phases: vec![
                        PhaseSpec::compute(320, 4),
                        PhaseSpec::memory(4, 2, strided(13, 64, 4 * MB), 1, 4),
                    ],
                    trips: 18,
                    divergence: 2,
                    barrier: false,
                    waves_per_cu: w(80),
                stagger: 64,
                },
                KernelSpec {
                    name: "fft".into(),
                    phases: vec![PhaseSpec::mixed(120, 3, 6, strided(14, 128, 8 * MB), 1, 6)],
                    trips: 12,
                    divergence: 0,
                    barrier: false,
                    waves_per_cu: w(48),
                stagger: 64,
                },
            ],
            rounds: 6,
        },
        // Quicksilver: the paper's highest inter-wavefront variation
        // (Fig. 11a) — heavy trip-count divergence + random access.
        "quickS" => WorkloadSpec {
            name: name.into(),
            kernels: vec![KernelSpec {
                name: "track".into(),
                phases: vec![
                    PhaseSpec::mixed(40, 2, 8, random(16, 64 * MB), 2, 4),
                    PhaseSpec::compute(30, 2),
                ],
                trips: 18,
                divergence: 15,
                barrier: false,
                waves_per_cu: w(64),
                stagger: 64,
            }],
            rounds: 8,
        },
        // Unstructured mesh: 5 kernels, gather + compute mixes.
        "pennant" => WorkloadSpec {
            name: name.into(),
            kernels: (0..5)
                .map(|i| KernelSpec {
                    name: format!("mesh{i}"),
                    phases: vec![
                        PhaseSpec::mixed(
                            30 + 20 * (i % 3) as u16,
                            2,
                            8,
                            random(20 + i as u8, 24 * MB),
                            2,
                            4,
                        ),
                        PhaseSpec::compute(20 + 10 * (i % 2) as u16, 3),
                    ],
                    trips: 12,
                    divergence: 4,
                    barrier: false,
                    waves_per_cu: w(32),
                stagger: 64,
                })
                .collect(),
            rounds: 3,
        },
        // Discrete ordinates sweep: wavefront-staggered compute with
        // barriers per iteration.
        "snapc" => WorkloadSpec {
            name: name.into(),
            kernels: vec![KernelSpec {
                name: "sweep".into(),
                phases: vec![
                    PhaseSpec::compute(80, 2),
                    PhaseSpec::memory(6, 2, strided(26, 64, 8 * MB), 1, 6),
                ],
                trips: 20,
                divergence: 5,
                barrier: true,
                waves_per_cu: w(64),
                stagger: 64,
            }],
            rounds: 6,
        },
        // ---------------- MI (DeepBench / DNNMark) ----------------
        // DGEMM: tile-load then long FMA burst — compute-intensive but
        // heterogeneous (paper notes its lower prediction accuracy).
        "dgemm" => WorkloadSpec {
            name: name.into(),
            kernels: vec![KernelSpec {
                name: "gemm".into(),
                phases: vec![
                    PhaseSpec::memory(16, 0, strided(28, 64, 2 * MB), 1, 8),
                    PhaseSpec::compute(360, 4),
                    PhaseSpec::memory(0, 4, strided(29, 64, 2 * MB), 1, 4),
                ],
                trips: 16,
                divergence: 0,
                barrier: false,
                waves_per_cu: w(96),
                stagger: 64,
            }],
            rounds: 8,
        },
        // BatchNorm backward: reduction (memory) and elementwise
        // (compute) alternation — the paper's Fig. 6c / Fig. 8 subject.
        "BwdBN" => WorkloadSpec {
            name: name.into(),
            kernels: vec![KernelSpec {
                name: "bn_bwd".into(),
                phases: vec![
                    PhaseSpec::memory(20, 0, strided(32, 64, MB), 1, 10),
                    PhaseSpec::compute(120, 2),
                    PhaseSpec::memory(10, 10, strided(33, 64, MB), 1, 5),
                ],
                trips: 14,
                divergence: 3,
                barrier: false,
                waves_per_cu: w(64),
                stagger: 64,
            }],
            rounds: 8,
        },
        // Pooling backward: steady uniform mix — the paper reports it
        // locks onto a single frequency (Fig. 16).
        "BwdPool" => WorkloadSpec {
            name: name.into(),
            kernels: vec![KernelSpec {
                name: "pool_bwd".into(),
                phases: vec![PhaseSpec::mixed(48, 2, 8, strided(36, 64, 2 * MB), 1, 4)],
                trips: 30,
                divergence: 0,
                barrier: false,
                waves_per_cu: w(80),
                stagger: 64,
            }],
            rounds: 8,
        },
        // Softmax backward: moderate mixed behaviour.
        "BwdSoft" => WorkloadSpec {
            name: name.into(),
            kernels: vec![KernelSpec {
                name: "softmax_bwd".into(),
                phases: vec![
                    PhaseSpec::mixed(36, 2, 10, strided(40, 64, 3 * MB), 1, 5),
                    PhaseSpec::compute(40, 1),
                ],
                trips: 18,
                divergence: 2,
                barrier: false,
                waves_per_cu: w(64),
                stagger: 64,
            }],
            rounds: 8,
        },
        // BatchNorm forward: like BwdBN with a larger elementwise share.
        "FwdBN" => WorkloadSpec {
            name: name.into(),
            kernels: vec![KernelSpec {
                name: "bn_fwd".into(),
                phases: vec![
                    PhaseSpec::memory(14, 0, strided(44, 64, MB), 1, 7),
                    PhaseSpec::compute(160, 2),
                ],
                trips: 16,
                divergence: 2,
                barrier: false,
                waves_per_cu: w(72),
                stagger: 64,
            }],
            rounds: 8,
        },
        // Pooling forward: steady, slightly more compute than backward.
        "FwdPool" => WorkloadSpec {
            name: name.into(),
            kernels: vec![KernelSpec {
                name: "pool_fwd".into(),
                phases: vec![PhaseSpec::mixed(60, 2, 8, strided(48, 64, 2 * MB), 1, 4)],
                trips: 30,
                divergence: 0,
                barrier: false,
                waves_per_cu: w(80),
                stagger: 64,
            }],
            rounds: 8,
        },
        // Softmax forward: L2-sized shared working set -> cache pressure
        // grows with aggregate frequency (the paper's 2.2 GHz thrashing
        // anomaly, §6.2).
        "FwdSoft" => WorkloadSpec {
            name: name.into(),
            kernels: vec![KernelSpec {
                name: "softmax_fwd".into(),
                phases: vec![
                    PhaseSpec::memory(28, 0, strided(52, 64, 6 * MB), 2, 14),
                    PhaseSpec::compute(24, 1),
                ],
                trips: 22,
                divergence: 1,
                barrier: false,
                waves_per_cu: w(64),
                stagger: 64,
            }],
            rounds: 8,
        },
        other => panic!("unknown workload: {other} (see workloads::names())"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_has_all_table2_apps() {
        assert_eq!(names().len(), 16);
        for n in names() {
            let w = build(n, 1.0);
            assert_eq!(w.name, n);
            assert!(!w.kernels.is_empty());
        }
    }

    #[test]
    fn kernel_counts_match_table2() {
        assert_eq!(build("lulesh", 1.0).kernels.len(), 27);
        assert_eq!(build("minife", 1.0).kernels.len(), 3);
        assert_eq!(build("pennant", 1.0).kernels.len(), 5);
        assert_eq!(build("hacc", 1.0).kernels.len(), 2);
        assert_eq!(build("dgemm", 1.0).kernels.len(), 1);
    }

    #[test]
    fn all_programs_validate() {
        for n in names() {
            for launch in build(n, 1.0).launches() {
                assert!(
                    launch.program.validate().is_ok(),
                    "workload {n} kernel {} invalid",
                    launch.program.name
                );
            }
        }
    }

    #[test]
    fn waves_scale_shrinks_runs() {
        let full = build("comd", 1.0);
        let tiny = build("comd", 0.1);
        assert!(tiny.kernels[0].waves_per_cu < full.kernels[0].waves_per_cu);
        assert!(tiny.kernels[0].waves_per_cu >= 1);
    }

    #[test]
    fn pc_footprint_fits_paper_table_sizing() {
        // Paper §4.4: 128 entries x 4 instructions cover ~512
        // instructions; most workload kernels should fit that budget.
        let mut fitting = 0;
        let mut total = 0;
        for n in names() {
            for k in &build(n, 1.0).kernels {
                total += 1;
                if k.static_instrs() <= 512 {
                    fitting += 1;
                }
            }
        }
        assert!(
            fitting * 10 >= total * 9,
            "only {fitting}/{total} kernels fit the PC table coverage"
        );
    }

    #[test]
    fn unknown_workload_panics() {
        let r = std::panic::catch_unwind(|| build("nope", 1.0));
        assert!(r.is_err());
    }

    #[test]
    fn hacc_is_compute_heavy_xsbench_is_not() {
        let hacc = build("hacc", 1.0);
        let xs = build("xsbench", 1.0);
        let compute_share = |w: &WorkloadSpec| {
            let mut valu = 0usize;
            let mut mem = 0usize;
            for k in &w.kernels {
                for p in &k.phases {
                    valu += p.valu as usize * p.valu_cycles as usize;
                    mem += (p.loads + p.stores) as usize;
                }
            }
            valu as f64 / (valu + mem * 30) as f64
        };
        assert!(compute_share(&hacc) > 0.8, "{}", compute_share(&hacc));
        assert!(compute_share(&xs) < 0.3, "{}", compute_share(&xs));
    }
}
